"""Integration: train loop drives loss down; checkpoint/restart after an
injected failure is bit-exact vs an uninterrupted run; microbatch
accumulation equals full-batch gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import make_batch_for
from repro.distributed.fault import FailureInjector, StragglerMonitor
from repro.models import Runtime, build
from repro.optim.adamw import AdamWConfig
from repro.train import (LoopConfig, TrainConfig, init_train_state,
                         make_train_step, train_loop)

RT = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")


def small_setup(arch="qwen2_5_3b", microbatches=1):
    cfg = get_smoke_config(arch)
    api = build(cfg)
    tcfg = TrainConfig(microbatches=microbatches, peak_lr=1e-2,
                       warmup_steps=5, total_steps=60, optimizer="adamw",
                       adamw=AdamWConfig(weight_decay=0.0))
    step_fn = jax.jit(make_train_step(api, RT, tcfg))
    return cfg, api, tcfg, step_fn


def test_loss_decreases():
    cfg, api, tcfg, step_fn = small_setup()
    lcfg = LoopConfig(total_steps=30, seq_len=32, global_batch=8,
                      ckpt_dir=None, log_every=1000)
    state, hist = train_loop(api, RT, tcfg, lcfg, step_fn,
                             log=lambda *a: None)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.5, (first, last)


def test_microbatch_equals_fullbatch_grads():
    cfg, api, tcfg, _ = small_setup()
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch_for(cfg, 0, 32, 8)

    from repro.train.train_step import _microbatch_grads
    l1, g1 = _microbatch_grads(api, params, batch, RT, 1)
    l4, g4 = _microbatch_grads(api, params, batch, RT, 4)
    assert float(l1) == pytest.approx(float(l4), rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-4)


def test_failure_recovery_bit_exact(tmp_path):
    """Run A: uninterrupted.  Run B: crashes at steps 7 and 13, restarts
    from checkpoints.  Final params must be bit-identical (stateless data +
    exact checkpoints)."""
    cfg, api, tcfg, step_fn = small_setup()

    lcfg_a = LoopConfig(total_steps=20, seq_len=32, global_batch=8,
                        ckpt_dir=str(tmp_path / "a"), ckpt_every=5,
                        log_every=1000)
    state_a, _ = train_loop(api, RT, tcfg, lcfg_a, step_fn,
                            log=lambda *a: None)

    lcfg_b = LoopConfig(total_steps=20, seq_len=32, global_batch=8,
                        ckpt_dir=str(tmp_path / "b"), ckpt_every=5,
                        log_every=1000)
    inj = FailureInjector(fail_at_steps=(7, 13))
    state_b, _ = train_loop(api, RT, tcfg, lcfg_b, step_fn, injector=inj,
                            log=lambda *a: None)

    for a, b in zip(jax.tree_util.tree_leaves(state_a["params"]),
                    jax.tree_util.tree_leaves(state_b["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_roundtrip_bf16(tmp_path):
    from repro.checkpoint import manager as ckpt
    tree = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (17, 9)),
                             jnp.bfloat16),
            "n": {"b": jnp.arange(5, dtype=jnp.int32)},
            "s": jnp.float32(3.25)}
    ckpt.save(tree, str(tmp_path), step=3)
    back = ckpt.restore(tree, str(tmp_path))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_three(tmp_path):
    from repro.checkpoint import manager as ckpt
    tree = {"w": jnp.ones((4,))}
    for s in range(6):
        ckpt.save(tree, str(tmp_path), step=s)
    import os
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 3 and kept[-1] == "step_00000005"


def test_straggler_monitor():
    m = StragglerMonitor()
    for s in range(10):
        m.observe(s, 1.0)
    assert m.observe(10, 5.0) is True
    assert m.recommendation() in ("monitor", "exclude-host-and-reshard")
    for s in range(11, 14):
        m.observe(s, 5.0)
    assert m.recommendation() == "exclude-host-and-reshard"


def test_adafactor_trains():
    cfg = get_smoke_config("qwen2_5_3b")
    api = build(cfg)
    tcfg = TrainConfig(microbatches=1, peak_lr=1e-2, warmup_steps=2,
                       total_steps=30, optimizer="adafactor")
    step_fn = jax.jit(make_train_step(api, RT, tcfg))
    state = init_train_state(api.init(jax.random.PRNGKey(0)), tcfg, False)
    losses = []
    for s in range(25):
        state, m = step_fn(state, make_batch_for(cfg, s, 32, 8))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3
