"""Paged KV cache: block allocator invariants, paged-vs-dense token
parity across schedulers (greedy and sampled, with oversubscription and
mid-wave admissions), blocked-head non-starvation under KV admission
control, and the serving observability surface."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api as rapi
from repro.configs import get_smoke_config
from repro.models import Runtime, build
from repro.serve import DONE, FAILED, Request
from repro.serve.paged_kv import TRASH_BLOCK, BlockAllocator, blocks_for

RT = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")


# -- allocator unit tests (no model) -----------------------------------------

def test_allocator_never_hands_out_trash_block():
    a = BlockAllocator(n_blocks=5, block_size=8)
    got = a.alloc(4)
    assert got is not None and TRASH_BLOCK not in got
    assert sorted(got) == [1, 2, 3, 4]
    assert a.available == 0


def test_allocator_all_or_nothing():
    a = BlockAllocator(n_blocks=5, block_size=8)
    first = a.alloc(3)
    assert a.alloc(2) is None, "over-ask must not partially allocate"
    assert a.available == 1, "failed alloc must leave the free list intact"
    more = a.alloc(1)
    assert more is not None
    a.free(first + more)
    assert a.available == 4 and a.in_use == 0
    assert a.peak_in_use == 4


def test_allocator_rejects_double_and_bogus_free():
    a = BlockAllocator(n_blocks=4, block_size=8)
    got = a.alloc(2)
    a.free(got)
    with pytest.raises(ValueError):
        a.free(got)                     # double free
    with pytest.raises(ValueError):
        a.free([TRASH_BLOCK])           # trash block is not allocatable
    with pytest.raises(ValueError):
        a.free([99])                    # out of range


def test_blocks_for_rounding():
    lp, need = blocks_for(prompt_len=6, max_new=4, block_size=8)
    assert (lp, need) == (8, 2)         # 8 prompt slots + 4 new -> 2 blocks
    lp, need = blocks_for(prompt_len=16, max_new=0, block_size=8)
    assert (lp, need) == (16, 2)
    lp, need = blocks_for(prompt_len=1, max_new=1, block_size=4)
    assert (lp, need) == (4, 2)


# -- engine-level parity ------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    return cfg, api, base


def _experts(api, base, n=3, scale=0.03, density=0.2):
    out = []
    for i in range(n):
        leaves, tdef = jax.tree_util.tree_flatten(base)
        keys = jax.random.split(jax.random.PRNGKey(100 + i), len(leaves))
        ft = jax.tree_util.tree_unflatten(tdef, [
            (l.astype(jnp.float32)
             + scale * jax.random.normal(k, l.shape)).astype(l.dtype)
            for l, k in zip(leaves, keys)])
        out.append(rapi.compress(base, ft, name=f"expert{i}",
                                 density=density))
    return out


def _mk_reqs(cfg, n=6, n_experts=2, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, expert=f"expert{i % n_experts}",
                    prompt=jnp.asarray(
                        rng.integers(1, cfg.vocab, 5 + 3 * (i % 3)),
                        jnp.int32),
                    max_new_tokens=2 + i % 3)
            for i in range(n)]


def _run(smoke_lm, reqs, **kw):
    cfg, api, base = smoke_lm
    kw.setdefault("max_batch", 3)
    kw.setdefault("cache_len", 64)
    kw.setdefault("max_stack", 2)
    kw.setdefault("decode_chunk", 2)
    eng = rapi.serve(api, RT, base,
                     rapi.registry(experts=_experts(api, base)), **kw)
    eng.run(reqs)
    return eng, {r.uid: list(r.out_tokens) for r in reqs}


def test_paged_matches_dense_with_refills(smoke_lm):
    """Block-table KV is bit-identical to the dense left-pad path on an
    oversubscribed workload (6 requests, 3 slots => mid-wave admissions),
    for every scheduler."""
    cfg = smoke_lm[0]
    eng_d, dense = _run(smoke_lm, _mk_reqs(cfg), kv_layout="dense")
    assert sum(w["admitted"] for w in eng_d.wave_log) >= 1
    for sched in ("fifo", "priority", "affinity"):
        eng_p, paged = _run(smoke_lm, _mk_reqs(cfg), kv_layout="paged",
                            kv_block_size=8, scheduler=sched)
        assert paged == dense, f"paged/{sched} diverged from dense"
        assert eng_p.swap_summary()["kv"]["layout"] == "paged"


def test_paged_sampling_matches_dense(smoke_lm):
    """Seeded sampling is invariant to the KV layout: streams are keyed
    by (seed, uid, draw index), not by where the KV rows live."""
    cfg = smoke_lm[0]
    samp = dict(temperature=0.8, top_k=5, seed=7)
    _, dense = _run(smoke_lm, _mk_reqs(cfg), kv_layout="dense", **samp)
    _, paged = _run(smoke_lm, _mk_reqs(cfg), kv_layout="paged",
                    kv_block_size=8, scheduler="affinity", **samp)
    assert paged == dense


def test_paged_pool_oversubscription_requeues(smoke_lm):
    """A pool smaller than the wave's demand re-queues the overflow rows
    instead of failing them; everything still completes and the tokens
    still match the dense baseline."""
    cfg = smoke_lm[0]
    _, dense = _run(smoke_lm, _mk_reqs(cfg), kv_layout="dense")
    eng, paged = _run(smoke_lm, _mk_reqs(cfg), kv_layout="paged",
                      kv_block_size=8, kv_blocks=7, scheduler="priority")
    assert paged == dense
    kv = eng.swap_summary()["kv"]
    assert kv["blocks_total"] == 6 and kv["blocks_peak"] <= 6


def test_blocked_head_does_not_starve_followers(smoke_lm):
    """Satellite fix: a head that cannot be placed (KV blocks exhausted)
    must not stall placeable requests behind it under the non-FIFO
    schedulers — FIFO keeps the historical head-of-line blocking."""
    cfg, api, base = smoke_lm

    def reqs():
        rng = np.random.default_rng(0)
        return [
            Request(uid=0, expert="expert0", max_new_tokens=10,
                    prompt=jnp.asarray(rng.integers(1, cfg.vocab, 6),
                                       jnp.int32)),       # 3 blocks, long-run
            Request(uid=1, expert="expert0", max_new_tokens=2,
                    prompt=jnp.asarray([5, 6, 7], jnp.int32)),  # 2, quick
            Request(uid=2, expert="expert0", max_new_tokens=8,
                    prompt=jnp.asarray(rng.integers(1, cfg.vocab, 30),
                                       jnp.int32)),       # 5 blocks: big head
            Request(uid=3, expert="expert0", max_new_tokens=2,
                    prompt=jnp.asarray([8, 9, 10], jnp.int32)),  # 2, fits
        ]

    # 6 usable blocks: wave = {uid0 (3), uid1 (2)}; when uid1 frees, the
    # head uid2 needs 5 > 3 available, but uid3 needs only 2.
    kw = dict(kv_layout="paged", kv_block_size=8, kv_blocks=7,
              max_batch=2, decode_chunk=2)
    rp = reqs()
    eng_p, toks_p = _run(smoke_lm, rp, scheduler="priority", **kw)
    assert all(len(t) for t in toks_p.values())
    assert rp[3].t_first_s < rp[2].t_first_s, \
        "priority scheduler should admit uid3 past the blocked head uid2"
    assert eng_p.swap_summary()["scheduler"]["deferred"] >= 1

    rf = reqs()
    eng_f, toks_f = _run(smoke_lm, rf, scheduler="fifo", **kw)
    assert toks_f == toks_p, "tokens are scheduler-invariant"
    assert rf[2].t_first_s < rf[3].t_first_s, \
        "strict FIFO must keep head-of-line order (uid2 before uid3)"


def test_serving_observability_surface(smoke_lm):
    """swap_summary() and registry.health() expose the new gauges: KV
    block occupancy, per-priority admission wait, stack hit-rate."""
    cfg, api, base = smoke_lm
    reg = rapi.registry(experts=_experts(api, base))
    eng = rapi.serve(api, RT, base, reg, max_batch=3, cache_len=64,
                     max_stack=2, decode_chunk=2, kv_layout="paged",
                     kv_block_size=8, scheduler="affinity")
    eng.run(_mk_reqs(cfg))
    s = eng.swap_summary()
    assert 0.0 <= s["stack_hit_rate"] <= 1.0
    assert s["scheduler"]["policy"] == "affinity"
    assert s["scheduler"]["queue_depth_max"] >= 1
    assert "admission_wait_s" in s["scheduler"]
    for wait in s["scheduler"]["admission_wait_s"].values():
        assert wait["n"] >= 1 and wait["max"] >= wait["mean"] >= 0.0
    kv = s["kv"]
    assert kv["layout"] == "paged" and kv["block_size"] == 8
    assert kv["blocks_in_use"] == 0, "end of run must free every block"
    assert kv["blocks_peak"] >= 1
    h = reg.health()
    assert "serving" in h
    assert h["serving"]["scheduler"]["policy"] == "affinity"
    assert h["serving"]["kv"]["layout"] == "paged"


def test_paged_rejects_impossible_requests(smoke_lm):
    """A request that can never fit the pool fails terminally instead of
    deadlocking admission."""
    cfg, api, base = smoke_lm
    big = Request(uid=0, expert="expert0", max_new_tokens=60,
                  prompt=jnp.asarray(np.arange(2, 40), jnp.int32))
    ok = Request(uid=1, expert="expert0", max_new_tokens=2,
                 prompt=jnp.asarray([5, 6, 7], jnp.int32))
    eng, toks = _run(smoke_lm, [big, ok], kv_layout="paged",
                     kv_block_size=8)
    assert big.status == FAILED and not toks[0]
    assert big.error
    assert ok.status == DONE and len(toks[1]) == 2
