"""Chaos serving: injected transport faults must degrade to per-request
FAILED statuses — never a crashed engine — while healthy requests stay
bit-identical to a no-fault run, and the whole schedule is deterministic
under the seed (the ``perf_lab --exp chaos_serve`` gate, unit-sized)."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api as rapi
from repro.configs import get_smoke_config
from repro.models import Runtime, build
from repro.serve import DONE, FAILED, ExpertUnavailable, Request
from repro.transport import ChaosFault, ChaosTransport, InMemoryTransport

RT = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")
N_EXPERTS = 3


@pytest.fixture(scope="module")
def fixture():
    """Model + experts published over a transport (built once: the model
    compile dominates test time)."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    experts = []
    for i in range(N_EXPERTS):
        leaves, tdef = jax.tree_util.tree_flatten(base)
        keys = jax.random.split(jax.random.PRNGKey(100 + i), len(leaves))
        ft = jax.tree_util.tree_unflatten(tdef, [
            (l.astype(jnp.float32)
             + 0.01 * jax.random.normal(k, l.shape)).astype(l.dtype)
            for l, k in zip(leaves, keys)])
        experts.append(rapi.compress(base, ft, name=f"expert{i}",
                                     density=0.2))
    rng = np.random.default_rng(0)
    prompts = [jnp.asarray(rng.integers(1, cfg.vocab, 6), jnp.int32)
               for _ in range(8)]
    return api, base, experts, prompts


def _registry(experts, faults=(), blackout=(), **kw):
    inner = InMemoryTransport()
    for e in experts:
        rapi.publish(e, inner)
    tr = (ChaosTransport(inner, faults=faults, blackout=blackout, seed=0)
          if (faults or blackout) else inner)
    kw.setdefault("quarantine_after", 1)
    kw.setdefault("quarantine_probe_s", 1000.0)
    return rapi.registry(transport=tr, **kw), tr


def _reqs(prompts, experts_by_uid, max_new=3):
    return [Request(uid=i, expert=e, prompt=prompts[i],
                    max_new_tokens=max_new)
            for i, e in enumerate(experts_by_uid)]


def test_blackout_fails_only_affected_requests(fixture):
    api, base, experts, prompts = fixture
    stream = ["expert0", "expert1", "expert2", "expert0", "expert1",
              "expert2"]

    reg0, _ = _registry(experts)
    eng0 = rapi.serve(api, RT, base, reg0, max_batch=6, cache_len=32)
    clean = _reqs(prompts, stream)
    eng0.run(clean)
    assert all(r.status == DONE for r in clean)
    want = {r.uid: list(r.out_tokens) for r in clean}
    reg0.close()

    reg, tr = _registry(experts, blackout=["expert2"])
    eng = rapi.serve(api, RT, base, reg, max_batch=6, cache_len=32)
    reqs = _reqs(prompts, stream)
    out = eng.run(reqs)
    assert out is reqs            # results flow through the normal path
    for r in reqs:
        if r.expert == "expert2":
            assert r.status == FAILED
            assert "expert2" in r.error and "unavailable" in r.error
            assert r.out_tokens == []
        else:
            # healthy rows: bit-identical to the no-fault run even though
            # the wave composition changed under them
            assert r.status == DONE
            assert r.out_tokens == want[r.uid]
    s = eng.swap_summary()
    assert s["failed"] == 2
    assert s["quarantines"] == 1
    assert reg.health()["quarantined"].keys() == {"expert2"}
    reg.close()


def test_transient_faults_are_absorbed(fixture):
    """A timeout and a corrupted payload retry/refetch to success: no
    FAILED requests, and tokens match the no-fault run."""
    api, base, experts, prompts = fixture
    stream = ["expert0", "expert1"]

    reg0, _ = _registry(experts)
    eng0 = rapi.serve(api, RT, base, reg0, max_batch=2, cache_len=32)
    clean = _reqs(prompts, stream)
    eng0.run(clean)
    want = {r.uid: list(r.out_tokens) for r in clean}
    reg0.close()

    reg, tr = _registry(experts,
                        faults=[ChaosFault("expert0", 0, "timeout"),
                                ChaosFault("expert1", 0, "bitflip")])
    eng = rapi.serve(api, RT, base, reg, max_batch=2, cache_len=32)
    reqs = _reqs(prompts, stream)
    eng.run(reqs)
    assert all(r.status == DONE for r in reqs)
    assert all(r.out_tokens == want[r.uid] for r in reqs)
    assert eng.swap_summary()["retries"] == 2
    assert eng.swap_summary()["quarantines"] == 0
    assert {f["kind"] for f in tr.fired()} == {"timeout", "bitflip"}
    reg.close()


def test_admission_path_failure_does_not_block_queue(fixture):
    """A dead expert arriving through continuous admission fails ONLY its
    request; requests behind it in the queue still serve."""
    api, base, experts, prompts = fixture
    stream = ["expert0", "expert0", "expert1", "expert0"]
    reg, _ = _registry(experts, blackout=["expert1"])
    eng = rapi.serve(api, RT, base, reg, max_batch=2, cache_len=32)
    reqs = _reqs(prompts, stream)
    eng.run(reqs)
    statuses = {r.uid: r.status for r in reqs}
    assert statuses == {0: DONE, 1: DONE, 2: FAILED, 3: DONE}
    reg.close()


def test_degrade_raise_propagates(fixture):
    api, base, experts, prompts = fixture
    reg, _ = _registry(experts, blackout=["expert0"])
    eng = rapi.serve(api, RT, base, reg, max_batch=2, cache_len=32,
                     degrade="raise")
    with pytest.raises(ExpertUnavailable):
        eng.run(_reqs(prompts, ["expert0"]))
    reg.close()


def test_blackout_paged_affinity_survivors_exact(fixture):
    """Chaos on the hard path: paged KV + AffinityScheduler + a
    blacked-out expert arriving mid-wave.  The dead expert's requests
    must FAIL with the typed error, survivors must stay bit-identical to
    the no-fault run, the block allocator must balance at teardown (no
    leaked KV blocks on the failure path), and no queued request may
    starve behind the failures."""
    api, base, experts, prompts = fixture
    stream = ["expert0", "expert1", "expert0", "expert2", "expert1",
              "expert2", "expert0", "expert1"]
    kw = dict(max_batch=3, cache_len=32, kv_layout="paged",
              scheduler="affinity")

    reg0, _ = _registry(experts)
    eng0 = rapi.serve(api, RT, base, reg0, **kw)
    clean = _reqs(prompts, stream)
    eng0.run(clean)
    assert all(r.status == DONE for r in clean)
    want = {r.uid: list(r.out_tokens) for r in clean}
    reg0.close()

    reg, _ = _registry(experts, blackout=["expert2"])
    eng = rapi.serve(api, RT, base, reg, **kw)
    reqs = _reqs(prompts, stream)
    eng.run(reqs)
    for r in reqs:
        if r.expert == "expert2":
            assert r.status == FAILED            # typed, terminal
            assert "expert2" in r.error and "unavailable" in r.error
        else:
            # no starvation: every healthy request completes, exactly
            assert r.status == DONE
            assert r.out_tokens == want[r.uid]
    s = eng.swap_summary()
    assert s["failed"] == 2
    assert s["kv"]["blocks_in_use"] == 0         # free list balanced
    assert s["log_dropped"] == {"swap": 0, "wave": 0, "failed": 0}
    reg.close()


def test_quarantine_reprobe_recovers():
    """After the probe window a restored replica serves again and its
    health account resets (no engine needed: store-level contract)."""
    tau = {"w": np.full((8, 8), 0.5, np.float32)}
    ex = rapi.compress(tau, name="e", density=0.5)
    inner = InMemoryTransport()
    rapi.publish(ex, inner)
    tr = ChaosTransport(inner, blackout=["e"], seed=0)
    reg = rapi.registry(transport=tr, quarantine_after=1,
                        quarantine_probe_s=0.05)
    with pytest.raises(ExpertUnavailable):
        reg.get("e")
    # inside the window every access is refused WITHOUT touching the wire
    fetches_after_trip = len(tr.fired())
    with pytest.raises(ExpertUnavailable) as ei:
        reg.get("e")
    assert ei.value.quarantined
    assert len(tr.fired()) == fetches_after_trip
    # replica comes back; past the window one probe is let through
    tr.restore("e")
    time.sleep(0.06)
    got = reg.get("e")
    assert got.name == "e"
    h = reg.health()
    assert h["failures"] == {} and h["quarantined"] == {}
    assert h["quarantines"] == 1          # the historical trip count stays
    reg.close()


def test_prefetch_failure_is_counted_and_surfaces():
    """Satellite of PR 6: the staged-prefetch path must COUNT a failed
    fetch and surface the typed error — never swallow it."""
    tau = {"w": np.full((8, 8), 0.5, np.float32)}
    ex = rapi.compress(tau, name="e", density=0.5)
    inner = InMemoryTransport()
    rapi.publish(ex, inner)
    tr = ChaosTransport(inner, blackout=["e"], seed=0)
    reg = rapi.registry(transport=tr, quarantine_after=1,
                        quarantine_probe_s=1000.0)
    cache = reg.device(1 << 20)
    reg.prefetch(["e"])
    with pytest.raises(ExpertUnavailable):
        cache.fetch("e")
    assert cache.stats.prefetch_errors == 1
    assert cache.stats.quarantines == 1
    reg.close()
