"""The Expert artifact + repro.api facade: representation round-trips
(bit-identical to the legacy compress/pack/Golomb paths), save/load across
both on-disk formats, representation-aware merging, and engine-via-registry
output parity with the legacy store-wired engine."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api as rapi
from repro.core import (CompressionConfig, compress, compress_packed,
                        decompress, pack_tree, tree_packed_bytes,
                        unpack_tree)
from repro.expert import DENSE, GOLOMB, PACKED, TERNARY, Expert


def _tau(seed=0, shapes=((64, 64), (32, 96), (48,))):
    rng = np.random.default_rng(seed)
    return {f"layer{i}/w": jnp.asarray(rng.normal(0, 7e-4, s), jnp.float32)
            for i, s in enumerate(shapes)}


def _assert_planes_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.pos), np.asarray(b.pos))
    np.testing.assert_array_equal(np.asarray(a.neg), np.asarray(b.neg))
    np.testing.assert_allclose(float(a.scale), float(b.scale), rtol=0)
    assert tuple(a.shape) == tuple(b.shape)


def test_packed_bit_identical_to_streaming_path():
    """as_(PACKED) on a dense expert == compress_packed (the PR-1 streaming
    pipeline), word for word."""
    tau = _tau()
    ex = rapi.compress(tau, density=0.1, alpha=2.0)
    ref = compress_packed(tau, CompressionConfig(density=0.1, alpha=2.0))
    got = ex.as_(PACKED)
    for k in tau:
        _assert_planes_equal(got[k], ref[k])


def test_exact_method_bit_identical_to_legacy_pack():
    """method='exact': DENSE -> TERNARY -> PACKED reproduces the seed
    pack_tree(compress(tau)) path exactly."""
    tau = _tau(1)
    ex = rapi.compress(tau, density=0.2, method="exact")
    cfg = CompressionConfig(density=0.2, alpha=1.0)
    tern_ref = compress(tau, cfg)
    packed_ref = pack_tree(tern_ref)
    tern = ex.as_(TERNARY)
    for k in tau:
        np.testing.assert_array_equal(np.asarray(tern[k].signs),
                                      np.asarray(tern_ref[k].signs))
    got = ex.as_(PACKED)
    for k in tau:
        _assert_planes_equal(got[k], packed_ref[k])


def test_full_lattice_roundtrip():
    """DENSE -> PACKED -> GOLOMB -> PACKED -> TERNARY -> DENSE: the ternary
    content survives every hop exactly."""
    tau = _tau(2)
    ex = rapi.compress(tau, name="rt", density=0.1)
    packed = {k: v for k, v in ex.packed.items()}
    blobs = ex.as_(GOLOMB)
    assert set(blobs) == set(packed)

    back = Expert("rt2", density=0.1)
    back._reps[GOLOMB] = blobs
    back._leaf_meta = {p: dict(m) for p, m in ex._leaf_meta.items()}
    for k, pt in back.packed.items():
        _assert_planes_equal(pt, packed[k])

    # ternary reconstruction equals the legacy decompress path
    dense_back = back.to_dense_tau()
    ref = decompress(unpack_tree(ex.as_(PACKED)))
    ref_flat, _ = jax.tree_util.tree_flatten_with_path(ref)
    from repro.peft.lora import _path_str
    ref_d = {_path_str(p): l for p, l in ref_flat}
    for k in ref_d:
        np.testing.assert_array_equal(np.asarray(dense_back[k]),
                                      np.asarray(ref_d[k]))


def test_nbytes_per_representation():
    tau = _tau(3)
    ex = rapi.compress(tau, density=0.05)
    n = sum(int(np.prod(l.shape)) for l in tau.values())
    assert ex.nbytes(DENSE) == 4 * n
    assert ex.nbytes(PACKED) == tree_packed_bytes(ex.as_(PACKED))
    assert ex.nbytes(GOLOMB) < ex.nbytes(PACKED) < ex.nbytes(DENSE)
    assert ex.nbytes(TERNARY) > ex.nbytes(PACKED)


def test_summary_subsumes_compression_summary():
    """Expert.summary() == compression_summary over the same ternary tree
    (plus per-representation byte accounting)."""
    from repro.core import compression_summary
    tau = _tau(4)
    ex = rapi.compress(tau, density=0.2)
    s = ex.summary()
    ref = compression_summary(tau, ex.as_(TERNARY))
    for key in ("n_params", "nnz", "density", "dense_bits", "entropy_bits",
                "bitplane_bits", "rel_recon_err"):
        assert s[key] == ref[key], key
    assert s["bytes"][PACKED] == ex.nbytes(PACKED)
    assert s["name"] == "expert"


def test_save_load_roundtrip_new_format(tmp_path):
    tau = _tau(5)
    ex = rapi.compress(tau, name="math-expert", kind="lora", density=0.1,
                       alpha=3.0)
    stats = ex.save(str(tmp_path / "e.npz"))
    assert stats["ratio"] > 1.0
    back = rapi.load(str(tmp_path / "e.npz"))
    assert back.name == "math-expert"
    assert back.kind == "lora"
    assert back.density == 0.1
    assert back.alpha == 3.0
    ref = ex.packed
    for k, pt in back.packed.items():
        _assert_planes_equal(pt, ref[k])


def test_load_legacy_export_expert_file(tmp_path):
    """Expert.load reads files written by the legacy checkpoint shim, and
    the legacy import reads files written by Expert.save — one format."""
    from repro.checkpoint.manager import export_expert, import_expert
    rng = np.random.default_rng(6)
    init = {"w": jnp.asarray(rng.normal(0, 1, (64, 64)), jnp.float32)}
    ft = {"w": init["w"] + jnp.asarray(rng.normal(0, 1e-3, (64, 64)),
                                       jnp.float32)}
    with pytest.deprecated_call():
        export_expert(init, ft, str(tmp_path / "legacy.npz"), density=0.1)
    ex = rapi.load(str(tmp_path / "legacy.npz"))
    assert ex.density == 0.1
    assert "w" in ex.packed

    # reverse direction: new save -> legacy import
    ex2 = rapi.compress(init, ft, name="n", density=0.1)
    ex2.save(str(tmp_path / "new.npz"))
    with pytest.deprecated_call():
        taus, manifest = import_expert(str(tmp_path / "new.npz"))
    assert manifest["density"] == 0.1
    np.testing.assert_array_equal(
        taus["w"], np.asarray(ex2.to_dense_tau()["w"], np.float32))


def test_merge_dispatch_by_representation():
    """api.merge: dense TA == packed TA on f32 leaves; ties runs; auto
    picks the bitplane path for packed-resident experts."""
    from repro.core.merging import merge_packed, task_arithmetic
    taus = [_tau(seed) for seed in (10, 11)]
    exps = [rapi.compress(t, name=f"e{i}", density=0.2)
            for i, t in enumerate(taus)]

    m_dense = rapi.merge(exps, method="task_arithmetic", lam=0.7)
    ref = task_arithmetic([e.to_dense_tau() for e in exps], lam=0.7)
    for k in taus[0]:
        np.testing.assert_array_equal(np.asarray(m_dense[k]),
                                      np.asarray(ref[k]))

    m_packed = rapi.merge(exps, method="packed", lam=0.7)
    ref_p = merge_packed([e.as_(PACKED) for e in exps], lam=0.7)
    for k in taus[0]:
        np.testing.assert_array_equal(np.asarray(m_packed[k]),
                                      np.asarray(ref_p[k]))

    m_ties = rapi.merge(exps, method="ties", lam=0.7, density=0.3)
    assert set(m_ties) == set(taus[0])

    # packed-resident experts (no dense rep) dispatch to the bitplane path
    lean = [Expert.from_packed(f"p{i}", "full", e.as_(PACKED))
            for i, e in enumerate(exps)]
    m_auto = rapi.merge(lean, method="auto", lam=0.7)
    for k in taus[0]:
        np.testing.assert_array_equal(np.asarray(m_auto[k]),
                                      np.asarray(ref_p[k]))

    merged_ex = rapi.merge(exps, method="task_arithmetic", lam=0.7,
                           as_expert=True, name="blend", density=0.2)
    assert isinstance(merged_ex, Expert)
    assert merged_ex.name == "blend"

    # legacy ExpertArtifact inputs are normalized, not crashed on
    from repro.peft.task_vector import ExpertArtifact
    arts = [ExpertArtifact(name=f"a{i}", kind="full",
                           packed=e.as_(PACKED), density=0.2, alpha=1.0)
            for i, e in enumerate(exps)]
    m_legacy = rapi.merge(arts, method="packed", lam=0.7)
    for k in taus[0]:
        np.testing.assert_array_equal(np.asarray(m_legacy[k]),
                                      np.asarray(ref_p[k]))


def test_registry_engine_parity_with_legacy_store():
    """ServeEngine-via-registry must produce exactly the tokens the legacy
    store-wired engine does on a mixed-expert wave."""
    from repro.configs import get_smoke_config
    from repro.models import Runtime, build
    from repro.peft import compress_expert
    from repro.peft.lora import _path_str
    from repro.peft.task_vector import task_vector
    from repro.serve import (EngineConfig, ExpertStore, Request, ServeEngine)

    RT = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))

    store = ExpertStore()
    reg = rapi.registry()
    for i in range(2):
        leaves, tdef = jax.tree_util.tree_flatten(base)
        keys = jax.random.split(jax.random.PRNGKey(100 + i), len(leaves))
        ft = jax.tree_util.tree_unflatten(tdef, [
            (l.astype(jnp.float32)
             + 0.03 * jax.random.normal(k, l.shape)).astype(l.dtype)
            for l, k in zip(leaves, keys)])
        tau = task_vector(base, ft)
        flat, _ = jax.tree_util.tree_flatten_with_path(tau)
        with pytest.deprecated_call():
            store.put(compress_expert(f"expert{i}", "full",
                                      {_path_str(p): l for p, l in flat},
                                      density=0.2, alpha=1.0))
        reg.add(rapi.compress(tau, name=f"expert{i}", density=0.2))

    rng = np.random.default_rng(3)
    prompts = [jnp.asarray(rng.integers(1, cfg.vocab, 10), jnp.int32)
               for _ in range(4)]

    def mk():
        return [Request(uid=i, expert=f"expert{i % 2}", prompt=prompts[i],
                        max_new_tokens=3) for i in range(4)]

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng_legacy = ServeEngine(api, RT, base, store,
                                 EngineConfig(max_batch=4, cache_len=48))
    legacy_reqs = mk()
    eng_legacy.run(legacy_reqs)

    eng_new = rapi.serve(api, RT, base, reg, max_batch=4, cache_len=48)
    new_reqs = mk()
    eng_new.run(new_reqs)

    assert ({r.uid: r.out_tokens for r in legacy_reqs}
            == {r.uid: r.out_tokens for r in new_reqs})
    assert eng_new.swap_summary()["n_swaps"] == 0


def test_registry_merged_params_single_equals_ensemble_of_one():
    """registry.merged_params([e]) is the merge-on-swap promotion — one
    fused sweep, identical to the ensemble path with weight 1."""
    tau = _tau(12, shapes=((64, 64),))
    reg = rapi.registry(experts=[rapi.compress(tau, name="e", density=0.2)])
    base = {"layer0/w": jnp.asarray(
        np.random.default_rng(0).normal(0, 1, (64, 64)), jnp.float32)}
    a = reg.merged_params(base, ["e"])
    b = reg.merged_params(base, ["e"], weights=[1.0])
    np.testing.assert_array_equal(np.asarray(a["layer0/w"]),
                                  np.asarray(b["layer0/w"]))


def test_expert_lazy_compression():
    """compress() is lazy: no packed rep exists until first access."""
    tau = _tau(13)
    ex = rapi.compress(tau, density=0.1)
    assert ex.available() == (DENSE,)
    ex.packed
    assert PACKED in ex.available()


def test_unknown_representation_raises():
    ex = rapi.compress(_tau(14), density=0.1)
    with pytest.raises(ValueError):
        ex.as_("int4")


def test_dense_expert_without_density_raises():
    ex = Expert.from_task_vector(_tau(15), density=0.0)
    with pytest.raises(ValueError):
        ex.as_(PACKED)
