"""Kill–restart recovery: a crashed engine resumed from its journal (+
snapshot) must produce bit-identical continuation tokens to the
uninterrupted run — greedy AND sampled, dense AND paged KV — and the
journal itself must be torn-tail tolerant (the WAL property).  The
subprocess SIGKILL variant of these gates lives in ``perf_lab --exp
chaos_restart``; here the crash is an exception raised from a chunk hook,
which exercises the same journal/snapshot/replay machinery in-process."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api as rapi
from repro.configs import get_smoke_config
from repro.models import Runtime, build
from repro.serve import (DONE, FAILED, JournalWriter, Request, read_records,
                         replay)
from repro.serve.paged_kv import BlockAllocator
from repro.transport import InMemoryTransport

RT = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")
N_EXPERTS = 3


# ---------------------------------------------------------------------------
# journal container (no model needed)
# ---------------------------------------------------------------------------


def test_journal_roundtrip_and_torn_tail(tmp_path):
    path = str(tmp_path / "journal.bin")
    w = JournalWriter(path)
    w.append("run_start", {"requests": []}, t=0.0)
    w.append("chunk", {"i": 1, "rows": [{"uid": 0, "n": 2,
                                         "toks": [5, 7], "total": 2}]},
             t=0.5)
    w.append("run_end", {"requests": 1}, t=1.0)
    w.close()
    recs = read_records(path)
    assert [r["k"] for r in recs] == ["run_start", "chunk", "run_end"]
    assert recs[1]["d"]["rows"][0]["toks"] == [5, 7]

    # torn tail: truncate mid-frame — the intact prefix must survive
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - 3)
    recs = read_records(path)
    assert [r["k"] for r in recs] == ["run_start", "chunk"]

    # CRC corruption ends the scan at the damaged frame
    w = JournalWriter(path, fresh=True)
    w.append("run_start", {"requests": []})
    w.append("chunk", {"i": 1, "rows": []})
    w.close()
    with open(path, "r+b") as f:
        f.seek(-2, os.SEEK_END)
        f.write(b"\xff")
    assert [r["k"] for r in read_records(path)] == ["run_start"]


def test_journal_replay_folds_tokens(tmp_path):
    path = str(tmp_path / "journal.bin")
    w = JournalWriter(path)
    w.append("run_start", {"requests": [{"uid": 0}, {"uid": 1}]}, t=0.0)
    w.append("chunk", {"i": 1, "rows": [
        {"uid": 0, "n": 2, "toks": [1, 2], "total": 2}]}, t=0.1)
    w.append("chunk", {"i": 2, "rows": [
        {"uid": 0, "n": 1, "toks": [3], "total": 3},
        {"uid": 1, "n": 2, "toks": [9, 9], "total": 2}]}, t=0.2)
    w.append("fail", {"uid": 1, "error": "boom"}, t=0.3)
    w.close()                              # no run_end: a crashed run
    st = replay(path)
    assert st.tokens == {0: [1, 2, 3], 1: [9, 9]}
    assert st.failed == {1: "boom"}
    assert st.chunks == 2 and not st.clean_end
    assert st.last_t == pytest.approx(0.3)


def test_journal_requires_run_start(tmp_path):
    path = str(tmp_path / "journal.bin")
    w = JournalWriter(path)
    w.append("chunk", {"i": 1, "rows": []})
    w.close()
    with pytest.raises(ValueError, match="run_start"):
        replay(path)


def test_allocator_state_roundtrip():
    a = BlockAllocator(9, 4)
    first = a.alloc(3)
    restored = BlockAllocator.from_state(9, 4, a.state())
    assert restored.in_use == a.in_use
    # the restored free list must replay the SAME allocation order
    assert restored.alloc(2) == a.alloc(2)
    with pytest.raises(ValueError):
        BlockAllocator.from_state(9, 4, [1, 1, 2])     # duplicate id
    with pytest.raises(ValueError):
        BlockAllocator.from_state(9, 4, [0, 2])        # reserved block
    assert first is not None


# ---------------------------------------------------------------------------
# crash -> resume parity (engine-level)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fixture():
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    experts = []
    for i in range(N_EXPERTS):
        leaves, tdef = jax.tree_util.tree_flatten(base)
        keys = jax.random.split(jax.random.PRNGKey(100 + i), len(leaves))
        ft = jax.tree_util.tree_unflatten(tdef, [
            (l.astype(jnp.float32)
             + 0.01 * jax.random.normal(k, l.shape)).astype(l.dtype)
            for l, k in zip(leaves, keys)])
        experts.append(rapi.compress(base, ft, name=f"expert{i}",
                                     density=0.2))
    rng = np.random.default_rng(0)
    prompts = [jnp.asarray(rng.integers(1, cfg.vocab, 6), jnp.int32)
               for _ in range(8)]
    return api, base, experts, prompts


def _registry(experts):
    inner = InMemoryTransport()
    for e in experts:
        rapi.publish(e, inner)
    return rapi.registry(transport=inner)


STREAM = ["expert0", "expert1", "expert2", "expert0", "expert1", "expert2"]


def _reqs(prompts, max_new=8):
    # 8 tokens = 4 chunks at decode_chunk=2, so a kill at chunk 3 lands
    # MID-generation: resume must restore KV from the snapshot (the
    # replay tier), not just fold the journal and re-prefill
    return [Request(uid=i, expert=e, prompt=prompts[i],
                    max_new_tokens=max_new)
            for i, e in enumerate(STREAM)]


class _Crash(Exception):
    pass


def _crash_at(eng, chunk_idx):
    def hook(i):
        if i == chunk_idx:
            raise _Crash(f"injected crash at chunk {i}")
    eng.chunk_hooks.append(hook)


def _run_pair(api, base, experts, prompts, tmp_path, kill_at, **kw):
    """(baseline tokens, resumed requests, resumed engine)."""
    kw.setdefault("max_batch", 4)
    kw.setdefault("cache_len", 32)
    kw.setdefault("decode_chunk", 2)
    reg0 = _registry(experts)
    eng0 = rapi.serve(api, RT, base, reg0, **kw)
    clean = _reqs(prompts)
    eng0.run(clean)
    assert all(r.status == DONE for r in clean)
    want = {r.uid: list(r.out_tokens) for r in clean}
    reg0.close()

    snap_dir = str(tmp_path / "snap")
    reg1 = _registry(experts)
    eng1 = rapi.serve(api, RT, base, reg1, snapshot_dir=snap_dir,
                      snapshot_every_chunks=1, **kw)
    _crash_at(eng1, kill_at)
    with pytest.raises(_Crash):
        eng1.run(_reqs(prompts))
    reg1.close()

    reg2 = _registry(experts)
    eng2 = rapi.serve(api, RT, base, reg2, snapshot_dir=snap_dir,
                      snapshot_every_chunks=1, **kw)
    out = eng2.resume()
    reg2.close()
    return want, out, eng2


def test_crash_resume_dense_greedy(fixture, tmp_path):
    api, base, experts, prompts = fixture
    want, out, eng = _run_pair(api, base, experts, prompts, tmp_path,
                               kill_at=3)
    assert all(r.status == DONE for r in out)
    assert {r.uid: r.out_tokens for r in out} == want
    plan = eng.recovery_stats["plan"]
    assert plan.snapshot_step is not None
    assert plan.replayed_rows > 0          # snapshot KV actually restored
    assert plan.journal_records > 0
    assert eng.recovery_stats["resume_seconds"] > 0
    assert "first_resumed_token_s" in eng.recovery_stats


def test_crash_resume_paged_sampled_affinity(fixture, tmp_path):
    """The hard quadrant: paged KV + affinity scheduler + temperature
    sampling.  Resume must restore the allocator free list (allocation
    order is part of the determinism contract) and the sampled streams
    must continue bit-identically."""
    api, base, experts, prompts = fixture
    want, out, eng = _run_pair(api, base, experts, prompts, tmp_path,
                               kill_at=3, kv_layout="paged",
                               scheduler="affinity",
                               temperature=0.8, top_k=20, seed=7)
    assert all(r.status == DONE for r in out)
    assert {r.uid: r.out_tokens for r in out} == want
    # allocator balanced after the resumed run (leak gate)
    assert eng.swap_summary()["kv"]["blocks_in_use"] == 0


def test_resume_journal_only(fixture, tmp_path):
    """snapshot_every_chunks=0: no KV snapshot exists, so every
    incomplete request re-serves from its prompt — still bit-identical,
    and the plan records the journal-only tier."""
    api, base, experts, prompts = fixture
    kw = dict(max_batch=4, cache_len=32, decode_chunk=2)
    reg0 = _registry(experts)
    eng0 = rapi.serve(api, RT, base, reg0, **kw)
    clean = _reqs(prompts)
    eng0.run(clean)
    want = {r.uid: list(r.out_tokens) for r in clean}
    reg0.close()

    snap_dir = str(tmp_path / "snap")
    reg1 = _registry(experts)
    eng1 = rapi.serve(api, RT, base, reg1, snapshot_dir=snap_dir, **kw)
    _crash_at(eng1, 2)
    with pytest.raises(_Crash):
        eng1.run(_reqs(prompts))
    reg1.close()

    reg2 = _registry(experts)
    # api.serve(resume=True) is the one-call restart path
    eng2 = rapi.serve(api, RT, base, reg2, snapshot_dir=snap_dir,
                      resume=True, **kw)
    out = eng2.resumed_requests
    assert all(r.status == DONE for r in out)
    assert {r.uid: r.out_tokens for r in out} == want
    plan = eng2.recovery_stats["plan"]
    assert plan.snapshot_step is None
    assert plan.replayed_rows == 0
    reg2.close()


def test_resume_refuses_mismatched_sampling(fixture, tmp_path):
    api, base, experts, prompts = fixture
    kw = dict(max_batch=4, cache_len=32, decode_chunk=2)
    snap_dir = str(tmp_path / "snap")
    reg1 = _registry(experts)
    eng1 = rapi.serve(api, RT, base, reg1, snapshot_dir=snap_dir,
                      seed=7, temperature=0.8, **kw)
    _crash_at(eng1, 2)
    with pytest.raises(_Crash):
        eng1.run(_reqs(prompts))
    reg1.close()

    reg2 = _registry(experts)
    eng2 = rapi.serve(api, RT, base, reg2, snapshot_dir=snap_dir,
                      seed=8, temperature=0.8, **kw)
    with pytest.raises(ValueError, match="sampling mismatch"):
        eng2.resume()
    reg2.close()


def test_completed_run_resumes_from_journal_alone(fixture, tmp_path):
    """A clean run's journal fully reconstructs the results (run_end +
    all tokens journaled) without re-serving anything."""
    api, base, experts, prompts = fixture
    kw = dict(max_batch=4, cache_len=32, decode_chunk=2)
    snap_dir = str(tmp_path / "snap")
    reg = _registry(experts)
    eng = rapi.serve(api, RT, base, reg, snapshot_dir=snap_dir, **kw)
    reqs = _reqs(prompts)
    eng.run(reqs)
    want = {r.uid: list(r.out_tokens) for r in reqs}
    n_waves_before = len(eng.wave_log)

    eng2 = rapi.serve(api, RT, base, reg, snapshot_dir=snap_dir, **kw)
    out = eng2.resume()
    assert {r.uid: r.out_tokens for r in out} == want
    assert all(r.status == DONE for r in out)
    assert len(eng2.wave_log) == 0         # nothing re-served
    assert n_waves_before > 0
    reg.close()
