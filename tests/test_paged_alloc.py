"""Property tests for :class:`repro.serve.paged_kv.BlockAllocator`.

Driven against a reference simulator: random alloc/free/double-free/
invalid-free sequences must never leak blocks, never grant partially,
never hand out the reserved trash block or a block twice, and ``peak``
must match the simulator's high-water mark.  Runs under hypothesis when
it is installed; a seeded stdlib-``random`` fallback always runs so the
property is exercised in minimal environments too.
"""

import random

import pytest

from repro.serve.paged_kv import TRASH_BLOCK, BlockAllocator


class RefSim:
    """Obviously-correct reference: a set of held block ids."""

    def __init__(self, n_blocks):
        self.n_blocks = n_blocks
        self.held = set()
        self.peak = 0

    @property
    def available(self):
        return (self.n_blocks - 1) - len(self.held)

    def alloc(self, n):
        if n > self.available:
            return False
        self.peak = max(self.peak, len(self.held) + n)
        return True

    def can_free(self, b):
        return b in self.held


def drive(n_blocks: int, ops: list) -> None:
    """Replay an op sequence against allocator + simulator in lockstep.

    ``ops`` entries: ("alloc", n) | ("free", k) free k held blocks |
    ("double_free",) | ("invalid_free", bad_id).
    """
    alloc = BlockAllocator(n_blocks, block_size=4)
    sim = RefSim(n_blocks)
    rng = random.Random(1234)

    for op in ops:
        if op[0] == "alloc":
            n = op[1]
            got = alloc.alloc(n)
            if not sim.alloc(n):
                # all-or-nothing: an over-ask grants NOTHING
                assert got is None
                assert alloc.available == sim.available
                continue
            assert got is not None and len(got) == n
            for b in got:
                assert b != TRASH_BLOCK, "granted the reserved trash block"
                assert 0 < b < n_blocks, f"granted out-of-range id {b}"
                assert b not in sim.held, f"granted held block {b} twice"
                sim.held.add(b)
        elif op[0] == "free":
            k = min(op[1], len(sim.held))
            if not k:
                continue
            victims = rng.sample(sorted(sim.held), k)
            alloc.free(victims)
            sim.held -= set(victims)
        elif op[0] == "double_free":
            free = [b for b in range(1, n_blocks) if b not in sim.held]
            if not free:
                continue
            with pytest.raises(ValueError, match="double free"):
                alloc.free([free[0]])
        elif op[0] == "invalid_free":
            with pytest.raises(ValueError, match="invalid block"):
                alloc.free([op[1]])

        # invariants after EVERY op
        assert alloc.available == sim.available, "leaked or lost blocks"
        assert alloc.in_use == len(sim.held)
        assert alloc.peak_in_use == sim.peak

    # drain: everything held frees cleanly, pool returns to full
    if sim.held:
        alloc.free(sorted(sim.held))
    assert alloc.available == n_blocks - 1
    assert alloc.in_use == 0


def _random_ops(rng, n_blocks, length):
    ops = []
    for _ in range(length):
        r = rng.random()
        if r < 0.45:
            ops.append(("alloc", rng.randint(0, n_blocks)))
        elif r < 0.8:
            ops.append(("free", rng.randint(1, max(n_blocks // 2, 1))))
        elif r < 0.9:
            ops.append(("double_free",))
        else:
            bad = rng.choice([0, -1, n_blocks, n_blocks + 7])
            ops.append(("invalid_free", bad))
    return ops


def test_allocator_random_sequences_stdlib():
    """Seeded fallback: always runs, no optional deps."""
    rng = random.Random(0)
    for trial in range(200):
        n_blocks = rng.randint(2, 33)
        drive(n_blocks, _random_ops(rng, n_blocks, rng.randint(1, 60)))


def test_allocator_edges():
    with pytest.raises(ValueError):
        BlockAllocator(1, block_size=4)
    a = BlockAllocator(2, block_size=4)
    assert a.alloc(1) == [1]
    assert a.alloc(1) is None          # pool exhausted -> None, not partial
    assert a.available == 0 and a.in_use == 1 and a.peak_in_use == 1
    a.free([1])
    assert a.available == 1 and a.peak_in_use == 1  # peak is sticky


# -- hypothesis-driven variant (optional dependency; the stdlib test above
#    always runs, so skipping here never drops the property entirely) -------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def _op_seqs(draw):
        n_blocks = draw(st.integers(min_value=2, max_value=40))
        op = st.one_of(
            st.tuples(st.just("alloc"), st.integers(0, n_blocks + 2)),
            st.tuples(st.just("free"), st.integers(1, n_blocks)),
            st.tuples(st.just("double_free")),
            st.tuples(st.just("invalid_free"),
                      st.sampled_from([0, -3, n_blocks, n_blocks + 5])),
        )
        return n_blocks, draw(st.lists(op, min_size=1, max_size=80))

    @given(_op_seqs())
    @settings(max_examples=300, deadline=None)
    def test_allocator_hypothesis(case):
        n_blocks, ops = case
        drive(n_blocks, ops)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_allocator_hypothesis():
        pass
