"""Fault-tolerance contracts (repro.distributed.fault).

The recovery path is testable because failures are *injected*
deterministically: a crashed-and-restarted run must produce bit-identical
parameters to an uninterrupted one (stateless pipeline + exact
checkpoints), stragglers must not poison the step-time EWMA, and every
injected failure fires exactly once.
"""

import numpy as np
import pytest

from repro.distributed.fault import (ElasticPlan, FailureInjector,
                                     SimulatedFailure, StragglerMonitor)


# ---------------- FailureInjector ----------------

def test_injector_fires_at_configured_steps_once():
    inj = FailureInjector(fail_at_steps=[2, 5])
    fired = []
    for step in range(8):
        try:
            inj.check(step)
        except SimulatedFailure:
            fired.append(step)
    assert fired == [2, 5]
    # each failure fires exactly once: re-checking the same steps is clean
    for step in range(8):
        inj.check(step)


def test_injector_no_failures_is_a_noop():
    inj = FailureInjector()
    for step in range(10):
        inj.check(step)


# ---------------- restart reproducibility ----------------

def _batch(step: int) -> np.ndarray:
    """Stateless pipeline: batch = f(step), no iterator state to lose."""
    return np.random.default_rng(1000 + step).standard_normal(4)


def _update(params: np.ndarray, batch: np.ndarray) -> np.ndarray:
    """Deterministic step function (stand-in for the pjit'd train step)."""
    return params + 0.1 * np.tanh(batch) - 0.01 * params


def _train(n_steps: int, injector=None):
    """Tiny elastic-training loop: checkpoint every step, and on a
    SimulatedFailure restart from the last checkpoint (the crashed step
    re-runs — exactly the restore contract of ElasticPlan)."""
    params = np.zeros(4)
    ckpt = {"step": 0, "params": params.copy()}
    step = 0
    while step < n_steps:
        try:
            if injector is not None:
                injector.check(step)
            params = _update(params, _batch(step))
            step += 1
            ckpt = {"step": step, "params": params.copy()}
        except SimulatedFailure:
            # crash: lose in-memory state, restore from the checkpoint
            step = ckpt["step"]
            params = ckpt["params"].copy()
    return params


def test_crash_restart_bit_identical():
    clean = _train(10)
    crashed = _train(10, injector=FailureInjector(fail_at_steps=[3, 7]))
    np.testing.assert_array_equal(clean, crashed)


def test_crash_at_step_zero_restarts_from_init():
    clean = _train(5)
    crashed = _train(5, injector=FailureInjector(fail_at_steps=[0]))
    np.testing.assert_array_equal(clean, crashed)


# ---------------- StragglerMonitor ----------------

def test_straggler_flagged_but_ewma_not_poisoned():
    mon = StragglerMonitor(alpha=0.2, slowdown_threshold=2.0)
    assert not mon.observe(0, 1.0)
    assert mon.ewma == 1.0
    assert not mon.observe(1, 1.0)
    ewma_before = mon.ewma
    # a 10x step is flagged AND excluded from the average, so one slow
    # host does not raise the threshold for catching the next one
    assert mon.observe(2, 10.0)
    assert mon.ewma == ewma_before
    assert mon.flagged_steps[0][0] == 2
    # the monitor still flags a later straggler of the same magnitude
    assert mon.observe(3, 10.0)


def test_straggler_recommendation_transitions():
    mon = StragglerMonitor()
    assert mon.recommendation() == "healthy"
    mon.observe(0, 1.0)
    assert mon.recommendation() == "healthy"
    mon.observe(1, 5.0)
    assert mon.recommendation() == "monitor"
    mon.observe(2, 5.0)
    mon.observe(3, 5.0)
    assert mon.recommendation() == "exclude-host-and-reshard"


def test_normal_steps_update_ewma():
    mon = StragglerMonitor(alpha=0.5)
    mon.observe(0, 1.0)
    mon.observe(1, 2.0)          # 2x exactly is NOT > threshold * ewma
    assert mon.ewma == pytest.approx(1.5)
    assert mon.flagged_steps == []


# ---------------- ElasticPlan ----------------

def test_elastic_plan_validity():
    assert ElasticPlan(old_shape=(4, 2), new_shape=(2, 2)).valid()
    assert not ElasticPlan(old_shape=(4, 2), new_shape=(0, 2)).valid()
