"""Tests for bitplane packing, entropy accounting and the Golomb codec,
including hypothesis property tests (pack/unpack and encode/decode are
exact inverses for arbitrary ternary vectors)."""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # optional dev dep; fall back to seed sweeps
    HAVE_HYPOTHESIS = False

from repro.core import golomb_total_bits  # noqa: F401 (public API check)
from repro.core import (entropy_bits, pack_bits, pack_ternary, unpack_bits,
                        unpack_ternary)
from repro.core.compeft import CompressedTensor
from repro.core.golomb import (decode, encode, encoded_bits,
                               theoretical_bits_check)


def test_pack_unpack_roundtrip_exact():
    rng = np.random.default_rng(0)
    for n in (1, 31, 32, 33, 1000, 4096):
        mask = jnp.asarray(rng.integers(0, 2, n), jnp.int32)
        words = pack_bits(mask)
        assert words.dtype == jnp.uint32
        assert words.shape[0] == (n + 31) // 32
        back = unpack_bits(words, n)
        np.testing.assert_array_equal(np.array(back), np.array(mask))


def test_pack_ternary_roundtrip():
    rng = np.random.default_rng(1)
    signs = jnp.asarray(rng.integers(-1, 2, (40, 17)), jnp.int8)
    ct = CompressedTensor(signs=signs, scale=jnp.float32(0.37))
    pt = pack_ternary(ct)
    back = unpack_ternary(pt)
    np.testing.assert_array_equal(np.array(back.signs), np.array(signs))
    assert float(back.scale) == pytest.approx(0.37)
    assert pt.packed_bytes == 2 * ((40 * 17 + 31) // 32) * 4 + 4


def _golomb_roundtrip_property(signs, scale):
    arr = np.array(signs, dtype=np.int8)
    blob = encode(arr, scale)
    back, s = decode(blob)
    np.testing.assert_array_equal(back, arr)
    assert s == pytest.approx(scale, rel=1e-6)


def _pack_bits_property(n):
    rng = np.random.default_rng(n)
    mask = jnp.asarray(rng.integers(0, 2, n), jnp.int32)
    np.testing.assert_array_equal(
        np.array(unpack_bits(pack_bits(mask), n)), np.array(mask))


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.sampled_from([-1, 0, 1]), min_size=1, max_size=400),
           st.floats(min_value=1e-6, max_value=10.0, allow_nan=False))
    def test_golomb_roundtrip_property(signs, scale):
        _golomb_roundtrip_property(signs, scale)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=1, max_value=512))
    def test_pack_bits_property(n):
        _pack_bits_property(n)
else:
    @pytest.mark.parametrize("seed", range(8))
    def test_golomb_roundtrip_property(seed):
        rng = np.random.default_rng(seed)
        signs = rng.integers(-1, 2, int(rng.integers(1, 400))).tolist()
        _golomb_roundtrip_property(signs, float(rng.uniform(1e-6, 10.0)))

    @pytest.mark.parametrize("n", [1, 31, 32, 33, 100, 511, 512])
    def test_pack_bits_property(n):
        _pack_bits_property(n)


def test_entropy_formula_paper_value():
    # k=0.05: H = 0.3382 bits/param (paper: "0.34 * d + 16")
    h = (entropy_bits(1_000_000, 0.05) - 16) / 1_000_000
    assert h == pytest.approx(0.3382, abs=2e-3)
    # 16 / 0.34 ~= 47x (paper's claim)
    assert 16.0 / h == pytest.approx(47.0, abs=1.0)


def test_golomb_actual_close_to_theory():
    rng = np.random.default_rng(3)
    n = 200_000
    for k in (0.05, 0.1, 0.2):
        mask = rng.random(n) < k
        signs = np.where(mask, rng.choice([-1, 1], n), 0).astype(np.int8)
        actual = encoded_bits(signs)
        theory = theoretical_bits_check(n, k)
        assert actual == pytest.approx(theory, rel=0.08), (k, actual, theory)


def test_golomb_bits_monotone_in_density():
    n = 1_000_000
    sizes = [golomb_total_bits(n, k) for k in (0.01, 0.05, 0.1, 0.3, 0.5)]
    assert sizes == sorted(sizes)


def test_empty_vector_encode():
    blob = encode(np.zeros(100, np.int8), 1.0)
    back, s = decode(blob)
    assert back.sum() == 0 and len(back) == 100


# ---------------------------------------------------------------------------
# Vectorized codec (PR 2) vs the bit-at-a-time reference implementations
# ---------------------------------------------------------------------------


def test_vectorized_codec_byte_identical_to_reference():
    from repro.core.golomb import decode_ref, encode_ref
    rng = np.random.default_rng(7)
    for _ in range(25):
        n = int(rng.integers(1, 3000))
        density = float(rng.uniform(0.0, 0.6))
        signs = np.where(rng.random(n) < density,
                         rng.choice([-1, 1], n), 0).astype(np.int8)
        scale = float(rng.uniform(1e-3, 5.0))
        blob = encode(signs, scale)
        assert blob == encode_ref(signs, scale)      # byte-identical stream
        s_vec, sc_vec = decode(blob)
        s_ref, sc_ref = decode_ref(blob)
        np.testing.assert_array_equal(s_vec, signs)
        np.testing.assert_array_equal(s_ref, signs)
        assert sc_vec == sc_ref


def test_vectorized_codec_edges():
    from repro.core.golomb import encode_ref
    for signs in (np.zeros(10, np.int8), np.ones(5, np.int8),
                  -np.ones(1, np.int8),
                  np.concatenate([np.zeros(500, np.int8), [1]]).astype(np.int8),
                  np.concatenate([[-1], np.zeros(500)]).astype(np.int8)):
        blob = encode(signs, 2.0)
        assert blob == encode_ref(signs, 2.0)
        out, s = decode(blob)
        np.testing.assert_array_equal(out, signs)
        assert s == 2.0


def test_decode_tree_batches_all_leaves():
    from repro.core.golomb import decode_tree
    rng = np.random.default_rng(8)
    blobs, truth = {}, {}
    for i in range(5):
        n = int(rng.integers(10, 400))
        signs = np.where(rng.random(n) < 0.2,
                         rng.choice([-1, 1], n), 0).astype(np.int8)
        truth[f"leaf{i}"] = signs
        blobs[f"leaf{i}"] = encode(signs, float(i + 1))
    out = decode_tree(blobs)
    for k, signs in truth.items():
        got, scale = out[k]
        np.testing.assert_array_equal(got, signs)
        assert scale == float(int(k[-1]) + 1)
