"""Tests for ternary bitwise algebra, merging methods and baselines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # optional dev dep; fall back to a seed sweep
    HAVE_HYPOTHESIS = False

from repro.core import CompressionConfig, compress, decompress, pack_tree
from repro.core.baselines import (bitdelta, dare, method_bits, pruned,
                                  run_method, stc)
from repro.core.compeft import CompressedTensor
from repro.core.merging import (compose_lora, lorahub_search, merge_packed,
                                pairwise_similarity_matrix, task_arithmetic,
                                ties_merge)
from repro.core.packing import pack_ternary
from repro.core.ternary_ops import (cosine_similarity, hamming_distance, nnz,
                                    packed_matvec, sign_agreement, ternary_dot)


def rnd_signs(key, n):
    rng = np.random.default_rng(key)
    return jnp.asarray(rng.integers(-1, 2, n), jnp.int8)


def packed(key, n, scale=1.0):
    return pack_ternary(CompressedTensor(signs=rnd_signs(key, n),
                                         scale=jnp.float32(scale)))


# ---------------------------------------------------------------- ternary ops

def test_ternary_dot_matches_dense():
    for n in (10, 64, 100, 257):
        a, b = rnd_signs(0, n), rnd_signs(1, n)
        pa = pack_ternary(CompressedTensor(signs=a, scale=jnp.float32(1)))
        pb = pack_ternary(CompressedTensor(signs=b, scale=jnp.float32(1)))
        want = float(jnp.sum(a.astype(jnp.int32) * b.astype(jnp.int32)))
        assert float(ternary_dot(pa, pb)) == want


def _hamming_property(n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(-1, 2, n), jnp.int8)
    b = jnp.asarray(rng.integers(-1, 2, n), jnp.int8)
    pa = pack_ternary(CompressedTensor(signs=a, scale=jnp.float32(1)))
    pb = pack_ternary(CompressedTensor(signs=b, scale=jnp.float32(1)))
    want = int(np.sum(np.array(a) != np.array(b)))
    assert int(hamming_distance(pa, pb)) == want


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=1, max_value=300), st.integers(0, 10_000))
    def test_hamming_property(n, seed):
        _hamming_property(n, seed)
else:
    @pytest.mark.parametrize("n,seed", [(1, 0), (31, 1), (32, 2), (33, 3),
                                        (100, 4), (300, 5)])
    def test_hamming_property(n, seed):
        _hamming_property(n, seed)


def test_nnz_and_cosine():
    a = jnp.asarray([1, -1, 0, 1, 0, -1, 1, 0], jnp.int8)
    pa = pack_ternary(CompressedTensor(signs=a, scale=jnp.float32(1)))
    assert int(nnz(pa)) == 5
    assert float(cosine_similarity(pa, pa)) == pytest.approx(1.0)


def test_sign_agreement():
    a = jnp.asarray([1, -1, 1, 0], jnp.int8)
    b = jnp.asarray([1, 1, 0, -1], jnp.int8)
    pa = pack_ternary(CompressedTensor(signs=a, scale=jnp.float32(1)))
    pb = pack_ternary(CompressedTensor(signs=b, scale=jnp.float32(1)))
    # overlap positions: 0,1 -> agree at 0 only
    assert float(sign_agreement(pa, pb)) == pytest.approx(0.5)


def test_packed_matvec_matches_dense():
    rng = np.random.default_rng(5)
    signs = jnp.asarray(rng.integers(-1, 2, (24, 16)), jnp.int8)
    ct = CompressedTensor(signs=signs, scale=jnp.float32(0.25))
    pt = pack_ternary(ct)
    x = jnp.asarray(rng.normal(0, 1, (16,)), jnp.float32)
    want = (signs.astype(jnp.float32) @ x) * 0.25
    np.testing.assert_allclose(np.array(packed_matvec(pt, x)), np.array(want),
                               rtol=1e-5)


# ------------------------------------------------------------------- merging

def make_taus(n_tasks=3, shapes=((32, 16), (48,))):
    rng = np.random.default_rng(11)
    return [{f"w{i}": jnp.asarray(rng.normal(0, 0.02, s), jnp.float32)
             for i, s in enumerate(shapes)} for _ in range(n_tasks)]


def test_task_arithmetic_is_sum():
    taus = make_taus()
    m = task_arithmetic(taus, lam=0.5)
    want = 0.5 * sum(np.array(t["w0"]) for t in taus)
    np.testing.assert_allclose(np.array(m["w0"]), want, rtol=1e-5)


def test_ties_zero_on_disagreement():
    a = {"w": jnp.asarray([1.0, 1.0, 0.0, 0.0])}
    b = {"w": jnp.asarray([-1.0, 1.0, 0.0, 0.0])}
    m = ties_merge([a, b], density=1.0)
    got = np.array(m["w"])
    assert got[0] == 0.0           # exact sign conflict cancels
    assert got[1] == pytest.approx(1.0)  # agreement -> mean


def test_merge_packed_equals_dense_ta():
    taus = make_taus()
    comp = [compress(t, CompressionConfig(density=0.3)) for t in taus]
    packed = [pack_tree(c) for c in comp]
    fast = merge_packed(packed, lam=1.0)
    slow = task_arithmetic([decompress(c) for c in comp], lam=1.0)
    for kk in fast:
        np.testing.assert_allclose(np.array(fast[kk], np.float32).reshape(-1),
                                   np.array(slow[kk], np.float32).reshape(-1),
                                   atol=1e-5)


def test_compose_lora_eq1():
    mods = make_taus(4)
    w = jnp.asarray([0.5, 0.25, 0.25, 0.0])
    m = compose_lora(mods, w)
    want = sum(float(wi) * np.array(mi["w0"]) for wi, mi in zip(w, mods))
    np.testing.assert_allclose(np.array(m["w0"]), want, rtol=1e-5)


def test_lorahub_search_recovers_useful_weights():
    mods = make_taus(3)
    target = np.array(mods[0]["w0"]) * 1.0  # task 0 is the right expert

    def loss(composed):
        return float(np.sum((np.array(composed["w0"]) - target) ** 2))

    w, best = lorahub_search(mods, loss, n_iters=80, seed=0, l1_reg=0.0)
    assert best < loss(compose_lora(mods, jnp.zeros(3)))
    assert w[0] > 0.3  # the matching expert got meaningful weight


def test_similarity_matrix_identity_diag():
    taus = make_taus(3)
    packed = [pack_tree(compress(t, CompressionConfig(density=0.3)))
              for t in taus]
    m = pairwise_similarity_matrix(packed)
    np.testing.assert_allclose(np.diag(m), 1.0)
    assert np.all(np.abs(m) <= 1.0 + 1e-6)


# ----------------------------------------------------------------- baselines

def test_pruned_keeps_magnitudes():
    t = {"w": jnp.asarray([0.1, -5.0, 0.01, 3.0])}
    p = pruned(t, density=0.5)
    np.testing.assert_allclose(np.array(p["w"]), [0.0, -5.0, 0.0, 3.0])


def test_stc_scale_is_mean_survivor_magnitude():
    t = {"w": jnp.asarray([0.1, -4.0, 0.01, 2.0])}
    s = stc(t, density=0.5)
    got = np.array(s["w"])
    np.testing.assert_allclose(got, [0.0, -3.0, 0.0, 3.0], atol=1e-6)


def test_bitdelta_density_one():
    t = {"w": jnp.asarray([0.5, -1.5])}
    b = bitdelta(t)
    np.testing.assert_allclose(np.array(b["w"]), [1.0, -1.0])


def test_dare_unbiased():
    rng = np.random.default_rng(0)
    t = {"w": jnp.asarray(rng.normal(0, 1, (20_000,)), jnp.float32)}
    d = dare(t, density=0.5, key=jax.random.PRNGKey(0))
    # E[dare(tau)] = tau -> means close
    assert float(jnp.mean(d["w"] - t["w"])) == pytest.approx(0.0, abs=0.02)


def test_run_method_dispatch_and_bits():
    t = {"w": jnp.asarray(np.random.default_rng(1).normal(0, 1, 1000),
                          jnp.float32)}
    for m in ("compeft", "stc", "pruned", "bitdelta", "dare"):
        out = run_method(m, t, density=0.2)
        assert out["w"].shape == t["w"].shape
        assert method_bits(m, 1000, 0.2) > 0
    # compeft strictly cheaper than pruned (ternary vs 16-bit magnitudes)
    assert method_bits("compeft", 10_000, 0.1) < method_bits("pruned", 10_000, 0.1)
