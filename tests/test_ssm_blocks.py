"""Chunked Mamba / RWKV6 implementations vs naive per-token recurrences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MambaCfg, RWKVCfg
from repro.models.mamba import (_ssm_scan_chunked, init_mamba_state,
                                mamba_decode_step, mamba_forward)
from repro.models.rwkv import (init_rwkv_state, rwkv_channel_mix,
                               rwkv_time_mix)
from repro.models.transformer import init_mamba as init_mamba_params
from repro.models.transformer import init_rwkv as init_rwkv_params


def naive_ssm(dA, dBx, C, h0):
    B, T, Din, S = dA.shape
    h = h0
    ys = []
    for t in range(T):
        h = dA[:, t] * h + dBx[:, t]
        ys.append(jnp.einsum("bds,bs->bd", h, C[:, t]))
    return jnp.stack(ys, axis=1), h


@pytest.mark.parametrize("T,chunk", [(8, 4), (10, 4), (16, 16), (7, 3)])
def test_ssm_chunked_matches_naive(T, chunk):
    rng = np.random.default_rng(0)
    B, Din, S = 2, 6, 4
    dt = jnp.asarray(rng.uniform(0.1, 0.5, (B, T, Din)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.1, 1.0, (Din, S)), jnp.float32)
    B_ssm = jnp.asarray(rng.normal(0, 1, (B, T, S)), jnp.float32)
    C = jnp.asarray(rng.normal(0, 1, (B, T, S)), jnp.float32)
    x_act = jnp.asarray(rng.normal(0, 1, (B, T, Din)), jnp.float32)
    h0 = jnp.asarray(rng.normal(0, 1, (B, Din, S)), jnp.float32)
    y, h = _ssm_scan_chunked(dt, A, B_ssm, C, x_act, h0, chunk=chunk)
    dA = jnp.exp(dt[..., None] * A[None, None])
    dBx = (dt * x_act)[..., None] * B_ssm[:, :, None, :]
    y_ref, h_ref = naive_ssm(dA, dBx, C, h0)
    np.testing.assert_allclose(np.array(y), np.array(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.array(h), np.array(h_ref), atol=1e-4)


def test_mamba_forward_decode_consistency():
    """Running T tokens at once == stepping one token at a time."""
    key = jax.random.PRNGKey(0)
    d, T, B = 16, 10, 2
    cfg = MambaCfg(d_state=4, d_conv=4, expand=2, dt_rank=4)
    p = init_mamba_params(key, d, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, d), jnp.float32)

    y_full, _ = mamba_forward(x, p, cfg, chunk=4)

    state = init_mamba_state(B, d, cfg, jnp.float32)
    ys = []
    for t in range(T):
        y_t, state = mamba_decode_step(x[:, t:t + 1], p, cfg, state)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.array(y_full), np.array(y_step),
                               atol=2e-4, rtol=1e-3)


def naive_rwkv_heads(r, k, v, logw, u, S0):
    """Reference recurrence.  r,k,v,logw: [B, T, H, dh]; S0: [B, H, dh, dh]."""
    B, T, H, dh = r.shape
    S = S0
    ys = []
    for t in range(T):
        kv = jnp.einsum("bhd,bhe->bhde", k[:, t], v[:, t])
        wkv = S + u[None, :, :, None] * kv
        ys.append(jnp.einsum("bhd,bhde->bhe", r[:, t], wkv))
        S = jnp.exp(logw[:, t])[..., None] * S + kv
    return jnp.stack(ys, axis=1), S


@pytest.mark.parametrize("T,chunk", [(8, 4), (12, 5), (6, 6)])
def test_rwkv_chunked_core_matches_naive(T, chunk):
    """Exercise the chunked kernel through rwkv_time_mix with decay forced
    by parameters; compare to the naive recurrence on the same internal
    r/k/v/w tensors by monkeypatching is heavy — instead validate the chunk
    math directly with a standalone replica of the scan."""
    from repro.models.rwkv import _decay, _ddlerp, _shift
    rng = np.random.default_rng(1)
    B, H, dh = 2, 2, 4
    r = jnp.asarray(rng.normal(0, 1, (B, T, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, T, H, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, T, H, dh)), jnp.float32)
    logw = jnp.asarray(-rng.uniform(0.01, 2.0, (B, T, H, dh)), jnp.float32)
    u = jnp.asarray(rng.normal(0, 1, (H, dh)), jnp.float32)
    S0 = jnp.asarray(rng.normal(0, 1, (B, H, dh, dh)), jnp.float32)

    # --- chunked computation (mirrors rwkv_time_mix internals) ---
    from jax import lax
    pad = (-T) % chunk
    rp, kp, vp = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                  for a in (r, k, v))
    wp = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = (T + pad) // chunk
    L = chunk
    rc = rp.reshape(B, n, L, H, dh)
    kc = kp.reshape(B, n, L, H, dh)
    vc = vp.reshape(B, n, L, H, dh)
    wc = wp.reshape(B, n, L, H, dh)
    ci = jnp.cumsum(wc, axis=2)
    ce = ci - wc
    mask = jnp.tril(jnp.ones((L, L), bool), k=-1)

    def step(S, xs):
        rcc, kcc, vcc, cii, cee = xs
        y_inter = jnp.einsum("blhd,bhde->blhe", rcc * jnp.exp(cee), S)
        diff = cee[:, :, None] - cii[:, None, :]
        A = jnp.einsum("blhd,bmhd,blmhd->blmh", rcc, kcc,
                       jnp.exp(jnp.minimum(diff, 0.0)))
        A = jnp.where(mask[None, :, :, None], A, 0.0)
        y_intra = jnp.einsum("blmh,bmhe->blhe", A, vcc)
        y_diag = jnp.einsum("blhd,blhd,blhe->blhe", rcc * u[None, None], kcc,
                            vcc)
        decay_all = jnp.exp(cii[:, -1][:, None] - cii)
        S_new = jnp.exp(cii[:, -1])[..., None] * S + jnp.einsum(
            "blhd,blhe->bhde", kcc * decay_all, vcc)
        return S_new, y_inter + y_intra + y_diag

    S_fin, ys = lax.scan(step, S0, tuple(jnp.moveaxis(a, 1, 0)
                                         for a in (rc, kc, vc, ci, ce)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T + pad, H, dh)[:, :T]

    y_ref, S_ref = naive_rwkv_heads(r, k, v, logw, u, S0)
    np.testing.assert_allclose(np.array(y), np.array(y_ref), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.array(S_fin), np.array(S_ref), atol=1e-4,
                               rtol=1e-4)


def test_rwkv_time_mix_full_vs_step():
    """Whole-sequence chunked path == token-by-token decode path."""
    key = jax.random.PRNGKey(0)
    d, T, B = 32, 9, 2
    cfg = RWKVCfg(head_dim=8, decay_lora=8, mix_lora=4)
    p = init_rwkv_params(key, d, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, T, d), jnp.float32) * 0.5

    y_full, (S_full, last_full) = rwkv_time_mix(x, p, cfg, chunk=4)

    S, tm, _ = init_rwkv_state(B, d, cfg, jnp.float32)
    ys = []
    for t in range(T):
        y_t, (S, tm) = rwkv_time_mix(x[:, t:t + 1], p, cfg, state=(S, tm),
                                     chunk=1)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.array(y_full), np.array(y_step), atol=2e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.array(S_full), np.array(S), atol=2e-4,
                               rtol=1e-3)


def test_rwkv_channel_mix_shift_consistency():
    key = jax.random.PRNGKey(3)
    d, T, B = 16, 6, 1
    from repro.configs.base import FFNCfg
    from repro.models.transformer import init_block
    from repro.configs.base import BlockCfg, RWKVCfg
    cfgb = BlockCfg(kind="rwkv", rwkv=RWKVCfg(head_dim=8, decay_lora=4,
                                              mix_lora=4),
                    ffn=FFNCfg(d_ff=32, activation="relu2"))

    class _C:  # minimal cfg shim for init_block
        d_model = d
        dtype = "float32"
        cross_attn = False
        name = "t"
        rms_eps = 1e-6
    p = init_block(key, _C, cfgb)["ffn"]
    x = jax.random.normal(jax.random.PRNGKey(4), (B, T, d), jnp.float32)

    y_full, _ = rwkv_channel_mix(x, p)
    state = jnp.zeros((B, 1, d), jnp.float32)
    ys = []
    for t in range(T):
        y_t, state = rwkv_channel_mix(x[:, t:t + 1], p, state=state)
        ys.append(y_t)
    np.testing.assert_allclose(np.array(y_full),
                               np.array(jnp.concatenate(ys, 1)), atol=1e-5)


def test_rwkv_matmul_form_matches_einsum():
    """The GLA-style factorised intra-chunk product (perf path) must agree
    with the exact einsum reference."""
    key = jax.random.PRNGKey(0)
    d, T, B = 64, 50, 2
    cfg = RWKVCfg(head_dim=16, decay_lora=8, mix_lora=4)
    p = init_rwkv_params(key, d, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, T, d), jnp.float32) * 0.5
    for chunk in (8, 32):
        y_e, (S_e, _) = rwkv_time_mix(x, p, cfg, chunk=chunk, impl="einsum")
        y_m, (S_m, _) = rwkv_time_mix(x, p, cfg, chunk=chunk, impl="matmul")
        np.testing.assert_allclose(np.asarray(y_m), np.asarray(y_e),
                                   atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(S_m), np.asarray(S_e),
                                   atol=1e-5, rtol=1e-4)
