"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp ref across a
shape/dtype sweep, plus hypothesis property tests and integration with the
PackedTernary container."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # optional dev dep; fall back to a seed sweep
    HAVE_HYPOTHESIS = False

from repro.core import CompressionConfig, compress, pack_ternary
from repro.core.compeft import CompressedTensor
from repro.kernels import ops, ref
from repro.kernels.pack import pack_ternary_planes
from repro.kernels.popcount_dot import popcount_dot
from repro.kernels.ternary_matmul import ternary_matmul, ternary_matmul_grouped
from repro.kernels.unpack_add import unpack_add, unpack_add_many

LANE = 32


def rand_planes(key, m, n):
    rng = np.random.default_rng(key)
    assert n % LANE == 0
    pos = rng.integers(0, 2 ** 32, (m, n // LANE), dtype=np.uint32)
    neg = rng.integers(0, 2 ** 32, (m, n // LANE), dtype=np.uint32)
    neg = neg & ~pos  # disjoint
    return jnp.asarray(pos), jnp.asarray(neg)


def rand_plane_stack(key, e, m, n):
    ps, ns = zip(*[rand_planes(key + 17 * i, m, n) for i in range(e)])
    return jnp.stack(ps), jnp.stack(ns)


MATMUL_CASES = [
    # (M, K, N, bm, bk, bn)
    (8, 32, 32, 8, 32, 32),
    (16, 64, 128, 8, 32, 64),
    (1, 128, 96, 1, 64, 32),
    (33, 96, 64, 16, 32, 64),    # padding on every dim
    (128, 128, 128, 128, 128, 128),
]


@pytest.mark.parametrize("M,K,N,bm,bk,bn", MATMUL_CASES)
def test_ternary_matmul_matches_ref(M, K, N, bm, bk, bn):
    pos, neg = rand_planes(0, K, N)
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (M, K)),
                    jnp.float32)
    scale = jnp.float32(0.37)
    got = ternary_matmul(x, pos, neg, scale, bm=bm, bk=bk, bn=bn,
                         interpret=True)
    want = ref.ternary_matmul_ref(x, pos, neg, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ternary_matmul_dtypes(dtype):
    pos, neg = rand_planes(2, 64, 64)
    x = jnp.asarray(np.random.default_rng(3).normal(0, 1, (8, 64)), dtype)
    got = ternary_matmul(x, pos, neg, jnp.float32(1.0), bm=8, bk=32, bn=32,
                         interpret=True)
    want = ref.ternary_matmul_ref(x.astype(jnp.float32), pos, neg, 1.0)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol,
                               atol=tol)


UNPACK_CASES = [(8, 32, 8, 32), (32, 128, 16, 64), (17, 96, 8, 64),
                (256, 512, 256, 512)]


@pytest.mark.parametrize("M,N,bm,bn", UNPACK_CASES)
def test_unpack_add_matches_ref(M, N, bm, bn):
    pos, neg = rand_planes(4, M, N)
    base = jnp.asarray(np.random.default_rng(5).normal(0, 1, (M, N)),
                       jnp.bfloat16)
    got = unpack_add(base, pos, neg, jnp.float32(0.25), bm=bm, bn=bn,
                     interpret=True)
    want = ref.unpack_add_ref(base, pos, neg, jnp.float32(0.25))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-2,
                               atol=1e-2)
    assert got.dtype == base.dtype


@pytest.mark.parametrize("M,N,bm,bn", [(8, 64, 8, 64), (30, 100, 16, 64),
                                       (256, 512, 128, 256)])
def test_pack_matches_ref(M, N, bm, bn):
    tau = jnp.asarray(np.random.default_rng(6).normal(0, 1, (M, N)),
                      jnp.float32)
    thr = jnp.float32(1.0)
    gp, gn = pack_ternary_planes(tau, thr, bm=bm, bn=bn, interpret=True)
    wp, wn = ref.pack_ternary_planes_ref(tau, thr)
    np.testing.assert_array_equal(np.asarray(gp), np.asarray(wp))
    np.testing.assert_array_equal(np.asarray(gn), np.asarray(wn))


def test_pack_then_matmul_roundtrip():
    """compress -> kernel-pack -> kernel-matmul == dense delta matmul."""
    rng = np.random.default_rng(7)
    K, N, M = 64, 96, 4
    tau = jnp.asarray(rng.normal(0, 0.02, (K, N)), jnp.float32)
    thr = jnp.quantile(jnp.abs(tau), 0.8)
    pos, neg = ops.compress_to_planes(tau, thr)
    x = jnp.asarray(rng.normal(0, 1, (M, K)), jnp.float32)
    scale = jnp.float32(0.01)
    got = ternary_matmul(x, pos, neg, scale, bm=4, bk=32, bn=32,
                         interpret=True)
    dense = jnp.where(jnp.abs(tau) >= thr, jnp.sign(tau), 0.0) * scale
    want = x @ dense
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def _popcount_dot_property(seed):
    rng = np.random.default_rng(seed)
    W = int(rng.integers(1, 40))
    ap, an = rand_planes(seed, 1, W * LANE)
    bp, bn = rand_planes(seed + 100, 1, W * LANE)
    got = popcount_dot(ap.reshape(-1), an.reshape(-1), bp.reshape(-1),
                       bn.reshape(-1), bw=64, interpret=True)
    want = ref.popcount_dot_ref(ap.reshape(-1), an.reshape(-1),
                                bp.reshape(-1), bn.reshape(-1))
    assert int(got) == int(want)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 8))
    def test_popcount_dot_property(seed):
        _popcount_dot_property(seed)
else:
    @pytest.mark.parametrize("seed", range(1, 9))
    def test_popcount_dot_property(seed):
        _popcount_dot_property(seed)


# ---------------------------------------------------------------------------
# Batched kernels (PR 2): stacked-plane variants must be bit-identical to
# looping the single-expert kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,N,E,bm,bn", [(8, 64, 1, 8, 64),
                                         (17, 96, 3, 8, 64),
                                         (33, 160, 5, 16, 96)])
def test_unpack_add_many_bit_identical_to_loop(M, N, E, bm, bn):
    pos, neg = rand_plane_stack(10, E, M, N)
    base = jnp.asarray(np.random.default_rng(11).normal(0, 1, (M, N)),
                       jnp.bfloat16)
    scales = jnp.asarray(np.random.default_rng(12).normal(0, 0.3, E),
                         jnp.float32)
    got = unpack_add_many(base, pos, neg, scales, bm=bm, bn=bn,
                          interpret=True)
    want = base
    for e in range(E):
        want = unpack_add(want, pos[e], neg[e], scales[e], bm=bm, bn=bn,
                          interpret=True)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))
    # jnp mirror used by the CPU serve path agrees too
    np.testing.assert_array_equal(
        np.asarray(ref.unpack_add_many_ref(base, pos, neg, scales),
                   np.float32),
        np.asarray(want, np.float32))


def test_unpack_add_many_ragged_expert_set():
    """Zero planes + zero scale slots (experts missing a leaf) are no-ops."""
    M, N, E = 16, 64, 3
    pos, neg = rand_plane_stack(13, E, M, N)
    z = jnp.zeros_like(pos[0])
    pos = pos.at[1].set(z)
    neg = neg.at[1].set(z)
    scales = jnp.asarray([0.5, 0.0, -0.25], jnp.float32)
    base = jnp.asarray(np.random.default_rng(14).normal(0, 1, (M, N)),
                       jnp.float32)
    got = unpack_add_many(base, pos, neg, scales, bm=8, bn=64, interpret=True)
    two = unpack_add(base, pos[0], neg[0], scales[0], bm=8, bn=64,
                     interpret=True)
    two = unpack_add(two, pos[2], neg[2], scales[2], bm=8, bn=64,
                     interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(two))


def test_unpack_add_small_shape_regression():
    """N < LANE (and N % LANE != 0) used to break the bn % LANE assert."""
    for M, N in [(5, 16), (8, 40), (3, 1)]:
        n_words = -(-N // LANE)
        pos, neg = rand_planes(20 + N, M, n_words * LANE)
        mask = ((1 << (N % LANE)) - 1) if N % LANE else 0xFFFFFFFF
        pos = pos.at[:, -1].set(pos[:, -1] & jnp.uint32(mask))
        neg = neg.at[:, -1].set(neg[:, -1] & jnp.uint32(mask))
        base = jnp.asarray(np.random.default_rng(21).normal(0, 1, (M, N)),
                           jnp.float32)
        got = unpack_add(base, pos, neg, jnp.float32(0.5), interpret=True)
        want = ref.unpack_add_ref(base, pos, neg, jnp.float32(0.5))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ternary_matmul_small_bn_regression():
    """A non-LANE-multiple bn is clamped, not asserted on."""
    pos, neg = rand_planes(22, 64, 32)
    x = jnp.asarray(np.random.default_rng(23).normal(0, 1, (4, 64)),
                    jnp.float32)
    got = ternary_matmul(x, pos, neg, jnp.float32(1.0), bm=4, bk=32, bn=48,
                         interpret=True)
    want = ref.ternary_matmul_ref(x, pos, neg, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("M,K,N,E", [(8, 32, 32, 1), (13, 96, 64, 3),
                                     (33, 64, 128, 4)])
def test_grouped_matmul_bit_identical_to_single(M, K, N, E):
    """Row-wise, the grouped kernel == the single-expert kernel run per
    expert (same block shapes) with rows selected by expert id."""
    pos, neg = rand_plane_stack(30, E, K, N)
    x = jnp.asarray(np.random.default_rng(31).normal(0, 1, (M, K)),
                    jnp.float32)
    scales = jnp.asarray(np.random.default_rng(32).normal(0, 0.5, E),
                         jnp.float32)
    eid = jnp.asarray(np.random.default_rng(33).integers(0, E, M), jnp.int32)
    kw = dict(bm=8, bk=32, bn=32, interpret=True)
    got = ternary_matmul_grouped(x, pos, neg, scales, eid, **kw)
    per = jnp.stack([ternary_matmul(x, pos[e], neg[e], scales[e], **kw)
                     for e in range(E)])
    want = per[eid, jnp.arange(M)]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_grouped_matmul_negative_rows_zero():
    """expert_idx == -1 rows (base-only requests) get an exact zero delta."""
    M, K, N, E = 9, 64, 64, 2
    pos, neg = rand_plane_stack(34, E, K, N)
    x = jnp.asarray(np.random.default_rng(35).normal(0, 1, (M, K)),
                    jnp.float32)
    eid = jnp.asarray([0, -1, 1, -1, 0, 1, -1, 0, 1], jnp.int32)
    got = ternary_matmul_grouped(x, pos, neg, jnp.ones((E,), jnp.float32),
                                 eid, bm=8, bk=32, bn=32, interpret=True)
    assert np.all(np.asarray(got)[np.asarray(eid) < 0] == 0.0)


def test_grouped_matmul_transposed_matches_ref():
    """transpose_rhs consumes [E, N, ceil(K/32)] planes (tied LM head)."""
    M, K, N, E = 7, 48, 64, 3           # K not a lane multiple
    rng = np.random.default_rng(36)
    n_words = -(-K // LANE)
    ps, ns = [], []
    mask = (1 << (K % LANE)) - 1 if K % LANE else 0xFFFFFFFF
    for e in range(E):
        p, n = rand_planes(40 + e, N, n_words * LANE)
        ps.append(p.at[:, -1].set(p[:, -1] & jnp.uint32(mask)))
        ns.append(n.at[:, -1].set(n[:, -1] & jnp.uint32(mask)))
    pos, neg = jnp.stack(ps), jnp.stack(ns)
    x = jnp.asarray(rng.normal(0, 1, (M, K)), jnp.float32)
    scales = jnp.asarray(rng.normal(0, 0.5, E), jnp.float32)
    eid = jnp.asarray(rng.integers(0, E, M), jnp.int32)
    got = ternary_matmul_grouped(x, pos, neg, scales, eid,
                                 transpose_rhs=True, bm=8, bk=32, bn=32,
                                 interpret=True)
    want = ref.ternary_matmul_grouped_ref(x, pos, neg, scales, eid,
                                          transpose_rhs=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


def test_grouped_ref_mixed_rows_equal_single_expert_runs():
    """The jnp serve-path mirror: a mixed batch is row-wise bitwise what
    single-expert batches produce (the engine's parity contract)."""
    M, K, N, E = 12, 64, 96, 3
    pos, neg = rand_plane_stack(50, E, K, N)
    x = jnp.asarray(np.random.default_rng(51).normal(0, 1, (M, K)),
                    jnp.float32)
    scales = jnp.asarray([0.3, -0.7, 1.1], jnp.float32)
    eid = jnp.asarray(np.random.default_rng(52).integers(0, E, M), jnp.int32)
    mixed = ref.ternary_matmul_grouped_ref(x, pos, neg, scales, eid)
    single = jnp.stack([
        ref.ternary_matmul_grouped_ref(x, pos[e:e + 1], neg[e:e + 1],
                                       scales[e:e + 1],
                                       jnp.zeros((M,), jnp.int32))
        for e in range(E)])
    np.testing.assert_array_equal(np.asarray(mixed),
                                  np.asarray(single[eid, jnp.arange(M)]))


def test_ops_integration_with_compressed_tensor():
    """End-to-end: Algorithm-1 compress -> pack -> kernel expert apply
    equals apply_compressed."""
    rng = np.random.default_rng(8)
    base = jnp.asarray(rng.normal(0, 1, (48, 64)), jnp.bfloat16)
    tau = {"w": jnp.asarray(rng.normal(0, 0.02, (48, 64)), jnp.float32)}
    comp = compress(tau, CompressionConfig(density=0.2))
    pt = pack_ternary(comp["w"])
    got = ops.apply_ternary_delta(base, pt)
    want = (base.astype(jnp.float32)
            + comp["w"].signs.astype(jnp.float32) * comp["w"].scale
            ).astype(jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=1e-2)


def test_ops_expert_dot_matches_core():
    from repro.core.ternary_ops import scaled_dot
    rng = np.random.default_rng(9)
    a = CompressedTensor(signs=jnp.asarray(rng.integers(-1, 2, (128,)),
                                           jnp.int8), scale=jnp.float32(0.5))
    b = CompressedTensor(signs=jnp.asarray(rng.integers(-1, 2, (128,)),
                                           jnp.int8), scale=jnp.float32(2.0))
    pa, pb = pack_ternary(a), pack_ternary(b)
    got = float(ops.expert_dot(pa, pb))
    want = float(scaled_dot(pa, pb))
    assert got == pytest.approx(want)
