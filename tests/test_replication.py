"""Replicated expert CDN: placement, failover, hedging, revalidation.

Covers the replication tentpole end to end:

* consistent-hash ring stability under replica add/remove (bounded key
  movement) and R-way publish fan-out;
* leaf-resumable mid-stream failover — bit-identical Expert vs a
  no-fault fetch, with the byte ledger proving ZERO refetched bytes;
* hedged reads (winner determinism under seeded link latencies);
* per-replica quarantine -> revalidate -> recover, and repair of
  under-replicated names;
* HTTP 206/Range roundtrip against ``serve_local_http``;
* the satellite fixes: ``bytes_wasted`` accounting, deadline-aware
  simulated links, and the DeviceCache straggler monitor.
"""

import time

import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as rapi
from repro.expert import GOLOMB, PACKED
from repro.serve.expert_cache import ExpertRegistry
from repro.transport import (ChaosFault, ChaosTransport, ChecksumError,
                             DeadlineExceeded, ExpertNotFound, HTTPTransport,
                             InMemoryTransport, LocalTransport, ReplicaFault,
                             ReplicatedTransport, RetryPolicy,
                             SimulatedNetworkTransport, decode_leaves,
                             encode_expert, peek_manifest, payload_offset,
                             serve_local_http, verify_leaf)

FAST = RetryPolicy(max_attempts=3, backoff_base_s=0.0)


def make_expert(name="cdn", seed=0, shape=(256, 192), nleaves=4):
    rng = np.random.default_rng(seed)
    tau = {f"l{i}/w": jnp.asarray(rng.normal(0, 7e-4, shape), jnp.float32)
           for i in range(nleaves)}
    return rapi.compress(tau, name=name, density=0.2)


def assert_planes_equal(a, b):
    assert set(a) == set(b)
    for p in a:
        np.testing.assert_array_equal(np.asarray(a[p].pos),
                                      np.asarray(b[p].pos))
        np.testing.assert_array_equal(np.asarray(a[p].neg),
                                      np.asarray(b[p].neg))
        assert float(a[p].scale) == float(b[p].scale)


def fleet(n=3, fault_replica=None, replica_faults=(), **rep_kw):
    """n chaos-wrapped in-memory replicas under one ReplicatedTransport."""
    inners = [InMemoryTransport() for _ in range(n)]
    chaos = [ChaosTransport(inners[i], retry=FAST,
                            replica_faults=(replica_faults
                                            if i == fault_replica else ()))
             for i in range(n)]
    rep_kw.setdefault("retry", FAST)
    return ReplicatedTransport(chaos, **rep_kw), chaos, inners


# ---------------------------------------------------------------- wire helpers
def test_manifest_carries_per_leaf_crcs():
    blob = encode_expert(make_expert(), rep=GOLOMB)
    m = peek_manifest(blob)
    leaves = decode_leaves(m)
    assert all("crc32" in l for l in leaves)
    assert [l["offset"] for l in leaves] == sorted(l["offset"]
                                                   for l in leaves)
    pay = payload_offset(blob)
    for l in leaves:
        raw = blob[pay + l["offset"]:pay + l["offset"] + l["nbytes"]]
        verify_leaf(l, raw)                      # clean bytes verify
        if l["nbytes"]:
            bad = bytearray(raw)
            bad[0] ^= 1
            with pytest.raises(ChecksumError):
                verify_leaf(l, bytes(bad))       # one flipped bit is caught


def test_decode_leaves_byte_range_selects_intersecting():
    blob = encode_expert(make_expert(), rep=PACKED)
    m = peek_manifest(blob)
    leaves = decode_leaves(m)
    l1 = leaves[1]
    mid = l1["offset"] + l1["nbytes"] // 2
    got = decode_leaves(m, byte_range=(mid, mid + 1))
    assert [l["path"] for l in got] == [l1["path"]]
    rest = decode_leaves(m, byte_range=(mid, m["payload_nbytes"]))
    assert [l["path"] for l in rest] == [l["path"] for l in leaves[1:]]


# ------------------------------------------------------------------ placement
def test_ring_stability_bounded_key_movement():
    names = [f"expert-{i}" for i in range(300)]
    ids4 = ["a", "b", "c", "d"]
    r4 = ReplicatedTransport([InMemoryTransport() for _ in ids4],
                             replica_ids=ids4, replication_factor=2)
    owners4 = {n: [ids4[i] for i in r4._owners(n)] for n in names}

    ids5 = ids4 + ["e"]
    r5 = ReplicatedTransport([InMemoryTransport() for _ in ids5],
                             replica_ids=ids5, replication_factor=2)
    owners5 = {n: [ids5[i] for i in r5._owners(n)] for n in names}

    moved = sum(1 for n in names if set(owners4[n]) != set(owners5[n]))
    # adding 1 of 5 replicas should re-home roughly R/5 of the keys; a
    # naive mod-N hash would move nearly all of them
    assert moved < 0.45 * len(names), f"{moved}/{len(names)} keys moved"
    # every changed assignment involves the new replica
    for n in names:
        diff = set(owners5[n]) - set(owners4[n])
        assert diff <= {"e"}

    # removal is symmetric: drop "e" again -> back to the original owners
    owners4b = {n: [ids4[i] for i in r4._owners(n)] for n in names}
    assert owners4b == owners4


def test_publish_fans_out_to_R_owners():
    rep, _, inners = fleet(n=3, replication_factor=2)
    experts = [make_expert(f"e{i}", seed=i) for i in range(6)]
    for ex in experts:
        info = rep.publish(ex, rep=GOLOMB)
        assert len(info["replicas"]) == 2
        holders = [i for i, t in enumerate(inners) if ex.name in t._blobs]
        assert sorted(holders) == sorted(info["replicas"])
    assert sorted(rep.names()) == sorted(e.name for e in experts)
    for ex in experts:
        assert ex.name in rep


# ------------------------------------------------- resumable fetch / failover
def test_clean_fetch_bit_identical_and_zero_waste():
    rep, chaos, _ = fleet(n=3, replication_factor=3, probe_bytes=4096)
    ex = make_expert()
    blob = encode_expert(ex, rep=PACKED)
    rep.publish(ex, rep=PACKED)
    got = rep.fetch(ex.name)
    assert_planes_equal(got.packed, ex.packed)
    assert rep.stats.bytes_wasted == 0
    # total bytes pulled across the fleet == bytes-on-wire, exactly
    assert sum(c.stats.bytes_in for c in chaos) == len(blob)
    assert rep.stats.bytes_in == len(blob)


def test_midstream_failover_refetches_only_unfinished_leaves():
    ex = make_expert()
    blob = encode_expert(ex, rep=PACKED)
    m = peek_manifest(blob)
    leaves = decode_leaves(m)
    pay = payload_offset(blob)
    probe = 4096

    # replica 0 dies after serving 2 chunks of every name: the probe
    # (op 0) and leaf0's suffix (op 1) arrive, op 2 never does
    rep, chaos, _ = fleet(n=3, fault_replica=0,
                          replica_faults=[ReplicaFault("blackout", at=2)],
                          replication_factor=3, probe_bytes=probe,
                          quarantine_after=99)
    rep.publish(ex, rep=PACKED)
    got = rep.fetch(ex.name)
    assert_planes_equal(got.packed, ex.packed)       # bit-identical stitch

    # replica 0 delivered: probe + (leaf0 end - probe) + nothing more
    leaf0_end = pay + leaves[0]["offset"] + leaves[0]["nbytes"]
    assert chaos[0].stats.bytes_in == probe + (leaf0_end - probe)
    # failover pulled ONLY the unfinished leaves from the next replica
    rest = sum(l["nbytes"] for l in leaves[1:])
    assert chaos[1].stats.bytes_in + chaos[2].stats.bytes_in == rest
    # nothing was fetched twice, nothing was thrown away
    assert sum(c.stats.bytes_in for c in chaos) == len(blob)
    assert rep.stats.bytes_wasted == 0
    assert rep.stats.retries == 1
    assert chaos[0].fired() == [{"name": ex.name, "fetch": 2,
                                 "kind": "replica_blackout"}]


def test_r1_control_fails_where_r3_survives():
    faults = [ReplicaFault("blackout", at=2)]
    ex = make_expert()

    rep1, _, _ = fleet(n=1, fault_replica=0, replica_faults=faults,
                       replication_factor=1, probe_bytes=4096)
    rep1.publish(ex, rep=PACKED)
    with pytest.raises(Exception) as ei:
        rep1.fetch(ex.name)
    assert "failed after" in str(ei.value)
    # everything the dead fetch pulled is accounted as waste
    assert rep1.stats.bytes_wasted > 0
    assert rep1.stats.bytes_wasted == rep1.replicas[0].stats.bytes_in

    rep3, _, _ = fleet(n=3, fault_replica=0, replica_faults=faults,
                       replication_factor=3, probe_bytes=4096)
    rep3.publish(ex, rep=PACKED)
    got = rep3.fetch(ex.name)
    assert_planes_equal(got.packed, ex.packed)


def test_corrupt_leaf_from_one_replica_is_refetched_clean():
    ex = make_expert()
    inners = [InMemoryTransport() for _ in range(2)]
    # bitflip on replica 0's op 1 (the first post-probe chunk)
    chaos = [ChaosTransport(inners[0], retry=FAST,
                            faults=[ChaosFault(ex.name, 1, "bitflip")]),
             ChaosTransport(inners[1], retry=FAST)]
    rep = ReplicatedTransport(chaos, replication_factor=2, probe_bytes=4096,
                              retry=FAST)
    rep.publish(ex, rep=PACKED)
    got = rep.fetch(ex.name)
    assert_planes_equal(got.packed, ex.packed)
    assert rep.stats.bytes_wasted > 0        # the corrupt chunk
    assert rep.stats.retries >= 1


def test_absent_everywhere_is_terminal_not_found():
    rep, _, _ = fleet(n=3, replication_factor=2)
    with pytest.raises(ExpertNotFound):
        rep.fetch_bytes("never-published")


# -------------------------------------------------------------------- hedging
def test_hedge_winner_deterministic_under_seeded_latencies():
    ex = make_expert()
    blob = encode_expert(ex, rep=PACKED)
    for _ in range(3):          # deterministic across repeated runs
        slow = SimulatedNetworkTransport(latency_s=0.25, seed=0)
        fast = SimulatedNetworkTransport(latency_s=0.002, seed=1)
        rep = ReplicatedTransport([slow, fast], replication_factor=2,
                                  hedge_ms=40, probe_bytes=4096, retry=FAST)
        rep.publish(ex, rep=PACKED)
        t0 = time.perf_counter()
        out = rep.fetch_bytes(ex.name)
        dt = time.perf_counter() - t0
        assert out == blob
        # the slow primary needs >= 5 x 250ms; the hedge must win long
        # before that (40ms budget + a few fast-link chunks)
        assert dt < 0.8, f"hedge did not rescue the fetch ({dt:.3f}s)"
        assert fast.stats.bytes_in >= len(blob) - 4096


def test_hedge_disabled_pays_the_slow_primary():
    ex = make_expert()
    slow = SimulatedNetworkTransport(latency_s=0.10, seed=0)
    fast = SimulatedNetworkTransport(latency_s=0.002, seed=1)
    rep = ReplicatedTransport([slow, fast], replication_factor=2,
                              hedge_ms=None, probe_bytes=4096, retry=FAST)
    rep.publish(ex, rep=PACKED)
    t0 = time.perf_counter()
    rep.fetch_bytes(ex.name)
    dt = time.perf_counter() - t0
    assert dt > 0.3              # unprobed order tries the slow link first
    assert rep.stats.bytes_wasted == 0


# ------------------------------------------- quarantine / revalidate / repair
def test_quarantine_revalidate_recover():
    ex = make_expert()
    rep, chaos, _ = fleet(n=2, fault_replica=0,
                          replica_faults=[ReplicaFault("blackout", at=0)],
                          replication_factor=2, probe_bytes=4096,
                          quarantine_after=1, quarantine_probe_s=30.0)
    rep.publish(ex, rep=PACKED)
    got = rep.fetch(ex.name)                 # fails over to replica 1
    assert_planes_equal(got.packed, ex.packed)
    h = rep.health()
    assert h["quarantined"] == 1
    assert h["replicas"][0]["quarantined_for_s"] > 0
    assert h["replicas"][0]["failures"] >= 1

    ops_before = chaos[0].stats.range_fetches + chaos[0].stats.fetches
    rep.fetch_bytes(ex.name)                 # quarantined replica skipped
    assert (chaos[0].stats.range_fetches
            + chaos[0].stats.fetches) == ops_before

    # dead host: revalidation probes it, keeps it benched
    out = rep.revalidate(repair=False)
    assert out["probed"] == 1 and out["recovered"] == 0
    assert rep.health()["quarantined"] == 1

    # host comes back: re-probe clears the bench
    chaos[0].restore_replica()
    out = rep.revalidate(repair=False)
    assert out["probed"] == 1 and out["recovered"] == 1
    h = rep.health()
    assert h["quarantined"] == 0
    assert h["replicas"][0]["failures"] == 0


def test_revalidate_repairs_under_replicated_names():
    rep, _, inners = fleet(n=3, replication_factor=2)
    ex = make_expert()
    info = rep.publish(ex, rep=GOLOMB)
    lost = info["replicas"][0]
    inners[lost]._delete(ex.name)            # a replica lost its disk
    holders = [i for i, t in enumerate(inners) if ex.name in t._blobs]
    assert len(holders) == 1                 # under-replicated now
    out = rep.revalidate(repair=True)
    assert out["repaired"] == 1
    holders = [i for i, t in enumerate(inners) if ex.name in t._blobs]
    assert sorted(holders) == sorted(info["replicas"])
    got = rep.fetch(ex.name)
    assert_planes_equal(got.packed, ex.packed)


def test_background_sweep_runs_and_stops():
    rep, _, inners = fleet(n=2, replication_factor=2)
    ex = make_expert()
    info = rep.publish(ex, rep=GOLOMB)
    inners[info["replicas"][0]]._delete(ex.name)
    rep.start_sweep(interval_s=0.05)
    try:
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            if all(ex.name in inners[i]._blobs for i in info["replicas"]):
                break
            time.sleep(0.02)
        assert all(ex.name in inners[i]._blobs for i in info["replicas"])
    finally:
        rep.stop_sweep()
    assert rep._sweep_thread is None


# ------------------------------------------------------------ HTTP 206/Range
def test_http_range_roundtrip_206(tmp_path):
    root = str(tmp_path)
    local = LocalTransport(root)
    ex = make_expert()
    blob = encode_expert(ex, rep=PACKED)
    local.publish(ex, rep=PACKED)
    server, base = serve_local_http(root)
    try:
        tr = HTTPTransport(base, retry=FAST)
        # exact interior slice
        chunk = tr.get_range(ex.name, 100, 1000)
        assert chunk == blob[100:1100]
        # probe larger than the blob clamps at EOF (single-request fetch)
        whole = tr.get_range(ex.name, 0, len(blob) + 100000)
        assert whole == blob
        assert tr.stats.bytes_wasted == 0    # 206s, no 200 fallback
        # and a replicated fetch over two HTTP replicas of the same root
        rep = ReplicatedTransport([HTTPTransport(base, retry=FAST),
                                   HTTPTransport(base, retry=FAST)],
                                  replication_factor=2, probe_bytes=4096,
                                  retry=FAST)
        out = rep.fetch_bytes(ex.name)
        assert out == blob
        assert rep.stats.bytes_wasted == 0
    finally:
        server.shutdown()


# --------------------------------------------------------- satellite: ledger
def test_simulated_timeout_partial_bytes_are_wasted():
    ex = make_expert()
    tr = SimulatedNetworkTransport(
        bandwidth_bps=1e5, latency_s=0.0, seed=0,
        retry=RetryPolicy(max_attempts=1, backoff_base_s=0.0,
                          per_attempt_timeout_s=0.05))
    tr.publish(ex, rep=GOLOMB)
    with pytest.raises(Exception):
        tr.fetch_bytes(ex.name)
    # ~0.05s at 1e5 B/s arrived before the attempt hung
    assert 0 < tr.stats.bytes_wasted <= 5500


def test_simulated_drop_counts_wasted_bytes():
    ex = make_expert()
    tr = SimulatedNetworkTransport(loss=0.5, seed=3, retry=FAST)
    tr.publish(ex, rep=GOLOMB)
    blob = encode_expert(ex, rep=GOLOMB)
    failures = 0
    for _ in range(6):
        try:
            tr.fetch_bytes(ex.name)
        except Exception:
            failures += 1           # all attempts dropped
    # every drop crossed the link and bought nothing: waste is an exact
    # multiple of the blob, one per retry plus one per exhausted fetch
    # (whose final drop triggers no further retry)
    drops = tr.stats.bytes_wasted // len(blob)
    assert drops >= 1
    assert tr.stats.bytes_wasted == drops * len(blob)
    assert drops == tr.stats.retries + failures


def test_deadline_skips_link_sleep():
    ex = make_expert()
    crawl = SimulatedNetworkTransport(bandwidth_bps=1e3, latency_s=0.0,
                                      seed=0)
    crawl.publish(ex, rep=GOLOMB)
    pol = RetryPolicy(max_attempts=3, backoff_base_s=0.0, deadline_s=0.05)
    t0 = time.perf_counter()
    with pytest.raises(DeadlineExceeded):
        crawl.fetch_expert(ex.name, retry=pol)
    # the blob needs ~20s of link time; without the deadline check the
    # attempt would sleep through all of it
    assert time.perf_counter() - t0 < 1.0


# ------------------------------------------- satellite: straggler + registry
def test_registry_replicas_knob_and_health_sections():
    ex = make_expert()
    reg = rapi.registry(replicas=[InMemoryTransport() for _ in range(3)],
                        replication_factor=2)
    assert isinstance(reg.store.transport, ReplicatedTransport)
    assert reg.store.transport.replication_factor == 2
    reg.publish(ex, rep=GOLOMB)
    got = reg.get(ex.name)
    assert_planes_equal(got.packed, ex.packed)
    h = reg.health()
    assert len(h["replicas"]["replicas"]) == 3
    assert h["replicas"]["quarantined"] == 0

    with pytest.raises(ValueError):
        rapi.registry(transport=InMemoryTransport(),
                      replicas=[InMemoryTransport()])
    with pytest.raises(ValueError):
        ExpertRegistry(replication_factor=2)   # needs replicas=


def test_api_publish_accepts_replica_list():
    ex = make_expert()
    fleet_ = [InMemoryTransport() for _ in range(3)]
    info = rapi.publish(ex, fleet_, rep=GOLOMB, replication_factor=2)
    holders = [i for i, t in enumerate(fleet_) if ex.name in t._blobs]
    assert sorted(holders) == sorted(info["replicas"])
    # a consumer over the same fleet computes the same owners
    rep = ReplicatedTransport(fleet_, replication_factor=2)
    assert rep._owners(ex.name) == info["replicas"]
    got = rep.fetch(ex.name)
    assert_planes_equal(got.packed, ex.packed)


def test_device_cache_straggler_recommendation_surfaces():
    ex = [make_expert(f"s{i}", seed=i) for i in range(3)]
    inner = InMemoryTransport()
    # per-name ops 3..4 pay +0.5s (a replica warming up); promotion-
    # latency health should flag the slow promotions it causes
    chaos = ChaosTransport(inner, retry=FAST, replica_faults=[
        ReplicaFault("slow_start", at=3, slow_s=0.5, warmup=2)])
    reg = rapi.registry(transport=chaos)
    for e in ex:
        reg.publish(e, rep=PACKED)      # publish keeps a cold-local copy
    cache = reg.device()
    for e in ex:                        # cold-local promotions: fast
        cache.fetch(e.name)
    assert cache.stats.straggler_recommendation == "healthy"
    # repeated re-promotions of one name advance its per-name op count
    # into the slow window; drop it from every tier to force refetches
    for _ in range(4):
        cache._cache.pop(ex[0].name, None)
        cache._sizes.pop(ex[0].name, None)
        reg.store._evict_cold(ex[0].name)     # force a real refetch
        cache.fetch(ex[0].name)
    assert cache.stats.straggler_flags >= 1
    assert cache.stats.straggler_recommendation in ("monitor",
                                                    "exclude-host-and-reshard")
    h = reg.health()
    assert h["straggler"]["recommendation"] != "healthy"
    assert h["straggler"]["flags"] >= 1
