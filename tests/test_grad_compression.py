"""Tests for EF-ternary cross-pod gradient compression.

Leaf-level tests run single-device; the shard_map collective test runs in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=4 so the
main test process keeps seeing exactly one device (per the dry-run rules).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gradient_compression import (GradCompressionConfig,
                                             _pack_planes, _unpack_planes,
                                             compress_leaf_for_allgather,
                                             gaussian_topk_threshold,
                                             init_error_state)


def test_gaussian_threshold_density():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 0.3, (50_000,)), jnp.float32)
    for k in (0.05, 0.1, 0.3):
        thr = gaussian_topk_threshold(x, k)
        frac = float(jnp.mean((jnp.abs(x) >= thr).astype(jnp.float32)))
        assert abs(frac - k) < 0.02, (k, frac)


def test_plane_pack_roundtrip():
    rng = np.random.default_rng(1)
    signs = jnp.asarray(rng.integers(-1, 2, (1000,)), jnp.int8)
    pos, neg = _pack_planes(signs)
    back = _unpack_planes(pos, neg, 1000)
    np.testing.assert_array_equal(np.array(back, np.int8), np.array(signs))


def test_error_feedback_reduces_bias():
    """Repeated EF compression of a constant gradient converges: mean of
    reconstructions -> true gradient (the EF guarantee)."""
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(0, 1, (8_192,)), jnp.float32)
    cfg = GradCompressionConfig(density=0.1)
    err = jnp.zeros_like(g)
    recons = []
    step = jax.jit(lambda e: compress_leaf_for_allgather(g, e, cfg))
    for _ in range(120):
        pos, neg, scale, err = step(err)
        recon = _unpack_planes(pos, neg, g.size) * scale
        recons.append(np.array(recon))
    early = np.linalg.norm(np.mean(recons[:10], axis=0) - np.array(g))
    late = np.linalg.norm(np.mean(recons, axis=0) - np.array(g))
    rel = late / np.linalg.norm(np.array(g))
    assert rel < 0.12, rel
    assert late < early  # averaging converges (EF guarantee)


def test_compressed_leaf_is_sparse_and_scaled():
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.normal(0, 1, (4_096,)), jnp.float32)
    cfg = GradCompressionConfig(density=0.05)
    pos, neg, scale, err = compress_leaf_for_allgather(
        g, jnp.zeros_like(g), cfg)
    dens = (float(jnp.sum(jax.lax.population_count(pos)))
            + float(jnp.sum(jax.lax.population_count(neg)))) / g.size
    assert abs(dens - 0.05) < 0.02
    assert float(scale) > 0


def test_init_error_state_shapes():
    params = {"a": jnp.ones((3, 4), jnp.bfloat16), "b": jnp.ones((7,))}
    e = init_error_state(params)
    assert e["a"].shape == (3, 4) and e["a"].dtype == jnp.float32
    assert float(jnp.sum(e["b"])) == 0.0


SHARD_MAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from jax import shard_map
    from repro.core.gradient_compression import (
        GradCompressionConfig, compressed_cross_pod_mean, init_error_state)

    mesh = jax.make_mesh((4,), ("pod",))
    cfg = GradCompressionConfig(density=0.25)
    rng = np.random.default_rng(0)
    g_all = jnp.asarray(rng.normal(0, 1, (4, 2048)), jnp.float32)

    def f(g):
        g = g.reshape(2048)
        mean, err = compressed_cross_pod_mean(
            {"w": g}, {"w": jnp.zeros_like(g)}, cfg, axis_name="pod")
        return mean["w"][None], err["w"][None]

    fm = shard_map(f, mesh=mesh, in_specs=P("pod"),
                   out_specs=(P("pod"), P("pod")))
    mean, err = jax.jit(fm)(g_all)
    mean = np.array(mean)
    # all pods agree on the mean
    assert np.allclose(mean[0], mean[1]) and np.allclose(mean[0], mean[3])
    # compressed mean correlates strongly with true mean
    true = np.mean(np.array(g_all), axis=0)
    corr = np.corrcoef(mean[0], true)[0, 1]
    assert corr > 0.55, corr
    # error feedback holds the residual
    assert float(np.abs(np.array(err)).sum()) > 0
    print("OK")
""")


def test_cross_pod_mean_shard_map():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SHARD_MAP_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
