"""Serving tier: expert store/cache hierarchy, LRU eviction, swap
accounting, end-to-end multi-expert engine, and the compressed-expert
export/import round trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Runtime, build
from repro.peft import compress_expert, task_vector
from repro.serve import (EngineConfig, ExpertStore, Request, ServeEngine,
                         uncompressed_baseline_bytes)

RT = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")


def make_experts(api, base, n=3, scale=0.01):
    """Fake fine-tunes: base + random deltas, ComPEFT-compressed."""
    store = ExpertStore()
    for i in range(n):
        key = jax.random.PRNGKey(100 + i)
        leaves, tdef = jax.tree_util.tree_flatten(base)
        keys = jax.random.split(key, len(leaves))
        ft = jax.tree_util.tree_unflatten(tdef, [
            (l.astype(jnp.float32)
             + scale * jax.random.normal(k, l.shape)).astype(l.dtype)
            for l, k in zip(leaves, keys)])
        tau = task_vector(base, ft)
        # flatten to path-dict so the engine can merge by path
        from repro.peft.lora import _path_str
        flat, _ = jax.tree_util.tree_flatten_with_path(tau)
        tau_dict = {_path_str(p): l for p, l in flat}
        art = compress_expert(f"expert{i}", "full", tau_dict, density=0.2,
                              alpha=1.0)
        store.put(art)
    return store


def test_store_and_cache_lru():
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    store = make_experts(api, base, n=3)
    from repro.serve import DeviceCache
    one = store.get("expert0")
    packed_bytes = one.nbytes
    cache = DeviceCache(store, capacity_bytes=int(packed_bytes * 1.5))

    cache.fetch("expert0")
    cache.fetch("expert1")           # evicts expert0 (capacity 1.5 experts)
    assert cache.stats.evictions >= 1
    cache.fetch("expert1")
    assert cache.stats.hits == 1
    # packed residency: device bytes are the compressed bytes, far below
    # what dense f32 deltas would have cost for the same promotions
    dense_bytes = uncompressed_baseline_bytes(one) * 2  # f32 deltas
    assert cache.stats.host_to_device_bytes < 2 * dense_bytes / 8
    assert cache.stats.host_to_device_bytes == cache.stats.store_to_host_bytes


def test_packed_residency_capacity_multiplier():
    """Under one byte budget the packed-resident cache must hold >= 8x the
    experts a dense-delta cache would (the tentpole capacity claim)."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    store = make_experts(api, base, n=10)
    from repro.serve import DeviceCache
    one = store.get("expert0")
    dense_bytes = uncompressed_baseline_bytes(one) * 2  # f32 dense deltas
    budget = int(dense_bytes * 1.5)   # seed layout: fits 1 dense expert
    cache = DeviceCache(store, capacity_bytes=budget)
    for i in range(10):
        cache.fetch(f"expert{i}")
    assert cache.stats.evictions == 0
    assert len(cache.resident()) >= 8
    assert cache.resident_bytes() <= budget


def test_engine_end_to_end_multi_expert():
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    store = make_experts(api, base, n=2)
    eng = ServeEngine(api, RT, base, store,
                      EngineConfig(max_batch=4, cache_len=48))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    expert=f"expert{i % 2}",
                    prompt=jnp.asarray(rng.integers(1, cfg.vocab, 12),
                                       jnp.int32),
                    max_new_tokens=4)
            for i in range(6)]
    out = eng.run(reqs)
    for r in out:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)
    s = eng.swap_summary()
    assert s["n_swaps"] == 2           # one merge per expert
    assert s["store_to_host_bytes"] > 0


def test_packed_swap_bitwise_matches_dense_path():
    """The fused plane merge must reproduce the seed dense round-trip
    (decompress to {path: f32 delta}, add, cast) bit for bit."""
    from repro.peft.lora import _path_str
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    store = make_experts(api, base, n=1, scale=0.03)
    eng = ServeEngine(api, RT, base, store, EngineConfig(cache_len=32))
    got = eng._params_for("expert0")

    tau_dense = store.get("expert0").to_dense_tau()   # {path: f32 delta}
    flat, treedef = jax.tree_util.tree_flatten_with_path(base)
    want = []
    for path, leaf in flat:
        d = tau_dense.get(_path_str(path))
        if d is None:
            want.append(leaf)
        else:
            want.append((leaf.astype(jnp.float32)
                         + jnp.asarray(d).reshape(leaf.shape)
                         ).astype(leaf.dtype))
    want = jax.tree_util.tree_unflatten(treedef, want)
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_experts_change_behaviour():
    """A compressed expert must actually alter logits vs base."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    store = make_experts(api, base, n=1, scale=0.05)
    eng = ServeEngine(api, RT, base, store, EngineConfig(cache_len=32))
    p_exp = eng._params_for("expert0")
    toks = jnp.ones((1, 8), jnp.int32)
    l_base, _ = api.forward(base, {"tokens": toks}, RT)
    l_exp, _ = api.forward(p_exp, {"tokens": toks}, RT)
    assert float(jnp.max(jnp.abs(l_base - l_exp))) > 1e-3


def test_export_import_expert_roundtrip(tmp_path):
    from repro.checkpoint.manager import export_expert, import_expert
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    leaves, tdef = jax.tree_util.tree_flatten(base)
    keys = jax.random.split(jax.random.PRNGKey(5), len(leaves))
    ft = jax.tree_util.tree_unflatten(tdef, [
        (l.astype(jnp.float32) + 0.01 * jax.random.normal(k, l.shape)
         ).astype(l.dtype) for l, k in zip(leaves, keys)])

    stats = export_expert(base, ft, str(tmp_path / "e.npz"), density=0.1)
    assert stats["ratio"] > 8.0   # paper: >= 8x
    taus, manifest = import_expert(str(tmp_path / "e.npz"))
    assert manifest["density"] == 0.1
    # decompressed values are ternary * scale
    anyleaf = next(iter(taus.values()))
    vals = np.unique(anyleaf)
    assert len(vals) <= 3
