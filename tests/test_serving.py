"""Serving tier: expert registry/store/cache hierarchy, LRU eviction, swap
accounting, end-to-end multi-expert engine, and the compressed-expert
export/import round trip — all through the ``repro.api`` facade."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api as rapi
from repro.configs import get_smoke_config
from repro.expert import GOLOMB, PACKED
from repro.models import Runtime, build
from repro.serve import (EngineConfig, ExpertRegistry, ExpertStore, Request,
                         ServeEngine, uncompressed_baseline_bytes)

RT = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")


def make_experts(api, base, n=3, scale=0.01, density=0.2,
                 **registry_kw) -> ExpertRegistry:
    """Fake fine-tunes: base + random deltas, ComPEFT-compressed into a
    registry (the facade path — no hand-flattening, no ExpertArtifact)."""
    reg = rapi.registry(**registry_kw)
    for i in range(n):
        key = jax.random.PRNGKey(100 + i)
        leaves, tdef = jax.tree_util.tree_flatten(base)
        keys = jax.random.split(key, len(leaves))
        ft = jax.tree_util.tree_unflatten(tdef, [
            (l.astype(jnp.float32)
             + scale * jax.random.normal(k, l.shape)).astype(l.dtype)
            for l, k in zip(leaves, keys)])
        reg.add(rapi.compress(base, ft, name=f"expert{i}", density=density))
    return reg


def test_store_and_cache_lru():
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=3)
    one = reg.get("expert0")
    packed_bytes = one.nbytes(PACKED)
    cache = reg.device(int(packed_bytes * 1.5))

    cache.fetch("expert0")
    cache.fetch("expert1")           # evicts expert0 (capacity 1.5 experts)
    assert cache.stats.evictions >= 1
    cache.fetch("expert1")
    assert cache.stats.hits == 1
    # packed residency: device bytes are the compressed bytes, far below
    # what dense f32 deltas would have cost for the same promotions
    dense_bytes = uncompressed_baseline_bytes(one) * 2  # f32 deltas
    assert cache.stats.host_to_device_bytes < 2 * dense_bytes / 8
    assert cache.stats.host_to_device_bytes == cache.stats.store_to_host_bytes


def test_packed_residency_capacity_multiplier():
    """Under one byte budget the packed-resident cache must hold >= 8x the
    experts a dense-delta cache would (the tentpole capacity claim)."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=10)
    dense_bytes = uncompressed_baseline_bytes(reg.get("expert0")) * 2
    budget = int(dense_bytes * 1.5)   # seed layout: fits 1 dense expert
    cache = reg.device(budget)
    for i in range(10):
        cache.fetch(f"expert{i}")
    assert cache.stats.evictions == 0
    assert len(cache.resident()) >= 8
    assert cache.resident_bytes() <= budget


def test_stack_bytes_count_against_budget():
    """Stack-aware HBM accounting: an over-capacity stack build must
    trigger eviction (other stacks first, then LRU non-member trees), and
    resident_bytes() includes the stack buffers."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=3)
    one = reg.get("expert0").nbytes(PACKED)
    # room for all three packed trees, but NOT for trees + two stacks
    cache = reg.device(int(one * 4.5))
    cache.stacked(("expert0", "expert1"))
    assert cache.stats.stack_bytes > 0
    assert cache.resident_bytes() <= cache.capacity
    # second stack overflows the budget -> the first stack must be evicted
    cache.stacked(("expert1", "expert2"))
    assert cache.stats.stack_evictions >= 1
    assert not cache.has_stack(("expert0", "expert1"))
    assert cache.has_stack(("expert1", "expert2"))
    assert cache.resident_bytes() <= cache.capacity


def test_tiny_budget_stack_evicts_trees():
    """With a budget that can't hold trees + stack, LRU non-member packed
    trees are evicted to make room for the active stack."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=3)
    one = reg.get("expert0").nbytes(PACKED)
    cache = reg.device(int(one * 3.5))
    cache.fetch("expert2")          # non-member: the eviction victim
    cache.stacked(("expert0", "expert1"))   # 2 trees + stack > budget
    assert "expert2" not in cache.resident()
    assert cache.stats.evictions >= 1
    # the active set itself is protected even when over budget
    assert cache.has_stack(("expert0", "expert1"))


def test_engine_end_to_end_multi_expert():
    """Default (mixed) scheduling: heterogeneous waves, ZERO merges."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=2)
    eng = rapi.serve(api, RT, base, reg, max_batch=4, cache_len=48)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    expert=f"expert{i % 2}",
                    prompt=jnp.asarray(rng.integers(1, cfg.vocab, 12),
                                       jnp.int32),
                    max_new_tokens=4)
            for i in range(6)]
    out = eng.run(reqs)
    for r in out:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)
    s = eng.swap_summary()
    assert s["n_swaps"] == 0           # zero-merge hot path
    assert s["n_waves"] >= 1
    assert s["stack_builds"] >= 1
    assert s["store_to_host_bytes"] > 0


def test_engine_grouped_mode_still_merges():
    """scheduling='grouped' keeps the PR-1 merge-on-swap baseline."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=2)
    eng = rapi.serve(api, RT, base, reg, max_batch=4, cache_len=48,
                     scheduling="grouped")
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, expert=f"expert{i % 2}",
                    prompt=jnp.asarray(rng.integers(1, cfg.vocab, 12),
                                       jnp.int32), max_new_tokens=4)
            for i in range(6)]
    eng.run(reqs)
    s = eng.swap_summary()
    assert s["n_swaps"] == 2           # one merge per expert
    assert s["n_waves"] == 0
    for r in reqs:
        assert len(r.out_tokens) == 4


def test_mixed_wave_bit_identical_to_sequential():
    """The tentpole correctness contract: a mixed-expert wave produces
    exactly the tokens each request gets when its expert is served alone
    through the same zero-merge path."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=3, scale=0.03)
    rng = np.random.default_rng(1)
    prompts = [jnp.asarray(rng.integers(1, cfg.vocab, 10), jnp.int32)
               for _ in range(6)]

    def mk():
        return [Request(uid=i, expert=f"expert{i % 3}", prompt=prompts[i],
                        max_new_tokens=4) for i in range(6)]

    eng = rapi.serve(api, RT, base, reg, max_batch=6, cache_len=48)
    mixed = mk()
    eng.run(mixed)

    eng2 = rapi.serve(api, RT, base, make_experts(api, base, n=3,
                                                  scale=0.03),
                      max_batch=6, cache_len=48)
    seq = mk()
    for e in range(3):
        eng2.run([r for r in seq if r.expert == f"expert{e}"])
    assert ({r.uid: r.out_tokens for r in mixed}
            == {r.uid: r.out_tokens for r in seq})


def test_mixed_wave_base_rows():
    """__base__ requests ride in a mixed wave with a zero delta."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=1, scale=0.05)
    rng = np.random.default_rng(2)
    prompt = jnp.asarray(rng.integers(1, cfg.vocab, 10), jnp.int32)
    reqs = [Request(uid=0, expert="__base__", prompt=prompt,
                    max_new_tokens=4),
            Request(uid=1, expert="expert0", prompt=prompt,
                    max_new_tokens=4)]
    eng = rapi.serve(api, RT, base, reg, max_batch=2, cache_len=48)
    eng.run(reqs)
    solo = Request(uid=2, expert="__base__", prompt=prompt, max_new_tokens=4)
    eng2 = rapi.serve(api, RT, base, make_experts(api, base, n=1,
                                                  scale=0.05),
                      max_batch=2, cache_len=48)
    eng2.run([solo])
    assert reqs[0].out_tokens == solo.out_tokens
    assert eng.swap_summary()["n_swaps"] == 0


def test_continuous_admission_refills_slots():
    """More requests than batch slots: finished rows are refilled in place
    (one wave, spliced prefills) instead of starting fresh waves."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=2)
    rng = np.random.default_rng(3)
    reqs = [Request(uid=i, expert=f"expert{i % 2}",
                    prompt=jnp.asarray(rng.integers(1, cfg.vocab, 8),
                                       jnp.int32),
                    max_new_tokens=2 + (i % 3))
            for i in range(7)]
    eng = rapi.serve(api, RT, base, reg, max_batch=3, cache_len=64)
    eng.run(reqs)
    for r in reqs:
        assert len(r.out_tokens) == r.max_new_tokens
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)
    s = eng.swap_summary()
    assert s["admitted"] >= 1
    assert s["n_swaps"] == 0


def test_admitted_row_matches_solo_serve():
    """Per-row pad-mask regression: a request spliced into a running wave
    (left-padded single-row prefill + KV splice) must produce the same
    tokens as the same prompt served solo — the pad tokens are masked out
    of its attention."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=2, scale=0.03)
    rng = np.random.default_rng(7)
    pa = jnp.asarray(rng.integers(1, cfg.vocab, 9), jnp.int32)
    pb = jnp.asarray(rng.integers(1, cfg.vocab, 5), jnp.int32)   # shorter!
    a = Request(uid=0, expert="expert0", prompt=pa, max_new_tokens=3)
    b = Request(uid=1, expert="expert1", prompt=pb, max_new_tokens=4)
    eng = rapi.serve(api, RT, base, reg, max_batch=1, cache_len=64)
    eng.run([a, b])
    assert eng.swap_summary()["admitted"] == 1   # b spliced into a's slot

    solo = Request(uid=2, expert="expert1", prompt=pb, max_new_tokens=4)
    eng2 = rapi.serve(api, RT, base, make_experts(api, base, n=2,
                                                  scale=0.03),
                      max_batch=1, cache_len=64)
    eng2.run([solo])
    assert b.out_tokens == solo.out_tokens


def test_ragged_wave_rows_match_solo_serve():
    """Rows left-padded at wave start (ragged prompt lengths in one batch)
    also ignore their pads: every row matches its solo serve."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=2, scale=0.03)
    rng = np.random.default_rng(8)
    lens = (6, 10, 8)
    prompts = [jnp.asarray(rng.integers(1, cfg.vocab, L), jnp.int32)
               for L in lens]
    reqs = [Request(uid=i, expert=f"expert{i % 2}", prompt=prompts[i],
                    max_new_tokens=3) for i in range(3)]
    eng = rapi.serve(api, RT, base, reg, max_batch=3, cache_len=48)
    eng.run(reqs)
    for i in range(3):
        solo = Request(uid=10 + i, expert=f"expert{i % 2}",
                       prompt=prompts[i], max_new_tokens=3)
        engs = rapi.serve(api, RT, base, make_experts(api, base, n=2,
                                                      scale=0.03),
                          max_batch=1, cache_len=48)
        engs.run([solo])
        assert reqs[i].out_tokens == solo.out_tokens, f"row {i} diverged"


def test_unsupported_family_falls_back_to_merge():
    """A family the overlay cannot express (MoE) serves via merge-on-swap
    even under mixed scheduling."""
    cfg = get_smoke_config("mixtral_8x7b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=2, scale=0.02)
    eng = rapi.serve(api, RT, base, reg, max_batch=4, cache_len=48)
    assert eng._plan is None
    rng = np.random.default_rng(4)
    reqs = [Request(uid=i, expert=f"expert{i % 2}",
                    prompt=jnp.asarray(rng.integers(1, cfg.vocab, 8),
                                       jnp.int32), max_new_tokens=2)
            for i in range(4)]
    eng.run(reqs)
    for r in reqs:
        assert len(r.out_tokens) == 2
    assert eng.swap_summary()["n_swaps"] == 2   # fallback merged per expert


def test_merged_ensemble_single_sweep():
    """unpack_add_many consumer: W + sum_e a_e D_e in one sweep equals
    applying the scaled experts one at a time."""
    from repro.core.packing import PackedTernary
    from repro.kernels.ops import apply_ternary_delta_flat
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=3, scale=0.03)
    eng = rapi.serve(api, RT, base, reg, cache_len=32)
    weights = [0.5, 1.0, 0.25]
    got = eng.merged_ensemble_params([f"expert{i}" for i in range(3)],
                                     weights)

    from repro.peft.lora import _path_str
    flat, treedef = jax.tree_util.tree_flatten_with_path(base)
    want = []
    packs = [reg.get(f"expert{i}").packed for i in range(3)]
    for path, leaf in flat:
        ps = _path_str(path)
        acc = leaf
        for pk, w in zip(packs, weights):
            if ps in pk:
                pt = pk[ps]
                scaled = PackedTernary(pos=pt.pos, neg=pt.neg,
                                       scale=pt.scale * w, shape=pt.shape,
                                       orig_dtype=pt.orig_dtype)
                acc = apply_ternary_delta_flat(acc, scaled)
        want.append(acc)
    want = jax.tree_util.tree_unflatten(treedef, want)
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_array_equal(np.asarray(g, np.float32),
                                      np.asarray(w, np.float32))


def test_golomb_cold_store_roundtrip():
    """cold_golomb registry tier: promotion decodes all leaves in one
    batched pass and reproduces the exact packed planes."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    warm = make_experts(api, base, n=1)
    art = warm.get("expert0")
    cold = rapi.registry(cold_golomb=True)
    cold.add(art)
    assert cold.nbytes("expert0") < art.nbytes(PACKED)  # golomb < bitplanes
    back = cold.get("expert0")
    for path, pt in art.packed.items():
        bpt = back.packed[path]
        np.testing.assert_array_equal(np.asarray(pt.pos),
                                      np.asarray(bpt.pos))
        np.testing.assert_array_equal(np.asarray(pt.neg),
                                      np.asarray(bpt.neg))
        np.testing.assert_allclose(float(pt.scale), float(bpt.scale),
                                   rtol=1e-6)


def test_admitted_row_keeps_first_token():
    """Regression: a slot-refilled request's first generated token is the
    argmax of its (left-padded, pad-masked) prefill — it must not be
    dropped."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=2, scale=0.03)
    rng = np.random.default_rng(5)
    pa = jnp.asarray(rng.integers(1, cfg.vocab, 8), jnp.int32)
    pb = jnp.asarray(rng.integers(1, cfg.vocab, 6), jnp.int32)
    a = Request(uid=0, expert="expert0", prompt=pa, max_new_tokens=1)
    b = Request(uid=1, expert="expert1", prompt=pb, max_new_tokens=2)
    eng = rapi.serve(api, RT, base, reg, max_batch=1, cache_len=32)
    eng.run([a, b])
    assert eng.swap_summary()["admitted"] == 1

    # expected: B prefilled left-padded to cur=8 (A's prompt len, A decoded
    # 0 steps past prefill) with its pads masked (start=2), then one decode
    # step — through the same zero-merge overlay
    overlay = eng._overlay_for(("expert0", "expert1"))
    eid = jnp.asarray([1], jnp.int32)
    start = jnp.asarray([8 - pb.shape[0]], jnp.int32)
    padded = jnp.pad(pb, (8 - pb.shape[0], 0), constant_values=1)[None]
    logits, cache = api.prefill(base, {"tokens": padded}, RT, 32,
                                delta=overlay, eid=eid, start=start)
    t1 = int(jnp.argmax(logits[0, -1]))
    logits2, _ = api.decode_step(base, jnp.asarray([[t1]], jnp.int32),
                                 cache, RT, delta=overlay, eid=eid)
    t2 = int(jnp.argmax(logits2[0, -1]))
    assert b.out_tokens == [t1, t2]


def test_mixed_unknown_expert_raises():
    """A typo'd expert name must fail loudly under mixed scheduling, not
    silently serve base weights (only __base__ gets the zero slot)."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=1)
    eng = rapi.serve(api, RT, base, reg, max_batch=2, cache_len=32)
    bad = Request(uid=0, expert="expert_9",
                  prompt=jnp.ones((6,), jnp.int32), max_new_tokens=2)
    with pytest.raises(KeyError):
        eng.run([bad])


def test_stacked_buffers_invalidated_on_eviction():
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=3)
    one = reg.get("expert0").nbytes(PACKED)
    cache = reg.device(int(one * 4.5))
    cache.stacked(("expert0", "expert1"))
    assert cache.stats.stack_builds == 1
    cache.stacked(("expert0", "expert1"))
    assert cache.stats.stack_hits == 1
    cache.fetch("expert2")                 # evicts expert0 -> stack dropped
    assert cache.stats.evictions >= 1
    assert cache.stats.stack_bytes == 0
    cache.stacked(("expert0", "expert1"))  # rebuilt
    assert cache.stats.stack_builds == 2


def test_packed_swap_bitwise_matches_dense_path():
    """The fused plane merge must reproduce the seed dense round-trip
    (decompress to {path: f32 delta}, add, cast) bit for bit."""
    from repro.peft.lora import _path_str
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=1, scale=0.03)
    eng = rapi.serve(api, RT, base, reg, cache_len=32)
    got = eng._params_for("expert0")

    recon = reg.get("expert0").to_dense_tau()   # {nested}: tau_tilde
    flat_r, _ = jax.tree_util.tree_flatten_with_path(recon)
    tau_dense = {_path_str(p): np.asarray(l) for p, l in flat_r}
    flat, treedef = jax.tree_util.tree_flatten_with_path(base)
    want = []
    for path, leaf in flat:
        d = tau_dense.get(_path_str(path))
        if d is None:
            want.append(leaf)
        else:
            want.append((leaf.astype(jnp.float32)
                         + jnp.asarray(d).reshape(leaf.shape)
                         ).astype(leaf.dtype))
    want = jax.tree_util.tree_unflatten(treedef, want)
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_experts_change_behaviour():
    """A compressed expert must actually alter logits vs base."""
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    reg = make_experts(api, base, n=1, scale=0.05)
    eng = rapi.serve(api, RT, base, reg, cache_len=32)
    p_exp = eng._params_for("expert0")
    toks = jnp.ones((1, 8), jnp.int32)
    l_base, _ = api.forward(base, {"tokens": toks}, RT)
    l_exp, _ = api.forward(p_exp, {"tokens": toks}, RT)
    assert float(jnp.max(jnp.abs(l_base - l_exp))) > 1e-3


def test_legacy_store_and_artifact_still_work():
    """Deprecated entry points: compress_expert + ExpertStore wired
    straight into ServeEngine keep serving (with warnings)."""
    from repro.peft import compress_expert
    from repro.peft.lora import _path_str
    from repro.peft.task_vector import task_vector
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    store = ExpertStore()
    leaves, tdef = jax.tree_util.tree_flatten(base)
    keys = jax.random.split(jax.random.PRNGKey(100), len(leaves))
    ft = jax.tree_util.tree_unflatten(tdef, [
        (l.astype(jnp.float32)
         + 0.02 * jax.random.normal(k, l.shape)).astype(l.dtype)
        for l, k in zip(leaves, keys)])
    tau = task_vector(base, ft)
    flat, _ = jax.tree_util.tree_flatten_with_path(tau)
    with pytest.deprecated_call():
        art = compress_expert("expert0", "full",
                              {_path_str(p): l for p, l in flat},
                              density=0.2, alpha=1.0)
    store.put(art)
    with pytest.deprecated_call():
        eng = ServeEngine(api, RT, base, store,
                          EngineConfig(max_batch=2, cache_len=32))
    req = Request(uid=0, expert="expert0",
                  prompt=jnp.ones((6,), jnp.int32), max_new_tokens=2)
    eng.run([req])
    assert len(req.out_tokens) == 2


def test_export_import_expert_roundtrip(tmp_path):
    """Legacy checkpoint shims still work (now over Expert.save/load)."""
    from repro.checkpoint.manager import export_expert, import_expert
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    leaves, tdef = jax.tree_util.tree_flatten(base)
    keys = jax.random.split(jax.random.PRNGKey(5), len(leaves))
    ft = jax.tree_util.tree_unflatten(tdef, [
        (l.astype(jnp.float32) + 0.01 * jax.random.normal(k, l.shape)
         ).astype(l.dtype) for l, k in zip(leaves, keys)])

    with pytest.deprecated_call():
        stats = export_expert(base, ft, str(tmp_path / "e.npz"), density=0.1)
    assert stats["ratio"] > 8.0   # paper: >= 8x
    with pytest.deprecated_call():
        taus, manifest = import_expert(str(tmp_path / "e.npz"))
    assert manifest["density"] == 0.1
    # decompressed values are ternary * scale
    anyleaf = next(iter(taus.values()))
    vals = np.unique(anyleaf)
    assert len(vals) <= 3
