"""Streaming-compression validation: the O(n) histogram-quantile threshold
vs ``jnp.quantile``, the Pallas sweep kernel vs the vectorised jnp path, and
the end-to-end ``compress_packed`` pipeline vs the seed per-leaf path."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CompressionConfig, compress, compress_packed,
                        decompress, pack_tree, unpack_tree)
from repro.core.compeft import _build_segment_buffer
from repro.kernels.histogram_quantile import (NBINS,
                                              _segment_hist_moments_jnp,
                                              segment_hist_moments_pallas,
                                              segmented_quantile_moments)

DENSITIES = (0.05, 0.1, 0.5)


def _dists(n=20_000):
    rng = np.random.default_rng(0)
    return {
        "normal": rng.normal(0, 1, n).astype(np.float32),
        "constant": np.full(n, 0.7, np.float32),
        "bimodal": np.where(rng.random(n) < 0.5, 0.1, 10.0
                            ).astype(np.float32) * rng.choice([-1, 1], n),
        "heavy_tail": (rng.standard_t(2, n) * 3).astype(np.float32),
        "with_zeros": np.where(rng.random(n) < 0.8, 0.0,
                               rng.normal(0, 1, n)).astype(np.float32),
    }


def _segbuf(arrays, cols=512):
    leaves = [jnp.asarray(a) for a in arrays]
    return _build_segment_buffer(leaves, cols)


@pytest.mark.parametrize("density", DENSITIES)
def test_threshold_matches_order_statistic(density):
    """thr must sit within one refined histogram bin below the k-th largest
    magnitude — for every distribution, including ties and heavy tails."""
    arrays = list(_dists().values())
    buf, row_seg, row_valid, seg_count, _ = _segbuf(arrays)
    out = segmented_quantile_moments(buf, row_seg, row_valid, seg_count,
                                     density, n_seg=len(arrays))
    for i, a in enumerate(arrays):
        mag = np.abs(a)
        n = a.size
        k = max(1, round(density * n))
        kth = np.partition(mag, n - k)[n - k]          # k-th largest
        thr = float(out["threshold"][i])
        bin_w = float(out["max"][i]) / NBINS           # coarse-bin width
        assert thr <= kth + 1e-7, (i, thr, kth)
        assert kth - thr <= bin_w + 1e-7, (i, thr, kth, bin_w)
        # the kept set contains the top-k (ties may keep a few more)
        kept = int((mag >= thr).sum())
        assert kept >= k


@pytest.mark.parametrize("density", DENSITIES)
def test_threshold_close_to_jnp_quantile_smooth(density):
    """On smooth distributions the threshold also matches the interpolating
    jnp.quantile within a coarse bin width."""
    rng = np.random.default_rng(1)
    for scale in (1e-3, 1.0, 50.0):
        a = (rng.normal(0, scale, 30_000)).astype(np.float32)
        buf, row_seg, row_valid, seg_count, _ = _segbuf([a])
        out = segmented_quantile_moments(buf, row_seg, row_valid, seg_count,
                                         density, n_seg=1)
        want = float(jnp.quantile(jnp.abs(jnp.asarray(a)), 1.0 - density))
        bin_w = float(out["max"][0]) / NBINS
        assert abs(float(out["threshold"][0]) - want) <= bin_w + 1e-7


def test_moments_match_numpy():
    arrays = list(_dists().values())
    buf, row_seg, row_valid, seg_count, _ = _segbuf(arrays)
    out = segmented_quantile_moments(buf, row_seg, row_valid, seg_count,
                                     0.1, n_seg=len(arrays))
    for i, a in enumerate(arrays):
        assert float(out["std"][i]) == pytest.approx(float(a.std()),
                                                     rel=2e-3, abs=1e-6)
        assert float(out["mean_abs"][i]) == pytest.approx(
            float(np.abs(a).mean()), rel=2e-3, abs=1e-6)
        assert float(out["max"][i]) == pytest.approx(
            float(np.abs(a).max()), rel=1e-6)


def test_all_zero_segment_threshold_is_zero():
    buf, row_seg, row_valid, seg_count, _ = _segbuf(
        [np.zeros(1000, np.float32), np.ones(1000, np.float32)])
    out = segmented_quantile_moments(buf, row_seg, row_valid, seg_count,
                                     0.1, n_seg=2)
    assert float(out["threshold"][0]) == 0.0
    assert float(out["std"][0]) == 0.0


def test_pallas_sweep_matches_jnp_sweep():
    arrays = [v[:4100] for v in _dists().values()]
    buf, row_seg, row_valid, _, _ = _segbuf(arrays, cols=256)
    n_seg = len(arrays)
    lo = jnp.zeros((n_seg,), jnp.float32)
    width = jnp.asarray([float(np.abs(a).max()) for a in arrays], jnp.float32)
    jh = _segment_hist_moments_jnp(buf, row_seg, row_valid, lo, width,
                                   n_seg=n_seg, nbins=256)
    assert buf.shape[0] % 8 != 0     # exercises the kernel's internal pad
    ph = segment_hist_moments_pallas(buf, row_seg, row_valid, lo, width,
                                     n_seg=n_seg, nbins=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(jh[0]), np.asarray(ph[0]))
    for a, b in zip(jh[1:], ph[1:]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("backend", ["numpy", "jnp", "pallas"])
def test_backends_agree_on_threshold(backend):
    """All three sweep implementations (incl. the TPU path with a row count
    that is not a multiple of its block) produce the same threshold."""
    rng = np.random.default_rng(3)
    arrays = [rng.normal(0, 1, 4321).astype(np.float32),
              rng.normal(0, 5, 777).astype(np.float32)]
    buf, row_seg, row_valid, seg_count, _ = _segbuf(arrays, cols=512)
    assert buf.shape[0] % 8 != 0
    out = segmented_quantile_moments(buf, row_seg, row_valid, seg_count,
                                     0.1, n_seg=2, nbins=256,
                                     backend=backend, interpret=True)
    ref = segmented_quantile_moments(buf, row_seg, row_valid, seg_count,
                                     0.1, n_seg=2, nbins=256,
                                     backend="numpy")
    np.testing.assert_allclose(np.asarray(out["threshold"]),
                               np.asarray(ref["threshold"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out["std"]),
                               np.asarray(ref["std"]), rtol=1e-4)


@pytest.mark.parametrize("per_tensor", [True, False])
def test_compress_packed_matches_seed_path(per_tensor):
    """Streaming pipeline vs the seed sort-based per-leaf path: identical
    scales, same packed layout, and kept sets equal up to quantile ties."""
    rng = np.random.default_rng(7)
    tau = {"w1": jnp.asarray(rng.normal(0, 0.02, (300, 77)), jnp.float32),
           "b": jnp.asarray(rng.normal(0, 0.5, (13,)), jnp.float32),
           "w2": jnp.asarray(rng.standard_t(2, (64, 129)) * 0.1,
                             jnp.float32)}
    for density in DENSITIES:
        cfg = CompressionConfig(density=density, per_tensor=per_tensor)
        legacy = pack_tree(compress(tau, cfg))
        stream = compress_packed(tau, cfg)
        for k in tau:
            assert stream[k].shape == legacy[k].shape
            assert stream[k].pos.shape == legacy[k].pos.shape
            np.testing.assert_allclose(float(stream[k].scale),
                                       float(legacy[k].scale), rtol=1e-5)
            sl = unpack_tree({k: legacy[k]})[k].signs
            ss = unpack_tree({k: stream[k]})[k].signs
            nl = int(np.abs(np.asarray(sl)).sum())
            ns = int(np.abs(np.asarray(ss)).sum())
            # thresholds differ by < one refined bin -> at most a couple of
            # tie-adjacent elements flip in/out of the kept set
            assert abs(nl - ns) <= max(2, int(0.001 * sl.size)), (k, nl, ns)
            diff = (np.asarray(sl).reshape(-1)
                    != np.asarray(ss).reshape(-1)).sum()
            assert diff <= max(2, int(0.001 * sl.size)), (k, diff)


def test_compress_packed_roundtrip_decompress():
    rng = np.random.default_rng(8)
    tau = {"w": jnp.asarray(rng.normal(0, 0.02, (48, 64)), jnp.float32)}
    packed = compress_packed(tau, CompressionConfig(density=0.2))
    dense = decompress(unpack_tree(packed))["w"]
    vals = np.unique(np.asarray(dense))
    assert len(vals) <= 3                      # {-s, 0, +s}
    achieved = float((np.asarray(dense) != 0).mean())
    assert achieved == pytest.approx(0.2, abs=0.02)
