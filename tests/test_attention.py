"""flash_attention vs naive-softmax oracle across variants, and decode
attention partial-statistics correctness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttnCfg
from repro.models.attention import (cache_write, decode_attention_partial,
                                    finalize_partial, flash_attention)


def naive_attention(q, k, v, *, causal, window=None, cap=None, kv_len=None):
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, T, Hkv, G, D)
    s = jnp.einsum("bthgd,bshd->bthgs", qf, k.astype(jnp.float32))
    s = s / np.sqrt(D)
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None and causal:
        mask &= (qpos - kpos) < window
    if kv_len is not None:
        mask &= kpos < kv_len
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bthgs,bshd->bthgd", p, v.astype(jnp.float32))
    return o.reshape(B, T, Hq, D)


def rand_qkv(key, B, T, S, Hq, Hkv, D, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, T, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    return q, k, v


CASES = [
    # (T, S, Hq, Hkv, D, causal, window, cap, chunks)
    (32, 32, 4, 4, 16, True, None, None, 8),
    (32, 32, 4, 2, 16, True, None, None, 8),     # GQA
    (64, 64, 4, 1, 8, True, 16, None, 16),       # SWA
    (32, 32, 2, 2, 16, True, None, 50.0, 8),     # softcap
    (48, 48, 4, 2, 16, True, None, None, 16),    # chunk not dividing T
    (16, 40, 4, 4, 8, False, None, None, 8),     # cross/bidirectional
    (33, 17, 2, 1, 8, False, None, None, 8),     # ragged shapes
]


@pytest.mark.parametrize("T,S,Hq,Hkv,D,causal,window,cap,chunk", CASES)
def test_flash_matches_naive(T, S, Hq, Hkv, D, causal, window, cap, chunk):
    B = 2
    q, k, v = rand_qkv(jax.random.PRNGKey(0), B, T, S, Hq, Hkv, D)
    cfg = AttnCfg(n_q=Hq, n_kv=Hkv, head_dim=D, window=window,
                  attn_softcap=cap)
    got = flash_attention(q, k, v, cfg, causal=causal, chunk_q=chunk,
                          chunk_k=chunk)
    want = naive_attention(q, k, v, causal=causal, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtype_sweep(dtype):
    q, k, v = rand_qkv(jax.random.PRNGKey(1), 1, 32, 32, 4, 2, 16, dtype)
    cfg = AttnCfg(n_q=4, n_kv=2, head_dim=16)
    got = flash_attention(q, k, v, cfg, chunk_q=8, chunk_k=8)
    want = naive_attention(q, k, v, causal=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)
    assert got.dtype == dtype


def test_kv_valid_len_masks_padding():
    q, k, v = rand_qkv(jax.random.PRNGKey(2), 1, 8, 32, 2, 2, 8)
    cfg = AttnCfg(n_q=2, n_kv=2, head_dim=8)
    got = flash_attention(q, k, v, cfg, causal=False, kv_valid_len=20,
                          chunk_q=8, chunk_k=8)
    want = naive_attention(q, k, v, causal=False, kv_len=20)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_decode_partial_matches_full_attention():
    """Stepping a cache then attending == row T-1 of full causal attention."""
    B, T, Hq, Hkv, D = 2, 12, 4, 2, 8
    q_all, k_all, v_all = rand_qkv(jax.random.PRNGKey(3), B, T, T, Hq, Hkv, D)
    cfg = AttnCfg(n_q=Hq, n_kv=Hkv, head_dim=D)

    kc = jnp.zeros((B, T, Hkv, D))
    vc = jnp.zeros((B, T, Hkv, D))
    pos = jnp.full((T,), -1, jnp.int32)
    for t in range(T):
        kc, vc, pos = cache_write(kc, vc, pos, k_all[:, t:t + 1],
                                  v_all[:, t:t + 1], jnp.asarray(t))
    o, m, l = decode_attention_partial(q_all[:, -1:], kc, vc, pos,
                                       jnp.asarray(T - 1), cfg)
    got = finalize_partial(o, m, l)
    want = naive_attention(q_all, k_all, v_all, causal=True)[:, -1]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_decode_partial_combine_across_shards():
    """Manually split the cache in two 'shards'; flash-combining the partials
    must equal attention over the whole cache (the SP-decode invariant)."""
    B, S, Hq, Hkv, D = 1, 16, 2, 1, 8
    q, k, v = rand_qkv(jax.random.PRNGKey(4), B, 1, S, Hq, Hkv, D)
    cfg = AttnCfg(n_q=Hq, n_kv=Hkv, head_dim=D)
    pos = jnp.arange(S, dtype=jnp.int32)
    cur = jnp.asarray(S - 1)

    o_full, m_full, l_full = decode_attention_partial(q, k, v, pos, cur, cfg)
    want = finalize_partial(o_full, m_full, l_full)

    halves = []
    for sl in (slice(0, 8), slice(8, 16)):
        halves.append(decode_attention_partial(q, k[:, sl], v[:, sl],
                                               pos[sl], cur, cfg))
    m = jnp.maximum(halves[0][1], halves[1][1])
    l = sum(h[2] * jnp.exp(h[1] - m) for h in halves)
    o = sum(h[0] * jnp.exp(h[1] - m)[..., None] for h in halves)
    got = o / jnp.maximum(l[..., None], 1e-30)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_ring_cache_swa_decode():
    """With a window-sized ring cache, decode must equal SWA full attention."""
    B, T, H, D, W = 1, 20, 2, 8, 8
    q_all, k_all, v_all = rand_qkv(jax.random.PRNGKey(5), B, T, T, H, H, D)
    cfg = AttnCfg(n_q=H, n_kv=H, head_dim=D, window=W)
    kc = jnp.zeros((B, W, H, D))
    vc = jnp.zeros((B, W, H, D))
    pos = jnp.full((W,), -1, jnp.int32)
    for t in range(T):
        kc, vc, pos = cache_write(kc, vc, pos, k_all[:, t:t + 1],
                                  v_all[:, t:t + 1], jnp.asarray(t))
    o, m, l = decode_attention_partial(q_all[:, -1:], kc, vc, pos,
                                       jnp.asarray(T - 1), cfg)
    got = finalize_partial(o, m, l)
    want = naive_attention(q_all, k_all, v_all, causal=True, window=W)[:, -1]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
