"""Unit tests for the ComPEFT core algorithm (Algorithm 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CompressionConfig, apply_compressed, compress,
                        compression_summary, decompress, rescale)
from repro.core.compeft import CompressedTensor, calibrate_alpha


def make_tau(key=0, shapes=((64, 32), (128,), (16, 16, 4))):
    rng = np.random.default_rng(key)
    return {f"w{i}": jnp.asarray(rng.normal(0, 0.01, s), jnp.float32)
            for i, s in enumerate(shapes)}


def test_density_respected():
    tau = make_tau()
    for k in (0.05, 0.1, 0.3, 0.5):
        c = compress(tau, CompressionConfig(density=k))
        for leaf in jax.tree_util.tree_leaves(
                c, is_leaf=lambda x: isinstance(x, CompressedTensor)):
            d = float(leaf.density)
            assert abs(d - k) < 0.06, (k, d)


def test_signs_match_largest_magnitudes():
    rng = np.random.default_rng(1)
    t = jnp.asarray(rng.normal(0, 1, (1000,)), jnp.float32)
    c = compress({"w": t}, CompressionConfig(density=0.1))["w"]
    kept = np.nonzero(np.array(c.signs))[0]
    mags = np.abs(np.array(t))
    cutoff = np.sort(mags)[-len(kept)]
    assert np.all(mags[kept] >= cutoff - 1e-7)
    # surviving signs equal the original signs
    assert np.all(np.sign(np.array(t))[kept] == np.array(c.signs)[kept])


def test_scale_is_alpha_sigma():
    tau = make_tau(2)
    alpha = 3.0
    c = compress(tau, CompressionConfig(density=0.2, alpha=alpha))
    for name, leaf in tau.items():
        got = float(c[name].scale)
        want = alpha * float(jnp.std(leaf))
        assert got == pytest.approx(want, rel=1e-5)


def test_decompress_values_are_ternary_times_scale():
    tau = make_tau(3)
    c = compress(tau, CompressionConfig(density=0.1, alpha=2.0))
    d = decompress(c)
    for name in tau:
        vals = np.unique(np.array(d[name], np.float32))
        s = float(c[name].scale)
        for v in vals:
            assert min(abs(v), abs(v - s), abs(v + s)) < 1e-6


def test_apply_compressed_reconstructs():
    tau = make_tau(4)
    theta0 = jax.tree_util.tree_map(
        lambda t: jnp.ones_like(t), tau)
    c = compress(tau, CompressionConfig(density=0.3))
    theta = apply_compressed(theta0, c)
    want = jax.tree_util.tree_map(
        lambda w, d: w + d, theta0, decompress(c))
    for a, b in zip(jax.tree_util.tree_leaves(theta),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.array(a), np.array(b), atol=1e-6)


def test_rescale():
    tau = make_tau(5)
    c1 = compress(tau, CompressionConfig(density=0.2, alpha=1.0))
    c4 = rescale(c1, 1.0, 4.0)
    for name in tau:
        assert float(c4[name].scale) == pytest.approx(4 * float(c1[name].scale))
        np.testing.assert_array_equal(np.array(c4[name].signs),
                                      np.array(c1[name].signs))


def test_global_threshold_mode():
    tau = make_tau(6)
    c = compress(tau, CompressionConfig(density=0.1, per_tensor=False))
    total = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tau))
    nnz = sum(int(jnp.sum(jnp.abs(l.signs).astype(jnp.int32)))
              for l in jax.tree_util.tree_leaves(
                  c, is_leaf=lambda x: isinstance(x, CompressedTensor)))
    assert abs(nnz / total - 0.1) < 0.03


def test_calibrate_alpha_picks_best():
    tau = make_tau(7)
    target = decompress(compress(tau, CompressionConfig(density=0.2, alpha=4.0)))

    def eval_fn(recon):
        err = 0.0
        for a, b in zip(jax.tree_util.tree_leaves(recon),
                        jax.tree_util.tree_leaves(target)):
            err += float(jnp.sum((a - b) ** 2))
        return -err

    best_alpha, _, _ = calibrate_alpha(tau, eval_fn, density=0.2)
    assert best_alpha == 4.0


def test_summary_compression_ratio_matches_paper_k005():
    # paper §2.2: k=0.05 => entropy 0.34*d + 16 bits => ~47x vs 16-bit dense
    tau = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (100_000,)),
                            jnp.float32)}
    c = compress(tau, CompressionConfig(density=0.05))
    s = compression_summary(tau, c)
    assert 40 < s["compression_x_entropy"] < 50
    assert s["compression_x_bitplane"] == pytest.approx(8.0, rel=0.01)


def test_compress_is_jittable():
    tau = make_tau(8)
    cfg = CompressionConfig(density=0.2)
    jitted = jax.jit(lambda t: compress(t, cfg))
    c = jitted(tau)
    d = float(c["w0"].density)
    assert abs(d - 0.2) < 0.05


def test_invalid_configs_raise():
    with pytest.raises(ValueError):
        CompressionConfig(density=0.0)
    with pytest.raises(ValueError):
        CompressionConfig(alpha=-1.0)
    with pytest.raises(ValueError):
        CompressionConfig(scale_mode="bogus")
