"""Cross-mesh parity suite for the sharded serving engine.

The mesh contract is *bitwise*: greedy AND seeded-sampled token streams
must be identical between ``mesh=None`` and every swept mesh shape —
covering mixed waves with mid-chunk admissions, paged KV, and an expert
set larger than a shard's budget (per-shard eviction churn included).
Runs on 8 forced host devices in a subprocess (the main pytest process
keeps its single-device view).
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(script: str, timeout: int = 600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    p = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert p.returncode == 0, (
        f"subprocess failed\nstdout:\n{p.stdout[-1500:]}\n"
        f"stderr:\n{p.stderr[-3000:]}")
    return p.stdout


HEADER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
import repro.api as capi
from repro.configs import get_smoke_config
from repro.models import build, Runtime
from repro.launch.mesh import make_serve_mesh
from repro.serve.engine import Request

assert len(jax.devices()) == 8

cfg = get_smoke_config("qwen2_5_3b", n_units=1)
api = build(cfg)
rt = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")
base = api.init(jax.random.PRNGKey(0))

experts = []
for i in range(6):
    k = jax.random.PRNGKey(100 + i)
    leaves, treedef = jax.tree_util.tree_flatten(base)
    ks = jax.random.split(k, len(leaves))
    ft = jax.tree_util.tree_unflatten(
        treedef, [l + 0.02 * jax.random.normal(kk, l.shape, l.dtype)
                  for l, kk in zip(leaves, ks)])
    experts.append(capi.compress(base, ft, name=f"e{i}", density=0.2,
                                 alpha=1.0))

# budget smaller than the 6-expert resident set: serving all experts
# forces evictions (per-shard accounting on the mesh path)
BUDGET = 96 * 1024


def mk_requests(n=10):
    # mixed experts, varied prompt lengths and budgets; n > max_batch so
    # the wave loop exercises mid-chunk continuous admission
    rng = np.random.default_rng(0)
    out = []
    for u in range(n):
        plen = int(rng.integers(3, 12)) if u % 3 else 11
        out.append(Request(
            uid=u, expert=f"e{u % 6}",
            prompt=jnp.asarray(np.arange(1, plen + 1) + u, jnp.int32),
            max_new_tokens=int(3 + u % 5)))
    return out


def run(mesh, samp, kv):
    reg = capi.registry(experts=experts, device_cache_bytes=BUDGET,
                        mesh=mesh)
    eng = capi.serve(api, rt, base, reg, max_batch=4, cache_len=64,
                     decode_chunk=4, kv_layout=kv, mesh=mesh, **samp)
    done = eng.run(mk_requests())
    toks = {r.uid: (r.status, list(r.out_tokens)) for r in done}
    return toks, eng.swap_summary()


def check(kv, samp):
    ref, ref_summ = run(None, samp, kv)
    assert all(s == "done" for s, _ in ref.values())
    for shape in ((1, 1), (2, 1), (2, 4)):
        got, summ = run(make_serve_mesh(shape), samp, kv)
        assert got == ref, (
            f"kv={kv} samp={samp} mesh={shape}: token streams diverged\n"
            f"ref={ref}\ngot={got}")
        assert summ["n_expert_shards"] == shape[0]
        assert summ["admitted"] > 0, "no mid-wave admissions exercised"
        assert summ["evictions"] + summ["stack_evictions"] > 0, \
            "budget never forced an eviction"
        shards = summ["shards"]
        assert len(shards) == shape[0]
        counts = [s["resident_experts"] for s in shards]
        if max(counts):
            assert max(counts) <= 2 * max(min(counts), 1), \
                f"shard imbalance > 2x: {counts}"
        for s in shards:
            assert s["capacity_bytes"] == BUDGET
    print(f"OK kv={kv} samp={samp}")
"""


@pytest.mark.parametrize("kv", ["dense", "paged"])
def test_cross_mesh_parity(kv):
    out = run_sub(HEADER + f"""
check({kv!r}, {{}})
check({kv!r}, {{"temperature": 0.8, "top_k": 5, "seed": 7}})
print("ALL_OK")
""")
    assert "ALL_OK" in out


def test_mesh_device_cache_shards():
    """DeviceCache on a mesh: stacks pad E to the shard count with inert
    zero slots, per-shard budget accounting, and shard gauges."""
    out = run_sub(HEADER + """
from repro.serve.expert_cache import BASE

mesh = make_serve_mesh((2, 4))
reg = capi.registry(experts=experts, device_cache_bytes=BUDGET, mesh=mesh)
cache = reg.device()
assert cache.n_shards == 2
stacks = cache.stacked(("e0", "e1", "e2"))          # E=3 pads to 4
for pos, neg, scales, shape in stacks.values():
    assert pos.shape[0] == 4 and scales.shape[0] == 4
    assert float(jnp.abs(scales[3])) == 0.0          # pad slot is inert
    assert "expert" in str(pos.sharding.spec)
sh = cache.shard_summary()
assert [s["resident_experts"] for s in sh] == [2, 1]
assert cache.shard_resident_bytes() <= cache.resident_bytes()

# mesh=None registry keeps today's path: no padding, shard count 1
reg1 = capi.registry(experts=experts, device_cache_bytes=BUDGET)
c1 = reg1.device()
assert c1.n_shards == 1
s1 = c1.stacked(("e0", "e1", "e2"))
for pos, neg, scales, shape in s1.values():
    assert pos.shape[0] == 3
print("CACHE_OK")
""")
    assert "CACHE_OK" in out
