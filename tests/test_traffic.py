"""Seeded open-loop traffic generator: deterministic replay, Zipf expert
popularity matching the configured skew, and burst windows landing at the
scheduled offsets with the configured rate multiplier."""

import numpy as np

from benchmarks import traffic
from repro.serve.engine import Request


def _hist(reqs, n):
    counts = np.zeros(n, np.int64)
    for r in reqs:
        counts[int(r.expert.removeprefix("expert"))] += 1
    return counts


def test_generate_is_deterministic():
    cfg = traffic.TrafficConfig(seed=3, n_requests=40)
    a, b = traffic.generate(cfg), traffic.generate(cfg)
    assert len(a) == len(b) == 40
    for x, y in zip(a, b):
        assert isinstance(x, Request)
        assert (x.uid, x.expert, x.arrival_s, x.max_new_tokens,
                x.priority, x.deadline_s) == \
               (y.uid, y.expert, y.arrival_s, y.max_new_tokens,
                y.priority, y.deadline_s)
        np.testing.assert_array_equal(np.asarray(x.prompt),
                                      np.asarray(y.prompt))
    # a different seed moves the timeline
    c = traffic.generate(traffic.TrafficConfig(seed=4, n_requests=40))
    assert any(x.arrival_s != y.arrival_s for x, y in zip(a, c))


def test_arrivals_monotone_and_metadata_consistent():
    cfg = traffic.TrafficConfig(seed=0, n_requests=64)
    reqs = traffic.generate(cfg)
    ts = [r.arrival_s for r in reqs]
    assert all(t1 < t2 for t1, t2 in zip(ts, ts[1:]))
    budget = dict(cfg.deadline_by_priority)
    for r in reqs:
        assert len(r.prompt) in (cfg.prompt_len_short, cfg.prompt_len_long)
        assert r.max_new_tokens in (cfg.max_new_short, cfg.max_new_long)
        assert r.deadline_s == r.arrival_s + budget[r.priority]


def test_zipf_histogram_matches_skew():
    """Empirical expert counts track k^-alpha: expert0 dominates, the
    ranking is (statistically) monotone, and the head mass matches the
    analytic Zipf weights."""
    n = 6
    cfg = traffic.TrafficConfig(seed=1, n_requests=4000, n_experts=n,
                                zipf_alpha=1.3)
    counts = _hist(traffic.generate(cfg), n)
    w = traffic.zipf_weights(n, 1.3)
    assert counts[0] == counts.max()
    assert counts[0] > 2 * counts[-1]
    emp = counts / counts.sum()
    np.testing.assert_allclose(emp, w, atol=0.03)
    # alpha=0 degenerates to uniform
    u = traffic.zipf_weights(4, 0.0)
    np.testing.assert_allclose(u, 0.25)


def test_burst_windows_at_scheduled_offsets():
    cfg = traffic.TrafficConfig(burst_every_s=4.0, burst_duration_s=1.0)
    assert traffic.in_burst(0.5, cfg)
    assert traffic.in_burst(4.2, cfg)
    assert not traffic.in_burst(1.5, cfg)
    assert not traffic.in_burst(3.99, cfg)
    off = traffic.TrafficConfig(burst_duration_s=0.0)
    assert not traffic.in_burst(0.0, off)


def test_burst_density_exceeds_off_burst_density():
    """Arrivals per second inside burst windows approach burst_rate_x
    times the off-window density."""
    cfg = traffic.TrafficConfig(seed=5, n_requests=3000, base_rate=10.0,
                                burst_every_s=2.0, burst_duration_s=0.5,
                                burst_rate_x=4.0)
    reqs = traffic.generate(cfg)
    span = reqs[-1].arrival_s
    n_in = sum(1 for r in reqs if traffic.in_burst(r.arrival_s, cfg))
    # window fraction of the timeline
    frac = cfg.burst_duration_s / cfg.burst_every_s
    t_in = span * frac
    t_out = span * (1 - frac)
    dens_in = n_in / t_in
    dens_out = (len(reqs) - n_in) / t_out
    assert dens_in > 2.0 * dens_out, (dens_in, dens_out)


def test_summarize_counts_and_percentiles():
    cfg = traffic.TrafficConfig(seed=0, n_requests=8)
    reqs = traffic.generate(cfg)
    for i, r in enumerate(reqs):
        r.t_first_s = r.arrival_s + 0.1
        r.t_done_s = r.arrival_s + 0.5
        r.out_tokens.extend([1] * 3)
    reqs[0].t_first_s = None            # never served -> excluded
    s = traffic.summarize(reqs)
    assert s["n_served"] == 7 and s["n_failed"] == 0
    np.testing.assert_allclose(s["ttft_p50_s"], 0.1)
    assert s["tokens"] == 21
    assert s["tokens_per_s"] > 0
    assert set(s["per_priority"]) <= {"0", "1"}
