"""Sharding-rule coverage across every registry architecture.

Every parameter path of every arch must resolve to a PartitionSpec whose
sharded dims divide evenly by the mesh extents they map to — on the
production mesh, the multi-pod mesh, and under every combination of the
``ShardingOverrides`` escape hatches (head_tp / expert_parallel).  The
serving rules (``serve_param_pspec``) get the same treatment plus their
semantic contract: only vocab-parallel embed / lm_head shard, everything
else replicates.
"""

import dataclasses
import math

import jax
import pytest

from repro.configs.registry import ARCHS, get_config
from repro.distributed import sharding as sh
from repro.launch.mesh import make_serve_mesh
from repro.models import build
from repro.peft.lora import _path_str


class FakeMesh:
    """Shape-only stand-in: the spec rules read ``mesh.shape`` (a dict of
    axis -> extent) and ``axis_names``; no devices needed."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


PROD = FakeMesh({"data": 16, "model": 16})
PROD_POD = FakeMesh({"pod": 2, "data": 16, "model": 16})
SERVE = FakeMesh({"expert": 2, "model": 4})


def _extent(mesh, entry) -> int:
    """Product of mesh extents one spec entry maps to."""
    if entry is None:
        return 1
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    return math.prod(mesh.shape[a] for a in axes)


def _axes_of(entry):
    if entry is None:
        return ()
    return (entry,) if isinstance(entry, str) else tuple(entry)


def _param_shapes(cfg):
    api = build(cfg)
    shapes = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    out = {}
    jax.tree_util.tree_map_with_path(
        lambda p, l: out.setdefault(_path_str(p), tuple(l.shape)), shapes)
    return out


def _check_spec(path, shape, spec, mesh):
    assert len(spec) <= len(shape), \
        f"{path}: spec {spec} longer than shape {shape}"
    used = []
    for i, entry in enumerate(tuple(spec)):
        for a in _axes_of(entry):
            assert a in mesh.shape, f"{path}: unknown mesh axis {a!r}"
            assert a not in used, f"{path}: axis {a!r} used twice in {spec}"
            used.append(a)
        ext = _extent(mesh, entry)
        assert shape[i] % ext == 0, (
            f"{path}: dim {i} of {shape} not divisible by mesh extent "
            f"{ext} ({spec})")


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_divide_evenly(arch):
    cfg = get_config(arch)
    shapes = _param_shapes(cfg)
    assert shapes, f"{arch}: empty param tree"
    for mesh in (PROD, PROD_POD):
        for path, shape in shapes.items():
            spec = sh.param_pspec(path, shape, cfg, mesh)
            _check_spec(path, shape, spec, mesh)


@pytest.mark.parametrize("arch", ARCHS)
def test_override_escape_hatches(arch):
    """Every head_tp x expert_parallel combination must still produce
    evenly-dividing specs — the escape hatches may change layouts, never
    break them."""
    cfg = get_config(arch)
    shapes = _param_shapes(cfg)
    for head_tp in (True, False):
        for ep in (True, False):
            c = dataclasses.replace(
                cfg, sharding=dataclasses.replace(
                    cfg.sharding, head_tp=head_tp, expert_parallel=ep))
            for path, shape in shapes.items():
                spec = sh.param_pspec(path, shape, c, PROD)
                _check_spec(path, shape, spec, PROD)
                if not head_tp and path.rsplit("/", 1)[-1] in (
                        "wq", "wk", "wv", "bq", "bk", "bv"):
                    assert "model" not in _flat_axes(spec), (
                        f"{path}: head_tp=False must not shard heads over "
                        f"'model' ({spec})")


def _flat_axes(spec):
    out = []
    for entry in tuple(spec):
        out.extend(_axes_of(entry))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_param_specs(arch):
    """Serving rules: vocab-parallel embed / lm_head only; every other
    leaf fully replicated (the bitwise-parity contract forbids sharding
    contraction dims)."""
    cfg = get_config(arch)
    shapes = _param_shapes(cfg)
    for path, shape in shapes.items():
        spec = sh.serve_param_pspec(path, shape, SERVE)
        _check_spec(path, shape, spec, SERVE)
        axes = _flat_axes(spec)
        assert "expert" not in axes, \
            f"{path}: base params must never shard over 'expert'"
        leaf = path.rsplit("/", 1)[-1]
        if leaf not in ("embed", "lm_head", "unembed"):
            assert not axes, f"{path}: serve rules must replicate ({spec})"
        elif axes:
            assert axes == ["model"]
            if leaf == "embed":
                assert tuple(spec)[0] == "model" and shape[0] % 4 == 0
            else:
                assert tuple(spec)[-1] == "model" and shape[-1] % 4 == 0


def test_serve_stack_and_kv_shardings():
    """NamedSharding-producing serve helpers on a real (1, 1) mesh."""
    mesh = make_serve_mesh((1, 1))
    plane, scale = sh.serve_stack_shardings(mesh)
    assert tuple(plane.spec) == ("expert",)
    assert tuple(scale.spec) == ("expert",)

    assert sh.serve_mesh_axes(mesh) == (1, 1)

    dense = sh.serve_kv_sharding(mesh, (2, 4, 64, 2, 8), layout="dense")
    assert tuple(dense.spec) == (None, "model", None, None, None)
    # non-5D / non-dividing shapes fall back to full replication
    odd = sh.serve_kv_sharding(mesh, (2, 3, 64), layout="dense")
    assert all(e is None for e in tuple(odd.spec))

    import numpy as np
    cache = {"k": np.zeros((2, 4, 8, 2, 4)), "lens": np.zeros((4,)),
             "cur": np.zeros(())}
    placed = sh.serve_cache_shardings(cache, mesh, layout="paged")
    assert tuple(placed["k"].spec) == (None, "model", None, None, None)
    assert all(e is None for e in tuple(placed["lens"].spec))
