"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, shape + finiteness assertions, and prefill/decode
consistency (the serving-path invariant)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import Runtime, build

RT = Runtime(attn_chunk_q=16, attn_chunk_k=16, mamba_chunk=8, rwkv_chunk=8,
             remat_policy="none")
B, T = 2, 24


def make_batch(cfg, key=0):
    rng = np.random.default_rng(key)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    batch = {"tokens": toks,
             "targets": jnp.roll(toks, -1, axis=1).at[:, -1].set(-1)}
    if cfg.family == "vlm":
        batch["mm_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.frontend.n_tokens,
                              cfg.frontend.embed_dim)), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.frontend.n_tokens,
                              cfg.frontend.embed_dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)

    loss, (logits, aux) = api.loss_and_logits(params, batch, RT)
    assert logits.shape == (B, T, cfg.vocab)
    assert bool(jnp.isfinite(loss)), float(loss)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # one SGD step must also be finite (gradient path exercised)
    g = jax.grad(lambda p: api.loss_and_logits(p, batch, RT)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(x.astype(jnp.float32))))
                for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """logits from prefill(T tokens) + decode steps must match the one-shot
    forward pass (teacher forcing) — the core serving invariant."""
    cfg = get_smoke_config(arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)

    logits_full, _ = api.forward(params, batch, RT)
    # align: full logits include the mm prefix for VLMs
    n_mm = logits_full.shape[1] - T

    lp, cache = api.prefill(params, batch, RT, cache_len=T + 8)
    np.testing.assert_allclose(
        np.asarray(lp[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32), atol=2e-3, rtol=2e-3)

    # two decode steps with teacher-forced tokens extend consistently
    nxt = batch["tokens"][:, -1:]  # arbitrary valid token
    ld, cache = api.decode_step(params, nxt, cache, RT)
    assert ld.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(ld.astype(jnp.float32))))
    ld2, cache = api.decode_step(params, nxt, cache, RT)
    assert bool(jnp.all(jnp.isfinite(ld2.astype(jnp.float32))))
    assert int(cache["cur"]) == T + n_mm + 2  # VLM prefill includes mm prefix


def test_decode_matches_forward_token_by_token():
    """Strong consistency: stepping every position reproduces full-forward
    logits (dense arch as representative; SSM archs covered in
    test_ssm_blocks)."""
    cfg = get_smoke_config("qwen2_5_3b")
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits_full, _ = api.forward(params, batch, RT)

    # prefill with the first token only, then decode the rest
    b1 = dict(batch)
    b1["tokens"] = batch["tokens"][:, :1]
    lp, cache = api.prefill(params, b1, RT, cache_len=T + 4)
    outs = [lp]
    for t in range(1, T):
        ld, cache = api.decode_step(params, batch["tokens"][:, t:t + 1],
                                    cache, RT)
        outs.append(ld)
    stepped = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stepped, np.float32),
                               np.asarray(logits_full, np.float32),
                               atol=2e-3, rtol=2e-3)


def test_param_counts_match_public_sizes():
    from repro.configs import get_config
    expected = {
        "llama4_maverick_400b": (390e9, 410e9),
        "mixtral_8x7b": (45e9, 48e9),
        "qwen2_5_3b": (2.8e9, 3.3e9),
        "qwen3_32b": (31e9, 34e9),
        "qwen1_5_110b": (105e9, 115e9),
        "gemma2_9b": (8.8e9, 9.8e9),
        "internvl2_1b": (0.4e9, 0.6e9),
        "jamba_1_5_large_398b": (390e9, 405e9),
        "rwkv6_3b": (2.5e9, 3.3e9),
        "seamless_m4t_medium": (0.8e9, 1.4e9),
        "llama_7b": (6.3e9, 7.2e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
