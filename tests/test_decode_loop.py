"""Device-resident decode: scan-compiled chunked wave loop vs the eager
per-token loop (token parity across schedulers and chunk sizes, mid-wave
slot refills, seeded-sampling reproducibility) and the cold-tier
byte-budget LRU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api as rapi
from repro.configs import get_smoke_config
from repro.models import Runtime, build
from repro.serve import Request, SamplingConfig
from repro.serve.decode_loop import row_keys, select_tokens

RT = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")


@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    return cfg, api, base


def _registry(api, base, n=2, scale=0.03, density=0.2):
    reg = rapi.registry()
    for i in range(n):
        leaves, tdef = jax.tree_util.tree_flatten(base)
        keys = jax.random.split(jax.random.PRNGKey(100 + i), len(leaves))
        ft = jax.tree_util.tree_unflatten(tdef, [
            (l.astype(jnp.float32)
             + scale * jax.random.normal(k, l.shape)).astype(l.dtype)
            for l, k in zip(leaves, keys)])
        reg.add(rapi.compress(base, ft, name=f"expert{i}", density=density))
    return reg


def _mk_reqs(cfg, n=6, seed=0, max_new=None):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, expert=f"expert{i % 2}",
                    prompt=jnp.asarray(
                        rng.integers(1, cfg.vocab, 6 + 2 * (i % 3)),
                        jnp.int32),
                    max_new_tokens=max_new or (2 + i % 3))
            for i in range(n)]


def _serve(smoke_lm, reqs, **kw):
    cfg, api, base = smoke_lm
    eng = rapi.serve(api, RT, base, _registry(api, base),
                     max_batch=3, cache_len=64, **kw)
    eng.run(reqs)
    return eng, {r.uid: list(r.out_tokens) for r in reqs}


def test_chunked_matches_eager_mixed_with_refills(smoke_lm):
    """Greedy chunked decode (several K) is bit-identical to the eager
    loop on mixed waves — with more requests than slots, so mid-wave
    admissions (left-padded spliced prefills) are exercised too."""
    cfg = smoke_lm[0]
    eng, eager = _serve(smoke_lm, _mk_reqs(cfg), decode_chunk=0)
    assert sum(w["admitted"] for w in eng.wave_log) >= 1
    for K in (1, 4, 16):
        eng_k, toks = _serve(smoke_lm, _mk_reqs(cfg), decode_chunk=K)
        assert toks == eager, f"K={K} diverged from eager"
        assert sum(w["chunks"] for w in eng_k.wave_log) >= 1


def test_chunked_matches_eager_grouped(smoke_lm):
    """The merge-path (grouped) scheduler goes through the same compiled
    chunk loop with a zero overlay — token parity with eager."""
    cfg = smoke_lm[0]
    _, eager = _serve(smoke_lm, _mk_reqs(cfg), decode_chunk=0,
                      scheduling="grouped")
    _, toks = _serve(smoke_lm, _mk_reqs(cfg), decode_chunk=4,
                     scheduling="grouped")
    assert toks == eager


def test_seeded_sampling_reproducible_across_chunk_sizes(smoke_lm):
    """Same PRNG seed => same sampled tokens whatever the chunk size:
    each request's stream is keyed by (seed, uid, token index), not by
    launch geometry or admission timing."""
    cfg = smoke_lm[0]
    runs = {}
    for K in (2, 8):
        _, runs[K] = _serve(smoke_lm, _mk_reqs(cfg), decode_chunk=K,
                            temperature=0.8, top_k=5, seed=7)
    assert runs[2] == runs[8]
    # and it is deterministic across repeated runs of the same K
    _, again = _serve(smoke_lm, _mk_reqs(cfg), decode_chunk=2,
                      temperature=0.8, top_k=5, seed=7)
    assert again == runs[2]
    # a different seed gives a different stream somewhere
    _, other = _serve(smoke_lm, _mk_reqs(cfg), decode_chunk=2,
                      temperature=0.8, top_k=5, seed=8)
    assert other != runs[2]
    for toks in runs[2].values():
        assert all(0 <= t < cfg.vocab for t in toks)


def test_eager_sampling_matches_chunked(smoke_lm):
    """Seeded sampling in the eager per-token baseline produces the same
    streams as the compiled chunk loop: both draw token i of request uid
    from fold_in(fold_in(seed, uid), i), so the loop form is invisible."""
    cfg = smoke_lm[0]
    _, eager = _serve(smoke_lm, _mk_reqs(cfg), decode_chunk=0,
                      temperature=0.8, top_k=5, seed=7)
    _, chunked = _serve(smoke_lm, _mk_reqs(cfg), decode_chunk=4,
                        temperature=0.8, top_k=5, seed=7)
    assert eager == chunked


def test_select_tokens_greedy_is_argmax():
    logits = jnp.asarray(np.random.default_rng(0).normal(0, 1, (4, 37)),
                         jnp.float32)
    keys = row_keys(0, [0, 1, 2, 3])
    gen = jnp.zeros((4,), jnp.int32)
    got = select_tokens(logits, keys, gen, SamplingConfig())
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_select_tokens_top_k_stays_in_top_k():
    logits = jnp.asarray(np.random.default_rng(1).normal(0, 1, (8, 64)),
                         jnp.float32)
    keys = row_keys(3, list(range(8)))
    scfg = SamplingConfig(temperature=1.5, top_k=4, seed=3)
    topk = set()
    for b in range(8):
        topk |= {(b, int(i)) for i in np.argsort(-np.asarray(logits[b]))[:4]}
    for gen0 in range(5):
        gen = jnp.full((8,), gen0, jnp.int32)
        got = np.asarray(select_tokens(logits, keys, gen, scfg))
        assert all((b, int(t)) in topk for b, t in enumerate(got))


def test_cold_budget_lru_evicts_and_refetches(smoke_lm):
    """RemoteExpertStore under a cold byte budget: LRU wire blobs are
    dropped (counted in SwapStats.cold_evictions) and transparently
    re-fetched over the transport on next use."""
    from repro.transport import InMemoryTransport
    cfg, api, base = smoke_lm
    src = _registry(api, base, n=3)
    tr = InMemoryTransport()
    sizes = {}
    for i in range(3):
        pub = tr.publish(src.get(f"expert{i}"))
        sizes[f"expert{i}"] = pub["nbytes"]
    budget = sizes["expert0"] + sizes["expert1"] + sizes["expert2"] // 2
    reg = rapi.registry(transport=tr, cold_budget_bytes=budget)
    for i in range(3):
        reg.get(f"expert{i}")            # third fetch must evict expert0
    store = reg.store
    assert store.cold_evictions >= 1
    assert store.cold_resident_bytes() <= budget
    fetches_before = store.remote_totals()["fetches"]
    back = reg.get("expert0")            # evicted -> refetched on demand
    assert store.remote_totals()["fetches"] == fetches_before + 1
    for path, pt in src.get("expert0").packed.items():
        np.testing.assert_array_equal(np.asarray(pt.pos),
                                      np.asarray(back.packed[path].pos))
    # the eviction counter surfaces through the device tier's SwapStats
    cache = reg.device(1 << 24)
    cache.fetch("expert1")
    assert cache.stats.cold_evictions == store.cold_evictions
    assert "cold_evictions" in cache.stats.as_dict()


def test_unbounded_store_never_evicts(smoke_lm):
    from repro.transport import InMemoryTransport
    cfg, api, base = smoke_lm
    src = _registry(api, base, n=3)
    tr = InMemoryTransport()
    for i in range(3):
        tr.publish(src.get(f"expert{i}"))
    reg = rapi.registry(transport=tr)    # no budget: legacy behaviour
    for i in range(3):
        reg.get(f"expert{i}")
    assert reg.store.cold_evictions == 0
