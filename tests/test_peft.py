"""LoRA / (IA)3 adapters: zero-init identity, gradient flow, ComPEFT
round-trip through the expert-artifact path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api as rapi
from repro.configs import get_smoke_config
from repro.models import Runtime, build
from repro.peft import (IA3Config, LoraConfig, apply_ia3, apply_lora,
                        init_ia3, init_lora, task_vector)

RT = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")
B, T = 2, 16


def setup(arch="qwen2_5_3b"):
    cfg = get_smoke_config(arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1).at[:, -1].set(-1)}
    return cfg, api, params, batch


def test_lora_zero_init_is_identity():
    cfg, api, params, batch = setup()
    lcfg = LoraConfig(rank=4)
    lora = init_lora(jax.random.PRNGKey(1), params, lcfg)
    assert len(lora) > 0
    merged = apply_lora(params, lora, lcfg)
    l0, _ = api.loss_and_logits(params, batch, RT)
    l1, _ = api.loss_and_logits(merged, batch, RT)
    assert float(l0) == pytest.approx(float(l1), rel=1e-6)


def test_lora_grads_flow_and_training_reduces_loss():
    cfg, api, params, batch = setup()
    lcfg = LoraConfig(rank=4, alpha=8.0)
    lora = init_lora(jax.random.PRNGKey(1), params, lcfg)

    def loss_fn(lp):
        merged = apply_lora(params, lp, lcfg)
        return api.loss_and_logits(merged, batch, RT)[0]

    l0 = float(loss_fn(lora))
    g = jax.grad(loss_fn)(lora)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree_util.tree_leaves(g))
    assert gn > 0
    lora2 = jax.tree_util.tree_map(lambda p, gg: p - 0.3 * gg, lora, g)
    assert float(loss_fn(lora2)) < l0


def test_ia3_zero_init_is_identity_and_trains():
    cfg, api, params, batch = setup()
    ia3 = init_ia3(params)
    assert len(ia3) > 0
    merged = apply_ia3(params, ia3)
    l0, _ = api.loss_and_logits(params, batch, RT)
    l1, _ = api.loss_and_logits(merged, batch, RT)
    assert float(l0) == pytest.approx(float(l1), rel=1e-6)

    def loss_fn(ip):
        return api.loss_and_logits(apply_ia3(params, ip), batch, RT)[0]

    g = jax.grad(loss_fn)(ia3)
    ia3_2 = jax.tree_util.tree_map(lambda p, gg: p - 0.5 * gg, ia3, g)
    assert float(loss_fn(ia3_2)) < float(loss_fn(ia3))


def test_compressed_lora_expert_roundtrip():
    """Train a few LoRA steps, compress the LoRA task vector with ComPEFT,
    verify the reconstructed expert behaves close to the fine-tuned one."""
    cfg, api, params, batch = setup()
    lcfg = LoraConfig(rank=4, alpha=8.0)
    lora0 = init_lora(jax.random.PRNGKey(1), params, lcfg)

    def loss_fn(lp):
        return api.loss_and_logits(apply_lora(params, lp, lcfg), batch, RT)[0]

    lora = lora0
    for _ in range(5):
        lora = jax.tree_util.tree_map(lambda p, g: p - 0.3 * g, lora,
                                      jax.grad(loss_fn)(lora))
    tau = task_vector(lora0, lora)
    art = rapi.compress(tau, name="exp0", kind="lora", density=0.3)
    assert art.nbytes() < sum(x.size * 2 for x in
                              jax.tree_util.tree_leaves(tau)) / 4
    tau_hat = art.to_dense_tau()
    lora_hat = jax.tree_util.tree_map(
        lambda a, d: (a.astype(jnp.float32) + d).astype(a.dtype), lora0,
        tau_hat)
    l_ft = float(loss_fn(lora))
    l_hat = float(loss_fn(lora_hat))
    l_base = float(loss_fn(lora0))
    # compressed expert recovers most of the fine-tuning win
    assert l_hat < l_base
    assert l_hat < l_ft + 0.5 * (l_base - l_ft)


def test_lora_targets_cover_ssm_and_moe():
    for arch in ("rwkv6_3b", "mixtral_8x7b", "jamba_1_5_large_398b"):
        cfg = get_smoke_config(arch)
        api = build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        lora = init_lora(jax.random.PRNGKey(1), params, LoraConfig(rank=2))
        assert len(lora) >= cfg.n_units * 0 + 3  # adapters exist
