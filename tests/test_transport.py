"""Wire format + transport backends + REMOTE-tier serving.

Covers the PR-4 contracts: round-trip bit-identity across all three
backends and all three wire representations, manifest version/checksum
rejection on corruption, prefetch overlap under the simulated-latency
backend, and the engine's admission-time prefetch over a remote registry.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api as rapi
from repro.expert import DENSE, GOLOMB, PACKED
from repro.transport import (ChecksumError, HTTPTransport, InMemoryTransport,
                             LocalTransport, SimulatedNetworkTransport,
                             TransportError, WireFormatError, decode_expert,
                             encode_expert, peek_manifest, serve_local_http)

WIRE_REPS = (DENSE, PACKED, GOLOMB)


def small_expert(name="wire", seed=0, density=0.05, shape=(256, 192)):
    rng = np.random.default_rng(seed)
    tau = {"l0/wq": jnp.asarray(rng.normal(0, 7e-4, shape), jnp.float32),
           "l0/wo": jnp.asarray(rng.normal(0, 7e-4, (shape[1], 70)),
                                jnp.float32)}
    return rapi.compress(tau, name=name, density=density,
                         meta={"task": "unit-test"})


def assert_planes_equal(a, b):
    """Bit-identity of two {path: PackedTernary} dicts."""
    assert set(a) == set(b)
    for p in a:
        np.testing.assert_array_equal(np.asarray(a[p].pos),
                                      np.asarray(b[p].pos))
        np.testing.assert_array_equal(np.asarray(a[p].neg),
                                      np.asarray(b[p].neg))
        assert float(a[p].scale) == float(b[p].scale)
        assert tuple(a[p].shape) == tuple(b[p].shape)


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rep", WIRE_REPS)
def test_wire_roundtrip_bit_identical(rep):
    ex = small_expert()
    blob = encode_expert(ex, rep=rep)
    back = decode_expert(blob)
    assert back.name == ex.name
    assert back.density == ex.density
    assert back.meta == ex.meta
    assert_planes_equal(ex.packed, back.packed)


def test_wire_rep_size_ordering():
    """The communication-cost curve: golomb < packed < dense on the wire."""
    ex = small_expert()
    sizes = {rep: len(encode_expert(ex, rep=rep)) for rep in WIRE_REPS}
    assert sizes[GOLOMB] < sizes[PACKED] < sizes[DENSE]


def test_wire_manifest_is_self_describing():
    ex = small_expert()
    m = peek_manifest(encode_expert(ex, rep=PACKED))
    assert m["rep"] == PACKED
    assert m["name"] == "wire"
    paths = {l["path"] for l in m["leaves"]}
    assert paths == set(ex.packed)
    for leaf in m["leaves"]:
        assert tuple(leaf["shape"]) == tuple(ex.packed[leaf["path"]].shape)
        assert leaf["scale"] == float(ex.packed[leaf["path"]].scale)


def test_wire_rejects_bad_magic():
    with pytest.raises(WireFormatError, match="magic"):
        decode_expert(b"NOPE" + b"\x00" * 64)


def test_wire_rejects_future_version():
    blob = bytearray(encode_expert(small_expert()))
    blob[4] = 99
    with pytest.raises(WireFormatError, match="version 99"):
        decode_expert(bytes(blob))


@pytest.mark.parametrize("rep", WIRE_REPS)
def test_wire_rejects_corrupt_payload(rep):
    blob = bytearray(encode_expert(small_expert(), rep=rep))
    blob[-2] ^= 0xFF
    with pytest.raises(ChecksumError):
        decode_expert(bytes(blob))


def test_wire_rejects_truncated_payload():
    blob = encode_expert(small_expert())
    with pytest.raises(ChecksumError, match="truncated"):
        decode_expert(blob[:-10])


def test_wire_rejects_unknown_rep_on_encode():
    with pytest.raises(WireFormatError):
        encode_expert(small_expert(), rep="ternary")


def test_expert_save_load_cpft(tmp_path):
    """Expert.save/.load speak the wire container too (sniffed by magic)."""
    ex = small_expert()
    stats = ex.save(str(tmp_path / "e.cpft"))
    assert stats["ratio"] > 4
    back = rapi.load(str(tmp_path / "e.cpft"))
    assert_planes_equal(ex.packed, back.packed)


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rep", WIRE_REPS)
def test_local_transport_roundtrip(tmp_path, rep):
    tr = LocalTransport(str(tmp_path))
    ex = small_expert()
    pub = tr.publish(ex, rep=rep)
    assert pub["nbytes"] == tr.stats.bytes_out
    assert tr.names() == ["wire"]
    assert "wire" in tr
    assert_planes_equal(ex.packed, tr.fetch("wire").packed)


@pytest.mark.parametrize("rep", WIRE_REPS)
def test_simulated_transport_roundtrip(rep):
    tr = SimulatedNetworkTransport(bandwidth_bps=1e9, latency_s=0.0)
    ex = small_expert()
    tr.publish(ex, rep=rep)
    assert_planes_equal(ex.packed, tr.fetch("wire").packed)


@pytest.mark.parametrize("rep", WIRE_REPS)
def test_http_transport_roundtrip(tmp_path, rep):
    root = LocalTransport(str(tmp_path))
    ex = small_expert()
    root.publish(ex, rep=rep)
    server, url = serve_local_http(str(tmp_path))
    try:
        tr = HTTPTransport(url)
        assert "wire" in tr
        assert "missing" not in tr
        assert_planes_equal(ex.packed, tr.fetch("wire").packed)
        assert tr.stats.bytes_in == root.stats.bytes_out
        with pytest.raises(TransportError):
            tr.fetch("missing")
    finally:
        server.shutdown()


def test_missing_expert_raises():
    for tr in (InMemoryTransport(), LocalTransport("/tmp/compeft-none")):
        with pytest.raises(TransportError, match="missing"):
            tr.fetch_bytes("missing")


def test_simulated_link_charges_wall_time():
    tr = SimulatedNetworkTransport(bandwidth_bps=1e5, latency_s=0.05)
    ex = small_expert()
    tr.publish(ex, rep=PACKED)
    nbytes = tr.stats.bytes_out
    t0 = time.perf_counter()
    tr.fetch_bytes("wire")
    dt = time.perf_counter() - t0
    assert dt >= 0.05 + nbytes / 1e5
    assert tr.stats.fetch_seconds >= 0.05


def test_simulated_link_loss_retries_deterministically():
    def run():
        tr = SimulatedNetworkTransport(bandwidth_bps=1e9, latency_s=0.0,
                                       loss=0.7, seed=7)
        tr.publish(small_expert(), rep=GOLOMB)
        tr.fetch_bytes("wire")
        return tr.stats.retries
    a, b = run(), run()
    assert a == b       # seeded: reproducible benchmark conditions
    assert a >= 1


def test_simulated_link_total_loss_fails_loudly():
    tr = SimulatedNetworkTransport(bandwidth_bps=1e9, loss=0.99, seed=0,
                                   max_retries=3)
    tr.publish(small_expert(), rep=GOLOMB)
    with pytest.raises(TransportError, match="dropped"):
        tr.fetch_bytes("wire")


# ---------------------------------------------------------------------------
# REMOTE tier: registry over a transport, prefetch pipeline
# ---------------------------------------------------------------------------


def publish_library(tr, n=4, **kw):
    exs = [small_expert(name=f"e{i}", seed=i, **kw) for i in range(n)]
    for e in exs:
        tr.publish(e)
    return exs


def test_remote_registry_fetch_and_cold_cache():
    tr = SimulatedNetworkTransport(bandwidth_bps=1e9, latency_s=0.0)
    exs = publish_library(tr)
    reg = rapi.registry(transport=tr)
    assert set(reg.names()) == {e.name for e in exs}
    assert "e0" in reg
    assert_planes_equal(exs[0].packed, reg.get("e0").packed)
    fetches = tr.stats.fetches
    reg.get("e0")                       # cold-local tier: no refetch
    reg.device().fetch("e0")
    assert tr.stats.fetches == fetches
    # store→host accounting uses bytes-on-wire for remote experts
    assert reg.nbytes("e0") < exs[0].nbytes(PACKED)


def test_remote_registry_local_overlay_and_publish():
    tr = InMemoryTransport()
    reg = rapi.registry(transport=tr)
    ex = small_expert(name="local-only")
    reg.add(ex)                         # local overlay, NOT uploaded
    assert "local-only" not in tr
    reg.publish(small_expert(name="shared"))
    assert "shared" in tr
    plain = rapi.registry()
    with pytest.raises(TypeError, match="transport"):
        plain.publish(ex)


def test_local_overlay_invalidates_staged_prefetch():
    """A reg.add() that shadows a remote name must win over an in-flight
    prefetch of the remote artifact — the stale staged planes are
    dropped, not promoted."""
    tr = SimulatedNetworkTransport(bandwidth_bps=1e9, latency_s=0.05)
    publish_library(tr, n=1)
    reg = rapi.registry(transport=tr)
    assert reg.prefetch(["e0"]) == 1
    overlay = small_expert(name="e0", seed=99)      # different content
    reg.add(overlay)
    packed = reg.device().fetch("e0")
    assert_planes_equal(overlay.packed, packed)


def test_remote_registry_cold_golomb_tier():
    """REMOTE + cold-Golomb: the cold-local tier keeps only the streams,
    and promotion still yields planes bit-identical to the publisher's."""
    tr = InMemoryTransport()
    exs = publish_library(tr, n=2)
    reg = rapi.registry(transport=tr, cold_golomb=True)
    for e in exs:
        assert_planes_equal(e.packed, reg.get(e.name).packed)
        reg.device().fetch(e.name)
    assert tr.stats.fetches == len(exs)     # cold tier absorbed repeats


def test_prefetch_overlaps_simulated_latency():
    """4 fetches at 200 ms link latency: serial >= 800 ms, the staged
    pipeline must land well under that (transfer overlaps decode+pack).
    The latency is large relative to host-side decode/dispatch costs so
    the 0.6x budget holds on loaded CI runners."""
    latency = 0.2
    tr = SimulatedNetworkTransport(bandwidth_bps=1e9, latency_s=latency)
    exs = publish_library(tr)
    names = [e.name for e in exs]
    reg = rapi.registry(transport=tr)
    t0 = time.perf_counter()
    issued = reg.prefetch(names)
    for n in names:
        reg.device().fetch(n)
    elapsed = time.perf_counter() - t0
    assert issued == len(names)
    assert elapsed < 0.6 * len(names) * latency
    st = reg.device().stats
    assert st.prefetch_hits == len(names)
    assert st.remote_fetches == len(names)
    assert st.remote_bytes == tr.stats.bytes_in
    for e in exs:                      # overlap never trades correctness
        assert_planes_equal(e.packed, reg.get(e.name).packed)


def test_prefetch_advisory_on_unknown_and_resident():
    tr = InMemoryTransport()
    publish_library(tr, n=1)
    reg = rapi.registry(transport=tr)
    assert reg.prefetch(["__base__"]) == 0      # sentinel skipped
    reg.device().fetch("e0")
    assert reg.prefetch(["e0"]) == 0            # already resident
    # unknown names stage (membership probes must not block the caller);
    # the stage fails on the worker and the sync fetch still fails loudly
    assert reg.prefetch(["nope"]) == 1
    with pytest.raises(TransportError):
        reg.device().fetch("nope")
    reg.close()                                 # drops staged promotions
    with pytest.raises(TransportError):
        reg.device().fetch("nope")              # still loud after close


def _smoke_engine(reg, n_experts=2):
    from repro.configs import get_smoke_config
    from repro.models import Runtime, build
    rt = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    model = build(cfg)
    base = model.init(jax.random.PRNGKey(0))
    exs = []
    for i in range(n_experts):
        leaves, tdef = jax.tree_util.tree_flatten(base)
        keys = jax.random.split(jax.random.PRNGKey(100 + i), len(leaves))
        ft = jax.tree_util.tree_unflatten(tdef, [
            (l.astype(jnp.float32)
             + 0.01 * jax.random.normal(k, l.shape)).astype(l.dtype)
            for l, k in zip(leaves, keys)])
        exs.append(rapi.compress(base, ft, name=f"expert{i}", density=0.2))
    engine = rapi.serve(model, rt, base, reg, max_batch=2, cache_len=64)
    return model, cfg, base, exs, engine


def test_engine_over_remote_registry_matches_local():
    """Serving from a remote registry is token-identical to serving from a
    local one, and the engine's admission prefetch stages remote fetches."""
    tr = SimulatedNetworkTransport(bandwidth_bps=1e9, latency_s=0.01)
    reg_remote = rapi.registry(transport=tr)
    model, cfg, base, exs, eng_remote = _smoke_engine(reg_remote)
    for e in exs:
        tr.publish(e)
    reg_local = rapi.registry(experts=exs)
    eng_local = rapi.serve(
        model, eng_remote.rt, base, reg_local, max_batch=2, cache_len=64)

    rng = np.random.default_rng(0)
    prompts = [jnp.asarray(rng.integers(1, cfg.vocab, 10), jnp.int32)
               for _ in range(4)]

    def mk():
        from repro.serve import Request
        return [Request(uid=i, expert=f"expert{i % 2}", prompt=prompts[i],
                        max_new_tokens=3) for i in range(4)]

    remote_reqs, local_reqs = mk(), mk()
    eng_remote.run(remote_reqs)
    eng_local.run(local_reqs)
    assert ([r.out_tokens for r in remote_reqs]
            == [r.out_tokens for r in local_reqs])
    st = reg_remote.device().stats
    assert st.remote_fetches == len(exs)
    assert st.prefetch_hits >= 1        # admission staged the cold fetches
    assert st.remote_bytes == tr.stats.bytes_in


# ---------------------------------------------------------------------------
# retry/backoff policy + failure classification (PR 6)
# ---------------------------------------------------------------------------

from repro.transport import (ChaosFault, ChaosTransport, DeadlineExceeded,
                             ExpertNotFound, ReplicaUnreachable,
                             RetriesExhausted, RetryPolicy, is_retryable)

FAST = RetryPolicy(max_attempts=3, backoff_base_s=0.0)


class CountingTransport(InMemoryTransport):
    """Counts raw _get attempts — what the retry loop actually issued."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.calls = 0

    def _get(self, name):
        self.calls += 1
        return super()._get(name)


def test_backoff_schedule_deterministic_and_bounded():
    pol = RetryPolicy(seed=3, backoff_base_s=0.05, backoff_multiplier=2.0,
                      jitter=0.1)
    again = RetryPolicy(seed=3, backoff_base_s=0.05, backoff_multiplier=2.0,
                        jitter=0.1)
    for attempt in range(4):
        d = pol.backoff_s(attempt, "ex")
        # keyed by (seed, name, attempt): stable across policy instances
        # and independent of call order / thread interleaving
        assert d == again.backoff_s(attempt, "ex")
        nominal = 0.05 * 2.0 ** attempt
        assert nominal * 0.9 <= d <= nominal * 1.1
    # different names draw different jitter, so replicas don't sync up
    assert pol.backoff_s(0, "ex") != pol.backoff_s(0, "other")


def test_terminal_absence_is_not_retried():
    tr = CountingTransport(retry=FAST)
    with pytest.raises(ExpertNotFound):
        tr.fetch_bytes("missing")
    assert tr.calls == 1              # 404-class errors never retry
    assert tr.stats.retries == 0


@pytest.mark.parametrize("kind", ["bitflip", "partial"])
def test_corrupted_payload_refetched(kind):
    inner = CountingTransport()
    ex = small_expert()
    inner.publish(ex, rep=GOLOMB)
    tr = ChaosTransport(inner, faults=[ChaosFault("wire", 0, kind)],
                        seed=0, retry=FAST)
    got, nbytes = tr.fetch_expert("wire")
    assert_planes_equal(ex.packed, got.packed)
    assert inner.calls == 2           # corrupt read + clean refetch
    assert tr.stats.retries == 1
    assert [f["kind"] for f in tr.fired()] == [kind]


def test_blackout_exhausts_retries_with_typed_error():
    inner = InMemoryTransport()
    inner.publish(small_expert(), rep=GOLOMB)
    tr = ChaosTransport(inner, blackout=["wire"], seed=0, retry=FAST)
    with pytest.raises(RetriesExhausted, match="blacked out"):
        tr.fetch_bytes("wire")
    assert len(tr.fired()) == FAST.max_attempts
    # the wrapped error chain keeps the last cause for diagnostics
    tr.restore("wire")
    assert len(tr.fetch_bytes("wire")) > 0


def test_overall_deadline_cuts_backoff_short():
    inner = InMemoryTransport()
    inner.publish(small_expert(), rep=GOLOMB)
    slow = RetryPolicy(max_attempts=5, backoff_base_s=10.0, jitter=0.0,
                       deadline_s=0.05)
    tr = ChaosTransport(inner, blackout=["wire"], seed=0, retry=slow)
    t0 = time.perf_counter()
    with pytest.raises(DeadlineExceeded):
        tr.fetch_bytes("wire")
    # the 10 s backoff would blow the 50 ms deadline, so the loop gives
    # up BEFORE sleeping — not after
    assert time.perf_counter() - t0 < 2.0


def test_error_classification():
    assert is_retryable(ChecksumError("crc"))          # refetch fixes it
    assert is_retryable(ReplicaUnreachable("down"))
    assert not is_retryable(ExpertNotFound("404"))
    assert not is_retryable(WireFormatError("bad magic"))
    assert not is_retryable(ValueError("not transport-related"))


def test_http_contains_absent_vs_unreachable(tmp_path):
    """`contains` answers "expert absent" ONLY from a definitive 404; a
    dead replica raises instead of masquerading as absence (callers would
    otherwise treat an outage as "never published")."""
    root = LocalTransport(str(tmp_path))
    root.publish(small_expert(), rep=GOLOMB)
    server, url = serve_local_http(str(tmp_path))
    try:
        tr = HTTPTransport(url, retry=FAST)
        assert tr.contains("wire")
        assert not tr.contains("missing")      # 404: definitively absent
    finally:
        server.shutdown()
    dead = HTTPTransport("http://127.0.0.1:9", timeout_s=0.2,
                         retry=RetryPolicy(max_attempts=1))
    with pytest.raises(ReplicaUnreachable):
        dead.contains("wire")
    with pytest.raises((RetriesExhausted, ReplicaUnreachable)):
        dead.fetch_bytes("wire")


def test_simulated_timeout_classified_and_retried():
    tr = SimulatedNetworkTransport(
        bandwidth_bps=1e3, latency_s=0.05, seed=0,
        retry=RetryPolicy(max_attempts=2, backoff_base_s=0.0,
                          per_attempt_timeout_s=0.01))
    tr.publish(small_expert(), rep=GOLOMB)
    with pytest.raises(RetriesExhausted, match="per-attempt timeout"):
        tr.fetch_bytes("wire")
    assert tr.stats.retries == 1
