"""Unit tests for the while-aware HLO analyzer on hand-written HLO."""

import numpy as np

from repro.launch.hlo_analysis import (_parse_op_line, _shape_elems_bytes,
                                       analyze, parse_computations)

TOY = """
HloModule toy

%body.1 (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%arg), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.0 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.0), replica_groups=[2,4]<=[8], to_apply=%sum.9
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ip, %ar)
}

%cond.2 (arg2: (s32[], f32[8,16])) -> pred[] {
  %arg2 = (s32[], f32[8,16]) parameter(0)
  %i2 = s32[] get-tuple-element(%arg2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

%sum.9 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main.3 (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %tup = (s32[], f32[8,16]) tuple(%c0, %p0)
  %while.5 = (s32[], f32[8,16]) while(%tup), condition=%cond.2, body=%body.1
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%while.5), index=1
}
"""


def test_parse_op_line_nested_tuple_type():
    line = ("  %while.5 = (s32[], f32[8,64]{1,0}, (f32[2,2], s32[])) "
            "while(%tuple), condition=%c, body=%b")
    name, ty, opcode, rest = _parse_op_line(line)
    assert name == "while.5"
    assert opcode == "while"
    assert "condition=%c" in rest
    e, b = _shape_elems_bytes(ty)
    assert b == 4 + 8 * 64 * 4 + 4 * 4 + 4


def test_analyze_counts_trips_and_collectives():
    res = analyze(TOY, n_devices=8)
    # dot flops: 2*8*16*16 per trip x 5 trips
    assert res["flops_per_device"] == 2 * 8 * 16 * 16 * 5
    # all-reduce wire (ring): 2 * bytes * (g-1)/g, g=4, x5 trips
    want = 2 * (8 * 16 * 4) * 3 / 4 * 5
    assert res["collective_bytes_per_device"]["all-reduce"] == want
    assert res["collective_total"] == want


def test_analyze_group_parsing_list_form():
    hlo = TOY.replace("replica_groups=[2,4]<=[8]",
                      "replica_groups={{0,1},{2,3},{4,5},{6,7}}")
    res = analyze(hlo, n_devices=8)
    want = 2 * (8 * 16 * 4) * 1 / 2 * 5   # g=2 now
    assert res["collective_bytes_per_device"]["all-reduce"] == want
