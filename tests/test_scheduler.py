"""Pluggable admission schedulers: FIFO wave-replica semantics, priority
classes + deadline EDF ordering, expert-affinity wave packing with
canonical stack tuples, and arrival-time release gating."""

import jax.numpy as jnp

from repro.serve.engine import Request
from repro.serve.scheduler import (SCHEDULERS, AffinityScheduler,
                                   FIFOScheduler, PriorityScheduler,
                                   make_scheduler)


def _req(uid, expert="expert0", priority=1, deadline=None, arrival=0.0,
         max_new=4):
    return Request(uid=uid, expert=expert,
                   prompt=jnp.asarray([1, 2, 3], jnp.int32),
                   max_new_tokens=max_new, priority=priority,
                   deadline_s=deadline, arrival_s=arrival)


def test_registry_and_factory():
    assert set(SCHEDULERS) == {"fifo", "priority", "affinity"}
    assert isinstance(make_scheduler("fifo"), FIFOScheduler)
    assert isinstance(make_scheduler("priority"), PriorityScheduler)
    assert isinstance(make_scheduler("affinity"), AffinityScheduler)
    try:
        make_scheduler("nope")
        assert False, "unknown scheduler must raise"
    except ValueError:
        pass


def test_fifo_wave_replicates_historical_semantics():
    """FIFO pops in arrival order and stops the wave when the head would
    introduce expert number max_stack+1 — the head then BLOCKS (strict
    head-of-line), exactly like the historical deque loop."""
    s = make_scheduler("fifo")
    reqs = [_req(0, "a"), _req(1, "b"), _req(2, "c"), _req(3, "a")]
    for r in reqs:
        s.push(r)
    s.release(0.0)
    wave, experts = s.take_wave(max_batch=8, max_stack=2)
    # wave stops at uid=2 ("c" would be a third expert) even though
    # uid=3 ("a") would fit — strict FIFO never reorders.
    assert [r.uid for r in wave] == [0, 1]
    assert sorted(experts) == ["a", "b"]
    assert s.strict_fifo
    # next wave picks up the rest
    wave2, experts2 = s.take_wave(max_batch=8, max_stack=2)
    assert [r.uid for r in wave2] == [2, 3]


def test_priority_orders_by_class_then_deadline():
    s = make_scheduler("priority")
    s.push(_req(0, priority=2, deadline=None))
    s.push(_req(1, priority=0, deadline=9.0))
    s.push(_req(2, priority=0, deadline=1.0))
    s.push(_req(3, priority=1, deadline=0.5))
    s.release(0.0)
    wave, _ = s.take_wave(max_batch=8, max_stack=4)
    # class asc, then earliest deadline (None == +inf), then arrival
    assert [r.uid for r in wave] == [2, 1, 3, 0]
    assert not s.strict_fifo


def test_priority_skips_over_stack_instead_of_blocking():
    """A head whose expert does not fit the stack is skipped (deferred),
    not allowed to starve placeable requests behind it."""
    s = make_scheduler("priority")
    s.push(_req(0, "a", priority=0))
    s.push(_req(1, "b", priority=0))
    s.push(_req(2, "c", priority=0))   # third expert: over max_stack=2
    s.push(_req(3, "a", priority=1))   # placeable, arrived later
    s.release(0.0)
    wave, experts = s.take_wave(max_batch=8, max_stack=2)
    assert [r.uid for r in wave] == [0, 1, 3]
    assert s.stats()["deferred"] >= 1
    wave2, _ = s.take_wave(max_batch=8, max_stack=2)
    assert [r.uid for r in wave2] == [2]


def test_affinity_packs_by_expert_with_canonical_tuple():
    s = make_scheduler("affinity")
    # backlog: 3x "b", 2x "a", 1x "c" -- affinity should choose the two
    # biggest backlogs for max_stack=2 and emit a SORTED expert tuple.
    for uid, e in enumerate(["b", "a", "c", "b", "a", "b"]):
        s.push(_req(uid, e))
    s.release(0.0)
    wave, experts = s.take_wave(max_batch=8, max_stack=2)
    assert experts == sorted(experts), "stack tuple must be canonical"
    assert set(experts) == {"a", "b"}
    assert {r.expert for r in wave} == {"a", "b"}
    assert len(wave) == 5
    # stickiness: with fresh backlog on the same experts plus a new one,
    # the previously-served experts win ties.
    for uid, e in enumerate(["c", "a", "b"], start=10):
        s.push(_req(uid, e))
    s.release(0.0)
    wave2, experts2 = s.take_wave(max_batch=8, max_stack=2)
    assert set(experts2) == {"a", "b"}


def test_affinity_candidates_prefer_in_slot_experts():
    s = make_scheduler("affinity")
    s.push(_req(0, "cold", priority=0))      # best priority, new expert
    s.push(_req(1, "hot", priority=1))       # in-slot expert
    s.release(0.0)
    cands = s.candidates(slot=["hot", "warm"])
    assert cands[0].uid == 1, "in-slot expert should be offered first"
    assert [c.uid for c in cands] == [1, 0]


def test_arrival_release_gating():
    """Requests with a future arrival_s stay invisible until release(now)
    passes their arrival time — the open-loop replay contract."""
    for name in SCHEDULERS:
        s = make_scheduler(name)
        s.push(_req(0, arrival=0.0))
        s.push(_req(1, arrival=5.0))
        s.push(_req(2, arrival=2.0))
        s.release(0.0)
        assert s.ready_count() == 1 and s.pending() == 3
        assert s.next_arrival() == 2.0
        wave, _ = s.take_wave(max_batch=8, max_stack=4)
        assert [r.uid for r in wave] == [0]
        s.release(2.5)
        wave, _ = s.take_wave(max_batch=8, max_stack=4)
        assert [r.uid for r in wave] == [2]
        s.release(10.0)
        wave, _ = s.take_wave(max_batch=8, max_stack=4)
        assert [r.uid for r in wave] == [1]
        assert s.pending() == 0


def test_remove_reaches_future_items():
    s = make_scheduler("fifo")
    r = _req(0, arrival=99.0)
    s.push(r)
    s.release(0.0)
    s.remove(r)
    assert s.pending() == 0
