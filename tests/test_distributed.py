"""Multi-device distribution tests (subprocess with 8 fake devices so the
main process keeps a single device): sharded train step with compressed
cross-pod gradients, SP flash decoding, and sharding-rule sanity."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.distributed.sharding import param_pspec


def run_sub(script: str, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    return r.stdout


def test_param_pspec_rules():
    import jax as _jax
    cfg = get_config("qwen3_32b")
    mesh = _jax.sharding.Mesh(
        np.array(_jax.devices() * 1).reshape(1, 1), ("data", "model"))
    # embedding vocab-parallel (d_model unsharded: XLA partitioner
    # workaround, see sharding.py)
    spec = param_pspec("embed", (151936, 5120), cfg, mesh)
    assert tuple(spec) == ("model", None)
    # attention head-TP (64 heads % 16 ... here n_model=1 so divisible)
    spec = param_pspec("blocks/block0/attn/wq", (64, 5120, 64, 128), cfg,
                       mesh)
    assert tuple(spec) == (None, "data", "model", None)
    # llama4: head_tp disabled -> FSDP on the NON-contraction head_dim
    # (sharding d_model forces activation regathers; see §Perf E2)
    cfg4 = get_config("llama4_maverick_400b")
    spec = param_pspec("blocks/block0/attn/wq", (24, 5120, 40, 128), cfg4,
                       mesh)
    assert tuple(spec) == (None, None, None, "data")
    # mixtral experts: internal TP
    cfgm = get_config("mixtral_8x7b")
    spec = param_pspec("blocks/block0/ffn/wg_e", (32, 8, 4096, 14336), cfgm,
                       mesh)
    assert tuple(spec) == (None, None, "data", "model")
    # llama4 experts: EP
    spec = param_pspec("blocks/block1/ffn/wg_e", (24, 128, 5120, 8192), cfg4,
                       mesh)
    assert tuple(spec) == (None, "model", "data", None)


TRAIN_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke_config
    from repro.models import build, Runtime
    from repro.distributed.sharding import (make_shard_fn, param_shardings,
                                            batch_shardings, replicated)
    from repro.train import TrainConfig, init_train_state, make_train_step
    from repro.data.pipeline import make_batch_for
    from repro.core.gradient_compression import GradCompressionConfig

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_smoke_config("qwen2_5_3b")
    api = build(cfg)
    rt = Runtime(shard=make_shard_fn(mesh, cfg), attn_chunk_q=16,
                 attn_chunk_k=16, remat_policy="none")
    tcfg = TrainConfig(microbatches=2, peak_lr=5e-3, warmup_steps=2,
                       total_steps=50,
                       grad_compression=GradCompressionConfig(
                           enabled=True, density=0.3))
    with jax.set_mesh(mesh):
        params = api.init(jax.random.PRNGKey(0))
        state = init_train_state(params, tcfg, multi_pod=True)
        pshard = param_shardings(jax.eval_shape(lambda: params), cfg, mesh)
        state = jax.device_put(state, {
            "params": pshard,
            "opt": {"mu": pshard, "nu": pshard,
                    "count": replicated(mesh)},
            "ef": pshard,
            "step": replicated(mesh)})
        step_fn = jax.jit(make_train_step(api, rt, tcfg, mesh=mesh))
        losses = []
        for s in range(12):
            batch = make_batch_for(cfg, s, 32, 8)
            state, m = step_fn(state, batch)
            losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses
    print("TRAIN_OK", losses[0], losses[-1])
""")


def test_compressed_multipod_train_step():
    out = run_sub(TRAIN_SHARDED)
    assert "TRAIN_OK" in out


SP_DECODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke_config
    from repro.models import build, Runtime
    from repro.distributed.sharding import make_shard_fn
    from repro.distributed.collectives import make_sp_decode_attn

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_smoke_config("qwen2_5_3b", n_units=2)
    api = build(cfg)
    rt_local = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")
    rt_sp = Runtime(shard=make_shard_fn(mesh, cfg),
                    decode_attn=make_sp_decode_attn(mesh),
                    attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (2, 12)), jnp.int32)
    batch = {"tokens": toks}

    # local reference (cache_len multiple of model axis = 4)
    lp_l, cache_l = api.prefill(params, batch, rt_local, cache_len=16)
    prefill_sp = jax.jit(lambda p, b: api.prefill(p, b, rt_sp, 16))
    decode_sp = jax.jit(lambda p, t, c: api.decode_step(p, t, c, rt_sp))
    with jax.set_mesh(mesh):
        lp_s, cache_s = prefill_sp(params, batch)
        np.testing.assert_allclose(np.asarray(lp_l, np.float32),
                                   np.asarray(lp_s, np.float32),
                                   atol=2e-3, rtol=2e-3)
        tok = jnp.argmax(lp_l[:, -1], -1).astype(jnp.int32)[:, None]
        ld_l, cache_l = api.decode_step(params, tok, cache_l, rt_local)
        ld_s, cache_s = decode_sp(params, tok, cache_s)
        np.testing.assert_allclose(np.asarray(ld_l, np.float32),
                                   np.asarray(ld_s, np.float32),
                                   atol=2e-3, rtol=2e-3)
        ld_l2, _ = api.decode_step(params, tok, cache_l, rt_local)
        ld_s2, _ = decode_sp(params, tok, cache_s)
        np.testing.assert_allclose(np.asarray(ld_l2, np.float32),
                                   np.asarray(ld_s2, np.float32),
                                   atol=2e-3, rtol=2e-3)
    print("SP_OK")
""")


def test_sp_decode_matches_local():
    out = run_sub(SP_DECODE)
    assert "SP_OK" in out
