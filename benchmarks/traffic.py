"""Seeded open-loop traffic generation for the serving engine.

Production expert-serving traffic is not a batch of identical prompts:
arrivals are bursty, expert popularity is heavy-tailed (a few hot
adapters take most requests — the S-LoRA observation), and prompt/output
lengths are bimodal.  This module synthesises such a workload as a
deterministic function of a seed, so a load experiment can be replayed
bit-identically (double-run determinism is a gate of ``perf_lab --exp
serve_load``):

* **arrivals** — an open-loop (arrival times independent of service
  rate) inhomogeneous Poisson process: exponential gaps at ``base_rate``
  req/s, multiplied by ``burst_rate_x`` inside periodic burst windows
  (``burst_every_s``/``burst_duration_s``).
* **expert popularity** — Zipf: expert k (1-indexed) drawn with
  probability ∝ k^-alpha over ``n_experts`` experts.
* **lengths** — a short/long prompt mix (``long_frac``) with independent
  short/long output budgets.
* **SLO metadata** — priority classes drawn from ``priorities`` weights;
  each class maps to a deadline budget (``deadline_by_priority``,
  seconds after arrival) consumed by the deadline-aware schedulers.

``generate()`` returns engine :class:`~repro.serve.engine.Request`
objects with ``arrival_s`` set; the engine's scheduler holds each
request invisible until its arrival time passes, which is what makes the
replay open-loop rather than closed-loop.  ``summarize()`` reduces a
served request list to the latency/throughput record keyed into
``BENCH_serve.json`` (TTFT p50/p95/p99, tokens/s, per-priority waits,
deadline violations).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.serve.engine import FAILED, Request

__all__ = ["TrafficConfig", "zipf_weights", "in_burst", "generate",
           "summarize"]


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Everything the arrival process depends on.  Two equal configs
    generate bit-identical request timelines."""

    seed: int = 0
    n_requests: int = 64
    # -- arrivals (open-loop Poisson + periodic bursts) --
    base_rate: float = 8.0          # req/s outside bursts
    burst_every_s: float = 4.0      # burst window period
    burst_duration_s: float = 1.0   # burst window length
    burst_rate_x: float = 4.0       # rate multiplier inside a window
    # -- expert popularity (Zipf over expert0..expert{n-1}) --
    n_experts: int = 8
    zipf_alpha: float = 1.1
    expert_prefix: str = "expert"
    # -- prompt/output length mix --
    prompt_len_short: int = 6
    prompt_len_long: int = 40
    long_frac: float = 0.25
    max_new_short: int = 8
    max_new_long: int = 16
    long_out_frac: float = 0.25
    vocab: int = 512
    # -- SLO metadata --
    priorities: tuple = ((0, 0.2), (1, 0.8))   # (class, weight)
    deadline_by_priority: tuple = ((0, 2.0), (1, 10.0))  # class -> budget s


def zipf_weights(n: int, alpha: float) -> np.ndarray:
    """P(expert k) ∝ (k+1)^-alpha, normalised.  ``alpha=0`` is uniform."""
    w = np.arange(1, n + 1, dtype=np.float64) ** (-alpha)
    return w / w.sum()


def in_burst(t: float, cfg: TrafficConfig) -> bool:
    """Whether absolute time ``t`` lands inside a periodic burst window."""
    if cfg.burst_every_s <= 0 or cfg.burst_duration_s <= 0:
        return False
    return (t % cfg.burst_every_s) < cfg.burst_duration_s


def _rate(t: float, cfg: TrafficConfig) -> float:
    return cfg.base_rate * (cfg.burst_rate_x if in_burst(t, cfg) else 1.0)


def generate(cfg: TrafficConfig) -> list:
    """Materialise the seeded timeline as engine requests.

    Arrival gaps are sampled from the exponential at the rate *in effect
    at the current time* (a standard thinning-free approximation that
    keeps the process a pure function of the seed); expert, lengths,
    priority and prompt tokens come from the same generator stream, so
    the whole workload — ordering, content and metadata — replays
    bit-identically for equal configs.
    """
    rng = np.random.default_rng(cfg.seed)
    pw = zipf_weights(cfg.n_experts, cfg.zipf_alpha)
    prio_cls = np.asarray([p for p, _ in cfg.priorities], np.int64)
    prio_w = np.asarray([w for _, w in cfg.priorities], np.float64)
    prio_w = prio_w / prio_w.sum()
    budget = dict(cfg.deadline_by_priority)

    out = []
    t = 0.0
    for uid in range(cfg.n_requests):
        t += float(rng.exponential(1.0 / max(_rate(t, cfg), 1e-9)))
        expert = int(rng.choice(cfg.n_experts, p=pw))
        lp = (cfg.prompt_len_long if rng.random() < cfg.long_frac
              else cfg.prompt_len_short)
        mx = (cfg.max_new_long if rng.random() < cfg.long_out_frac
              else cfg.max_new_short)
        prio = int(rng.choice(prio_cls, p=prio_w))
        prompt = rng.integers(2, cfg.vocab, size=lp)
        out.append(Request(
            uid=uid,
            expert=f"{cfg.expert_prefix}{expert}",
            prompt=jnp.asarray(prompt, jnp.int32),
            max_new_tokens=int(mx),
            priority=prio,
            deadline_s=t + budget[prio] if prio in budget else None,
            arrival_s=t,
        ))
    return out


def _pct(xs: list, q: float) -> Optional[float]:
    return float(np.percentile(np.asarray(xs), q)) if xs else None


def summarize(requests: list) -> dict:
    """Latency/throughput record for a served request list.

    TTFT is ``t_first_s - arrival_s`` (time to the first *selected*
    token); tokens/s counts generated tokens over the span from the first
    arrival to the last completion.  Requests that failed (or never got a
    first token) are counted but excluded from the percentiles.
    """
    served = [r for r in requests
              if r.status != FAILED and r.t_first_s is not None]
    ttft = [r.t_first_s - r.arrival_s for r in served]
    n_tokens = sum(len(r.out_tokens) for r in served)
    done_t = [r.t_done_s for r in served if r.t_done_s is not None]
    t0 = min((r.arrival_s for r in served), default=0.0)
    span = (max(done_t) - t0) if done_t else 0.0
    by_prio: dict = {}
    for r in served:
        b = by_prio.setdefault(r.priority, {"n": 0, "ttft": [], "miss": 0})
        b["n"] += 1
        b["ttft"].append(r.t_first_s - r.arrival_s)
        if (r.deadline_s is not None and r.t_done_s is not None
                and r.t_done_s > r.deadline_s):
            b["miss"] += 1
    return {
        "n_served": len(served),
        "n_failed": sum(1 for r in requests if r.status == FAILED),
        "ttft_p50_s": _pct(ttft, 50),
        "ttft_p95_s": _pct(ttft, 95),
        "ttft_p99_s": _pct(ttft, 99),
        "tokens": n_tokens,
        "tokens_per_s": n_tokens / span if span > 0 else None,
        "span_s": span,
        "per_priority": {
            str(p): {"n": b["n"], "ttft_p95_s": _pct(b["ttft"], 95),
                     "deadline_miss": b["miss"]}
            for p, b in sorted(by_prio.items())},
    }
