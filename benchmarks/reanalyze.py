"""Re-run the HLO analyzer over cached .hlo.gz dry-run artifacts (no
recompile) and update the JSON records in place.

    PYTHONPATH=src python -m benchmarks.reanalyze
"""

import glob
import gzip
import json
import os

from repro.launch.hlo_analysis import analyze

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def main():
    for jpath in sorted(glob.glob(os.path.join(RESULTS, "*", "*.json"))):
        hpath = jpath.replace(".json", ".hlo.gz")
        if not os.path.exists(hpath):
            print("no hlo for", jpath)
            continue
        with open(jpath) as f:
            rec = json.load(f)
        with gzip.open(hpath, "rt") as f:
            hlo = f.read()
        st = analyze(hlo, rec["n_devices"])
        rec["flops"] = st["flops_per_device"]
        rec["bytes_accessed"] = st["bytes_per_device"]
        rec["collectives"] = {**st["collective_bytes_per_device"],
                              "ops": st["collective_op_counts"],
                              "total": st["collective_total"]}
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=1)
        print("reanalyzed", os.path.basename(jpath))


if __name__ == "__main__":
    main()
