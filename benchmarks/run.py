"""Benchmark harness — one function per paper table/figure (DESIGN.md §8).

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV rows and writes JSON detail to
benchmarks/results/paper/.  All model-based benchmarks train real (reduced)
models on CPU; compression numbers are exact (same math at any scale).
"""

from __future__ import annotations

import argparse
import json
import os
import time
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro import api as capi
from repro.configs import get_smoke_config
from repro.core import ALPHA_GRID, golomb_total_bits, rescale
from repro.core.baselines import METHODS, method_bits, run_method
from repro.core.golomb import decode as golomb_decode
from repro.core.golomb import encode as golomb_encode
from repro.core.merging import compose_lora, lorahub_search
from repro.data.pipeline import eval_loss, make_batch_for
from repro.expert import PACKED, TERNARY
from repro.models import Runtime, build
from repro.peft import LoraConfig, apply_lora, init_lora, task_vector
from repro.train import TrainConfig, init_train_state, make_train_step

RT = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")
OUT_DIR = os.path.join(os.path.dirname(__file__), "results", "paper")

ROWS: list[str] = []


def emit(name: str, us: float, derived: str):
    ROWS.append(f"{name},{us:.1f},{derived}")
    print(f"{name},{us:.1f},{derived}", flush=True)


def save_json(name: str, obj):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=1, default=float)


# ---------------------------------------------------------------------------
# Shared setup: base model + LoRA experts on distinct tasks
# ---------------------------------------------------------------------------


@lru_cache(maxsize=1)
def setup(quick: bool = False):
    cfg = get_smoke_config("qwen2_5_3b")
    api = build(cfg)
    tcfg = TrainConfig(peak_lr=1e-2, warmup_steps=5, total_steps=100,
                       optimizer="adamw")
    step_fn = jax.jit(make_train_step(api, RT, tcfg))

    # base model: brief pretraining on task 0 for nonzero competence
    state = init_train_state(api.init(jax.random.PRNGKey(0)), tcfg, False)
    n_base = 20 if quick else 60
    for s in range(n_base):
        state, _ = step_fn(state, make_batch_for(cfg, s, 48, 8, task_id=0))
    base = state["params"]

    # LoRA experts per task
    lcfg = LoraConfig(rank=4, alpha=8.0)
    experts = {}
    n_exp = 12 if quick else 50
    for task in (1, 2, 3):
        lora0 = init_lora(jax.random.PRNGKey(10 + task), base, lcfg)

        def loss_fn(lp, batch):
            merged = apply_lora(base, lp, lcfg)
            return api.loss_and_logits(merged, batch, RT)[0]

        grad_fn = jax.jit(jax.grad(loss_fn))
        lora = lora0
        for s in range(n_exp):
            b = make_batch_for(cfg, s, 48, 8, task_id=task)
            lora = jax.tree_util.tree_map(
                lambda p, g: p - 0.5 * g, lora, grad_fn(lora, b))
        experts[task] = (lora0, lora)
    return cfg, api, base, lcfg, experts


def expert_eval(cfg, api, base, lcfg, lora, task) -> float:
    merged = apply_lora(base, lora, lcfg)
    return eval_loss(api, merged, RT, cfg, task, n_batches=2, seq_len=48,
                     global_batch=8)


def tau_of(experts, task):
    lora0, lora = experts[task]
    return task_vector(lora0, lora)


def apply_tau(experts, task, tau):
    lora0, _ = experts[task]
    return jax.tree_util.tree_map(
        lambda a, d: (a.astype(jnp.float32) + d.astype(jnp.float32)
                      ).astype(a.dtype), lora0, tau)


# ---------------------------------------------------------------------------
# §Compression-ratios (paper Tables 1-4): size + quality vs density
# ---------------------------------------------------------------------------


def bench_compression_ratio(quick=False):
    cfg, api, base, lcfg, experts = setup(quick)
    results = {}
    t0 = time.perf_counter()
    for task in (1,):
        tau = tau_of(experts, task)
        l_orig = expert_eval(cfg, api, base, lcfg, experts[task][1], task)
        l_base = expert_eval(cfg, api, base, lcfg, experts[task][0], task)
        for k in (0.05, 0.1, 0.2, 0.3, 0.5):
            ex = capi.compress(tau, name=f"task{task}_k{k}", kind="lora",
                               density=k, alpha=1.0)
            summ = ex.summary()
            lora_hat = apply_tau(experts, task, ex.to_dense_tau())
            l_comp = expert_eval(cfg, api, base, lcfg, lora_hat, task)
            results[f"task{task}_k{k}"] = {
                "ratio_entropy": summ["compression_x_entropy"],
                "ratio_bitplane": summ["compression_x_bitplane"],
                "loss_orig": l_orig, "loss_comp": l_comp,
                "loss_base": l_base,
                "recovery": ((l_base - l_comp) / max(l_base - l_orig, 1e-9)),
            }
    us = (time.perf_counter() - t0) * 1e6 / max(len(results), 1)
    save_json("compression_ratio", results)
    r10 = results["task1_k0.1"]
    emit("compression_ratio", us,
         f"k=0.1:{r10['ratio_entropy']:.1f}x recov={r10['recovery']:.2f}")
    # paper claim: 8x-50x across k in [0.05, 0.2]
    assert results["task1_k0.05"]["ratio_entropy"] > 40
    assert results["task1_k0.2"]["ratio_entropy"] > 8


# ---------------------------------------------------------------------------
# §Ablation (Fig. 5): ComPEFT vs STC vs Pruned vs BitDelta vs DARE
# ---------------------------------------------------------------------------


def bench_ablation(quick=False):
    cfg, api, base, lcfg, experts = setup(quick)
    task = 1
    tau = tau_of(experts, task)
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tau))
    results = {}
    t0 = time.perf_counter()
    for k in (0.05, 0.2, 0.5):
        for m in METHODS:
            if m == "compeft":
                # alpha picked on validation (held-out batches), as §2.1
                best = None
                for a in (0.5, 1.0, 2.0, 3.0):
                    th = run_method(m, tau, k, alpha=a)
                    l = expert_eval(cfg, api, base, lcfg,
                                    apply_tau(experts, task, th), task)
                    if best is None or l < best[0]:
                        best = (l, a)
                l, alpha = best
            else:
                th = run_method(m, tau, k, key=jax.random.PRNGKey(0))
                l = expert_eval(cfg, api, base, lcfg,
                                apply_tau(experts, task, th), task)
                alpha = None
            results[f"{m}_k{k}"] = {"loss": l, "alpha": alpha,
                                    "bits": method_bits(m, n, k)}
    us = (time.perf_counter() - t0) * 1e6 / len(results)
    save_json("ablation", results)
    comp, stc = results["compeft_k0.05"]["loss"], results["stc_k0.05"]["loss"]
    pru = results["pruned_k0.05"]["loss"]
    emit("ablation_fig5", us,
         f"k=0.05 compeft={comp:.3f} stc={stc:.3f} pruned={pru:.3f}")
    assert comp <= stc + 1e-3   # paper: ComPEFT >= STC (tuned alpha)


# ---------------------------------------------------------------------------
# §Alpha-sweep (Fig. 6)
# ---------------------------------------------------------------------------


def bench_alpha_sweep(quick=False):
    cfg, api, base, lcfg, experts = setup(quick)
    task = 2
    tau = tau_of(experts, task)
    results = {}
    t0 = time.perf_counter()
    grid = ALPHA_GRID if not quick else (0.5, 1.0, 2.0, 4.0)
    for k in (0.05, 0.2, 0.5):
        from repro.core import decompress
        comp = capi.compress(tau, density=k, alpha=1.0).as_(TERNARY)
        for a in grid:
            th = decompress(rescale(comp, 1.0, a))
            l = expert_eval(cfg, api, base, lcfg,
                            apply_tau(experts, task, th), task)
            results[f"k{k}_a{a}"] = l
    us = (time.perf_counter() - t0) * 1e6 / len(results)
    save_json("alpha_sweep", results)
    # optimum alpha shifts down as density rises (paper obs. 2)
    best_a_lo = min((a for a in grid), key=lambda a: results[f"k0.05_a{a}"])
    best_a_hi = min((a for a in grid), key=lambda a: results[f"k0.5_a{a}"])
    emit("alpha_sweep_fig6", us,
         f"argmin_a@k0.05={best_a_lo} argmin_a@k0.5={best_a_hi}")


# ---------------------------------------------------------------------------
# §Latency (Table 5): transmission + load times, measured + modeled
# ---------------------------------------------------------------------------


def bench_transmission_latency(quick=False):
    cfg, api, base, lcfg, experts = setup(quick)
    tau = tau_of(experts, 1)
    results = {}
    t0 = time.perf_counter()
    for k in (0.05, 0.2):
        ex = capi.compress(tau, density=k)
        comp = ex.as_(TERNARY)
        dense_bytes = sum(l.size * 2 for l in jax.tree_util.tree_leaves(tau))
        golomb_bytes = 0
        enc_t = dec_t = 0.0
        for leaf in jax.tree_util.tree_leaves(
                comp, is_leaf=lambda x: hasattr(x, "signs")):
            signs = np.asarray(leaf.signs).reshape(-1)
            t1 = time.perf_counter()
            blob = golomb_encode(signs, float(leaf.scale))
            enc_t += time.perf_counter() - t1
            golomb_bytes += len(blob)
            t1 = time.perf_counter()
            golomb_decode(blob)
            dec_t += time.perf_counter() - t1
        # modeled links: 1 Gb/s internet, 16 GB/s host->device
        results[f"k{k}"] = {
            "dense_bytes": dense_bytes,
            "golomb_bytes": golomb_bytes,
            "bitplane_bytes": ex.nbytes(PACKED),
            "net_s_dense": dense_bytes / 125e6,
            "net_s_comp": golomb_bytes / 125e6,
            "pcie_ms_dense": dense_bytes / 16e9 * 1e3,
            "pcie_ms_comp": ex.nbytes(PACKED) / 16e9 * 1e3,
            "encode_s": enc_t, "decode_s": dec_t,
        }
    us = (time.perf_counter() - t0) * 1e6 / len(results)
    save_json("transmission_latency", results)
    r = results["k0.05"]
    emit("latency_table5", us,
         f"net {r['net_s_dense']:.2e}s->{r['net_s_comp']:.2e}s "
         f"({r['dense_bytes'] / max(r['golomb_bytes'], 1):.0f}x)")


# ---------------------------------------------------------------------------
# §Merging (Table 6): TA + TIES on raw vs compressed experts
# ---------------------------------------------------------------------------


def bench_merging(quick=False):
    cfg, api, base, lcfg, experts = setup(quick)
    tasks = (1, 2, 3)
    taus = [tau_of(experts, t) for t in tasks]
    arts = [capi.compress(t, name=f"task{i}", kind="lora", density=0.2,
                          alpha=1.0) for i, t in enumerate(taus)]

    def avg_loss(tau_merged):
        losses = []
        for t in tasks:
            lora_m = apply_tau(experts, t, tau_merged)
            losses.append(expert_eval(cfg, api, base, lcfg, lora_m, t))
        return float(np.mean(losses))

    t0 = time.perf_counter()
    results = {
        "ta_raw": avg_loss(capi.merge(taus, "task_arithmetic", lam=0.7)),
        "ta_compeft": avg_loss(capi.merge(arts, "task_arithmetic", lam=0.7)),
        "ties_raw": avg_loss(capi.merge(taus, "ties", lam=0.7, density=0.3)),
        "ties_compeft": avg_loss(capi.merge(arts, "ties", lam=0.7,
                                            density=0.3)),
        "zero": avg_loss(jax.tree_util.tree_map(jnp.zeros_like, taus[0])),
    }
    us = (time.perf_counter() - t0) * 1e6 / len(results)
    save_json("merging", results)
    emit("merging_table6", us,
         f"TA raw={results['ta_raw']:.3f} comp={results['ta_compeft']:.3f} "
         f"TIES raw={results['ties_raw']:.3f} comp={results['ties_compeft']:.3f}")


# ---------------------------------------------------------------------------
# §Pareto (Fig. 3): storage vs performance across PEFT methods
# ---------------------------------------------------------------------------


def bench_pareto(quick=False):
    cfg, api, base, lcfg, experts = setup(quick)
    task = 1
    tau = tau_of(experts, task)
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tau))
    t0 = time.perf_counter()
    results = {"lora_r4": {
        "bytes": n * 2,
        "loss": expert_eval(cfg, api, base, lcfg, experts[task][1], task)}}
    for k in (0.05, 0.2):
        th = capi.compress(tau, density=k).to_dense_tau()
        results[f"comlora_k{k}"] = {
            "bytes": golomb_total_bits(n, k) / 8,
            "loss": expert_eval(cfg, api, base, lcfg,
                                apply_tau(experts, task, th), task)}
    # IA3 expert trained fresh (much smaller)
    from repro.peft import apply_ia3, init_ia3
    ia3 = init_ia3(base)
    def loss_fn(ip, b):
        return api.loss_and_logits(apply_ia3(base, ip), b, RT)[0]
    g = jax.jit(jax.grad(loss_fn))
    for s in range(12 if quick else 40):
        ia3 = jax.tree_util.tree_map(
            lambda p, gg: p - 0.5 * gg, ia3,
            g(ia3, make_batch_for(cfg, s, 48, 8, task_id=task)))
    n_ia3 = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(ia3))
    results["ia3"] = {
        "bytes": n_ia3 * 2,
        "loss": eval_loss(api, apply_ia3(base, ia3), RT, cfg, task,
                          n_batches=2, seq_len=48, global_batch=8)}
    tau_i = task_vector(init_ia3(base), ia3)
    th = capi.compress(tau_i, density=0.2).to_dense_tau()
    ia3_hat = jax.tree_util.tree_map(
        lambda a, d: a + d, init_ia3(base), th)
    results["comia3_k0.2"] = {
        "bytes": golomb_total_bits(n_ia3, 0.2) / 8,
        "loss": eval_loss(api, apply_ia3(base, ia3_hat), RT, cfg, task,
                          n_batches=2, seq_len=48, global_batch=8)}
    us = (time.perf_counter() - t0) * 1e6 / len(results)
    save_json("pareto", results)
    emit("pareto_fig3", us,
         " ".join(f"{k}:{v['bytes']:.0f}B/{v['loss']:.3f}"
                  for k, v in results.items()))


# ---------------------------------------------------------------------------
# §CG / LoraHub (Fig. 4): compose experts for an unseen task
# ---------------------------------------------------------------------------


def bench_lorahub(quick=False):
    cfg, api, base, lcfg, experts = setup(quick)
    unseen = 100  # mixture of tasks 1-3: solvable by composition
    modules_raw = [tau_of(experts, t) for t in (1, 2, 3)]
    modules_comp = [capi.compress(t, density=0.2).to_dense_tau()
                    for t in modules_raw]

    def few_shot_loss(tau_comb):
        lora_c = apply_tau(experts, 1, tau_comb)
        merged = apply_lora(base, lora_c, lcfg)
        b = make_batch_for(cfg, 0, 48, 8, task_id=unseen)
        return float(api.loss_and_logits(merged, b, RT)[0])

    t0 = time.perf_counter()
    iters = 15 if quick else 40
    w_raw, l_raw = lorahub_search(modules_raw, few_shot_loss, n_iters=iters,
                                  seed=0)
    w_comp, l_comp = lorahub_search(modules_comp, few_shot_loss,
                                    n_iters=iters, seed=0)
    zero = few_shot_loss(jax.tree_util.tree_map(jnp.zeros_like,
                                                modules_raw[0]))
    us = (time.perf_counter() - t0) * 1e6 / 2
    save_json("lorahub", {"loss_raw": l_raw, "loss_comp": l_comp,
                          "loss_zero": zero, "w_raw": list(w_raw),
                          "w_comp": list(w_comp)})
    emit("lorahub_fig4", us,
         f"zero={zero:.3f} raw={l_raw:.3f} compeft={l_comp:.3f}")


# ---------------------------------------------------------------------------
# Kernel micro-benchmarks (wall time of the jitted paths)
# ---------------------------------------------------------------------------


def bench_kernels(quick=False):
    from repro.core.compeft import CompressedTensor
    from repro.core.packing import pack_ternary
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    M = 256 if quick else 512
    signs = jnp.asarray(rng.integers(-1, 2, (M, M)), jnp.int8)
    pt = pack_ternary(CompressedTensor(signs=signs, scale=jnp.float32(0.5)))
    base = jnp.asarray(rng.normal(0, 1, (M, M)), jnp.bfloat16)
    x = jnp.asarray(rng.normal(0, 1, (8, M)), jnp.float32)

    def timeit(f, *a, n=3):
        f(*a)  # compile
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(f(*a))
        return (time.perf_counter() - t0) / n * 1e6

    emit("kernel_unpack_add", timeit(ops.apply_ternary_delta, base, pt),
         f"{M}x{M} interpret={ops.INTERPRET}")
    emit("kernel_ternary_matmul", timeit(ops.ternary_matvec, x, pt),
         f"8x{M}x{M}")
    emit("kernel_expert_dot", timeit(ops.expert_dot, pt, pt),
         f"{M * M}params")
    thr = jnp.float32(0.5)
    tau = jnp.asarray(rng.normal(0, 1, (M, M)), jnp.float32)
    emit("kernel_pack", timeit(ops.compress_to_planes, tau, thr),
         f"{M}x{M}")


BENCHES = [bench_compression_ratio, bench_ablation, bench_alpha_sweep,
           bench_transmission_latency, bench_merging, bench_pareto,
           bench_lorahub, bench_kernels]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for b in BENCHES:
        if args.only and args.only not in b.__name__:
            continue
        b(args.quick)
        jax.clear_caches()  # bound JIT-artifact memory across benches
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "summary.csv"), "w") as f:
        f.write("name,us_per_call,derived\n" + "\n".join(ROWS) + "\n")


if __name__ == "__main__":
    main()
