"""SIGKILL child for ``perf_lab --exp chaos_restart``.

Serves one chaos_restart scenario with per-chunk snapshots, then kills
its own process — ``SIGKILL``, so no atexit handler, no buffered flush,
no __del__ runs — from a chunk hook at the requested chunk index.  The
parent asserts the death was by signal and resumes from whatever the
journal/snapshot machinery made durable before the kill.

Usage: ``restart_child.py <snapshot_dir> <scenario> <kill_at> <smoke>``

Exits 3 if the run completes without being killed (kill_at was past the
end of the workload) so the parent can distinguish that from a crash.
"""

import os
import signal
import sys


def main() -> int:
    snap_dir, scenario, kill_at, smoke = sys.argv[1:5]
    kill_at = int(kill_at)
    smoke = bool(int(smoke))

    # Env BEFORE jax (via perf_lab) imports: the mesh scenario needs 8
    # forced host devices, everything else runs single-device.
    ndev = 8 if "mesh" in scenario else 1
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={ndev}"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

    from benchmarks.perf_lab import _restart_setup

    from repro import api as capi

    api, rt, base, reg, mk_reqs, engine_kw = _restart_setup(scenario, smoke)
    eng = capi.serve(api, rt, base, reg, snapshot_dir=snap_dir,
                     snapshot_every_chunks=1, **engine_kw)

    def die(i):
        if i == kill_at:
            os.kill(os.getpid(), signal.SIGKILL)

    eng.chunk_hooks.append(die)
    eng.run(mk_reqs())
    return 3          # survived: kill_at never fired


if __name__ == "__main__":
    sys.exit(main())
