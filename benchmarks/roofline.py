"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

    PYTHONPATH=src python -m benchmarks.roofline [--mesh pod16x16] [--md]

Per (arch x shape) cell:
  compute term     t_c  = HLO_dot_FLOPs_per_device / peak_FLOPs
  memory term      t_m  = HLO_bytes_per_device / HBM_bw
  collective term  t_x  = collective_wire_bytes_per_device / link_bw
  bottleneck       argmax(t_c, t_m, t_x)
  MODEL_FLOPS      6*N*D (train) or 2*N_active*tokens (serve), N from config
  useful ratio     MODEL_FLOPS / (HLO_FLOPs * chips)  — remat/redundancy waste
  roofline frac    t_model / max(t_c, t_m, t_x) — MFU bound if perfectly
                   overlapped (the §Perf score)
"""

from __future__ import annotations

import argparse
import glob
import json
import os

HW = dict(peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)
RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def model_flops_of(rec: dict) -> float:
    """Useful (algorithmic) FLOPs for the whole step, global."""
    n_act = rec["active_param_count"]
    if rec["kind"] == "train":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 6.0 * n_act * tokens
    if rec["kind"] == "prefill":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * rec["global_batch"]


def analyze_record(rec: dict) -> dict:
    chips = rec["n_devices"]
    t_c = rec["flops"] / HW["peak_flops"]
    t_m = rec["bytes_accessed"] / HW["hbm_bw"]
    t_x = rec["collectives"]["total"] / HW["link_bw"]
    t_model = model_flops_of(rec) / (chips * HW["peak_flops"])
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    bound = max(t_c, t_m, t_x)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "kind": rec["kind"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "bottleneck": dom,
        "model_flops": model_flops_of(rec),
        "hlo_flops_global": rec["flops"] * chips,
        "useful_ratio": model_flops_of(rec) / max(rec["flops"] * chips, 1.0),
        "roofline_frac": t_model / max(bound, 1e-30),
        "hbm_gib_per_device": (rec["memory"]["argument_bytes"]
                               + rec["memory"]["temp_bytes"]) / 2 ** 30,
        "compile_s": rec["compile_s"],
    }


SUGGEST = {
    "compute": "cut redundant FLOPs (remat policy, causal-schedule waste, "
               "capacity factor) or raise arithmetic intensity per chip",
    "memory": "fuse/window the dominant tensor traffic (cache layout, "
              "bf16 accumulators, smaller flash tiles)",
    "collective": "reshard to shrink the dominant collective (FSDP gather "
                  "granularity, compressed cross-pod exchange, TP extent)",
}


def load(mesh: str) -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(RESULTS, mesh, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def render(mesh: str, md: bool = True) -> str:
    rows = [analyze_record(r) for r in load(mesh)]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = []
    hdr = ("| arch | shape | t_comp(s) | t_mem(s) | t_coll(s) | bound | "
           "useful | roofline | HBM GiB |")
    out.append(f"### Roofline — mesh {mesh} "
               f"(v5e: {HW['peak_flops']/1e12:.0f} TF/s, "
               f"{HW['hbm_bw']/1e9:.0f} GB/s HBM, {HW['link_bw']/1e9:.0f} "
               "GB/s link)")
    out.append(hdr)
    out.append("|" + "---|" * 9)
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.3f} | {r['hbm_gib_per_device']:.1f} |")
    # per-cell guidance
    out.append("")
    for r in rows:
        out.append(f"- **{r['arch']}/{r['shape']}** — bound: "
                   f"{r['bottleneck']}; {SUGGEST[r['bottleneck']]}.")
    return "\n".join(out)


def hillclimb_candidates(mesh: str) -> dict:
    rows = [analyze_record(r) for r in load(mesh)]
    if not rows:
        return {}
    worst = min(rows, key=lambda r: r["roofline_frac"])
    coll = max(rows, key=lambda r: r["t_collective_s"]
               / max(r["t_compute_s"], r["t_memory_s"], 1e-30))
    return {"worst_roofline": (worst["arch"], worst["shape"]),
            "most_collective_bound": (coll["arch"], coll["shape"])}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    if args.json:
        rows = [analyze_record(r) for r in load(args.mesh)]
        print(json.dumps(rows, indent=1))
    else:
        print(render(args.mesh))
        print()
        print("hillclimb candidates:", hillclimb_candidates(args.mesh))


if __name__ == "__main__":
    main()
