"""Perf hillclimbing lab (EXPERIMENTS.md §Perf).

Lowers dry-run cells with experiment knobs (sharding overrides, remat
policy, compression on/off, kernel form switches) and records the roofline
deltas, so every hypothesis -> change -> measure cycle is reproducible:

    PYTHONPATH=src python -m benchmarks.perf_lab --exp <name>

Each experiment writes benchmarks/results/perf/<name>.json.
"""

from __future__ import annotations

# XLA device count must be set before jax import (same rule as dryrun)
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

OUT = os.path.join(os.path.dirname(__file__), "results", "perf")


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             tcfg_override=None, cfg_override=None, rt_override=None,
             tag: str = "") -> dict:
    """lower_cell with knob injection."""
    import repro.launch.dryrun as dr
    from repro.configs import get_config
    from repro.configs.registry import normalize

    orig_train_cfg = dr._train_cfg_for
    orig_get = dr.get_config
    orig_runtime = dr.make_runtime
    try:
        if tcfg_override:
            def patched_tcfg(cfg, shape_, mp=False):
                t = orig_train_cfg(cfg, shape_, mp)
                return dataclasses.replace(t, **tcfg_override)
            dr._train_cfg_for = patched_tcfg
        if cfg_override:
            def patched_get(a):
                c = orig_get(a)
                if normalize(a) == normalize(arch):
                    c = dataclasses.replace(c, **cfg_override)
                return c
            dr.get_config = patched_get
        if rt_override:
            def patched_rt(mesh, cfg, gb=None):
                rt = orig_runtime(mesh, cfg, gb)
                return dataclasses.replace(rt, **rt_override)
            dr.make_runtime = patched_rt
        res = dr.lower_cell(arch, shape, multi_pod, extra_tags=tag)
    finally:
        dr._train_cfg_for = orig_train_cfg
        dr.get_config = orig_get
        dr.make_runtime = orig_runtime
    res["tag"] = tag
    return res


def summarize(res: dict) -> dict:
    from benchmarks.roofline import analyze_record
    a = analyze_record(res)
    a["collective_kinds"] = {k: v for k, v in res["collectives"].items()
                             if k not in ("ops", "total")}
    a["tag"] = res.get("tag", "")
    return a


def save(name: str, records: list):
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, f"{name}.json"), "w") as f:
        json.dump(records, f, indent=1, default=float)
    for r in records:
        print(f"[{r['tag']:>28s}] comp={r['t_compute_s']:.3e}s "
              f"mem={r['t_memory_s']:.3e}s coll={r['t_collective_s']:.3e}s "
              f"bound={r['bottleneck']} roofline={r['roofline_frac']:.4f}")


# ---------------------------------------------------------------------------
# Experiments
# ---------------------------------------------------------------------------


def exp_compression_ablation():
    """Paper-representative cell: multi-pod train with the EF-ternary
    cross-pod exchange ON (beyond-paper) vs OFF (paper-faithful dense DP
    baseline).  Hypothesis: compression cuts cross-pod wire bytes ~16x and
    the total collective term measurably."""
    rows = []
    for on, tag in ((False, "dense-crosspod-baseline"),
                    (True, "ef-ternary-crosspod")):
        from repro.core.gradient_compression import GradCompressionConfig
        r = run_cell("qwen3_32b", "train_4k", multi_pod=True,
                     tcfg_override={"grad_compression":
                                    GradCompressionConfig(enabled=on,
                                                          density=0.05)},
                     tag=tag)
        rows.append(summarize(r))
    save("compression_ablation", rows)


def exp_rwkv_chunk():
    """rwkv6 train is the worst-roofline cell: the chunked time-mix
    materialises a [B,L,L,H,dh] decay tensor.  Hypothesis: the matmul-form
    intra-chunk product (stabilised exp factored into the operands) plus a
    smaller chunk cuts the memory term by ~L/dh."""
    rows = []
    for impl, chunk, tag in (("einsum", 64, "baseline-einsum-L64"),
                             ("matmul", 64, "matmul-form-L64"),
                             ("matmul", 32, "matmul-form-L32"),
                             ("matmul", 128, "matmul-form-L128")):
        r = run_cell("rwkv6_3b", "train_4k",
                     rt_override={"rwkv_chunk": chunk,
                                  "rwkv_impl": impl},
                     tag=tag)
        rows.append(summarize(r))
    save("rwkv_chunk", rows)


def exp_llama4_prefill():
    """Most collective-bound cell.  Hypotheses tested:
    h1: replicated-attention (head_tp=False) causes per-layer activation
        all-gathers -> padded head-TP (40 heads over 16 shards) trades 20%
        pad compute for removing them.
    h2: remat policy 'none' (prefill has no backward) — the unit-remat
        wrapper is wasted here."""
    from repro.configs.base import ShardingOverrides
    rows = []
    r = run_cell("llama4_maverick_400b", "prefill_32k", tag="baseline")
    rows.append(summarize(r))
    r = run_cell("llama4_maverick_400b", "prefill_32k",
                 cfg_override={"sharding": ShardingOverrides(
                     head_tp=True, expert_parallel=True)},
                 tag="padded-head-tp")
    rows.append(summarize(r))
    r = run_cell("llama4_maverick_400b", "prefill_32k",
                 rt_override={"remat_policy": "none"}, tag="no-remat")
    rows.append(summarize(r))
    save("llama4_prefill", rows)


EXPS = {
    "compression_ablation": exp_compression_ablation,
    "rwkv_chunk": exp_rwkv_chunk,
    "llama4_prefill": exp_llama4_prefill,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", required=True, choices=list(EXPS) + ["all"])
    args = ap.parse_args()
    if args.exp == "all":
        for f in EXPS.values():
            f()
    else:
        EXPS[args.exp]()


if __name__ == "__main__":
    main()
