"""Perf hillclimbing lab (EXPERIMENTS.md §Perf).

Lowers dry-run cells with experiment knobs (sharding overrides, remat
policy, compression on/off, kernel form switches) and records the roofline
deltas, so every hypothesis -> change -> measure cycle is reproducible:

    PYTHONPATH=src python -m benchmarks.perf_lab --exp <name>

Each experiment writes benchmarks/results/perf/<name>.json.
"""

from __future__ import annotations

# XLA device count must be set before jax import (same rule as dryrun) —
# and scoped PER EXPERIMENT: the dry-run lowering experiments emulate the
# full 512-chip production pod, the sharded serving sweep needs the
# 8-device forced-host mesh, and everything else is single-device (a
# forced 512-device view makes eager CPU jax dispatch pathologically
# slow, which used to tax every serving/transport experiment).
import os
import sys

_POD_EXPS = ("compression_ablation", "rwkv_chunk", "llama4_prefill", "all")


def _device_count_for(argv) -> int:
    exp = None
    for i, a in enumerate(argv):
        if a == "--exp" and i + 1 < len(argv):
            exp = argv[i + 1]
        elif a.startswith("--exp="):
            exp = a.split("=", 1)[1]
    if exp in _POD_EXPS:
        return 512
    if exp in ("sharded_serve", "chaos_restart"):
        return 8
    return 1


os.environ.setdefault(
    "XLA_FLAGS",
    f"--xla_force_host_platform_device_count={_device_count_for(sys.argv)}")

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

OUT = os.path.join(os.path.dirname(__file__), "results", "perf")


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             tcfg_override=None, cfg_override=None, rt_override=None,
             tag: str = "") -> dict:
    """lower_cell with knob injection."""
    import repro.launch.dryrun as dr
    from repro.configs import get_config
    from repro.configs.registry import normalize

    orig_train_cfg = dr._train_cfg_for
    orig_get = dr.get_config
    orig_runtime = dr.make_runtime
    try:
        if tcfg_override:
            def patched_tcfg(cfg, shape_, mp=False):
                t = orig_train_cfg(cfg, shape_, mp)
                return dataclasses.replace(t, **tcfg_override)
            dr._train_cfg_for = patched_tcfg
        if cfg_override:
            def patched_get(a):
                c = orig_get(a)
                if normalize(a) == normalize(arch):
                    c = dataclasses.replace(c, **cfg_override)
                return c
            dr.get_config = patched_get
        if rt_override:
            def patched_rt(mesh, cfg, gb=None):
                rt = orig_runtime(mesh, cfg, gb)
                return dataclasses.replace(rt, **rt_override)
            dr.make_runtime = patched_rt
        res = dr.lower_cell(arch, shape, multi_pod, extra_tags=tag)
    finally:
        dr._train_cfg_for = orig_train_cfg
        dr.get_config = orig_get
        dr.make_runtime = orig_runtime
    res["tag"] = tag
    return res


def summarize(res: dict) -> dict:
    from benchmarks.roofline import analyze_record
    a = analyze_record(res)
    a["collective_kinds"] = {k: v for k, v in res["collectives"].items()
                             if k not in ("ops", "total")}
    a["tag"] = res.get("tag", "")
    return a


def save_raw(name: str, records: list):
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, f"{name}.json"), "w") as f:
        json.dump(records, f, indent=1, default=float)


def save(name: str, records: list):
    save_raw(name, records)
    for r in records:
        print(f"[{r['tag']:>28s}] comp={r['t_compute_s']:.3e}s "
              f"mem={r['t_memory_s']:.3e}s coll={r['t_collective_s']:.3e}s "
              f"bound={r['bottleneck']} roofline={r['roofline_frac']:.4f}")


def bench_update(fname: str, key: str, rec: dict):
    """Merge one experiment's record into a repo-root BENCH_*.json snapshot
    keyed by experiment, preserving the other experiments' entries (so
    e.g. BENCH_serve.json carries mixed_serve AND decode_loop side by
    side).  Legacy single-record snapshots are lifted under their tag."""
    path = os.path.join(os.path.dirname(__file__), "..", fname)
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            data = {}
    if "tag" in data:                      # legacy layout: one bare record
        data = {data["tag"]: data}
    data[key] = rec
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float)


# ---------------------------------------------------------------------------
# Experiments
# ---------------------------------------------------------------------------


def exp_compression_ablation():
    """Paper-representative cell: multi-pod train with the EF-ternary
    cross-pod exchange ON (beyond-paper) vs OFF (paper-faithful dense DP
    baseline).  Hypothesis: compression cuts cross-pod wire bytes ~16x and
    the total collective term measurably."""
    rows = []
    for on, tag in ((False, "dense-crosspod-baseline"),
                    (True, "ef-ternary-crosspod")):
        from repro.core.gradient_compression import GradCompressionConfig
        r = run_cell("qwen3_32b", "train_4k", multi_pod=True,
                     tcfg_override={"grad_compression":
                                    GradCompressionConfig(enabled=on,
                                                          density=0.05)},
                     tag=tag)
        rows.append(summarize(r))
    save("compression_ablation", rows)


def exp_rwkv_chunk():
    """rwkv6 train is the worst-roofline cell: the chunked time-mix
    materialises a [B,L,L,H,dh] decay tensor.  Hypothesis: the matmul-form
    intra-chunk product (stabilised exp factored into the operands) plus a
    smaller chunk cuts the memory term by ~L/dh."""
    rows = []
    for impl, chunk, tag in (("einsum", 64, "baseline-einsum-L64"),
                             ("matmul", 64, "matmul-form-L64"),
                             ("matmul", 32, "matmul-form-L32"),
                             ("matmul", 128, "matmul-form-L128")):
        r = run_cell("rwkv6_3b", "train_4k",
                     rt_override={"rwkv_chunk": chunk,
                                  "rwkv_impl": impl},
                     tag=tag)
        rows.append(summarize(r))
    save("rwkv_chunk", rows)


def exp_llama4_prefill():
    """Most collective-bound cell.  Hypotheses tested:
    h1: replicated-attention (head_tp=False) causes per-layer activation
        all-gathers -> padded head-TP (40 heads over 16 shards) trades 20%
        pad compute for removing them.
    h2: remat policy 'none' (prefill has no backward) — the unit-remat
        wrapper is wasted here."""
    from repro.configs.base import ShardingOverrides
    rows = []
    r = run_cell("llama4_maverick_400b", "prefill_32k", tag="baseline")
    rows.append(summarize(r))
    r = run_cell("llama4_maverick_400b", "prefill_32k",
                 cfg_override={"sharding": ShardingOverrides(
                     head_tp=True, expert_parallel=True)},
                 tag="padded-head-tp")
    rows.append(summarize(r))
    r = run_cell("llama4_maverick_400b", "prefill_32k",
                 rt_override={"remat_policy": "none"}, tag="no-remat")
    rows.append(summarize(r))
    save("llama4_prefill", rows)


def _time(fn, reps=3):
    """Best-of-reps wall time; blocks on all jax leaves."""
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(jax.tree_util.tree_leaves(
            out, is_leaf=lambda x: hasattr(x, "pos")))
        best = min(best, time.perf_counter() - t0)
    return best, out


def _synth_expert(n_params=50_000_000, seed=0):
    """Synthetic >=50M-param task vector shaped like a transformer block."""
    rng = np.random.default_rng(seed)
    d = 4096
    tau, total, i = {}, 0, 0
    while total < n_params:
        tau[f"blocks/block{i}/w"] = jnp.asarray(
            rng.normal(0, 0.02, (d, d)).astype(np.float32))
        total += d * d
        i += 1
    return tau, total


def exp_compress_swap():
    """Tentpole measurement: single-pass streaming compression vs the seed
    per-leaf quantile path, and packed-resident vs dense-resident expert
    capacity/swap parity, on CPU interpret mode — expert lifecycle through
    ``repro.api`` (method='exact' is the seed path, 'streaming' the PR-1
    pipeline)."""
    from repro import api as capi
    from repro.expert import PACKED
    from repro.kernels.ops import apply_ternary_delta_flat

    density, alpha = 0.05, 1.0
    tau, n_params = _synth_expert()
    rec = {"tag": "compress_swap", "n_params": n_params,
           "density": density}

    # --- compression throughput: seed per-leaf loop vs streaming ---------
    t_seed, packed_seed = _time(
        lambda: capi.compress(tau, density=density, alpha=alpha,
                              method="exact").as_(PACKED), reps=2)
    t_stream, packed_new = _time(
        lambda: capi.compress(tau, density=density, alpha=alpha,
                              method="streaming").as_(PACKED), reps=2)
    rec["compress_seed_s"] = t_seed
    rec["compress_stream_s"] = t_stream
    rec["compress_speedup_x"] = t_seed / t_stream
    rec["compress_stream_gbps"] = n_params * 4 / t_stream / 1e9
    for k in tau:
        np.testing.assert_allclose(float(packed_new[k].scale),
                                   float(packed_seed[k].scale), rtol=1e-4)

    # --- packed-resident capacity under a fixed HBM budget ---------------
    registry = capi.registry()
    small = {k: v[:512, :512] for k, v in list(tau.items())[:2]}
    n_experts = 24
    for i in range(n_experts):
        rng = np.random.default_rng(100 + i)
        e = {k: v + jnp.asarray(rng.normal(0, 0.01, v.shape), jnp.float32)
             for k, v in small.items()}
        registry.add(capi.compress(e, name=f"e{i}", density=density,
                                   alpha=alpha))
    dense_bytes = sum(int(np.prod(v.shape)) * 4 for v in small.values())
    budget = int(dense_bytes * 1.5)        # seed layout: one dense expert
    cache = registry.device(budget)
    for i in range(n_experts):
        cache.fetch(f"e{i}")
    rec["budget_bytes"] = budget
    rec["resident_packed"] = len(cache.resident())
    rec["resident_dense_equiv"] = max(1, budget // dense_bytes)
    rec["capacity_multiplier_x"] = (rec["resident_packed"]
                                    / rec["resident_dense_equiv"])

    # --- swap latency + numerical parity: fused plane merge vs dense -----
    art = registry.get("e0")
    base = {k: jnp.asarray(np.random.default_rng(1).normal(0, 1, v.shape),
                           jnp.float32) for k, v in small.items()}

    def merge_packed():
        return {k: apply_ternary_delta_flat(base[k], art.packed[k])
                for k in base}

    def merge_dense():
        taud = art.to_dense_tau()
        return {k: (base[k].astype(jnp.float32)
                    + jnp.asarray(taud[k]).reshape(base[k].shape)
                    ).astype(base[k].dtype) for k in base}

    t_packed, merged_p = _time(merge_packed)
    t_dense, merged_d = _time(merge_dense)
    for k in base:
        np.testing.assert_array_equal(np.asarray(merged_p[k]),
                                      np.asarray(merged_d[k]))
    rec["swap_packed_s"] = t_packed
    rec["swap_dense_s"] = t_dense
    rec["swap_bitwise_identical"] = True
    rec["packed_expert_bytes"] = art.nbytes(PACKED)
    rec["dense_expert_bytes"] = dense_bytes

    save_raw("compress_swap", [rec])
    with open(os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_compress.json"), "w") as f:
        json.dump(rec, f, indent=1, default=float)
    print(f"compress: seed={t_seed:.2f}s stream={t_stream:.2f}s "
          f"({rec['compress_speedup_x']:.1f}x); "
          f"capacity: {rec['resident_packed']} packed vs "
          f"{rec['resident_dense_equiv']} dense "
          f"({rec['capacity_multiplier_x']:.0f}x); "
          f"swap: packed={t_packed*1e3:.1f}ms dense={t_dense*1e3:.1f}ms "
          f"bitwise_identical={rec['swap_bitwise_identical']}")
    assert rec["compress_speedup_x"] >= 3.0, rec["compress_speedup_x"]
    assert rec["capacity_multiplier_x"] >= 8.0, rec["capacity_multiplier_x"]


def _serve_fixture(n_experts=4, density=0.2, scale=0.02):
    """Smoke LM + ComPEFT Expert artifacts (fake fine-tunes of base)."""
    import jax
    import jax.numpy as jnp

    from repro import api as capi
    from repro.configs import get_smoke_config
    from repro.models import Runtime, build

    rt = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")
    cfg = get_smoke_config("qwen2_5_3b", n_units=1)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))
    experts = []
    for i in range(n_experts):
        leaves, tdef = jax.tree_util.tree_flatten(base)
        keys = jax.random.split(jax.random.PRNGKey(100 + i), len(leaves))
        ft = jax.tree_util.tree_unflatten(tdef, [
            (l.astype(jnp.float32)
             + scale * jax.random.normal(k, l.shape)).astype(l.dtype)
            for l, k in zip(leaves, keys)])
        experts.append(capi.compress(base, ft, name=f"expert{i}",
                                     density=density, alpha=1.0))
    return api, rt, cfg, base, experts


def exp_mixed_serve(smoke: bool = False):
    """Tentpole measurement: continuous mixed-expert zero-merge serving vs
    the PR-1 merge-on-swap path on a round-robin request stream.

    The stream interleaves 4 experts (the paper's many-experts-per-device
    scenario).  The grouped baseline must split it into per-expert batches
    and pay a full-model merge per expert; the mixed scheduler serves one
    heterogeneous wave through the grouped ternary kernels with zero
    merges.  Also checks the correctness contract: mixed-wave outputs are
    bit-identical (token-exact AND prefill-logit-exact) to serving each
    expert separately through the same zero-merge path.
    """
    import jax.numpy as jnp

    from repro import api as capi
    from repro.serve import Request

    n_experts = 4
    n_reqs = 8 if smoke else 16
    max_new = 4 if smoke else 8
    prompt_len = 12
    api, rt, cfg, base, experts = _serve_fixture(n_experts=n_experts)
    rng = np.random.default_rng(0)
    prompts = [jnp.asarray(rng.integers(1, cfg.vocab, prompt_len), jnp.int32)
               for _ in range(n_reqs)]

    def mk_reqs():
        # round-robin arrival over the expert set
        return [Request(uid=i, expert=f"expert{i % n_experts}",
                        prompt=prompts[i], max_new_tokens=max_new)
                for i in range(n_reqs)]

    def run(scheduling):
        # fresh registry per run: each engine gets its own device tier, so
        # swap stats and promotions are not shared across measurements
        eng = capi.serve(api, rt, base, capi.registry(experts=experts),
                         max_batch=n_reqs, cache_len=64,
                         scheduling=scheduling)
        # warm pass with the identical workload: compiles every step
        # executable both paths will use, so the timed pass is steady-state
        eng.run(mk_reqs())
        eng._merged_name = None    # drop the warmed merge cache
        eng._merged_params = None
        eng.swap_log.clear()
        eng.wave_log.clear()
        reqs = mk_reqs()
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = time.perf_counter() - t0
        return dt, eng, reqs

    t_grouped, eng_g, reqs_grouped = run("grouped")
    t_mixed, eng_m, reqs_mixed = run("mixed")

    tokens = n_reqs * max_new
    rec = {"tag": "mixed_serve", "n_experts": n_experts, "n_reqs": n_reqs,
           "max_new_tokens": max_new, "tokens": tokens,
           "grouped_s": t_grouped, "mixed_s": t_mixed,
           "grouped_tok_s": tokens / t_grouped,
           "mixed_tok_s": tokens / t_mixed,
           "decode_speedup_x": t_grouped / t_mixed,
           "grouped_summary": eng_g.swap_summary(),
           "mixed_summary": eng_m.swap_summary()}

    # correctness: mixed wave == sequential per-expert zero-merge serving
    reqs_seq = mk_reqs()
    eng_s = capi.serve(api, rt, base, capi.registry(experts=experts),
                       max_batch=n_reqs, cache_len=64)
    for e in range(n_experts):
        eng_s.run([r for r in reqs_seq if r.expert == f"expert{e}"])
    tok_mixed = {r.uid: r.out_tokens for r in reqs_mixed}
    tok_seq = {r.uid: r.out_tokens for r in reqs_seq}
    rec["mixed_equals_sequential"] = tok_mixed == tok_seq
    assert rec["mixed_equals_sequential"], "mixed wave diverged"

    save_raw("mixed_serve", [rec])
    bench_update("BENCH_serve.json", "mixed_serve", rec)
    print(f"serve: grouped={t_grouped:.2f}s ({rec['grouped_tok_s']:.1f} "
          f"tok/s, {rec['grouped_summary']['n_swaps']} merges) "
          f"mixed={t_mixed:.2f}s ({rec['mixed_tok_s']:.1f} tok/s, "
          f"{rec['mixed_summary']['n_waves']} waves, 0 merges); "
          f"speedup={rec['decode_speedup_x']:.2f}x; "
          f"parity={rec['mixed_equals_sequential']}")
    if not smoke:
        assert rec["decode_speedup_x"] >= 2.0, rec["decode_speedup_x"]


def exp_decode_loop(smoke: bool = False):
    """Tentpole measurement: device-resident chunked decode (scan-compiled
    wave loop, one host sync per K steps, donated KV cache) vs the eager
    per-token loop (one dispatch + one blocking ``np.asarray`` sync per
    generated token).

    Sweeps K ∈ {1, 4, 8, 16, 32} against eager at B ∈ {1, 8}, mixed and
    grouped scheduling, on a request stream with more requests than slots
    so mid-wave admissions (slot refills) are exercised.  Two gates:

    * **parity** — greedy chunked decode must reproduce the eager loop's
      tokens exactly, per request, for every (scheduling, B, K) cell,
      admissions included (asserted in smoke mode too);
    * **speedup** — ≥ 1.5x decode tokens/s over eager at B=8, K=16
      (full runs only).
    """
    import jax.numpy as jnp

    from repro import api as capi
    from repro.serve import Request

    n_experts = 4
    max_new = 8 if smoke else 32     # decode-dominated sweep workload
    adm_max_new = 8                  # admission workload: 2 fills per slot
    prompt_len = 12
    cache_len = 96
    api, rt, cfg, base, experts = _serve_fixture(n_experts=n_experts)
    rng = np.random.default_rng(0)
    prompt_pool = [jnp.asarray(rng.integers(1, cfg.vocab, prompt_len),
                               jnp.int32) for _ in range(16)]

    def mk_reqs(n, new_tokens):
        return [Request(uid=i, expert=f"expert{i % n_experts}",
                        prompt=prompt_pool[i], max_new_tokens=new_tokens)
                for i in range(n)]

    def engine(sched, B, K):
        return capi.serve(api, rt, base, capi.registry(experts=experts),
                          max_batch=B, cache_len=cache_len,
                          scheduling=sched, decode_chunk=K)

    def run_timed(sched, B, K):
        """One wave-sized batch (n_reqs = B), warm pass first, so the
        timed pass isolates steady-state decode throughput."""
        eng = engine(sched, B, K)
        eng.run(mk_reqs(B, max_new))   # warm: compiles every executable
        reqs = mk_reqs(B, max_new)
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = time.perf_counter() - t0
        return dt, {r.uid: list(r.out_tokens) for r in reqs}

    def run_admissions(sched, B, K):
        """2x oversubscribed queue: finished slots refill mid-wave."""
        eng = engine(sched, B, K)
        reqs = mk_reqs(2 * B, adm_max_new)
        eng.run(reqs)
        admitted = sum(w["admitted"] for w in eng.wave_log)
        return {r.uid: list(r.out_tokens) for r in reqs}, admitted

    scheds = ("mixed",) if smoke else ("mixed", "grouped")
    batches = (8,) if smoke else (1, 8)
    chunk_sizes = (8,) if smoke else (1, 4, 8, 16, 32)
    rows, parity = [], True
    tok_s = {}
    for sched in scheds:
        for B in batches:
            t_eager, tok_eager = run_timed(sched, B, 0)
            total = sum(len(v) for v in tok_eager.values())
            tok_s[(sched, B, 0)] = total / t_eager
            rows.append({"sched": sched, "B": B, "K": 0, "mode": "eager",
                         "tokens": total, "seconds": t_eager,
                         "tok_s": total / t_eager})
            for K in chunk_sizes:
                t, toks = run_timed(sched, B, K)
                ok = toks == tok_eager
                parity = parity and ok
                tok_s[(sched, B, K)] = total / t
                rows.append({"sched": sched, "B": B, "K": K,
                             "mode": "chunked", "tokens": total,
                             "seconds": t, "tok_s": total / t,
                             "speedup_vs_eager_x": t_eager / t,
                             "token_parity_vs_eager": ok})
                print(f"[{sched:>7s} B={B} K={K:>2d}] "
                      f"{total / t:8.1f} tok/s "
                      f"({t_eager / t:4.2f}x eager) parity={ok}")

    # parity gate WITH mid-wave admissions: greedy chunked decode must
    # reproduce the eager loop's per-request tokens exactly while slots
    # are being refilled (spliced prefills folded into the device state)
    adm_B = 8
    adm_parity = True
    for sched in scheds:
        tok_eager, _ = run_admissions(sched, adm_B, 0)
        for K in chunk_sizes:
            toks, admitted = run_admissions(sched, adm_B, K)
            ok = toks == tok_eager
            adm_parity = adm_parity and ok
            print(f"[{sched:>7s} admissions K={K:>2d}] refills={admitted} "
                  f"parity={ok}")

    gate_B, gate_K = (8, 8) if smoke else (8, 16)
    speedup = tok_s[("mixed", gate_B, gate_K)] / tok_s[("mixed", gate_B, 0)]
    rec = {"tag": "decode_loop", "n_experts": n_experts,
           "max_new_tokens": max_new, "prompt_len": prompt_len,
           "rows": rows, "token_parity": parity,
           "admission_token_parity": adm_parity,
           "gate": {"B": gate_B, "K": gate_K,
                    "speedup_vs_eager_x": speedup}}
    save_raw("decode_loop", [rec])
    bench_update("BENCH_serve.json", "decode_loop", rec)
    print(f"decode_loop: parity={parity} (admissions: {adm_parity}); "
          f"chunked K={gate_K} B={gate_B} is {speedup:.2f}x eager decode")
    assert parity, "chunked decode diverged from the eager loop"
    assert adm_parity, "chunked decode diverged under mid-wave admissions"
    if not smoke:
        assert speedup >= 1.5, speedup


def exp_serve_load(smoke: bool = False):
    """Tentpole measurement: paged KV + SLO-aware scheduling under seeded
    open-loop traffic (Poisson arrivals + bursts, Zipf expert popularity,
    short/long prompt and output mix — :mod:`benchmarks.traffic`).

    Three engine configurations serve the same workload:

    * ``dense_fifo`` — left-padded KV slots + FIFO admission (the
      historical engine, parity baseline);
    * ``paged_fifo`` — block-table KV, same FIFO order;
    * ``paged_affinity`` — block-table KV + priority/deadline scheduler
      with expert-affinity wave packing (canonical stack tuples).

    Gates (smoke included unless noted):

    * **token parity** — all three produce identical per-request tokens,
      greedy AND sampled (streams are keyed by (seed, uid, draw), so they
      are invariant to KV layout, wave composition and admission timing);
    * **affinity stack hits** — the affinity scheduler's stacked-plane
      hit-rate beats FIFO's on the same Zipf traffic;
    * **determinism** — ``generate()`` replays bit-identically and a
      repeated paged_affinity run reproduces tokens and statuses;
    * **latency/throughput** (full runs only) — paged_affinity p99 TTFT
      <= dense_fifo and tokens/s >= dense_fifo at B >= 16.
    """
    from benchmarks import traffic
    from repro import api as capi

    if smoke:
        n_experts, B, max_stack = 6, 6, 3
        tcfg = traffic.TrafficConfig(
            seed=11, n_requests=24, base_rate=60.0, burst_every_s=0.2,
            burst_duration_s=0.05, burst_rate_x=4.0, n_experts=n_experts,
            zipf_alpha=1.2, prompt_len_short=6, prompt_len_long=24,
            long_frac=0.25, max_new_short=4, max_new_long=8,
            long_out_frac=0.25, vocab=512)
        cache_len = 48
    else:
        n_experts, B, max_stack = 8, 16, 4
        tcfg = traffic.TrafficConfig(
            seed=11, n_requests=96, base_rate=24.0, burst_every_s=2.0,
            burst_duration_s=0.5, burst_rate_x=4.0, n_experts=n_experts,
            zipf_alpha=1.1, prompt_len_short=6, prompt_len_long=40,
            long_frac=0.25, max_new_short=8, max_new_long=16,
            long_out_frac=0.25, vocab=512)
        cache_len = 64
    api, rt, cfg, base, experts = _serve_fixture(n_experts=n_experts)

    CONFIGS = {
        "dense_fifo": dict(kv_layout="dense", scheduler="fifo"),
        "paged_fifo": dict(kv_layout="paged", scheduler="fifo"),
        "paged_affinity": dict(kv_layout="paged", scheduler="affinity"),
    }

    def engine(name, **samp):
        kw = dict(CONFIGS[name])
        if kw["kv_layout"] == "paged":
            kw["kv_block_size"] = 8
        return capi.serve(api, rt, base, capi.registry(experts=experts),
                          max_batch=B, cache_len=cache_len,
                          max_stack=max_stack, **kw, **samp)

    def workload(immediate=False):
        reqs = traffic.generate(tcfg)
        if immediate:
            for r in reqs:
                r.arrival_s = 0.0
        return reqs

    def toks(reqs):
        return {r.uid: list(r.out_tokens) for r in reqs}

    # -- phase 1: three-way token parity, greedy and sampled -------------
    parity = {}
    for samp in ({}, {"temperature": 0.8, "top_k": 5, "seed": 7}):
        label = "sampled" if samp else "greedy"
        outs = {}
        for name in CONFIGS:
            reqs = engine(name, **samp).run(workload(immediate=True))
            outs[name] = toks(reqs)
        ok = (outs["dense_fifo"] == outs["paged_fifo"]
              == outs["paged_affinity"])
        parity[label] = ok
        print(f"[serve_load] {label} parity "
              f"dense_fifo == paged_fifo == paged_affinity: {ok}")

    # -- phase 2: timed open-loop replay (warm pass compiles first) ------
    results = {}
    for name in ("dense_fifo", "paged_affinity"):
        eng = engine(name)
        eng.run(workload(immediate=True))        # warm: compile everything
        eng.swap_log.clear()
        eng.wave_log.clear()
        eng.cache.stats.stack_hits = 0
        eng.cache.stats.stack_builds = 0
        reqs = workload()
        eng.run(reqs)
        s = eng.swap_summary()
        results[name] = {"load": traffic.summarize(reqs),
                         "stack_hit_rate": s["stack_hit_rate"],
                         "stack_hits": s.get("stack_hits", 0),
                         "stack_builds": s.get("stack_builds", 0),
                         "scheduler": s["scheduler"], "kv": s["kv"],
                         "n_waves": s["n_waves"], "admitted": s["admitted"]}
        ld = results[name]["load"]
        print(f"[serve_load] {name:>15s}: ttft p50={ld['ttft_p50_s']:.3f}s "
              f"p99={ld['ttft_p99_s']:.3f}s tok/s={ld['tokens_per_s']:.1f} "
              f"stack_hit_rate={s['stack_hit_rate']:.2f} "
              f"waves={s['n_waves']}")

    # -- phase 3: determinism -------------------------------------------
    g1, g2 = traffic.generate(tcfg), traffic.generate(tcfg)
    gen_ok = all(
        a.uid == b.uid and a.expert == b.expert
        and a.arrival_s == b.arrival_s and a.priority == b.priority
        and a.deadline_s == b.deadline_s
        and a.max_new_tokens == b.max_new_tokens
        and np.array_equal(np.asarray(a.prompt), np.asarray(b.prompt))
        for a, b in zip(g1, g2)) and len(g1) == len(g2)
    ra = engine("paged_affinity").run(workload())
    rb = engine("paged_affinity").run(workload())
    replay_ok = (toks(ra) == toks(rb)
                 and [r.status for r in ra] == [r.status for r in rb])
    print(f"[serve_load] generator determinism={gen_ok} "
          f"replay determinism={replay_ok}")

    rec = {"tag": "serve_load", "smoke": smoke, "n_experts": n_experts,
           "max_batch": B, "max_stack": max_stack,
           "traffic": dataclasses.asdict(tcfg),
           "token_parity": parity, "generator_deterministic": gen_ok,
           "replay_deterministic": replay_ok, "results": results}
    save_raw("serve_load", [rec])
    bench_update("BENCH_serve.json", "serve_load", rec)

    assert parity["greedy"], "paged/scheduled engines diverged (greedy)"
    assert parity["sampled"], "paged/scheduled engines diverged (sampled)"
    assert gen_ok, "traffic generator is not deterministic"
    assert replay_ok, "seeded replay is not deterministic"
    hit_fifo = results["dense_fifo"]["stack_hit_rate"]
    hit_aff = results["paged_affinity"]["stack_hit_rate"]
    if smoke:
        assert hit_aff >= hit_fifo, (hit_aff, hit_fifo)
    else:
        assert hit_aff > hit_fifo, (hit_aff, hit_fifo)
        ld_d = results["dense_fifo"]["load"]
        ld_a = results["paged_affinity"]["load"]
        assert ld_a["ttft_p99_s"] <= ld_d["ttft_p99_s"], (ld_a, ld_d)
        assert ld_a["tokens_per_s"] >= ld_d["tokens_per_s"], (ld_a, ld_d)


def exp_remote_fetch(smoke: bool = False):
    """Tentpole measurement: the paper's communication-cost argument as a
    measured curve.

    Publishes experts through a :class:`SimulatedNetworkTransport` and
    sweeps wire representation (DENSE bf16 baseline / PACKED bitplanes /
    GOLOMB streams) x link speed, measuring bytes-on-wire and
    **time-to-first-token**: a cold request whose expert must be fetched
    over the link before the wave can prefill.  Per configuration the
    engine is first warmed on a different expert (same shapes), so the
    timed run isolates fetch + decode + promote + prefill — not XLA
    compilation.  Gate: GOLOMB TTFT beats DENSE on the slow link, and the
    fetched planes are bit-identical to the locally built ones.
    """
    import jax.numpy as jnp

    from repro import api as capi
    from repro.expert import DENSE, GOLOMB, PACKED
    from repro.serve import Request
    from repro.transport import InMemoryTransport, SimulatedNetworkTransport

    prompt_len = 12
    api, rt, cfg, base, experts = _serve_fixture(n_experts=3)
    ref_packed = {e.name: e.packed for e in experts}
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(1, cfg.vocab, prompt_len), jnp.int32)

    # The slow link is a ~2 Mbit/s high-latency consumer line — the regime
    # the paper's retrieval-over-the-network claim targets.  On this
    # fixture the dense bf16 blob takes ~0.5 s of pure transfer there,
    # so TTFT differences dwarf CPU timing noise.
    links = {"slow": dict(bandwidth_bps=0.25e6, latency_s=0.1),
             "fast": dict(bandwidth_bps=1e9, latency_s=0.002)}
    rows = []
    identical = True
    for rep in (DENSE, PACKED, GOLOMB):
        inner = InMemoryTransport()
        pubs = {e.name: inner.publish(e, rep=rep) for e in experts}
        for link, lp in links.items():
            tr = SimulatedNetworkTransport(inner=inner, seed=0, **lp)
            reg = capi.registry(transport=tr)
            eng = capi.serve(api, rt, base, reg, max_batch=1, cache_len=64)
            # warm: compiles prefill/decode on expert0's (identical) shapes
            eng.run([Request(uid=0, expert="expert0", prompt=prompt,
                             max_new_tokens=1)])
            # TTFT = cold request whose expert must cross the link first;
            # best-of-2 over two distinct cold experts to shed CPU noise
            ttft, first_token = float("inf"), None
            for uid, cold in ((1, "expert1"), (2, "expert2")):
                r = Request(uid=uid, expert=cold, prompt=prompt,
                            max_new_tokens=1)
                t0 = time.perf_counter()
                eng.run([r])
                dt = time.perf_counter() - t0
                if dt < ttft:
                    ttft, first_token = dt, list(r.out_tokens)
            fetched = reg.get("expert1").packed
            for p, pt in ref_packed["expert1"].items():
                ok = ((np.asarray(pt.pos) == np.asarray(fetched[p].pos)).all()
                      and (np.asarray(pt.neg)
                           == np.asarray(fetched[p].neg)).all()
                      and float(pt.scale) == float(fetched[p].scale))
                identical = identical and bool(ok)
            reg.close()           # stop this config's prefetch workers
            rows.append({"rep": rep, "link": link,
                         "bytes_on_wire": pubs["expert1"]["nbytes"],
                         "ttft_s": ttft,
                         "link_bandwidth_bps": lp["bandwidth_bps"],
                         "link_latency_s": lp["latency_s"],
                         "first_token": first_token})
            print(f"[{rep:>6s} | {link:>4s}] "
                  f"wire={rows[-1]['bytes_on_wire']:>9,d} B  "
                  f"ttft={ttft*1e3:8.1f} ms")

    by = {(r["rep"], r["link"]): r for r in rows}
    rec = {"tag": "remote_fetch", "rows": rows,
           "bit_identical": identical,
           "golomb_vs_dense_wire_x": (by[(DENSE, "slow")]["bytes_on_wire"]
                                      / by[(GOLOMB, "slow")]["bytes_on_wire"]),
           "golomb_vs_dense_slow_ttft_x": (by[(DENSE, "slow")]["ttft_s"]
                                           / by[(GOLOMB, "slow")]["ttft_s"])}
    save_raw("remote_fetch", [rec])
    bench_update("BENCH_transport.json", "remote_fetch", rec)
    print(f"remote_fetch: golomb wire is "
          f"{rec['golomb_vs_dense_wire_x']:.1f}x smaller than dense; "
          f"slow-link TTFT {rec['golomb_vs_dense_slow_ttft_x']:.2f}x faster; "
          f"bit_identical={identical}")
    assert identical, "fetched expert diverged from local planes"
    assert rec["golomb_vs_dense_slow_ttft_x"] > 1.0, rec
    if not smoke:
        assert rec["golomb_vs_dense_wire_x"] >= 8.0, rec


def exp_chaos_serve(smoke: bool = False):
    """Robustness gate: serving under an injected fault schedule.

    Publishes 4 experts through a :class:`ChaosTransport` whose schedule
    injects one timeout (expert1), one payload bit-flip (expert2) and a
    persistent replica blackout (expert3) into a round-robin request
    stream, with a 1-failure quarantine trip.  Gates (all deterministic
    under the seed):

    * every healthy request completes with tokens **bit-identical** to
      the no-fault run — transient faults are absorbed by retry/refetch
      without touching decode results;
    * every expert3 request ends in the terminal ``FAILED`` status with
      error detail, returned via the normal results path (the engine
      degrades per-request instead of crashing the wave);
    * ``SwapStats`` match the schedule exactly: 5 transport retries
      (1 timeout + 1 checksum refetch + 3 blackout retries), 1 quarantine
      trip, ≥1 prefetch error — and a second chaos run reproduces the
      same tokens, statuses and fired-fault log bit-for-bit.
    """
    import jax.numpy as jnp

    from repro import api as capi
    from repro.serve import DONE, FAILED, Request
    from repro.transport import (ChaosFault, ChaosTransport,
                                 InMemoryTransport)

    n_experts = 4
    n_reqs = 8 if smoke else 16
    max_new = 4 if smoke else 8
    # full mode serves two waves of 8, so the second wave's expert3 rows
    # arrive through the continuous-admission path while quarantined
    max_batch = 8
    prompt_len = 8
    api, rt, cfg, base, experts = _serve_fixture(n_experts=n_experts)
    rng = np.random.default_rng(0)
    prompts = [jnp.asarray(rng.integers(1, cfg.vocab, prompt_len), jnp.int32)
               for _ in range(n_reqs)]

    def mk_reqs():
        return [Request(uid=i, expert=f"expert{i % n_experts}",
                        prompt=prompts[i], max_new_tokens=max_new)
                for i in range(n_reqs)]

    schedule = [ChaosFault("expert1", 0, "timeout"),
                ChaosFault("expert2", 0, "bitflip")]

    def run(chaotic):
        inner = InMemoryTransport()
        for e in experts:
            capi.publish(e, inner)
        tr = (ChaosTransport(inner, faults=schedule, blackout=["expert3"],
                             seed=0) if chaotic else inner)
        reg = capi.registry(transport=tr, quarantine_after=1,
                            quarantine_probe_s=1000.0)
        eng = capi.serve(api, rt, base, reg, max_batch=max_batch,
                         cache_len=64)
        reqs = mk_reqs()
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = time.perf_counter() - t0
        reg.close()
        return dt, eng, reqs, tr

    t_base, eng_b, base_reqs, _ = run(chaotic=False)
    assert all(r.status == DONE for r in base_reqs)
    base_toks = {r.uid: list(r.out_tokens) for r in base_reqs}

    t_chaos, eng_c, reqs, tr = run(chaotic=True)
    healthy = [r for r in reqs if r.expert != "expert3"]
    dead = [r for r in reqs if r.expert == "expert3"]
    stats = eng_c.swap_summary()
    parity = all(r.out_tokens == base_toks[r.uid] for r in healthy)

    # determinism: an identical chaos run reproduces everything.  The
    # fired log is compared order-independently: per-name fault order is
    # deterministic (per-name fetch counters), but the prefetch pool may
    # interleave fetches of DIFFERENT names either way round.
    def fired_sorted(t):
        return sorted(t.fired(), key=lambda f: (f["name"], f["fetch"]))

    _, eng_c2, reqs2, tr2 = run(chaotic=True)
    reproduced = (
        [(r.uid, r.status, list(r.out_tokens)) for r in reqs]
        == [(r.uid, r.status, list(r.out_tokens)) for r in reqs2]
        and fired_sorted(tr) == fired_sorted(tr2)
        and {k: eng_c2.swap_summary()[k]
             for k in ("retries", "quarantines", "failed")}
        == {k: stats[k] for k in ("retries", "quarantines", "failed")})

    rec = {"tag": "chaos_serve", "n_reqs": n_reqs, "max_batch": max_batch,
           "max_new_tokens": max_new, "baseline_s": t_base,
           "chaos_s": t_chaos,
           "healthy": len(healthy), "failed": len(dead),
           "healthy_bit_identical": parity,
           "all_failed_typed": all(r.status == FAILED and r.error
                                   and not r.out_tokens for r in dead),
           "retries": stats["retries"],
           "quarantines": stats["quarantines"],
           "prefetch_errors": stats["prefetch_errors"],
           "fired": tr.fired(),
           "health": eng_c.registry.health(),
           "deterministic": reproduced}
    save_raw("chaos_serve", [rec])
    bench_update("BENCH_serve.json", "chaos_serve", rec)
    print(f"chaos_serve: {len(healthy)} healthy (bit_identical={parity}), "
          f"{len(dead)} failed, retries={rec['retries']}, "
          f"quarantines={rec['quarantines']}, "
          f"prefetch_errors={rec['prefetch_errors']}, "
          f"deterministic={reproduced}")
    assert all(r.status == DONE for r in healthy), rec
    assert parity, "healthy requests diverged from the no-fault run"
    assert rec["all_failed_typed"], rec
    assert stats["failed"] == len(dead) == n_reqs // n_experts, rec
    # the schedule, exactly: 1 timeout retry + 1 checksum refetch +
    # (max_attempts-1)=3 blackout retries; ONE quarantine trip keeps every
    # later expert3 fetch off the wire
    assert rec["retries"] == 5, rec
    assert rec["quarantines"] == 1, rec
    assert rec["prefetch_errors"] >= 1, rec
    assert [f["kind"] for f in rec["fired"]].count("blackout") == 4, rec
    assert reproduced, "chaos run is not reproducible under the seed"


def exp_chaos_cdn(smoke: bool = False):
    """Robustness gate: the replicated expert CDN losing a replica
    mid-fetch.

    A 3-replica heterogeneous fleet (fast / medium / slow simulated
    links, each behind a :class:`ChaosTransport`) serves a round-robin
    request stream with ``replication_factor=3``.  The *fast* replica —
    the one EWMA selection always tries first — blacks out at per-name
    op index 2: the probe and the first leaf range of every expert are
    delivered, the rest never arrive, so every fetch fails over
    **mid-blob**.  Gates (deterministic under the seeds):

    * token parity — every request completes ``DONE`` with tokens
      bit-identical to the same fleet without the fault;
    * zero-waste failover — only undelivered leaves are re-requested:
      the CDN's ``bytes_in`` equals the published bytes-on-wire exactly,
      ``bytes_wasted == 0``, and the per-replica ledgers sum to the same
      total (the new byte accounting makes this assertable);
    * exactly one failover per expert (``retries == n_experts``) and one
      ``replica_blackout`` fired per name on the dead replica;
    * an R=1 control fleet of just the faulty replica fails every
      request with a typed ``FAILED`` status (never a crashed engine);
    * a second chaos run reproduces tokens, statuses, fired logs and
      fleet byte totals bit-for-bit.

    Also measures the cold-start TTFT-vs-replica-count curve (fleet of
    R ∈ {1, 2, 3} slowest-first links, hedged and unhedged, cold and
    EWMA-probed) and merges it into ``BENCH_transport.json``.
    """
    import jax.numpy as jnp

    from repro import api as capi
    from repro.expert import PACKED
    from repro.serve import DONE, FAILED, Request
    from repro.transport import (ChaosTransport, ReplicaFault,
                                 ReplicatedTransport, RetryPolicy,
                                 SimulatedNetworkTransport)

    n_experts = 3
    n_reqs = 6 if smoke else 12
    max_new = 4 if smoke else 8
    prompt_len = 8
    probe = 4096        # < blob size: the probe leaves leaves in flight
    pol = RetryPolicy(max_attempts=3, backoff_base_s=0.0)
    api, rt, cfg, base, experts = _serve_fixture(n_experts=n_experts + 1)
    warm, experts = experts[-1], experts[:-1]
    rng = np.random.default_rng(0)
    prompts = [jnp.asarray(rng.integers(1, cfg.vocab, prompt_len), jnp.int32)
               for _ in range(n_reqs)]
    links = [dict(bandwidth_bps=1e8, latency_s=0.001),   # fast (faulty)
             dict(bandwidth_bps=2e7, latency_s=0.005),   # medium
             dict(bandwidth_bps=5e6, latency_s=0.02)]    # slow

    def mk_fleet(faulty):
        chaos = [ChaosTransport(
            SimulatedNetworkTransport(seed=i, **links[i]),
            replica_faults=([ReplicaFault("blackout", at=2)]
                            if faulty and i == 0 else ()))
            for i in range(3)]
        cdn = ReplicatedTransport(chaos, replication_factor=3,
                                  probe_bytes=probe, quarantine_after=99,
                                  retry=pol)
        return cdn, chaos

    def run(faulty):
        cdn, chaos = mk_fleet(faulty)
        pubs = [cdn.publish(e, rep=PACKED) for e in experts]
        reg = capi.registry(transport=cdn)
        eng = capi.serve(api, rt, base, reg, max_batch=8, cache_len=64)
        reqs = [Request(uid=i, expert=f"expert{i % n_experts}",
                        prompt=prompts[i], max_new_tokens=max_new)
                for i in range(n_reqs)]
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = time.perf_counter() - t0
        reg.close()
        return dt, reqs, cdn, chaos, pubs

    t_base, base_reqs, _, _, _ = run(faulty=False)
    assert all(r.status == DONE for r in base_reqs)
    base_toks = {r.uid: list(r.out_tokens) for r in base_reqs}

    def fired_sorted(chaos):
        return sorted((f for c in chaos for f in c.fired()),
                      key=lambda f: (f["name"], f["fetch"]))

    t_chaos, reqs, cdn, chaos, pubs = run(faulty=True)
    expected_bytes = sum(p["nbytes"] for p in pubs)
    parity = all(r.status == DONE and list(r.out_tokens) == base_toks[r.uid]
                 for r in reqs)
    fleet_bytes_in = sum(c.stats.bytes_in for c in chaos)

    # R=1 control: the same faulty replica with nobody to fail over to
    cdn1 = ReplicatedTransport(
        [ChaosTransport(SimulatedNetworkTransport(seed=0, **links[0]),
                        replica_faults=[ReplicaFault("blackout", at=2)])],
        replication_factor=1, probe_bytes=probe,
        retry=RetryPolicy(max_attempts=2, backoff_base_s=0.0))
    for e in experts:
        cdn1.publish(e, rep=PACKED)
    reg1 = capi.registry(transport=cdn1, quarantine_after=1)
    eng1 = capi.serve(api, rt, base, reg1, max_batch=8, cache_len=64)
    ctrl = [Request(uid=i, expert=f"expert{i}", prompt=prompts[i],
                    max_new_tokens=max_new) for i in range(n_experts)]
    eng1.run(ctrl)
    reg1.close()
    control_failed = all(r.status == FAILED and r.error for r in ctrl)

    # determinism: an identical chaos run reproduces everything
    _, reqs2, cdn2, chaos2, _ = run(faulty=True)
    reproduced = (
        [(r.uid, r.status, list(r.out_tokens)) for r in reqs]
        == [(r.uid, r.status, list(r.out_tokens)) for r in reqs2]
        and fired_sorted(chaos) == fired_sorted(chaos2)
        and (cdn2.stats.retries, cdn2.stats.bytes_in,
             cdn2.stats.bytes_wasted)
        == (cdn.stats.retries, cdn.stats.bytes_in, cdn.stats.bytes_wasted)
        and sum(c.stats.bytes_in for c in chaos2) == fleet_bytes_in)

    # cold-start TTFT vs replica count: slowest-first fleets, so the
    # cold (unprobed) path pays the worst link and hedging/EWMA recover
    curve_links = [dict(bandwidth_bps=1e6, latency_s=0.05),    # slow
                   dict(bandwidth_bps=2e7, latency_s=0.005),   # medium
                   dict(bandwidth_bps=1e8, latency_s=0.001)]   # fast
    curve = []
    for R in (1, 2, 3):
        for hedge_ms in (None, 25.0):
            fleet = [SimulatedNetworkTransport(seed=10 + i, **curve_links[i])
                     for i in range(R)]
            ttft_cdn = ReplicatedTransport(fleet, replication_factor=R,
                                           probe_bytes=probe,
                                           hedge_ms=hedge_ms, retry=pol)
            for e in experts[:2]:
                ttft_cdn.publish(e, rep=PACKED)
            reg = capi.registry(transport=ttft_cdn)
            reg.add(warm)       # local overlay: warm-up never probes links
            eng = capi.serve(api, rt, base, reg, max_batch=1, cache_len=64)
            eng.run([Request(uid=0, expert=warm.name, prompt=prompts[0],
                             max_new_tokens=1)])
            row = {"replicas": R, "hedge_ms": hedge_ms}
            # cold: no EWMA yet, selection is index order (the slow link);
            # probed: the cold fetch taught the EWMAs, selection recovers
            for regime, uid, name in (("cold", 1, experts[0].name),
                                      ("probed", 2, experts[1].name)):
                r = Request(uid=uid, expert=name, prompt=prompts[0],
                            max_new_tokens=1)
                t0 = time.perf_counter()
                eng.run([r])
                row[f"ttft_{regime}_s"] = time.perf_counter() - t0
            row["bytes_wasted"] = ttft_cdn.stats.bytes_wasted
            reg.close()
            curve.append(row)
            print(f"[cdn ttft | R={R} hedge={hedge_ms}] "
                  f"cold={row['ttft_cold_s']*1e3:7.1f} ms  "
                  f"probed={row['ttft_probed_s']*1e3:7.1f} ms")

    by = {(r["replicas"], r["hedge_ms"]): r for r in curve}
    rec = {"tag": "chaos_cdn", "n_reqs": n_reqs, "max_new_tokens": max_new,
           "baseline_s": t_base, "chaos_s": t_chaos,
           "bytes_on_wire": expected_bytes,
           "cdn_bytes_in": cdn.stats.bytes_in,
           "fleet_bytes_in": fleet_bytes_in,
           "bytes_wasted": cdn.stats.bytes_wasted,
           "retries": cdn.stats.retries,
           "healthy_bit_identical": parity,
           "control_r1_all_failed": control_failed,
           "fired": fired_sorted(chaos),
           "health": cdn.health(),
           "deterministic": reproduced,
           "ttft_curve": curve}
    save_raw("chaos_cdn", [rec])
    bench_update("BENCH_transport.json", "chaos_cdn", rec)
    print(f"chaos_cdn: parity={parity}, bytes_in={cdn.stats.bytes_in} "
          f"(expected {expected_bytes}), wasted={cdn.stats.bytes_wasted}, "
          f"retries={cdn.stats.retries}, r1_control_failed={control_failed}, "
          f"deterministic={reproduced}")
    assert parity, "requests diverged from the no-fault fleet"
    # the zero-waste invariant, through the new byte accounting: failover
    # refetched ONLY undelivered leaves, so the fleet moved exactly the
    # published bytes and threw none of them away
    assert cdn.stats.bytes_in == expected_bytes, rec
    assert fleet_bytes_in == expected_bytes, rec
    assert cdn.stats.bytes_wasted == 0, rec
    assert cdn.stats.retries == n_experts, rec
    assert (rec["fired"]
            == [{"name": e.name, "fetch": 2, "kind": "replica_blackout"}
                for e in experts]), rec
    assert control_failed, "R=1 control should fail every request"
    assert reproduced, "chaos_cdn run is not reproducible under the seeds"
    assert (by[(3, 25.0)]["ttft_cold_s"]
            < by[(1, None)]["ttft_cold_s"]), rec


def exp_sharded_serve(smoke: bool = False):
    """Tentpole measurement: the mesh-sharded serving engine swept over
    mesh shapes on 8 forced host devices.

    Per shape ``(expert, model)`` the same oversubscribed request stream
    (10 requests into 4 slots — continuous admission exercised) is served
    greedy AND seeded-sampled on paged KV, timed after a warm pass, and
    compared token-for-token against the ``mesh=None`` single-device
    engine.  Gates:

    * **parity** — every swept shape reproduces the single-device token
      streams bitwise, both sampling modes, admissions included;
    * **balance** — per-shard resident expert counts stay within 2x on
      every multi-shard shape (block partition of the stacked planes);
    * the throughput-vs-mesh-shape curve is merged into
      ``BENCH_serve.json`` (forced host devices share one CPU, so the
      curve measures partitioning overhead, not speedup — the point is
      the *shape* of the cost, and that parity holds while paying it).
    """
    import jax.numpy as jnp

    from repro import api as capi
    from repro.launch.mesh import make_serve_mesh
    from repro.serve import Request

    if len(jax.devices()) < 8:
        raise SystemExit("sharded_serve needs 8 devices — run via "
                         "`--exp sharded_serve` so the XLA flag is set "
                         "before jax imports")

    n_experts = 6
    n_reqs = 10 if smoke else 16
    max_batch = 4
    max_new = 4 if smoke else 8
    prompt_len = 12
    api, rt, cfg, base, experts = _serve_fixture(n_experts=n_experts)
    rng = np.random.default_rng(0)
    prompts = [jnp.asarray(rng.integers(1, cfg.vocab, prompt_len), jnp.int32)
               for _ in range(n_reqs)]

    def mk_reqs():
        return [Request(uid=i, expert=f"expert{i % n_experts}",
                        prompt=prompts[i], max_new_tokens=max_new)
                for i in range(n_reqs)]

    SAMP = {"greedy": {},
            "sampled": {"temperature": 0.8, "top_k": 5, "seed": 7}}

    def engine(mesh, samp):
        # fresh registry per engine: per-mesh device caches and stats
        reg = capi.registry(experts=experts, device_cache_bytes=1 << 18,
                            mesh=mesh)
        return capi.serve(api, rt, base, reg, max_batch=max_batch,
                          cache_len=64, decode_chunk=4, kv_layout="paged",
                          kv_block_size=8, mesh=mesh, **samp)

    shapes = [(1, 1), (2, 4)] if smoke else \
        [(1, 1), (2, 1), (1, 2), (2, 2), (2, 4), (4, 2)]

    base_toks = {}
    for label, samp in SAMP.items():
        reqs = mk_reqs()
        engine(None, samp).run(reqs)
        base_toks[label] = {r.uid: (r.status, list(r.out_tokens))
                            for r in reqs}

    rows, parity_all, balance_all = [], True, True
    for shape in shapes:
        mesh = make_serve_mesh(shape)
        row = {"mesh": list(shape)}
        summ = None
        for label, samp in SAMP.items():
            eng = engine(mesh, samp)
            eng.run(mk_reqs())            # warm: compiles on this mesh
            reqs = mk_reqs()
            t0 = time.perf_counter()
            eng.run(reqs)
            dt = time.perf_counter() - t0
            toks = {r.uid: (r.status, list(r.out_tokens)) for r in reqs}
            ok = toks == base_toks[label]
            parity_all = parity_all and ok
            total = sum(len(t) for _, t in toks.values())
            summ = eng.swap_summary()
            row[label] = {"seconds": dt, "tok_s": total / dt, "parity": ok}
        row["admitted"] = summ["admitted"]
        if shape[0] > 1:
            counts = [s["resident_experts"] for s in summ["shards"]]
            row["resident_experts_per_shard"] = counts
            balanced = max(counts) <= 2 * max(min(counts), 1)
            balance_all = balance_all and balanced
        rows.append(row)
        print(f"[mesh={shape}] greedy={row['greedy']['tok_s']:7.1f} tok/s "
              f"sampled={row['sampled']['tok_s']:7.1f} tok/s "
              f"parity={row['greedy']['parity'] and row['sampled']['parity']}"
              + (f" shards={row.get('resident_experts_per_shard')}"
                 if shape[0] > 1 else ""))

    rec = {"tag": "sharded_serve", "smoke": smoke, "n_experts": n_experts,
           "n_reqs": n_reqs, "max_batch": max_batch,
           "max_new_tokens": max_new, "kv_layout": "paged",
           "rows": rows, "token_parity": parity_all,
           "shard_balance_within_2x": balance_all}
    save_raw("sharded_serve", [rec])
    bench_update("BENCH_serve.json", "sharded_serve", rec)
    print(f"sharded_serve: parity={parity_all} "
          f"balance_within_2x={balance_all} over {len(shapes)} shapes")
    assert parity_all, "a mesh shape diverged from the single-device engine"
    assert balance_all, "per-shard resident counts exceeded 2x imbalance"
    assert all(r["admitted"] > 0 for r in rows), \
        "admission path not exercised"


_RESTART_SCENARIOS = {
    # engine kwargs per chaos_restart scenario; "mesh_shape" is popped and
    # turned into a live mesh by _restart_setup
    "dense_greedy": {},
    "paged_sampled": {"kv_layout": "paged", "kv_block_size": 8,
                      "temperature": 0.8, "top_k": 20, "seed": 7},
    "paged_greedy_mesh": {"kv_layout": "paged", "kv_block_size": 8,
                          "mesh_shape": (2, 4)},
}


def _restart_setup(scenario: str, smoke: bool, mesh_shape=None,
                   fixture=None):
    """Deterministic engine ingredients for one chaos_restart scenario.

    Shared between the parent experiment and the SIGKILL child process
    (``benchmarks/restart_child.py``): both sides must build the exact
    same model, experts, registry and request stream so the journal +
    snapshot written by the killed child replays cleanly in the parent.
    ``mesh_shape`` overrides the scenario's default mesh — the parent
    uses this to resume onto a DIFFERENT shape than the one that
    crashed.  ``fixture`` reuses a prebuilt ``_serve_fixture(3)`` (the
    parent amortises the model compile across scenarios and trials).
    Returns ``(api, rt, base, reg, mk_reqs, engine_kw)``.
    """
    import jax.numpy as jnp

    from repro import api as capi
    from repro.serve import Request

    kw = dict(_RESTART_SCENARIOS[scenario])
    if mesh_shape is None:
        mesh_shape = kw.pop("mesh_shape", None)
    else:
        kw.pop("mesh_shape", None)
    n_experts = 3
    n_reqs = 6 if smoke else 9
    # max_new chosen so rows are mid-generation at the kill chunk: the
    # run must cross the snapshot-REPLAY tier, not just journal +
    # re-prefill (4 chunks per wave at decode_chunk=2, kill at 3)
    max_new = 8 if smoke else 10
    api, rt, cfg, base, experts = \
        fixture if fixture is not None else _serve_fixture(n_experts)
    rng = np.random.default_rng(0)
    prompts = [jnp.asarray(rng.integers(1, cfg.vocab, 8), jnp.int32)
               for _ in range(n_reqs)]

    def mk_reqs():
        return [Request(uid=i, expert=f"expert{i % n_experts}",
                        prompt=prompts[i], max_new_tokens=max_new)
                for i in range(n_reqs)]

    reg_kw = {}
    if mesh_shape is not None:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(tuple(mesh_shape))
        kw["mesh"] = mesh
        reg_kw["mesh"] = mesh
    reg = capi.registry(experts=experts, **reg_kw)
    engine_kw = dict(max_batch=4, cache_len=48, decode_chunk=2, **kw)
    return api, rt, base, reg, mk_reqs, engine_kw


def exp_chaos_restart(smoke: bool = False):
    """Robustness gate: kill–restart recovery with bit-identical resume.

    For each scenario (dense+greedy, paged+sampled, paged+greedy on a
    (2,4) mesh) a child process serves the seeded stream with per-chunk
    snapshots and ``SIGKILL``s itself from a chunk hook at a seeded
    chunk index — no atexit, no flush-on-exit: whatever survives is what
    the journal/snapshot machinery made durable.  The parent then
    resumes from the child's snapshot directory in-process and gates:

    * **kill** — the child really died by signal (``-SIGKILL``), having
      journaled at least one chunk first;
    * **parity** — every resumed request finishes with tokens
      bit-identical to an uninterrupted in-process run (the mesh
      scenario resumes onto a DIFFERENT shape, (4,2), than it crashed
      on);
    * **determinism** — a second kill–resume trial reproduces the same
      tokens, statuses and recovery plan;
    * **recovery time** — resume seconds and time-to-first-resumed-token
      are recorded per trial and merged into ``BENCH_serve.json``.
    """
    import signal as _signal
    import subprocess
    import sys as _sys
    import tempfile

    from repro import api as capi
    from repro.serve import DONE

    if len(jax.devices()) < 8:
        raise SystemExit("chaos_restart needs 8 devices — run via "
                         "`--exp chaos_restart` so the XLA flag is set "
                         "before jax imports")

    child = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "restart_child.py")
    kill_at = 3
    n_trials = 2
    resume_mesh = {"paged_greedy_mesh": (4, 2)}
    fixture = _serve_fixture(n_experts=3)
    rows, parity_all, determ_all = [], True, True

    for scenario in _RESTART_SCENARIOS:
        # uninterrupted baseline (scenario's own mesh shape)
        api, rt, base, reg, mk_reqs, engine_kw = _restart_setup(
            scenario, smoke, fixture=fixture)
        reqs = mk_reqs()
        capi.serve(api, rt, base, reg, **engine_kw).run(reqs)
        assert all(r.status == DONE for r in reqs)
        want = {r.uid: (r.status, list(r.out_tokens)) for r in reqs}
        reg.close()

        trials, outcomes = [], []
        for trial in range(n_trials):
            with tempfile.TemporaryDirectory() as snap_dir:
                env = dict(os.environ)
                env.pop("XLA_FLAGS", None)    # child picks its own count
                proc = subprocess.run(
                    [_sys.executable, child, snap_dir, scenario,
                     str(kill_at), str(int(smoke))],
                    env=env, capture_output=True, text=True, timeout=1800)
                assert proc.returncode == -_signal.SIGKILL, (
                    f"{scenario}: child survived or failed "
                    f"(rc={proc.returncode})\n{proc.stdout}\n{proc.stderr}")

                api, rt, base, reg, mk_reqs, engine_kw = _restart_setup(
                    scenario, smoke,
                    mesh_shape=resume_mesh.get(scenario), fixture=fixture)
                eng = capi.serve(api, rt, base, reg, snapshot_dir=snap_dir,
                                 snapshot_every_chunks=1, **engine_kw)
                out = eng.resume()
                reg.close()
            got = {r.uid: (r.status, list(r.out_tokens)) for r in out}
            plan = eng.recovery_stats["plan"]
            ok = got == want
            parity_all = parity_all and ok
            outcomes.append((sorted(got.items()), plan.as_dict()))
            trials.append({
                "parity": ok,
                "resume_seconds": eng.recovery_stats["resume_seconds"],
                "first_resumed_token_s":
                    eng.recovery_stats.get("first_resumed_token_s"),
                **plan.as_dict()})
        deterministic = outcomes[0] == outcomes[-1]
        determ_all = determ_all and deterministic
        row = {"scenario": scenario, "kill_at": kill_at,
               "resume_mesh": list(resume_mesh.get(scenario) or []),
               "trials": trials, "deterministic": deterministic}
        rows.append(row)
        t = trials[0]
        print(f"[{scenario:>18s}] parity={t['parity']} "
              f"resume={t['resume_seconds']:.2f}s "
              f"first_tok={t['first_resumed_token_s']:.2f}s "
              f"replayed={t['replayed_rows']} "
              f"reprefilled={t['reprefilled_rows']} "
              f"deterministic={deterministic}")

    rec = {"tag": "chaos_restart", "smoke": smoke, "kill_at": kill_at,
           "n_trials": n_trials, "scenarios": rows,
           "token_parity": parity_all, "deterministic": determ_all}
    save_raw("chaos_restart", [rec])
    bench_update("BENCH_serve.json", "chaos_restart", rec)
    assert parity_all, "a resumed run diverged from the uninterrupted run"
    assert determ_all, "kill-resume trials were not deterministic"
    assert all(t["replayed_rows"] > 0
               for row in rows for t in row["trials"]), \
        "snapshot-replay tier never exercised (rows all re-prefilled)"


EXPS = {
    "compression_ablation": exp_compression_ablation,
    "rwkv_chunk": exp_rwkv_chunk,
    "llama4_prefill": exp_llama4_prefill,
    "compress_swap": exp_compress_swap,
    "mixed_serve": exp_mixed_serve,
    "decode_loop": exp_decode_loop,
    "serve_load": exp_serve_load,
    "remote_fetch": exp_remote_fetch,
    "chaos_serve": exp_chaos_serve,
    "chaos_cdn": exp_chaos_cdn,
    "sharded_serve": exp_sharded_serve,
    "chaos_restart": exp_chaos_restart,
}


def main():
    import inspect
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", required=True, choices=list(EXPS) + ["all"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI (skips the speedup gate)")
    args = ap.parse_args()

    def call(f):
        if args.smoke and "smoke" in inspect.signature(f).parameters:
            f(smoke=True)
        else:
            f()

    if args.exp == "all":
        for f in EXPS.values():
            call(f)
    else:
        call(EXPS[args.exp])


if __name__ == "__main__":
    main()
