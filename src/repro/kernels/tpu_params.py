"""Shared Mosaic compiler hints for the Pallas kernels.

``dimension_semantics`` tells the TPU lowering which grid dimensions are
embarrassingly parallel (safe to pipeline/reorder across cores) and which
carry a sequential accumulation ("arbitrary").  Interpret mode (CPU CI)
ignores compiler hints, so we return ``None`` there and keep the kernels
runnable on any backend.
"""

from __future__ import annotations

from jax.experimental import pallas as pl

LANE = 32   # uint32 bit lanes (TPU VPU native word)


def lane_block(b: int, n: int) -> int:
    """Clamp a block width to [LANE, ~n] while keeping it a LANE multiple.

    ``min(b, n)`` alone breaks the ``% LANE`` contract whenever n (or the
    caller's b) is not a multiple of 32 — the small-shape bug; the floor at
    one lane keeps tiny-N inputs legal (they pad up to one word).  Shared
    by every kernel that tiles a packed-plane dimension.
    """
    return max(LANE, (min(b, n) // LANE) * LANE)


def tpu_compiler_params(dimension_semantics: tuple[str, ...], *,
                        interpret: bool = False):
    """TPUCompilerParams with the given grid semantics, or None off-TPU."""
    if interpret:
        return None
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.TPUCompilerParams(dimension_semantics=dimension_semantics)


def matmul_cost(m: int, n: int, k: int, *, elem_bytes: int = 4,
                packed_k_bytes: int | None = None) -> pl.CostEstimate:
    """CostEstimate for a dense x packed-ternary matmul: FLOPs from the MXU
    contraction, bytes from x + the 2-bit planes + the f32 output."""
    plane_bytes = (packed_k_bytes if packed_k_bytes is not None
                   else 2 * (k * n // 8))          # two planes, 1 bit each
    return pl.CostEstimate(
        flops=2 * m * n * k,
        bytes_accessed=m * k * elem_bytes + plane_bytes + m * n * 4,
        transcendentals=0,
    )


def streaming_cost(n_elems: int, *, in_bytes_per_elem: float,
                   out_bytes_per_elem: float) -> pl.CostEstimate:
    """CostEstimate for a bandwidth-bound streaming kernel (pack/unpack)."""
    return pl.CostEstimate(
        flops=4 * n_elems,   # compare/shift/mask per element, roughly
        bytes_accessed=int(n_elems * (in_bytes_per_elem + out_bytes_per_elem)),
        transcendentals=0,
    )


def grouped_matmul_cost(m: int, n: int, k: int, n_experts: int, *,
                        elem_bytes: int = 4) -> pl.CostEstimate:
    """CostEstimate for the per-row-expert grouped ternary matmul.

    Each of the E stacked experts contracts a row-masked copy of x on the
    MXU (E full matmuls of FLOPs), but the bytes are x once + E sets of
    2-bit planes + the f32 output — the kernel stays bandwidth-cheap even
    though the masked-contraction FLOPs scale with E.
    """
    plane_bytes = n_experts * 2 * (k * n // 8)      # two planes, 1 bit each
    return pl.CostEstimate(
        flops=2 * m * n * k * max(n_experts, 1),
        bytes_accessed=m * k * elem_bytes + plane_bytes + m * n * 4,
        transcendentals=0,
    )
