"""O(n) streaming threshold for Algorithm 1: histogram quantile + moments.

The seed compression path computed the top-k magnitude cut-off with a
sort-based ``jnp.quantile`` per leaf (O(n log n), one dispatch per leaf) and
then re-read the data for ``std``.  This module replaces it with a two-pass
segmented histogram scheme over a single flat buffer holding *all* leaves of
a pytree:

  pass 1 (coarse)  — 2048-bin histogram of |tau| per segment over
                     ``[0, max_s]``, accumulating ``sum``/``sum_sq`` in the
                     same sweep so sigma comes for free;
  pass 2 (refine)  — 2048 sub-bins inside the coarse bin that contains the
                     k-th largest magnitude.

The returned threshold is the lower edge of the refined bin holding the
k-th order statistic, so it is within ``max_s / 2048^2`` of the exact
quantile and — crucially for Algorithm 1 — ``|x| >= thr`` keeps the same
top-k set as the exact threshold for every distribution, including ties.

Two implementations with identical semantics:

* ``*_jnp``    — vectorised scatter-add path (used off-TPU; O(n) and fully
                 batched, this is what the CPU perf numbers measure);
* Pallas kernel — bin-chunked compare-accumulate grid kernel for TPU, with
                 the moments fused into the coarse pass.  Validated against
                 the jnp path in interpret mode by the test suite.

Layout contract (shared with :func:`repro.core.compeft.compress_packed`):
leaves are flattened C-order, each padded to a multiple of ``cols`` so a
row belongs to exactly one segment; ``row_seg[r]`` maps rows to segments
and ``row_valid[r]`` counts non-padding elements in row ``r``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NBINS = 2048
_BIN_CHUNK = 256   # bins compared per inner step inside the Pallas kernel


# ---------------------------------------------------------------------------
# Vectorised jnp path (CPU / interpret default)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("n_seg", "nbins", "with_moments"))
def _segment_hist_moments_jnp(buf, row_seg, row_valid, lo, width, *,
                              n_seg: int, nbins: int,
                              with_moments: bool = True):
    """One histogram sweep: buf [R, C] -> (hist [S, nbins], sum, sumsq [S]).

    Elements are binned by ``(|x| - lo_s) / width_s`` and clipped into
    [0, nbins-1]; padding (col index >= row_valid) is dropped from every
    accumulator.  ``lo``/``width`` are per-segment f32 vectors.
    """
    R, C = buf.shape
    x = buf.astype(jnp.float32)
    mag = jnp.abs(x)
    valid = (jnp.arange(C, dtype=jnp.int32)[None, :]
             < row_valid[:, None])                      # [R, C]
    seg = jnp.broadcast_to(row_seg[:, None], (R, C))
    w = jnp.maximum(width[seg], 1e-30)
    b = jnp.clip(((mag - lo[seg]) / w * nbins).astype(jnp.int32), 0, nbins - 1)
    in_range = valid & (mag >= lo[seg]) & (mag <= lo[seg] + w)
    hist = jnp.zeros((n_seg, nbins), jnp.int32).at[
        seg.reshape(-1), b.reshape(-1)].add(in_range.reshape(-1)
                                            .astype(jnp.int32))
    if not with_moments:
        z = jnp.zeros((n_seg,), jnp.float32)
        return hist, z, z, z, z
    xm = jnp.where(valid, x, 0.0)
    magm = jnp.where(valid, mag, 0.0)
    ssum = jnp.zeros((n_seg,), jnp.float32).at[row_seg].add(
        jnp.sum(xm, axis=1))
    ssq = jnp.zeros((n_seg,), jnp.float32).at[row_seg].add(
        jnp.sum(xm * xm, axis=1))
    smax = jnp.zeros((n_seg,), jnp.float32).at[row_seg].max(
        jnp.max(magm, axis=1))
    sabs = jnp.zeros((n_seg,), jnp.float32).at[row_seg].add(
        jnp.sum(magm, axis=1))
    return hist, ssum, ssq, smax, sabs


# ---------------------------------------------------------------------------
# Pallas kernel path (TPU): bin-chunked compare-accumulate
# ---------------------------------------------------------------------------


def _hist_kernel(buf_ref, seg_ref, valid_ref, lo_ref, width_ref,
                 hist_ref, mom_ref, *, n_seg: int, nbins: int,
                 with_moments: bool):
    """Grid (n_row_chunks,): accumulate [S, nbins] histogram + [S, 3]
    moments (sum, sumsq, max) across sequential row-chunk steps."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        if with_moments:
            mom_ref[...] = jnp.zeros_like(mom_ref)

    x = buf_ref[...].astype(jnp.float32)                 # [BR, C]
    br, c = x.shape
    mag = jnp.abs(x)
    seg = seg_ref[...].reshape(br)                       # [BR] int32
    nvalid = valid_ref[...].reshape(br)
    valid = (jax.lax.broadcasted_iota(jnp.int32, (br, c), 1)
             < nvalid[:, None])
    lo = lo_ref[...].reshape(-1)[seg][:, None]           # [BR, 1]
    w = jnp.maximum(width_ref[...].reshape(-1)[seg], 1e-30)[:, None]
    b = jnp.clip(((mag - lo) / w * nbins).astype(jnp.int32), 0, nbins - 1)
    in_range = valid & (mag >= lo) & (mag <= lo + w)
    b = jnp.where(in_range, b, -1)                       # park padding

    # per-row one-hot over a bin chunk, then segment scatter via matmul:
    #   seg_onehot [S, BR] @ rowhist [BR, chunk] -> [S, chunk]
    seg_onehot = (jax.lax.broadcasted_iota(jnp.int32, (n_seg, br), 0)
                  == seg[None, :]).astype(jnp.float32)
    for b0 in range(0, nbins, _BIN_CHUNK):
        ids = b0 + jax.lax.broadcasted_iota(jnp.int32, (1, 1, _BIN_CHUNK), 2)
        rowhist = jnp.sum((b[:, :, None] == ids), axis=1,
                          dtype=jnp.float32)             # [BR, chunk]
        upd = jnp.dot(seg_onehot, rowhist,
                      preferred_element_type=jnp.float32)
        hist_ref[:, b0:b0 + _BIN_CHUNK] += upd.astype(jnp.int32)

    if with_moments:
        xm = jnp.where(valid, x, 0.0)
        magm = jnp.where(valid, mag, 0.0)
        s1 = seg_onehot @ jnp.sum(xm, axis=1)
        s2 = seg_onehot @ jnp.sum(xm * xm, axis=1)
        s3 = seg_onehot @ jnp.sum(magm, axis=1)
        rmax = jnp.max(magm, axis=1)
        cand = jnp.max(jnp.where(seg_onehot > 0, rmax[None, :], 0.0), axis=1)
        m = mom_ref[...]
        mom_ref[...] = jnp.stack(
            [m[:, 0] + s1, m[:, 1] + s2, jnp.maximum(m[:, 2], cand),
             m[:, 3] + s3], axis=1)


@functools.partial(jax.jit,
                   static_argnames=("n_seg", "nbins", "br", "with_moments",
                                    "interpret"))
def segment_hist_moments_pallas(buf, row_seg, row_valid, lo, width, *,
                                n_seg: int, nbins: int = NBINS, br: int = 8,
                                with_moments: bool = True,
                                interpret: bool = True):
    """Pallas version of :func:`_segment_hist_moments_jnp` (hist, sum, sumsq,
    max, sum|x|).  ``buf`` [R, C]; rows are padded here to a multiple of
    ``br`` with ``row_valid == 0`` rows (aliased to segment 0), which
    contribute to no accumulator."""
    from repro.kernels.tpu_params import tpu_compiler_params

    R, C = buf.shape
    br = min(br, R)
    pad = (-R) % br
    if pad:
        buf = jnp.pad(buf, ((0, pad), (0, 0)))
        row_seg = jnp.pad(row_seg.reshape(-1), (0, pad))
        row_valid = jnp.pad(row_valid.reshape(-1), (0, pad))
        R += pad
    grid = (R // br,)
    hist, mom = pl.pallas_call(
        functools.partial(_hist_kernel, n_seg=n_seg, nbins=nbins,
                          with_moments=with_moments),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, C), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, n_seg), lambda i: (0, 0)),
            pl.BlockSpec((1, n_seg), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_seg, nbins), lambda i: (0, 0)),
            pl.BlockSpec((n_seg, 4), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_seg, nbins), jnp.int32),
            jax.ShapeDtypeStruct((n_seg, 4), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(("arbitrary",),
                                            interpret=interpret),
        interpret=interpret,
    )(buf, row_seg.reshape(-1, 1), row_valid.reshape(-1, 1),
      lo.reshape(1, -1), width.reshape(1, -1))
    return hist, mom[:, 0], mom[:, 1], mom[:, 2], mom[:, 3]


# ---------------------------------------------------------------------------
# Host numpy fast path (off-TPU default): np.bincount histograms
# ---------------------------------------------------------------------------


def _quantile_moments_np(buf, row_seg, row_valid, seg_count, density, *,
                         n_seg: int, nbins: int):
    """Whole two-pass scheme on the host with C-speed ``np.bincount``.

    XLA lowers the segment scatter-add to a serial loop that is ~10x slower
    than numpy's bincount on CPU, and interpret-mode Pallas is slower
    still, so off-TPU the sweeps run here.  Semantics are identical to the
    jnp/Pallas paths (same binning, same refine, same moments); padding is
    handled by subtracting the known pad count from bin 0 instead of
    masking, so |x| is computed once and reused by both passes.
    """
    buf = np.asarray(buf)
    row_seg = np.asarray(row_seg)
    row_valid = np.asarray(row_valid)
    seg_count = np.asarray(seg_count)
    R, C = buf.shape
    mag = np.abs(buf, dtype=np.float32)                   # reused by pass 2

    n = seg_count.astype(np.float64)
    keep = np.maximum(np.round(n * density), 1.0).astype(np.int64)
    pad = np.bincount(row_seg, weights=(C - row_valid),
                      minlength=n_seg).astype(np.int64)

    rmax = mag.max(axis=1)
    smax = np.zeros(n_seg, np.float32)
    np.maximum.at(smax, row_seg, rmax)
    ssum = np.bincount(row_seg, weights=buf.sum(axis=1, dtype=np.float64),
                       minlength=n_seg)
    ssq = np.bincount(row_seg,
                      weights=np.einsum("rc,rc->r", buf, buf,
                                        dtype=np.float64),
                      minlength=n_seg)
    sabs = np.bincount(row_seg, weights=mag.sum(axis=1, dtype=np.float64),
                       minlength=n_seg)

    def hist_pass(lo, width):
        w = np.maximum(width, 1e-30)
        lo_r = lo[row_seg][:, None]
        scale_r = (nbins / w)[row_seg][:, None]
        b = ((mag - lo_r) * scale_r).astype(np.int64)
        np.clip(b, 0, nbins - 1, out=b)
        idx = row_seg[:, None] * np.int64(nbins) + b
        # out-of-range (refine pass) and padding go to a trash bin
        oob = (mag < lo_r) | (mag > lo_r + w[row_seg][:, None])
        if oob.any():
            idx = np.where(oob, n_seg * nbins, idx)
        h = np.bincount(idx.ravel(), minlength=n_seg * nbins + 1)
        h = h[:n_seg * nbins].reshape(n_seg, nbins)
        h[:, 0] -= np.where(lo <= 0.0, pad, 0)           # padded zeros
        return h

    coarse = hist_pass(np.zeros(n_seg, np.float32), smax)
    suffix = np.cumsum(coarse[:, ::-1], axis=1)[:, ::-1]
    ge = suffix >= keep[:, None]
    cb = np.maximum((ge * np.arange(nbins)[None, :]).max(axis=1), 0)
    cw = np.maximum(smax, 1e-30) / nbins
    lo1 = cb.astype(np.float32) * cw
    above = np.where(cb + 1 < nbins,
                     np.take_along_axis(
                         np.pad(suffix, ((0, 0), (0, 1))),
                         (cb + 1)[:, None], axis=1)[:, 0], 0)
    keep_in_bin = np.maximum(keep - above, 1)

    refined = hist_pass(lo1, cw)
    suffix2 = np.cumsum(refined[:, ::-1], axis=1)[:, ::-1]
    ge2 = suffix2 >= keep_in_bin[:, None]
    rb = np.maximum((ge2 * np.arange(nbins)[None, :]).max(axis=1), 0)
    thr = np.where(smax > 0.0, lo1 + rb.astype(np.float32) * (cw / nbins),
                   0.0)

    nmax = np.maximum(n, 1.0)
    mean = ssum / nmax
    var = np.maximum(ssq / nmax - mean * mean, 0.0)
    as32 = lambda v: jnp.asarray(v, jnp.float32)  # noqa: E731
    return {"threshold": as32(thr), "mean": as32(mean),
            "std": as32(np.sqrt(var)), "mean_abs": as32(sabs / nmax),
            "max": as32(smax), "sum": as32(ssum), "sumsq": as32(ssq),
            "keep": jnp.asarray(keep, jnp.int32)}


@functools.partial(jax.jit, static_argnames=("n_seg",))
def _segment_absmax(buf, row_seg, row_valid, *, n_seg: int):
    R, C = buf.shape
    valid = (jnp.arange(C, dtype=jnp.int32)[None, :] < row_valid[:, None])
    mag = jnp.where(valid, jnp.abs(buf.astype(jnp.float32)), 0.0)
    return jnp.zeros((n_seg,), jnp.float32).at[row_seg].max(
        jnp.max(mag, axis=1))


# ---------------------------------------------------------------------------
# Threshold selection from histograms (host-side jnp, O(S * nbins))
# ---------------------------------------------------------------------------


def _select_bin(hist, keep):
    """Smallest bin index b with suffix_count(b) >= keep (the bin holding
    the keep-th largest in-range magnitude).  hist [S, B], keep [S]."""
    suffix = jnp.cumsum(hist[:, ::-1], axis=1)[:, ::-1]        # [S, B]
    ge = suffix >= keep[:, None]
    # last True index (ge is monotone non-increasing along bins)
    idx = jnp.max(jnp.where(ge, jnp.arange(hist.shape[1])[None, :], -1),
                  axis=1)
    return jnp.maximum(idx, 0)


def segmented_quantile_moments(buf, row_seg, row_valid, seg_count, density,
                               *, n_seg: int, nbins: int = NBINS,
                               backend: str = "auto",
                               interpret: bool = True):
    """Two-pass histogram threshold + moments over a segment buffer.

    Args:
      buf:       [R, C] f32 flat segment buffer (padding rows/cols zeroed).
      row_seg:   [R] int32 row -> segment id.
      row_valid: [R] int32 valid element count per row.
      seg_count: [S] int32 total element count per segment.
      density:   fraction of entries to keep (Algorithm 1 ``k``).
      backend:   'pallas' (TPU kernel), 'jnp' (differentiable/jit
                 reference), 'numpy' (host bincount fast path), or 'auto'
                 — pallas on a real TPU, numpy otherwise.

    Returns dict with per-segment f32 vectors: ``threshold``, ``mean``,
    ``std``, ``mean_abs``, ``max`` — everything Algorithm 1 needs, in two
    data sweeps.
    """
    if backend == "auto":
        backend = "numpy" if interpret else "pallas"
    if backend == "numpy":
        return _quantile_moments_np(buf, row_seg, row_valid, seg_count,
                                    density, n_seg=n_seg, nbins=nbins)
    sweep = (functools.partial(segment_hist_moments_pallas,
                               interpret=interpret)
             if backend == "pallas" else
             functools.partial(_segment_hist_moments_jnp))

    n = seg_count.astype(jnp.float32)
    keep = jnp.maximum(jnp.round(n * density), 1.0).astype(jnp.int32)

    zeros = jnp.zeros((n_seg,), jnp.float32)
    # The histogram needs a range before it can bin, so the segment max is
    # computed by a plain fused reduction first (bandwidth-bound, no sort);
    # the coarse sweep then bins over [0, max_s] and carries the moments.
    smax = _segment_absmax(buf, row_seg, row_valid, n_seg=n_seg)
    coarse, ssum, ssq, _, sabs = sweep(buf, row_seg, row_valid, zeros, smax,
                                       n_seg=n_seg, nbins=nbins)
    cb = _select_bin(coarse, keep)                             # [S]
    cw = jnp.maximum(smax, 1e-30) / nbins
    lo1 = cb.astype(jnp.float32) * cw
    # rank of the target inside the selected coarse bin
    suffix = jnp.cumsum(coarse[:, ::-1], axis=1)[:, ::-1]
    above = jnp.where(cb + 1 < nbins,
                      jnp.take_along_axis(
                          jnp.pad(suffix, ((0, 0), (0, 1))),
                          (cb + 1)[:, None], axis=1)[:, 0],
                      0)
    keep_in_bin = jnp.maximum(keep - above, 1)

    refined, _, _, _, _ = sweep(buf, row_seg, row_valid, lo1, cw,
                                n_seg=n_seg, nbins=nbins,
                                with_moments=False)
    rb = _select_bin(refined, keep_in_bin)
    thr = lo1 + rb.astype(jnp.float32) * (cw / nbins)
    thr = jnp.where(smax > 0.0, thr, 0.0)

    mean = ssum / jnp.maximum(n, 1.0)
    var = jnp.maximum(ssq / jnp.maximum(n, 1.0) - mean * mean, 0.0)
    return {"threshold": thr, "mean": mean, "std": jnp.sqrt(var),
            "mean_abs": sabs / jnp.maximum(n, 1.0), "max": smax,
            "sum": ssum, "sumsq": ssq, "keep": keep}
