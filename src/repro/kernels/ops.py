"""Public jit'd entry points for the Pallas kernels, with automatic
interpret-mode selection.  Off-TPU the bandwidth-bound serving ops route to
their vectorised jnp mirrors (same math, no interpreter tax — the PR-1
convention established by ``compress_packed``); on real TPUs they compile
the Pallas kernels.  Interpret-mode Pallas stays test-only."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import PackedTernary
from repro.kernels import ref
from repro.kernels.pack import pack_ternary_planes
from repro.kernels.popcount_dot import popcount_dot
from repro.kernels.ternary_matmul import ternary_matmul, ternary_matmul_grouped
from repro.kernels.unpack_add import unpack_add, unpack_add_many

INTERPRET = jax.default_backend() != "tpu"

_unpack_add_ref = jax.jit(ref.unpack_add_ref)
_unpack_add_many_ref = jax.jit(ref.unpack_add_many_ref)
_ternary_matmul_ref = jax.jit(ref.ternary_matmul_ref)
_grouped_ref = jax.jit(ref.ternary_matmul_grouped_ref,
                       static_argnames=("transpose_rhs", "n_out"))


def _fused_unpack_add(base, pos, neg, scale):
    if INTERPRET:
        return _unpack_add_ref(base, pos, neg, scale)
    return unpack_add(base, pos, neg, scale, interpret=False)


def _fused_unpack_add_many(base, pos, neg, scales):
    if INTERPRET:
        return _unpack_add_many_ref(base, pos, neg, scales)
    return unpack_add_many(base, pos, neg, scales, interpret=False)


def apply_ternary_delta(base: jax.Array, pt: PackedTernary) -> jax.Array:
    """Expert loading: base [M, N] + decompressed delta, fused."""
    M, N = base.shape
    pos = pt.pos.reshape(M, -1)
    neg = pt.neg.reshape(M, -1)
    return _fused_unpack_add(base, pos, neg, pt.scale)


MERGE_COLS = 4096  # flat-view row width for rank-agnostic merges (128 words)


def _flat_rows(base: jax.Array):
    """Padded [R, cols] flat view geometry for a leaf of any rank."""
    LANE = 32
    n = int(np.prod(base.shape))
    cols = min(MERGE_COLS, ((n + LANE - 1) // LANE) * LANE)
    rows = -(-n // cols)
    return n, rows, cols


def _pad_flat(arr, count, dtype=None):
    flat = arr.reshape(-1)
    if count:
        flat = jnp.concatenate(
            [flat, jnp.zeros((count,), dtype or flat.dtype)])
    return flat


def apply_ternary_delta_flat(base: jax.Array, pt: PackedTernary) -> jax.Array:
    """Rank-agnostic fused merge: base (any shape) + scale * (pos - neg).

    The planes are bit-packed over the *flattened* C-order tensor, so the
    merge views both operands as a padded [R, MERGE_COLS] buffer (row width
    a multiple of the 32-bit lane keeps word alignment) and runs the same
    bandwidth-bound unpack_add math.  This is the packed-resident swap
    path: HBM traffic is base + 2 bits/param, no dense delta is ever
    materialised.
    """
    LANE = 32
    n, rows, cols = _flat_rows(base)
    nw = -(-n // LANE)
    flat = _pad_flat(base, rows * cols - n)
    wpad = rows * (cols // LANE) - nw
    pos = _pad_flat(pt.pos, wpad, jnp.uint32)
    neg = _pad_flat(pt.neg, wpad, jnp.uint32)
    out = _fused_unpack_add(flat.reshape(rows, cols),
                            pos.reshape(rows, cols // LANE),
                            neg.reshape(rows, cols // LANE), pt.scale)
    return out.reshape(-1)[:n].reshape(base.shape)


def apply_ternary_delta_many_flat(base: jax.Array, pts, weights=None
                                  ) -> jax.Array:
    """Fused multi-expert merge of one leaf: base + sum_e w_e*scale_e*Δ_e.

    ``pts`` is a sequence of PackedTernary over the same leaf shape;
    ``weights`` (optional, len E) are the merged-ensemble mixing
    coefficients α_e.  One sweep over base instead of E round-trips —
    bit-identical to looping :func:`apply_ternary_delta_flat` with the
    scaled deltas.
    """
    LANE = 32
    n, rows, cols = _flat_rows(base)
    nw = -(-n // LANE)
    wpad = rows * (cols // LANE) - nw
    flat = _pad_flat(base, rows * cols - n)
    pos = jnp.stack([_pad_flat(pt.pos, wpad, jnp.uint32)
                     .reshape(rows, cols // LANE) for pt in pts])
    neg = jnp.stack([_pad_flat(pt.neg, wpad, jnp.uint32)
                     .reshape(rows, cols // LANE) for pt in pts])
    scales = jnp.stack([pt.scale.astype(jnp.float32) for pt in pts])
    if weights is not None:
        scales = scales * jnp.asarray(weights, jnp.float32)
    out = _fused_unpack_add_many(flat.reshape(rows, cols), pos, neg, scales)
    return out.reshape(-1)[:n].reshape(base.shape)


def ternary_matvec(x: jax.Array, pt: PackedTernary) -> jax.Array:
    """y = x @ (scale * ternary[K, N]) without materialising the matrix."""
    K, N = pt.shape
    pos = pt.pos.reshape(K, -1)
    neg = pt.neg.reshape(K, -1)
    squeeze = x.ndim == 1
    x2 = x[None] if squeeze else x
    if INTERPRET:
        y = _ternary_matmul_ref(x2, pos, neg, pt.scale)[:, :N]
    else:
        y = ternary_matmul(x2, pos, neg, pt.scale, interpret=False)[:, :N]
    return y[0] if squeeze else y


def grouped_delta_matmul(x: jax.Array, pos: jax.Array, neg: jax.Array,
                         scales: jax.Array, expert_idx: jax.Array, *,
                         transpose_rhs: bool = False,
                         n_out: int | None = None) -> jax.Array:
    """Zero-merge hot path: per-row-expert delta contraction.

    x: [M, K]; pos/neg: stacked [E, K, N//32] ([E, N, ceil(K/32)] when
    ``transpose_rhs``); scales [E]; expert_idx [M] int32 (-1 → zero delta).
    Returns the f32 delta [M, N] to add onto ``x @ W_base``.
    """
    if INTERPRET:
        y = _grouped_ref(x, pos, neg, scales, expert_idx,
                         transpose_rhs=transpose_rhs)
    else:
        y = ternary_matmul_grouped(x, pos, neg, scales, expert_idx,
                                   transpose_rhs=transpose_rhs,
                                   interpret=False)
    return y if n_out is None else y[:, :n_out]


def compress_to_planes(tau: jax.Array, thr: jax.Array):
    """Fused threshold+sign+pack for a [M, N] task-vector leaf."""
    return pack_ternary_planes(tau, thr, interpret=INTERPRET)


def expert_dot(a: PackedTernary, b: PackedTernary) -> jax.Array:
    """Scaled ternary dot via AND+POPCNT."""
    d = popcount_dot(a.pos.reshape(-1), a.neg.reshape(-1),
                     b.pos.reshape(-1), b.neg.reshape(-1),
                     interpret=INTERPRET)
    return d.astype(jnp.float32) * a.scale * b.scale
