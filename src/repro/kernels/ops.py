"""Public jit'd entry points for the Pallas kernels, with automatic
interpret-mode selection (interpret=True off-TPU so CI validates kernel
bodies on CPU; compiled pallas on real TPUs)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import PackedTernary
from repro.kernels.pack import pack_ternary_planes
from repro.kernels.popcount_dot import popcount_dot
from repro.kernels.ternary_matmul import ternary_matmul
from repro.kernels.unpack_add import unpack_add

INTERPRET = jax.default_backend() != "tpu"


def apply_ternary_delta(base: jax.Array, pt: PackedTernary) -> jax.Array:
    """Expert loading: base [M, N] + decompressed delta, fused."""
    M, N = base.shape
    pos = pt.pos.reshape(M, -1)
    neg = pt.neg.reshape(M, -1)
    return unpack_add(base, pos, neg, pt.scale, interpret=INTERPRET)


MERGE_COLS = 4096  # flat-view row width for rank-agnostic merges (128 words)


def apply_ternary_delta_flat(base: jax.Array, pt: PackedTernary) -> jax.Array:
    """Rank-agnostic fused merge: base (any shape) + scale * (pos - neg).

    The planes are bit-packed over the *flattened* C-order tensor, so the
    merge views both operands as a padded [R, MERGE_COLS] buffer (row width
    a multiple of the 32-bit lane keeps word alignment) and runs the same
    bandwidth-bound unpack_add kernel.  This is the packed-resident swap
    path: HBM traffic is base + 2 bits/param, no dense delta is ever
    materialised.
    """
    LANE = 32
    n = int(np.prod(base.shape))
    nw = -(-n // LANE)
    cols = min(MERGE_COLS, ((n + LANE - 1) // LANE) * LANE)
    rows = -(-n // cols)
    flat = base.reshape(-1)
    pad = rows * cols - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), base.dtype)])
    wpad = rows * (cols // LANE) - nw
    pos = jnp.concatenate([pt.pos.reshape(-1),
                           jnp.zeros((wpad,), jnp.uint32)]) if wpad else \
        pt.pos.reshape(-1)
    neg = jnp.concatenate([pt.neg.reshape(-1),
                           jnp.zeros((wpad,), jnp.uint32)]) if wpad else \
        pt.neg.reshape(-1)
    out = unpack_add(flat.reshape(rows, cols),
                     pos.reshape(rows, cols // LANE),
                     neg.reshape(rows, cols // LANE),
                     pt.scale, interpret=INTERPRET)
    return out.reshape(-1)[:n].reshape(base.shape)


def ternary_matvec(x: jax.Array, pt: PackedTernary) -> jax.Array:
    """y = x @ (scale * ternary[K, N]) without materialising the matrix."""
    K, N = pt.shape
    pos = pt.pos.reshape(K, -1)
    neg = pt.neg.reshape(K, -1)
    squeeze = x.ndim == 1
    x2 = x[None] if squeeze else x
    y = ternary_matmul(x2, pos, neg, pt.scale, interpret=INTERPRET)[:, :N]
    return y[0] if squeeze else y


def compress_to_planes(tau: jax.Array, thr: jax.Array):
    """Fused threshold+sign+pack for a [M, N] task-vector leaf."""
    return pack_ternary_planes(tau, thr, interpret=INTERPRET)


def expert_dot(a: PackedTernary, b: PackedTernary) -> jax.Array:
    """Scaled ternary dot via AND+POPCNT."""
    d = popcount_dot(a.pos.reshape(-1), a.neg.reshape(-1),
                     b.pos.reshape(-1), b.neg.reshape(-1),
                     interpret=INTERPRET)
    return d.astype(jnp.float32) * a.scale * b.scale
