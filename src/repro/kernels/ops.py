"""Public jit'd entry points for the Pallas kernels, with automatic
interpret-mode selection (interpret=True off-TPU so CI validates kernel
bodies on CPU; compiled pallas on real TPUs)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import PackedTernary
from repro.kernels.pack import pack_ternary_planes
from repro.kernels.popcount_dot import popcount_dot
from repro.kernels.ternary_matmul import ternary_matmul
from repro.kernels.unpack_add import unpack_add

INTERPRET = jax.default_backend() != "tpu"


def apply_ternary_delta(base: jax.Array, pt: PackedTernary) -> jax.Array:
    """Expert loading: base [M, N] + decompressed delta, fused."""
    M, N = base.shape
    pos = pt.pos.reshape(M, -1)
    neg = pt.neg.reshape(M, -1)
    return unpack_add(base, pos, neg, pt.scale, interpret=INTERPRET)


def ternary_matvec(x: jax.Array, pt: PackedTernary) -> jax.Array:
    """y = x @ (scale * ternary[K, N]) without materialising the matrix."""
    K, N = pt.shape
    pos = pt.pos.reshape(K, -1)
    neg = pt.neg.reshape(K, -1)
    squeeze = x.ndim == 1
    x2 = x[None] if squeeze else x
    y = ternary_matmul(x2, pos, neg, pt.scale, interpret=INTERPRET)[:, :N]
    return y[0] if squeeze else y


def compress_to_planes(tau: jax.Array, thr: jax.Array):
    """Fused threshold+sign+pack for a [M, N] task-vector leaf."""
    return pack_ternary_planes(tau, thr, interpret=INTERPRET)


def expert_dot(a: PackedTernary, b: PackedTernary) -> jax.Array:
    """Scaled ternary dot via AND+POPCNT."""
    d = popcount_dot(a.pos.reshape(-1), a.neg.reshape(-1),
                     b.pos.reshape(-1), b.neg.reshape(-1),
                     interpret=INTERPRET)
    return d.astype(jnp.float32) * a.scale * b.scale
