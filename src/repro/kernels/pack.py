"""Pallas TPU kernel: fused threshold + sign + bitplane pack (compression).

Given a task-vector tile and the pre-computed top-k magnitude threshold,
emit the two uint32 bitplanes in one pass:

    keep = |tau| >= thr
    pos_bits = pack(keep & (tau > 0));  neg_bits = pack(keep & (tau < 0))

The global threshold (one quantile per tensor) is computed outside — it is
O(n) once per expert; the kernel is the bandwidth-bound part that runs over
the full tensor and writes 2 bits/param.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 32


def _kernel(tau_ref, thr_ref, pos_ref, neg_ref):
    t = tau_ref[...].astype(jnp.float32)               # [BM, BN]
    thr = thr_ref[0, 0]
    keep = jnp.abs(t) >= thr
    bm, bn = t.shape
    lanes_p = (keep & (t > 0)).reshape(bm, bn // LANE, LANE)
    lanes_n = (keep & (t < 0)).reshape(bm, bn // LANE, LANE)
    weights = (jnp.uint32(1) << jnp.arange(LANE, dtype=jnp.uint32))[None, None]
    pos_ref[...] = jnp.sum(
        jnp.where(lanes_p, weights, jnp.uint32(0)), axis=-1, dtype=jnp.uint32)
    neg_ref[...] = jnp.sum(
        jnp.where(lanes_n, weights, jnp.uint32(0)), axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def pack_ternary_planes(tau: jax.Array, thr: jax.Array, *, bm: int = 256,
                        bn: int = 512, interpret: bool = True):
    """tau: [M, N] float; thr: scalar f32.  Returns (pos, neg) uint32
    [M, ceil(N/32)] planes (zero bits in padding)."""
    M, N = tau.shape
    bm = min(bm, M)
    bn = min(bn, max(LANE, N))
    bn = (bn // LANE) * LANE
    pad_m, pad_n = (-M) % bm, (-N) % bn
    if pad_m or pad_n:
        tau = jnp.pad(tau, ((0, pad_m), (0, pad_n)))
    Mp, Np = tau.shape

    pos, neg = pl.pallas_call(
        _kernel,
        grid=(Mp // bm, Np // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn // LANE), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn // LANE), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, Np // LANE), jnp.uint32),
            jax.ShapeDtypeStruct((Mp, Np // LANE), jnp.uint32),
        ],
        interpret=interpret,
    )(tau, thr.reshape(1, 1).astype(jnp.float32))
    return pos[:M, : -(-N // LANE)], neg[:M, : -(-N // LANE)]
