"""Pallas TPU kernel: fused threshold + sign + bitplane pack (compression).

Given a task-vector tile and the pre-computed top-k magnitude threshold,
emit the two uint32 bitplanes in one pass:

    keep = |tau| >= thr
    pos_bits = pack(keep & (tau > 0));  neg_bits = pack(keep & (tau < 0))

Two entry points:

* :func:`pack_ternary_planes` — one tensor, one scalar threshold (the seed
  per-leaf path and the unit-test surface);
* :func:`pack_ternary_planes_segmented` — the streaming-compression fast
  path: a single launch over the flat ``[R, C]`` segment buffer holding
  *all* leaves of a pytree, with a per-row threshold vector (each row
  belongs to exactly one leaf, so a per-row threshold is a per-leaf
  threshold).  This is what turns N python-level compress calls into one
  batched kernel.

Thresholds come from :mod:`repro.kernels.histogram_quantile` — O(n), no
sort; the kernels here are the bandwidth-bound part writing 2 bits/param.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tpu_params import streaming_cost, tpu_compiler_params

LANE = 32


def _pack_lanes(keep_pos, keep_neg):
    bm, bn = keep_pos.shape
    lanes_p = keep_pos.reshape(bm, bn // LANE, LANE)
    lanes_n = keep_neg.reshape(bm, bn // LANE, LANE)
    weights = (jnp.uint32(1) << jnp.arange(LANE, dtype=jnp.uint32))[None, None]
    pos = jnp.sum(jnp.where(lanes_p, weights, jnp.uint32(0)), axis=-1,
                  dtype=jnp.uint32)
    neg = jnp.sum(jnp.where(lanes_n, weights, jnp.uint32(0)), axis=-1,
                  dtype=jnp.uint32)
    return pos, neg


def _kernel(tau_ref, thr_ref, pos_ref, neg_ref):
    t = tau_ref[...].astype(jnp.float32)               # [BM, BN]
    thr = thr_ref[0, 0]
    keep = jnp.abs(t) >= thr
    pos_ref[...], neg_ref[...] = _pack_lanes(keep & (t > 0), keep & (t < 0))


def _kernel_rows(tau_ref, thr_ref, pos_ref, neg_ref):
    t = tau_ref[...].astype(jnp.float32)               # [BM, BN]
    thr = thr_ref[...]                                  # [BM, 1]
    keep = jnp.abs(t) >= thr
    pos_ref[...], neg_ref[...] = _pack_lanes(keep & (t > 0), keep & (t < 0))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def pack_ternary_planes(tau: jax.Array, thr: jax.Array, *, bm: int = 256,
                        bn: int = 512, interpret: bool = True):
    """tau: [M, N] float; thr: scalar f32.  Returns (pos, neg) uint32
    [M, ceil(N/32)] planes (zero bits in padding)."""
    M, N = tau.shape
    bm = min(bm, M)
    bn = min(bn, max(LANE, N))
    bn = (bn // LANE) * LANE
    pad_m, pad_n = (-M) % bm, (-N) % bn
    if pad_m or pad_n:
        tau = jnp.pad(tau, ((0, pad_m), (0, pad_n)))
    Mp, Np = tau.shape

    pos, neg = pl.pallas_call(
        _kernel,
        grid=(Mp // bm, Np // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn // LANE), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn // LANE), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, Np // LANE), jnp.uint32),
            jax.ShapeDtypeStruct((Mp, Np // LANE), jnp.uint32),
        ],
        compiler_params=tpu_compiler_params(("parallel", "parallel"),
                                            interpret=interpret),
        cost_estimate=streaming_cost(Mp * Np, in_bytes_per_elem=4.0,
                                     out_bytes_per_elem=0.25),
        interpret=interpret,
    )(tau, thr.reshape(1, 1).astype(jnp.float32))
    return pos[:M, : -(-N // LANE)], neg[:M, : -(-N // LANE)]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def pack_ternary_planes_segmented(tau: jax.Array, thr_rows: jax.Array, *,
                                  bm: int = 256, bn: int = 512,
                                  interpret: bool = True):
    """Batched pack over a segment buffer: tau [R, C] (C % 32 == 0),
    thr_rows [R] f32 per-row thresholds.  One launch for a whole pytree.

    Returns (pos, neg) uint32 [R, C//32].  Padding rows pack to zero words
    as long as their elements are zero and their threshold is > 0 — zeros
    never set a bit in either plane regardless of the threshold.
    """
    R, C = tau.shape
    assert C % LANE == 0, C
    bm = min(bm, R)
    bn = min(bn, C)
    bn = (bn // LANE) * LANE
    pad_r = (-R) % bm
    assert C % bn == 0, (C, bn)
    if pad_r:
        tau = jnp.pad(tau, ((0, pad_r), (0, 0)))
        thr_rows = jnp.pad(thr_rows, (0, pad_r))
    Rp = tau.shape[0]

    pos, neg = pl.pallas_call(
        _kernel_rows,
        grid=(Rp // bm, C // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn // LANE), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn // LANE), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Rp, C // LANE), jnp.uint32),
            jax.ShapeDtypeStruct((Rp, C // LANE), jnp.uint32),
        ],
        compiler_params=tpu_compiler_params(("parallel", "parallel"),
                                            interpret=interpret),
        cost_estimate=streaming_cost(Rp * C, in_bytes_per_elem=4.0,
                                     out_bytes_per_elem=0.25),
        interpret=interpret,
    )(tau.astype(jnp.float32), thr_rows.reshape(-1, 1).astype(jnp.float32))
    return pos[:R], neg[:R]


def pack_ternary_planes_segmented_ref(tau, thr_rows):
    """Vectorised jnp mirror of the segmented kernel (CPU fast path)."""
    t = tau.astype(jnp.float32)
    thr = thr_rows.astype(jnp.float32)[:, None]
    keep = jnp.abs(t) >= thr
    R, C = t.shape
    w = (jnp.uint32(1) << jnp.arange(LANE, dtype=jnp.uint32))
    posm = (keep & (t > 0)).astype(jnp.uint32).reshape(R, C // LANE, LANE)
    negm = (keep & (t < 0)).astype(jnp.uint32).reshape(R, C // LANE, LANE)
    return (jnp.sum(posm * w, axis=-1, dtype=jnp.uint32),
            jnp.sum(negm * w, axis=-1, dtype=jnp.uint32))
