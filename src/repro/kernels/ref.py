"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 32


def _unpack(words: jax.Array, n_last: int) -> jax.Array:
    """[..., W] uint32 -> [..., W*32] int32 in {0,1}, truncated to n_last."""
    shifts = jnp.arange(LANE, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(words.shape[:-1] + (-1,))[..., :n_last].astype(
        jnp.int32)


def dense_of_planes(pos: jax.Array, neg: jax.Array, n: int) -> jax.Array:
    """[M, W] planes -> [M, n] float ternary matrix."""
    return (_unpack(pos, n) - _unpack(neg, n)).astype(jnp.float32)


def ternary_matmul_ref(x, pos, neg, scale):
    K = x.shape[1]
    N = pos.shape[1] * LANE
    w = dense_of_planes(pos, neg, N)            # [K, N]
    return (x.astype(jnp.float32) @ w) * scale


def unpack_add_ref(base, pos, neg, scale):
    M, N = base.shape
    delta = dense_of_planes(pos, neg, N)
    return (base.astype(jnp.float32) + scale * delta).astype(base.dtype)


def pack_ternary_planes_ref(tau, thr):
    t = tau.astype(jnp.float32)
    keep = jnp.abs(t) >= thr
    M, N = t.shape
    padn = (-N) % LANE
    posm = jnp.pad((keep & (t > 0)).astype(jnp.uint32), ((0, 0), (0, padn)))
    negm = jnp.pad((keep & (t < 0)).astype(jnp.uint32), ((0, 0), (0, padn)))
    w = (jnp.uint32(1) << jnp.arange(LANE, dtype=jnp.uint32))
    pos = jnp.sum(posm.reshape(M, -1, LANE) * w, axis=-1, dtype=jnp.uint32)
    neg = jnp.sum(negm.reshape(M, -1, LANE) * w, axis=-1, dtype=jnp.uint32)
    return pos, neg


def unpack_add_many_ref(base, pos, neg, scales):
    """Loop of unpack_add_ref — the bit-exact oracle for the fused
    multi-expert merge (round-trips through base.dtype per expert)."""
    out = base
    for e in range(pos.shape[0]):
        out = unpack_add_ref(out, pos[e], neg[e], scales[e])
    return out


def ternary_matmul_grouped_ref(x, pos, neg, scales, expert_idx,
                               transpose_rhs: bool = False, n_out=None):
    """Per-row-expert delta: y[m] = scales[e(m)] * (x[m] @ T_{e(m)}).

    pos/neg: [E, K, N//32] ([E, N, ceil(K/32)] when ``transpose_rhs``).
    Rows with expert_idx == -1 get a zero delta.  Mirrors the grouped
    kernel's accumulation order (per-expert masked matmuls, scale last) so
    mixed-batch rows are bitwise what a single-expert run produces.
    """
    E = pos.shape[0]
    x32 = x.astype(jnp.float32)
    M, K = x32.shape
    if transpose_rhs:
        N = pos.shape[1]
        n_dense = K
    else:
        N = pos.shape[2] * LANE if n_out is None else n_out
        n_dense = pos.shape[2] * LANE
    acc = jnp.zeros((M, N), jnp.float32)
    eid = expert_idx.astype(jnp.int32)[:, None]
    for e in range(E):
        w = dense_of_planes(pos[e], neg[e], n_dense)
        if transpose_rhs:
            w = w.T                                   # [K, N]
        sel = (eid == e).astype(jnp.float32)
        acc += jnp.dot(x32 * sel, w[:, :N])
    srow = jnp.zeros((M, 1), jnp.float32)
    for e in range(E):
        srow += jnp.where(eid == e, scales[e].astype(jnp.float32), 0.0)
    return acc * srow


def popcount_dot_ref(a_pos, a_neg, b_pos, b_neg):
    n = a_pos.shape[0] * LANE
    a = dense_of_planes(a_pos[None], a_neg[None], n)[0]
    b = dense_of_planes(b_pos[None], b_neg[None], n)[0]
    return jnp.sum(a * b).astype(jnp.int32)
