"""Pallas TPU kernel: ternary-ternary dot product via AND + POPCNT.

    <a, b> = popcnt(a+ & b+) + popcnt(a- & b-) - popcnt(a+ & b-) - popcnt(a- & b+)

Operates on uint32 bitplanes (32 params/lane on the VPU) — the paper's
§2.2 "two machine instructions per 64 parameters" idea, on TPU lanes.
Used for expert-similarity / routing over compressed expert libraries.
Each grid step emits a block-partial; ops.py sums the partials.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _kernel(ap_ref, an_ref, bp_ref, bn_ref, o_ref):
    ap, an = ap_ref[...], an_ref[...]
    bp, bn = bp_ref[...], bn_ref[...]

    def pc(x):
        return jnp.sum(lax.population_count(x).astype(jnp.int32))

    o_ref[0, 0] = (pc(ap & bp) + pc(an & bn) - pc(ap & bn) - pc(an & bp))


@functools.partial(jax.jit, static_argnames=("bw", "interpret"))
def popcount_dot(a_pos: jax.Array, a_neg: jax.Array, b_pos: jax.Array,
                 b_neg: jax.Array, *, bw: int = 2048,
                 interpret: bool = True) -> jax.Array:
    """All inputs flat uint32 plane arrays of equal length.  Returns the
    integer ternary dot product as int32 (scales applied by the caller)."""
    (W,) = a_pos.shape
    bw = min(bw, W)
    pad = (-W) % bw
    if pad:
        a_pos, a_neg, b_pos, b_neg = (
            jnp.pad(x, (0, pad)) for x in (a_pos, a_neg, b_pos, b_neg))
    Wp = W + pad
    n = Wp // bw

    partials = pl.pallas_call(
        _kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((bw,), lambda i: (i,)) for _ in range(4)],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        interpret=interpret,
    )(a_pos, a_neg, b_pos, b_neg)
    return jnp.sum(partials)
