"""Pallas TPU kernels: dense × packed-ternary matmul.

Single-expert form:

    y[M, N] = scale * ( x[M, K] @ (pos - neg)[K, N] )

with the ternary matrix stored as two uint32 bitplanes packed along the
*output* dim (C-order of a [K, N] weight): planes have shape [K, N//32].

Grouped (per-row-expert) form — the zero-merge serving hot path:

    y[m, :] = scale[e(m)] * ( x[m, :] @ T_{e(m)} )

with E experts' planes stacked as [E, K, N//32] and a per-row ``expert_idx``
vector.  One launch contracts a decode batch that mixes experts against all
resident ternary deltas; the caller adds ``x @ W_base`` (the base weights
are never re-materialised per expert, and the experts are never merged).
``transpose_rhs=True`` takes planes packed along the *contraction* dim
([N, K//32], e.g. an embedding table reused as a tied LM head) and computes
``x @ T^t`` without repacking.

TPU adaptation of the paper's §2.2 "binary vector" computation: the ternary
delta streams HBM→VMEM at 2 bits/param (16x less bandwidth than bf16), is
unpacked to ±1 tiles in-register, and contracts on the MXU.  Decode-time
expert application is memory-bound, so the bandwidth saving is the win;
the unpack ALU work rides free under the matmul.  In the grouped kernel the
per-expert row masks cost E small VPU selects per tile; each expert's
contribution still contracts on the MXU.

Grid: (M/BM, N/BN, K/BK), K innermost for accumulation in the VMEM output
block.  Block shapes keep the MXU dims at 128 multiples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.tpu_params import (grouped_matmul_cost, lane_block,
                                      matmul_cost, tpu_compiler_params)

LANE = 32


def _unpack_tile(pw, nw, dtype=jnp.int8):
    """[BK, W] uint32 plane pair -> [BK, W*32] ±1 tile."""
    shifts = jnp.arange(LANE, dtype=jnp.uint32)[None, None, :]
    pb = ((pw[:, :, None] >> shifts) & jnp.uint32(1)).astype(dtype)
    nb = ((nw[:, :, None] >> shifts) & jnp.uint32(1)).astype(dtype)
    return (pb - nb).reshape(pw.shape[0], pw.shape[1] * LANE)


def _kernel(x_ref, pos_ref, neg_ref, scale_ref, o_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...]                                   # [BM, BK]
    w = _unpack_tile(pos_ref[...], neg_ref[...])      # [BK, BN]
    acc = jnp.dot(xb.astype(jnp.float32), w.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    o_ref[...] += acc

    @pl.when(k == n_k - 1)
    def _scale():
        o_ref[...] *= scale_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def ternary_matmul(x: jax.Array, pos: jax.Array, neg: jax.Array,
                   scale: jax.Array, *, bm: int = 128, bn: int = 128,
                   bk: int = 128, interpret: bool = True) -> jax.Array:
    """x: [M, K] float; pos/neg: [K, N//32] uint32; scale: scalar f32.
    Returns [M, N] f32."""
    M, K = x.shape
    Kp, Wn = pos.shape
    assert Kp == K, (Kp, K)
    N = Wn * LANE

    bm = min(bm, M)
    bk = min(bk, K)
    bn = lane_block(bn, N)
    pad_m, pad_k, pad_n = (-M) % bm, (-K) % bk, (-N) % bn
    if pad_m or pad_k:
        x = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        pos = jnp.pad(pos, ((0, pad_k), (0, pad_n // LANE)))
        neg = jnp.pad(neg, ((0, pad_k), (0, pad_n // LANE)))
    Mp, Kpd, Np = M + pad_m, K + pad_k, N + pad_n
    n_k = Kpd // bk

    grid = (Mp // bm, Np // bn, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn // LANE), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn // LANE), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        # i/j tiles are independent; k accumulates into the output block
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary"), interpret=interpret),
        cost_estimate=matmul_cost(Mp, Np, Kpd,
                                  elem_bytes=x.dtype.itemsize),
        interpret=interpret,
    )(x, pos, neg, scale.reshape(1, 1).astype(jnp.float32))
    return out[:M, :N]


def _kernel_grouped(x_ref, pos_ref, neg_ref, scales_ref, eid_ref, o_ref, *,
                    n_k: int, n_e: int, transpose_rhs: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...].astype(jnp.float32)               # [BM, BK]
    eid = eid_ref[...]                                # [BM, 1] int32
    acc = jnp.zeros_like(o_ref)
    for e in range(n_e):                              # static unroll over E
        w = _unpack_tile(pos_ref[e], neg_ref[e]).astype(jnp.float32)
        if transpose_rhs:                             # w: [BN, BK] -> use w^t
            w = w.T
        sel = (eid == e).astype(jnp.float32)          # [BM, 1] row mask
        acc += jnp.dot(xb * sel, w, preferred_element_type=jnp.float32)
    o_ref[...] += acc

    @pl.when(k == n_k - 1)
    def _scale():
        eid_f = eid_ref[...]
        srow = jnp.zeros_like(eid_f, dtype=jnp.float32)
        for e in range(n_e):                          # per-row scale gather
            srow += jnp.where(eid_f == e, scales_ref[e, 0], 0.0)
        o_ref[...] *= srow


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "transpose_rhs"))
def ternary_matmul_grouped(x: jax.Array, pos: jax.Array, neg: jax.Array,
                           scales: jax.Array, expert_idx: jax.Array, *,
                           transpose_rhs: bool = False, bm: int = 128,
                           bn: int = 128, bk: int = 128,
                           interpret: bool = True) -> jax.Array:
    """Per-row-expert delta contraction over stacked planes, one launch.

    x: [M, K] float; pos/neg: [E, K, N//32] uint32 ([E, N, K//32] when
    ``transpose_rhs``); scales: [E] f32; expert_idx: [M] int32 in [0, E)
    (-1 rows get a zero delta).  Returns [M, N] f32 with
    ``y[m] = scales[expert_idx[m]] * (x[m] @ T_{expert_idx[m]})`` — row-wise
    bit-identical to running :func:`ternary_matmul` per expert with the same
    block shapes and selecting rows.
    """
    M, K = x.shape
    E = pos.shape[0]
    if transpose_rhs:
        N, Wk = pos.shape[1], pos.shape[2]
        assert Wk == -(-K // LANE), (pos.shape, K)
    else:
        Kp, Wn = pos.shape[1], pos.shape[2]
        assert Kp == K, (pos.shape, K)
        N = Wn * LANE
    assert scales.shape == (E,), scales.shape
    assert expert_idx.shape == (M,), (expert_idx.shape, M)

    bm = min(bm, M)
    bk = lane_block(bk, K) if transpose_rhs else min(bk, K)
    bn = min(bn, N) if transpose_rhs else lane_block(bn, N)
    pad_m, pad_k, pad_n = (-M) % bm, (-K) % bk, (-N) % bn
    if pad_m or pad_k:
        x = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    if pad_m:
        expert_idx = jnp.pad(expert_idx, (0, pad_m), constant_values=-1)
    if transpose_rhs:
        pad_w = (K + pad_k) // LANE - pos.shape[2]
        if pad_n or pad_w:
            pos = jnp.pad(pos, ((0, 0), (0, pad_n), (0, pad_w)))
            neg = jnp.pad(neg, ((0, 0), (0, pad_n), (0, pad_w)))
    else:
        if pad_k or pad_n:
            pos = jnp.pad(pos, ((0, 0), (0, pad_k), (0, pad_n // LANE)))
            neg = jnp.pad(neg, ((0, 0), (0, pad_k), (0, pad_n // LANE)))
    Mp, Kpd, Np = M + pad_m, K + pad_k, N + pad_n
    n_k = Kpd // bk

    if transpose_rhs:
        plane_block = (E, bn, bk // LANE)
        plane_map = lambda i, j, k: (0, j, k)  # noqa: E731
    else:
        plane_block = (E, bk, bn // LANE)
        plane_map = lambda i, j, k: (0, k, j)  # noqa: E731

    grid = (Mp // bm, Np // bn, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel_grouped, n_k=n_k, n_e=E,
                          transpose_rhs=transpose_rhs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec(plane_block, plane_map),
            pl.BlockSpec(plane_block, plane_map),
            pl.BlockSpec((E, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary"), interpret=interpret),
        cost_estimate=grouped_matmul_cost(Mp, Np, Kpd, E,
                                          elem_bytes=x.dtype.itemsize),
        interpret=interpret,
    )(x, pos, neg, scales.reshape(-1, 1).astype(jnp.float32),
      expert_idx.reshape(-1, 1).astype(jnp.int32))
    return out[:M, :N]
