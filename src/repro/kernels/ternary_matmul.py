"""Pallas TPU kernel: dense × packed-ternary matmul.

    y[M, N] = scale * ( x[M, K] @ (pos - neg)[K, N] )

with the ternary matrix stored as two uint32 bitplanes packed along the
*output* dim (C-order of a [K, N] weight): planes have shape [K, N//32].

TPU adaptation of the paper's §2.2 "binary vector" computation: the ternary
delta streams HBM→VMEM at 2 bits/param (16x less bandwidth than bf16), is
unpacked to ±1 tiles in-register, and contracts on the MXU.  Decode-time
expert application is memory-bound, so the bandwidth saving is the win;
the unpack ALU work rides free under the matmul.

Grid: (M/BM, N/BN, K/BK), K innermost for accumulation in the VMEM output
block.  Block shapes keep the MXU dims at 128 multiples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.tpu_params import matmul_cost, tpu_compiler_params

LANE = 32


def _kernel(x_ref, pos_ref, neg_ref, scale_ref, o_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...]                                   # [BM, BK]
    pw = pos_ref[...]                                 # [BK, BN//32] uint32
    nw = neg_ref[...]
    shifts = jnp.arange(LANE, dtype=jnp.uint32)[None, None, :]
    pb = ((pw[:, :, None] >> shifts) & jnp.uint32(1)).astype(jnp.int8)
    nb = ((nw[:, :, None] >> shifts) & jnp.uint32(1)).astype(jnp.int8)
    w = (pb - nb).reshape(pw.shape[0], pw.shape[1] * LANE)  # [BK, BN]
    acc = jnp.dot(xb.astype(jnp.float32), w.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    o_ref[...] += acc

    @pl.when(k == n_k - 1)
    def _scale():
        o_ref[...] *= scale_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def ternary_matmul(x: jax.Array, pos: jax.Array, neg: jax.Array,
                   scale: jax.Array, *, bm: int = 128, bn: int = 128,
                   bk: int = 128, interpret: bool = True) -> jax.Array:
    """x: [M, K] float; pos/neg: [K, N//32] uint32; scale: scalar f32.
    Returns [M, N] f32."""
    M, K = x.shape
    Kp, Wn = pos.shape
    assert Kp == K, (Kp, K)
    N = Wn * LANE

    bm = min(bm, M)
    bk = min(bk, K)
    bn = min(bn, N)
    assert bn % LANE == 0
    pad_m, pad_k, pad_n = (-M) % bm, (-K) % bk, (-N) % bn
    if pad_m or pad_k:
        x = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        pos = jnp.pad(pos, ((0, pad_k), (0, pad_n // LANE)))
        neg = jnp.pad(neg, ((0, pad_k), (0, pad_n // LANE)))
    Mp, Kpd, Np = M + pad_m, K + pad_k, N + pad_n
    n_k = Kpd // bk

    grid = (Mp // bm, Np // bn, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn // LANE), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn // LANE), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        # i/j tiles are independent; k accumulates into the output block
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary"), interpret=interpret),
        cost_estimate=matmul_cost(Mp, Np, Kpd,
                                  elem_bytes=x.dtype.itemsize),
        interpret=interpret,
    )(x, pos, neg, scale.reshape(1, 1).astype(jnp.float32))
    return out[:M, :N]
