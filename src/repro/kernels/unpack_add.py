"""Pallas TPU kernel: fused ternary decompress + add (expert loading).

    W_out[M, N] = W_base[M, N] + scale * (pos - neg)[M, N]

planes packed along the last dim: [M, N//32] uint32.  One pass over the
base weight: HBM traffic is  base(2B) + 2bits  per param instead of the
naive  base(2B) + dense-delta(2B) + write(2B)  of materialise-then-add —
this is the swap-latency fast path of the paper's Table 5 on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tpu_params import streaming_cost, tpu_compiler_params

LANE = 32


def _kernel(base_ref, pos_ref, neg_ref, scale_ref, o_ref):
    pw = pos_ref[...]
    nw = neg_ref[...]
    shifts = jnp.arange(LANE, dtype=jnp.uint32)[None, None, :]
    pb = ((pw[:, :, None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)
    nb = ((nw[:, :, None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)
    delta = (pb - nb).reshape(pw.shape[0], pw.shape[1] * LANE)
    base = base_ref[...].astype(jnp.float32)
    o_ref[...] = (base + scale_ref[0, 0] * delta).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def unpack_add(base: jax.Array, pos: jax.Array, neg: jax.Array,
               scale: jax.Array, *, bm: int = 256, bn: int = 512,
               interpret: bool = True) -> jax.Array:
    """base: [M, N]; pos/neg: [M, N//32] uint32; scale scalar.  Returns
    base + scale*(pos-neg) in base.dtype."""
    M, N = base.shape
    assert pos.shape == (M, N // LANE), (pos.shape, base.shape)
    bm = min(bm, M)
    bn = min(bn, N)
    assert bn % LANE == 0
    pad_m, pad_n = (-M) % bm, (-N) % bn
    if pad_m or pad_n:
        base = jnp.pad(base, ((0, pad_m), (0, pad_n)))
        pos = jnp.pad(pos, ((0, pad_m), (0, pad_n // LANE)))
        neg = jnp.pad(neg, ((0, pad_m), (0, pad_n // LANE)))
    Mp, Np = base.shape

    out = pl.pallas_call(
        _kernel,
        grid=(Mp // bm, Np // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn // LANE), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn // LANE), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), base.dtype),
        compiler_params=tpu_compiler_params(("parallel", "parallel"),
                                            interpret=interpret),
        cost_estimate=streaming_cost(
            Mp * Np,
            in_bytes_per_elem=base.dtype.itemsize + 0.25,
            out_bytes_per_elem=float(base.dtype.itemsize)),
        interpret=interpret,
    )(base, pos, neg, scale.reshape(1, 1).astype(jnp.float32))
    return out[:M, :N]
