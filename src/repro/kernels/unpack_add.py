"""Pallas TPU kernels: fused ternary decompress + add (expert loading).

Single-expert form (the PR-1 swap fast path):

    W_out[M, N] = W_base[M, N] + scale * (pos - neg)[M, N]

Multi-expert form (``unpack_add_many`` — merged-ensemble mode):

    W_out[M, N] = W_base[M, N] + sum_e scale[e] * (pos_e - neg_e)[M, N]

planes packed along the last dim: [M, ceil(N/32)] uint32 (bits >= N in the
last word must be zero — that is what the pack kernels emit).  One pass over
the base weight: HBM traffic is  base(2B) + E * 2bits  per param instead of
E full read-modify-write sweeps (base 3*2B each) of applying the experts one
at a time — the multi-expert generalisation of the paper's Table-5 swap
claim.  The expert grid dimension accumulates with a round-trip through the
output dtype per expert, so the fused result is bit-identical to looping the
single-expert kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tpu_params import (lane_block, streaming_cost,
                                      tpu_compiler_params)

LANE = 32


def _unpack_delta(pw, nw):
    shifts = jnp.arange(LANE, dtype=jnp.uint32)[None, None, :]
    pb = ((pw[:, :, None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)
    nb = ((nw[:, :, None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)
    return (pb - nb).reshape(pw.shape[0], pw.shape[1] * LANE)


def _kernel(base_ref, pos_ref, neg_ref, scale_ref, o_ref):
    delta = _unpack_delta(pos_ref[...], neg_ref[...])
    base = base_ref[...].astype(jnp.float32)
    o_ref[...] = (base + scale_ref[0, 0] * delta).astype(o_ref.dtype)


def _pad_inputs(base, pos, neg, bm, bn):
    """Pad base to whole blocks and planes to matching word counts."""
    M, N = base.shape
    Wn = -(-N // LANE)
    pad_m, pad_n = (-M) % bm, (-N) % bn
    if pad_m or pad_n:
        base = jnp.pad(base, ((0, pad_m), (0, pad_n)))
    Np = N + pad_n
    pad_w = Np // LANE - Wn
    plane_pad = [(0, 0)] * (pos.ndim - 2) + [(0, pad_m), (0, pad_w)]
    if pad_m or pad_w:
        pos = jnp.pad(pos, plane_pad)
        neg = jnp.pad(neg, plane_pad)
    return base, pos, neg


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def unpack_add(base: jax.Array, pos: jax.Array, neg: jax.Array,
               scale: jax.Array, *, bm: int = 256, bn: int = 512,
               interpret: bool = True) -> jax.Array:
    """base: [M, N]; pos/neg: [M, ceil(N/32)] uint32; scale scalar.  Returns
    base + scale*(pos-neg) in base.dtype."""
    M, N = base.shape
    assert pos.shape == (M, -(-N // LANE)), (pos.shape, base.shape)
    bm = min(bm, M)
    bn = lane_block(bn, N)
    base, pos, neg = _pad_inputs(base, pos, neg, bm, bn)
    Mp, Np = base.shape

    out = pl.pallas_call(
        _kernel,
        grid=(Mp // bm, Np // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn // LANE), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn // LANE), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), base.dtype),
        compiler_params=tpu_compiler_params(("parallel", "parallel"),
                                            interpret=interpret),
        cost_estimate=streaming_cost(
            Mp * Np,
            in_bytes_per_elem=base.dtype.itemsize + 0.25,
            out_bytes_per_elem=float(base.dtype.itemsize)),
        interpret=interpret,
    )(base, pos, neg, scale.reshape(1, 1).astype(jnp.float32))
    return out[:M, :N]


def _kernel_many(base_ref, pos_ref, neg_ref, scale_ref, o_ref, *, n_e: int):
    e = pl.program_id(2)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = base_ref[...]

    delta = _unpack_delta(pos_ref[0], neg_ref[0])
    acc = o_ref[...].astype(jnp.float32) + scale_ref[0, 0] * delta
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def unpack_add_many(base: jax.Array, pos: jax.Array, neg: jax.Array,
                    scales: jax.Array, *, bm: int = 256, bn: int = 512,
                    interpret: bool = True) -> jax.Array:
    """Fused multi-expert merge: one sweep over base applies E experts.

    base: [M, N]; pos/neg: [E, M, ceil(N/32)] uint32 stacked planes;
    scales: [E] f32 per-expert scales.  Returns
    ``base + sum_e scales[e] * (pos_e - neg_e)`` in base.dtype, accumulated
    expert-by-expert through base.dtype so the result is bit-identical to
    looping :func:`unpack_add`.
    """
    M, N = base.shape
    E = pos.shape[0]
    assert pos.shape == (E, M, -(-N // LANE)), (pos.shape, base.shape)
    assert scales.shape == (E,), scales.shape
    bm = min(bm, M)
    bn = lane_block(bn, N)
    base, pos, neg = _pad_inputs(base, pos, neg, bm, bn)
    Mp, Np = base.shape

    out = pl.pallas_call(
        functools.partial(_kernel_many, n_e=E),
        grid=(Mp // bm, Np // bn, E),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, e: (i, j)),
            pl.BlockSpec((1, bm, bn // LANE), lambda i, j, e: (e, i, j)),
            pl.BlockSpec((1, bm, bn // LANE), lambda i, j, e: (e, i, j)),
            pl.BlockSpec((1, 1), lambda i, j, e: (e, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, e: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), base.dtype),
        # i/j tiles independent; e accumulates into the output block
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary"), interpret=interpret),
        cost_estimate=streaming_cost(
            Mp * Np,
            in_bytes_per_elem=base.dtype.itemsize + 0.25 * E,
            out_bytes_per_elem=float(base.dtype.itemsize)),
        interpret=interpret,
    )(base, pos, neg, scales.reshape(-1, 1).astype(jnp.float32))
    return out[:M, :N]
