"""Fault tolerance: simulated failure injection, straggler monitoring, and
the elastic-restart contract.

On real pods, failures surface as device errors / missed heartbeats; here
they are injected deterministically so the recovery path is *testable*:
because the data pipeline is stateless (batch = f(step)) and checkpoints
are exact and mesh-agnostic, a crashed-and-restarted run must produce
bit-identical parameters to an uninterrupted one — and the test suite
asserts exactly that (tests/test_fault_tolerance.py).
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Optional, Sequence


class SimulatedFailure(RuntimeError):
    """Stands in for a node loss / device error during a step."""


@dataclasses.dataclass
class FailureInjector:
    """Raise SimulatedFailure at the configured global steps (once each)."""

    fail_at_steps: Sequence[int] = ()

    def __post_init__(self):
        self._pending = set(self.fail_at_steps)

    def check(self, step: int) -> None:
        if step in self._pending:
            self._pending.discard(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker.  On TPU pods the mitigation is to exclude the
    slow host and re-shard (elastic restart); here we record the decision.

    slowdown_threshold: flag a step slower than threshold x EWMA.
    """

    MAX_FLAGGED = 256        # ring cap: week-long runs must not leak

    alpha: float = 0.2
    slowdown_threshold: float = 2.0
    ewma: Optional[float] = None
    flagged_steps: list = dataclasses.field(default_factory=list)
    flags: int = 0           # total flag count (survives the ring cap)

    def observe(self, step: int, seconds: float) -> bool:
        is_straggler = (self.ewma is not None
                        and seconds > self.slowdown_threshold * self.ewma)
        if is_straggler:
            self.flags += 1
            if len(self.flagged_steps) >= self.MAX_FLAGGED:
                del self.flagged_steps[0]
            self.flagged_steps.append((step, seconds, self.ewma))
        # stragglers don't poison the EWMA
        if not is_straggler:
            self.ewma = (seconds if self.ewma is None
                         else self.alpha * seconds
                         + (1 - self.alpha) * self.ewma)
        return is_straggler

    @contextlib.contextmanager
    def probe(self, step: int):
        """Time a step with the monotonic clock and observe it.  EWMA
        probes must never see a wall-clock jump (NTP slew, manual reset)
        as a straggler — ``time.monotonic`` is immune by definition."""
        t0 = time.monotonic()
        try:
            yield
        finally:
            self.observe(step, time.monotonic() - t0)

    def recommendation(self) -> str:
        if self.flags >= 3:
            return "exclude-host-and-reshard"
        if self.flags:
            return "monitor"
        return "healthy"


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Restart contract: a checkpoint saved under mesh A restores under mesh
    B when (1) arrays are logical/unsharded on disk, (2) the data pipeline
    is stateless in `step`, and (3) batch shardings are re-derived from the
    new mesh.  ``repro.checkpoint.manager.restore(shardings=...)`` implements
    (1)+(3); the pipeline guarantees (2)."""

    old_shape: tuple
    new_shape: tuple

    def valid(self) -> bool:
        # any mesh works as long as batch divides the new dp extent
        return all(x > 0 for x in self.new_shape)


@dataclasses.dataclass(frozen=True)
class RecoveryPlan:
    """Serve-side restart accounting (snapshot → journal → replay).

    The serving analogue of :class:`ElasticPlan`: a killed engine
    resumes when (1) the journal names every request and every emitted
    token, (2) the snapshot restores the in-flight wave's KV at a chunk
    boundary, and (3) streams are pure functions of (seed, uid, draw
    index) so everything past the restored state regenerates
    bit-identically — on any mesh shape, since snapshot arrays are
    logical.  ``ServeEngine.resume`` returns one of these in
    ``recovery_stats["plan"]``.
    """

    snapshot_step: Optional[int]   # restored snapshot (None = journal-only)
    journal_records: int           # intact WAL records replayed
    replayed_rows: int             # rows continued from restored KV
    reprefilled_rows: int          # rows whose KV postdated the snapshot

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)
