from repro.distributed.collectives import flash_combine, make_sp_decode_attn
from repro.distributed.fault import (ElasticPlan, FailureInjector,
                                     SimulatedFailure, StragglerMonitor)
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        make_shard_fn, param_shardings,
                                        replicated)

__all__ = ["flash_combine", "make_sp_decode_attn", "ElasticPlan",
           "FailureInjector", "SimulatedFailure", "StragglerMonitor",
           "batch_shardings", "cache_shardings", "make_shard_fn",
           "param_shardings", "replicated"]
