"""Manual-collective regions: sequence-parallel flash decoding and the
flash-stat combine.  Everything else in the system relies on GSPMD
propagation; these are the places where the communication pattern is the
algorithm (DESIGN.md §4 SP)."""

from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import AttnCfg
from repro.models.attention import decode_attention_partial


def flash_combine(parts, axis_names):
    """Numerically-stable combine of flash partials across ``axis_names``.

    parts: (o [B,H,D] f32 unnormalised, m [B,H], l [B,H]) per shard.
    """
    o, m, l = parts
    m_g = lax.pmax(m, axis_names)
    corr = jnp.where(m <= -1e38 / 2, 0.0, jnp.exp(m - m_g))
    l_g = lax.psum(l * corr, axis_names)
    o_g = lax.psum(o * corr[..., None], axis_names)
    return o_g / jnp.maximum(l_g[..., None], 1e-30)


def make_sp_decode_attn(mesh: Mesh, global_batch: Optional[int] = None
                        ) -> Callable:
    """Sequence-parallel decode attention: the KV cache is sequence-sharded
    (over 'model', or over *all* axes when the batch can't shard — the
    500k-cache layout); each shard computes flash partials over its slice
    and the output is psum-combined.  Works for any head count and any
    cache length divisible by the sequence shards.

    Returned callable matches transformer.default_decode_cache_attn:
      (q, k_new, v_new, cache_k, cache_v, pos, cur, attn_cfg)
        -> (out [B,1,Hq,D], new_k, new_v, new_pos)
    """
    from repro.distributed.sharding import batch_axes, decode_layout

    def sp_attn(q, k_new, v_new, cache_k, cache_v, pos, cur, attn_cfg,
                start=None):
        B = q.shape[0]
        gb = global_batch if global_batch is not None else B
        baxes, seq_axes = decode_layout(mesh, gb)
        n_seq = int(np.prod([mesh.shape[a] for a in seq_axes]))
        S_total = cache_k.shape[1]
        if S_total % n_seq != 0:
            # tiny caches (smoke tests): fall back to local attention
            from repro.models.transformer import default_decode_cache_attn
            return default_decode_cache_attn(q, k_new, v_new, cache_k,
                                             cache_v, pos, cur, attn_cfg)
        S_loc = S_total // n_seq

        def inner(q, k_new, v_new, ck, cv, pos_loc, cur):
            idx = jnp.zeros((), jnp.int32)
            mult = 1
            for a in reversed(seq_axes):
                idx = idx + lax.axis_index(a) * mult
                mult *= mesh.shape[a]
            slot = jnp.mod(cur, S_total)
            local_start = idx * S_loc
            in_range = (slot >= local_start) & (slot < local_start + S_loc)
            lslot = jnp.clip(slot - local_start, 0, S_loc - 1)

            k_upd = lax.dynamic_update_slice(
                ck, k_new.astype(ck.dtype), (0, lslot, 0, 0))
            v_upd = lax.dynamic_update_slice(
                cv, v_new.astype(cv.dtype), (0, lslot, 0, 0))
            pos_upd = lax.dynamic_update_slice(
                pos_loc, (cur[None]).astype(pos_loc.dtype), (lslot,))
            ck = jnp.where(in_range, k_upd, ck)
            cv = jnp.where(in_range, v_upd, cv)
            pos_loc = jnp.where(in_range, pos_upd, pos_loc)

            o, m, l = decode_attention_partial(q, ck, cv, pos_loc, cur,
                                               attn_cfg, start=start)
            out = flash_combine((o, m, l), seq_axes)
            return out[:, None].astype(q.dtype), ck, cv, pos_loc

        qspec = P(baxes, None, None, None)
        cspec = P(baxes, seq_axes, None, None)
        f = jax.shard_map(
            inner, mesh=mesh,
            in_specs=(qspec, qspec, qspec, cspec, cspec, P(seq_axes), P()),
            out_specs=(qspec, cspec, cspec, P(seq_axes)),
            check_vma=False)
        return f(q, k_new, v_new, cache_k, cache_v, pos, cur)

    return sp_attn


def batch_axes_of(mesh: Mesh):
    from repro.distributed.sharding import batch_axes
    return tuple(a for a in batch_axes(mesh) if a != "pod")


def make_vp_embed_lookup(mesh: Mesh) -> Callable:
    """Manual vocab-parallel embedding lookup.

    XLA's SPMD gather partitioner CHECK-crashes (spmd_partitioner_util.cc:
    504) on vocab-sharded gathers in partially-manual scopes (jax 0.8.2),
    and partially-manual inner regions hit a second crash ("Invalid binary
    instruction opcode copy").  This lookup therefore goes FULLY manual: it
    inspects the context mesh and takes every still-Auto axis manual, so
    each (data, model[, pod]) shard gathers from its local table slice,
    masks out-of-range ids, and psums over 'model'.  Falls back to a plain
    gather when the vocab does not divide the model axis.
    """
    n_model = mesh.shape["model"]

    def lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
        V, D = table.shape
        if V % n_model != 0:
            return table[tokens]

        am = jax.sharding.get_abstract_mesh()
        if am is None or not am.axis_names:
            return table[tokens]
        from jax.sharding import AxisType
        auto_axes = {n for n, t in zip(am.axis_names, am.axis_types)
                     if t == AxisType.Auto}
        if "model" not in auto_axes:
            return table[tokens]
        baxes = tuple(a for a in ("pod", "data")
                      if a in auto_axes and a in mesh.axis_names)

        import numpy as _np
        dp = int(_np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
        B0 = tokens.shape[0]
        pad_b = (-B0) % dp
        if pad_b:   # manual regions need even batch shards: pad + slice
            tokens = jnp.pad(tokens,
                             ((0, pad_b),) + ((0, 0),) * (tokens.ndim - 1))

        def inner(tbl, tok):
            v_loc = tbl.shape[0]
            off = lax.axis_index("model") * v_loc
            loc = tok - off
            ok = (loc >= 0) & (loc < v_loc)
            x = tbl[jnp.clip(loc, 0, v_loc - 1)]
            x = jnp.where(ok[..., None], x, jnp.zeros((), x.dtype))
            return lax.psum(x, "model")

        tok_spec = P(baxes) if tokens.ndim == 2 else P(baxes, None)
        out_spec = (P(baxes, None, None) if tokens.ndim == 2
                    else P(baxes, None, None, None))
        # mesh omitted: use the context mesh (its already-Manual axes stay
        # manual; we take all remaining Auto axes manual here)
        out = jax.shard_map(
            inner, axis_names=auto_axes,
            in_specs=(P("model", None), tok_spec),
            out_specs=out_spec, check_vma=False)(table, tokens)
        return out[:B0] if pad_b else out

    return lookup
