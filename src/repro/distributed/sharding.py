"""Logical-axis sharding rules: map every parameter / activation to a
PartitionSpec from its tree path and shape (MaxText-style, but path-regex
driven so model code stays annotation-free).

Mesh axes:
  pod   — pure data parallelism across pods (slow DCI links; gradients cross
          it ComPEFT-compressed, params replicated)
  data  — FSDP: batch + parameter/optimizer-state sharding (ZeRO-3)
  model — tensor/expert/sequence parallelism

Per-arch overrides (``ShardingOverrides``):
  head_tp=False        attention weights FSDP-only (llama4 40H, internvl2 14H,
                       rwkv6 40 heads — not divisible by |model|)
  expert_parallel=False  TP inside experts instead of expert sharding
                       (mixtral: 8 experts < |model|)
"""

from __future__ import annotations

import re
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

PyTree = Any


def _last(path: str) -> str:
    return path.split("/")[-1]


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def param_pspec(path: str, shape: tuple[int, ...], cfg: ModelConfig,
                mesh: Mesh) -> P:
    """PartitionSpec for one parameter."""
    name = _last(path)
    head_tp = cfg.sharding.head_tp
    ep = cfg.sharding.expert_parallel
    n_model = mesh.shape["model"]
    stacked = path.startswith(("blocks", "enc_blocks"))

    def S(*spec):  # prepend the scan-unit axis for stacked weights
        return P(*((None,) + spec if stacked else spec))

    # embeddings / head
    if name == "embed":
        # vocab-parallel only: sharding d_model here trips an XLA SPMD
        # partitioner CHECK (spmd_partitioner_util.cc:504) when the token
        # gather sits inside the pod-manual compressed-gradient shard_map
        # (jax 0.8.2 / bundled XLA).  Vocab-sharded gather is fine and the
        # table is small relative to HBM once /16 over `model`.  Odd vocab
        # sizes (internvl2 151655, seamless 256206) cannot shard evenly ->
        # replicated (both are <=0.5 GB tables).
        if shape[0] % n_model == 0:
            return P("model", None)
        return P(None, None)
    if name == "lm_head":
        if shape[1] % n_model == 0:
            return P("data", "model")
        return P("data", None)
    if name == "frontend_proj":
        return P(None, "data")

    # attention.  FSDP shards must sit on NON-contraction dims: sharding
    # d_model (the contraction) makes GSPMD reshard the [B,T,D] activations
    # instead of gathering the (small) weights — measured at 127 TB/device
    # of all-gathers on llama4 prefill (EXPERIMENTS.md §Perf E2).
    if name in ("wq", "wo") and len(shape) - stacked == 3:
        hq = shape[1] if stacked else shape[0]
        if name == "wq":
            hq = shape[2] if stacked else shape[1]
        if head_tp and hq % n_model == 0:
            return S("data", "model", None) if name == "wq" \
                else S("model", None, "data")
        return S(None, None, "data") if name == "wq" \
            else S(None, None, "data")
    if name in ("wk", "wv") and len(shape) - stacked == 3:
        hkv = shape[2] if stacked else shape[1]
        if head_tp and hkv % n_model == 0:
            return S("data", "model", None)
        return S(None, None, "data")
    if name in ("bq", "bk", "bv"):
        h = shape[1] if stacked else shape[0]
        if head_tp and h % n_model == 0:
            return S("model", None)
        return S(None, None)

    # dense / shared-expert FFN
    if name in ("wg", "wu", "wg_s", "wu_s", "cm_Wk"):
        return S("data", "model")
    if name in ("wo", "wo_s", "cm_Wv"):
        return S("model", "data")

    # MoE experts
    if name == "router":
        return S("data", None)
    if name in ("wg_e", "wu_e"):
        E = shape[1] if stacked else shape[0]
        if ep and E % n_model == 0:
            return S("model", "data", None)
        return S(None, "data", "model")
    if name == "wo_e":
        E = shape[1] if stacked else shape[0]
        if ep and E % n_model == 0:
            return S("model", None, "data")
        return S(None, "model", "data")

    # mamba (TP over d_inner)
    if name == "in_proj":
        return S("data", "model")
    if name == "conv_w":
        return S(None, "model")
    if name in ("conv_b", "dt_bias", "D_skip"):
        return S("model")
    if name in ("x_proj", "A_log"):
        return S("model", None)
    if name == "dt_proj":
        return S(None, "model")
    if name == "out_proj":
        return S("model", "data")

    # rwkv time-mix (head_tp=False for rwkv6 -> FSDP on OUTPUT dims)
    if name in ("Wr", "Wk", "Wv", "Wg", "cm_Wr"):
        return S(None, "data")
    if name == "Wo":
        return S(None, "data")
    if name in ("mix_w1", "decay_w1"):
        return S(None, "data")
    if name == "mix_w2":
        return S(None, None, "data")
    if name == "decay_w2":
        return S(None, "data")

    # cross-attention weights share attention rules via recursion
    # (handled by name above since they reuse wq/wk/wv/wo keys)

    # norms, scalars, small vectors: replicate
    return P(*([None] * len(shape)))


ACT_RULES_BASE = {
    "batch": "__BATCH__",
    "seq": None,
    "embed_act": None,
    "vocab_act": "model",
    "heads": "model",       # dropped if head_tp False / non-divisible
    "kv_heads": "model",
}


def make_shard_fn(mesh: Mesh, cfg: ModelConfig,
                  drop_axes: tuple = ()) -> Callable:
    """Activation-constraint callback for Runtime.shard.

    ``drop_axes``: mesh axes to omit from constraints — used inside
    manual shard_map regions (e.g. the 'pod'-manual compressed-gradient
    scope, where 'pod' may not appear in GSPMD constraints).
    """
    baxes = tuple(a for a in batch_axes(mesh) if a not in drop_axes)
    n_model = mesh.shape["model"]

    def shard(x, axes):
        # 'model' may appear at most once per spec; head axes take priority
        # over the flash-carry cq axis
        model_taken = any(
            a in ("heads", "kv_heads") and cfg.sharding.head_tp
            and x.shape[i] % n_model == 0
            for i, a in enumerate(axes))
        spec = []
        for i, a in enumerate(axes):
            if a is None:
                spec.append(None)
            elif a == "batch":
                spec.append(baxes)
            elif a in ("heads", "kv_heads"):
                ok = cfg.sharding.head_tp and (x.shape[i] % n_model == 0)
                spec.append("model" if ok else None)
            elif a == "flash_cq":
                ok = (not model_taken) and x.shape[i] % n_model == 0
                spec.append("model" if ok else None)
            elif a == "vocab_act":
                # constraints tolerate uneven dims (GSPMD pads); only input
                # shardings require divisibility
                spec.append("model")
            else:
                spec.append(None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))

    def heads_shardable(n_heads: int) -> bool:
        return cfg.sharding.head_tp and n_heads % n_model == 0

    shard.heads_shardable = heads_shardable
    return shard


def param_shardings(params_shape: PyTree, cfg: ModelConfig,
                    mesh: Mesh) -> PyTree:
    """NamedSharding tree matching an eval_shape param tree."""
    from repro.peft.lora import _path_str

    def f(path, leaf):
        ps = _path_str(path)
        return NamedSharding(mesh, param_pspec(ps, tuple(leaf.shape), cfg,
                                               mesh))

    return jax.tree_util.tree_map_with_path(f, params_shape)


def decode_layout(mesh: Mesh, global_batch: int):
    """(batch_axes_or_None, seq_axes) for decode-cache sharding.

    Normal serving: batch over (pod,)data, cache sequence over model.
    Long-context batch=1 (or any batch < dp extent): batch unsharded and
    the cache sequence sharded over EVERY mesh axis — flash decoding across
    all chips, the only viable 500k-cache layout."""
    baxes = batch_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in baxes]))
    if global_batch % dp == 0:
        return baxes, ("model",)
    return None, tuple(mesh.axis_names)


def cache_pspec(path: str, shape: tuple[int, ...], mesh: Mesh,
                global_batch: int, seq_shard: bool = True) -> P:
    """Decode-cache shardings.  KV caches [U, B, S, Hkv, D]: batch over
    data(+pod), sequence over model (SP flash decoding); recurrent states
    batch-sharded.  Batch-unshardable cells flip to all-axis sequence
    sharding (see decode_layout)."""
    name = _last(path)
    baxes, seq_axes = decode_layout(mesh, global_batch)
    if name in ("k", "v") and len(shape) == 5:
        seq = seq_axes if seq_shard else None
        return P(None, baxes, seq, None, None)
    if name == "pos" and len(shape) == 2:
        return P(None, seq_axes if seq_shard else None)
    if name in ("h", "conv"):  # mamba states: shard d_inner over model
        if baxes is None:
            return P(*((None, None) + (None,) * (len(shape) - 3) + ("model",))) \
                if name == "conv" else P(None, None, "model", None)
        return P(*((None, baxes) + (None,) * (len(shape) - 2)))
    if name in ("S", "tm", "cm") or len(shape) >= 2:
        if baxes is None:
            return P(*([None] * len(shape)))
        return P(*((None, baxes) + (None,) * (len(shape) - 2)))
    return P(*([None] * len(shape)))


def cache_shardings(cache_shape: PyTree, mesh: Mesh, global_batch: int,
                    seq_shard: bool = True) -> PyTree:
    from repro.peft.lora import _path_str

    def f(path, leaf):
        return NamedSharding(mesh, cache_pspec(_path_str(path),
                                               tuple(leaf.shape), mesh,
                                               global_batch, seq_shard))

    return jax.tree_util.tree_map_with_path(f, cache_shape)


def batch_shardings(batch_shape: PyTree, mesh: Mesh) -> PyTree:
    baxes = batch_axes(mesh)

    def f(leaf):
        return NamedSharding(
            mesh, P(*((baxes,) + (None,) * (len(leaf.shape) - 1))))

    return jax.tree_util.tree_map(f, batch_shape)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Serving rules (mesh axes: ("expert", "model"))
#
# The serving engine's contract is *bitwise* parity with the single-device
# path, which rules out any spec that shards a contraction dim (partial
# sums + psum reorder f32 accumulation).  The rules below only shard dims
# where every output element is still computed by exactly one device:
#   - embed / lm_head: vocab-parallel along "model" (the all-gather the
#     tentpole allows is exactly the logits gather this induces),
#   - stacked [E, ...] bitplane buffers: expert-parallel along "expert"
#     (each row contracts against exactly one expert's delta; pad experts
#     carry zero scales so partial sums only ever add exact zeros),
#   - KV caches: batch rows along "model" (rows are independent end to
#     end), paged block pools along the block dim (pure gather/scatter).
# Verified empirically on forced-host meshes up to (2, 4): full-TP rules
# from param_pspec diverge (psum reorder), these stay bit-identical.
# ---------------------------------------------------------------------------


def serve_mesh_axes(mesh: Mesh) -> tuple[int, int]:
    """(n_expert_shards, n_model_shards) of a serving mesh."""
    shape = dict(mesh.shape)
    return shape.get("expert", 1), shape.get("model", 1)


def serve_param_pspec(path: str, shape: tuple, mesh: Mesh) -> P:
    n_model = dict(mesh.shape).get("model", 1)
    leaf = path.rsplit("/", 1)[-1]
    if leaf == "embed" and len(shape) >= 2 and shape[0] % n_model == 0:
        return P("model", *([None] * (len(shape) - 1)))
    if leaf in ("lm_head", "unembed") and len(shape) >= 2 \
            and shape[-1] % n_model == 0:
        return P(*([None] * (len(shape) - 1)), "model")
    return P(*([None] * len(shape)))


def serve_param_shardings(params: PyTree, mesh: Mesh) -> PyTree:
    from repro.peft.lora import _path_str

    def f(path, leaf):
        return NamedSharding(mesh, serve_param_pspec(
            _path_str(path), tuple(leaf.shape), mesh))

    return jax.tree_util.tree_map_with_path(f, params)


def serve_stack_shardings(mesh: Mesh) -> tuple[NamedSharding, NamedSharding]:
    """(plane_sharding, scale_sharding) for one stacked-plane entry.

    Planes are ``[E, W]`` uint32 bitplanes (or ``[E, ...]`` dense deltas);
    scales are ``[E]``.  Both shard dim 0 along "expert"; ``build_overlay``
    propagates the expert axis onto every overlay leaf it stacks."""
    return (NamedSharding(mesh, P("expert")),
            NamedSharding(mesh, P("expert")))


def serve_kv_sharding(mesh: Mesh, shape: tuple, *,
                      layout: str = "dense") -> NamedSharding:
    """Sharding for one 5-D KV buffer on the serving mesh.

    dense  [U, B,  S,  Hkv, D]: shard batch rows along "model" — rows are
           independent through attention, so this is exact.
    paged  [U, NB, BS, Hkv, D]: shard the block pool along "model" — block
           reads/writes are gathers/scatters, also exact.
    Non-dividing dims fall back to replication (smoke configs are tiny)."""
    n_model = dict(mesh.shape).get("model", 1)
    if len(shape) == 5 and shape[1] % n_model == 0:
        return NamedSharding(mesh, P(None, "model", None, None, None))
    return NamedSharding(mesh, P(*([None] * len(shape))))


def serve_cache_shardings(cache: PyTree, mesh: Mesh, *,
                          layout: str = "dense") -> PyTree:
    """Shardings for a whole decode-cache pytree: 5-D KV buffers get
    :func:`serve_kv_sharding`; everything else (lens, starts, tables,
    active flags) stays replicated — they are host-roundtripped scalars
    and row vectors."""

    def f(leaf):
        if hasattr(leaf, "ndim") and leaf.ndim == 5:
            return serve_kv_sharding(mesh, tuple(leaf.shape), layout=layout)
        return NamedSharding(mesh, P(*([None] * getattr(leaf, "ndim", 0))))

    return jax.tree_util.tree_map(f, cache)


def train_state_shardings(state_shape: PyTree, cfg: ModelConfig,
                          mesh: Mesh) -> PyTree:
    """Shardings for a full TrainState (params / optimizer slots / EF).

    AdamW moments and EF buffers shard like their parameters; Adafactor's
    factored slots inherit the param spec minus the reduced dim."""
    from repro.peft.lora import _path_str

    def f(path, leaf):
        ps = _path_str(path)
        parts = ps.split("/")
        top = parts[0]
        if top == "step" or parts[-1] == "count":
            return NamedSharding(mesh, P())
        if top in ("params", "ef"):
            return NamedSharding(
                mesh, param_pspec("/".join(parts[1:]), tuple(leaf.shape),
                                  cfg, mesh))
        if top == "opt":
            rest = parts[1:]
            if rest and rest[0] in ("mu", "nu"):
                return NamedSharding(
                    mesh, param_pspec("/".join(rest[1:]), tuple(leaf.shape),
                                      cfg, mesh))
            if rest and rest[0] == "slots":
                slot = rest[-1]                      # vr | vc | v
                ppath = "/".join(rest[1:-1])
                # param shape is unknown here; re-derive from slot shape:
                if slot == "v":
                    spec = param_pspec(ppath, tuple(leaf.shape), cfg, mesh)
                    return NamedSharding(mesh, spec)
                # factored: vr drops the last param dim, vc the 2nd-to-last
                if slot == "vr":
                    pshape = tuple(leaf.shape) + (1,)
                    spec = param_pspec(ppath, pshape, cfg, mesh)
                    return NamedSharding(mesh, P(*tuple(spec)[:-1]))
                if slot == "vc":
                    pshape = tuple(leaf.shape[:-1]) + (1, leaf.shape[-1])
                    spec = param_pspec(ppath, pshape, cfg, mesh)
                    sp = tuple(spec)
                    return NamedSharding(mesh, P(*(sp[:-2] + (sp[-1],))))
        return NamedSharding(mesh, P(*([None] * len(leaf.shape))))

    return jax.tree_util.tree_map_with_path(f, state_shape)
