"""Versioned, self-describing wire format for :class:`~repro.expert.Expert`.

This is the artifact that actually crosses a network — the paper's whole
point is that a ComPEFT expert is small enough to fetch per query.  One
blob carries an entire expert:

    +------+---------+--------------+-----------------------+----------+
    | CPFT | version | manifest len | manifest (JSON, utf-8) | payload  |
    | 4 B  |  u8     |   u32 LE     |                       | N bytes  |
    +------+---------+--------------+-----------------------+----------+

The manifest is self-describing: representation (``dense`` / ``packed`` /
``golomb``), per-leaf path/shape/dtype/scale and payload offsets, plus a
CRC-32 of the payload so a torn or corrupted transfer is rejected instead
of silently decoded.  Each leaf additionally carries its own CRC-32, so a
**partial** payload can be verified leaf by leaf: a ranged fetch that died
mid-blob resumes from the first unfinished leaf instead of starting over
(:func:`decode_leaves` / :func:`verify_leaf`; the replicated CDN in
:mod:`repro.transport.replication` is the consumer).  The payload is the
concatenation of the per-leaf encodings for the chosen representation:

* ``GOLOMB`` — each leaf is a self-contained Golomb-Rice stream
  (:func:`repro.core.golomb.encode`); the storage-optimal form and the
  default for every transport backend.
* ``PACKED`` — each leaf is the raw ``pos`` then ``neg`` bitplane words
  (little-endian uint32; 2 bits/param) — no decode cost on arrival.
* ``DENSE``  — each leaf is the bf16 reconstruction ``signs * scale``
  (2 bytes/param).  This is the "ship the dense checkpoint" baseline the
  paper argues against; it exists so ``perf_lab --exp remote_fetch`` can
  measure the communication-cost curve against it.

All three decode back to **bit-identical** packed bitplanes (dense sends
``±scale`` values whose signs recover the ternary mask exactly), so a
fetched expert serves the same tokens as a locally loaded one.
"""

from __future__ import annotations

import json
import struct
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.expert import (DENSE, GOLOMB, PACKED, Expert, as_expert,
                          planes_from_signs)

MAGIC = b"CPFT"
VERSION = 1
WIRE_SUFFIX = ".cpft"
WIRE_FORMAT = "compeft-wire"

_HEADER = struct.Struct("<4sBI")        # magic | version | manifest nbytes
_WIRE_REPS = (DENSE, PACKED, GOLOMB)    # TERNARY has no wire advantage

_BF16 = np.dtype(jnp.bfloat16)


class TransportError(Exception):
    """Base error for the transport subsystem (backends + wire format)."""


class WireFormatError(TransportError):
    """Blob is not a (supported) ComPEFT wire artifact."""


class ChecksumError(WireFormatError):
    """Payload failed CRC verification — corrupt or truncated transfer."""


def _leaf_payload(pt, rep: str) -> bytes:
    """Encode one PackedTernary leaf for the chosen wire representation."""
    from repro.core import golomb
    from repro.core.packing import signs_np
    if rep == GOLOMB:
        return golomb.encode(signs_np(pt), float(pt.scale))
    if rep == PACKED:
        pos = np.asarray(jax.device_get(pt.pos)).astype("<u4")
        neg = np.asarray(jax.device_get(pt.neg)).astype("<u4")
        return pos.tobytes() + neg.tobytes()
    if rep == DENSE:
        vals = signs_np(pt).astype(np.float32) * float(pt.scale)
        return vals.astype(_BF16).tobytes()
    raise WireFormatError(f"representation {rep!r} has no wire encoding; "
                          f"choose from {_WIRE_REPS}")


def encode_expert(expert: Any, rep: str = GOLOMB) -> bytes:
    """Serialize an expert (or legacy artifact) into one wire blob.

    ``rep`` picks the payload encoding (see module docstring); the
    manifest records it so :func:`decode_expert` needs no out-of-band
    information.  Bytes-on-wire is ``len(result)``.
    """
    if rep not in _WIRE_REPS:
        raise WireFormatError(f"representation {rep!r} has no wire "
                              f"encoding; choose from {_WIRE_REPS}")
    ex = as_expert(expert)
    packed = ex.packed
    parts: list[bytes] = []
    leaves: list[dict] = []
    offset = 0
    for path, pt in packed.items():
        blob = _leaf_payload(pt, rep)
        leaves.append({"path": path, "shape": list(pt.shape),
                       "dtype": str(jnp.dtype(pt.orig_dtype)),
                       "scale": float(pt.scale),
                       "offset": offset, "nbytes": len(blob),
                       "crc32": zlib.crc32(blob)})
        parts.append(blob)
        offset += len(blob)
    payload = b"".join(parts)
    manifest = {"format": WIRE_FORMAT, "version": VERSION,
                "name": ex.name, "kind": ex.kind, "rep": rep,
                "density": ex.density, "alpha": ex.alpha, "meta": ex.meta,
                "leaves": leaves, "payload_nbytes": len(payload),
                "crc32": zlib.crc32(payload)}
    mj = json.dumps(manifest).encode("utf-8")
    return _HEADER.pack(MAGIC, VERSION, len(mj)) + mj + payload


def is_wire_blob(data: bytes) -> bool:
    """Cheap sniff: does this look like a ComPEFT wire artifact?"""
    return len(data) >= _HEADER.size and data[:4] == MAGIC


def peek_manifest(data: bytes) -> dict:
    """Parse and validate the header + manifest WITHOUT touching the
    payload (no checksum pass) — for listings and size accounting."""
    if len(data) < _HEADER.size:
        raise WireFormatError("blob shorter than the wire header")
    magic, version, mlen = _HEADER.unpack_from(data)
    if magic != MAGIC:
        raise WireFormatError("bad magic: not a ComPEFT wire artifact")
    if version > VERSION:
        raise WireFormatError(
            f"wire format version {version} is newer than supported "
            f"({VERSION}); upgrade the reader")
    if len(data) < _HEADER.size + mlen:
        raise WireFormatError("truncated blob: manifest incomplete")
    try:
        manifest = json.loads(data[_HEADER.size:_HEADER.size + mlen])
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireFormatError(f"manifest is not valid JSON: {e}") from e
    if manifest.get("format") != WIRE_FORMAT:
        raise WireFormatError(f"unknown manifest format "
                              f"{manifest.get('format')!r}")
    return manifest


def payload_offset(data: bytes) -> int:
    """Absolute byte offset where the payload starts (header + manifest).

    Works on any prefix of the blob that covers the 9-byte header; leaf
    ``offset`` fields are payload-relative, so a ranged read of leaf L
    spans ``[payload_offset(head) + L["offset"], ... + L["nbytes"])``.
    """
    if len(data) < _HEADER.size:
        raise WireFormatError("blob shorter than the wire header")
    magic, _, mlen = _HEADER.unpack_from(data)
    if magic != MAGIC:
        raise WireFormatError("bad magic: not a ComPEFT wire artifact")
    return _HEADER.size + mlen


def decode_leaves(manifest: dict,
                  byte_range: Optional[tuple] = None) -> list[dict]:
    """Leaf descriptors driving a (partial) payload fetch.

    Returns the manifest's leaves sorted by payload ``offset``.  With
    ``byte_range=(start, stop)`` (payload-relative, half-open) only the
    leaves intersecting that span are returned — the unit of resumption
    for a fetch that died mid-blob: everything before the range is already
    verified, everything inside it still needs bytes.
    """
    leaves = sorted(manifest["leaves"], key=lambda l: l["offset"])
    if byte_range is None:
        return leaves
    start, stop = byte_range
    return [l for l in leaves
            if l["offset"] < stop and l["offset"] + l["nbytes"] > start]


def supports_resume(manifest: dict) -> bool:
    """True when every leaf carries its own CRC-32 (blobs written by this
    version do).  Older blobs fall back to whole-payload verification —
    a mid-blob failover then refetches the full payload."""
    return all("crc32" in l for l in manifest["leaves"])


def verify_leaf(leaf: dict, raw: bytes) -> None:
    """Verify one leaf's bytes against its manifest entry.

    Raises :class:`ChecksumError` on a length or CRC mismatch — the
    caller treats that like any retryable transfer fault and re-requests
    just this leaf (possibly from a different replica).
    """
    if len(raw) != leaf["nbytes"]:
        raise ChecksumError(
            f"leaf {leaf.get('path')!r} is {len(raw)} bytes, manifest "
            f"promises {leaf['nbytes']} — truncated transfer?")
    crc = leaf.get("crc32")
    if crc is not None and zlib.crc32(raw) != crc:
        raise ChecksumError(f"leaf {leaf.get('path')!r} CRC mismatch — "
                            f"corrupt transfer")


def decode_expert(data: bytes, name: Optional[str] = None) -> Expert:
    """Inverse of :func:`encode_expert` -> :class:`~repro.expert.Expert`.

    Verifies magic, version, payload length and CRC-32 before building
    anything; raises :class:`WireFormatError` / :class:`ChecksumError` on
    a bad blob.  GOLOMB payloads stay lazily encoded on the Expert (the
    batched plane decode runs on first ``as_``/``.packed`` access, exactly
    like the cold store tier); PACKED and DENSE payloads realise planes
    immediately.
    """
    manifest = peek_manifest(data)
    _, _, mlen = _HEADER.unpack_from(data)
    payload = data[_HEADER.size + mlen:]
    if len(payload) != manifest["payload_nbytes"]:
        raise ChecksumError(
            f"payload is {len(payload)} bytes, manifest promises "
            f"{manifest['payload_nbytes']} — truncated transfer?")
    if zlib.crc32(payload) != manifest["crc32"]:
        raise ChecksumError("payload CRC mismatch — corrupt transfer")

    from repro.expert import _np_dtype
    rep = manifest["rep"]
    ex = Expert(name or manifest["name"], manifest.get("kind", "full"),
                density=manifest.get("density", 0.0),
                alpha=manifest.get("alpha", 1.0),
                meta=manifest.get("meta", {}))
    ex._manifest = manifest
    blobs: dict[str, bytes] = {}
    planes: dict[str, Any] = {}
    for leaf in manifest["leaves"]:
        path = leaf["path"]
        shape = tuple(leaf["shape"])
        dtype = _np_dtype(leaf["dtype"])
        ex._leaf_meta[path] = {"shape": shape, "orig_dtype": dtype}
        raw = payload[leaf["offset"]:leaf["offset"] + leaf["nbytes"]]
        if rep == GOLOMB:
            blobs[path] = raw
        elif rep == PACKED:
            words = np.frombuffer(raw, dtype="<u4")
            half = words.size // 2
            from repro.core.packing import PackedTernary
            planes[path] = PackedTernary(
                pos=jnp.asarray(words[:half]), neg=jnp.asarray(words[half:]),
                scale=jnp.asarray(leaf["scale"], jnp.float32),
                shape=shape, orig_dtype=dtype)
        elif rep == DENSE:
            vals = np.frombuffer(raw, dtype=_BF16).astype(np.float32)
            signs = np.sign(vals).astype(np.int8)
            planes[path] = planes_from_signs(signs, leaf["scale"], shape,
                                             dtype)
        else:
            raise WireFormatError(f"manifest names unknown representation "
                                  f"{rep!r}")
    if rep == GOLOMB:
        ex._reps[GOLOMB] = blobs
    else:
        ex._reps[PACKED] = planes
    return ex


def wire_nbytes(expert: Any, rep: str = GOLOMB) -> int:
    """Bytes-on-wire for one expert in one representation (header incl.)."""
    return len(encode_expert(expert, rep=rep))
