"""ReplicatedTransport — a small expert CDN over N transport replicas.

The paper's serving story fetches experts per query over high-latency
networks; PR 6 made a *single* origin survivable, but one origin is one
point of failure.  This module fronts N independent
:class:`~repro.transport.backends.ExpertTransport` replicas and makes a
fetch survive any single-replica failure with **zero extra fetched bytes
in the common case**.  Four layers:

1. **Placement** — a consistent-hash ring (``vnodes`` virtual nodes per
   replica).  ``publish`` writes the blob to the R =
   ``replication_factor`` owners of ``hash(name)``; ``names`` /
   ``contains`` union across ring members.  Ring positions derive from
   stable replica ids, so adding or removing one replica moves only the
   expected ~1/N of keys (bounded key movement).
2. **Selection** — per-replica health: an EWMA latency score per replica
   (:class:`repro.distributed.fault.StragglerMonitor`, fed by every
   ranged read) plus a consecutive-failure counter with a timed
   quarantine mirroring PR 6's per-expert quarantine.  Candidates are
   ordered owners-first, fastest-healthy-first; quarantined replicas sort
   last and are only touched when everyone else failed.
3. **Resumable streamed fetch** — the fetch proceeds leaf by leaf using
   the manifest's per-leaf ``offset``/``nbytes`` (ranged reads via
   :meth:`ExpertTransport.get_range`).  When a replica dies mid-blob,
   failover re-requests **only the unfinished leaves** from the next
   candidate; per-leaf CRCs verify the stitched payload
   (:func:`repro.transport.wire.verify_leaf`).  Legacy blobs without
   per-leaf CRCs degrade to whole-payload resumption.
4. **Tail control** — optional hedged reads (``hedge_ms``: fire a second
   contender over a rotated candidate order after the budget elapses;
   first complete wins, the loser is cancelled between leaves and its
   bytes are charged to ``stats.bytes_wasted``) and a revalidation sweep
   (:meth:`revalidate` / :meth:`start_sweep`) that re-probes quarantined
   replicas and re-copies under-replicated names after a host returns.

The ledger keeps the CDN's headline claim assertable: on a clean fetch
``stats.bytes_in`` equals bytes-on-wire of the blob and
``stats.bytes_wasted`` is 0 — even when a replica died mid-stream.
"""

from __future__ import annotations

import bisect
import dataclasses
import hashlib
import threading
import time
from typing import Any, Iterable, Optional, Sequence

from repro.distributed.fault import StragglerMonitor
from repro.expert import Expert
from repro.transport.backends import _DEADLINE, ExpertTransport
from repro.transport.retry import (DeadlineExceeded, ExpertNotFound,
                                   RetriesExhausted, RetryPolicy,
                                   is_retryable)
from repro.transport.wire import (ChecksumError, TransportError,
                                  WireFormatError, _HEADER, decode_expert,
                                  decode_leaves, encode_expert, peek_manifest,
                                  payload_offset, supports_resume, verify_leaf)


def _hash64(key: str) -> int:
    """Stable 64-bit ring position (independent of PYTHONHASHSEED)."""
    return int.from_bytes(
        hashlib.blake2b(key.encode("utf-8"), digest_size=8).digest(), "big")


class _HedgeCancelled(Exception):
    """The other hedge contender won; unwind quietly."""


@dataclasses.dataclass
class _ReplicaHealth:
    monitor: StragglerMonitor
    observations: int = 0
    failures: int = 0
    quarantined_until: Optional[float] = None
    quarantines: int = 0
    last_error: str = ""


class _FetchState:
    """Progress of one (possibly multi-replica) resumable fetch: the
    verified head + manifest, the contiguous payload prefix reusable from
    the probe, and the per-leaf bytes already verified.  Failover hands
    this to the next replica so finished work is never refetched."""

    __slots__ = ("raw_head", "manifest", "payload_abs", "head", "prefix",
                 "leaves", "got", "fetched", "wasted")

    def __init__(self):
        self.raw_head: Optional[bytes] = None   # blob[0:...] as fetched
        self.manifest: Optional[dict] = None
        self.payload_abs = 0                    # header + manifest nbytes
        self.head = b""                         # verified header+manifest
        self.prefix = b""                       # payload[0:len) from probe
        self.leaves: list[dict] = []
        self.got: dict[str, bytes] = {}
        self.fetched = 0                        # bytes pulled off replicas
        self.wasted = 0                         # fetched but unusable

    def assemble(self) -> bytes:
        return self.head + b"".join(self.got[l["path"]] for l in self.leaves)


class ReplicatedTransport(ExpertTransport):
    """Fetch/publish experts across a fleet of transport replicas.

    ``replicas`` is a sequence of any :class:`ExpertTransport` instances
    (mix freely: HTTP origins, filesystem mounts, simulated links).
    ``replica_ids`` (optional) are the stable identities hashed onto the
    ring — pass them when replicas can join/leave so surviving replicas
    keep their ring positions.  See the module docstring for the four
    layers; knobs:

    * ``replication_factor`` — R owners per name (clamped to fleet size).
    * ``hedge_ms`` — tail-latency budget; ``None`` disables hedging.
    * ``quarantine_after`` / ``quarantine_probe_s`` — consecutive
      failures before a replica is benched, and for how long.
    * ``probe_bytes`` — first ranged read size; covers header + manifest
      and, for small blobs, the whole payload (then a fetch is exactly
      one request and "zero extra bytes" is literal).
    """

    def __init__(self, replicas: Sequence[ExpertTransport], *,
                 replication_factor: int = 2,
                 hedge_ms: Optional[float] = None,
                 quarantine_after: int = 3,
                 quarantine_probe_s: float = 30.0,
                 vnodes: int = 64,
                 probe_bytes: int = 65536,
                 replica_ids: Optional[Sequence[str]] = None,
                 retry: Optional[RetryPolicy] = None):
        super().__init__(retry=retry)
        self.replicas = list(replicas)
        if not self.replicas:
            raise ValueError("ReplicatedTransport needs at least 1 replica")
        if replication_factor < 1:
            raise ValueError("replication_factor must be >= 1")
        if replica_ids is not None and len(replica_ids) != len(self.replicas):
            raise ValueError("replica_ids must match replicas 1:1")
        self.replica_ids = (list(replica_ids) if replica_ids is not None
                            else [f"replica-{i}" for i in
                                  range(len(self.replicas))])
        self.replication_factor = min(replication_factor, len(self.replicas))
        self.hedge_ms = hedge_ms
        self.quarantine_after = quarantine_after
        self.quarantine_probe_s = quarantine_probe_s
        self.probe_bytes = int(probe_bytes)
        self._ring: list[tuple[int, int]] = sorted(
            (_hash64(f"{rid}#{v}"), i)
            for i, rid in enumerate(self.replica_ids)
            for v in range(vnodes))
        self._ring_points = [p for p, _ in self._ring]
        self._health = [_ReplicaHealth(monitor=StragglerMonitor())
                        for _ in self.replicas]
        self._health_lock = threading.Lock()
        self._published: set[str] = set()
        self._sweep_thread: Optional[threading.Thread] = None
        self._sweep_stop: Optional[threading.Event] = None

    # ---- placement -----------------------------------------------------
    def _owners(self, name: str) -> list[int]:
        """The R distinct replicas owning ``hash(name)``, clockwise."""
        pos = bisect.bisect(self._ring_points, _hash64(name))
        owners: list[int] = []
        for k in range(len(self._ring)):
            _, ri = self._ring[(pos + k) % len(self._ring)]
            if ri not in owners:
                owners.append(ri)
                if len(owners) == self.replication_factor:
                    break
        return owners

    # ---- health & selection --------------------------------------------
    def _in_quarantine(self, ri: int, now: float) -> bool:
        until = self._health[ri].quarantined_until
        return until is not None and now < until

    def _mark_failure(self, ri: int, err: Exception) -> None:
        now = time.monotonic()
        with self._health_lock:
            st = self._health[ri]
            st.failures += 1
            st.last_error = f"{type(err).__name__}: {err}"
            if st.failures >= self.quarantine_after:
                if st.quarantined_until is None or now >= st.quarantined_until:
                    st.quarantines += 1
                st.quarantined_until = now + self.quarantine_probe_s

    def _mark_success(self, ri: int) -> None:
        with self._health_lock:
            st = self._health[ri]
            st.failures = 0
            st.quarantined_until = None
            st.last_error = ""

    def _observe(self, ri: int, seconds: float) -> None:
        with self._health_lock:
            st = self._health[ri]
            st.observations += 1
            st.monitor.observe(st.observations, seconds)

    def _ordered(self, name: str) -> list[int]:
        """Candidate order: owners before non-owners, fastest known EWMA
        first (unprobed replicas sort fastest — natural exploration),
        quarantined replicas last (touched only when all else failed)."""
        now = time.monotonic()
        owner_set = set(self._owners(name))
        with self._health_lock:
            def score(i):
                ew = self._health[i].monitor.ewma
                return (0 if i in owner_set else 1,
                        ew if ew is not None else 0.0, i)
            active = [i for i in range(len(self.replicas))
                      if not self._in_quarantine(i, now)]
            benched = [i for i in range(len(self.replicas))
                       if self._in_quarantine(i, now)]
            active.sort(key=score)
            benched.sort(key=lambda i: self._health[i].quarantined_until)
        return active + benched

    # ---- resumable streamed fetch --------------------------------------
    def _ensure_manifest(self, ri: int, name: str, st: _FetchState) -> None:
        """Fetch enough of the blob head to know the manifest (resumable:
        a later replica continues from wherever the head fetch died)."""
        if st.manifest is not None:
            return
        r = self.replicas[ri]
        if st.raw_head is None or len(st.raw_head) < _HEADER.size:
            have = len(st.raw_head) if st.raw_head else 0
            t0 = time.monotonic()
            chunk = r.get_range(name, have, max(self.probe_bytes - have,
                                                _HEADER.size))
            self._observe(ri, time.monotonic() - t0)
            st.fetched += len(chunk)
            st.raw_head = (st.raw_head or b"") + chunk
        if len(st.raw_head) < _HEADER.size:
            raise WireFormatError(
                f"blob for {name!r} shorter than the wire header")
        need = payload_offset(st.raw_head)      # validates magic too
        if len(st.raw_head) < need:
            t0 = time.monotonic()
            more = r.get_range(name, len(st.raw_head),
                               need - len(st.raw_head))
            self._observe(ri, time.monotonic() - t0)
            st.fetched += len(more)
            st.raw_head += more
            if len(st.raw_head) < need:
                raise ChecksumError(
                    f"short read of {name!r} manifest: have "
                    f"{len(st.raw_head)} of {need} bytes")
        manifest = peek_manifest(st.raw_head[:need])
        st.manifest = manifest
        st.payload_abs = need
        st.head = st.raw_head[:need]
        st.prefix = st.raw_head[need:]
        if supports_resume(manifest) and manifest["leaves"]:
            st.leaves = decode_leaves(manifest)
        else:
            # Legacy blob without per-leaf CRCs: resume at whole-payload
            # granularity, verified by the manifest's payload CRC.
            st.leaves = [{"path": "__payload__", "offset": 0,
                          "nbytes": manifest["payload_nbytes"],
                          "crc32": manifest["crc32"]}]

    def _pull_leaves(self, ri: int, name: str, st: _FetchState,
                     cancel: Optional[threading.Event]) -> None:
        """Fetch + verify every still-unfinished leaf from replica ``ri``.
        Bytes already in ``st`` (probe prefix, finished leaves) are never
        re-requested — that is the zero-waste failover invariant."""
        r = self.replicas[ri]
        for leaf in st.leaves:
            path = leaf["path"]
            if path in st.got:
                continue
            if cancel is not None and cancel.is_set():
                raise _HedgeCancelled()
            off, n = leaf["offset"], leaf["nbytes"]
            pref = len(st.prefix)
            pulled = 0
            if off + n <= pref:
                raw = st.prefix[off:off + n]
            else:
                head_part = st.prefix[off:pref] if off < pref else b""
                start_abs = st.payload_abs + max(off, pref)
                need = n - len(head_part)
                t0 = time.monotonic()
                chunk = r.get_range(name, start_abs, need)
                self._observe(ri, time.monotonic() - t0)
                st.fetched += len(chunk)
                pulled = len(chunk)
                if len(chunk) != need:
                    st.wasted += pulled
                    raise ChecksumError(
                        f"short range read for leaf {path!r} of {name!r}: "
                        f"got {len(chunk)} of {need} bytes")
                raw = head_part + chunk
            try:
                verify_leaf(leaf, raw)
            except ChecksumError:
                # A corrupt prefix region must not poison the next
                # replica: truncate the prefix back to this leaf's start
                # so failover refetches it from clean bytes.
                if off < pref:
                    st.wasted += min(pref, off + n) - off
                    st.prefix = st.prefix[:off]
                st.wasted += pulled
                raise
            st.got[path] = raw

    def _resumable_fetch(self, name: str, pol: RetryPolicy,
                         st: _FetchState, rotate: int = 0,
                         cancel: Optional[threading.Event] = None) -> bytes:
        """Failover loop: walk the candidate order, resuming the same
        :class:`_FetchState` on each replica; back off between passes."""
        t0 = time.monotonic()
        absent: set[int] = set()
        last: Optional[Exception] = None
        for attempt in range(pol.max_attempts):
            if attempt:
                delay = pol.backoff_s(attempt - 1, name)
                if (pol.deadline_s is not None
                        and time.monotonic() - t0 + delay > pol.deadline_s):
                    raise DeadlineExceeded(
                        f"fetch of {name!r} would exceed the "
                        f"{pol.deadline_s}s deadline after {attempt} "
                        f"pass(es); last error: {last}") from last
                if delay:
                    if cancel is not None:
                        if cancel.wait(delay):
                            raise _HedgeCancelled()
                    else:
                        time.sleep(delay)
            order = self._ordered(name)
            if rotate and len(order) > 1:
                k = rotate % len(order)
                order = order[k:] + order[:k]
            for ri in order:
                if ri in absent:
                    continue
                if cancel is not None and cancel.is_set():
                    raise _HedgeCancelled()
                try:
                    self._ensure_manifest(ri, name, st)
                    self._pull_leaves(ri, name, st, cancel)
                    self._mark_success(ri)
                    return st.assemble()
                except _HedgeCancelled:
                    raise
                except ExpertNotFound as e:
                    # Absent on this replica is not a health failure and
                    # not absence everywhere — but absent on ALL
                    # candidates is definitive.
                    absent.add(ri)
                    last = e
                    if absent >= set(order):
                        raise ExpertNotFound(
                            f"no replica holds {name!r} "
                            f"(asked {len(order)})") from e
                except Exception as e:
                    if not is_retryable(e):
                        raise
                    self._mark_failure(ri, e)
                    with self._stats_lock:
                        self.stats.retries += 1
                    last = e
        raise RetriesExhausted(
            f"fetch of {name!r} failed after {pol.max_attempts} pass(es) "
            f"over {len(self.replicas)} replica(s); last error: {last}") \
            from last

    # ---- hedged reads --------------------------------------------------
    def _hedged_fetch(self, name: str, pol: RetryPolicy
                      ) -> tuple[bytes, _FetchState]:
        """Primary contender starts immediately; if it has not finished
        within ``hedge_ms``, a second contender races over a rotated
        candidate order.  First complete blob wins; the loser is
        cancelled between leaves and its bytes are charged to
        ``stats.bytes_wasted`` when it unwinds."""
        import concurrent.futures as cf
        hedge_s = float(self.hedge_ms) / 1000.0
        states = [_FetchState(), _FetchState()]
        cancels = [threading.Event(), threading.Event()]
        pool = cf.ThreadPoolExecutor(max_workers=2,
                                     thread_name_prefix="cdn-hedge")

        def run(k: int, rot: int) -> bytes:
            prev = getattr(_DEADLINE, "until", None)
            if pol.deadline_s is not None:
                _DEADLINE.until = time.monotonic() + pol.deadline_s
            try:
                return self._resumable_fetch(name, pol, states[k],
                                             rotate=rot, cancel=cancels[k])
            finally:
                _DEADLINE.until = prev

        futs = [pool.submit(run, 0, 0)]
        done, _ = cf.wait(futs, timeout=hedge_s)
        if not done:
            futs.append(pool.submit(run, 1, 1))
        try:
            pending = set(futs)
            errors: list[Exception] = []
            while pending:
                done, pending = cf.wait(pending,
                                        return_when=cf.FIRST_COMPLETED)
                for f in done:
                    try:
                        blob = f.result()
                    except _HedgeCancelled:
                        continue
                    except Exception as e:
                        errors.append(e)
                        continue
                    k = futs.index(f)
                    loser = 1 - k
                    cancels[loser].set()
                    if loser < len(futs):
                        def charge(lf, lk=loser):
                            lf.exception()          # consume, keep quiet
                            ls = states[lk]
                            with self._stats_lock:
                                self.stats.bytes_wasted += (ls.fetched
                                                            + ls.wasted)
                        futs[loser].add_done_callback(charge)
                    return blob, states[k]
            with self._stats_lock:          # both contenders failed: all
                for ls in states:           # their bytes bought nothing
                    self.stats.bytes_wasted += ls.fetched + ls.wasted
            raise errors[0] if errors else RetriesExhausted(
                f"hedged fetch of {name!r}: every contender failed")
        finally:
            pool.shutdown(wait=False)

    # ---- public API ----------------------------------------------------
    def publish(self, expert: Any, rep: Optional[str] = None) -> dict:
        """Encode once, upload to every ring owner of the name.  Returns
        ``{name, rep, nbytes, replicas}`` — ``nbytes`` is bytes-on-wire
        per copy; ``bytes_out`` charges the full R-way fan-out."""
        rep = rep or self.default_rep
        blob = encode_expert(expert, rep=rep)
        name = getattr(expert, "name", None) or "expert"
        owners = self._owners(name)
        for ri in owners:
            self.replicas[ri]._put(name, blob)
        with self._stats_lock:
            self.stats.publishes += 1
            self.stats.bytes_out += len(blob) * len(owners)
        self._published.add(name)
        return {"name": name, "rep": rep, "nbytes": len(blob),
                "replicas": owners}

    def fetch_bytes(self, name: str,
                    retry: Optional[RetryPolicy] = None) -> bytes:
        """Resumable multi-replica download of the raw wire blob.  The
        stitched result is leaf-CRC verified even when multiple replicas
        contributed bytes."""
        pol = retry or self.retry
        st = _FetchState()
        prev = getattr(_DEADLINE, "until", None)
        if pol.deadline_s is not None:
            _DEADLINE.until = time.monotonic() + pol.deadline_s
        t0 = time.monotonic()
        try:
            if self.hedge_ms is not None and len(self.replicas) > 1:
                blob, st = self._hedged_fetch(name, pol)
            else:
                blob = self._resumable_fetch(name, pol, st)
        except Exception:
            with self._stats_lock:
                # a failed fetch bought nothing: everything it pulled
                # (including verified leaves) is waste
                self.stats.bytes_wasted += st.fetched
                self.stats.fetch_seconds += time.monotonic() - t0
            raise
        finally:
            _DEADLINE.until = prev
        dt = time.monotonic() - t0
        with self._stats_lock:
            self.stats.fetches += 1
            self.stats.bytes_in += st.fetched
            self.stats.bytes_wasted += st.wasted
            self.stats.fetch_seconds += dt
        return blob

    def fetch_expert(self, name: str,
                     retry: Optional[RetryPolicy] = None
                     ) -> tuple[Expert, int]:
        blob = self.fetch_bytes(name, retry=retry)
        return decode_expert(blob, name=name), len(blob)

    def contains(self, name: str) -> bool:
        """True if ANY replica holds the name.  False only when every
        reachable replica definitively answered "absent" AND all replicas
        were reachable; otherwise the unreachability surfaces."""
        unreachable: Optional[Exception] = None
        for r in self.replicas:
            try:
                if r.contains(name):
                    return True
            except TransportError as e:
                unreachable = e
        if unreachable is not None:
            raise unreachable
        return False

    def _names(self) -> list[str]:
        out: set[str] = set()
        for r in self.replicas:
            try:
                out.update(r._names())
            except TransportError:
                continue        # unreachable / cannot enumerate
        return sorted(out)

    def _put(self, name: str, blob: bytes) -> None:
        for ri in self._owners(name):
            self.replicas[ri]._put(name, blob)
        self._published.add(name)

    def _get(self, name: str) -> bytes:
        # whole-blob fallback (base-class paths); the resumable fetch
        # above is the real read path
        last: Optional[Exception] = None
        for ri in self._ordered(name):
            try:
                return self.replicas[ri]._get(name)
            except Exception as e:
                if not is_retryable(e) and not isinstance(e, ExpertNotFound):
                    raise
                last = e
        raise last if last is not None else ExpertNotFound(name)

    # ---- health / revalidation ----------------------------------------
    def health(self) -> dict:
        now = time.monotonic()
        with self._health_lock:
            reps = []
            for i, st in enumerate(self._health):
                q_for = (max(0.0, st.quarantined_until - now)
                         if st.quarantined_until is not None else 0.0)
                reps.append({"replica": i, "id": self.replica_ids[i],
                             "ewma_s": st.monitor.ewma,
                             "failures": st.failures,
                             "flagged": st.monitor.flags,
                             "recommendation": st.monitor.recommendation(),
                             "quarantined_for_s": q_for,
                             "quarantines": st.quarantines,
                             "last_error": st.last_error})
        return {"replicas": reps,
                "quarantined": sum(1 for r in reps
                                   if r["quarantined_for_s"] > 0),
                "replication_factor": self.replication_factor}

    def _probe(self, ri: int) -> bool:
        """Is the replica answering at all?  A definitive "absent" from a
        contains probe still proves reachability."""
        r = self.replicas[ri]
        try:
            r._names()
            return True
        except TransportError as e:
            if "enumerate" not in str(e):
                return False
        probe_name = next(iter(self._published), None)
        if probe_name is None:
            return True
        try:
            r.contains(probe_name)
            return True
        except TransportError:
            return False

    def revalidate(self, repair: bool = True) -> dict:
        """One sweep pass: re-probe unhealthy replicas (recover or
        re-bench them) and, with ``repair=True``, copy any
        under-replicated name back onto its missing ring owners from a
        surviving holder.  Returns
        ``{probed, recovered, repaired, under_replicated}``."""
        out = {"probed": 0, "recovered": 0, "repaired": 0,
               "under_replicated": 0}
        now = time.monotonic()
        with self._health_lock:
            suspects = [i for i, st in enumerate(self._health)
                        if st.failures > 0 or self._in_quarantine(i, now)]
        for ri in suspects:
            out["probed"] += 1
            if self._probe(ri):
                self._mark_success(ri)
                out["recovered"] += 1
            else:
                self._mark_failure(
                    ri, TransportError("revalidation probe failed"))
        if not repair:
            return out
        for name in sorted(set(self._names()) | self._published):
            holders: list[int] = []
            missing: list[int] = []
            unknown = False
            for ri in self._owners(name):
                try:
                    (holders if self.replicas[ri].contains(name)
                     else missing).append(ri)
                except TransportError:
                    unknown = True
            if not missing:
                continue
            blob: Optional[bytes] = None
            for src in holders or [j for j in range(len(self.replicas))
                                   if j not in missing]:
                try:
                    blob = self.replicas[src]._get(name)
                    break
                except Exception:
                    continue
            if blob is None:
                out["under_replicated"] += 1
                continue
            repaired_any = False
            for ri in missing:
                try:
                    self.replicas[ri]._put(name, blob)
                    out["repaired"] += 1
                    repaired_any = True
                except Exception:
                    pass
            if unknown or not repaired_any:
                out["under_replicated"] += 1
        return out

    def start_sweep(self, interval_s: float = 5.0,
                    repair: bool = True) -> None:
        """Run :meth:`revalidate` in a daemon thread every
        ``interval_s`` until :meth:`stop_sweep`."""
        if self._sweep_thread is not None:
            return
        stop = threading.Event()

        def loop():
            while not stop.wait(interval_s):
                try:
                    self.revalidate(repair=repair)
                except Exception:
                    pass            # the sweep must never kill serving

        t = threading.Thread(target=loop, daemon=True,
                             name="cdn-revalidate")
        self._sweep_stop = stop
        self._sweep_thread = t
        t.start()

    def stop_sweep(self) -> None:
        if self._sweep_thread is None:
            return
        self._sweep_stop.set()
        self._sweep_thread.join(timeout=5.0)
        self._sweep_thread = None
        self._sweep_stop = None
