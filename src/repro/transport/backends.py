"""Transport backends: how a wire-format expert blob actually moves.

:class:`ExpertTransport` is the abstraction the serving stack's REMOTE
tier (:class:`~repro.serve.expert_cache.RemoteExpertStore`) sits on: a
named blob store with ``publish`` (encode + upload) and ``fetch``
(download + decode) and per-transport byte/latency accounting.  Three
backends ship:

* :class:`LocalTransport`      — a directory of ``<name>.cpft`` files
  (shared filesystem / object-store mount).
* :class:`SimulatedNetworkTransport` — in-process store behind a
  configurable bandwidth / latency / loss model.  Deterministic (seeded),
  so benchmarks of the paper's communication-cost claim are reproducible
  without real network flakiness (``perf_lab --exp remote_fetch``).
* :class:`HTTPTransport`       — fetch over HTTP(S) with stdlib urllib
  (no extra dependencies); any static file server works, e.g.
  :func:`serve_local_http` over a :class:`LocalTransport` root.

Backends are thread-safe for concurrent ``fetch`` of distinct names —
the prefetch pipeline in :class:`~repro.serve.expert_cache.DeviceCache`
issues them from worker threads so transfer overlaps decode.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Any, Optional

import numpy as np

from repro.expert import GOLOMB, Expert
from repro.transport.wire import (WIRE_SUFFIX, TransportError, decode_expert,
                                  encode_expert)


@dataclasses.dataclass
class TransportStats:
    publishes: int = 0
    fetches: int = 0
    bytes_out: int = 0
    bytes_in: int = 0
    fetch_seconds: float = 0.0
    retries: int = 0

    def as_dict(self):
        return dataclasses.asdict(self)


class ExpertTransport:
    """Named blob store for wire-format experts.

    Subclasses implement ``_put(name, blob)``, ``_get(name) -> bytes``
    and ``_names() -> list[str]``; this base class owns encode/decode and
    the :class:`TransportStats` ledger.
    """

    default_rep = GOLOMB

    def __init__(self):
        self.stats = TransportStats()
        self._stats_lock = threading.Lock()

    # ---- public API ----------------------------------------------------
    def publish(self, expert: Any, rep: Optional[str] = None) -> dict:
        """Encode ``expert`` (Expert or legacy artifact) and upload it.

        Returns ``{name, rep, nbytes}`` — ``nbytes`` is bytes-on-wire.
        """
        rep = rep or self.default_rep
        blob = encode_expert(expert, rep=rep)
        name = getattr(expert, "name", None) or "expert"
        self._put(name, blob)
        with self._stats_lock:
            self.stats.publishes += 1
            self.stats.bytes_out += len(blob)
        return {"name": name, "rep": rep, "nbytes": len(blob)}

    def fetch_bytes(self, name: str) -> bytes:
        """Download the raw wire blob for ``name`` (no decode)."""
        t0 = time.perf_counter()
        blob = self._get(name)
        dt = time.perf_counter() - t0
        with self._stats_lock:
            self.stats.fetches += 1
            self.stats.bytes_in += len(blob)
            self.stats.fetch_seconds += dt
        return blob

    def fetch(self, name: str) -> Expert:
        """Download + decode ``name`` into an :class:`Expert` (checksum
        verified; GOLOMB payloads stay lazily encoded on the Expert)."""
        return decode_expert(self.fetch_bytes(name), name=name)

    def names(self) -> list[str]:
        return self._names()

    def __contains__(self, name: str) -> bool:
        return name in self._names()

    # ---- backend hooks -------------------------------------------------
    def _put(self, name: str, blob: bytes) -> None:
        raise NotImplementedError

    def _get(self, name: str) -> bytes:
        raise NotImplementedError

    def _names(self) -> list[str]:
        raise NotImplementedError


class InMemoryTransport(ExpertTransport):
    """Dict-backed store — unit tests and the simulated-network inner
    store."""

    def __init__(self):
        super().__init__()
        self._blobs: dict[str, bytes] = {}

    def _put(self, name: str, blob: bytes) -> None:
        self._blobs[name] = blob

    def _get(self, name: str) -> bytes:
        try:
            return self._blobs[name]
        except KeyError:
            raise TransportError(f"no published expert named {name!r}") \
                from None

    def _names(self) -> list[str]:
        return list(self._blobs)


class LocalTransport(ExpertTransport):
    """Filesystem backend: one ``<name>.cpft`` file per expert under
    ``root``.  Expert names must be filesystem-safe (they are used as
    file names verbatim)."""

    def __init__(self, root: str):
        super().__init__()
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, name: str) -> str:
        return os.path.join(self.root, name + WIRE_SUFFIX)

    def _put(self, name: str, blob: bytes) -> None:
        tmp = self._path(name) + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, self._path(name))      # atomic: no torn reads

    def _get(self, name: str) -> bytes:
        try:
            with open(self._path(name), "rb") as f:
                return f.read()
        except FileNotFoundError:
            raise TransportError(
                f"no published expert named {name!r} under {self.root}") \
                from None

    def _names(self) -> list[str]:
        return sorted(f[:-len(WIRE_SUFFIX)] for f in os.listdir(self.root)
                      if f.endswith(WIRE_SUFFIX))


class SimulatedNetworkTransport(ExpertTransport):
    """A link model in front of another transport.

    ``fetch_bytes`` charges ``latency_s + nbytes / bandwidth_bps`` of real
    wall time per attempt, and with probability ``loss`` an attempt is
    dropped (the full delay is still paid, then the fetch retries, up to
    ``max_retries``).  Seeded, so a benchmark run is reproducible.
    Publishing is free: the publisher's upload is not what the paper's
    per-query retrieval claim is about.
    """

    def __init__(self, bandwidth_bps: float = 1e9, latency_s: float = 0.0,
                 loss: float = 0.0, seed: int = 0,
                 inner: Optional[ExpertTransport] = None,
                 max_retries: int = 5):
        super().__init__()
        if not (0.0 <= loss < 1.0):
            raise ValueError(f"loss must be in [0, 1), got {loss}")
        self.bandwidth_bps = float(bandwidth_bps)
        self.latency_s = float(latency_s)
        self.loss = float(loss)
        self.max_retries = max_retries
        self.inner = inner or InMemoryTransport()
        self._rng = np.random.default_rng(seed)
        self._rng_lock = threading.Lock()

    def _delay(self, nbytes: int) -> float:
        return self.latency_s + nbytes / max(self.bandwidth_bps, 1.0)

    def _dropped(self) -> bool:
        if not self.loss:
            return False
        with self._rng_lock:
            return bool(self._rng.random() < self.loss)

    def _put(self, name: str, blob: bytes) -> None:
        self.inner._put(name, blob)

    def _get(self, name: str) -> bytes:
        blob = self.inner._get(name)
        delay = self._delay(len(blob))
        for _ in range(self.max_retries):
            time.sleep(delay)
            if not self._dropped():
                return blob
            with self._stats_lock:
                self.stats.retries += 1
        raise TransportError(
            f"fetch of {name!r} dropped {self.max_retries} times "
            f"(loss={self.loss})")

    def _names(self) -> list[str]:
        return self.inner._names()


class HTTPTransport(ExpertTransport):
    """Fetch experts from ``<base_url>/<name>.cpft`` over HTTP(S).

    Read-mostly by design: any static file server fronting a
    :class:`LocalTransport` root works (see :func:`serve_local_http`).
    ``publish`` issues an HTTP PUT, which plain static servers reject —
    publish through the filesystem/object store behind the server instead.
    """

    def __init__(self, base_url: str, timeout_s: float = 30.0):
        super().__init__()
        self.base_url = base_url.rstrip("/")
        self.timeout_s = timeout_s

    def _url(self, name: str) -> str:
        from urllib.parse import quote
        return f"{self.base_url}/{quote(name)}{WIRE_SUFFIX}"

    def _request(self, name: str, method: str):
        import urllib.error
        import urllib.request
        req = urllib.request.Request(self._url(name), method=method)
        try:
            return urllib.request.urlopen(req, timeout=self.timeout_s)
        except urllib.error.HTTPError as e:
            if method == "HEAD" and e.code == 404:
                return None
            raise TransportError(
                f"HTTP {e.code} for expert {name!r} at {self._url(name)}") \
                from e
        except urllib.error.URLError as e:
            raise TransportError(
                f"cannot reach {self._url(name)}: {e.reason}") from e

    def _get(self, name: str) -> bytes:
        with self._request(name, "GET") as resp:
            return resp.read()

    def _put(self, name: str, blob: bytes) -> None:
        import urllib.error
        import urllib.request
        req = urllib.request.Request(self._url(name), data=blob,
                                     method="PUT")
        try:
            urllib.request.urlopen(req, timeout=self.timeout_s).close()
        except (urllib.error.URLError, OSError) as e:
            raise TransportError(
                f"HTTP publish to {self._url(name)} failed ({e}); static "
                "servers are read-only — publish via the store behind "
                "the server (e.g. LocalTransport on its root)") from e

    def __contains__(self, name: str) -> bool:
        resp = self._request(name, "HEAD")
        if resp is None:
            return False
        resp.close()
        return True

    def _names(self) -> list[str]:
        raise TransportError(
            "HTTPTransport cannot enumerate experts; fetch by name")


def serve_local_http(root: str, host: str = "127.0.0.1", port: int = 0):
    """Serve a :class:`LocalTransport` root over HTTP in a daemon thread.

    Returns ``(server, base_url)``; call ``server.shutdown()`` when done.
    Pairs a filesystem publisher with :class:`HTTPTransport` consumers —
    the integration tests and ``examples/remote_experts.py`` use it.
    """
    import functools
    from http.server import SimpleHTTPRequestHandler, ThreadingHTTPServer
    handler = functools.partial(SimpleHTTPRequestHandler, directory=root)
    server = ThreadingHTTPServer((host, port), handler)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    return server, f"http://{server.server_address[0]}:{server.server_address[1]}"
