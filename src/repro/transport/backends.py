"""Transport backends: how a wire-format expert blob actually moves.

:class:`ExpertTransport` is the abstraction the serving stack's REMOTE
tier (:class:`~repro.serve.expert_cache.RemoteExpertStore`) sits on: a
named blob store with ``publish`` (encode + upload) and ``fetch``
(download + decode) and per-transport byte/latency accounting.  Three
backends ship:

* :class:`LocalTransport`      — a directory of ``<name>.cpft`` files
  (shared filesystem / object-store mount).
* :class:`SimulatedNetworkTransport` — in-process store behind a
  configurable bandwidth / latency / loss model.  Deterministic (seeded),
  so benchmarks of the paper's communication-cost claim are reproducible
  without real network flakiness (``perf_lab --exp remote_fetch``).
* :class:`HTTPTransport`       — fetch over HTTP(S) with stdlib urllib
  (no extra dependencies); any static file server works, e.g.
  :func:`serve_local_http` over a :class:`LocalTransport` root.

Backends are thread-safe for concurrent ``fetch`` of distinct names —
the prefetch pipeline in :class:`~repro.serve.expert_cache.DeviceCache`
issues them from worker threads so transfer overlaps decode.

Every backend applies one uniform :class:`~repro.transport.retry.RetryPolicy`
to its fetch path: retryable failures (5xx, unreachable replica, seeded
loss, timeouts, CRC mismatch → refetch) back off and retry up to the
attempt/deadline budget; terminal failures (404, bad magic, unsupported
version) raise immediately.  See :mod:`repro.transport.retry` for the
taxonomy and :class:`~repro.transport.chaos.ChaosTransport` for the
failure-injection wrapper that exercises every branch deterministically.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Any, Callable, Optional

import numpy as np

from repro.expert import GOLOMB, Expert
from repro.transport.retry import (DEFAULT_RETRY, SIMULATED_RETRY,
                                   DeadlineExceeded, ExpertNotFound,
                                   FetchTimeout, ReplicaUnreachable,
                                   RetriesExhausted, RetryPolicy,
                                   TransientTransportError, is_retryable)
from repro.transport.wire import (WIRE_SUFFIX, TransportError, WireFormatError,
                                  decode_expert, encode_expert)


@dataclasses.dataclass
class TransportStats:
    publishes: int = 0
    fetches: int = 0
    range_fetches: int = 0
    bytes_out: int = 0
    bytes_in: int = 0
    bytes_wasted: int = 0
    fetch_seconds: float = 0.0
    retries: int = 0

    def as_dict(self):
        return dataclasses.asdict(self)


# Overall fetch deadline for the *current thread*, as a monotonic instant.
# ``_retrying`` (and the replicated fetch loop) arm it so backends that
# charge wall time — the simulated link above all — can refuse to start a
# transfer the caller will no longer wait for, instead of sleeping through
# an already-expired deadline (chaos sweeps with many blackouts would
# otherwise serially burn CI wall-clock).
_DEADLINE = threading.local()


def _deadline_remaining() -> Optional[float]:
    until = getattr(_DEADLINE, "until", None)
    return None if until is None else until - time.monotonic()


class ExpertTransport:
    """Named blob store for wire-format experts.

    Subclasses implement ``_put(name, blob)``, ``_get(name) -> bytes``
    and ``_names() -> list[str]``; this base class owns encode/decode,
    the :class:`TransportStats` ledger, and the uniform retry loop
    (``retry=`` a :class:`~repro.transport.retry.RetryPolicy`).
    """

    default_rep = GOLOMB

    def __init__(self, retry: Optional[RetryPolicy] = None):
        self.retry = retry or DEFAULT_RETRY
        self.stats = TransportStats()
        self._stats_lock = threading.Lock()

    # ---- public API ----------------------------------------------------
    def publish(self, expert: Any, rep: Optional[str] = None) -> dict:
        """Encode ``expert`` (Expert or legacy artifact) and upload it.

        Returns ``{name, rep, nbytes}`` — ``nbytes`` is bytes-on-wire.
        """
        rep = rep or self.default_rep
        blob = encode_expert(expert, rep=rep)
        name = getattr(expert, "name", None) or "expert"
        self._put(name, blob)
        with self._stats_lock:
            self.stats.publishes += 1
            self.stats.bytes_out += len(blob)
        return {"name": name, "rep": rep, "nbytes": len(blob)}

    def _timed_get(self, name: str) -> bytes:
        """One fetch attempt with byte/latency accounting (bytes that
        arrive are charged even if decode later rejects them)."""
        t0 = time.perf_counter()
        blob = self._get(name)
        dt = time.perf_counter() - t0
        with self._stats_lock:
            self.stats.fetches += 1
            self.stats.bytes_in += len(blob)
            self.stats.fetch_seconds += dt
        return blob

    def _retrying(self, name: str, attempt: Callable[[], Any],
                  retry: Optional[RetryPolicy] = None) -> Any:
        """Run ``attempt`` under the retry policy: retryable errors back
        off (seeded jitter, deterministic per name) and retry within the
        attempt/deadline budget; terminal errors raise immediately."""
        pol = retry or self.retry
        t0 = time.monotonic()
        prev_deadline = getattr(_DEADLINE, "until", None)
        if pol.deadline_s is not None:
            _DEADLINE.until = t0 + pol.deadline_s
        last: Optional[Exception] = None
        try:
            for i in range(pol.max_attempts):
                if i:
                    delay = pol.backoff_s(i - 1, name)
                    if (pol.deadline_s is not None
                            and time.monotonic() - t0 + delay
                            > pol.deadline_s):
                        raise DeadlineExceeded(
                            f"fetch of {name!r} would exceed the "
                            f"{pol.deadline_s}s deadline after {i} "
                            f"attempt(s); last error: {last}") from last
                    if delay:
                        time.sleep(delay)
                    with self._stats_lock:
                        self.stats.retries += 1
                try:
                    return attempt()
                except Exception as e:
                    if not is_retryable(e):
                        raise
                    last = e
            raise RetriesExhausted(
                f"fetch of {name!r} failed after {pol.max_attempts} "
                f"attempt(s); last error: {last}") from last
        finally:
            _DEADLINE.until = prev_deadline

    def fetch_bytes(self, name: str,
                    retry: Optional[RetryPolicy] = None) -> bytes:
        """Download the raw wire blob for ``name`` (no decode).  Retries
        transport-level failures; cannot see checksum corruption — use
        :meth:`fetch_expert` for the verified refetch-on-corruption path."""
        return self._retrying(name, lambda: self._timed_get(name), retry)

    def fetch_expert(self, name: str,
                     retry: Optional[RetryPolicy] = None
                     ) -> tuple[Expert, int]:
        """Download + decode + verify ``name``; returns ``(expert,
        bytes_on_wire)``.  The retry loop spans decode too, so a blob
        that arrives corrupt (``ChecksumError``) is *refetched* instead
        of failing the caller.  Bytes that arrived but failed
        verification are charged to ``stats.bytes_wasted`` — they crossed
        the link and bought nothing."""
        def attempt():
            blob = self._timed_get(name)
            try:
                return decode_expert(blob, name=name), len(blob)
            except WireFormatError:
                with self._stats_lock:
                    self.stats.bytes_wasted += len(blob)
                raise
        return self._retrying(name, attempt, retry)

    def get_range(self, name: str, start: int, length: int) -> bytes:
        """One ranged read of the stored blob: ``length`` bytes from
        absolute offset ``start``, clamped at end-of-blob (a probe larger
        than the blob returns the whole blob, never an error).

        No retry loop and no decode — this is the primitive the
        replicated CDN (:mod:`repro.transport.replication`) builds its
        leaf-resumable fetch on; multi-replica callers own failover.
        Charged to ``stats.range_fetches`` / ``bytes_in``.
        """
        t0 = time.perf_counter()
        chunk = self._get_range(name, int(start), int(length))
        dt = time.perf_counter() - t0
        with self._stats_lock:
            self.stats.range_fetches += 1
            self.stats.bytes_in += len(chunk)
            self.stats.fetch_seconds += dt
        return chunk

    def fetch(self, name: str) -> Expert:
        """Download + decode ``name`` into an :class:`Expert` (checksum
        verified; GOLOMB payloads stay lazily encoded on the Expert)."""
        return self.fetch_expert(name)[0]

    def names(self) -> list[str]:
        return self._names()

    def contains(self, name: str) -> bool:
        """Definitive membership: True/False when the backend can answer,
        :class:`ReplicaUnreachable` when it cannot — "the replica is
        down" is NOT "the expert is absent" (health accounting depends
        on the distinction)."""
        return name in self._names()

    def __contains__(self, name: str) -> bool:
        return self.contains(name)

    # ---- backend hooks -------------------------------------------------
    def _put(self, name: str, blob: bytes) -> None:
        raise NotImplementedError

    def _get(self, name: str) -> bytes:
        raise NotImplementedError

    def _get_range(self, name: str, start: int, length: int) -> bytes:
        # Fallback: fetch whole, slice locally.  Backends with a native
        # ranged read (file seek, HTTP Range) override this.
        return self._get(name)[start:start + length]

    def _names(self) -> list[str]:
        raise NotImplementedError


class InMemoryTransport(ExpertTransport):
    """Dict-backed store — unit tests and the simulated-network inner
    store."""

    def __init__(self, retry: Optional[RetryPolicy] = None):
        super().__init__(retry=retry)
        self._blobs: dict[str, bytes] = {}

    def _put(self, name: str, blob: bytes) -> None:
        self._blobs[name] = blob

    def _get(self, name: str) -> bytes:
        try:
            return self._blobs[name]
        except KeyError:
            raise ExpertNotFound(f"no published expert named {name!r}") \
                from None

    def _get_range(self, name: str, start: int, length: int) -> bytes:
        return self._get(name)[start:start + length]

    def _delete(self, name: str) -> None:
        self._blobs.pop(name, None)

    def _names(self) -> list[str]:
        return list(self._blobs)


class LocalTransport(ExpertTransport):
    """Filesystem backend: one ``<name>.cpft`` file per expert under
    ``root``.  Expert names must be filesystem-safe (they are used as
    file names verbatim)."""

    def __init__(self, root: str, retry: Optional[RetryPolicy] = None):
        super().__init__(retry=retry)
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, name: str) -> str:
        return os.path.join(self.root, name + WIRE_SUFFIX)

    def _put(self, name: str, blob: bytes) -> None:
        tmp = self._path(name) + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, self._path(name))      # atomic: no torn reads

    def _get(self, name: str) -> bytes:
        try:
            with open(self._path(name), "rb") as f:
                return f.read()
        except FileNotFoundError:
            raise ExpertNotFound(
                f"no published expert named {name!r} under {self.root}") \
                from None

    def _get_range(self, name: str, start: int, length: int) -> bytes:
        try:
            with open(self._path(name), "rb") as f:
                f.seek(start)
                return f.read(length)
        except FileNotFoundError:
            raise ExpertNotFound(
                f"no published expert named {name!r} under {self.root}") \
                from None

    def _names(self) -> list[str]:
        return sorted(f[:-len(WIRE_SUFFIX)] for f in os.listdir(self.root)
                      if f.endswith(WIRE_SUFFIX))


class SimulatedNetworkTransport(ExpertTransport):
    """A link model in front of another transport.

    One ``_get`` attempt charges ``latency_s + nbytes / bandwidth_bps``
    of real wall time, and with probability ``loss`` the attempt is
    dropped (the full delay is still paid, then
    :class:`~repro.transport.retry.TransientTransportError` surfaces and
    the base class's :class:`~repro.transport.retry.RetryPolicy` decides
    whether to retry).  Seeded, so a benchmark run is reproducible.
    ``max_retries`` survives as a shorthand for ``retry=
    RetryPolicy(max_attempts=max_retries, backoff_base_s=0)`` — the link
    already charges latency per attempt, so the default adds no backoff.
    Publishing is free: the publisher's upload is not what the paper's
    per-query retrieval claim is about.
    """

    def __init__(self, bandwidth_bps: float = 1e9, latency_s: float = 0.0,
                 loss: float = 0.0, seed: int = 0,
                 inner: Optional[ExpertTransport] = None,
                 max_retries: Optional[int] = None,
                 retry: Optional[RetryPolicy] = None):
        if retry is None:
            retry = (SIMULATED_RETRY if max_retries is None else
                     dataclasses.replace(SIMULATED_RETRY,
                                         max_attempts=max_retries))
        elif max_retries is not None:
            raise ValueError("pass either retry= or max_retries=, not both")
        super().__init__(retry=retry)
        if not (0.0 <= loss < 1.0):
            raise ValueError(f"loss must be in [0, 1), got {loss}")
        self.bandwidth_bps = float(bandwidth_bps)
        self.latency_s = float(latency_s)
        self.loss = float(loss)
        self.inner = inner or InMemoryTransport()
        self._rng = np.random.default_rng(seed)
        self._rng_lock = threading.Lock()

    def _delay(self, nbytes: int) -> float:
        return self.latency_s + nbytes / max(self.bandwidth_bps, 1.0)

    def _dropped(self) -> bool:
        if not self.loss:
            return False
        with self._rng_lock:
            return bool(self._rng.random() < self.loss)

    def _put(self, name: str, blob: bytes) -> None:
        self.inner._put(name, blob)

    def _transmit(self, name: str, nbytes: int) -> None:
        """Charge link time for ``nbytes``, honouring the caller's
        per-attempt timeout AND overall deadline, and roll the loss dice.

        If the sleep we are about to pay would outlive the thread's armed
        deadline, raise :class:`DeadlineExceeded` *without sleeping* —
        the caller has already given up on this fetch, so burning its
        wall-clock on the link model is pure waste (chaos CI sweeps hit
        this constantly).  Bytes that cross the link but never reach the
        caller (timeout partials, loss drops) are charged to
        ``stats.bytes_wasted``.
        """
        delay = self._delay(nbytes)
        timeout = self.retry.per_attempt_timeout_s
        sleep_s = delay if (timeout is None or delay <= timeout) else timeout
        remaining = _deadline_remaining()
        if remaining is not None and sleep_s > remaining:
            raise DeadlineExceeded(
                f"fetch of {name!r} needs {sleep_s:.3f}s of link time but "
                f"only {max(0.0, remaining):.3f}s of the deadline remain")
        if timeout is not None and delay > timeout:
            time.sleep(timeout)     # the attempt hangs until its budget
            arrived = int(max(0.0, timeout - self.latency_s)
                          * self.bandwidth_bps)
            with self._stats_lock:
                self.stats.bytes_wasted += min(nbytes, arrived)
            raise FetchTimeout(
                f"fetch of {name!r} needs {delay:.3f}s on this link, over "
                f"the {timeout}s per-attempt timeout")
        time.sleep(delay)
        if self._dropped():
            with self._stats_lock:
                self.stats.bytes_wasted += nbytes
            raise TransientTransportError(
                f"fetch of {name!r} dropped (loss={self.loss})")

    def _get(self, name: str) -> bytes:
        blob = self.inner._get(name)
        self._transmit(name, len(blob))
        return blob

    def _get_range(self, name: str, start: int, length: int) -> bytes:
        # Link time is charged per chunk: a leaf-granular resumable fetch
        # pays for exactly the bytes it requests, nothing more.
        chunk = self.inner._get_range(name, start, length)
        self._transmit(name, len(chunk))
        return chunk

    def _names(self) -> list[str]:
        return self.inner._names()


class HTTPTransport(ExpertTransport):
    """Fetch experts from ``<base_url>/<name>.cpft`` over HTTP(S).

    Read-mostly by design: any static file server fronting a
    :class:`LocalTransport` root works (see :func:`serve_local_http`).
    ``publish`` issues an HTTP PUT, which plain static servers reject —
    publish through the filesystem/object store behind the server instead.

    Failures are classified for the retry policy: 404 is a terminal
    :class:`ExpertNotFound` (the expert was never published), 5xx and
    socket timeouts are retryable, and a connection-level failure is
    :class:`ReplicaUnreachable` — retryable, and explicitly NOT the same
    thing as "absent" (see :meth:`contains`).
    """

    def __init__(self, base_url: str, timeout_s: float = 30.0,
                 retry: Optional[RetryPolicy] = None):
        super().__init__(retry=retry)
        self.base_url = base_url.rstrip("/")
        self.timeout_s = timeout_s

    def _url(self, name: str) -> str:
        from urllib.parse import quote
        return f"{self.base_url}/{quote(name)}{WIRE_SUFFIX}"

    def _request(self, name: str, method: str,
                 headers: Optional[dict] = None):
        import socket
        import urllib.error
        import urllib.request
        req = urllib.request.Request(self._url(name), method=method,
                                     headers=headers or {})
        timeout = self.retry.per_attempt_timeout_s or self.timeout_s
        try:
            return urllib.request.urlopen(req, timeout=timeout)
        except urllib.error.HTTPError as e:
            if e.code == 404:
                raise ExpertNotFound(
                    f"no expert {name!r} at {self._url(name)} "
                    f"(HTTP 404)") from e
            cls = (TransientTransportError if e.code >= 500
                   else TransportError)
            raise cls(
                f"HTTP {e.code} for expert {name!r} at {self._url(name)}") \
                from e
        except urllib.error.URLError as e:
            if isinstance(e.reason, (TimeoutError, socket.timeout)):
                raise FetchTimeout(
                    f"fetch of {name!r} from {self._url(name)} timed out "
                    f"after {timeout}s") from e
            raise ReplicaUnreachable(
                f"cannot reach {self._url(name)}: {e.reason}") from e
        except (TimeoutError, socket.timeout) as e:
            raise FetchTimeout(
                f"fetch of {name!r} from {self._url(name)} timed out "
                f"after {timeout}s") from e

    def _get(self, name: str) -> bytes:
        with self._request(name, "GET") as resp:
            return resp.read()

    def _get_range(self, name: str, start: int, length: int) -> bytes:
        """Ranged GET via an RFC 7233 ``Range`` header.

        A 206 body is the requested slice (clamped at end-of-file by the
        server).  A server that ignores Range answers 200 with the full
        blob — we slice locally and charge the surplus to
        ``stats.bytes_wasted``, so "zero extra bytes" claims stay honest
        against non-compliant origins."""
        if length <= 0:
            return b""
        hdrs = {"Range": f"bytes={start}-{start + length - 1}"}
        with self._request(name, "GET", headers=hdrs) as resp:
            body = resp.read()
            if resp.status == 206:
                return body
        chunk = body[start:start + length]
        with self._stats_lock:
            self.stats.bytes_wasted += len(body) - len(chunk)
        return chunk

    def _put(self, name: str, blob: bytes) -> None:
        import urllib.error
        import urllib.request
        req = urllib.request.Request(self._url(name), data=blob,
                                     method="PUT")
        try:
            urllib.request.urlopen(req, timeout=self.timeout_s).close()
        except (urllib.error.URLError, OSError) as e:
            raise TransportError(
                f"HTTP publish to {self._url(name)} failed ({e}); static "
                "servers are read-only — publish via the store behind "
                "the server (e.g. LocalTransport on its root)") from e

    def contains(self, name: str) -> bool:
        """HEAD probe.  False ONLY on a definitive 404 ("the expert is
        absent"); an unreachable replica raises
        :class:`ReplicaUnreachable` instead of masquerading as absence —
        health accounting must never quarantine an expert because the
        probe could not be delivered."""
        try:
            resp = self._request(name, "HEAD")
        except ExpertNotFound:
            return False
        resp.close()
        return True

    def _names(self) -> list[str]:
        raise TransportError(
            "HTTPTransport cannot enumerate experts; fetch by name")


def _make_range_handler():
    import re
    from http.server import SimpleHTTPRequestHandler

    class RangeRequestHandler(SimpleHTTPRequestHandler):
        """SimpleHTTPRequestHandler + single-range ``Range: bytes=a-b``
        support (RFC 7233): answers 206 Partial Content with the
        requested slice, clamped at end-of-file.  This is what makes the
        replicated CDN's leaf-resumable fetch work over plain HTTP."""

        _range_re = re.compile(r"bytes=(\d+)-(\d*)$")

        def log_message(self, *a):        # keep test output quiet
            pass

        def do_GET(self):
            m = self._range_re.match(self.headers.get("Range", ""))
            if not m:
                return super().do_GET()
            path = self.translate_path(self.path)
            try:
                f = open(path, "rb")
            except OSError:
                self.send_error(404, "File not found")
                return
            try:
                size = os.fstat(f.fileno()).st_size
                start = int(m.group(1))
                end = int(m.group(2)) if m.group(2) else size - 1
                end = min(end, size - 1)
                if start >= size or start > end:
                    self.send_error(
                        416, "Requested Range Not Satisfiable")
                    return
                length = end - start + 1
                self.send_response(206)
                self.send_header("Content-Type",
                                 self.guess_type(path))
                self.send_header("Accept-Ranges", "bytes")
                self.send_header("Content-Range",
                                 f"bytes {start}-{end}/{size}")
                self.send_header("Content-Length", str(length))
                self.end_headers()
                f.seek(start)
                self.wfile.write(f.read(length))
            finally:
                f.close()

    return RangeRequestHandler


def serve_local_http(root: str, host: str = "127.0.0.1", port: int = 0):
    """Serve a :class:`LocalTransport` root over HTTP in a daemon thread.

    Returns ``(server, base_url)``; call ``server.shutdown()`` when done.
    Pairs a filesystem publisher with :class:`HTTPTransport` consumers —
    the integration tests and ``examples/remote_experts.py`` use it.
    Answers ``Range`` requests with 206 Partial Content, so
    :meth:`HTTPTransport.get_range` (and the replicated CDN's resumable
    fetch on top of it) transfers only the requested bytes.
    """
    import functools
    from http.server import ThreadingHTTPServer
    handler = functools.partial(_make_range_handler(), directory=root)
    server = ThreadingHTTPServer((host, port), handler)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    return server, f"http://{server.server_address[0]}:{server.server_address[1]}"
