"""Retry/backoff policy and the transport error taxonomy.

The paper's headline scenario fetches experts *per query over
high-latency networks* — links that time out, drop packets, and corrupt
payloads.  Fault tolerance starts with naming the failures precisely:

**Retryable** (another attempt can plausibly succeed):

* :class:`TransientTransportError` — seeded loss on a simulated link,
  HTTP 5xx, an injected chaos fault.
* :class:`FetchTimeout`            — a single attempt exceeded its
  per-attempt timeout.
* :class:`ReplicaUnreachable`      — connection refused / DNS failure /
  URLError: the *replica* is down, which says nothing about whether the
  expert exists.
* :class:`~repro.transport.wire.ChecksumError` — the blob arrived but
  failed CRC (torn or bit-flipped transfer): a **refetch** is the fix.

**Terminal** (retrying cannot help):

* :class:`ExpertNotFound`  — a definitive 404 / missing file / absent
  key: the expert was never published.  Distinct from
  :class:`ReplicaUnreachable` on purpose, so health accounting never
  quarantines an expert that simply does not exist.
* :class:`~repro.transport.wire.WireFormatError` (non-checksum) — bad
  magic / unsupported version / malformed manifest: the published blob
  itself is wrong.

:class:`RetryPolicy` drives the uniform retry loop in
:class:`~repro.transport.backends.ExpertTransport`: bounded attempts,
exponential backoff with **seeded** jitter (deterministic per (seed,
name, attempt) — no shared RNG state, so concurrent prefetch threads
cannot perturb each other's schedules), an optional per-attempt timeout
and an optional overall deadline.  Exhaustion surfaces as
:class:`RetriesExhausted` / :class:`DeadlineExceeded`, both terminal.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import numpy as np

from repro.transport.wire import (ChecksumError, TransportError,
                                  WireFormatError)


class TransientTransportError(TransportError):
    """A retryable failure: the next attempt can plausibly succeed."""


class FetchTimeout(TransientTransportError):
    """One fetch attempt exceeded its per-attempt timeout."""


class ReplicaUnreachable(TransientTransportError):
    """The replica/origin cannot be reached (connection refused, DNS,
    URLError).  Says nothing about whether the expert exists."""


class ExpertNotFound(TransportError):
    """Terminal: the expert was never published (definitive 404 /
    missing file / absent key) — retrying cannot help."""


class RetriesExhausted(TransportError):
    """The retry budget (``max_attempts``) ran out; carries the last
    underlying error in its message and ``__cause__``."""


class DeadlineExceeded(TransportError):
    """The overall fetch deadline (``deadline_s``) would be crossed."""


def is_retryable(exc: BaseException) -> bool:
    """Classify one transport-layer exception.

    ``ChecksumError`` is checked before its ``WireFormatError`` parent:
    a failed CRC means the *transfer* was torn (refetch), while the
    other wire-format errors mean the *blob* is wrong (terminal).
    """
    if isinstance(exc, ChecksumError):
        return True
    if isinstance(exc, (ExpertNotFound, RetriesExhausted, DeadlineExceeded,
                        WireFormatError)):
        return False
    if isinstance(exc, TransientTransportError):
        return True
    return False        # unknown errors (incl. bare TransportError): terminal


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Uniform retry/backoff contract for every transport backend.

    ``backoff_s(attempt, name)`` is pure and seeded: the jitter draw is
    keyed by ``(seed, crc32(name), attempt)``, so a retry schedule is
    bit-reproducible across runs and indifferent to thread interleaving
    — the property the chaos harness gates on.
    """

    max_attempts: int = 4
    backoff_base_s: float = 0.05
    backoff_multiplier: float = 2.0
    jitter: float = 0.1                      # +- fraction of the base delay
    per_attempt_timeout_s: Optional[float] = None
    deadline_s: Optional[float] = None       # overall budget across attempts
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if not (0.0 <= self.jitter <= 1.0):
            raise ValueError("jitter must be in [0, 1]")

    def backoff_s(self, attempt: int, name: str = "") -> float:
        """Delay before retry number ``attempt`` (0-based) of ``name``."""
        base = self.backoff_base_s * self.backoff_multiplier ** attempt
        if not base:
            return 0.0
        if not self.jitter:
            return base
        rng = np.random.default_rng(
            (self.seed, zlib.crc32(name.encode("utf-8")), attempt))
        return base * (1.0 + self.jitter * (2.0 * rng.random() - 1.0))


#: Default policy for real backends (HTTP / filesystem).
DEFAULT_RETRY = RetryPolicy()

#: Default for :class:`SimulatedNetworkTransport` — immediate retries,
#: matching the historical loss-model loop (the link already charges
#: latency per attempt, so added backoff would double-count it).
SIMULATED_RETRY = RetryPolicy(max_attempts=5, backoff_base_s=0.0)
