"""repro.transport — move ComPEFT experts between hosts.

The wire format (:mod:`repro.transport.wire`) serializes one
:class:`~repro.expert.Expert` into a self-describing, checksummed blob;
the backends (:mod:`repro.transport.backends`) move blobs over a
filesystem, a simulated network link, or HTTP(S), all behind one
retry/backoff policy (:mod:`repro.transport.retry`); the chaos wrapper
(:mod:`repro.transport.chaos`) injects deterministic faults so every
recovery path is testable.  The serving stack's REMOTE storage tier
(:class:`repro.serve.expert_cache.RemoteExpertStore`) is built on this
module — see ``docs/ARCHITECTURE.md``.
"""

from repro.transport.backends import (ExpertTransport, HTTPTransport,
                                      InMemoryTransport, LocalTransport,
                                      SimulatedNetworkTransport,
                                      TransportStats, serve_local_http)
from repro.transport.chaos import ChaosFault, ChaosTransport, ReplicaFault
from repro.transport.replication import ReplicatedTransport
from repro.transport.retry import (DeadlineExceeded, ExpertNotFound,
                                   FetchTimeout, ReplicaUnreachable,
                                   RetriesExhausted, RetryPolicy,
                                   TransientTransportError, is_retryable)
from repro.transport.wire import (MAGIC, VERSION, WIRE_SUFFIX, ChecksumError,
                                  TransportError, WireFormatError,
                                  decode_expert, decode_leaves, encode_expert,
                                  is_wire_blob, payload_offset, peek_manifest,
                                  supports_resume, verify_leaf, wire_nbytes)

__all__ = ["ExpertTransport", "HTTPTransport", "InMemoryTransport",
           "LocalTransport", "SimulatedNetworkTransport", "TransportStats",
           "serve_local_http", "ChaosFault", "ChaosTransport", "ReplicaFault",
           "ReplicatedTransport", "DeadlineExceeded", "ExpertNotFound",
           "FetchTimeout", "ReplicaUnreachable", "RetriesExhausted",
           "RetryPolicy", "TransientTransportError", "is_retryable", "MAGIC",
           "VERSION", "WIRE_SUFFIX", "ChecksumError", "TransportError",
           "WireFormatError", "decode_expert", "decode_leaves",
           "encode_expert", "is_wire_blob", "payload_offset", "peek_manifest",
           "supports_resume", "verify_leaf", "wire_nbytes"]
