"""ChaosTransport — deterministic failure injection for the fetch path.

Same spirit as :class:`repro.distributed.fault.FailureInjector`: on real
networks failures arrive as timeouts, torn reads and dead origins; here
they are *injected* at configured points so every recovery path is
testable and every test is reproducible.  The wrapper sits in front of
any :class:`~repro.transport.backends.ExpertTransport` and perturbs raw
``_get`` attempts:

* ``timeout``  — the attempt raises :class:`FetchTimeout` (retryable).
* ``partial``  — the blob is truncated; ``decode_expert`` rejects it
  with a :class:`ChecksumError` and the retry loop refetches.
* ``bitflip``  — one payload bit is flipped (seeded position); CRC
  verification rejects it and the retry loop refetches.
* ``blackout`` — the replica is unreachable
  (:class:`ReplicaUnreachable`).  As a scheduled fault it fires once;
  names in ``blackout`` (or hit by a scheduled ``blackout`` fault with
  ``persistent=True``, the default) stay dark until
  :meth:`restore` — the scenario that must degrade to a request-level
  ``FAILED``, not a crashed engine.

Faults are addressed by **(expert name, per-name fetch index)** — not a
global counter — so schedules are deterministic even when the prefetch
pool interleaves fetches of different experts across threads.  Each
scheduled fault fires exactly once; ``log`` records what fired and when.

**Replica-addressed faults** (:class:`ReplicaFault`) model the whole
replica — not one name — failing: blackout, flapping up/down, or a
slow-start after restart.  They are evaluated against the same per-name
op index (every ``_get`` or ranged ``_get_range`` on a name advances that
name's counter), so "the replica died after serving 2 chunks" hits every
in-flight fetch at the same logical point regardless of thread
interleaving — which is what makes the replicated CDN's mid-stream
failover tests deterministic.  :meth:`restore_replica` heals them all.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import defaultdict, deque
from typing import Iterable, Optional

import numpy as np

from repro.transport.backends import ExpertTransport
from repro.transport.retry import (FetchTimeout, ReplicaUnreachable,
                                   RetryPolicy)
from repro.transport.wire import _HEADER

FAULT_KINDS = ("timeout", "partial", "bitflip", "blackout")
REPLICA_FAULT_KINDS = ("blackout", "flap", "slow_start")


@dataclasses.dataclass(frozen=True)
class ChaosFault:
    """One scheduled fault: the ``at``-th fetch (0-based, per-name) of
    ``name`` fails with ``kind``.  A ``blackout`` with ``persistent=True``
    additionally takes the name dark for every later fetch."""

    name: str
    at: int
    kind: str
    persistent: bool = True      # blackout only: stay dark after firing

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {FAULT_KINDS}")


@dataclasses.dataclass(frozen=True)
class ReplicaFault:
    """A whole-replica fault, addressed by per-name op index (``at``).

    * ``blackout``   — every op with index >= ``at`` raises
      :class:`ReplicaUnreachable` until :meth:`ChaosTransport.
      restore_replica`.  ``at > 0`` kills a streamed fetch *mid-blob*
      (the first ``at`` chunks of every name arrive, the rest never do)
      — the scenario leaf-resumable failover exists for.
    * ``flap``       — alternates dark/up in phases of ``period`` ops,
      starting dark at ``at``.
    * ``slow_start`` — ops ``at .. at+warmup-1`` pay an extra ``slow_s``
      of latency (a cold replica warming its caches); EWMA selection
      should learn to deprioritise it.

    Indexing by per-name op count (not wall time or a global counter)
    keeps chaos schedules deterministic under concurrent prefetch.
    """

    kind: str
    at: int = 0
    period: int = 1              # flap: ops per dark/up phase
    slow_s: float = 0.05         # slow_start: extra delay per op
    warmup: int = 4              # slow_start: number of slowed ops

    def __post_init__(self):
        if self.kind not in REPLICA_FAULT_KINDS:
            raise ValueError(f"unknown replica fault kind {self.kind!r}; "
                             f"choose from {REPLICA_FAULT_KINDS}")


class ChaosTransport(ExpertTransport):
    """Failure-injecting wrapper around ``inner`` (seeded, deterministic).

    The retry policy applies at THIS layer (the wrapped transport's own
    fetch entry points are bypassed), so an injected fault exercises
    exactly one retry loop.  ``stats.retries`` therefore counts the
    recoveries the schedule forced.
    """

    def __init__(self, inner: ExpertTransport,
                 faults: Iterable[ChaosFault] = (),
                 replica_faults: Iterable[ReplicaFault] = (),
                 blackout: Iterable[str] = (), seed: int = 0,
                 retry: Optional[RetryPolicy] = None):
        super().__init__(retry=retry)
        self.inner = inner
        self._faults: dict[tuple[str, int], ChaosFault] = {}
        for f in faults:
            key = (f.name, f.at)
            if key in self._faults:
                raise ValueError(f"duplicate fault for {key}")
            self._faults[key] = f
        self.replica_faults = tuple(replica_faults)
        self._replica_restored = False
        self._dark: set[str] = set(blackout)
        self._counts: defaultdict[str, int] = defaultdict(int)
        self._rng = np.random.default_rng(seed)
        self._chaos_lock = threading.Lock()
        # bounded ring: a chaos schedule under a week-long soak must not
        # grow host memory without bound; ``log_dropped`` counts evictions
        self.log: deque = deque(maxlen=1024)
        self.log_dropped = 0
        self._saw_replica_blackout = False

    # ---- fault scheduling ----------------------------------------------
    def _replica_kind(self, idx: int) -> tuple[Optional[str], float]:
        """Replica-fault verdict for op index ``idx`` (pure function of
        the index + the restored flag, so deterministic under any thread
        interleaving).  Returns ``(kind, extra_delay_s)``."""
        if self._replica_restored:
            return None, 0.0
        for f in self.replica_faults:
            if idx < f.at:
                continue
            if f.kind == "blackout":
                return "replica_blackout", 0.0
            if f.kind == "flap":
                if ((idx - f.at) // max(f.period, 1)) % 2 == 0:
                    return "replica_flap", 0.0
            elif f.kind == "slow_start" and idx < f.at + f.warmup:
                return "replica_slow_start", f.slow_s
        return None, 0.0

    def _next_fault(self, name: str) -> tuple[Optional[str], float]:
        """Consume (at most) the fault scheduled for this op; returns
        ``(kind, extra_delay_s)``.  Name-addressed faults take precedence
        over replica-addressed ones.  Thread-safe and
        order-deterministic because the index is per-name."""
        with self._chaos_lock:
            idx = self._counts[name]
            self._counts[name] += 1
            fault = self._faults.pop((name, idx), None)
            kind = fault.kind if fault is not None else None
            if kind is None and name in self._dark:
                kind = "blackout"
            elif kind == "blackout" and fault.persistent:
                self._dark.add(name)
            delay = 0.0
            if kind is None:
                kind, delay = self._replica_kind(idx)
            if kind is not None:
                if kind == "replica_blackout":
                    # sticky flag: _replica_dark must keep answering True
                    # even after the ring evicts the triggering event
                    self._saw_replica_blackout = True
                if len(self.log) == self.log.maxlen:
                    self.log_dropped += 1
                self.log.append({"name": name, "fetch": idx, "kind": kind})
            return kind, delay

    def restore(self, name: str) -> None:
        """Bring a blacked-out replica back (quarantine re-probes then
        succeed)."""
        with self._chaos_lock:
            self._dark.discard(name)

    def restore_replica(self) -> None:
        """End every replica-addressed fault (the host came back).  The
        revalidation sweep's re-probe then succeeds and the replica
        rejoins the rotation."""
        with self._chaos_lock:
            self._replica_restored = True

    def fired(self) -> list[dict]:
        """Schedule accounting for tests/benchmarks: every fault that has
        fired, in firing order."""
        with self._chaos_lock:
            return list(self.log)

    # ---- corruption ----------------------------------------------------
    def _corrupt(self, blob: bytes, kind: str) -> bytes:
        """Damage the *payload* region only — the manifest must stay
        parseable so the failure is a retryable ChecksumError, not a
        terminal WireFormatError (a torn read rarely lands in the first
        few hundred header bytes of a multi-KB blob)."""
        _, _, mlen = _HEADER.unpack_from(blob)
        payload_start = _HEADER.size + mlen
        if payload_start >= len(blob):         # degenerate blob: drop a byte
            return blob[:-1]
        if kind == "partial":
            keep = max(payload_start, (payload_start + len(blob)) // 2)
            return blob[:keep]
        flipped = bytearray(blob)
        with self._chaos_lock:
            pos = int(self._rng.integers(payload_start, len(blob)))
            bit = int(self._rng.integers(8))
        flipped[pos] ^= 1 << bit
        return bytes(flipped)

    # ---- backend hooks -------------------------------------------------
    def _apply(self, name: str) -> Optional[str]:
        """Consume the next fault for ``name``; raise for dead-replica
        kinds, sleep for slow-start, return corrupt kinds to the caller."""
        kind, delay = self._next_fault(name)
        if kind == "blackout":
            raise ReplicaUnreachable(
                f"replica for {name!r} blacked out (injected)")
        if kind in ("replica_blackout", "replica_flap"):
            raise ReplicaUnreachable(
                f"replica dark ({kind}, injected) while fetching {name!r}")
        if kind == "timeout":
            raise FetchTimeout(f"fetch of {name!r} timed out (injected)")
        if delay:
            import time
            time.sleep(delay)
        return kind

    def _get(self, name: str) -> bytes:
        kind = self._apply(name)
        blob = self.inner._get(name)
        if kind in ("partial", "bitflip"):
            return self._corrupt(blob, kind)
        return blob

    def _get_range(self, name: str, start: int, length: int) -> bytes:
        # Ranged ops advance the same per-name counter as whole gets, so
        # one schedule covers both access patterns.  ``partial`` truncates
        # the chunk (leaf CRC rejects it); ``bitflip`` flips one chunk bit
        # (seeded) — note a flip landing in a head/manifest chunk is a
        # terminal WireFormatError, exactly like real header corruption.
        kind = self._apply(name)
        chunk = self.inner._get_range(name, start, length)
        if kind == "partial":
            return chunk[:len(chunk) // 2]
        if kind == "bitflip" and chunk:
            flipped = bytearray(chunk)
            with self._chaos_lock:
                pos = int(self._rng.integers(len(chunk)))
                bit = int(self._rng.integers(8))
            flipped[pos] ^= 1 << bit
            return bytes(flipped)
        return chunk

    def _put(self, name: str, blob: bytes) -> None:
        self.inner._put(name, blob)

    def _replica_dark(self) -> bool:
        """Host-level darkness (call under ``_chaos_lock``): a blackout
        ReplicaFault that starts at op 0 or has already fired takes the
        control plane down too — ``names()``/``contains`` probes must
        fail like data reads do, or a revalidation sweep would "recover"
        a dead host."""
        if self._replica_restored:
            return False
        for f in self.replica_faults:
            if f.kind != "blackout":
                continue
            if f.at == 0 or self._saw_replica_blackout:
                return True
        return False

    def _names(self) -> list[str]:
        with self._chaos_lock:
            if self._replica_dark():
                raise ReplicaUnreachable(
                    "replica dark (blackout, injected); names() unanswered")
        return self.inner._names()
