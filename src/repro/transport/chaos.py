"""ChaosTransport — deterministic failure injection for the fetch path.

Same spirit as :class:`repro.distributed.fault.FailureInjector`: on real
networks failures arrive as timeouts, torn reads and dead origins; here
they are *injected* at configured points so every recovery path is
testable and every test is reproducible.  The wrapper sits in front of
any :class:`~repro.transport.backends.ExpertTransport` and perturbs raw
``_get`` attempts:

* ``timeout``  — the attempt raises :class:`FetchTimeout` (retryable).
* ``partial``  — the blob is truncated; ``decode_expert`` rejects it
  with a :class:`ChecksumError` and the retry loop refetches.
* ``bitflip``  — one payload bit is flipped (seeded position); CRC
  verification rejects it and the retry loop refetches.
* ``blackout`` — the replica is unreachable
  (:class:`ReplicaUnreachable`).  As a scheduled fault it fires once;
  names in ``blackout`` (or hit by a scheduled ``blackout`` fault with
  ``persistent=True``, the default) stay dark until
  :meth:`restore` — the scenario that must degrade to a request-level
  ``FAILED``, not a crashed engine.

Faults are addressed by **(expert name, per-name fetch index)** — not a
global counter — so schedules are deterministic even when the prefetch
pool interleaves fetches of different experts across threads.  Each
scheduled fault fires exactly once; ``log`` records what fired and when.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import defaultdict
from typing import Iterable, Optional

import numpy as np

from repro.transport.backends import ExpertTransport
from repro.transport.retry import (FetchTimeout, ReplicaUnreachable,
                                   RetryPolicy)
from repro.transport.wire import _HEADER

FAULT_KINDS = ("timeout", "partial", "bitflip", "blackout")


@dataclasses.dataclass(frozen=True)
class ChaosFault:
    """One scheduled fault: the ``at``-th fetch (0-based, per-name) of
    ``name`` fails with ``kind``.  A ``blackout`` with ``persistent=True``
    additionally takes the name dark for every later fetch."""

    name: str
    at: int
    kind: str
    persistent: bool = True      # blackout only: stay dark after firing

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {FAULT_KINDS}")


class ChaosTransport(ExpertTransport):
    """Failure-injecting wrapper around ``inner`` (seeded, deterministic).

    The retry policy applies at THIS layer (the wrapped transport's own
    fetch entry points are bypassed), so an injected fault exercises
    exactly one retry loop.  ``stats.retries`` therefore counts the
    recoveries the schedule forced.
    """

    def __init__(self, inner: ExpertTransport,
                 faults: Iterable[ChaosFault] = (),
                 blackout: Iterable[str] = (), seed: int = 0,
                 retry: Optional[RetryPolicy] = None):
        super().__init__(retry=retry)
        self.inner = inner
        self._faults: dict[tuple[str, int], ChaosFault] = {}
        for f in faults:
            key = (f.name, f.at)
            if key in self._faults:
                raise ValueError(f"duplicate fault for {key}")
            self._faults[key] = f
        self._dark: set[str] = set(blackout)
        self._counts: defaultdict[str, int] = defaultdict(int)
        self._rng = np.random.default_rng(seed)
        self._chaos_lock = threading.Lock()
        self.log: list[dict] = []

    # ---- fault scheduling ----------------------------------------------
    def _next_fault(self, name: str) -> Optional[str]:
        """Consume (at most) the fault scheduled for this fetch attempt;
        returns its kind.  Thread-safe and order-deterministic because
        the index is per-name."""
        with self._chaos_lock:
            idx = self._counts[name]
            self._counts[name] += 1
            fault = self._faults.pop((name, idx), None)
            kind = fault.kind if fault is not None else None
            if kind is None and name in self._dark:
                kind = "blackout"
            elif kind == "blackout" and fault.persistent:
                self._dark.add(name)
            if kind is not None:
                self.log.append({"name": name, "fetch": idx, "kind": kind})
            return kind

    def restore(self, name: str) -> None:
        """Bring a blacked-out replica back (quarantine re-probes then
        succeed)."""
        with self._chaos_lock:
            self._dark.discard(name)

    def fired(self) -> list[dict]:
        """Schedule accounting for tests/benchmarks: every fault that has
        fired, in firing order."""
        with self._chaos_lock:
            return list(self.log)

    # ---- corruption ----------------------------------------------------
    def _corrupt(self, blob: bytes, kind: str) -> bytes:
        """Damage the *payload* region only — the manifest must stay
        parseable so the failure is a retryable ChecksumError, not a
        terminal WireFormatError (a torn read rarely lands in the first
        few hundred header bytes of a multi-KB blob)."""
        _, _, mlen = _HEADER.unpack_from(blob)
        payload_start = _HEADER.size + mlen
        if payload_start >= len(blob):         # degenerate blob: drop a byte
            return blob[:-1]
        if kind == "partial":
            keep = max(payload_start, (payload_start + len(blob)) // 2)
            return blob[:keep]
        flipped = bytearray(blob)
        with self._chaos_lock:
            pos = int(self._rng.integers(payload_start, len(blob)))
            bit = int(self._rng.integers(8))
        flipped[pos] ^= 1 << bit
        return bytes(flipped)

    # ---- backend hooks -------------------------------------------------
    def _get(self, name: str) -> bytes:
        kind = self._next_fault(name)
        if kind == "blackout":
            raise ReplicaUnreachable(
                f"replica for {name!r} blacked out (injected)")
        if kind == "timeout":
            raise FetchTimeout(f"fetch of {name!r} timed out (injected)")
        blob = self.inner._get(name)
        if kind in ("partial", "bitflip"):
            return self._corrupt(blob, kind)
        return blob

    def _put(self, name: str, blob: bytes) -> None:
        self.inner._put(name, blob)

    def _names(self) -> list[str]:
        return self.inner._names()
