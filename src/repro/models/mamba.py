"""Mamba (S6) block for the Jamba hybrid — chunked selective scan for
train/prefill, O(1)-state recurrent step for decode.

Layout: state [B, d_inner, d_state]; conv ring buffer [B, d_conv-1, d_inner].
The time scan runs over chunks (``lax.scan``) with a ``lax.associative_scan``
inside each chunk, so sequential depth is T/chunk and the intra-chunk work is
parallel — the standard TPU-friendly factorisation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import MambaCfg


def _dt_rank(cfg: MambaCfg, d_model: int) -> int:
    return cfg.dt_rank or -(-d_model // 16)


def _conv1d_causal(x: jax.Array, w: jax.Array, b: jax.Array,
                   prefix: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv.  x: [B, T, C], w: [K, C], prefix: [B, K-1, C]
    (state from previous tokens; zeros at sequence start)."""
    K = w.shape[0]
    if prefix is None:
        prefix = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prefix, x], axis=1)           # [B, T+K-1, C]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):  # K is tiny (4): unrolled taps, no gather
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i][None, None, :]
    return (out + b[None, None, :]).astype(x.dtype)


def _ssm_scan_chunked(dt: jax.Array, A: jax.Array, B_ssm: jax.Array,
                      C: jax.Array, x_act: jax.Array, h0: jax.Array,
                      chunk: int = 64):
    """Selective-scan: h_t = dA_t * h_{t-1} + dBx_t ;  y_t = sum_s C_t[s] h_t[:,s].

    dt, x_act: [B, T, Din]; A: [Din, S]; B_ssm, C: [B, T, S]; h0: [B, Din, S].
    Returns (y [B, T, Din] f32, h_final).

    The discretised tensors dA/dBx ([B, T, Din, S] — 34 TB at 32k prefill
    scale) are NEVER materialised for the full sequence: each chunk step
    computes its own [B, chunk, Din, S] slice on the fly, so live memory
    and HBM traffic stay O(B * chunk * Din * S).
    """
    B, T, Din = dt.shape
    S = A.shape[1]
    pad = (-T) % chunk
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        x_act = jnp.pad(x_act, ((0, 0), (0, pad), (0, 0)))
        B_ssm = jnp.pad(B_ssm, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    n = Tp // chunk

    def r(x):
        return jnp.moveaxis(x.reshape(B, n, chunk, -1), 1, 0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    def step(h, xs):
        dt_c, x_c, b_c, c_c = xs                  # [B, chunk, *]
        a = jnp.exp(dt_c[..., None] * A[None, None])        # [B,chunk,Din,S]
        b = (dt_c * x_c)[..., None] * b_c[:, :, None, :]
        aa, bb = lax.associative_scan(combine, (a, b), axis=1)
        h_all = aa * h[:, None] + bb
        y = jnp.einsum("blds,bls->bld", h_all, c_c, optimize=True)
        return h_all[:, -1], y

    # remat: keeps only chunk-boundary states live in the backward pass
    h_fin, ys = lax.scan(jax.checkpoint(step), h0,
                         (r(dt), r(x_act), r(B_ssm), r(C)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Tp, Din)[:, :T]
    return y, h_fin


def mamba_forward(x: jax.Array, p: dict, cfg: MambaCfg,
                  state: tuple | None = None, chunk: int = 64):
    """Full-sequence forward.  x: [B, T, D].

    state (decode/prefill carry): (h [B, Din, S], conv_buf [B, K-1, Din]).
    Returns (out [B, T, D], new_state).
    """
    B, T, D = x.shape
    Din = cfg.expand * D
    h0 = state[0] if state is not None else None
    conv_buf = state[1] if state is not None else None

    xz = jnp.einsum("btd,de->bte", x, p["in_proj"], optimize=True)
    x_in, z = jnp.split(xz, 2, axis=-1)                 # [B, T, Din] each

    x_conv = _conv1d_causal(x_in, p["conv_w"], p["conv_b"], conv_buf)
    x_act = jax.nn.silu(x_conv.astype(jnp.float32))

    proj = jnp.einsum("bte,er->btr", x_act.astype(x.dtype), p["x_proj"],
                      optimize=True)
    R = _dt_rank(cfg, D)
    dt, B_ssm, C_ssm = jnp.split(proj, [R, R + cfg.d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,re->bte", dt, p["dt_proj"], optimize=True
                   ).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # [Din, S]

    if h0 is None:
        h0 = jnp.zeros((B, Din, cfg.d_state), jnp.float32)
    y, h_fin = _ssm_scan_chunked(dt, A, B_ssm.astype(jnp.float32),
                                 C_ssm.astype(jnp.float32), x_act, h0,
                                 chunk=chunk)
    y = y + x_act * p["D_skip"].astype(jnp.float32)[None, None, :]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bte,ed->btd", y.astype(x.dtype), p["out_proj"],
                     optimize=True)

    K = p["conv_w"].shape[0]
    tail = jnp.concatenate(
        [conv_buf if conv_buf is not None
         else jnp.zeros((B, K - 1, Din), x.dtype), x_in], axis=1)[:, -(K - 1):]
    return out, (h_fin, tail.astype(x.dtype))


def mamba_decode_step(x: jax.Array, p: dict, cfg: MambaCfg, state: tuple):
    """One-token step.  x: [B, 1, D]; state: (h, conv_buf)."""
    return mamba_forward(x, p, cfg, state=state, chunk=1)


def init_mamba_state(batch: int, d_model: int, cfg: MambaCfg,
                     dtype=jnp.bfloat16):
    Din = cfg.expand * d_model
    return (jnp.zeros((batch, Din, cfg.d_state), jnp.float32),
            jnp.zeros((batch, cfg.d_conv - 1, Din), dtype))
