"""Zero-merge expert overlays: per-row ternary deltas applied inside forward.

The serving engine historically merged a ComPEFT expert into a full copy of
the base parameters before a batch could run (`unpack_add` per leaf), which
serialises a mixed-expert request stream on swap-merge round trips.  This
module is the alternative the paper's cheap-expert claim enables: the packed
bitplanes of *several* experts stay stacked in HBM, and every projection in
the decode path computes

    y[m] = x[m] @ W_base + scale[e(m)] * (x[m] @ T_{e(m)})

with the grouped ternary kernel — no merged parameters ever exist, and one
decode batch can mix experts freely (S-LoRA-style heterogeneous batching
over compressed full-rank modules).

Three leaf-delta forms cover a dense transformer:

* :class:`MatmulDelta` — projection weights (wq/wk/wv/wo, ffn, lm_head):
  stacked planes consumed by ``ternary_matmul_grouped``.
* :class:`EmbedDelta` — the embedding table: per-token row gather on the
  embed side, transposed grouped matmul on the tied-logits side (the planes
  are packed along d, which *is* the contraction dim of the tied head).
* :class:`VectorDelta` — norm scales / biases: tiny leaves kept as dense
  per-expert stacks, gathered per row.

``plan_overlay`` decides whether a model family is coverable (dense
attention + gated-MLP stacks); anything else makes the engine fall back to
merge-on-swap.  ``build_overlay`` assembles the per-leaf stacks from the
experts' packed path-dicts; block-level leaves carry the unit axis in front
so the overlay threads through the model's ``lax.scan`` like the parameters
themselves.

The delta leaves are registered pytree nodes whose static aux data
(``n_out``/``transpose``) is plain hashable tuples: an overlay built once
per expert set has a **stable treedef**, so the compiled decode loop
(``repro.serve.decode_loop``) can close over it as a scan invariant and
re-trigger no compilation across chunk launches.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import LANE

PyTree = Any


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MatmulDelta:
    """Stacked planes of one projection leaf, matmul view [K, N].

    ``pos``/``neg``: uint32 [(U,) E, K, N//32] ([(U,) E, N, K//32] when
    ``transpose``); ``scales``: f32 [(U,) E].  The optional leading unit
    axis is stripped by the model's unit scan.

    ``dense``: optional f32 sign stack [(U,) E, K, N] (unscaled).  On TPU
    it stays None — the grouped Pallas kernel unpacks the 2-bit planes
    in-register under the MXU contraction, so HBM traffic is the packed
    bytes.  Off-TPU (jnp reference path) re-unpacking every step is real
    ALU cost, so the overlay build materialises the active stack once
    (the S-LoRA memory/compute trade, scoped to the resident expert set).
    """

    pos: jax.Array
    neg: jax.Array
    scales: jax.Array
    n_out: int = 0
    transpose: bool = False
    dense: Optional[jax.Array] = None

    def tree_flatten(self):
        return ((self.pos, self.neg, self.scales, self.dense),
                (self.n_out, self.transpose))

    @classmethod
    def tree_unflatten(cls, aux, children):
        pos, neg, scales, dense = children
        return cls(pos=pos, neg=neg, scales=scales, n_out=aux[0],
                   transpose=aux[1], dense=dense)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EmbedDelta:
    """Stacked planes of the embedding table [V, d] (d % 32 == 0).

    ``dense``: optional f32 sign stack [E, V, d] (unscaled), materialised
    off-TPU exactly like :class:`MatmulDelta`.
    """

    pos: jax.Array      # [E, V, d//32]
    neg: jax.Array
    scales: jax.Array   # [E]
    dense: Optional[jax.Array] = None

    def tree_flatten(self):
        return (self.pos, self.neg, self.scales, self.dense), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class VectorDelta:
    """Dense per-expert delta stack for small leaves: f32 [(U,) E, *shape]
    (scale already folded in)."""

    values: jax.Array

    def tree_flatten(self):
        return (self.values,), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(values=children[0])


# ---------------------------------------------------------------------------
# Per-row application helpers (called from the model forward)
# ---------------------------------------------------------------------------


def _row_scales(eid_rows: jax.Array, scales: jax.Array) -> jax.Array:
    """[M, E] selection-and-scale matrix: S[m, e] = scales[e]·1[e(m)=e]."""
    E = scales.shape[0]
    sel = (eid_rows[:, None] == jnp.arange(E, dtype=jnp.int32)[None, :])
    return sel.astype(jnp.float32) * scales[None, :].astype(jnp.float32)


def delta_proj(x: jax.Array, md: Optional[MatmulDelta],
               eid: Optional[jax.Array]):
    """f32 delta of a projection: x [B, T, K] -> [B, T, n_out] or None."""
    if md is None or eid is None:
        return None
    B, T, K = x.shape
    rows = x.reshape(B * T, K).astype(jnp.float32)
    eid_rows = jnp.repeat(eid.astype(jnp.int32), T)
    if md.dense is not None:
        spec = "mk,enk->emn" if md.transpose else "mk,ekn->emn"
        per_e = jnp.einsum(spec, rows, md.dense, optimize=True)  # [E, M, N]
        d = jnp.einsum("emn,me->mn", per_e, _row_scales(eid_rows, md.scales),
                       optimize=True)
    else:
        from repro.kernels.ops import grouped_delta_matmul
        d = grouped_delta_matmul(rows, md.pos, md.neg, md.scales, eid_rows,
                                 transpose_rhs=md.transpose, n_out=md.n_out)
    return d.reshape(B, T, md.n_out)


def add_delta(y: jax.Array, d: Optional[jax.Array]) -> jax.Array:
    """y + d in f32, cast back to y.dtype (no-op when d is None)."""
    if d is None:
        return y
    return (y.astype(jnp.float32) + d.reshape(y.shape)).astype(y.dtype)


def eff_param(base: jax.Array, vd: Optional[VectorDelta],
              eid: Optional[jax.Array], expand: int = 1) -> jax.Array:
    """Per-row effective small parameter: (base + delta[e(m)]).astype.

    Returns ``base`` unchanged without a delta; otherwise a [B, 1*expand,
    *base.shape] array that broadcasts over the time (and head) axes —
    bitwise the per-row gather of the merged parameter.
    """
    if vd is None or eid is None:
        return base
    v = vd.values[eid.astype(jnp.int32)]          # [B, *shape]
    v = v.reshape(v.shape[:1] + (1,) * expand + v.shape[1:])
    return (base.astype(jnp.float32) + v).astype(base.dtype)


def embed_delta_rows(ed: Optional[EmbedDelta], tokens: jax.Array,
                     eid: Optional[jax.Array], d_model: int):
    """Per-(row, token) embedding delta: f32 [B, T, d] or None."""
    if ed is None or eid is None:
        return None
    e = eid.astype(jnp.int32)[:, None]                       # [B, 1]
    if ed.dense is not None:
        delta = ed.dense[e, tokens]                          # [B, T, d]
    else:
        pw = ed.pos[e, tokens]                               # [B, T, W]
        nw = ed.neg[e, tokens]
        shifts = jnp.arange(LANE, dtype=jnp.uint32)
        pb = ((pw[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)
        nb = ((nw[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)
        delta = (pb - nb).reshape(pw.shape[:2] + (-1,))[..., :d_model]
    return delta * ed.scales[e][..., None]


def tied_logits_delta(x: jax.Array, ed: Optional[EmbedDelta],
                      eid: Optional[jax.Array], vocab: int):
    """f32 delta of the tied LM head: x [B, T, d] -> [B, T, V] or None."""
    if ed is None or eid is None:
        return None
    md = MatmulDelta(pos=ed.pos, neg=ed.neg, scales=ed.scales, n_out=vocab,
                     transpose=True, dense=ed.dense)
    return delta_proj(x, md, eid)


# ---------------------------------------------------------------------------
# Overlay planning / construction
# ---------------------------------------------------------------------------

_VEC_NAMES = {"pre_norm", "ffn_norm", "post_attn_norm", "post_ffn_norm",
              "final_norm", "bq", "bk", "bv", "q_norm", "k_norm"}
_IN_PROJ = {"wq", "wk", "wv", "wg", "wu"}


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    kind: str                 # "matmul" | "vector" | "embed"
    units: int                # leading unit axis length (0 = no unit axis)
    core: tuple[int, ...]     # per-unit shape
    k: int = 0                # matmul view contraction dim
    n: int = 0                # matmul view output dim


def _classify(parts: list[str], core: tuple[int, ...], units: int):
    name = parts[-1]
    if parts == ["embed"]:
        if core[1] % LANE:
            return None
        return LeafSpec("embed", 0, core)
    if parts == ["lm_head"]:
        k, n = core
        return LeafSpec("matmul", 0, core, k, n) if n % LANE == 0 else None
    if name in _VEC_NAMES:
        return LeafSpec("vector", units, core)
    if name in _IN_PROJ and len(core) >= 2:
        k, n = core[0], int(np.prod(core[1:]))
        return LeafSpec("matmul", units, core, k, n) if n % LANE == 0 else None
    if name == "wo" and len(core) == 3:       # attn out: [H, D, d]
        k, n = int(np.prod(core[:2])), core[-1]
        return LeafSpec("matmul", units, core, k, n) if n % LANE == 0 else None
    if name == "wo" and len(core) == 2:       # ffn out: [f, d]
        k, n = core
        return LeafSpec("matmul", units, core, k, n) if n % LANE == 0 else None
    return None


def plan_overlay(params: PyTree, cfg) -> Optional[dict]:
    """Map every base-param path to a LeafSpec, or None if the family is
    not coverable by the zero-merge path (MoE, mamba/rwkv, enc-dec,
    cross-attn, multimodal frontends fall back to merge-on-swap)."""
    if cfg.enc_n_units or cfg.cross_attn or cfg.frontend is not None:
        return None
    for b in cfg.pattern:
        if b.kind != "attn" or (b.ffn is not None and b.ffn.moe is not None):
            return None
    from repro.peft.lora import _path_str
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    plan = {}
    for path, leaf in flat:
        ps = _path_str(path)
        parts = ps.split("/")
        if parts[0] == "blocks":
            units, core = leaf.shape[0], tuple(leaf.shape[1:])
            if int(np.prod(core)) % LANE:
                return None     # unit rows must stay word-aligned
        else:
            units, core = 0, tuple(leaf.shape)
        spec = _classify(parts, core, units)
        if spec is None:
            return None
        plan[ps] = spec
    return plan


def _dense_values(pos: jax.Array, neg: jax.Array, scales: jax.Array,
                  n: int) -> jax.Array:
    """[E, W] word stacks -> dense f32 [E, n] with scales folded in."""
    shifts = jnp.arange(LANE, dtype=jnp.uint32)
    pb = ((pos[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)
    nb = ((neg[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)
    signs = (pb - nb).reshape(pos.shape[0], -1)[:, :n]
    return signs * scales[:, None]


def build_overlay(plan: dict, stacks: dict,
                  materialize: Optional[bool] = None) -> Optional[dict]:
    """Shape the cache tier's stacked plane buffers into an overlay tree.

    ``stacks`` is {path: (pos [E, W], neg [E, W], scales [E], shape)} as
    produced by :func:`repro.core.packing.stack_packed` (what
    ``DeviceCache.stacked`` keeps resident).  Returns a nested dict
    mirroring the parameter tree (block leaves carry the unit axis in front
    for the scan), or None when a delta lands on a path the plan cannot
    express — the engine then falls back to merge-on-swap.

    ``materialize`` (default: off-TPU) additionally unpacks each projection
    stack to dense f32 signs once, so the jnp serve path pays zero
    per-step unpacking; on TPU the planes stay packed for the Pallas
    kernels.
    """
    if materialize is None:
        materialize = jax.default_backend() != "tpu"
    for path in stacks:
        if path not in plan:
            return None
    overlay: dict = {}
    for path, (pos, neg, scales, _) in stacks.items():
        spec = plan[path]
        E = pos.shape[0]
        n = int(np.prod(spec.core)) * max(spec.units, 1)
        ones = jnp.ones((E,), jnp.float32)
        if spec.kind == "vector":
            vals = _dense_values(pos, neg, scales, n)            # [E, n]
            if spec.units:
                vals = vals.reshape((E, spec.units) + spec.core)
                vals = jnp.swapaxes(vals, 0, 1)                  # [U, E, ...]
            else:
                vals = vals.reshape((E,) + spec.core)
            entry: Any = VectorDelta(values=vals)
        elif spec.kind == "embed":
            V, d = spec.core
            dense = (_dense_values(pos, neg, ones, n).reshape(E, V, d)
                     if materialize else None)
            entry = EmbedDelta(pos=pos.reshape(E, V, d // LANE),
                               neg=neg.reshape(E, V, d // LANE),
                               scales=scales, dense=dense)
        else:                                                    # matmul
            U = max(spec.units, 1)
            shape = (E, U, spec.k, spec.n // LANE)
            dense = (_dense_values(pos, neg, ones, n)
                     .reshape(E, U, spec.k, spec.n)
                     if materialize else None)
            if spec.units:
                entry = MatmulDelta(
                    pos=jnp.swapaxes(pos.reshape(shape), 0, 1),
                    neg=jnp.swapaxes(neg.reshape(shape), 0, 1),
                    scales=jnp.broadcast_to(scales[None], (spec.units, E)),
                    n_out=spec.n,
                    dense=(jnp.swapaxes(dense, 0, 1)
                           if dense is not None else None))
            else:
                entry = MatmulDelta(pos=pos.reshape(shape)[:, 0],
                                    neg=neg.reshape(shape)[:, 0],
                                    scales=scales, n_out=spec.n,
                                    dense=(dense[:, 0]
                                           if dense is not None else None))
        node = overlay
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = entry
    return overlay
