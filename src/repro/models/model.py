"""Uniform model API over every architecture family.

``build(cfg)`` returns a :class:`ModelApi` whose members close over the
config: ``init``, ``loss_and_logits`` (train), ``prefill`` / ``decode_step``
(serve), and ``encode`` for enc-dec archs.  Batches are dicts:

* LM:      {"tokens": [B,T] int32, "targets": [B,T] int32}
* VLM:     + {"mm_embeds": [B, n_patches, e] — ViT stub output}
* enc-dec: {"frames": [B, S_src, e] — audio stub, "tokens", "targets"}

``targets`` uses -1 for masked positions.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.models.transformer import Runtime

PyTree = Any


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean CE over targets >= 0.  logits [B, T, V] (any float dtype)."""
    l32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(l32, axis=-1)
    tgt = jnp.clip(targets, 0, logits.shape[-1] - 1)
    picked = jnp.take_along_axis(l32, tgt[..., None], axis=-1)[..., 0]
    nll = lse - picked
    mask = (targets >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


@dataclasses.dataclass
class ModelApi:
    cfg: ModelConfig
    init: Callable[[jax.Array], PyTree]
    loss_and_logits: Callable  # (params, batch, rt) -> (loss, (logits, aux))
    forward: Callable          # (params, batch, rt) -> (logits, aux)
    # (params, batch, rt, cache_len) -> (logits, cache)
    prefill: Callable
    # (params, token, cache, rt, delta=, eid=) -> (logits, cache).
    # Scan-compatible: ``cache["cur"]`` is a traced position, updates are
    # functional with a stable pytree, so the serving layer can roll K
    # steps into one lax.scan launch and donate the cache buffers.
    decode_step: Callable
    init_decode_cache: Callable  # (batch, cache_len) -> cache


AUX_LOSS_WEIGHT = 0.01


def build(cfg: ModelConfig) -> ModelApi:
    is_encdec = cfg.enc_n_units > 0
    is_vlm = cfg.frontend is not None and not is_encdec

    def init(key):
        return tf.init_params(key, cfg)

    def forward(params, batch, rt: Runtime):
        if is_encdec:
            enc_out = tf.encode(params, batch["frames"], cfg, rt)
            x = tf.embed_tokens(params, batch["tokens"], cfg, rt)
            positions = jnp.arange(x.shape[1])[None, :]
            x, aux, _, _ = tf._unit_scan(x, params["blocks"], cfg, rt,
                                         positions, cfg.pattern,
                                         enc_out=enc_out)
            return tf.logits_of(params, x, cfg, rt), aux
        mm = batch.get("mm_embeds") if is_vlm else None
        return tf.forward_train(params, batch["tokens"], cfg, rt,
                                mm_embeds=mm)

    def loss_and_logits(params, batch, rt: Runtime):
        logits, aux = forward(params, batch, rt)
        targets = batch["targets"]
        if is_vlm and cfg.frontend is not None:
            # logits cover [mm_prefix + text]; score text positions only
            n_mm = logits.shape[1] - targets.shape[1]
            logits_text = logits[:, n_mm:]
        else:
            logits_text = logits
        loss = cross_entropy(logits_text, targets) + AUX_LOSS_WEIGHT * aux
        return loss, (logits_text, aux)

    def prefill_fn(params, batch, rt: Runtime, cache_len: int,
                   delta=None, eid=None, start=None, kv_sharding=None):
        enc_out = None
        if is_encdec:
            enc_out = tf.encode(params, batch["frames"], cfg, rt)
        mm = batch.get("mm_embeds") if is_vlm else None
        return tf.prefill(params, batch["tokens"], cfg, rt, cache_len,
                          mm_embeds=mm, enc_out=enc_out, delta=delta,
                          eid=eid, start=start, kv_sharding=kv_sharding)

    def decode_fn(params, token, cache, rt: Runtime, delta=None, eid=None):
        return tf.decode_step(params, token, cache, cfg, rt, delta=delta,
                              eid=eid)

    def init_cache(batch: int, cache_len: int):
        return tf.init_decode_cache(cfg, batch, cache_len)

    return ModelApi(cfg=cfg, init=init, loss_and_logits=loss_and_logits,
                    forward=forward, prefill=prefill_fn,
                    decode_step=decode_fn, init_decode_cache=init_cache)
