"""Shared model primitives: norms, rope, embeddings, initializers."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
             gemma_style: bool = False) -> jax.Array:
    """RMSNorm in f32, cast back.  gemma_style uses (1 + scale)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    s = (1.0 + scale.astype(jnp.float32)) if gemma_style else scale.astype(jnp.float32)
    return (y * s).astype(x.dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, f32 [head_dim/2]."""
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: [..., T, H, D]; positions: broadcastable to [..., T]."""
    d = x.shape[-1]
    inv = jnp.asarray(rope_frequencies(d, theta))
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., T, D/2]
    sin = jnp.sin(ang)[..., None, :]                      # [..., T, 1, D/2]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key: jax.Array, shape: tuple[int, ...], in_dim: int,
               dtype) -> jax.Array:
    """Truncated-normal fan-in init (matches common LM practice)."""
    std = 1.0 / np.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def split_keys(key: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(key, n))


def count_params(params: PyTree) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


def param_bytes(params: PyTree) -> int:
    return sum(int(np.prod(p.shape)) * p.dtype.itemsize
               for p in jax.tree_util.tree_leaves(params))
