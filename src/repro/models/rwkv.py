"""RWKV-6 "Finch" block: data-dependent per-channel decay, matrix-valued
per-head state.  Chunked-parallel form for train/prefill (GLA-style), exact
recurrence for decode.

Recurrence per head (state S in R^{dk x dv}):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Chunked form over chunks of length L with ci = inclusive cumsum(log w),
ce = exclusive cumsum:
    inter:  y_t += (r_t ⊙ exp(ce_t)) @ S_in
    intra:  y_t += Σ_{s<t} [Σ_d r_t[d] k_s[d] exp(ce_t[d]-ci_s[d])] v_s
    diag :  y_t += (r_t ⊙ u ⊙ k_t) 1 · v_t
    state:  S_out = diag(exp(ci_L)) S_in + Σ_s (k_s ⊙ exp(ci_L - ci_s))^T v_s
Exponents of retained terms are ≤ 0 (decays in (0,1)), masked terms are
clamped before exp, so the chunked form is overflow-safe.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import RWKVCfg

MIX_CHANNELS = ("w", "k", "v", "r", "g")


def _shift(x: jax.Array, last: jax.Array | None) -> jax.Array:
    """Token shift: x_{t-1} (previous token's embedding).  last: [B, 1, D]."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _ddlerp(x: jax.Array, x_prev: jax.Array, p: dict):
    """Data-dependent token-shift interpolation (RWKV6).  Returns one mixed
    input per channel in MIX_CHANNELS."""
    dx = x_prev - x
    xxx = x + dx * p["mu_x"][None, None, :]
    hidden = jnp.tanh(jnp.einsum("btd,dr->btr", xxx, p["mix_w1"],
                                 optimize=True))        # [B, T, R]
    outs = {}
    for i, c in enumerate(MIX_CHANNELS):
        m = p[f"mu_{c}"][None, None, :] + jnp.einsum(
            "btr,rd->btd", hidden, p["mix_w2"][i], optimize=True)
        outs[c] = x + dx * m
    return outs


def _decay(x_w: jax.Array, p: dict) -> jax.Array:
    """log w_t in (-inf, 0): w = exp(-exp(w0 + tanh(x_w@d1)@d2))."""
    lw = p["w0"][None, None, :] + jnp.einsum(
        "btd,dr->btr", jnp.tanh(jnp.einsum("btd,dr->btr", x_w, p["decay_w1"],
                                           optimize=True)),
        p["decay_w2"], optimize=True)
    return -jnp.exp(lw.astype(jnp.float32))  # = log w  (≤ 0)


def _group_norm(y: jax.Array, scale: jax.Array, bias: jax.Array,
                eps: float = 64e-5) -> jax.Array:
    """Per-head LayerNorm on [B, T, H, dh]."""
    y32 = y.astype(jnp.float32)
    mean = jnp.mean(y32, axis=-1, keepdims=True)
    var = jnp.var(y32, axis=-1, keepdims=True)
    return (y32 - mean) * lax.rsqrt(var + eps) * scale + bias


def rwkv_time_mix(x: jax.Array, p: dict, cfg: RWKVCfg,
                  state: tuple | None = None, chunk: int = 64,
                  impl: str = "matmul"):
    """x: [B, T, D] -> (out, new_state).  state = (S [B,H,dk,dv] f32,
    last_x [B,1,D]).

    impl='einsum': exact 5-D decay tensor [B,L,L,H,dh] (reference; HBM
    traffic O(L^2 * dh) per token).
    impl='matmul': GLA-style factorisation A = (r*exp(ce)) @ (k*exp(-ci))^T
    per head — a true MXU matmul, cutting the intra-chunk traffic by ~dh.
    The exp(-ci) factor is clipped at e^60; clipped terms correspond to
    decays < e^-60 whose contribution is zero to f32 precision.
    """
    B, T, D = x.shape
    dh = cfg.head_dim
    H = D // dh

    last_x = state[1] if state is not None else None
    S0 = state[0] if state is not None else jnp.zeros((B, H, dh, dh),
                                                      jnp.float32)
    x_prev = _shift(x, last_x)
    mixed = _ddlerp(x, x_prev, p)

    r = jnp.einsum("btd,de->bte", mixed["r"], p["Wr"], optimize=True)
    k = jnp.einsum("btd,de->bte", mixed["k"], p["Wk"], optimize=True)
    v = jnp.einsum("btd,de->bte", mixed["v"], p["Wv"], optimize=True)
    g = jnp.einsum("btd,de->bte", mixed["g"], p["Wg"], optimize=True)
    logw = _decay(mixed["w"], p)                         # [B, T, D] (≤0)

    rh = r.reshape(B, T, H, dh).astype(jnp.float32)
    kh = k.reshape(B, T, H, dh).astype(jnp.float32)
    vh = v.reshape(B, T, H, dh).astype(jnp.float32)
    wh = logw.reshape(B, T, H, dh)
    u = p["u"].reshape(H, dh).astype(jnp.float32)

    pad = (-T) % chunk
    if pad:
        rh, kh, vh = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                      for a in (rh, kh, vh))
        wh = jnp.pad(wh, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = T + pad
    n = Tp // chunk
    L = chunk
    rc = rh.reshape(B, n, L, H, dh)
    kc = kh.reshape(B, n, L, H, dh)
    vc = vh.reshape(B, n, L, H, dh)
    wc = wh.reshape(B, n, L, H, dh)

    ci = jnp.cumsum(wc, axis=2)                          # inclusive
    ce = ci - wc                                         # exclusive
    mask = jnp.tril(jnp.ones((L, L), bool), k=-1)        # s < t

    def step(S, xs):
        rcc, kcc, vcc, cii, cee = xs                     # [B, L, H, dh] each
        # inter-chunk
        y_inter = jnp.einsum("blhd,bhde->blhe", rcc * jnp.exp(cee), S,
                             optimize=True)
        if impl == "matmul":
            # A[t,s] = sum_d r_t k_s exp(ce_t - ci_s), factorised so the
            # contraction is a per-head matmul (no [L,L,dh] tensor)
            r_fac = rcc * jnp.exp(cee)                   # exponent <= 0
            k_fac = kcc * jnp.exp(jnp.minimum(-cii, 60.0))
            A = jnp.einsum("blhd,bmhd->blmh", r_fac, k_fac, optimize=True)
        else:
            # exact reference: clamped elementwise decay tensor
            diff = cee[:, :, None] - cii[:, None, :]     # [B,L(t),L(s),H,dh]
            A = jnp.einsum("blhd,bmhd,blmhd->blmh", rcc, kcc,
                           jnp.exp(jnp.minimum(diff, 0.0)), optimize=True)
        A = jnp.where(mask[None, :, :, None], A, 0.0)
        y_intra = jnp.einsum("blmh,bmhe->blhe", A, vcc, optimize=True)
        # diagonal bonus term
        y_diag = jnp.einsum("blhd,blhd,blhe->blhe",
                            rcc * u[None, None], kcc, vcc, optimize=True)
        # state update
        decay_all = jnp.exp(cii[:, -1][:, None] - cii)   # [B, L, H, dh]
        S_new = jnp.exp(cii[:, -1])[..., None] * S + jnp.einsum(
            "blhd,blhe->bhde", kcc * decay_all, vcc, optimize=True)
        return S_new, y_inter + y_intra + y_diag

    S_fin, ys = lax.scan(  # remat: chunk residuals recomputed in backward
        jax.checkpoint(step), S0,
        tuple(jnp.moveaxis(a, 1, 0) for a in (rc, kc, vc, ci, ce)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Tp, H, dh)[:, :T]

    y = _group_norm(y, p["ln_x_scale"].reshape(H, dh),
                    p["ln_x_bias"].reshape(H, dh))
    y = (y.reshape(B, T, D) * jax.nn.silu(g.astype(jnp.float32)))
    out = jnp.einsum("bte,ed->btd", y.astype(x.dtype), p["Wo"], optimize=True)
    return out, (S_fin, x[:, -1:])


def rwkv_channel_mix(x: jax.Array, p: dict,
                     state: jax.Array | None = None):
    """RWKV FFN (relu² channel mix).  state: last_x [B,1,D]."""
    x_prev = _shift(x, state)
    dx = x_prev - x
    xk = x + dx * p["cm_mu_k"][None, None, :]
    xr = x + dx * p["cm_mu_r"][None, None, :]
    kk = jnp.einsum("btd,df->btf", xk, p["cm_Wk"], optimize=True)
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    rr = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["cm_Wr"],
                                   optimize=True).astype(jnp.float32))
    vv = jnp.einsum("btf,fd->btd", kk, p["cm_Wv"], optimize=True)
    return (rr * vv.astype(jnp.float32)).astype(x.dtype), x[:, -1:]


def init_rwkv_state(batch: int, d_model: int, cfg: RWKVCfg,
                    dtype=jnp.bfloat16):
    dh = cfg.head_dim
    H = d_model // dh
    return (jnp.zeros((batch, H, dh, dh), jnp.float32),      # S
            jnp.zeros((batch, 1, d_model), dtype),           # time-mix shift
            jnp.zeros((batch, 1, d_model), dtype))           # channel-mix shift
