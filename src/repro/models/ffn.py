"""Feed-forward blocks: gated-MLP variants and capacity-based MoE (GShard
style).  Expert parallelism emerges from sharding: tokens are data-sharded,
experts model-sharded, so the dispatch/combine einsums lower to all-to-all
under GSPMD."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FFNCfg, MoECfg


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":  # silu gate
        return jax.nn.silu(x)
    if kind == "geglu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def dense_ffn(x: jax.Array, p: dict, cfg: FFNCfg, dp=None,
              eid=None) -> jax.Array:
    """x: [B, T, D].  Gated (swiglu/geglu): out = (act(x@wg) * (x@wu)) @ wo.

    ``dp``/``eid``: zero-merge expert overlay — per-row grouped ternary
    delta added to each projection instead of merging expert weights."""
    from repro.models.delta import add_delta, delta_proj
    dp = dp or {}
    g_lin = jnp.einsum("btd,df->btf", x, p["wg"], optimize=True)
    g_lin = add_delta(g_lin, delta_proj(x, dp.get("wg"), eid))
    if cfg.activation in ("swiglu", "geglu"):
        u = jnp.einsum("btd,df->btf", x, p["wu"], optimize=True)
        u = add_delta(u, delta_proj(x, dp.get("wu"), eid))
        h = _act(g_lin, cfg.activation) * u
    else:
        h = _act(g_lin, cfg.activation)
    out = jnp.einsum("btf,fd->btd", h, p["wo"], optimize=True)
    return add_delta(out, delta_proj(h, dp.get("wo"), eid))


def _expert_ffn(h_in: jax.Array, p: dict, cfg: FFNCfg) -> jax.Array:
    """Batched expert MLP.  h_in: [G, E, C, D] -> [G, E, C, D]."""
    g = _act(jnp.einsum("gecd,edf->gecf", h_in, p["wg_e"], optimize=True),
             cfg.activation)
    u = jnp.einsum("gecd,edf->gecf", h_in, p["wu_e"], optimize=True)
    return jnp.einsum("gecf,efd->gecd", g * u, p["wo_e"], optimize=True)


def moe_ffn(x: jax.Array, p: dict, cfg: FFNCfg) -> tuple[jax.Array, jax.Array]:
    """Grouped capacity-based top-k MoE (GShard).  x: [B, T, D].

    Tokens are grouped per sequence (G=B, S=T) and dispatched within their
    group with per-group capacity C = ceil(K*S/E * cap).  The dispatch
    tensor is [G, S, E, C] = G*S^2*K*cap elements — independent of E and
    small once sharded (G over data, E over model); the GShard all-to-all
    emerges from that sharding contrast.  Tokens beyond capacity fall back
    to the residual stream.  aux is the Switch load-balancing loss.
    """
    mo: MoECfg = cfg.moe
    B, T, D = x.shape
    # Fixed-size token groups bound the [G, S, E, C] dispatch tensor to
    # N * S_g * K * cap elements (S_g <= 4096); per-sequence grouping would
    # grow as T^2 and explode at 32k prefill.  S_g = 4096 also makes the
    # train-shape regroup an identity (G == B), which sidesteps an XLA SPMD
    # partition-group CHECK crash on batch-crossing reshapes inside the
    # pod-manual gradient scope (spmd_partitioner_util.cc:504).
    S = 4096 if T % 4096 == 0 else (2048 if T % 2048 == 0 else T)
    G = (B * T) // S
    x = x.reshape(G, S, D)
    E, K = mo.n_experts, mo.top_k
    C = max(1, int(np.ceil(K * S / E * mo.capacity_factor)))

    logits = jnp.einsum("gsd,de->gse", x, p["router"], optimize=True)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, K)             # [G, S, K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # queue position of each (token, k) within its group's expert queue
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)     # [G, S, K, E]
    flat = onehot.reshape(G, S * K, E)
    rank = (jnp.cumsum(flat, axis=1) - flat).reshape(G, S, K, E)
    pos_in_expert = jnp.sum(rank * onehot, axis=-1)           # [G, S, K]
    keep = pos_in_expert < C

    slot_oh = jax.nn.one_hot(jnp.where(keep, pos_in_expert, C), C + 1,
                             dtype=x.dtype)[..., :C]           # [G, S, K, C]
    disp = jnp.einsum("gske,gskc->gsec", onehot.astype(x.dtype), slot_oh,
                      optimize=True)                           # [G, S, E, C]
    comb = jnp.einsum("gske,gskc,gsk->gsec", onehot.astype(jnp.float32),
                      slot_oh.astype(jnp.float32),
                      gate_vals.astype(jnp.float32), optimize=True)

    h_in = jnp.einsum("gsd,gsec->gecd", x, disp, optimize=True)  # [G,E,C,D]
    h_out = _expert_ffn(h_in, p, cfg)                            # [G,E,C,D]
    out = jnp.einsum("gecd,gsec->gsd", h_out.astype(jnp.float32), comb,
                     optimize=True).astype(x.dtype)

    if mo.shared_expert_dff:
        out = out + dense_ffn(x, {"wg": p["wg_s"], "wu": p["wu_s"],
                                  "wo": p["wo_s"]}, cfg)

    # load-balancing aux loss (Switch):  E * sum_e f_e * p_e
    frac_tokens = jnp.mean(
        onehot[:, :, 0, :].astype(jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return out.reshape(B, T, D), aux


def ffn_apply(x: jax.Array, p: dict, cfg: FFNCfg, dp=None,
              eid=None) -> tuple[jax.Array, jax.Array]:
    if cfg.moe is not None:
        assert not dp, "zero-merge overlay does not cover MoE FFNs"
        return moe_ffn(x, p, cfg)
    return dense_ffn(x, p, cfg, dp=dp, eid=eid), jnp.zeros((), jnp.float32)
