"""Attention: chunked online-softmax (flash-style) for train/prefill, and
decode attention over a ring-buffer KV cache with sequence-parallel partial
statistics.

Design notes (see DESIGN.md §4):

* Train/prefill attention iterates a **static pair schedule** of
  (q-chunk, kv-chunk) tiles via ``lax.scan``.  Causal masking and sliding
  windows prune the schedule *statically*, so compiled HLO FLOPs equal the
  true cost (T²/2 causal, T·w SWA) — this matters because the roofline
  reads ``compiled.cost_analysis()``.
* Decode attention returns flash partials ``(o, m, l)`` so the caller can
  combine across sequence-sharded cache shards with a stable ``psum``
  (``repro.distributed.collectives.flash_combine``).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import AttnCfg
from repro.models.common import apply_rope, softcap

NEG_INF = -2.0e38


def _chunk_pairs(nq: int, nk: int, causal: bool,
                 window_chunks: Optional[int]) -> np.ndarray:
    """Static (i, j) tile schedule.  For causal self-attention nq == nk and
    only j <= i tiles are emitted; a window additionally drops tiles entirely
    below the diagonal band."""
    pairs = []
    for i in range(nq):
        for j in range(nk):
            if causal and j > i:
                continue
            if window_chunks is not None and (i - j) > window_chunks:
                continue
            pairs.append((i, j))
    return np.asarray(pairs, np.int32)


def flash_attention(
    q: jax.Array,                 # [B, T, Hq, D]
    k: jax.Array,                 # [B, S, Hkv, D]
    v: jax.Array,                 # [B, S, Hkv, D]
    cfg: AttnCfg,
    *,
    causal: bool = True,
    q_offset: int = 0,
    kv_valid_len: Optional[int] = None,
    kv_start: Optional[jax.Array] = None,
    chunk_q: int = 512,
    chunk_k: int = 512,
    shard_fn=None,
) -> jax.Array:
    """Chunked flash attention.  Returns [B, T, Hq, D] in q.dtype.

    ``kv_start`` (optional, [B] int32) is the absolute position of each
    row's first *real* token: keys at positions below it are masked out,
    so left-padded rows (ragged prompts in one batch, engine slot refills)
    ignore their pad tokens.  Queries inside the pad region attend to
    nothing and produce zeros — callers discard them.

    ``shard_fn(x, logical_axes)`` (optional) pins the scan-carry shardings;
    without it GSPMD may pick a carry sharding that mismatches the body and
    re-gather the full [B,nq,cq,H,G,D] o-buffer EVERY pair step (measured:
    67 TB/device on llama4 prefill — EXPERIMENTS.md §Perf E2)."""
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(D)

    cq = min(chunk_q, T)
    ck = min(chunk_k, S)
    pad_t = (-T) % cq
    pad_s = (-S) % ck
    if pad_t:
        q = jnp.pad(q, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    Tp, Sp = T + pad_t, S + pad_s
    nq, nk = Tp // cq, Sp // ck

    win_chunks = None
    if cfg.window is not None and causal:
        win_chunks = int(np.ceil(cfg.window / ck)) + 1
    pairs = jnp.asarray(_chunk_pairs(nq, nk, causal and T == S, win_chunks))

    qc = q.reshape(B, nq, cq, Hkv, G, D)
    kc = k.reshape(B, nk, ck, Hkv, D)
    vc = v.reshape(B, nk, ck, Hkv, D)

    m0 = jnp.full((B, nq, cq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, cq, Hkv, G), jnp.float32)
    o0 = jnp.zeros((B, nq, cq, Hkv, G, D), jnp.float32)

    def pin(m, l, o):
        if shard_fn is None:
            return m, l, o
        # carry sharded over model on cq (dim 2): the per-step dynamic ops
        # slice dim 1 (nq) only, so this layout needs zero resharding per
        # step.  Head axes win when they are model-shardable.
        ml_axes = ("batch", None, "flash_cq", "kv_heads", None)
        m = shard_fn(m, ml_axes)
        l = shard_fn(l, ml_axes)
        o = shard_fn(o, ml_axes + (None,))
        return m, l, o

    m0, l0, o0 = pin(m0, l0, o0)

    kv_len = S if kv_valid_len is None else kv_valid_len

    def body(carry, ij):
        m, l, o = carry
        i, j = ij[0], ij[1]
        qi = lax.dynamic_index_in_dim(qc, i, axis=1, keepdims=False)
        kj = lax.dynamic_index_in_dim(kc, j, axis=1, keepdims=False)
        vj = lax.dynamic_index_in_dim(vc, j, axis=1, keepdims=False)

        # scores: [B, cq, Hkv, G, ck]
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qi.astype(jnp.float32),
                       kj.astype(jnp.float32), optimize=True) * scale
        s = softcap(s, cfg.attn_softcap)

        q_pos = q_offset + i * cq + jnp.arange(cq)
        k_pos = j * ck + jnp.arange(ck)
        mask = jnp.ones((cq, ck), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if cfg.window is not None and causal:
            mask &= (q_pos[:, None] - k_pos[None, :]) < cfg.window
        mask &= (k_pos < kv_len)[None, :]
        if kv_start is not None:     # per-row left-pad mask -> [B, cq, ck]
            mask = (mask[None]
                    & (k_pos[None, None, :]
                       >= kv_start.astype(jnp.int32)[:, None, None]))
        # additive mask: jnp.where(mask, s, NEG_INF) would give the NEG_INF
        # constant a cotangent that is batch-reduced ACROSS PODS in the
        # backward (measured: 1 MB x 9216 cross-pod all-reduces on qwen3
        # train, §Perf E3); the additive form keeps the constant out of AD
        if kv_start is not None:
            neg = jnp.where(mask, 0.0, NEG_INF)[:, :, None, None, :]
        else:
            neg = jnp.where(mask, 0.0, NEG_INF)[None, :, None, None, :]
        s = s + lax.stop_gradient(neg)

        mi = lax.dynamic_index_in_dim(m, i, axis=1, keepdims=False)
        li = lax.dynamic_index_in_dim(l, i, axis=1, keepdims=False)
        oi = lax.dynamic_index_in_dim(o, i, axis=1, keepdims=False)

        m_new = jnp.maximum(mi, jnp.max(s, axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        maskf = (mask[:, :, None, None, :] if kv_start is not None
                 else mask[None, :, None, None, :])
        p = p * lax.stop_gradient(maskf.astype(jnp.float32))
        alpha = jnp.where(mi <= NEG_INF / 2, 0.0, jnp.exp(mi - m_safe))
        l_new = alpha * li + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqhgk,bkhd->bqhgd", p, vj.astype(jnp.float32),
                        optimize=True)
        o_new = alpha[..., None] * oi + pv

        m = lax.dynamic_update_index_in_dim(m, m_new, i, axis=1)
        l = lax.dynamic_update_index_in_dim(l, l_new, i, axis=1)
        o = lax.dynamic_update_index_in_dim(o, o_new, i, axis=1)
        return pin(m, l, o), None

    # remat: without this, backward materialises every pair-step's p-matrix
    # ([B,cq,Hkv,G,ck] f32 x n_pairs) during the enclosing unit's backward
    (m, l, o), _ = lax.scan(jax.checkpoint(body), (m0, l0, o0), pairs)
    out = o / jnp.maximum(l[..., None], 1e-30)
    out = out.reshape(B, Tp, Hq, D)[:, :T]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache (ring buffer: covers global caches and SWA windows uniformly)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class KVCache:
    """Per-layer-stack cache.  ``k``/``v``: [units, B, S, Hkv, D]; ``pos``:
    [units, S] absolute position held in each slot (-1 = empty)."""

    k: jax.Array
    v: jax.Array
    pos: jax.Array

    def tree_flatten(self):
        return (self.k, self.v, self.pos), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_kv_cache(n_units: int, batch: int, seq: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((n_units, batch, seq, n_kv, head_dim), dtype),
        v=jnp.zeros((n_units, batch, seq, n_kv, head_dim), dtype),
        pos=jnp.full((n_units, seq), -1, jnp.int32),
    )


def cache_write(k_cache: jax.Array, v_cache: jax.Array, pos: jax.Array,
                k_new: jax.Array, v_new: jax.Array, cur: jax.Array):
    """Write one token (k_new/v_new: [B, 1, Hkv, D]) at ring slot cur % S.

    Single-shard version; the sequence-sharded variant lives in
    ``repro.distributed.collectives.sp_cache_write``.
    """
    S = k_cache.shape[1]
    slot = jnp.mod(cur, S)
    k_cache = lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype),
                                       (0, slot, 0, 0))
    v_cache = lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype),
                                       (0, slot, 0, 0))
    pos = lax.dynamic_update_slice(pos, cur[None].astype(jnp.int32), (slot,))
    return k_cache, v_cache, pos


def decode_attention_partial(
    q: jax.Array,        # [B, 1, Hq, D] (rope already applied)
    k_cache: jax.Array,  # [B, S_loc, Hkv, D] (rope already applied at write)
    v_cache: jax.Array,  # [B, S_loc, Hkv, D]
    pos: jax.Array,      # [S_loc] absolute positions, -1 empty
    cur: jax.Array,      # scalar current absolute position
    cfg: AttnCfg,
    start: Optional[jax.Array] = None,   # [B] first real position per row
):
    """One-token attention over a (possibly sequence-sharded) cache slice.

    ``start`` (optional, [B] int32) masks cache slots holding positions
    below each row's first real token — rows admitted into a running wave
    via left-padded prefill ignore their pad KV entries.

    Returns flash partials (o, m, l):
      o: [B, Hq, D] f32 unnormalised;  m, l: [B, Hq] f32.
    """
    B, _, Hq, D = q.shape
    Hkv = k_cache.shape[2]
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(D)

    qf = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, kf, optimize=True) * scale
    s = softcap(s, cfg.attn_softcap)

    valid = (pos >= 0) & (pos <= cur)
    if cfg.window is not None:
        valid &= pos > (cur - cfg.window)
    if start is not None:               # [B, S_loc] per-row validity
        valid = valid[None, :] & (pos[None, :]
                                  >= start.astype(jnp.int32)[:, None])
        vmask = valid[:, None, None, :]
    else:
        vmask = valid[None, None, None, :]
    s = jnp.where(vmask, s, NEG_INF)

    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(vmask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32),
                   optimize=True)
    return (o.reshape(B, Hq, D), m.reshape(B, Hq), l.reshape(B, Hq))


def finalize_partial(o: jax.Array, m: jax.Array, l: jax.Array) -> jax.Array:
    """Normalise flash partials when no cross-shard combine is needed."""
    return o / jnp.maximum(l[..., None], 1e-30)


# ---------------------------------------------------------------------------
# Paged KV (block-table pools; see repro.serve.paged_kv)
# ---------------------------------------------------------------------------


def paged_cache_write(k_pool: jax.Array, v_pool: jax.Array,
                      tables: jax.Array, lens: jax.Array, active: jax.Array,
                      k_new: jax.Array, v_new: jax.Array):
    """Write one token per row into each row's current block.

    ``k_pool``/``v_pool``: [NB, BS, Hkv, D]; ``tables``: [B, MAXB] block
    lists (-1 unallocated); ``lens``: [B] write positions; ``active``:
    [B] rows still generating; ``k_new``/``v_new``: [B, 1, Hkv, D].

    Finished rows keep stepping with the batch (host-free inner loop), so
    their writes are redirected to the reserved trash block — which is
    never listed in any live table, hence never read.  Duplicate trash
    indices across dead rows are harmless for the same reason.
    """
    B = tables.shape[0]
    maxb = tables.shape[1]
    BS = k_pool.shape[1]
    bidx = jnp.clip(lens // BS, 0, maxb - 1)
    blk = jnp.take_along_axis(tables, bidx[:, None], axis=1)[:, 0]
    blk = jnp.where(active & (blk >= 0), blk, 0)
    slot = jnp.mod(lens, BS)
    k_pool = k_pool.at[blk, slot].set(k_new[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[blk, slot].set(v_new[:, 0].astype(v_pool.dtype))
    return k_pool, v_pool


def paged_attention_partial(
    q: jax.Array,        # [B, 1, Hq, D] (rope already applied)
    k_pool: jax.Array,   # [NB, BS, Hkv, D]
    v_pool: jax.Array,   # [NB, BS, Hkv, D]
    tables: jax.Array,   # [B, MAXB] block lists, -1 unallocated
    lens: jax.Array,     # [B] current write position (== this token's pos)
    start: jax.Array,    # [B] first real (non-pad) position
    cfg: AttnCfg,
):
    """One-token attention over gathered block-table KV.

    ``pool[tables[b]]`` materialises row ``b``'s positions in order, so
    position ``s`` of the gathered sequence IS absolute position ``s`` —
    the validity mask is ``start[b] <= s <= lens[b]`` plus the window and
    an allocated-block mask.  The score/softmax math mirrors
    :func:`decode_attention_partial` exactly (same einsums, same masked
    ``NEG_INF`` max/exp/sum order), which is what makes paged-vs-dense
    token parity hold bit-for-bit at the argmax level.

    Returns flash partials (o, m, l) like :func:`decode_attention_partial`.
    """
    B, _, Hq, D = q.shape
    BS, Hkv = k_pool.shape[1], k_pool.shape[2]
    maxb = tables.shape[1]
    S = maxb * BS
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(D)

    safe = jnp.where(tables < 0, 0, tables)
    kf = k_pool[safe].reshape(B, S, Hkv, D).astype(jnp.float32)
    vf = v_pool[safe].reshape(B, S, Hkv, D).astype(jnp.float32)

    qf = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, kf, optimize=True) * scale
    s = softcap(s, cfg.attn_softcap)

    spos = jnp.arange(S)
    valid = ((spos[None, :] <= lens[:, None])
             & (spos[None, :] >= start.astype(jnp.int32)[:, None])
             & jnp.repeat(tables >= 0, BS, axis=1))
    if cfg.window is not None:
        valid &= spos[None, :] > (lens[:, None] - cfg.window)
    vmask = valid[:, None, None, :]
    s = jnp.where(vmask, s, NEG_INF)

    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(vmask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, vf, optimize=True)
    return (o.reshape(B, Hq, D), m.reshape(B, Hq), l.reshape(B, Hq))


# ---------------------------------------------------------------------------
# Projection helpers (shared by every attention block)
# ---------------------------------------------------------------------------


def qkv_project(x: jax.Array, p: dict, cfg: AttnCfg, positions: jax.Array,
                rms_eps: float = 1e-6, dp=None, eid=None):
    """x: [B, T, Dm] -> q, k, v with rope (and optional bias / qk-norm).

    ``dp``/``eid`` carry the zero-merge expert overlay (stacked ternary
    planes + per-row expert ids); each projection then adds the grouped
    delta contraction instead of ever merging expert weights."""
    from repro.models.common import rms_norm
    from repro.models.delta import add_delta, delta_proj, eff_param
    dp = dp or {}

    q = jnp.einsum("btd,dhk->bthk", x, p["wq"], optimize=True)
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"], optimize=True)
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"], optimize=True)
    if dp:
        q = add_delta(q, delta_proj(x, dp.get("wq"), eid))
        k = add_delta(k, delta_proj(x, dp.get("wk"), eid))
        v = add_delta(v, delta_proj(x, dp.get("wv"), eid))
    if cfg.qkv_bias:
        q = q + eff_param(p["bq"], dp.get("bq"), eid)
        k = k + eff_param(p["bk"], dp.get("bk"), eid)
        v = v + eff_param(p["bv"], dp.get("bv"), eid)
    if cfg.qk_norm:
        q = rms_norm(q, eff_param(p["q_norm"], dp.get("q_norm"), eid,
                                  expand=2), rms_eps)
        k = rms_norm(k, eff_param(p["k_norm"], dp.get("k_norm"), eid,
                                  expand=2), rms_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_project(attn_out: jax.Array, p: dict, dp=None, eid=None) -> jax.Array:
    """[B, T, Hq, D] @ wo[Hq, D, Dm] -> [B, T, Dm]."""
    out = jnp.einsum("bthk,hkd->btd", attn_out, p["wo"], optimize=True)
    if dp and dp.get("wo") is not None:
        from repro.models.delta import add_delta, delta_proj
        B, T, H, D = attn_out.shape
        d = delta_proj(attn_out.reshape(B, T, H * D), dp["wo"], eid)
        out = add_delta(out, d)
    return out
