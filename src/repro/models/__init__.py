from repro.models.model import ModelApi, build, cross_entropy
from repro.models.transformer import Runtime

__all__ = ["ModelApi", "build", "cross_entropy", "Runtime"]
