"""Decoder-only LM assembly: pattern-of-blocks × n_units with ``lax.scan``.

HLO size is O(len(pattern)) regardless of depth — an 80-layer dense model
compiles as one scanned body.  Heterogeneous stacks (jamba's attn/mamba
interleave, gemma2's SWA/global alternation, llama4's dense/MoE alternation)
are expressed as multi-block patterns scanned over repeat units.

All functions are functional: ``params`` is a nested dict, activations carry
an injected ``Runtime.shard`` callback for GSPMD sharding constraints.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import AttnCfg, BlockCfg, ModelConfig
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import mamba as mamba_mod
from repro.models import rwkv as rwkv_mod
from repro.models.attention import (KVCache, cache_write,
                                    decode_attention_partial,
                                    finalize_partial, flash_attention,
                                    out_project, paged_attention_partial,
                                    paged_cache_write, qkv_project)
from repro.models.common import (dense_init, dtype_of, embed_init, rms_norm,
                                 softcap, split_keys)
from repro.models.delta import (add_delta, eff_param, embed_delta_rows,
                                delta_proj, tied_logits_delta)

PyTree = Any


def _identity_shard(x, axes):
    return x


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Execution-context knobs threaded through the model code."""
    shard: Callable = _identity_shard
    # decode attention over the (possibly sequence-sharded) cache:
    # signature (q, k_cache, v_cache, pos, cur, attn_cfg) -> [B, 1, Hq, D]
    decode_attn: Optional[Callable] = None
    # vocab-parallel embedding lookup override (see collectives.make_vp_embed_lookup)
    embed_lookup: Optional[Callable] = None
    attn_chunk_q: int = 512
    attn_chunk_k: int = 512
    mamba_chunk: int = 64
    rwkv_chunk: int = 128  # measured optimum (§Perf E2 iter 2)
    rwkv_impl: str = "matmul"    # matmul | einsum (reference)
    remat_policy: str = "unit"   # unit | none


def _local_decode_attn(q, k_cache, v_cache, pos, cur, cfg: AttnCfg,
                       start=None):
    o, m, l = decode_attention_partial(q, k_cache, v_cache, pos, cur, cfg,
                                       start=start)
    return finalize_partial(o, m, l)[:, None].astype(q.dtype)  # [B,1,Hq,D]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_attn(key, d: int, a: AttnCfg, dtype) -> dict:
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, a.n_q, a.head_dim), d, dtype),
        "wk": dense_init(ks[1], (d, a.n_kv, a.head_dim), d, dtype),
        "wv": dense_init(ks[2], (d, a.n_kv, a.head_dim), d, dtype),
        "wo": dense_init(ks[3], (a.n_q, a.head_dim, d), a.n_q * a.head_dim,
                         dtype),
    }
    if a.qkv_bias:
        p["bq"] = jnp.zeros((a.n_q, a.head_dim), dtype)
        p["bk"] = jnp.zeros((a.n_kv, a.head_dim), dtype)
        p["bv"] = jnp.zeros((a.n_kv, a.head_dim), dtype)
    if a.qk_norm:
        p["q_norm"] = jnp.ones((a.head_dim,), dtype)
        p["k_norm"] = jnp.ones((a.head_dim,), dtype)
    return p


def init_ffn(key, d: int, f, dtype) -> dict:
    if f.moe is not None:
        mo = f.moe
        ks = split_keys(key, 7)
        p = {
            "router": dense_init(ks[0], (d, mo.n_experts), d, jnp.float32),
            "wg_e": dense_init(ks[1], (mo.n_experts, d, mo.d_ff_expert), d,
                               dtype),
            "wu_e": dense_init(ks[2], (mo.n_experts, d, mo.d_ff_expert), d,
                               dtype),
            "wo_e": dense_init(ks[3], (mo.n_experts, mo.d_ff_expert, d),
                               mo.d_ff_expert, dtype),
        }
        if mo.shared_expert_dff:
            p["wg_s"] = dense_init(ks[4], (d, mo.shared_expert_dff), d, dtype)
            p["wu_s"] = dense_init(ks[5], (d, mo.shared_expert_dff), d, dtype)
            p["wo_s"] = dense_init(ks[6], (mo.shared_expert_dff, d),
                                   mo.shared_expert_dff, dtype)
        return p
    ks = split_keys(key, 3)
    return {
        "wg": dense_init(ks[0], (d, f.d_ff), d, dtype),
        "wu": dense_init(ks[1], (d, f.d_ff), d, dtype),
        "wo": dense_init(ks[2], (f.d_ff, d), f.d_ff, dtype),
    }


def init_mamba(key, d: int, m, dtype) -> dict:
    din = m.expand * d
    R = m.dt_rank or -(-d // 16)
    ks = split_keys(key, 5)
    # S4D-real A init; dt bias init per mamba reference
    a_init = np.broadcast_to(np.arange(1, m.d_state + 1, dtype=np.float32),
                             (din, m.d_state))
    dt = np.exp(np.random.default_rng(0).uniform(np.log(1e-3), np.log(1e-1),
                                                 din)).astype(np.float32)
    dt_bias = dt + np.log(-np.expm1(-dt))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * din), d, dtype),
        "conv_w": dense_init(ks[1], (m.d_conv, din), m.d_conv, dtype),
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": dense_init(ks[2], (din, R + 2 * m.d_state), din, dtype),
        "dt_proj": dense_init(ks[3], (R, din), R, dtype),
        "dt_bias": jnp.asarray(dt_bias, jnp.float32),
        "A_log": jnp.asarray(np.log(a_init), jnp.float32),
        "D_skip": jnp.ones((din,), jnp.float32),
        "out_proj": dense_init(ks[4], (din, d), din, dtype),
    }


def init_rwkv(key, d: int, r, dtype) -> dict:
    ks = split_keys(key, 12)
    dh = r.head_dim
    H = d // dh
    p = {
        "mu_x": jnp.zeros((d,), dtype),
        "mix_w1": dense_init(ks[0], (d, r.mix_lora), d, dtype),
        "mix_w2": dense_init(ks[1], (len(rwkv_mod.MIX_CHANNELS), r.mix_lora, d),
                             r.mix_lora, dtype),
        "Wr": dense_init(ks[2], (d, d), d, dtype),
        "Wk": dense_init(ks[3], (d, d), d, dtype),
        "Wv": dense_init(ks[4], (d, d), d, dtype),
        "Wg": dense_init(ks[5], (d, d), d, dtype),
        "Wo": dense_init(ks[6], (d, d), d, dtype),
        "w0": jnp.asarray(np.linspace(-6.0, -1.0, d), jnp.float32),
        "decay_w1": dense_init(ks[7], (d, r.decay_lora), d, dtype),
        "decay_w2": dense_init(ks[8], (r.decay_lora, d), r.decay_lora,
                               jnp.float32),
        "u": jnp.zeros((d,), jnp.float32),
        "ln_x_scale": jnp.ones((d,), jnp.float32),
        "ln_x_bias": jnp.zeros((d,), jnp.float32),
    }
    for ch in rwkv_mod.MIX_CHANNELS:
        p[f"mu_{ch}"] = jnp.zeros((d,), dtype)
    return p


def init_block(key, cfg: ModelConfig, b: BlockCfg) -> dict:
    d = cfg.d_model
    dtype = dtype_of(cfg)
    ks = split_keys(key, 6)
    p: dict = {"pre_norm": jnp.zeros((d,), dtype) if _gemma(cfg)
               else jnp.ones((d,), dtype)}
    if b.kind == "attn":
        p["attn"] = init_attn(ks[0], d, b.attn, dtype)
    elif b.kind == "mamba":
        p["mamba"] = init_mamba(ks[0], d, b.mamba, dtype)
        p["mamba"]["norm"] = jnp.ones((d,), dtype)  # jamba in-block norm
    elif b.kind == "rwkv":
        p["rwkv"] = init_rwkv(ks[0], d, b.rwkv, dtype)
    else:
        raise ValueError(b.kind)
    if b.ffn is not None:
        p["ffn_norm"] = (jnp.zeros((d,), dtype) if _gemma(cfg)
                         else jnp.ones((d,), dtype))
        p["ffn"] = init_ffn(ks[1], d, b.ffn, dtype)
    if b.kind == "rwkv":
        # rwkv ffn (channel mix) params live in the rwkv dict
        f = b.ffn
        p["ffn"] = {
            "cm_Wk": dense_init(ks[2], (d, f.d_ff), d, dtype),
            "cm_Wv": dense_init(ks[3], (f.d_ff, d), f.d_ff, dtype),
            "cm_Wr": dense_init(ks[4], (d, d), d, dtype),
            "cm_mu_k": jnp.zeros((d,), dtype),
            "cm_mu_r": jnp.zeros((d,), dtype),
        }
    if b.sandwich_norm:
        p["post_attn_norm"] = jnp.zeros((d,), dtype)
        if b.ffn is not None:
            p["post_ffn_norm"] = jnp.zeros((d,), dtype)
    if cfg.cross_attn and b.kind == "attn":
        p["cross_norm"] = jnp.ones((d,), dtype)
        p["cross"] = init_attn(ks[5], d, dataclasses.replace(
            b.attn, causal=False, qkv_bias=False), dtype)
    return p


def _gemma(cfg: ModelConfig) -> bool:
    return cfg.name.startswith("gemma")


def init_params(key: jax.Array, cfg: ModelConfig) -> PyTree:
    dtype = dtype_of(cfg)
    ks = split_keys(key, 4 + len(cfg.pattern) + len(cfg.enc_pattern))
    params: dict = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": (jnp.zeros((cfg.d_model,), dtype) if _gemma(cfg)
                       else jnp.ones((cfg.d_model,), dtype)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab),
                                       cfg.d_model, dtype)
    if cfg.frontend is not None:
        params["frontend_proj"] = dense_init(
            ks[2], (cfg.frontend.embed_dim, cfg.d_model),
            cfg.frontend.embed_dim, dtype)

    def stack_init(subkey, block_cfg):
        return jax.vmap(lambda k: init_block(k, cfg, block_cfg))(
            jax.random.split(subkey, cfg.n_units))

    params["blocks"] = {
        f"block{i}": stack_init(ks[3 + i], b)
        for i, b in enumerate(cfg.pattern)
    }
    if cfg.enc_n_units:
        off = 3 + len(cfg.pattern)
        params["enc_blocks"] = {
            f"block{i}": jax.vmap(lambda k, b=b: init_block(k, cfg, b))(
                jax.random.split(ks[off + i], cfg.enc_n_units))
            for i, b in enumerate(cfg.enc_pattern)
        }
        params["enc_final_norm"] = jnp.ones((cfg.d_model,), dtype)
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _apply_attn_block(x, bp, b: BlockCfg, cfg: ModelConfig, rt: Runtime,
                      positions, enc_out=None, collect_cache=False,
                      dp=None, eid=None, kv_start=None):
    dp = dp or {}
    h = rms_norm(x, eff_param(bp["pre_norm"], dp.get("pre_norm"), eid),
                 cfg.rms_eps, _gemma(cfg))
    heads_ok = getattr(rt.shard, "heads_shardable", lambda hh: False)
    q, k, v = qkv_project(h, bp["attn"], b.attn, positions, cfg.rms_eps,
                          dp=dp.get("attn"), eid=eid)
    q = rt.shard(q, ("batch", "seq", "heads", None))
    k = rt.shard(k, ("batch", "seq", "kv_heads", None))
    # pin the flash scan-carry sharding only when the heads cannot take the
    # model axis — head-TP archs already have a good (jointly head-tiled)
    # carry layout and the pin would fight it (§Perf E2)
    pin = None if heads_ok(b.attn.n_q) else rt.shard
    o = flash_attention(q, k, v, b.attn, causal=b.attn.causal,
                        kv_start=kv_start,
                        chunk_q=rt.attn_chunk_q, chunk_k=rt.attn_chunk_k,
                        shard_fn=pin)
    o = out_project(o, bp["attn"], dp=dp.get("attn"), eid=eid)
    if b.sandwich_norm:
        o = rms_norm(o, eff_param(bp["post_attn_norm"],
                                  dp.get("post_attn_norm"), eid),
                     cfg.rms_eps, _gemma(cfg))
    x = x + o
    if enc_out is not None and "cross" in bp:
        hc = rms_norm(x, bp["cross_norm"], cfg.rms_eps)
        qc = jnp.einsum("btd,dhk->bthk", hc, bp["cross"]["wq"], optimize=True)
        ck = jnp.einsum("bsd,dhk->bshk", enc_out, bp["cross"]["wk"],
                        optimize=True)
        cv = jnp.einsum("bsd,dhk->bshk", enc_out, bp["cross"]["wv"],
                        optimize=True)
        oc = flash_attention(qc, ck, cv,
                             dataclasses.replace(b.attn, causal=False,
                                                 window=None),
                             causal=False, chunk_q=rt.attn_chunk_q,
                             chunk_k=rt.attn_chunk_k, shard_fn=pin)
        x = x + out_project(oc, bp["cross"])
    cache_out = (k, v) if collect_cache else None
    return x, cache_out


def _apply_ffn(x, bp, b: BlockCfg, cfg: ModelConfig, rt: Runtime,
               dp=None, eid=None):
    if b.ffn is None:
        return x, jnp.zeros((), jnp.float32)
    dp = dp or {}
    h = rms_norm(x, eff_param(bp["ffn_norm"], dp.get("ffn_norm"), eid),
                 cfg.rms_eps, _gemma(cfg))
    out, aux = ffn_mod.ffn_apply(h, bp["ffn"], b.ffn, dp=dp.get("ffn"),
                                 eid=eid)
    out = rt.shard(out, ("batch", "seq", "embed_act"))
    if b.sandwich_norm:
        out = rms_norm(out, eff_param(bp["post_ffn_norm"],
                                      dp.get("post_ffn_norm"), eid),
                       cfg.rms_eps, _gemma(cfg))
    return x + out, aux


def _apply_block_train(x, bp, b: BlockCfg = None, cfg: ModelConfig = None,
                       rt: Runtime = None, positions=None, state=None,
                       enc_out=None, collect_cache=False, dp=None, eid=None,
                       kv_start=None):
    """Returns (x, aux, cache_entry, new_state)."""
    aux = jnp.zeros((), jnp.float32)
    cache_entry, new_state = None, None
    if b.kind == "attn":
        x, cache_entry = _apply_attn_block(x, bp, b, cfg, rt, positions,
                                           enc_out, collect_cache,
                                           dp=dp, eid=eid, kv_start=kv_start)
        x, aux = _apply_ffn(x, bp, b, cfg, rt, dp=dp, eid=eid)
    elif b.kind == "mamba":
        h = rms_norm(x, bp["pre_norm"], cfg.rms_eps)
        out, new_state = mamba_mod.mamba_forward(
            h, bp["mamba"], b.mamba, state=state, chunk=rt.mamba_chunk)
        x = x + out
        x, aux = _apply_ffn(x, bp, b, cfg, rt)
    elif b.kind == "rwkv":
        h = rms_norm(x, bp["pre_norm"], cfg.rms_eps)
        tm_state = (state[0], state[1]) if state is not None else None
        out, tm_new = rwkv_mod.rwkv_time_mix(h, bp["rwkv"], b.rwkv,
                                             state=tm_state,
                                             chunk=rt.rwkv_chunk,
                                             impl=rt.rwkv_impl)
        x = x + out
        h2 = rms_norm(x, bp["ffn_norm"], cfg.rms_eps)
        out2, cm_new = rwkv_mod.rwkv_channel_mix(
            h2, bp["ffn"], state=state[2] if state is not None else None)
        x = x + out2
        new_state = (tm_new[0], tm_new[1], cm_new)
    return x, aux, cache_entry, new_state


def _unit_scan(x, stacked_blocks, cfg: ModelConfig, rt: Runtime, positions,
               pattern, enc_out=None, collect_cache=False, states=None,
               delta_blocks=None, eid=None, kv_start=None):
    """Scan over units.  Returns (x, aux_sum, caches, new_states)."""

    def body(carry, xs):
        h, aux = carry
        unit_params, unit_states, unit_delta = xs
        caches, new_states = [], []
        for i, b in enumerate(pattern):
            st = unit_states[i] if unit_states is not None else None
            dp = (unit_delta.get(f"block{i}")
                  if unit_delta is not None else None)
            block_fn = partial(_apply_block_train, b=b, cfg=cfg, rt=rt,
                               positions=positions, enc_out=enc_out,
                               collect_cache=collect_cache, dp=dp, eid=eid,
                               kv_start=kv_start)
            if rt.remat_policy == "block" and len(pattern) > 1:
                block_fn = jax.checkpoint(
                    block_fn, policy=jax.checkpoint_policies.nothing_saveable,
                    static_argnums=())
            h, a, ce, ns = block_fn(h, unit_params[f"block{i}"], state=st)
            aux = aux + a
            caches.append(ce)
            new_states.append(ns)
        h = rt.shard(h, ("batch", "seq", "embed_act"))
        ys = (tuple(caches) if collect_cache else None,
              tuple(new_states) if states is not None else None)
        return (h, aux), ys

    if rt.remat_policy in ("unit", "block"):
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), ys = lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (stacked_blocks, states, delta_blocks))
    return x, aux, ys[0], ys[1]


def embed_tokens(params, tokens, cfg: ModelConfig, rt: Runtime,
                 mm_embeds=None, delta=None, eid=None):
    if rt.embed_lookup is not None:
        x = rt.embed_lookup(params["embed"], tokens)
    else:
        x = params["embed"][tokens]  # gather; vocab-sharded under GSPMD
    if delta is not None:
        d = embed_delta_rows(delta.get("embed"), tokens, eid, cfg.d_model)
        x = add_delta(x, d)
    if cfg.embed_scale:
        x = (x.astype(jnp.float32) * np.sqrt(cfg.d_model)).astype(x.dtype)
    if cfg.frontend is not None and mm_embeds is not None:
        mm = jnp.einsum("bne,ed->bnd", mm_embeds.astype(x.dtype),
                        params["frontend_proj"], optimize=True)
        x = jnp.concatenate([mm, x], axis=1)
    return rt.shard(x, ("batch", "seq", "embed_act"))


def logits_of(params, x, cfg: ModelConfig, rt: Runtime, delta=None,
              eid=None):
    delta = delta or {}
    x = rms_norm(x, eff_param(params["final_norm"], delta.get("final_norm"),
                              eid), cfg.rms_eps, _gemma(cfg))
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("btd,dv->btv", x, head, optimize=True)
    if cfg.tie_embeddings:
        d = tied_logits_delta(x, delta.get("embed"), eid, cfg.vocab)
    else:
        d = delta_proj(x, delta.get("lm_head"), eid)
    logits = add_delta(logits, d)
    logits = softcap(logits, cfg.logit_softcap)
    return rt.shard(logits, ("batch", "seq", "vocab_act"))


def forward_train(params, tokens, cfg: ModelConfig, rt: Runtime,
                  mm_embeds=None):
    """tokens [B, T] -> (logits [B, T(+mm), V], aux_loss)."""
    x = embed_tokens(params, tokens, cfg, rt, mm_embeds)
    positions = jnp.arange(x.shape[1])[None, :]
    x, aux, _, _ = _unit_scan(x, params["blocks"], cfg, rt, positions,
                              cfg.pattern)
    return logits_of(params, x, cfg, rt), aux


# ---------------------------------------------------------------------------
# Decode (serving) path
# ---------------------------------------------------------------------------


def default_decode_cache_attn(q, k_new, v_new, cache_k, cache_v, pos, cur,
                              attn_cfg: AttnCfg, start=None):
    """Local (unsharded-cache) write + attend.  The sequence-parallel variant
    is repro.distributed.collectives.sp_decode_cache_attn.  ``start``
    ([B] int32, optional) masks each row's left-pad KV positions."""
    cache_k, cache_v, pos = cache_write(cache_k, cache_v, pos, k_new, v_new,
                                        cur)
    o, m, l = decode_attention_partial(q, cache_k, cache_v, pos, cur,
                                       attn_cfg, start=start)
    out = finalize_partial(o, m, l)[:, None].astype(q.dtype)
    return out, cache_k, cache_v, pos


def init_decode_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      dtype=None) -> PyTree:
    """Empty decode state for every block of the pattern."""
    dtype = dtype or dtype_of(cfg)
    layers = {}
    for i, b in enumerate(cfg.pattern):
        U = cfg.n_units
        if b.kind == "attn":
            S = min(cache_len, b.attn.window) if b.attn.window else cache_len
            layers[f"block{i}"] = {
                "k": jnp.zeros((U, batch, S, b.attn.n_kv, b.attn.head_dim),
                               dtype),
                "v": jnp.zeros((U, batch, S, b.attn.n_kv, b.attn.head_dim),
                               dtype),
                "pos": jnp.full((U, S), -1, jnp.int32),
            }
        elif b.kind == "mamba":
            din = b.mamba.expand * cfg.d_model
            layers[f"block{i}"] = {
                "h": jnp.zeros((U, batch, din, b.mamba.d_state), jnp.float32),
                "conv": jnp.zeros((U, batch, b.mamba.d_conv - 1, din), dtype),
            }
        elif b.kind == "rwkv":
            dh = b.rwkv.head_dim
            H = cfg.d_model // dh
            layers[f"block{i}"] = {
                "S": jnp.zeros((U, batch, H, dh, dh), jnp.float32),
                "tm": jnp.zeros((U, batch, 1, cfg.d_model), dtype),
                "cm": jnp.zeros((U, batch, 1, cfg.d_model), dtype),
            }
    cache: dict = {"layers": layers, "cur": jnp.zeros((), jnp.int32)}
    if cfg.cross_attn:
        a = cfg.pattern[0].attn
        # cross-KV filled by encode(); sized by the frontend stub
        S_src = cfg.frontend.n_tokens if cfg.frontend else cache_len
        cache["cross"] = {
            "k": jnp.zeros((cfg.n_units, batch, S_src, a.n_kv, a.head_dim),
                           dtype),
            "v": jnp.zeros((cfg.n_units, batch, S_src, a.n_kv, a.head_dim),
                           dtype),
        }
    return cache


def _decode_block(x, bp, b: BlockCfg, cfg: ModelConfig, rt: Runtime, st,
                  cur, cross_kv=None, dp=None, eid=None, start=None,
                  paged=None):
    """One-token step through one block.  Returns (x, new_state).

    ``paged`` (optional ``(tables, lens, active)``) switches the attn
    branch to the block-table KV pools of :mod:`repro.serve.paged_kv`:
    rope positions become per-row (``lens``), the write lands in each
    row's current block, and the attend is a gather over the row's block
    list.  Dense ring-buffer behaviour is untouched when absent."""
    decode_attn = rt.decode_attn or default_decode_cache_attn
    dp = dp or {}
    if b.kind == "attn":
        h = rms_norm(x, eff_param(bp["pre_norm"], dp.get("pre_norm"), eid),
                     cfg.rms_eps, _gemma(cfg))
        if paged is not None:
            tables, lens, active = paged
            positions = lens[:, None].astype(jnp.int32)      # [B, 1] per row
        else:
            positions = cur[None, None].astype(jnp.int32)  # [1,1] broadcasts to [B,T=1]
        q, k, v = qkv_project(h, bp["attn"], b.attn, positions, cfg.rms_eps,
                              dp=dp.get("attn"), eid=eid)
        if paged is not None:
            ck, cv = paged_cache_write(st["k"], st["v"], tables, lens,
                                       active, k, v)
            o, m, l = paged_attention_partial(q, ck, cv, tables, lens,
                                              start, b.attn)
            o = finalize_partial(o, m, l)[:, None].astype(q.dtype)
            pos = None
        elif start is None:
            o, ck, cv, pos = decode_attn(q, k, v, st["k"], st["v"],
                                         st["pos"], cur, b.attn)
        else:
            o, ck, cv, pos = decode_attn(q, k, v, st["k"], st["v"],
                                         st["pos"], cur, b.attn, start=start)
        o = out_project(o, bp["attn"], dp=dp.get("attn"), eid=eid)
        if b.sandwich_norm:
            o = rms_norm(o, eff_param(bp["post_attn_norm"],
                                      dp.get("post_attn_norm"), eid),
                         cfg.rms_eps, _gemma(cfg))
        x = x + o
        if cross_kv is not None:
            hc = rms_norm(x, bp["cross_norm"], cfg.rms_eps)
            qc = jnp.einsum("btd,dhk->bthk", hc, bp["cross"]["wq"],
                            optimize=True)
            o2, m2, l2 = decode_attention_partial(
                qc, cross_kv[0], cross_kv[1],
                jnp.arange(cross_kv[0].shape[1]), cur + 10 ** 9,
                dataclasses.replace(b.attn, causal=False, window=None))
            x = x + out_project(finalize_partial(o2, m2, l2)[:, None]
                                .astype(x.dtype), bp["cross"])
        x, _ = _apply_ffn(x, bp, b, cfg, rt, dp=dp, eid=eid)
        if paged is not None:
            return x, {"k": ck, "v": cv}   # tables/lens live at cache level
        return x, {"k": ck, "v": cv, "pos": pos}
    if b.kind == "mamba":
        h = rms_norm(x, bp["pre_norm"], cfg.rms_eps)
        out, (hn, conv) = mamba_mod.mamba_decode_step(
            h, bp["mamba"], b.mamba, (st["h"], st["conv"]))
        x = x + out
        x, _ = _apply_ffn(x, bp, b, cfg, rt)
        return x, {"h": hn, "conv": conv}
    if b.kind == "rwkv":
        h = rms_norm(x, bp["pre_norm"], cfg.rms_eps)
        out, (S, tm) = rwkv_mod.rwkv_time_mix(
            h, bp["rwkv"], b.rwkv, state=(st["S"], st["tm"]), chunk=1,
            impl="einsum")  # single-token step: matmul form is pointless
        x = x + out
        h2 = rms_norm(x, bp["ffn_norm"], cfg.rms_eps)
        out2, cm = rwkv_mod.rwkv_channel_mix(h2, bp["ffn"], state=st["cm"])
        x = x + out2
        return x, {"S": S, "tm": tm, "cm": cm}
    raise ValueError(b.kind)


def decode_step(params, token, cache, cfg: ModelConfig, rt: Runtime,
                delta=None, eid=None):
    """token [B, 1] int32 -> (logits [B, 1, V], new_cache).

    Scan-compatible by contract: the position ``cache["cur"]`` is consumed
    as a traced int32 scalar (normalised below, so host-built caches with
    a Python-int ``cur`` also work), every cache update is functional with
    stable shapes, and the returned cache has the identical pytree
    structure — the serving layer runs this body under ``lax.scan`` with
    the cache donated (:mod:`repro.serve.decode_loop`).
    """
    if rt.embed_lookup is not None:
        x = rt.embed_lookup(params["embed"], token)
    else:
        x = params["embed"][token]
    if delta is not None:
        x = add_delta(x, embed_delta_rows(delta.get("embed"), token, eid,
                                          cfg.d_model))
    if cfg.embed_scale:
        x = (x.astype(jnp.float32) * np.sqrt(cfg.d_model)).astype(x.dtype)
    x = rt.shard(x, ("batch", "seq", "embed_act"))
    paged = "tables" in cache        # block-table KV (repro.serve.paged_kv)
    if paged:
        cur = None
        lens = jnp.asarray(cache["lens"], jnp.int32)     # [B] per-row pos
        active = jnp.asarray(cache["active"], bool)
        start = jnp.asarray(cache["start"], jnp.int32)
        pg = (cache["tables"], lens, active)
    else:
        cur = jnp.asarray(cache["cur"], jnp.int32)   # traced scalar position
        start = cache.get("start")      # [B] first real position per row
        pg = None
    cross = cache.get("cross")
    delta_blocks = delta.get("blocks") if delta is not None else None

    def body(carry, xs):
        h = carry
        unit_params, unit_cache, unit_cross, unit_delta = xs
        new_states = {}
        for i, b in enumerate(cfg.pattern):
            ck = (unit_cross["k"], unit_cross["v"]) if (
                unit_cross is not None and b.kind == "attn") else None
            dp = (unit_delta.get(f"block{i}")
                  if unit_delta is not None else None)
            h, ns = _decode_block(h, unit_params[f"block{i}"], b, cfg, rt,
                                  unit_cache[f"block{i}"], cur, cross_kv=ck,
                                  dp=dp, eid=eid, start=start, paged=pg)
            new_states[f"block{i}"] = ns
        return h, new_states

    x = x.astype(dtype_of(cfg))
    x, new_layers = lax.scan(
        body, x, (params["blocks"], cache["layers"], cross, delta_blocks))
    logits = logits_of(params, x, cfg, rt, delta=delta, eid=eid)
    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    if paged:
        # only live rows advance; finished rows' lens freeze so their last
        # real position stays addressable if the row is ever inspected
        new_cache["lens"] = lens + active.astype(jnp.int32)
    else:
        new_cache["cur"] = cur + 1
    return logits, new_cache


def _ring_fill(full: jax.Array, pos_abs: int, S: int):
    """Scatter the last min(T,S) tokens of a [B, T, ...] tensor into ring
    slots (slot = pos % S).  Returns ([B, S, ...], pos_arr [S])."""
    T = full.shape[1]
    if T >= S:
        last = full[:, -S:]
        shift = (T - S) % S
        cache = jnp.roll(last, shift=shift, axis=1)
        pos = jnp.roll(jnp.arange(T - S, T), shift=shift)
    else:
        pad = [(0, 0), (0, S - T)] + [(0, 0)] * (full.ndim - 2)
        cache = jnp.pad(full, pad)
        pos = jnp.concatenate([jnp.arange(T),
                               jnp.full((S - T,), -1, jnp.int32)])
    return cache, pos.astype(jnp.int32)


def prefill(params, tokens, cfg: ModelConfig, rt: Runtime, cache_len: int,
            mm_embeds=None, enc_out=None, delta=None, eid=None, start=None,
            kv_sharding=None):
    """Run the full prompt, returning (last-token logits, filled cache).

    ``start`` (optional, [B] int32) marks each row's first real token:
    left-pad positions before it are masked out of attention, and the mask
    is carried in the cache (``cache["start"]``) so decode steps keep
    ignoring them.  Only meaningful for pure-attention stacks — recurrent
    blocks consume pad tokens through their state.

    ``kv_sharding`` (optional, a ``NamedSharding`` over the serving mesh,
    static under jit) constrains every 5-D cache buffer — KV rings, rwkv
    state, cross-KV, all ``[U, B, ...]`` with batch at dim 1 — inside this
    launch, so the wave's cache comes out batch-sharded without a
    post-prefill reshard.  Rows are independent through decode, so this
    placement cannot change any computed value.
    """
    x = embed_tokens(params, tokens, cfg, rt, mm_embeds, delta=delta,
                     eid=eid)
    T = x.shape[1]
    B = x.shape[0]
    positions = jnp.arange(T)[None, :]
    states0 = _init_unit_states(cfg, B, stacked=True)
    x, aux, caches, new_states = _unit_scan(
        x, params["blocks"], cfg, rt, positions, cfg.pattern,
        collect_cache=True, states=states0, enc_out=enc_out,
        delta_blocks=delta.get("blocks") if delta is not None else None,
        eid=eid, kv_start=start)

    cache = init_decode_cache(cfg, B, cache_len, dtype=dtype_of(cfg))
    for i, b in enumerate(cfg.pattern):
        if b.kind == "attn":
            k_full, v_full = caches[i]  # [U, B, T, Hkv, D]
            S = cache["layers"][f"block{i}"]["k"].shape[2]
            ks, pos = jax.vmap(lambda kk: _ring_fill(kk, T, S))(k_full)
            vs, _ = jax.vmap(lambda vv: _ring_fill(vv, T, S))(v_full)
            cache["layers"][f"block{i}"] = {"k": ks, "v": vs, "pos": pos}
        elif b.kind == "mamba":
            h, conv = new_states[i]
            cache["layers"][f"block{i}"] = {"h": h, "conv": conv}
        elif b.kind == "rwkv":
            S, tm, cm = new_states[i]
            cache["layers"][f"block{i}"] = {"S": S, "tm": tm, "cm": cm}
    cache["cur"] = jnp.asarray(T, jnp.int32)
    if start is not None:
        cache["start"] = jnp.asarray(start, jnp.int32)
    if enc_out is not None:
        cache["cross"] = cross_cache_from_encoder(params, enc_out, cfg)
    if kv_sharding is not None:
        n = dict(kv_sharding.mesh.shape).get("model", 1)

        def _place(leaf):
            if getattr(leaf, "ndim", 0) == 5 and leaf.shape[1] % n == 0:
                return jax.lax.with_sharding_constraint(leaf, kv_sharding)
            return leaf

        cache = jax.tree_util.tree_map(_place, cache)
    logits = logits_of(params, x[:, -1:], cfg, rt, delta=delta, eid=eid)
    return logits, cache


def _init_unit_states(cfg: ModelConfig, batch: int, stacked: bool):
    """Initial recurrent states for mamba/rwkv blocks (attn -> None)."""
    dtype = dtype_of(cfg)
    states = []
    for b in cfg.pattern:
        if b.kind == "mamba":
            s = mamba_mod.init_mamba_state(batch, cfg.d_model, b.mamba, dtype)
        elif b.kind == "rwkv":
            s = rwkv_mod.init_rwkv_state(batch, cfg.d_model, b.rwkv, dtype)
        else:
            states.append(None)
            continue
        if stacked:
            s = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None],
                                           (cfg.n_units,) + a.shape), s)
        states.append(s)
    if all(s is None for s in states):
        return None
    return tuple(states)


def cross_cache_from_encoder(params, enc_out, cfg: ModelConfig):
    """Precompute per-unit cross-attention K/V from encoder output."""
    stacked = params["blocks"]["block0"]["cross"]
    k = jnp.einsum("bsd,udhk->ubshk", enc_out, stacked["wk"], optimize=True)
    v = jnp.einsum("bsd,udhk->ubshk", enc_out, stacked["wv"], optimize=True)
    return {"k": k.astype(enc_out.dtype), "v": v.astype(enc_out.dtype)}


def encode(params, frames, cfg: ModelConfig, rt: Runtime):
    """Encoder stack for enc-dec archs.  frames: [B, S_src, embed_dim]
    (precomputed modality-frontend embeddings — the stub)."""
    x = jnp.einsum("bne,ed->bnd", frames.astype(dtype_of(cfg)),
                   params["frontend_proj"], optimize=True)
    x = rt.shard(x, ("batch", "seq", "embed_act"))
    positions = jnp.arange(x.shape[1])[None, :]
    x, _, _, _ = _unit_scan(x, params["enc_blocks"], cfg, rt, positions,
                            cfg.enc_pattern)
    return rms_norm(x, params["enc_final_norm"], cfg.rms_eps)
