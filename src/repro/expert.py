"""First-class ComPEFT expert artifact: one expert, many representations.

ComPEFT's value proposition (paper §3, Algorithm 1) is that a single expert
exists in several forms and moves between them cheaply:

    DENSE ──compress──> TERNARY ──pack──> PACKED ──encode──> GOLOMB
      ^                    |                 |                  |
      └────decompress──────┴─────unpack──────┴──────decode──────┘

* ``DENSE``   — pytree of f32 task-vector leaves (``tau = theta_ft -
  theta_init``), or its reconstruction ``tau_tilde = signs * scale`` when
  the expert was built from a compressed form.
* ``TERNARY`` — pytree of :class:`~repro.core.compeft.CompressedTensor`
  (int8 signs + one scalar; the device-compute-friendly oracle form).
* ``PACKED``  — pytree of :class:`~repro.core.packing.PackedTernary`
  (2 bits/param bitplanes; what the serving cache keeps resident and the
  Pallas kernels consume).
* ``GOLOMB``  — ``{path: bytes}`` Golomb-Rice streams (the storage/wire
  format; host-side codec).

:class:`Expert` carries name/kind/config metadata and realises each
representation lazily via :meth:`Expert.as_`.  Every transition is a thin
wrapper over the pre-existing paths (``compress`` / ``compress_packed`` /
``pack_tree`` / the vectorized Golomb codec), so results are bit-identical
to calling those functions by hand.  :meth:`Expert.save` /
:meth:`Expert.load` unify the ``checkpoint.export_expert`` npz format and
the ``ExpertStore`` cold-Golomb tier — one on-disk artifact, readable by
both old and new entry points (and, for ``.cpft`` paths, the transport
wire container of :mod:`repro.transport.wire`).

The facade in :mod:`repro.api` builds on this class; the serving stack's
:class:`~repro.serve.expert_cache.ExpertRegistry` stores and promotes
Experts across its tiers, and :mod:`repro.transport` moves them between
hosts.
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# Representation lattice, cheapest-to-reconstruct first.
DENSE = "dense"
TERNARY = "ternary"
PACKED = "packed"
GOLOMB = "golomb"
REPRESENTATIONS = (DENSE, TERNARY, PACKED, GOLOMB)

_FORMAT = "compeft-expert-v1"


def _path_str(path) -> str:
    from repro.peft.lora import _path_str as f
    return f(path)


def _flatten(tree: PyTree, is_leaf=None) -> dict[str, Any]:
    """Canonical {path: leaf} view of any pytree (dicts keep their keys)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)
    return {_path_str(p): l for p, l in flat}


def _is_ct(x) -> bool:
    from repro.core.compeft import CompressedTensor
    return isinstance(x, CompressedTensor)


def _is_pt(x) -> bool:
    from repro.core.packing import PackedTernary
    return isinstance(x, PackedTernary)


def planes_from_signs(signs: np.ndarray, scale: float, shape: tuple,
                      orig_dtype) -> Any:
    """Host int8 {-1,0,1} signs -> PackedTernary (np packbits, LE words)."""
    from repro.core.packing import LANE, PackedTernary
    signs = np.asarray(signs).reshape(-1)
    pad = (-signs.size) % LANE
    if pad:
        signs = np.concatenate([signs, np.zeros((pad,), np.int8)])
    pos = np.packbits(signs == 1, bitorder="little").view(np.uint32)
    neg = np.packbits(signs == -1, bitorder="little").view(np.uint32)
    return PackedTernary(pos=jnp.asarray(pos), neg=jnp.asarray(neg),
                        scale=jnp.asarray(scale, jnp.float32),
                        shape=tuple(shape), orig_dtype=orig_dtype)


def _np_dtype(name: str):
    return jnp.bfloat16 if name == "bfloat16" else np.dtype(name)


class Expert:
    """A named ComPEFT expert with lazily-realised representations.

    Construct with :meth:`from_task_vector` / :meth:`from_finetune` (dense
    input), :meth:`from_packed` (serving artifacts) or :meth:`load` (disk).
    ``as_(rep)`` returns the expert in the requested representation,
    converting (and caching) along the lattice as needed.
    """

    def __init__(self, name: str, kind: str = "full", *,
                 density: float = 0.0, alpha: float = 1.0,
                 per_tensor: bool = True, method: str = "streaming",
                 meta: Optional[dict] = None):
        self.name = name
        self.kind = kind                   # "lora" | "ia3" | "full"
        self.density = density
        self.alpha = alpha
        self.per_tensor = per_tensor
        self.method = method               # "streaming" | "exact"
        self.meta = dict(meta or {})
        self._reps: dict[str, Any] = {}
        # per-leaf geometry, required to rebuild planes from Golomb streams
        self._leaf_meta: dict[str, dict] = {}
        self._manifest: Optional[dict] = None   # raw on-disk manifest (load)

    # ---------------- constructors ----------------

    @classmethod
    def from_task_vector(cls, tau: PyTree, *, name: str = "expert",
                         kind: str = "full", density: float = 0.05,
                         alpha: float = 1.0, per_tensor: bool = True,
                         method: str = "streaming",
                         meta: Optional[dict] = None) -> "Expert":
        """Wrap a dense task vector; compression happens on first ``as_``."""
        if method not in ("streaming", "exact"):
            raise ValueError(f"unknown compression method {method!r}")
        ex = cls(name, kind, density=density, alpha=alpha,
                 per_tensor=per_tensor, method=method, meta=meta)
        ex._reps[DENSE] = tau
        return ex

    @classmethod
    def from_finetune(cls, theta_init: PyTree, theta_ft: PyTree,
                      **kw) -> "Expert":
        """tau = theta_ft - theta_init (paper §2), then as from_task_vector."""
        from repro.peft.task_vector import task_vector
        return cls.from_task_vector(task_vector(theta_init, theta_ft), **kw)

    @classmethod
    def from_packed(cls, name: str, kind: str, packed: PyTree, *,
                    density: float = 0.0, alpha: float = 1.0,
                    meta: Optional[dict] = None) -> "Expert":
        """Adopt an existing tree of PackedTernary (legacy artifacts)."""
        ex = cls(name, kind, density=density, alpha=alpha, meta=meta)
        ex._reps[PACKED] = packed
        return ex

    # ---------------- representation lattice ----------------

    def available(self) -> tuple[str, ...]:
        """Representations already realised (no conversion cost)."""
        return tuple(r for r in REPRESENTATIONS if r in self._reps)

    def as_(self, rep: str) -> PyTree:
        """The expert in representation ``rep`` (converted and cached).

        DENSE/TERNARY/PACKED come back as pytrees mirroring the source
        structure; GOLOMB is a flat ``{path: bytes}`` dict.  All transitions
        are bit-identical to the legacy ``compress`` / ``compress_packed``
        / ``pack_tree`` / Golomb-codec paths they wrap.
        """
        if rep not in REPRESENTATIONS:
            raise ValueError(f"unknown representation {rep!r}; "
                             f"choose from {REPRESENTATIONS}")
        if rep not in self._reps:
            self._reps[rep] = self._realize(rep)
        return self._reps[rep]

    def _realize(self, rep: str) -> PyTree:
        from repro.core import (CompressionConfig, compress, compress_packed,
                                decompress, pack_tree, unpack_tree)
        have = self._reps
        if rep == PACKED:
            if TERNARY in have:
                return pack_tree(have[TERNARY])
            if DENSE in have:
                cfg = self._ccfg()
                if self.method == "exact":
                    return pack_tree(self.as_(TERNARY))
                return compress_packed(have[DENSE], cfg)
            if GOLOMB in have:
                return self._decode_golomb()
            raise ValueError(f"expert {self.name!r} holds no representation")
        if rep == TERNARY:
            if PACKED in have:
                return unpack_tree(have[PACKED])
            if DENSE in have:
                if self.method == "streaming":
                    return unpack_tree(self.as_(PACKED))
                return compress(have[DENSE], self._ccfg())
            return unpack_tree(self.as_(PACKED))
        if rep == DENSE:
            # lossy inverse: reconstruction tau_tilde = signs * scale
            return decompress(self.as_(TERNARY))
        if rep == GOLOMB:
            return self._encode_golomb()
        raise AssertionError(rep)

    def _ccfg(self):
        from repro.core import CompressionConfig
        if not (0.0 < self.density <= 1.0):
            raise ValueError(
                f"expert {self.name!r} was not given a compression density; "
                "pass density= at construction to compress a dense tau")
        return CompressionConfig(density=self.density, alpha=self.alpha,
                                 per_tensor=self.per_tensor)

    def _encode_golomb(self) -> dict[str, bytes]:
        from repro.core import golomb
        from repro.core.packing import signs_np
        blobs = {}
        for path, pt in self.packed.items():
            blobs[path] = golomb.encode(signs_np(pt), float(pt.scale))
            self._leaf_meta.setdefault(path, {
                "shape": tuple(pt.shape),
                "orig_dtype": pt.orig_dtype,
            })
        return blobs

    def _decode_golomb(self) -> dict[str, Any]:
        """One batched host decode over every leaf (vectorized codec)."""
        from repro.core import golomb
        decoded = golomb.decode_tree(self._reps[GOLOMB])
        out = {}
        for path, (signs, scale) in decoded.items():
            m = self._leaf_meta[path]
            out[path] = planes_from_signs(signs, scale, m["shape"],
                                          m["orig_dtype"])
        return out

    # ---------------- serving views ----------------

    def as_path_dict(self, rep: str = PACKED) -> dict[str, Any]:
        """Flat ``{path: leaf}`` view of ``as_(rep)`` (paths match the base
        parameter tree's ``_path_str`` flattening)."""
        tree = self.as_(rep)
        if rep == GOLOMB:
            return dict(tree)
        is_leaf = (_is_pt if rep == PACKED
                   else _is_ct if rep == TERNARY else None)
        return _flatten(tree, is_leaf=is_leaf)

    @property
    def packed(self) -> dict[str, Any]:
        """Flat ``{path: PackedTernary}`` — the form the serving tiers and
        merge kernels consume (canonical view of ``as_(PACKED)``)."""
        return self.as_path_dict(PACKED)

    def to_dense_tau(self) -> PyTree:
        """Reconstructed dense task vector ``tau_tilde = signs * scale``
        (the ``ExpertArtifact`` contract — always the reconstruction, even
        when the original dense tau is cached)."""
        from repro.core import decompress
        return decompress(self.as_(TERNARY))

    # ---------------- accounting ----------------

    def nbytes(self, rep: str = PACKED) -> int:
        """Byte size of one representation (default: the packed artifact —
        the ``ExpertArtifact.nbytes`` contract)."""
        from repro.core import tree_packed_bytes
        tree = self.as_(rep)
        if rep == DENSE:
            return sum(l.size * jnp.dtype(l.dtype).itemsize
                       for l in jax.tree_util.tree_leaves(tree))
        if rep == TERNARY:
            return sum(c.signs.size + 4 for c in
                       jax.tree_util.tree_leaves(tree, is_leaf=_is_ct))
        if rep == PACKED:
            return tree_packed_bytes(tree)
        return sum(len(b) for b in tree.values())          # GOLOMB

    def summary(self) -> dict:
        """Diagnostics (subsumes ``compression_summary``): density, bit
        accounting per representation, reconstruction error when the
        original dense tau is on hand."""
        from repro.core import compression_summary
        from repro.core.packing import golomb_total_bits
        tern = self.as_(TERNARY)
        if DENSE in self._reps:
            s = compression_summary(self._reps[DENSE], tern)
        else:
            comps = jax.tree_util.tree_leaves(tern, is_leaf=_is_ct)
            n = sum(int(np.prod(c.shape)) for c in comps)
            nnz = sum(int(jnp.sum(jnp.abs(c.signs).astype(jnp.int32)))
                      for c in comps)
            s = {"n_params": n, "nnz": nnz, "density": nnz / max(n, 1),
                 "dense_bits": 16 * n, "rel_recon_err": None}
        s["name"] = self.name
        s["kind"] = self.kind
        s["bytes"] = {r: self.nbytes(r) for r in self.available()}
        s["bytes"][PACKED] = self.nbytes(PACKED)
        s.setdefault("golomb_bits",
                     golomb_total_bits(s["n_params"],
                                       max(s["density"], 1e-12)))
        return s

    def __repr__(self) -> str:
        return (f"Expert(name={self.name!r}, kind={self.kind!r}, "
                f"density={self.density}, alpha={self.alpha}, "
                f"reps={list(self.available())})")

    # ---------------- persistence ----------------

    def save(self, path: str) -> dict:
        """Write the storage-optimal (Golomb) artifact to disk.

        Two containers, one artifact: a ``.cpft`` path writes the
        transport wire format (:mod:`repro.transport.wire` — the blob a
        network backend would move, checksummed); any other path writes
        the npz layout, a superset of the legacy
        ``checkpoint.export_expert`` format — files written here load
        through the old ``import_expert`` and vice versa.  Returns size
        accounting ``{dense_bytes, compressed_bytes, ratio}`` (same
        contract as ``export_expert``).
        """
        from repro.transport.wire import WIRE_SUFFIX
        if path.endswith(WIRE_SUFFIX):
            from repro.transport.wire import encode_expert
            blob = encode_expert(self, rep=GOLOMB)
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            with open(path, "wb") as f:
                f.write(blob)
            dense = sum(pt.n_elements * 2 for pt in self.packed.values())
            return {"dense_bytes": dense, "compressed_bytes": len(blob),
                    "ratio": dense / max(len(blob), 1)}
        blobs = self.as_(GOLOMB)
        packed = self.packed
        manifest = {"format": _FORMAT, "name": self.name, "kind": self.kind,
                    "density": self.density, "alpha": self.alpha,
                    "meta": self.meta, "leaves": []}
        arrays, dense_bytes = {}, 0
        san = _sanitize
        for i, (p, blob) in enumerate(blobs.items()):
            key = f"e{i}_{san(p)[:80]}"
            arrays[key] = np.frombuffer(blob, np.uint8)
            pt = packed[p]
            manifest["leaves"].append({
                "path": p, "key": key, "shape": list(pt.shape),
                "dtype": str(jnp.dtype(pt.orig_dtype))})
            dense_bytes += pt.n_elements * 2       # bf16 wire baseline
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        np.savez(path, manifest=json.dumps(manifest), **arrays)
        comp_bytes = sum(a.nbytes for a in arrays.values())
        return {"dense_bytes": dense_bytes, "compressed_bytes": comp_bytes,
                "ratio": dense_bytes / max(comp_bytes, 1)}

    @classmethod
    def load(cls, path: str, name: Optional[str] = None) -> "Expert":
        """Read an expert artifact: new-format npz, legacy
        ``export_expert`` npz, or ``.cpft`` wire blobs alike (the
        container is sniffed, not judged by extension).  Decoding to
        planes is deferred to the first ``as_``.
        """
        with open(path, "rb") as f:
            head = f.read(4)
        from repro.transport.wire import MAGIC
        if head == MAGIC:
            from repro.transport.wire import decode_expert
            with open(path, "rb") as f:
                return decode_expert(f.read(), name=name)
        data = np.load(path)
        manifest = json.loads(str(data["manifest"]))
        legacy = manifest.get("format") != _FORMAT
        ex = cls(
            name or manifest.get("name")
            or os.path.splitext(os.path.basename(path))[0],
            manifest.get("kind", "full"),
            density=manifest.get("density", 0.0),
            alpha=manifest.get("alpha", 1.0),
            meta=manifest.get("meta", {"legacy_format": True} if legacy
                              else {}),
        )
        blobs = {}
        for leaf in manifest["leaves"]:
            blobs[leaf["path"]] = data[leaf["key"]].tobytes()
            ex._leaf_meta[leaf["path"]] = {
                "shape": tuple(leaf["shape"]),
                "orig_dtype": _np_dtype(leaf["dtype"]),
            }
        ex._reps[GOLOMB] = blobs
        ex._manifest = manifest    # raw on-disk manifest (legacy shims)
        return ex


def _sanitize(path: str) -> str:
    import re
    return re.sub(r"[^A-Za-z0-9_]", "__", path)


def as_expert(obj: Any, name: str = "expert") -> Expert:
    """Normalize legacy artifacts (anything with ``.packed``) to Expert."""
    if isinstance(obj, Expert):
        return obj
    if hasattr(obj, "packed"):          # peft.task_vector.ExpertArtifact
        return Expert.from_packed(
            getattr(obj, "name", name), getattr(obj, "kind", "full"),
            obj.packed, density=getattr(obj, "density", 0.0),
            alpha=getattr(obj, "alpha", 1.0),
            meta=dict(getattr(obj, "meta", {}) or {}))
    raise TypeError(f"cannot interpret {type(obj).__name__} as an Expert")
