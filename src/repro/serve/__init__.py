from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.serve.expert_cache import (DeviceCache, ExpertRegistry,
                                      ExpertStore, RemoteExpertStore,
                                      SwapStats, uncompressed_baseline_bytes)

__all__ = ["EngineConfig", "Request", "ServeEngine", "DeviceCache",
           "ExpertRegistry", "ExpertStore", "RemoteExpertStore", "SwapStats",
           "uncompressed_baseline_bytes"]
