from repro.serve.decode_loop import PAD_TOKEN, SamplingConfig
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.serve.expert_cache import (DeviceCache, ExpertRegistry,
                                      ExpertStore, RemoteExpertStore,
                                      SwapStats, uncompressed_baseline_bytes)

__all__ = ["EngineConfig", "Request", "ServeEngine", "DeviceCache",
           "ExpertRegistry", "ExpertStore", "RemoteExpertStore", "SwapStats",
           "SamplingConfig", "PAD_TOKEN", "uncompressed_baseline_bytes"]
