from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.serve.expert_cache import (DeviceCache, ExpertStore, SwapStats,
                                      uncompressed_baseline_bytes)

__all__ = ["EngineConfig", "Request", "ServeEngine", "DeviceCache",
           "ExpertStore", "SwapStats", "uncompressed_baseline_bytes"]
