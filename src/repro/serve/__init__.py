from repro.serve.decode_loop import PAD_TOKEN, SamplingConfig
from repro.serve.engine import (DONE, FAILED, PENDING, EngineConfig, Request,
                                ServeEngine)
from repro.serve.expert_cache import (DeviceCache, ExpertRegistry, ExpertStore,
                                      ExpertUnavailable, RemoteExpertStore,
                                      SwapStats, uncompressed_baseline_bytes)
from repro.serve.journal import (JournalState, JournalWriter, read_records,
                                 replay)
from repro.serve.paged_kv import BlockAllocator, blocks_for, init_paged_cache
from repro.serve.scheduler import (SCHEDULERS, AffinityScheduler,
                                   FIFOScheduler, PriorityScheduler,
                                   make_scheduler)
from repro.serve.snapshot import Snapshot, load_snapshot, write_snapshot

__all__ = ["EngineConfig", "Request", "ServeEngine", "DeviceCache",
           "ExpertRegistry", "ExpertStore", "ExpertUnavailable",
           "RemoteExpertStore", "SwapStats", "SamplingConfig", "PAD_TOKEN",
           "PENDING", "DONE", "FAILED", "uncompressed_baseline_bytes",
           "BlockAllocator", "blocks_for", "init_paged_cache",
           "FIFOScheduler", "PriorityScheduler", "AffinityScheduler",
           "SCHEDULERS", "make_scheduler",
           "JournalState", "JournalWriter", "read_records", "replay",
           "Snapshot", "load_snapshot", "write_snapshot"]
