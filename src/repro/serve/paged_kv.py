"""Paged KV cache: block tables + a free-list allocator (vLLM-style).

The dense-slot engine left-pads every row of a wave to one width and, on
each slot refill, splices a whole per-row KV tensor into the running
batch cache — admission is then hostage to the wave's position (a prompt
longer than ``cur`` cannot be left-padded down, and a budget that would
wrap the ring blocks the queue head).  This module removes both
constraints by storing KV in **fixed-size blocks** drawn from one pooled
buffer per attention layer:

* ``k``/``v`` pools: ``[U, NB, BS, Hkv, D]`` — ``NB`` blocks of ``BS``
  token slots, shared by every row (block 0 is reserved as the *trash*
  block: rows that have exhausted their generation budget keep stepping
  with the batch, and their dead writes are redirected there so they can
  never corrupt a live row's blocks).
* ``tables``: ``[B, MAXB]`` int32 per-row block lists (-1 = unallocated).
  Row ``b``'s token at position ``p`` lives in block ``tables[b, p //
  BS]`` at slot ``p % BS`` — one table shared by all layers, because
  every layer writes the same logical positions.
* ``lens``: ``[B]`` int32 per-row write positions; ``start``: ``[B]``
  first real (non-pad) position; ``active``: ``[B]`` bool, rows still
  generating.

Admission becomes "allocate ``ceil((Lp + max_new) / BS)`` blocks and
scatter the row's prefill KV into them" — no re-padding of the batch, no
full-row splice, and any prompt length is admissible whenever enough
blocks are free.  Prompts are left-padded only up to the next block
boundary (``Lp = ceil(L / BS) * BS``), which bounds prefill compilation
variants to one per *bucket* instead of one per length; the pad
positions are masked via ``start`` exactly like the dense path.

Attention reads are gather-based: ``pool[tables[b]]`` materialises the
row's positions in order, so the per-position validity mask is just
``start[b] <= s <= lens[b]`` (see
:func:`repro.models.attention.paged_attention_partial`).  The allocator
is host-side and O(1) per block; the device never sees the free list.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dtype_of

PyTree = Any

# block 0 is never handed out: dead rows' writes are redirected to it and
# gathers of unallocated table entries are clamped onto it (then masked)
TRASH_BLOCK = 0


def round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def blocks_for(prompt_len: int, max_new: int, block_size: int) -> tuple:
    """(bucketed prompt length Lp, blocks needed for Lp + max_new).

    The prompt is left-padded to the next block boundary (compile-variant
    bucketing); decode then writes positions ``Lp .. Lp + max_new - 1``.
    """
    lp = round_up(max(prompt_len, 1), block_size)
    need = -(-(lp + max_new) // block_size)
    return lp, need


class BlockAllocator:
    """Host-side free-list allocator over ``n_blocks`` fixed-size blocks.

    Block :data:`TRASH_BLOCK` is reserved.  ``alloc`` is all-or-nothing:
    it returns ``None`` (allocating nothing) when fewer than ``n`` blocks
    are free, so admission control is one ``available`` comparison.
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 2:
            raise ValueError("paged pool needs >= 2 blocks "
                             "(block 0 is reserved)")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._free = list(range(n_blocks - 1, 0, -1))  # pop() -> low ids first
        self.peak_in_use = 0

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return (self.n_blocks - 1) - len(self._free)

    def alloc(self, n: int) -> Optional[list]:
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return out

    def free(self, blocks) -> None:
        for b in blocks:
            if not (0 < b < self.n_blocks):
                raise ValueError(f"freeing invalid block id {b}")
            if b in self._free:
                raise ValueError(f"double free of block {b}")
            self._free.append(b)

    # -- crash-consistency (repro.serve.snapshot) -------------------------

    def state(self) -> list:
        """Free-list snapshot in exact order.  ``alloc`` pops from the
        tail, so the order IS the future allocation order — restoring it
        verbatim makes post-resume block assignment deterministic."""
        return list(self._free)

    @classmethod
    def from_state(cls, n_blocks: int, block_size: int,
                   free: list) -> "BlockAllocator":
        """Rebuild an allocator from a snapshotted free list."""
        a = cls(n_blocks, block_size)
        ids = [int(b) for b in free]
        if len(set(ids)) != len(ids) or any(
                not (0 < b < n_blocks) for b in ids):
            raise ValueError(f"invalid snapshotted free list: {ids}")
        a._free = ids
        a.peak_in_use = a.in_use
        return a


def init_paged_cache(cfg: ModelConfig, batch: int, n_blocks: int,
                     block_size: int, max_blocks: int, dtype=None,
                     mesh=None) -> PyTree:
    """Empty paged decode state (pure-attention patterns only).

    The returned dict is what :func:`repro.models.transformer.decode_step`
    dispatches on: the presence of ``"tables"`` selects the paged
    write/attend path and per-row positions (``lens``) instead of the
    dense ring buffer's shared scalar ``cur``.

    With ``mesh=`` the per-layer block pools are partitioned along the
    mesh's ``model`` axis on their block dim (each device owns a shard of
    the pool; paged reads/writes are gathers/scatters, so sharding the
    storage dim leaves the math bit-identical).  Tables / lens / start /
    active stay replicated — they are host-roundtripped row vectors.
    """
    dtype = dtype or dtype_of(cfg)

    def place(z):
        if mesh is None:
            return z
        from repro.distributed.sharding import serve_kv_sharding
        return jax.device_put(
            z, serve_kv_sharding(mesh, tuple(z.shape), layout="paged"))

    layers = {}
    for i, b in enumerate(cfg.pattern):
        if b.kind != "attn":
            raise ValueError("paged KV covers pure-attention patterns only; "
                             f"block {i} is {b.kind!r}")
        layers[f"block{i}"] = {
            "k": place(jnp.zeros((cfg.n_units, n_blocks, block_size,
                                  b.attn.n_kv, b.attn.head_dim), dtype)),
            "v": place(jnp.zeros((cfg.n_units, n_blocks, block_size,
                                  b.attn.n_kv, b.attn.head_dim), dtype)),
        }
    return {
        "layers": layers,
        "tables": jnp.full((batch, max_blocks), -1, jnp.int32),
        "lens": jnp.zeros((batch,), jnp.int32),
        "start": jnp.zeros((batch,), jnp.int32),
        "active": jnp.zeros((batch,), bool),
    }


@partial(jax.jit, donate_argnums=(0,))
def insert_prefill_rows(cache: PyTree, row_layers: PyTree, js: jax.Array,
                        prompt_blocks: jax.Array, row_tables: jax.Array,
                        lens_new: jax.Array, start_new: jax.Array) -> PyTree:
    """Scatter N freshly-prefilled rows into the pooled cache.

    ``row_layers``: ``{block_i: {"k"/"v": [U, N, Lp, Hkv, D]}}`` — the
    per-row ring caches a dense prefill at ``cache_len = Lp`` produced
    (``Lp`` a multiple of the block size, so slot order IS position
    order); ``js`` [N] the batch rows being (re)filled; ``prompt_blocks``
    [N, Lp // BS] the pool blocks receiving the prompt KV; ``row_tables``
    [N, MAXB] the complete per-row block lists (prompt + decode-growth
    blocks, -1 padded).  One fused donated update per admission group —
    this replaces the dense path's whole-batch KV splice.
    """

    def put(pool, row):
        U, NB, BS, H, D = pool.shape
        N, nb = prompt_blocks.shape
        r = row.reshape(U, N, nb, BS, H, D).astype(pool.dtype)
        # [U, N, nb, BS, H, D] scattered onto blocks [N, nb]
        return pool.at[:, prompt_blocks].set(r)

    layers = {
        name: {"k": put(cache["layers"][name]["k"], row_layers[name]["k"]),
               "v": put(cache["layers"][name]["v"], row_layers[name]["v"])}
        for name in cache["layers"]
    }
    return {
        "layers": layers,
        "tables": cache["tables"].at[js].set(row_tables),
        "lens": cache["lens"].at[js].set(lens_new),
        "start": cache["start"].at[js].set(start_new),
        "active": cache["active"].at[js].set(True),
    }
