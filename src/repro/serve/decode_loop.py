"""Device-resident decode: the wave loop as one compiled K-step launch.

The eager serving loop pays one ``jax.jit`` dispatch **and** one blocking
host sync (``np.asarray(tok)``) per generated token.  This module compiles
K decode steps into a single launch instead: a ``lax.scan`` whose body

  1. *emits* the pending token of every still-active row into an on-device
     ``[B, K]`` buffer (finished rows emit :data:`PAD_TOKEN` — a done row
     never forces an early host exit),
  2. decrements each row's ``remaining`` generation budget, and
  3. runs ``api.decode_step`` + on-device token selection (greedy argmax or
     temperature/top-k sampling) for the whole batch — guarded by a
     ``lax.cond`` on the on-device all-rows-done predicate, so the KV
     position stops advancing the moment no row needs another token
     (exactly where the eager loop breaks; this is what keeps chunked
     decode bit-identical to eager, including the position mid-wave
     admissions left-pad against).

The KV cache is threaded through the launch with ``donate_argnums``: the
scan updates it functionally and XLA reuses the donated buffers, so no
per-step cache copy survives.  The engine becomes a *segmented* driver —
launch a chunk, sync **once** to flush K tokens, run host-side
admission/slot-refill, launch the next chunk.

Sampling is reproducible by construction: every row derives its stream
from ``fold_in(PRNGKey(seed), request.uid)`` and draws token *i* with
``fold_in(row_key, i)``, so the tokens a request samples depend only on
``(seed, uid, i)`` — not on the chunk size K, the slot it landed in, or
when mid-wave admission spliced it into the batch.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any

# emitted for rows whose budget is exhausted; engine flushes by count, so
# pad entries are never read — -1 makes any accidental read fail loudly
PAD_TOKEN = -1


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """On-device token selection knobs (static under jit).

    ``temperature <= 0`` selects greedy argmax — the mode whose chunked
    decode is bit-identical to the eager loop.  ``top_k = 0`` samples the
    full vocabulary.  ``seed`` roots every per-request key stream.
    """

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    def to_meta(self) -> dict:
        """JSON round-trip for the serve journal.  The sampled-stream
        contract is exactly these three numbers — per-row streams are
        pure functions of ``(seed, uid, draw index)`` under a fixed
        (temperature, top_k) — so resume() can refuse a mismatched
        engine before emitting a single token."""
        return {"temperature": self.temperature, "top_k": self.top_k,
                "seed": self.seed}

    @classmethod
    def from_meta(cls, d: dict) -> "SamplingConfig":
        return cls(temperature=float(d["temperature"]),
                   top_k=int(d["top_k"]), seed=int(d["seed"]))


def row_keys(seed: int, uids) -> jax.Array:
    """Per-request PRNG keys [B, 2]: ``fold_in(PRNGKey(seed), uid)``."""
    base = jax.random.PRNGKey(seed)
    u = jnp.asarray(uids, jnp.uint32)
    return jax.vmap(lambda x: jax.random.fold_in(base, x))(u)


def select_tokens(logits: jax.Array, keys: jax.Array, gen: jax.Array,
                  sampling: SamplingConfig, mesh=None) -> jax.Array:
    """logits [B, V] -> next token [B] int32, on device.

    ``gen`` is each row's position in its own token stream (number of
    tokens generated so far); token *i* is drawn with
    ``fold_in(keys[row], i)``, which makes sampled streams independent of
    chunk size and admission timing.

    On a serving mesh the vocab-parallel lm_head leaves ``logits`` sharded
    along V.  Greedy argmax is layout-invariant, but the PRNG behind
    ``jax.random.categorical`` draws *different bits* when its operand is
    sharded — so with ``mesh=`` the sampled path all-gathers the scaled
    logits (the one collective the serve design allows) before drawing,
    which restores the exact single-device bit stream.
    """
    logits = logits.astype(jnp.float32)
    if sampling.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / max(sampling.temperature, 1e-6)
    if sampling.top_k and sampling.top_k < logits.shape[-1]:
        kth = lax.top_k(scaled, sampling.top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        scaled = jax.lax.with_sharding_constraint(
            scaled, NamedSharding(mesh, PartitionSpec(None, None)))
    step_keys = jax.vmap(jax.random.fold_in)(keys, gen.astype(jnp.uint32))
    draw = jax.vmap(lambda k, l: jax.random.categorical(k, l))
    return draw(step_keys, scaled).astype(jnp.int32)


def make_token_select(sampling: SamplingConfig, mesh=None):
    """Jitted first-token selector over prefill logits [B, T, V]."""

    def first(logits, keys, gen):
        return select_tokens(logits[:, -1], keys, gen, sampling,
                             mesh=mesh)[:, None]

    return jax.jit(first)


def host_decode_steps(max_remaining: int, chunk: int) -> int:
    """How many decode steps a chunk launch executes on device, computed
    host-side so the engine can mirror ``cache["cur"]`` without a device
    round-trip.  The scan body emits first, then decodes only while some
    row still has budget after the emit — so a chunk whose largest
    remaining budget is R advances the position by ``min(K, R - 1)``."""
    return min(chunk, max(max_remaining - 1, 0))


def make_decode_chunk(api, rt, chunk: int, sampling: SamplingConfig,
                      mesh=None):
    """Compile the K-step wave loop body for one engine.

    Returns a jitted ``run(params, overlay, eid, tok, cache, remaining,
    gen, keys) -> (tok, cache, tokens [B, K])`` with the cache donated.
    ``overlay``/``eid`` are the zero-merge expert overlay and per-row
    expert ids (``None`` on the merge/grouped path); ``tok`` [B, 1] is the
    pending (generated, not yet emitted) token per row; ``remaining`` [B]
    the per-row budget of tokens still to emit; ``gen`` [B] each row's
    token-stream position; ``keys`` [B, 2] the per-row PRNG keys.

    One launch serves up to K tokens per row; the engine syncs once on the
    returned buffer, refills finished slots, and launches the next chunk.

    ``mesh`` (a serving mesh, or None) pins the per-chunk host-visible
    outputs — the pending token and the ``[B, K]`` emit buffer — to a
    fully-replicated layout, so the engine's once-per-chunk sync reads one
    local buffer instead of gathering token shards off every device.
    Placement only: selected token *values* are unchanged.
    """
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        rep = NamedSharding(mesh, PartitionSpec())
    else:
        rep = None

    def run(params, overlay, eid, tok, cache, remaining, gen, keys):
        def body(carry, _):
            tok, cache, remaining, gen = carry
            active = remaining > 0
            emit = jnp.where(active, tok[:, 0], PAD_TOKEN)
            remaining = jnp.where(active, remaining - 1, remaining)
            if "active" in cache:
                # paged KV: rows whose budget just ran dry flip inactive —
                # decode_step then redirects their writes to the trash
                # block and freezes their lens (structure-stable update)
                cache = dict(cache)
                cache["active"] = remaining > 0

            def step(op):
                tok, cache, gen = op
                logits, cache = api.decode_step(params, tok, cache, rt,
                                                delta=overlay, eid=eid)
                nxt = select_tokens(logits[:, -1], keys, gen, sampling,
                                    mesh=mesh)
                return nxt[:, None].astype(jnp.int32), cache, gen + 1

            # all-rows-done predicate ON DEVICE: once every budget is
            # spent the position stops advancing, mirroring the eager
            # loop's break — no host sync needed to stop early
            tok, cache, gen = lax.cond(jnp.any(remaining > 0), step,
                                       lambda op: op, (tok, cache, gen))
            return (tok, cache, remaining, gen), emit

        (tok, cache, _, _), buf = lax.scan(
            body, (tok, cache, remaining, gen), length=chunk)
        buf = buf.T                       # tokens as [B, K]
        if rep is not None:
            tok = jax.lax.with_sharding_constraint(tok, rep)
            buf = jax.lax.with_sharding_constraint(buf, rep)
        return tok, cache, buf

    # donate the KV cache (arg 4): the scan's functional updates then reuse
    # the same HBM buffers across all K steps and across chunk launches
    return jax.jit(run, donate_argnums=(4,))
