"""Multi-expert memory hierarchy — the paper's headline serving scenario.

Three tiers mirror §1 of the paper:

  ExpertStore   (disk/network tier)  — packed artifacts, or Golomb-coded
                                       blobs (``cold_golomb=True``) decoded
                                       on promotion in one vectorized pass
  HostCache     (CPU RAM tier)       — packed bitplane trees (2 bits/param)
  DeviceCache   (HBM tier, LRU)      — *packed* bitplane trees, bounded by a
                                       byte budget; evicts LRU

The device tier is packed-resident: experts stay in the 2-bit bitplane form
end-to-end.  Since PR 2 the cache also exposes **stacked plane buffers**
(:meth:`DeviceCache.stacked`): for a set of resident experts, one
``[E, words]`` buffer per leaf path that the batched serving kernels
(``ternary_matmul_grouped`` / ``unpack_add_many``) consume directly — the
zero-merge mixed-expert decode path never materialises merged parameters.
Stacks are invalidated when a member is evicted.

Swap cost accounting is explicit: every promotion records bytes moved, so
benchmarks can report transmission bytes and load latency, and the engine
can amortise swaps across batches.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Optional

import jax
import numpy as np

from repro.core import tree_packed_bytes
from repro.core.packing import stack_packed, stacked_bytes
from repro.peft.task_vector import ExpertArtifact

PyTree = Any

BASE = "__base__"   # pseudo-expert: serve the unmodified base weights


@dataclasses.dataclass
class SwapStats:
    store_to_host_bytes: int = 0
    host_to_device_bytes: int = 0
    promotions: int = 0
    evictions: int = 0
    hits: int = 0
    misses: int = 0
    seconds: float = 0.0
    stack_builds: int = 0
    stack_hits: int = 0
    stack_bytes: int = 0
    golomb_decode_seconds: float = 0.0

    def as_dict(self):
        return dataclasses.asdict(self)


class ExpertStore:
    """Cold tier: name -> ExpertArtifact.

    ``cold_golomb=True`` stores Golomb-Rice streams (the paper's
    storage-optimal wire format) instead of bitplanes; promotion then pays
    one *batched* host-side decode over all leaves of the expert
    (:func:`repro.core.golomb.decode_tree` — the vectorized codec, no
    per-bit Python loops) before packing to device planes.
    """

    def __init__(self, cold_golomb: bool = False):
        self.cold_golomb = cold_golomb
        self._store: dict[str, ExpertArtifact] = {}
        self._blobs: dict[str, dict] = {}
        self._meta: dict[str, dict] = {}

    def put(self, art: ExpertArtifact) -> None:
        if not self.cold_golomb:
            self._store[art.name] = art
            return
        from repro.core import golomb
        from repro.core.packing import signs_np
        blobs, meta = {}, {}
        flat = art.packed if isinstance(art.packed, dict) else None
        assert flat is not None, "cold_golomb store expects {path: planes}"
        for path, pt in flat.items():
            blobs[path] = golomb.encode(signs_np(pt), float(pt.scale))
            meta[path] = {"shape": tuple(pt.shape),
                          "orig_dtype": pt.orig_dtype}
        self._blobs[art.name] = blobs
        self._meta[art.name] = {"leaf": meta, "kind": art.kind,
                                "density": art.density, "alpha": art.alpha}

    def get(self, name: str) -> ExpertArtifact:
        if not self.cold_golomb:
            return self._store[name]
        from repro.core import golomb
        m = self._meta[name]
        decoded = golomb.decode_tree(self._blobs[name])   # one batched pass
        packed = {path: _planes_from_signs(signs, scale,
                                           m["leaf"][path]["shape"],
                                           m["leaf"][path]["orig_dtype"])
                  for path, (signs, scale) in decoded.items()}
        return ExpertArtifact(name=name, kind=m["kind"], packed=packed,
                              density=m["density"], alpha=m["alpha"])

    def names(self):
        return list(self._blobs if self.cold_golomb else self._store)

    def nbytes(self, name: str) -> int:
        if self.cold_golomb:
            return sum(len(b) for b in self._blobs[name].values())
        return self._store[name].nbytes


def _planes_from_signs(signs: np.ndarray, scale: float,
                       shape: tuple, orig_dtype) -> Any:
    """Host int8 signs -> PackedTernary (np packbits, little-endian words)."""
    import jax.numpy as jnp

    from repro.core.packing import LANE, PackedTernary
    n = signs.size
    pad = (-n) % LANE
    if pad:
        signs = np.concatenate([signs, np.zeros((pad,), np.int8)])
    pos = np.packbits(signs == 1, bitorder="little").view(np.uint32)
    neg = np.packbits(signs == -1, bitorder="little").view(np.uint32)
    return PackedTernary(pos=jnp.asarray(pos), neg=jnp.asarray(neg),
                         scale=jnp.asarray(scale, jnp.float32),
                         shape=tuple(shape), orig_dtype=orig_dtype)


class DeviceCache:
    """LRU cache of *packed bitplane trees* under a byte budget (HBM
    residency of ComPEFT experts; 2 bits/param instead of dense deltas),
    plus stacked per-path plane buffers for mixed-expert batches."""

    MAX_STACKS = 4   # LRU bound on distinct expert-set stacks kept resident

    def __init__(self, store: ExpertStore, capacity_bytes: int):
        self.store = store
        self.capacity = capacity_bytes
        self._cache: OrderedDict[str, PyTree] = OrderedDict()
        self._sizes: dict[str, int] = {}
        self._stacks: OrderedDict[tuple, dict] = OrderedDict()
        self.stats = SwapStats()

    def resident_bytes(self) -> int:
        """Packed trees + stacked buffers — everything under the budget."""
        return sum(self._sizes.values()) + self.stats.stack_bytes

    def _evict_one(self) -> None:
        old, _ = self._cache.popitem(last=False)
        self._sizes.pop(old)
        self.stats.evictions += 1
        for key in [k for k in self._stacks if old in k]:
            self.stats.stack_bytes -= stacked_bytes(self._stacks.pop(key))

    def fetch(self, name: str) -> PyTree:
        """-> tree of PackedTernary, promoted to device-resident if needed."""
        if name in self._cache:
            self._cache.move_to_end(name)
            self.stats.hits += 1
            return self._cache[name]
        self.stats.misses += 1
        t0 = time.perf_counter()
        art = self.store.get(name)
        if self.store.cold_golomb:
            self.stats.golomb_decode_seconds += time.perf_counter() - t0
        self.stats.store_to_host_bytes += self.store.nbytes(name)
        packed = jax.tree_util.tree_map(
            jax.device_put, art.packed,
            is_leaf=lambda x: hasattr(x, "pos"))
        size = tree_packed_bytes(packed)
        while self._cache and (self.resident_bytes() + size > self.capacity):
            self._evict_one()
        self._cache[name] = packed
        self._sizes[name] = size
        self.stats.host_to_device_bytes += size        # packed, not dense
        self.stats.promotions += 1
        self.stats.seconds += time.perf_counter() - t0
        return packed

    def stacked(self, names: tuple) -> dict:
        """Stacked plane buffers for an ordered expert set (slot e = names[e]).

        Returns {path: (pos [E, W], neg [E, W], scales [E], shape)}.  Built
        from the resident packed trees (promoting as needed) and cached per
        expert-set; eviction of any member invalidates the stack.  Unknown
        names (e.g. ``__base__``) contribute all-zero slots.
        """
        key = tuple(names)
        hit = self._stacks.get(key)
        if hit is not None:
            self._stacks.move_to_end(key)
            self.stats.stack_hits += 1
            return hit
        # only the BASE sentinel maps to a zero slot; unknown names must
        # fail loudly, exactly like the merge path's store.get
        trees = [{} if n == BASE else self.fetch(n) for n in key]
        stacks = stack_packed(trees)
        while len(self._stacks) >= self.MAX_STACKS:
            _, old = self._stacks.popitem(last=False)
            self.stats.stack_bytes -= stacked_bytes(old)
        self._stacks[key] = stacks
        self.stats.stack_builds += 1
        self.stats.stack_bytes += stacked_bytes(stacks)
        return stacks

    def has_stack(self, names: tuple) -> bool:
        """True while the stack for this expert set is still resident (an
        eviction of any member drops it — consumers must rebuild)."""
        return tuple(names) in self._stacks

    def resident(self):
        return list(self._cache)


def uncompressed_baseline_bytes(art: ExpertArtifact) -> int:
    """What the same swap would cost without ComPEFT (bf16 dense)."""
    packed = jax.tree_util.tree_leaves(
        art.packed, is_leaf=lambda x: hasattr(x, "pos"))
    return sum(int(np.prod(p.shape)) * 2 for p in packed)
