"""Multi-expert memory hierarchy — the paper's headline serving scenario.

Three tiers mirror §1 of the paper:

  ExpertStore   (disk/network tier)  — Golomb-coded ComPEFT blobs
  HostCache     (CPU RAM tier)       — packed bitplane trees (2 bits/param)
  DeviceCache   (HBM tier, LRU)      — *packed* bitplane trees, bounded by a
                                       byte budget; evicts LRU

The device tier is packed-resident: experts stay in the 2-bit bitplane form
end-to-end and are merged into the base weights by the fused ``unpack_add``
kernel at swap time.  Compared to the seed's dense-delta residency this fits
~16x more experts into the same HBM budget (f32 deltas) and makes promotion
a metadata move — the bytes that cross each tier boundary are always the
compressed bytes, which is the paper's Table-5 claim.

Swap cost accounting is explicit: every promotion records bytes moved, so
benchmarks can report transmission bytes and load latency, and the engine
can amortise swaps across batches.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any

import jax
import numpy as np

from repro.core import tree_packed_bytes
from repro.peft.task_vector import ExpertArtifact

PyTree = Any


@dataclasses.dataclass
class SwapStats:
    store_to_host_bytes: int = 0
    host_to_device_bytes: int = 0
    promotions: int = 0
    evictions: int = 0
    hits: int = 0
    misses: int = 0
    seconds: float = 0.0

    def as_dict(self):
        return dataclasses.asdict(self)


class ExpertStore:
    """Cold tier: name -> ExpertArtifact (packed ternary; Golomb bytes are
    the on-disk format via checkpoint.manager.export_expert)."""

    def __init__(self):
        self._store: dict[str, ExpertArtifact] = {}

    def put(self, art: ExpertArtifact) -> None:
        self._store[art.name] = art

    def get(self, name: str) -> ExpertArtifact:
        return self._store[name]

    def names(self):
        return list(self._store)

    def nbytes(self, name: str) -> int:
        return self._store[name].nbytes


class DeviceCache:
    """LRU cache of *packed bitplane trees* under a byte budget (HBM
    residency of ComPEFT experts; 2 bits/param instead of dense deltas)."""

    def __init__(self, store: ExpertStore, capacity_bytes: int):
        self.store = store
        self.capacity = capacity_bytes
        self._cache: OrderedDict[str, PyTree] = OrderedDict()
        self._sizes: dict[str, int] = {}
        self.stats = SwapStats()

    def resident_bytes(self) -> int:
        return sum(self._sizes.values())

    def fetch(self, name: str) -> PyTree:
        """-> tree of PackedTernary, promoted to device-resident if needed."""
        if name in self._cache:
            self._cache.move_to_end(name)
            self.stats.hits += 1
            return self._cache[name]
        self.stats.misses += 1
        t0 = time.perf_counter()
        art = self.store.get(name)
        self.stats.store_to_host_bytes += art.nbytes   # compressed transfer!
        packed = jax.tree_util.tree_map(
            jax.device_put, art.packed,
            is_leaf=lambda x: hasattr(x, "pos"))
        size = tree_packed_bytes(packed)
        while self._cache and (self.resident_bytes() + size > self.capacity):
            old, _ = self._cache.popitem(last=False)
            self._sizes.pop(old)
            self.stats.evictions += 1
        self._cache[name] = packed
        self._sizes[name] = size
        self.stats.host_to_device_bytes += size        # packed, not dense
        self.stats.promotions += 1
        self.stats.seconds += time.perf_counter() - t0
        return packed

    def resident(self):
        return list(self._cache)


def uncompressed_baseline_bytes(art: ExpertArtifact) -> int:
    """What the same swap would cost without ComPEFT (bf16 dense)."""
    packed = jax.tree_util.tree_leaves(
        art.packed, is_leaf=lambda x: hasattr(x, "pos"))
    return sum(int(np.prod(p.shape)) * 2 for p in packed)
