"""Multi-expert memory hierarchy — the paper's headline serving scenario.

:class:`ExpertRegistry` is the front door: one named library of
:class:`~repro.expert.Expert` artifacts whose storage tiers mirror §1 of
the paper:

  RemoteExpertStore (REMOTE tier)    — wire-format blobs behind an
                                       :class:`~repro.transport.ExpertTransport`
                                       (filesystem / simulated link / HTTP);
                                       fetched + checksum-verified on first
                                       use, then cached cold-locally
  ExpertStore   (cold-local tier)    — packed artifacts, or Golomb-coded
                                       blobs (``cold_golomb=True``) decoded
                                       on promotion in one vectorized pass
  DeviceCache   (HBM tier, LRU)      — *packed* bitplane trees, bounded by a
                                       byte budget; evicts LRU

Promotion up the lattice can be **pipelined**: :meth:`DeviceCache.prefetch`
stages fetch → Golomb-decode → plane build on worker threads, so a remote
transfer for expert B overlaps the decode (or the decode steps the engine
is running) for expert A.  ``fetch`` then only pays the device_put.

The device tier is packed-resident: experts stay in the 2-bit bitplane form
end-to-end.  The cache also exposes **stacked plane buffers**
(:meth:`DeviceCache.stacked`): for a set of resident experts, one
``[E, words]`` buffer per leaf path that the batched serving kernels
(``ternary_matmul_grouped`` / ``unpack_add_many``) consume directly — the
zero-merge mixed-expert decode path never materialises merged parameters.
Stacks are invalidated when a member is evicted, and stack bytes count
against the same HBM budget as the packed trees: an over-capacity stack
build evicts (other stacks first, then LRU non-member trees).

Swap cost accounting is explicit: every promotion records bytes moved, so
benchmarks can report transmission bytes and load latency, and the engine
can amortise swaps across batches.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np

from repro.core import tree_packed_bytes
from repro.core.packing import stack_packed, stacked_bytes
from repro.distributed.fault import StragglerMonitor
from repro.expert import GOLOMB, PACKED, Expert, as_expert

# canonical sign->planes bridge lives with the Expert artifact now
from repro.expert import planes_from_signs as _planes_from_signs  # noqa: F401
from repro.transport.retry import ExpertNotFound
from repro.transport.wire import TransportError, WireFormatError

PyTree = Any

BASE = "__base__"   # pseudo-expert: serve the unmodified base weights

DEFAULT_DEVICE_BYTES = 1 << 28

_UNSET = object()   # "caller did not pass mesh=" sentinel (None is a value)

DEFAULT_QUARANTINE_AFTER = 3     # consecutive fetch failures -> quarantine
DEFAULT_QUARANTINE_PROBE_S = 30.0


class ExpertUnavailable(TransportError):
    """One expert cannot be promoted right now — the typed, per-request
    failure the engine degrades on (the affected request gets a terminal
    ``failed`` status; the rest of the wave proceeds).

    ``terminal=True`` means retrying cannot help (never published, bad
    wire blob); ``quarantined=True`` means the expert's health account
    tripped and fetches are suppressed until the timed re-probe.
    Subclasses :class:`~repro.transport.wire.TransportError` so existing
    ``except TransportError`` callers keep working.
    """

    def __init__(self, name: str, reason: str, *, terminal: bool = False,
                 quarantined: bool = False):
        super().__init__(f"expert {name!r} unavailable: {reason}")
        self.name = name
        self.reason = reason
        self.terminal = terminal
        self.quarantined = quarantined


@dataclasses.dataclass
class SwapStats:
    store_to_host_bytes: int = 0
    host_to_device_bytes: int = 0
    promotions: int = 0
    evictions: int = 0
    hits: int = 0
    misses: int = 0
    seconds: float = 0.0
    stack_builds: int = 0
    stack_hits: int = 0
    stack_bytes: int = 0
    stack_evictions: int = 0
    golomb_decode_seconds: float = 0.0
    prefetch_issued: int = 0
    prefetch_hits: int = 0          # fetch() served from a staged future
    prefetch_seconds: float = 0.0   # off-thread fetch+decode time (overlapped)
    remote_fetches: int = 0
    remote_bytes: int = 0
    remote_seconds: float = 0.0
    cold_evictions: int = 0         # refetchable blobs dropped by the
                                    # cold tier's byte-budget LRU
    prefetch_errors: int = 0        # staged promotions that failed (counted,
                                    # never silently dropped)
    retries: int = 0                # transport-level retry attempts (mirror
                                    # of the transport's ledger)
    quarantines: int = 0            # expert health trips (consecutive
                                    # failures -> timed quarantine)
    transport_bytes_wasted: int = 0  # bytes fetched but never served (mirror
                                     # of the transport's ledger)
    straggler_flags: int = 0        # promotions flagged slow vs the EWMA
    straggler_recommendation: str = "healthy"   # StragglerMonitor verdict
    n_expert_shards: int = 1        # expert-parallel shards of the stacked
                                    # planes (1 = single-device cache)

    def as_dict(self):
        return dataclasses.asdict(self)


class ExpertStore:
    """Cold tier: name -> :class:`~repro.expert.Expert`.

    ``cold_golomb=True`` keeps only Golomb-Rice streams (the paper's
    storage-optimal wire format) instead of bitplanes; promotion then pays
    one *batched* host-side decode over all leaves of the expert
    (:func:`repro.core.golomb.decode_tree` — the vectorized codec, no
    per-bit Python loops) before packing to device planes.

    Accepts both Experts and legacy ``ExpertArtifact`` objects on
    :meth:`put`; :meth:`get` always returns an Expert.

    ``budget_bytes`` bounds the **refetchable** entries (blobs registered
    via :meth:`_account` — in practice the wire blobs a
    :class:`RemoteExpertStore` caches after a fetch) with an LRU: when the
    accounted bytes exceed the budget, least-recently-used entries are
    dropped and re-fetched from their upstream tier on next use.  Experts
    ``put`` directly are the tier's source of truth and are never evicted.
    """

    def __init__(self, cold_golomb: bool = False,
                 budget_bytes: Optional[int] = None):
        self.cold_golomb = cold_golomb
        self.budget_bytes = budget_bytes
        self.cold_evictions = 0
        self._lru: OrderedDict[str, int] = OrderedDict()
        self._store: dict[str, Expert] = {}
        self._blobs: dict[str, dict] = {}
        self._meta: dict[str, dict] = {}

    # ---- cold byte-budget LRU (refetchable entries only) ---------------
    def _account(self, name: str, nbytes: int) -> None:
        """Register ``name`` as a refetchable cached blob of ``nbytes``
        and evict LRU refetchable entries past the budget (the entry just
        touched is always kept — it is the one in use)."""
        if self.budget_bytes is None:
            return
        self._lru[name] = nbytes
        self._lru.move_to_end(name)
        while (sum(self._lru.values()) > self.budget_bytes
               and len(self._lru) > 1):
            victim, _ = self._lru.popitem(last=False)
            self._evict_cold(victim)
            self.cold_evictions += 1

    def _touch(self, name: str) -> None:
        if name in self._lru:
            self._lru.move_to_end(name)

    def _evict_cold(self, name: str) -> None:
        self._store.pop(name, None)
        self._blobs.pop(name, None)
        self._meta.pop(name, None)

    def cold_resident_bytes(self) -> int:
        """Bytes held by the budget-bounded (refetchable) entries."""
        return sum(self._lru.values())

    def put(self, art) -> Expert:
        ex = as_expert(art)
        if not self.cold_golomb:
            self._store[ex.name] = ex
            return ex
        blobs = dict(ex.as_(GOLOMB))
        self._blobs[ex.name] = blobs
        self._meta[ex.name] = {
            "leaf": {p: dict(m) for p, m in ex._leaf_meta.items()},
            "kind": ex.kind, "density": ex.density, "alpha": ex.alpha,
        }
        return ex

    def get(self, name: str) -> Expert:
        ex, decode = self._get_cached(name)
        if decode:
            ex.as_(PACKED)   # one batched decode now, so promotion timing
        return ex            # is attributed to the store tier

    def _get_cached(self, name: str) -> tuple[Expert, bool]:
        """Cheap dict reads only (LRU touch + entry lookup) — callers that
        need thread safety against concurrent LRU eviction wrap THIS in
        their lock and run the returned expert's (expensive) Golomb decode
        outside it.  Returns (expert, needs_decode)."""
        self._touch(name)
        if not self.cold_golomb:
            return self._store[name], False
        m = self._meta[name]
        ex = Expert(name, m["kind"], density=m["density"], alpha=m["alpha"])
        ex._leaf_meta = {p: dict(v) for p, v in m["leaf"].items()}
        ex._reps[GOLOMB] = self._blobs[name]
        return ex, True

    def __contains__(self, name: str) -> bool:
        return name in (self._blobs if self.cold_golomb else self._store)

    def names(self):
        return list(self._blobs if self.cold_golomb else self._store)

    def nbytes(self, name: str) -> int:
        if self.cold_golomb:
            return sum(len(b) for b in self._blobs[name].values())
        return self._store[name].nbytes(PACKED)


def _resolve_transport(transport, replicas, replication_factor, hedge_ms):
    """Normalize the ``transport=`` / ``replicas=`` spelling shared by
    :class:`RemoteExpertStore`, :class:`ExpertRegistry` and
    ``repro.api.registry``: a replica fleet builds a
    :class:`~repro.transport.replication.ReplicatedTransport` (consistent-
    hash placement + leaf-resumable failover + optional hedged reads)."""
    if replicas is not None:
        if transport is not None:
            raise ValueError("pass either transport= or replicas=, not both")
        from repro.transport.replication import ReplicatedTransport
        return ReplicatedTransport(
            list(replicas),
            replication_factor=(replication_factor
                                if replication_factor is not None else 2),
            hedge_ms=hedge_ms)
    if replication_factor is not None or hedge_ms is not None:
        if transport is None or not hasattr(transport, "replication_factor"):
            raise ValueError("replication_factor=/hedge_ms= need replicas= "
                             "(or an existing ReplicatedTransport)")
        if replication_factor is not None:
            transport.replication_factor = min(
                replication_factor, len(transport.replicas))
        transport.hedge_ms = hedge_ms
    if transport is None:
        raise ValueError("a remote store needs transport= or replicas=")
    return transport


class RemoteExpertStore(ExpertStore):
    """REMOTE tier: wire-format experts behind an
    :class:`~repro.transport.ExpertTransport`.

    ``get`` fetches the blob over the transport on first use
    (checksum-verified :func:`~repro.transport.wire.decode_expert`), then
    caches the Expert in the inherited cold-local tier so repeated
    promotions never refetch.  Experts :meth:`put` directly act as a local
    overlay (they shadow same-named remote artifacts); use
    :meth:`publish` to also upload through the transport.

    Thread-safe for concurrent ``get`` of distinct names — the
    :class:`DeviceCache` prefetch pipeline calls it from worker threads.

    ``budget_bytes`` bounds the cold cache of fetched wire blobs: past it,
    LRU blobs are dropped (``cold_evictions`` counts them, mirrored into
    :class:`SwapStats`) and transparently re-fetched over the transport on
    next use.  Unbounded by default, as before.

    **Health accounting**: every name carries a consecutive-failure count.
    ``quarantine_after`` retry-exhausted fetch cycles in a row trip a
    timed quarantine — for ``quarantine_probe_s`` the store raises
    :class:`ExpertUnavailable` *without* touching the transport, then the
    next ``get`` is a re-probe (success clears the account, failure
    re-arms the timer).  Terminal failures (:class:`ExpertNotFound` — the
    expert was never published — and non-checksum wire-format errors)
    surface immediately as terminal :class:`ExpertUnavailable` and do NOT
    count against health: absence is not flakiness.
    """

    def __init__(self, transport=None, cold_golomb: bool = False,
                 budget_bytes: Optional[int] = None,
                 quarantine_after: int = DEFAULT_QUARANTINE_AFTER,
                 quarantine_probe_s: float = DEFAULT_QUARANTINE_PROBE_S,
                 replicas=None, replication_factor: Optional[int] = None,
                 hedge_ms: Optional[float] = None):
        super().__init__(cold_golomb=cold_golomb, budget_bytes=budget_bytes)
        transport = _resolve_transport(
            transport, replicas, replication_factor, hedge_ms)
        self.transport = transport
        self.quarantine_after = quarantine_after
        self.quarantine_probe_s = quarantine_probe_s
        self.quarantines = 0
        self._lock = threading.Lock()
        self._wire_bytes: dict[str, int] = {}
        self._failures: dict[str, int] = {}       # consecutive, per name
        self._quarantined: dict[str, float] = {}  # name -> re-probe time
        self._fetches = 0
        self._fetch_bytes = 0
        self._fetch_seconds = 0.0

    def _local(self, name: str) -> bool:
        return ExpertStore.__contains__(self, name)

    def _check_quarantine(self, name: str) -> None:
        """Raise inside an active quarantine window; past it, let ONE
        fetch through as the re-probe (the entry stays armed until the
        probe's outcome settles it)."""
        until = self._quarantined.get(name)
        if until is not None and time.monotonic() < until:
            raise ExpertUnavailable(
                name, f"quarantined after {self._failures.get(name, 0)} "
                f"consecutive fetch failures; re-probe in "
                f"{until - time.monotonic():.2f}s", quarantined=True)

    def _record_failure(self, name: str) -> None:
        with self._lock:
            fails = self._failures.get(name, 0) + 1
            self._failures[name] = fails
            # a failed re-probe re-arms the timer without re-counting
            # toward a second quarantine event
            if fails >= self.quarantine_after:
                if name not in self._quarantined:
                    self.quarantines += 1
                self._quarantined[name] = (time.monotonic()
                                           + self.quarantine_probe_s)

    def _record_success(self, name: str) -> None:
        with self._lock:
            self._failures.pop(name, None)
            self._quarantined.pop(name, None)

    def get(self, name: str) -> Expert:
        # every read of the cold-local dicts happens under the lock: the
        # byte-budget LRU may evict entries from a concurrent thread's
        # _account, so check-then-read must be atomic.  The expensive
        # Golomb decode still runs OUTSIDE the lock (prefetch threads keep
        # overlapping decodes) — the snapshot holds its own blob refs.
        with self._lock:
            ex, decode = (self._get_cached(name) if self._local(name)
                          else (None, False))
            if ex is None:
                self._check_quarantine(name)
        if ex is None:
            t0 = time.monotonic()
            try:
                # the transport's RetryPolicy spans decode: a corrupt
                # blob (ChecksumError) is refetched, not surfaced
                fetched, nbytes = self.transport.fetch_expert(name)
            except ExpertNotFound as e:
                raise ExpertUnavailable(name, str(e), terminal=True) from e
            except WireFormatError as e:
                # non-checksum by construction: ChecksumError is
                # retryable and only escapes wrapped in RetriesExhausted
                raise ExpertUnavailable(name, str(e), terminal=True) from e
            except TransportError as e:
                self._record_failure(name)
                raise ExpertUnavailable(name, str(e)) from e
            dt = time.monotonic() - t0
            self._record_success(name)
            with self._lock:
                if not self._local(name):   # lost a race: keep first copy
                    super().put(fetched)
                    self._wire_bytes[name] = nbytes
                    self._fetches += 1
                    self._fetch_bytes += nbytes
                    self._fetch_seconds += dt
                    self._account(name, nbytes)      # cold LRU budget
                ex, decode = self._get_cached(name)
        if decode:
            ex.as_(PACKED)      # batched decode, outside the lock
        return ex

    def health(self) -> dict:
        """Snapshot of the per-expert health account (for dashboards and
        tests): consecutive failures, active quarantines, trip count.
        Replicated transports contribute a ``replicas`` section (per-
        replica EWMA latency, failure counts, quarantine state)."""
        now = time.monotonic()
        with self._lock:
            out = {"failures": dict(self._failures),
                   "quarantined": {n: max(0.0, t - now)
                                   for n, t in self._quarantined.items()},
                   "quarantines": self.quarantines}
        transport_health = getattr(self.transport, "health", None)
        if transport_health is not None:
            out["replicas"] = transport_health()
        return out

    def _evict_cold(self, name: str) -> None:
        super()._evict_cold(name)
        self._wire_bytes.pop(name, None)

    def publish(self, expert, rep: Optional[str] = None) -> dict:
        """Upload through the transport AND keep a cold-local copy."""
        out = self.transport.publish(expert, rep=rep)
        self.put(expert)
        return out

    def remote_totals(self) -> dict:
        with self._lock:
            return {"fetches": self._fetches, "bytes": self._fetch_bytes,
                    "seconds": self._fetch_seconds}

    def __contains__(self, name: str) -> bool:
        return self._local(name) or name in self.transport

    def names(self):
        local = set(super().names())
        try:
            remote = set(self.transport.names())
        except Exception:       # e.g. HTTP backends cannot enumerate
            remote = set()
        return sorted(local | remote)

    def nbytes(self, name: str) -> int:
        """Store→host transfer cost: bytes-on-wire for fetched experts."""
        wire = self._wire_bytes.get(name)
        return wire if wire is not None else super().nbytes(name)


class DeviceCache:
    """LRU cache of *packed bitplane trees* under a byte budget (HBM
    residency of ComPEFT experts; 2 bits/param instead of dense deltas),
    plus stacked per-path plane buffers for mixed-expert batches.  Stack
    bytes share the budget: over-capacity builds trigger eviction.

    With ``mesh=`` (a serving mesh from :func:`repro.launch.mesh.
    make_serve_mesh`) the stacked ``[E, ...]`` buffers are partitioned
    expert-parallel along the mesh's ``expert`` axis: E is padded to a
    multiple of the shard count with inert zero-scale slots, planes and
    scales are placed with ``PartitionSpec("expert", ...)``, and
    ``capacity_bytes`` becomes a **per-shard** budget — each device pays
    its packed-tree replicas in full plus ``1/n_shards`` of every resident
    stack, and eviction triggers when any shard's share exceeds the
    budget.  ``mesh=None`` keeps the single-device accounting (shard count
    1) byte-for-byte."""

    MAX_STACKS = 4       # LRU bound on distinct expert-set stacks kept resident
    PREFETCH_WORKERS = 4  # concurrent fetch→decode stages (pipeline depth)

    def __init__(self, store: ExpertStore, capacity_bytes: int, mesh=None):
        self.store = store
        self.capacity = capacity_bytes
        self.mesh = mesh
        self.n_shards = dict(mesh.shape).get("expert", 1) \
            if mesh is not None else 1
        self._stack_real: dict[tuple, int] = {}   # key -> unpadded E
        self._cache: OrderedDict[str, PyTree] = OrderedDict()
        self._sizes: dict[str, int] = {}
        self._stacks: OrderedDict[tuple, dict] = OrderedDict()
        self._pending: dict[str, Future] = {}
        self._pool: Optional[ThreadPoolExecutor] = None
        self.stats = SwapStats(n_expert_shards=self.n_shards)
        # promotion-latency health: every fetch/decode stage (prefetch
        # worker or synchronous) feeds the EWMA; a stage much slower than
        # the running average is flagged and the monitor's
        # recommendation() surfaces in SwapStats / registry.health()
        self.straggler = StragglerMonitor()
        self._straggler_lock = threading.Lock()
        self._straggler_obs = 0
        # serving gauges published by the engine after each run() (queue
        # depth, KV blocks in use/free, stack hit-rate, per-priority
        # admission wait) — surfaced through ExpertRegistry.health()
        self.gauges: dict = {}

    def _observe_promotion(self, seconds: float) -> None:
        with self._straggler_lock:
            self._straggler_obs += 1
            self.straggler.observe(self._straggler_obs, seconds)

    def resident_bytes(self) -> int:
        """Packed trees + stacked buffers — everything under the budget."""
        return sum(self._sizes.values()) + self.stats.stack_bytes

    def shard_resident_bytes(self) -> int:
        """Bytes resident on ONE expert shard: packed trees are replicated
        (staging tier — every shard pays them in full), stacks are
        partitioned evenly along E.  Equals :meth:`resident_bytes` on a
        single-device cache, so budget checks reduce to today's."""
        return sum(self._sizes.values()) \
            + self.stats.stack_bytes // self.n_shards

    def _drop_stack(self, key: tuple) -> None:
        self.stats.stack_bytes -= stacked_bytes(self._stacks.pop(key))
        self.stats.stack_evictions += 1
        self._stack_real.pop(key, None)

    def _evict_one(self) -> None:
        old, _ = self._cache.popitem(last=False)
        self._sizes.pop(old)
        self.stats.evictions += 1
        for key in [k for k in self._stacks if old in k]:
            self._drop_stack(key)

    def _enforce_budget(self, protect: tuple = ()) -> None:
        """Evict until within budget: LRU stacks first (cheap rebuilds),
        then LRU packed trees — never touching ``protect`` members or
        their stack (the expert set being served right now)."""
        protect_key = tuple(protect)
        members = set(protect)
        while self.shard_resident_bytes() > self.capacity:
            other_stacks = [k for k in self._stacks if k != protect_key]
            if other_stacks:
                self._drop_stack(other_stacks[0])
                continue
            victims = [n for n in self._cache if n not in members]
            if not victims:
                break        # only the active set remains: allow overshoot
            old = victims[0]
            self._cache.pop(old)
            self._sizes.pop(old)
            self.stats.evictions += 1
            for key in [k for k in self._stacks if old in k]:
                self._drop_stack(key)

    def prefetch(self, names) -> int:
        """Stage fetch → decode → plane-build for ``names`` on worker
        threads.  Strictly advisory: nothing here blocks on the store or
        the network (membership probes and fetch errors live on the
        worker thread), and a failed stage falls back to the synchronous
        path on the eventual :meth:`fetch` — where unknown names still
        fail loudly.

        The pipeline overlaps the slow, host-side promotion work — remote
        transfer and Golomb decode — across experts and with whatever the
        caller does next (e.g. the engine's decode steps); a later
        :meth:`fetch` of a staged name only pays the device_put.  Returns
        the number of stages issued.
        """
        issued = 0
        for name in names:
            if name == BASE or name in self._cache or name in self._pending:
                continue
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.PREFETCH_WORKERS,
                    thread_name_prefix="expert-prefetch")
            self._pending[name] = self._pool.submit(self._stage, name)
            self.stats.prefetch_issued += 1
            issued += 1
        return issued

    def _stage(self, name: str):
        """Worker-thread half of a promotion: everything up to (but not
        including) the device transfer."""
        t0 = time.monotonic()
        art = self.store.get(name)      # remote fetch / cold Golomb decode
        packed_host = art.packed        # plane build (host)
        dt = time.monotonic() - t0
        self._observe_promotion(dt)
        return packed_host, dt

    def invalidate_pending(self, name: str) -> None:
        """Drop a staged promotion whose cold-tier source changed (e.g. a
        local overlay now shadows the remote artifact) — the next fetch
        re-promotes from the store instead of consuming stale planes."""
        self._pending.pop(name, None)

    def close(self) -> None:
        """Drop staged-but-unconsumed promotions and stop the prefetch
        workers.  Safe to call on caches that never prefetched."""
        self._pending.clear()
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None

    def fetch(self, name: str) -> PyTree:
        """-> tree of PackedTernary, promoted to device-resident if needed."""
        if name in self._cache:
            self._cache.move_to_end(name)
            self.stats.hits += 1
            return self._cache[name]
        self.stats.misses += 1
        t0 = time.monotonic()
        host_packed = None
        fut = self._pending.pop(name, None)
        if fut is not None:
            try:
                host_packed, stage_s = fut.result()
                self.stats.prefetch_hits += 1
                self.stats.prefetch_seconds += stage_s
            except ExpertUnavailable:
                # the store already ran the full retry + health path on
                # the worker thread; repeating it synchronously would
                # only double the damage (and break determinism) —
                # propagate the typed failure to the engine
                self.stats.prefetch_errors += 1
                self._sync_remote_stats()
                raise
            except Exception:
                # transient stage failure (not a store verdict): count
                # it and fall back to the synchronous path
                self.stats.prefetch_errors += 1
        if host_packed is None:
            try:
                art = self.store.get(name)
            except ExpertUnavailable:
                self._sync_remote_stats()    # failures still hit the ledger
                raise
            if self.store.cold_golomb:
                self.stats.golomb_decode_seconds += time.monotonic() - t0
            host_packed = art.packed
            self._observe_promotion(time.monotonic() - t0)
        self._sync_remote_stats()
        self.stats.store_to_host_bytes += self.store.nbytes(name)
        packed = jax.tree_util.tree_map(
            jax.device_put, host_packed,
            is_leaf=lambda x: hasattr(x, "pos"))
        size = tree_packed_bytes(packed)
        while self._cache and (self.shard_resident_bytes() + size
                               > self.capacity):
            self._evict_one()
        self._cache[name] = packed
        self._sizes[name] = size
        self.stats.host_to_device_bytes += size        # packed, not dense
        self.stats.promotions += 1
        self.stats.seconds += time.monotonic() - t0
        return packed

    def _sync_remote_stats(self) -> None:
        """Mirror the remote store's transfer ledger into SwapStats (totals,
        not deltas — safe against concurrent staging threads)."""
        totals = getattr(self.store, "remote_totals", None)
        if totals is not None:
            t = totals()
            self.stats.remote_fetches = t["fetches"]
            self.stats.remote_bytes = t["bytes"]
            self.stats.remote_seconds = t["seconds"]
        self.stats.cold_evictions = getattr(self.store, "cold_evictions", 0)
        self.stats.quarantines = getattr(self.store, "quarantines", 0)
        transport = getattr(self.store, "transport", None)
        if transport is not None:
            self.stats.retries = transport.stats.retries
            self.stats.transport_bytes_wasted = transport.stats.bytes_wasted
        with self._straggler_lock:
            self.stats.straggler_flags = self.straggler.flags
            self.stats.straggler_recommendation = \
                self.straggler.recommendation()

    def stacked(self, names: tuple) -> dict:
        """Stacked plane buffers for an ordered expert set (slot e = names[e]).

        Returns {path: (pos [E, W], neg [E, W], scales [E], shape)}.  Built
        from the resident packed trees (promoting as needed) and cached per
        expert-set; eviction of any member invalidates the stack.  Unknown
        names (e.g. ``__base__``) contribute all-zero slots.  The stack's
        bytes count against the HBM budget — an over-capacity build evicts
        other stacks, then LRU non-member trees.
        """
        key = tuple(names)
        hit = self._stacks.get(key)
        if hit is not None:
            self._stacks.move_to_end(key)
            self.stats.stack_hits += 1
            return hit
        # only the BASE sentinel maps to a zero slot; unknown names must
        # fail loudly, exactly like the merge path's store.get
        trees = [{} if n == BASE else self.fetch(n) for n in key]
        stacks = stack_packed(trees)
        self._stack_real[tuple(key)] = len(key)
        if self.mesh is not None:
            stacks = self._shard_stacks(stacks, len(key))
        while len(self._stacks) >= self.MAX_STACKS:
            self._drop_stack(next(iter(self._stacks)))
        self._stacks[key] = stacks
        self.stats.stack_builds += 1
        self.stats.stack_bytes += stacked_bytes(stacks)
        self._enforce_budget(protect=key)
        return stacks

    def _shard_stacks(self, stacks: dict, n_real: int) -> dict:
        """Partition stacked plane buffers expert-parallel along the mesh's
        ``expert`` axis.  E is padded up to a multiple of the shard count
        with zero planes and zero scales — inert slots: every grouped
        contraction multiplies them by an exact 0.0, so the overlay math
        (and therefore the token stream) is unchanged bit-for-bit."""
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        n = self.n_shards
        pad = (-n_real) % n
        plane_sh = NamedSharding(self.mesh, P("expert"))
        out = {}
        for path, (pos, neg, scales, shape) in stacks.items():
            if pad:
                zrow = jnp.zeros((pad,) + tuple(pos.shape[1:]), pos.dtype)
                pos = jnp.concatenate([pos, zrow], axis=0)
                neg = jnp.concatenate([neg, jnp.zeros_like(zrow)], axis=0)
                scales = jnp.concatenate(
                    [scales, jnp.zeros((pad,), scales.dtype)], axis=0)
            out[path] = (jax.device_put(pos, plane_sh),
                         jax.device_put(neg, plane_sh),
                         jax.device_put(scales, plane_sh), shape)
        return out

    def shard_summary(self) -> list[dict]:
        """Per-shard gauges for the expert-parallel stacks: how many *real*
        (non-pad) experts of each resident stack live on each shard, and
        the shard's byte accounting against its budget.  E rows are
        block-partitioned, so shard ``s`` of a stack padded to ``Ep`` rows
        holds rows ``[s*Ep/n, (s+1)*Ep/n)``."""
        shards = [{"shard": s, "resident_experts": 0,
                   "stack_bytes": self.stats.stack_bytes // self.n_shards,
                   "tree_bytes": sum(self._sizes.values()),
                   "capacity_bytes": self.capacity}
                  for s in range(self.n_shards)]
        for key in self._stacks:
            n_real = self._stack_real.get(key, len(key))
            n_pad = n_real + ((-n_real) % self.n_shards)
            per = n_pad // self.n_shards
            for s in range(self.n_shards):
                lo, hi = s * per, (s + 1) * per
                shards[s]["resident_experts"] += \
                    max(0, min(hi, n_real) - lo)
        return shards

    def has_stack(self, names: tuple) -> bool:
        """True while the stack for this expert set is still resident (an
        eviction of any member drops it — consumers must rebuild)."""
        return tuple(names) in self._stacks

    def resident(self):
        return list(self._cache)


class ExpertRegistry:
    """One coherent expert library over the storage tiers.

    Replaces the ad-hoc ``dict[str, ExpertArtifact]`` plumbing: experts go
    in as :class:`~repro.expert.Expert` (or legacy artifacts, normalized),
    the cold tier is an :class:`ExpertStore`, and the HBM tier — created
    lazily by :meth:`device` — is a :class:`DeviceCache` the serving engine
    shares.  Merge-on-demand lives here too (:meth:`merged_params`), so the
    engine no longer hand-rolls plane merges.

    Pass ``transport=`` (an :class:`~repro.transport.ExpertTransport`) to
    construct the registry over a **remote** store: the cold tier becomes
    a :class:`RemoteExpertStore` and experts are fetched over the wire on
    first use; :meth:`prefetch` overlaps those transfers with ongoing
    serving work.
    """

    def __init__(self, store: Optional[ExpertStore] = None, *,
                 cold_golomb: bool = False,
                 device_cache_bytes: int = DEFAULT_DEVICE_BYTES,
                 transport=None, cold_budget_bytes: Optional[int] = None,
                 retry=None,
                 quarantine_after: int = DEFAULT_QUARANTINE_AFTER,
                 quarantine_probe_s: float = DEFAULT_QUARANTINE_PROBE_S,
                 replicas=None, replication_factor: Optional[int] = None,
                 hedge_ms: Optional[float] = None, mesh=None):
        if store is not None and (transport is not None
                                  or replicas is not None):
            raise ValueError("pass either store= or transport=/replicas=, "
                             "not both")
        if (transport is not None or replicas is not None
                or replication_factor is not None or hedge_ms is not None):
            transport = _resolve_transport(transport, replicas,
                                           replication_factor, hedge_ms)
        if retry is not None:
            if transport is None:
                raise ValueError("retry= needs a transport-backed registry")
            transport.retry = retry
        if store is None:
            store = (RemoteExpertStore(transport, cold_golomb=cold_golomb,
                                       budget_bytes=cold_budget_bytes,
                                       quarantine_after=quarantine_after,
                                       quarantine_probe_s=quarantine_probe_s)
                     if transport is not None
                     else ExpertStore(cold_golomb=cold_golomb,
                                      budget_bytes=cold_budget_bytes))
        elif cold_budget_bytes is not None:
            store.budget_bytes = cold_budget_bytes
        self.store = store
        self.device_cache_bytes = device_cache_bytes
        self.mesh = mesh
        self._device: Optional[DeviceCache] = None

    # ---- library management -------------------------------------------
    def add(self, expert, *experts) -> Expert:
        """Register one or more experts; returns the (first) normalized
        Expert.  A staged prefetch for the same name is invalidated so a
        local overlay cannot be shadowed by an in-flight remote fetch."""
        out = []
        for e in (expert,) + experts:
            ex = self.store.put(e)
            if self._device is not None:
                self._device.invalidate_pending(ex.name)
            out.append(ex)
        return out[0]

    put = add   # ExpertStore-compatible spelling

    def get(self, name: str) -> Expert:
        return self.store.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self.store

    def __len__(self) -> int:
        return len(self.store.names())

    def names(self) -> list[str]:
        return self.store.names()

    def nbytes(self, name: str) -> int:
        return self.store.nbytes(name)

    # ---- device tier ---------------------------------------------------
    def device(self, capacity_bytes: Optional[int] = None,
               mesh=_UNSET) -> DeviceCache:
        """The HBM tier (created on first call).  ``capacity_bytes=None``
        keeps the registry's configured budget; an explicit value sets (or
        retargets) the budget — the most recent explicit request wins.
        ``mesh=`` defaults to the registry's mesh; passing a *different*
        mesh rebuilds the tier (resident arrays are placed per-mesh, so
        they cannot be carried across)."""
        mesh = self.mesh if mesh is _UNSET else mesh
        if self._device is not None and mesh is not self._device.mesh:
            self._device.close()
            self._device = None
        if self._device is None:
            self._device = DeviceCache(
                self.store, capacity_bytes or self.device_cache_bytes,
                mesh=mesh)
        elif (capacity_bytes is not None
              and capacity_bytes != self._device.capacity):
            self._device.capacity = capacity_bytes
            self._device._enforce_budget()
        return self._device

    def fetch_packed(self, name: str) -> dict:
        """Device-resident ``{path: PackedTernary}`` for one expert."""
        return {} if name == BASE else self.device().fetch(name)

    def prefetch(self, names) -> int:
        """Stage promotions for ``names`` in the background (see
        :meth:`DeviceCache.prefetch`).  Advisory — never blocks on the
        store; the BASE sentinel is skipped and a name that turns out to
        be unknown still fails loudly on its synchronous fetch.  Returns
        the number of stages issued."""
        if isinstance(names, str):
            names = [names]
        names = [n for n in names if n != BASE]
        if not names:
            return 0
        return self.device().prefetch(names)

    def close(self) -> None:
        """Release the HBM tier's prefetch workers and staged promotions
        (the registry stays usable; a later fetch re-promotes)."""
        if self._device is not None:
            self._device.close()

    def health(self) -> dict:
        """Health snapshot: per-expert failure/quarantine accounts (remote
        registries), per-replica health when the transport is replicated
        (``replicas`` section), the device tier's promotion-latency
        straggler verdict (``straggler`` section), and — once an engine
        has run — its serving gauges (``serving`` section: queue depth,
        KV blocks in use/free, stack hit-rate, per-priority admission
        wait)."""
        h = getattr(self.store, "health", None)
        out = (h() if h is not None
               else {"failures": {}, "quarantined": {}, "quarantines": 0})
        if self._device is not None:
            with self._device._straggler_lock:
                out["straggler"] = {
                    "recommendation":
                        self._device.straggler.recommendation(),
                    "flags": self._device.straggler.flags,
                    "ewma_s": self._device.straggler.ewma,
                }
            if self._device.gauges:
                out["serving"] = dict(self._device.gauges)
        return out

    def publish(self, expert, rep: Optional[str] = None) -> dict:
        """Upload an expert through the registry's transport (remote
        registries only) and keep a cold-local copy."""
        if not isinstance(self.store, RemoteExpertStore):
            raise TypeError("publish() needs a transport-backed registry; "
                            "construct with ExpertRegistry(transport=...) "
                            "or repro.api.registry(transport=...)")
        return self.store.publish(expert, rep=rep)

    def stacked(self, names: tuple) -> dict:
        return self.device().stacked(tuple(names))

    # ---- merge-on-demand ----------------------------------------------
    def merged_params(self, base: PyTree, names, weights=None) -> PyTree:
        """``W_base + sum_e w_e * Delta_e`` in ONE fused sweep per leaf.

        The ``unpack_add_many`` kernel applies every named expert's planes
        during a single pass over the base weights instead of E
        read-modify-write round trips over HBM; bit-identical to applying
        the (w-scaled) experts one at a time.  With a single name this is
        the classic merge-on-swap promotion.
        """
        from repro.kernels.ops import apply_ternary_delta_many_flat
        from repro.peft.lora import _path_str
        names = [names] if isinstance(names, str) else list(names)
        packs = [self.fetch_packed(n) for n in names]
        w = list(weights) if weights is not None else [1.0] * len(names)
        flat, treedef = jax.tree_util.tree_flatten_with_path(base)
        out = []
        for path, leaf in flat:
            ps = _path_str(path)
            pts, ws = [], []
            for pk, wi in zip(packs, w):
                if ps in pk:
                    pts.append(pk[ps])
                    ws.append(wi)
            out.append(leaf if not pts
                       else apply_ternary_delta_many_flat(leaf, pts, ws))
        return jax.tree_util.tree_unflatten(treedef, out)


def as_registry(obj) -> ExpertRegistry:
    """Normalize an ExpertStore (legacy engine wiring) to a registry."""
    if isinstance(obj, ExpertRegistry):
        return obj
    if isinstance(obj, ExpertStore):
        warnings.warn(
            "passing an ExpertStore to ServeEngine is deprecated; wrap it "
            "in repro.api.registry() / ExpertRegistry(store)",
            DeprecationWarning, stacklevel=3)
        return ExpertRegistry(store=obj)
    raise TypeError(f"expected ExpertRegistry or ExpertStore, "
                    f"got {type(obj).__name__}")


def uncompressed_baseline_bytes(art) -> int:
    """What the same swap would cost without ComPEFT (bf16 dense)."""
    packed = art.packed if not isinstance(art, dict) else art
    leaves = jax.tree_util.tree_leaves(
        packed, is_leaf=lambda x: hasattr(x, "pos"))
    return sum(int(np.prod(p.shape)) * 2 for p in leaves)
