"""Multi-expert memory hierarchy — the paper's headline serving scenario.

Three tiers mirror §1 of the paper:

  ExpertStore   (disk/network tier)  — Golomb-coded ComPEFT blobs
  HostCache     (CPU RAM tier)       — packed bitplane trees (2 bits/param)
  DeviceCache   (HBM tier, LRU)      — dense deltas ready to merge, bounded
                                       by a byte budget; evicts LRU

Swap cost accounting is explicit: every promotion records bytes moved, so
benchmarks can report the paper's Table-5 quantities (transmission bytes,
load latency) and the engine can amortise swaps across batches.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core import unpack_tree
from repro.peft.task_vector import ExpertArtifact

PyTree = Any


@dataclasses.dataclass
class SwapStats:
    store_to_host_bytes: int = 0
    host_to_device_bytes: int = 0
    promotions: int = 0
    evictions: int = 0
    hits: int = 0
    misses: int = 0
    seconds: float = 0.0

    def as_dict(self):
        return dataclasses.asdict(self)


class ExpertStore:
    """Cold tier: name -> ExpertArtifact (packed ternary; Golomb bytes are
    the on-disk format via checkpoint.manager.export_expert)."""

    def __init__(self):
        self._store: dict[str, ExpertArtifact] = {}

    def put(self, art: ExpertArtifact) -> None:
        self._store[art.name] = art

    def get(self, name: str) -> ExpertArtifact:
        return self._store[name]

    def names(self):
        return list(self._store)

    def nbytes(self, name: str) -> int:
        return self._store[name].nbytes


class DeviceCache:
    """LRU cache of *dense deltas* under a byte budget (stands in for HBM
    residency of merged expert weights)."""

    def __init__(self, store: ExpertStore, capacity_bytes: int,
                 decompress_fn: Optional[Callable] = None):
        self.store = store
        self.capacity = capacity_bytes
        self._cache: OrderedDict[str, PyTree] = OrderedDict()
        self._sizes: dict[str, int] = {}
        self.stats = SwapStats()
        self._decompress = decompress_fn or (lambda art: art.to_dense_tau())

    def _dense_bytes(self, tau: PyTree) -> int:
        return sum(int(np.prod(x.shape)) * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(tau))

    def fetch(self, name: str) -> PyTree:
        if name in self._cache:
            self._cache.move_to_end(name)
            self.stats.hits += 1
            return self._cache[name]
        self.stats.misses += 1
        t0 = time.perf_counter()
        art = self.store.get(name)
        self.stats.store_to_host_bytes += art.nbytes   # compressed transfer!
        tau = self._decompress(art)
        size = self._dense_bytes(tau)
        while self._cache and (sum(self._sizes.values()) + size
                               > self.capacity):
            old, _ = self._cache.popitem(last=False)
            self._sizes.pop(old)
            self.stats.evictions += 1
        self._cache[name] = tau
        self._sizes[name] = size
        self.stats.host_to_device_bytes += size
        self.stats.promotions += 1
        self.stats.seconds += time.perf_counter() - t0
        return tau

    def resident(self):
        return list(self._cache)


def uncompressed_baseline_bytes(art: ExpertArtifact) -> int:
    """What the same swap would cost without ComPEFT (bf16 dense)."""
    packed = jax.tree_util.tree_leaves(
        art.packed, is_leaf=lambda x: hasattr(x, "pos"))
    return sum(int(np.prod(p.shape)) * 2 for p in packed)
