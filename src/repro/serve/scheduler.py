"""Pluggable admission scheduling for the serving engine.

The engine used to pop a single FIFO deque: the wave builder took from
the head until the batch or the expert-stack budget filled, and slot
refills always considered the queue head only — a head that could not be
placed (over-stack expert, KV exhausted) stalled every placeable request
behind it.  This module makes that policy a strategy object:

* :class:`FIFOScheduler` — replicates the historical behaviour
  **bit-identically** (same wave composition, same head-of-line blocking)
  so ``scheduler="fifo"`` stays the parity baseline.
* :class:`PriorityScheduler` — priority classes with deadline-aware
  ordering (EDF within a class); admission candidates are *scanned past*
  a blocked head, so a stuck high-priority request never starves
  placeable work behind it.
* :class:`AffinityScheduler` — priority ordering plus per-expert wave
  packing: rows naming the same expert land in the same wave, expert
  tuples are emitted in canonical (sorted) order, and the previous wave's
  expert set is sticky — three choices that turn repeat traffic into
  stacked-plane cache hits (``stack_hits`` in ``swap_summary()``) instead
  of rebuilds.

Schedulers only order and release work; *placement* feasibility (expert
stack budget, KV blocks, ring position) stays in the engine, which asks
for ``candidates()`` and reports what it could not place.  Requests carry
``arrival_s`` (seconds, engine clock) for open-loop replay: a request is
invisible to wave building until its arrival time has passed —
:mod:`benchmarks.traffic` generates such timelines.
"""

from __future__ import annotations

import math
from collections import deque
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:                      # avoid a circular engine import
    from repro.serve.engine import Request

__all__ = ["FIFOScheduler", "PriorityScheduler", "AffinityScheduler",
           "SCHEDULERS", "make_scheduler"]


class FIFOScheduler:
    """Arrival-order admission; bit-identical to the pre-scheduler engine.

    ``strict_fifo`` tells the engine to preserve head-of-line blocking:
    when the head candidate cannot be placed, NO later request may jump
    it (that is what the historical deque did, and what the parity gates
    compare against).
    """

    name = "fifo"
    strict_fifo = True

    def __init__(self):
        self._ready: deque = deque()
        self._future: list = []        # arrival_s in the engine's future
        self.queue_depth_max = 0
        self.deferred = 0              # placeable-skips (non-FIFO only)
        # journal hook (repro.serve.journal): the engine sets this to
        # record wave-building decisions as WAL events; None = no-op
        self.on_decision = None

    def _note_wave(self, wave: list, experts: list) -> None:
        """Report one take_wave decision to the journal hook."""
        if self.on_decision is not None and wave:
            self.on_decision({"event": "take_wave", "policy": self.name,
                              "uids": [r.uid for r in wave],
                              "experts": list(experts)})

    # -- intake -----------------------------------------------------------

    def push(self, r: Request) -> None:
        if getattr(r, "arrival_s", 0.0) and r.arrival_s > 0.0:
            self._future.append(r)
            self._future.sort(key=lambda x: (x.arrival_s, x.uid))
        else:
            self._ready.append(r)
        self._note_depth()

    def release(self, now: float) -> None:
        """Move every request whose arrival time has passed into the ready
        set (arrival order)."""
        while self._future and self._future[0].arrival_s <= now:
            self._ready.append(self._future.pop(0))
        self._note_depth()

    def _note_depth(self) -> None:
        self.queue_depth_max = max(self.queue_depth_max, len(self._ready))

    # -- queries ----------------------------------------------------------

    def pending(self) -> int:
        return len(self._ready) + len(self._future)

    def ready_count(self) -> int:
        return len(self._ready)

    def next_arrival(self) -> Optional[float]:
        return self._future[0].arrival_s if self._future else None

    def peek(self, n: int) -> list:
        """Upcoming requests in admission order (for expert prefetch)."""
        out = list(self._ready)[:n]
        if len(out) < n:
            out += self._future[:n - len(out)]
        return out

    # -- wave building -----------------------------------------------------

    def take_wave(self, max_batch: int, max_stack: int) -> tuple:
        """Pop the next wave.  Exact replica of the historical loop: take
        from the head until the batch fills or the head names an expert
        that would exceed the stack budget."""
        wave: list = []
        experts: list = []
        while self._ready and len(wave) < max_batch:
            r = self._ready[0]
            if r.expert not in experts and len(experts) >= max_stack:
                break                          # over-capacity: next wave
            if r.expert not in experts:
                experts.append(r.expert)
            wave.append(self._ready.popleft())
        self._note_wave(wave, experts)
        return wave, experts

    # -- slot-refill admission --------------------------------------------

    def candidates(self, slot: dict) -> list:
        """Requests the engine may place into a finished slot, in order.
        FIFO considers the head ONLY (head-of-line semantics)."""
        return [self._ready[0]] if self._ready else []

    def remove(self, r: Request) -> None:
        try:
            self._ready.remove(r)
        except ValueError:
            self._future.remove(r)

    def note_deferred(self, reason: str = "") -> None:
        self.deferred += 1

    def stats(self) -> dict:
        return {"policy": self.name,
                "queue_depth_max": self.queue_depth_max,
                "deferred": self.deferred}


class PriorityScheduler(FIFOScheduler):
    """Priority classes (lower value = more urgent) with earliest-deadline
    ordering inside a class; FIFO inside equal (priority, deadline).

    ``strict_fifo = False``: the engine scans past candidates it cannot
    place, so a blocked head (KV blocks exhausted, over-stack expert)
    defers only itself — the fix for the historical head-of-line starve.
    """

    name = "priority"
    strict_fifo = False

    @staticmethod
    def _key(r: Request):
        dl = r.deadline_s if r.deadline_s is not None else math.inf
        return (r.priority, dl, r.arrival_s, r.uid)

    def take_wave(self, max_batch: int, max_stack: int) -> tuple:
        wave: list = []
        experts: list = []
        for r in sorted(self._ready, key=self._key):
            if len(wave) >= max_batch:
                break
            if r.expert not in experts and len(experts) >= max_stack:
                self.deferred += 1             # skipped, not blocking
                continue
            if r.expert not in experts:
                experts.append(r.expert)
            wave.append(r)
        for r in wave:
            self._ready.remove(r)
        self._note_wave(wave, experts)
        return wave, experts

    def candidates(self, slot: dict) -> list:
        return sorted(self._ready, key=self._key)


class AffinityScheduler(PriorityScheduler):
    """Priority ordering + expert-affinity wave packing.

    Wave building picks at most ``max_stack`` experts — preferring the
    previous wave's experts (sticky), then the most-backlogged, then the
    most urgent — and fills the batch from those experts' requests in
    priority order.  The expert tuple is emitted in **canonical sorted
    order**, so two waves serving the same expert set present the same
    ordered tuple to the overlay cache and hit the stacked planes instead
    of rebuilding them.  Slot refills prefer requests whose expert is
    already in the wave (no overlay growth, tuple stays stable).
    """

    name = "affinity"

    def __init__(self):
        super().__init__()
        self._last_experts: frozenset = frozenset()

    def take_wave(self, max_batch: int, max_stack: int) -> tuple:
        by_expert: dict = {}
        for r in self._ready:
            by_expert.setdefault(r.expert, []).append(r)
        if not by_expert:
            return [], []

        def escore(e):
            sticky = 0 if e in self._last_experts else 1
            best = min(self._key(r) for r in by_expert[e])
            return (sticky, -len(by_expert[e]), best)

        chosen = set(sorted(by_expert, key=escore)[:max_stack])
        pool = sorted((r for e in chosen for r in by_expert[e]),
                      key=self._key)
        wave = pool[:max_batch]
        skipped = len(self._ready) - len(pool)
        if skipped > 0:
            self.deferred += skipped
        for r in wave:
            self._ready.remove(r)
        # canonical order -> identical expert sets give identical stack
        # tuples wave after wave (the stack_hits lever)
        experts = sorted({r.expert for r in wave})
        self._last_experts = frozenset(experts)
        self._note_wave(wave, experts)
        return wave, experts

    def candidates(self, slot: dict) -> list:
        inside = [r for r in self._ready if r.expert in slot]
        outside = [r for r in self._ready if r.expert not in slot]
        return sorted(inside, key=self._key) + sorted(outside, key=self._key)


SCHEDULERS = {c.name: c for c in
              (FIFOScheduler, PriorityScheduler, AffinityScheduler)}


def make_scheduler(name: str):
    try:
        return SCHEDULERS[name]()
    except KeyError:
        raise ValueError(f"unknown scheduler {name!r}; "
                         f"expected one of {sorted(SCHEDULERS)}") from None
