"""Crash-consistent serving snapshots, written through checkpoint.manager.

A snapshot captures everything a resumed engine cannot re-derive cheaply
at a chunk boundary:

* the in-flight wave — the full KV cache pytree (dense ring slots *or*
  paged block pools + tables/lens/active) and the pending ``tok`` [B, 1]
  (generated but not yet emitted), as logical/unsharded arrays so the
  restore side may place them on any mesh shape (PR 9's cross-mesh
  parity makes the continuation bitwise-identical either way);
* row composition metadata — slot order (uids), the wave's ordered
  expert tuple, per-row emitted-token counts, the dense host position
  mirror ``cur``, and on the paged path the allocator free list (exact
  LIFO order — the allocation-order contract) plus per-row block lists;
* the device-cache residency manifest (which experts were HBM-resident —
  resume prefetches them so recovery does not serialize cold fetches),
  cumulative :class:`~repro.serve.expert_cache.SwapStats`, and the
  sampling config whose ``seed`` roots every row's fold-in RNG stream
  (per-row keys are pure functions of ``(seed, uid)``, so "RNG state" is
  two integers per row, not a device buffer).

Persistence goes through :func:`repro.checkpoint.manager.save`: arrays
land in one npz, metadata rides the manifest (``extra_meta``), and the
tmp-dir + ``os.rename`` commit makes the snapshot atomic — a SIGKILL
mid-write leaves either the previous complete snapshot or none.  The
engine appends a ``snap`` journal record (and fsyncs) only *after* the
rename returns, so a journal that names a step always names a complete
snapshot.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager

PyTree = Any


def write_snapshot(engine, *, rows, experts, cache, tok, cur: int = 0,
                   alloc=None, row_blocks=None) -> str:
    """Commit one engine snapshot at the current chunk boundary.

    Called by the engine's chunk loop right after a chunk's tokens were
    flushed (and journaled), with the post-chunk device state — ``tok``
    is the pending token the *next* chunk will emit first, which is
    exactly the restart point.  Returns the committed directory.
    """
    step = engine._chunk_idx
    meta = {
        "kind": "serve_snapshot",
        "chunk": step,
        "kv_layout": engine.cfg.kv_layout,
        "experts": list(experts),
        "row_uids": [r.uid for r in rows],
        "row_emitted": {str(r.uid): len(r.out_tokens) for r in rows},
        "cur": int(cur),
        "sampling": engine.cfg.sampling.to_meta(),
        "scheduler": engine.cfg.scheduler,
        "resident": list(engine.cache.resident()),
        "stats": engine.cache.stats.as_dict(),
    }
    if alloc is not None:
        meta["alloc_free"] = alloc.state()
        meta["row_blocks"] = {str(j): list(b)
                              for j, b in row_blocks.items()}
    state = {"cache": cache, "tok": tok}
    path = manager.save(state, engine.cfg.snapshot_dir, step,
                        extra_meta=meta)
    if engine._journal is not None:
        engine._journal.append("snap", {"step": step,
                                        "rows": meta["row_emitted"]},
                               t=engine._now())
        engine._journal.sync()
    return path


@dataclasses.dataclass
class Snapshot:
    """A loaded snapshot: metadata + logical (numpy) arrays."""

    step: int
    meta: dict
    cache_np: dict                     # nested KV cache pytree of ndarrays
    tok_np: np.ndarray                 # [B, 1] pending tokens

    @property
    def row_uids(self) -> list:
        return list(self.meta["row_uids"])

    @property
    def emitted(self) -> dict:
        return {int(u): int(n)
                for u, n in self.meta["row_emitted"].items()}

    def device_state(self, engine) -> tuple:
        """-> (cache, tok) placed for ``engine`` — possibly a different
        mesh shape than the writer's (elastic restore: arrays on disk are
        logical, so placement is free to differ; values cannot)."""
        mesh = engine.mesh
        paged = self.meta["kv_layout"] == "paged"
        if mesh is not None and paged:
            from repro.distributed.sharding import serve_kv_sharding

            def place_pool(z):
                return jax.device_put(
                    z, serve_kv_sharding(mesh, tuple(z.shape),
                                         layout="paged"))
        else:
            place_pool = jnp.asarray
        cache: dict = {}
        for key, val in self.cache_np.items():
            if key == "layers":
                cache["layers"] = {
                    name: {kv: place_pool(arr) if paged else jnp.asarray(arr)
                           for kv, arr in st.items()}
                    for name, st in val.items()}
            else:
                cache[key] = jnp.asarray(val)
        return cache, jnp.asarray(self.tok_np, jnp.int32)


def _unflatten(arrays: dict) -> dict:
    """``{"a/b/c": arr}`` -> nested dicts (inverse of the manager's
    path-string flatten for the dict-only snapshot pytree)."""
    out: dict = {}
    for path, arr in arrays.items():
        node = out
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return out


def load_snapshot(snapshot_dir: str, step: Optional[int] = None
                  ) -> Snapshot:
    """Load a committed snapshot (latest step if unspecified)."""
    manifest, arrays = manager.load_raw(snapshot_dir, step)
    meta = manifest.get("extra")
    if not meta or meta.get("kind") != "serve_snapshot":
        raise ValueError(f"{snapshot_dir} step {manifest['step']}: "
                         "not a serve snapshot")
    tree = _unflatten(arrays)
    return Snapshot(step=int(manifest["step"]), meta=meta,
                    cache_np=tree["cache"],
                    tok_np=np.asarray(tree["tok"]))
