"""Write-ahead journal for the serving engine (crash consistency).

The engine's token streams are deterministic by construction — a row's
tokens depend only on ``(sampling seed, request uid, draw index)`` plus
the prompt and expert, never on chunk size, admission timing, KV layout
or mesh shape.  That contract means an interrupted run is recoverable
from surprisingly little state: *which* requests existed, *what* each
row had emitted when the process died, and (optionally) a KV snapshot so
the tail is replayed from the last chunk boundary instead of from the
prompt.  This module records the first two as an append-only journal;
:mod:`repro.serve.snapshot` provides the third.

Format
------
A journal file is a 4-byte magic followed by CRC-framed records::

    b"CJ1\\n" | [len u32 | crc32 u32 | payload] ...

where ``payload`` is UTF-8 JSON ``{"k": kind, "t": engine_seconds,
"d": {...}}``.  Frames are little-endian.  A reader stops at the first
torn frame (short header, short payload, or CRC mismatch) — a crash mid
``write`` loses at most the final record, never the prefix, which is
exactly the WAL property resume needs.

Record kinds written by the engine:

* ``run_start`` — engine/sampling config plus the full request manifest
  (uid, expert, prompt tokens, budget, priority, deadline, arrival), so
  a journal alone reconstructs every :class:`~repro.serve.engine.Request`.
* ``sched``     — scheduler wave decisions (policy, uids, expert tuple).
* ``admit``     — a row placed into a wave slot (uid, expert, slot,
  arrival, prompt length).
* ``chunk``     — one compiled chunk's flush: per-row uid, flushed-token
  count and the tokens themselves (the chunk boundary IS the sync point:
  the journal is flushed to the OS after every chunk record).
* ``fail``      — a request failed terminally (uid, error).
* ``snap``      — a snapshot committed (step, per-row emitted counts);
  written *after* the atomic snapshot rename and fsync'd, so a ``snap``
  record always points at a complete snapshot directory.
* ``run_end``   — clean shutdown (its absence marks a crashed run).
"""

from __future__ import annotations

import dataclasses
import json
import os
import struct
import zlib
from typing import Any, Optional

MAGIC = b"CJ1\n"
JOURNAL_NAME = "journal.bin"
_FRAME = struct.Struct("<II")          # payload length, crc32(payload)


class JournalWriter:
    """Append-only CRC-framed record writer.

    ``append`` buffers; ``flush`` pushes to the OS (the per-chunk sync
    point); ``sync`` additionally fsyncs (used around snapshot commits).
    """

    def __init__(self, path: str, fresh: bool = True):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        if fresh and os.path.exists(path):
            # keep the previous run's journal readable for post-mortems;
            # resume() reads BEFORE the engine re-opens a writer
            os.replace(path, path + ".prev")
        self._f = open(path, "ab" if not fresh else "wb")
        if fresh:
            self._f.write(MAGIC)
        self.records = 0

    def append(self, kind: str, data: dict, t: Optional[float] = None
               ) -> None:
        payload = json.dumps({"k": kind, "t": t, "d": data},
                             separators=(",", ":")).encode("utf-8")
        self._f.write(_FRAME.pack(len(payload), zlib.crc32(payload)))
        self._f.write(payload)
        self.records += 1

    def flush(self) -> None:
        self._f.flush()

    def sync(self) -> None:
        self._f.flush()
        os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()


def read_records(path: str) -> list[dict]:
    """All intact records, in order; tolerant of a torn tail.

    Truncated or CRC-corrupt frames end the scan (everything after a torn
    frame is unreachable by construction — lengths frame the stream), so
    a SIGKILL mid-write costs at most the record being written.
    """
    out: list[dict] = []
    with open(path, "rb") as f:
        if f.read(len(MAGIC)) != MAGIC:
            raise ValueError(f"{path}: not a serve journal (bad magic)")
        while True:
            head = f.read(_FRAME.size)
            if len(head) < _FRAME.size:
                break
            n, crc = _FRAME.unpack(head)
            payload = f.read(n)
            if len(payload) < n or zlib.crc32(payload) != crc:
                break                  # torn tail: drop and stop
            try:
                out.append(json.loads(payload.decode("utf-8")))
            except (UnicodeDecodeError, json.JSONDecodeError):
                break
    return out


@dataclasses.dataclass
class JournalState:
    """One journal, replayed into per-request facts."""

    meta: dict                         # run_start payload
    tokens: dict[int, list]            # uid -> emitted tokens, in order
    failed: dict[int, str]             # uid -> error detail
    admits: list[dict]                 # admit records, in order
    snapshots: list[dict]              # snap records, in order
    chunks: int                        # chunk records seen
    last_t: float                      # engine clock of the last record
    n_records: int
    clean_end: bool                    # run_end reached (no crash)


def replay(path: str) -> JournalState:
    """Scan a journal into :class:`JournalState` (pure host-side fold)."""
    records = read_records(path)
    if not records or records[0]["k"] != "run_start":
        raise ValueError(f"{path}: journal has no run_start record")
    meta = records[0]["d"]
    tokens: dict[int, list] = {}
    failed: dict[int, str] = {}
    admits: list[dict] = []
    snapshots: list[dict] = []
    chunks = 0
    last_t = 0.0
    clean = False
    for rec in records:
        if rec.get("t") is not None:
            last_t = max(last_t, float(rec["t"]))
        kind, d = rec["k"], rec["d"]
        if kind == "chunk":
            chunks += 1
            for row in d["rows"]:
                tokens.setdefault(int(row["uid"]), []).extend(row["toks"])
        elif kind == "admit":
            admits.append(d)
        elif kind == "fail":
            failed[int(d["uid"])] = d.get("error", "")
        elif kind == "snap":
            snapshots.append(d)
        elif kind == "run_end":
            clean = True
    return JournalState(meta=meta, tokens=tokens, failed=failed,
                        admits=admits, snapshots=snapshots, chunks=chunks,
                        last_t=last_t, n_records=len(records),
                        clean_end=clean)
