"""Multi-expert serving engine: request batching, expert routing, swap-aware
scheduling, prefill+decode loop.

Requests name an expert; the scheduler greedily groups same-expert requests
into batches (S-LoRA-style adapter batching is approximated by merge-on-
swap, which is the right trade-off once ComPEFT makes swaps ~16-50x
cheaper — the quantitative claim the paper makes in §3.4)."""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.model import ModelApi
from repro.models.transformer import Runtime
from repro.serve.expert_cache import DeviceCache, ExpertStore

PyTree = Any


@dataclasses.dataclass
class Request:
    uid: int
    expert: str
    prompt: jax.Array          # [T] int32
    max_new_tokens: int = 8
    out_tokens: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    cache_len: int = 128
    device_cache_bytes: int = 1 << 28


class ServeEngine:
    """Single-host engine; the model functions are the pjit'd serve path."""

    def __init__(self, api: ModelApi, rt: Runtime, base_params: PyTree,
                 store: ExpertStore, ecfg: EngineConfig,
                 peft_state: Optional[dict] = None):
        self.api = api
        self.rt = rt
        self.base = base_params
        self.store = store
        self.cfg = ecfg
        self.cache = DeviceCache(store, ecfg.device_cache_bytes)
        self._merged: dict[str, PyTree] = {}
        self._merged_name: Optional[str] = None
        self._merged_params: Optional[PyTree] = None
        self.swap_log: list = []

    # ---------------- expert management ----------------

    def _params_for(self, expert: str) -> PyTree:
        if expert == "__base__":
            return self.base
        if self._merged_name == expert:
            return self._merged_params
        t0 = time.perf_counter()
        packed = self.cache.fetch(expert)    # {path: PackedTernary} tree
        params = self._apply_packed(packed)
        self._merged_name = expert
        self._merged_params = params
        self.swap_log.append({"expert": expert,
                              "seconds": time.perf_counter() - t0})
        return params

    def _apply_packed(self, packed_pathdict) -> PyTree:
        """Merge a {path: PackedTernary} dict into a copy of base params.

        One fused unpack_add pass per leaf, straight from the 2-bit planes
        the DeviceCache keeps resident — the dense delta is never
        materialised (the seed's {path: dense} round-trip is gone).
        """
        from repro.kernels.ops import apply_ternary_delta_flat
        from repro.peft.lora import _path_str
        flat, treedef = jax.tree_util.tree_flatten_with_path(self.base)
        out = []
        for path, leaf in flat:
            pt = packed_pathdict.get(_path_str(path))
            out.append(leaf if pt is None
                       else apply_ternary_delta_flat(leaf, pt))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ---------------- serving loop ----------------

    def run(self, requests: list[Request]) -> list[Request]:
        """Greedy same-expert batching; prefill then decode each group."""
        groups: dict[str, list[Request]] = defaultdict(list)
        for r in requests:
            groups[r.expert].append(r)
        for expert, reqs in groups.items():
            params = self._params_for(expert)
            for i in range(0, len(reqs), self.cfg.max_batch):
                self._serve_batch(params, reqs[i:i + self.cfg.max_batch])
        return requests

    def _serve_batch(self, params, reqs: list[Request]) -> None:
        T = max(int(r.prompt.shape[0]) for r in reqs)
        toks = jnp.stack([jnp.pad(r.prompt, (T - r.prompt.shape[0], 0),
                                  constant_values=1) for r in reqs])
        batch = {"tokens": toks.astype(jnp.int32)}
        if self.api.cfg.frontend is not None:
            n = self.api.cfg.frontend.n_tokens
            e = self.api.cfg.frontend.embed_dim
            stub = jnp.zeros((len(reqs), n, e), jnp.float32)
            key = ("frames" if self.api.cfg.family == "audio"
                   else "mm_embeds")
            batch[key] = stub
        logits, cache = self.api.prefill(params, batch, self.rt,
                                         self.cfg.cache_len)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        steps = max(r.max_new_tokens for r in reqs)
        for _ in range(steps):
            for j, r in enumerate(reqs):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(tok[j, 0]))
            logits, cache = self.api.decode_step(params, tok, cache, self.rt)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]

    # ---------------- accounting ----------------

    def swap_summary(self) -> dict:
        s = self.cache.stats.as_dict()
        s["n_swaps"] = len(self.swap_log)
        s["swap_seconds"] = sum(x["seconds"] for x in self.swap_log)
        return s
