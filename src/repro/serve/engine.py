"""Multi-expert serving engine: continuous mixed-expert batching over
packed ternary experts.

Requests name an expert.  Since PR 2 the default scheduler is **mixed**:
requests are admitted FIFO into waves of up to ``max_batch`` rows *across*
experts, and a wave runs prefill/decode against the **base** parameters
plus a zero-merge overlay — the stacked bitplanes of every expert in the
wave, contracted per row by the grouped ternary kernels
(S-LoRA-style heterogeneous batching over ComPEFT modules; cf. "Composing
Parameter-Efficient Modules with Arithmetic Operations", Zhang et al.
2023, for why merged/composed ternary experts behave).  No merged
parameter tree is ever materialised, so a mixed request stream never pays
swap-merge round trips.  When a row finishes its generation budget and
requests are still queued, the slot is refilled in place: the newcomer's
prompt is left-padded to the wave's current position, prefilled as a
single row, and its KV state spliced into the running batch (continuous
batching).

Merge-on-swap (the PR-1 path: ``unpack_add`` every leaf into a copy of the
base) survives as a fallback for model families the overlay cannot express
(MoE/mamba/rwkv/enc-dec) and for waves whose expert set exceeds the stack
budget.  ``scheduling="grouped"`` forces the old greedy same-expert
scheduler — kept as the measured baseline of ``perf_lab --exp
mixed_serve``.

Since PR 5 decode is **device-resident**: ``decode_chunk=K`` (the default)
compiles K decode steps — including stopping masks and greedy/sampled
token selection — into one ``lax.scan`` launch with a donated KV cache
(:mod:`repro.serve.decode_loop`), and the wave loop becomes a segmented
driver that syncs with the host once per chunk to flush tokens and run
continuous admission.  ``decode_chunk=0`` keeps the eager per-token loop
as the measured baseline of ``perf_lab --exp decode_loop``; greedy
chunked decode is bit-identical to it, mid-wave admissions included."""

from __future__ import annotations

import dataclasses
import itertools
import os
import time
from collections import defaultdict, deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.fault import RecoveryPlan
from repro.models.delta import build_overlay, plan_overlay
from repro.models.model import ModelApi
from repro.models.transformer import Runtime
from repro.serve import decode_loop, paged_kv
from repro.serve import journal as journal_mod
from repro.serve import scheduler as scheduler_mod
from repro.serve import snapshot as snapshot_mod
from repro.serve.decode_loop import SamplingConfig
from repro.serve.expert_cache import (BASE, DeviceCache, ExpertRegistry,
                                      ExpertStore, ExpertUnavailable,
                                      as_registry)

PyTree = Any

# Request.status lifecycle: PENDING -> DONE | FAILED (terminal).  FAILED
# requests carry the error detail and are returned through the normal
# results path — an unavailable expert never crashes the wave.
PENDING = "pending"
DONE = "done"
FAILED = "failed"


@dataclasses.dataclass
class Request:
    uid: int
    expert: str
    prompt: jax.Array          # [T] int32
    max_new_tokens: int = 8
    out_tokens: list = dataclasses.field(default_factory=list)
    status: str = PENDING      # PENDING -> DONE | FAILED
    error: Optional[str] = None   # detail when status == FAILED
    # --- scheduling / SLO metadata (engine clock = seconds since run()) ---
    # All engine timing below is time.monotonic() based (immune to NTP
    # slew / wall-clock resets); t_wall is the ONE epoch stamp per
    # request, taken at run() entry, for correlating with external logs.
    priority: int = 1          # lower value = more urgent class
    deadline_s: Optional[float] = None   # absolute SLO deadline (EDF tiebreak)
    arrival_s: float = 0.0     # open-loop arrival offset; 0 = already queued
    t_wall: Optional[float] = None       # epoch seconds at arrival
    t_admit_s: Optional[float] = None    # first placed into a wave
    t_first_s: Optional[float] = None    # first token selected (TTFT anchor)
    t_done_s: Optional[float] = None     # generation budget exhausted


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    cache_len: int = 128
    # None -> use the registry's configured HBM budget; an explicit value
    # sets/overrides it (ExpertRegistry.device semantics)
    device_cache_bytes: Optional[int] = None
    scheduling: str = "mixed"     # "mixed" (zero-merge) | "grouped" (merge)
    max_stack: int = 8            # max distinct experts stacked per wave
    continuous: bool = True       # refill finished slots mid-wave
    # decode steps per compiled launch (scan-compiled wave loop with one
    # host sync per chunk); 0 = the eager per-token loop
    decode_chunk: int = 16
    sampling: SamplingConfig = dataclasses.field(
        default_factory=SamplingConfig)
    # what an ExpertUnavailable at admission does: "request" fails ONLY
    # the affected requests (terminal FAILED status, wave proceeds);
    # "raise" propagates — the pre-fault-tolerance behaviour
    degrade: str = "request"
    # admission policy for the mixed path: "fifo" (bit-identical to the
    # historical deque), "priority" (classes + deadline EDF), "affinity"
    # (priority + expert-affinity wave packing) — repro.serve.scheduler
    scheduler: str = "fifo"
    # KV memory layout: "dense" = per-wave left-padded slots + ring buffer
    # (the parity baseline); "paged" = block-table pools with a free-list
    # allocator (repro.serve.paged_kv) — admission allocates blocks
    # instead of splicing KV, so any prompt length fits any wave position
    kv_layout: str = "dense"
    kv_block_size: int = 16       # token positions per KV block (paged)
    # total pool blocks (incl. the reserved trash block); None sizes the
    # pool so a full batch at cache_len never blocks on allocation
    kv_blocks: Optional[int] = None
    # serving mesh (repro.launch.mesh.make_serve_mesh, axes
    # ("expert", "model")): base params go vocab-parallel, KV pools
    # batch/block-sharded, stacked [E, ...] bitplanes expert-parallel —
    # all along dims where every output element is computed by exactly
    # one device, so token streams stay bit-identical to mesh=None.
    # None keeps today's single-device placement byte-for-byte.
    mesh: Optional[Any] = None
    # crash consistency: a directory arms the write-ahead journal
    # (repro.serve.journal) for every run() and receives periodic
    # engine snapshots (repro.serve.snapshot); snapshot_every_chunks=N
    # commits one atomic snapshot every N compiled chunks (0 = journal
    # only — resume then replays from the prompt instead of from KV)
    snapshot_dir: Optional[str] = None
    snapshot_every_chunks: int = 0


class ServeEngine:
    """Single-host engine; the model functions are the pjit'd serve path."""

    def __init__(self, api: ModelApi, rt: Runtime, base_params: PyTree,
                 registry: ExpertRegistry, ecfg: EngineConfig,
                 peft_state: Optional[dict] = None):
        self.api = api
        self.rt = rt
        self.base = base_params
        self.registry = as_registry(registry)
        self.store = self.registry.store
        self.cfg = ecfg
        self.mesh = ecfg.mesh
        if self.mesh is not None:
            axes = dict(self.mesh.shape)
            if "expert" not in axes or "model" not in axes:
                raise ValueError(
                    "EngineConfig.mesh needs ('expert', 'model') axes "
                    f"(make_serve_mesh); got {tuple(axes)}")
            from repro.distributed import sharding as shard_rules
            self._shard_rules = shard_rules
            # vocab-parallel embed / lm_head; everything else replicated
            # (contraction-dim TP would break bitwise parity — see the
            # serve rules in distributed/sharding.py)
            self.base = jax.device_put(
                base_params,
                shard_rules.serve_param_shardings(base_params, self.mesh))
        self.cache = self.registry.device(ecfg.device_cache_bytes,
                                          mesh=self.mesh)
        self._merged_name: Optional[str] = None
        self._merged_params: Optional[PyTree] = None
        self._plan = plan_overlay(base_params, api.cfg)
        self._overlays: dict[tuple, Any] = {}
        # the serve step functions are jitted once per (batch shape, overlay
        # structure); rt and cache_len are static — as is kv_sharding, a
        # hashable NamedSharding the mesh path uses to place the wave's KV
        # inside the prefill launch itself
        self._prefill = jax.jit(api.prefill, static_argnums=(2, 3),
                                static_argnames=("kv_sharding",))
        self._decode = jax.jit(api.decode_step, static_argnums=(3,))
        if ecfg.decode_chunk < 0:
            raise ValueError("decode_chunk must be >= 0")
        if ecfg.degrade not in ("request", "raise"):
            raise ValueError('degrade must be "request" or "raise", '
                             f"got {ecfg.degrade!r}")
        if ecfg.scheduler not in scheduler_mod.SCHEDULERS:
            raise ValueError(f"unknown scheduler {ecfg.scheduler!r}; "
                             f"expected one of "
                             f"{sorted(scheduler_mod.SCHEDULERS)}")
        if ecfg.kv_layout not in ("dense", "paged"):
            raise ValueError('kv_layout must be "dense" or "paged", '
                             f"got {ecfg.kv_layout!r}")
        if ecfg.kv_layout == "paged":
            if not ecfg.decode_chunk:
                raise ValueError("kv_layout='paged' needs the compiled "
                                 "decode loop; set decode_chunk > 0")
            if not self._row_mask_ok():
                raise ValueError("kv_layout='paged' needs a pure-attention "
                                 "decoder-only pattern (recurrent blocks "
                                 "and frontends keep state outside KV)")
            if ecfg.kv_block_size < 1:
                raise ValueError("kv_block_size must be >= 1")
            for b in api.cfg.pattern:
                if b.attn.window is not None and b.attn.window < ecfg.cache_len:
                    # a window < cache_len shrinks the dense per-layer ring;
                    # paged prefill needs the full position range resident
                    raise ValueError(
                        "kv_layout='paged' needs attention windows >= "
                        f"cache_len (got window={b.attn.window}, "
                        f"cache_len={ecfg.cache_len})")
        self._bs = ecfg.kv_block_size
        self._max_blocks = -(-ecfg.cache_len // max(self._bs, 1))
        self._kv_blocks = (ecfg.kv_blocks if ecfg.kv_blocks is not None
                           else ecfg.max_batch * self._max_blocks + 1)
        if ecfg.kv_layout == "paged" and self._kv_blocks < 2:
            raise ValueError("kv_blocks must be >= 2 (block 0 is reserved)")
        if ecfg.snapshot_dir is not None and not ecfg.decode_chunk:
            raise ValueError("snapshot_dir needs the compiled decode loop "
                             "(journal/snapshot commit at chunk "
                             "boundaries); set decode_chunk > 0")
        if ecfg.snapshot_every_chunks < 0:
            raise ValueError("snapshot_every_chunks must be >= 0")
        if ecfg.snapshot_every_chunks and ecfg.snapshot_dir is None:
            raise ValueError("snapshot_every_chunks needs snapshot_dir")
        self._chunk_fn = (decode_loop.make_decode_chunk(
            api, rt, ecfg.decode_chunk, ecfg.sampling, mesh=self.mesh)
            if ecfg.decode_chunk else None)
        self._select = decode_loop.make_token_select(ecfg.sampling,
                                                     mesh=self.mesh)
        # bounded rings: a long-lived engine must not grow host memory
        # with its own accounting.  Evictions are counted per ring and
        # surfaced via swap_summary()["log_dropped"]; counters that must
        # survive eviction (failed_total) are kept separately.
        self.swap_log: deque = deque(maxlen=512)
        self.wave_log: deque = deque(maxlen=4096)
        self.failed_log: deque = deque(maxlen=1024)
        self.failed_total = 0
        self._log_dropped = {"swap": 0, "wave": 0, "failed": 0}
        self._sched = None                  # last run's scheduler instance
        self._t0 = time.monotonic()         # run() resets; engine clock zero
        self._adm_wait: dict[int, list] = defaultdict(list)
        self._kv_peak = 0                   # peak pool blocks in use
        self._kv_in_use = 0
        # --- crash consistency (repro.serve.journal / .snapshot) ---
        self._journal = None                # JournalWriter while run() lives
        self._chunk_idx = 0                 # global chunk counter = snap step
        self.chunk_hooks: list = []         # fired(chunk_idx) after a flush
        self._recovery_t0: Optional[float] = None
        self.recovery_stats: dict = {}
        self.resumed_requests: list = []

    # ---------------- expert management ----------------

    def _params_for(self, expert: str) -> PyTree:
        """Merge-on-swap fallback: full merged params for one expert.

        The fused plane merge itself lives in
        :meth:`ExpertRegistry.merged_params`; the engine only memoises the
        last merged expert and keeps the swap log.
        """
        if expert == BASE:
            return self.base
        if self._merged_name == expert:
            return self._merged_params
        t0 = time.monotonic()
        params = self.registry.merged_params(self.base, [expert])
        self._merged_name = expert
        self._merged_params = params
        self._ring_append("swap", {"expert": expert,
                                   "seconds": time.monotonic() - t0})
        return params

    def merged_ensemble_params(self, experts: list[str],
                               weights: Optional[list[float]] = None
                               ) -> PyTree:
        """Merged-ensemble mode: W_base + sum_e α_e Δ_e in ONE sweep
        (``unpack_add_many`` via the registry — bit-identical to applying
        the α-scaled experts one at a time)."""
        return self.registry.merged_params(self.base, experts, weights)

    def _overlay_for(self, experts: tuple) -> Optional[dict]:
        """Zero-merge overlay for an ordered expert set (None → fallback)."""
        if self._plan is None:
            return None
        if experts in self._overlays:
            # an eviction of any member drops the underlying stack; the
            # shaped overlay must not outlive it (HBM accounting + staleness)
            if self.cache.has_stack(experts):
                # overlay reuse rides the resident stack — count it as a
                # stack hit so stack_hit_rate reflects plane reuse even
                # when the shaped overlay short-circuits cache.stacked()
                self.cache.stats.stack_hits += 1
                return self._overlays[experts]
            del self._overlays[experts]
        stacks = self.cache.stacked(experts)
        overlay = build_overlay(self._plan, stacks)
        if overlay is not None:
            while len(self._overlays) >= DeviceCache.MAX_STACKS:
                self._overlays.pop(next(iter(self._overlays)))
            self._overlays[experts] = overlay
        return overlay

    # ---------------- graceful degradation ----------------

    def _fail(self, reqs: list[Request], err: Exception) -> None:
        """Terminal per-request failure.  ``degrade="request"`` marks ONLY
        the affected requests FAILED (error detail attached, returned via
        the normal results path) and lets the rest of the wave proceed;
        ``degrade="raise"`` propagates — the pre-fault-tolerance
        behaviour."""
        if self.cfg.degrade != "request":
            raise err
        for r in reqs:
            r.status = FAILED
            r.error = str(err)
            self.failed_total += 1
            self._ring_append("failed", {"uid": r.uid, "expert": r.expert,
                                         "error": str(err)})
            self._journal_append("fail", {"uid": r.uid, "expert": r.expert,
                                          "error": str(err)}, flush=True)

    # ---------------- bounded accounting rings ----------------

    def _ring_append(self, name: str, item: dict) -> None:
        """Append to one of the bounded logs, counting evictions (the
        ``log_dropped`` gauge) so a capped ring is never mistaken for a
        complete history."""
        ring = getattr(self, f"{name}_log")
        if getattr(ring, "maxlen", None) is not None \
                and len(ring) == ring.maxlen:
            self._log_dropped[name] += 1
        ring.append(item)

    # ---------------- write-ahead journal ----------------

    def _journal_append(self, kind: str, data: dict,
                        flush: bool = False) -> None:
        if self._journal is not None:
            self._journal.append(kind, data, t=self._now())
            if flush:
                self._journal.flush()

    def _journal_admit(self, r: Request, j: int) -> None:
        self._journal_append("admit", {
            "uid": r.uid, "expert": r.expert, "slot": j,
            "arrival_s": r.arrival_s,
            "prompt_len": int(r.prompt.shape[0])})

    def _run_meta(self, requests: list[Request], mode: str) -> dict:
        """run_start payload: everything needed to rebuild every Request
        from the journal alone (prompts included — a resumed process has
        no other source for them)."""
        return {
            "sampling": self.cfg.sampling.to_meta(),
            "scheduler": self.cfg.scheduler,
            "scheduling": mode,
            "kv_layout": self.cfg.kv_layout,
            "decode_chunk": self.cfg.decode_chunk,
            "max_batch": self.cfg.max_batch,
            "cache_len": self.cfg.cache_len,
            "wall": time.time(),
            "requests": [{
                "uid": r.uid, "expert": r.expert,
                "prompt": [int(t) for t in np.asarray(r.prompt)],
                "max_new": r.max_new_tokens, "priority": r.priority,
                "deadline_s": r.deadline_s, "arrival_s": r.arrival_s,
                "t_wall": r.t_wall,
            } for r in requests],
        }

    def _open_journal(self, requests: list[Request], mode: str) -> None:
        if self.cfg.snapshot_dir is None:
            return
        path = os.path.join(self.cfg.snapshot_dir,
                            journal_mod.JOURNAL_NAME)
        self._journal = journal_mod.JournalWriter(path, fresh=True)
        self._journal.append("run_start", self._run_meta(requests, mode))
        self._journal.sync()

    def _close_journal(self) -> None:
        if self._journal is not None:
            self._journal.close()
            self._journal = None

    # ---------------- serving loop ----------------

    def run(self, requests: list[Request],
            scheduling: Optional[str] = None) -> list[Request]:
        self._t0 = time.monotonic()     # engine clock zero for arrivals
        wall = time.time()              # the one epoch stamp per run
        for r in requests:
            if r.t_wall is None:
                r.t_wall = wall + r.arrival_s
        mode = scheduling or self.cfg.scheduling
        self._open_journal(requests, mode)
        try:
            if mode == "grouped":
                self._run_grouped(requests)
            else:
                self._run_mixed(requests)
            for r in requests:
                if r.status == PENDING:
                    r.status = DONE
            self._journal_append("run_end", {"requests": len(requests)},
                                 flush=True)
        finally:
            self._close_journal()
        self._export_gauges()
        return requests

    # ---------------- kill–restart recovery ----------------

    def resume(self) -> list[Request]:
        """Recover a killed run from ``snapshot_dir``'s journal (+ latest
        snapshot, if any) and serve it to completion.

        The determinism foundation makes this exact: every row's token
        stream is a pure function of (sampling seed, uid, draw index)
        plus prompt and expert — invariant to chunk size, admission
        timing, KV layout and mesh shape.  So recovery is:

        1. replay the journal → which requests existed, what each had
           emitted, which finished/failed (``run_end`` absent = crash);
        2. restore the last snapshot's wave (KV + pending token at a
           chunk boundary, allocator free list on the paged path) and
           continue it — the regenerated tail is verified against the
           journaled suffix;
        3. every other incomplete request re-serves from its prompt
           (its KV postdates the snapshot, or it was never admitted) —
           bit-identical because streams are uid-keyed.

        Experts are refetched through the normal registry tiers (an
        unavailable expert degrades to per-request FAILED, exactly like
        live serving).  The resumed run does NOT journal or snapshot —
        single-crash tolerance; re-arm with a fresh ``run()``.  Returns
        the rebuilt request list; ``recovery_stats`` carries timing and
        the :class:`~repro.distributed.fault.RecoveryPlan`.
        """
        cfg = self.cfg
        if cfg.snapshot_dir is None:
            raise ValueError("resume() needs EngineConfig.snapshot_dir")
        if self._plan is None:
            raise ValueError("resume() supports the mixed overlay path "
                             "only (this model family is not coverable)")
        t_resume0 = time.monotonic()
        self._recovery_t0 = t_resume0
        self.recovery_stats = {}
        path = os.path.join(cfg.snapshot_dir, journal_mod.JOURNAL_NAME)
        state = journal_mod.replay(path)
        meta = state.meta
        if SamplingConfig.from_meta(meta["sampling"]) != cfg.sampling:
            raise ValueError(
                "resume(): sampling mismatch — journaled "
                f"{meta['sampling']}, engine {cfg.sampling.to_meta()}; "
                "token streams would diverge")
        if meta.get("scheduling") == "grouped":
            raise ValueError("resume() supports mixed scheduling only")
        if meta["scheduler"] != cfg.scheduler:
            raise ValueError(f"resume(): scheduler mismatch — journaled "
                             f"{meta['scheduler']!r}, engine "
                             f"{cfg.scheduler!r}")
        if meta["kv_layout"] != cfg.kv_layout:
            raise ValueError(f"resume(): kv_layout mismatch — journaled "
                             f"{meta['kv_layout']!r}, engine "
                             f"{cfg.kv_layout!r}")
        snap = None
        if state.snapshots:
            snap = snapshot_mod.load_snapshot(
                cfg.snapshot_dir, int(state.snapshots[-1]["step"]))

        # rebuild every Request from the run_start manifest, then apply
        # the journaled facts (tokens / terminal states)
        requests: list[Request] = []
        for d in meta["requests"]:
            requests.append(Request(
                uid=int(d["uid"]), expert=d["expert"],
                prompt=jnp.asarray(d["prompt"], jnp.int32),
                max_new_tokens=int(d["max_new"]),
                priority=int(d.get("priority", 1)),
                deadline_s=d.get("deadline_s"),
                arrival_s=float(d.get("arrival_s", 0.0)),
                t_wall=d.get("t_wall")))
        by_uid = {r.uid: r for r in requests}
        snap_uids = set(snap.row_uids) if snap is not None else set()
        replayed: list[Request] = []
        reserve: list[Request] = []
        for r in requests:
            toks = state.tokens.get(r.uid, [])
            if r.uid in state.failed:
                r.status = FAILED
                r.error = state.failed[r.uid]
                r.out_tokens = list(toks)
            elif len(toks) >= r.max_new_tokens:
                r.status = DONE
                r.out_tokens = list(toks[:r.max_new_tokens])
            elif snap is not None and r.uid in snap_uids:
                # continue from restored KV: tokens past the snapshot
                # regenerate deterministically (verified against the
                # journaled suffix below)
                r.out_tokens = list(toks[:snap.emitted[r.uid]])
                replayed.append(r)
            else:
                # KV postdates the snapshot (admitted after it) or the
                # request was never admitted: full re-serve, prefill
                # re-runs — bit-identical because streams are uid-keyed
                r.out_tokens = []
                reserve.append(r)

        self._t0 = time.monotonic()        # resume-run engine clock zero
        sched = scheduler_mod.make_scheduler(cfg.scheduler)
        self._sched = sched
        if cfg.kv_layout == "paged":
            self._validate_paged(reserve)
        for r in reserve:
            if r.status == PENDING:
                # arrival offsets are relative to the ORIGINAL clock zero;
                # anything already due at crash time is due now
                r.arrival_s = max(0.0, r.arrival_s - state.last_t)
                sched.push(r)
        if snap is not None:
            resident = [n for n in snap.meta.get("resident", ())
                        if n != BASE]
            if resident:
                try:          # warm the device cache; purely opportunistic
                    self.registry.prefetch(resident)
                except ExpertUnavailable:
                    pass
        continued = demoted = 0
        if snap is not None and any(by_uid[u].status == PENDING
                                    for u in snap_uids):
            continued, demoted = self._resume_wave(snap, by_uid, sched)
        self._drain(sched)
        for r in requests:
            if r.status == PENDING:
                r.status = DONE
        self._verify_journal_prefix(requests, state)
        self.recovery_stats.update({
            "resume_seconds": time.monotonic() - t_resume0,
            "plan": RecoveryPlan(
                snapshot_step=snap.step if snap is not None else None,
                journal_records=state.n_records,
                replayed_rows=continued,
                reprefilled_rows=len(reserve) + demoted)})
        self._recovery_t0 = None
        self.resumed_requests = requests
        self._export_gauges()
        return requests

    def _resume_wave(self, snap, by_uid: dict, sched) -> tuple:
        """Restore the snapshotted in-flight wave (KV, pending tokens,
        slot composition, paged allocator) and run it to completion via
        the shared chunk loop.  Returns ``(continued, demoted)`` row
        counts; on a failed expert refetch the dead expert's rows FAIL
        and every other incomplete row is demoted to a full re-serve."""
        t0 = time.monotonic()
        experts = list(snap.meta["experts"])
        live = [u for u in snap.row_uids
                if by_uid[u].status == PENDING]
        try:
            overlay = self._overlay_for(tuple(experts))
        except ExpertUnavailable as e:
            demoted = 0
            for u in live:
                r = by_uid[u]
                if r.expert == e.name:
                    self._fail([r], e)
                else:
                    r.out_tokens = []
                    sched.push(r)
                    demoted += 1
            return 0, demoted
        if overlay is None:
            raise RuntimeError("resume(): snapshotted wave is not "
                               "coverable by the zero-merge overlay")
        rows = [by_uid[u] for u in snap.row_uids]
        self._mark_admitted(rows)
        slot = {e: i for i, e in enumerate(experts)}
        eid = jnp.asarray([slot[r.expert] for r in rows], jnp.int32)
        keys = decode_loop.row_keys(self.cfg.sampling.seed,
                                    [r.uid for r in rows])
        # logical arrays -> this engine's placement (possibly a different
        # mesh shape than the writer's; values are placement-invariant)
        cache, tok = snap.device_state(self)
        if self.cfg.kv_layout == "paged":
            alloc = paged_kv.BlockAllocator.from_state(
                self._kv_blocks, self._bs, snap.meta["alloc_free"])
            row_blocks = {int(j): [int(b) for b in bl]
                          for j, bl in snap.meta["row_blocks"].items()}
            self._kv_in_use = alloc.in_use
            self._kv_peak = max(self._kv_peak, alloc.peak_in_use)
            try:
                admitted, chunks = self._chunk_loop(
                    rows, experts, slot, overlay, eid, tok, keys, cache,
                    sched, alloc=alloc, row_blocks=row_blocks)
            finally:
                for j in list(row_blocks):
                    alloc.free(row_blocks.pop(j))
                self._kv_in_use = alloc.in_use
                assert alloc.in_use == 0, (
                    f"paged KV leak on resume: {alloc.in_use} blocks "
                    "still allocated at wave teardown")
        else:
            admitted, chunks = self._chunk_loop(
                rows, experts, slot, overlay, eid, tok, keys, cache,
                sched, cur=int(snap.meta["cur"]))
        self._ring_append("wave", {"rows": len(rows),
                                   "experts": len(experts),
                                   "admitted": admitted, "chunks": chunks,
                                   "resumed": True,
                                   "seconds": time.monotonic() - t0})
        return len(live), 0

    @staticmethod
    def _verify_journal_prefix(requests: list[Request], state) -> None:
        """Bit-identity guard: every journaled token must be a prefix of
        the post-resume stream.  A mismatch means the restored state or
        the refetched experts diverged — the resume is unsound and must
        fail loudly rather than return silently different tokens."""
        for r in requests:
            if r.status == FAILED:
                continue
            pre = [int(t) for t in
                   state.tokens.get(r.uid, [])][:r.max_new_tokens]
            got = [int(t) for t in r.out_tokens[:len(pre)]]
            if got != pre:
                raise RuntimeError(
                    f"resume(): request {r.uid} diverged from the "
                    f"journal (journaled {pre[:8]}, regenerated "
                    f"{got[:8]})")

    # -- engine clock / SLO bookkeeping --

    def _now(self) -> float:
        return time.monotonic() - self._t0

    def _mark_admitted(self, reqs: list[Request]) -> None:
        now = self._now()
        for r in reqs:
            if r.t_admit_s is None:
                r.t_admit_s = now
                self._adm_wait[r.priority].append(now - r.arrival_s)

    def _mark_first(self, reqs: list[Request]) -> None:
        now = self._now()
        for r in reqs:
            if r.t_first_s is None and r.max_new_tokens > 0:
                r.t_first_s = now

    def _mark_done(self, r: Request) -> None:
        if r.t_done_s is None and len(r.out_tokens) >= r.max_new_tokens:
            r.t_done_s = self._now()

    def _prefetch_upcoming(self, upcoming, extra=()) -> None:
        """Admission-time prefetch: stage promotions for every distinct
        expert named by queued-but-nonresident requests (bounded
        lookahead), plus ``extra`` (the wave about to be served, so its E
        cold fetches run concurrently instead of serially inside the
        stack build).  A wave then never stalls on a cold fetch that
        could have overlapped the previous wave's decode steps."""
        names = list(dict.fromkeys(extra))
        seen = set(names)
        for r in itertools.islice(upcoming, 0, 4 * self.cfg.max_batch):
            if r.expert not in seen:
                seen.add(r.expert)
                names.append(r.expert)
        if names:
            self.registry.prefetch(names)

    def _run_grouped(self, requests: list[Request]) -> list[Request]:
        """PR-1 baseline: greedy same-expert batching, merge per expert."""
        groups: dict[str, list[Request]] = defaultdict(list)
        for r in requests:
            groups[r.expert].append(r)
        order = list(groups)
        for gi, expert in enumerate(order):
            if gi + 1 < len(order):
                # overlap the next group's cold fetch with this group's
                # merge + decode steps
                self.registry.prefetch([order[gi + 1]])
            try:
                params = self._params_for(expert)
            except ExpertUnavailable as e:
                # one dead expert fails ITS group; every other group serves
                self._fail(groups[expert], e)
                continue
            reqs = groups[expert]
            for i in range(0, len(reqs), self.cfg.max_batch):
                self._serve_batch(params, reqs[i:i + self.cfg.max_batch])
        return requests

    def _validate_paged(self, requests: list[Request]) -> None:
        """Push-time feasibility: a request that can NEVER be placed (needs
        more blocks than the whole pool, or more positions than
        ``cache_len``) fails terminally instead of deadlocking the queue."""
        for r in requests:
            lp, need = paged_kv.blocks_for(int(r.prompt.shape[0]),
                                           r.max_new_tokens, self._bs)
            if (lp + r.max_new_tokens > self._max_blocks * self._bs
                    or need > min(self._max_blocks, self._kv_blocks - 1)):
                self._fail([r], ValueError(
                    f"request {r.uid} needs {need} KV blocks "
                    f"({lp}+{r.max_new_tokens} positions); pool holds "
                    f"{self._kv_blocks - 1} usable blocks of {self._bs} "
                    f"with {self._max_blocks} per row"))

    def _run_mixed(self, requests: list[Request]) -> list[Request]:
        """Continuous mixed-expert batching (zero-merge hot path).

        Admission order is delegated to the configured scheduler
        (``scheduler="fifo"`` replicates the historical deque
        bit-identically); requests with a future ``arrival_s`` are held
        back until the engine clock reaches them, which is what lets
        :mod:`benchmarks.traffic` replay open-loop timelines."""
        if self._plan is None:
            # family not coverable at all: hand the WHOLE list to the
            # grouped scheduler so it merges once per expert, not per wave
            return self._run_grouped(requests)
        if self.cfg.kv_layout == "paged":
            self._validate_paged(requests)
        sched = scheduler_mod.make_scheduler(self.cfg.scheduler)
        self._sched = sched
        sched.on_decision = lambda d: self._journal_append("sched", d)
        for r in requests:
            if r.status == PENDING:
                sched.push(r)
        self._drain(sched)
        return requests

    def _drain(self, sched) -> None:
        """Serve the scheduler dry: build waves, serve them, honor future
        arrivals.  Shared by :meth:`_run_mixed` and :meth:`resume` (which
        seeds the scheduler with re-served requests by hand)."""
        while sched.pending():
            sched.release(self._now())
            if not sched.ready_count():
                nxt = sched.next_arrival()
                if nxt is None:
                    break
                # open-loop idle: sleep toward the next arrival (bounded,
                # so a clock hiccup never wedges the loop)
                time.sleep(min(max(nxt - self._now(), 0.0), 0.05))
                continue
            wave, experts = sched.take_wave(self.cfg.max_batch,
                                            self.cfg.max_stack)
            if not wave:
                continue
            self._prefetch_upcoming(sched.peek(4 * self.cfg.max_batch),
                                    extra=experts)
            overlay = None
            while wave:
                try:
                    overlay = self._overlay_for(tuple(experts))
                    break
                except ExpertUnavailable as e:
                    # evict the dead expert's rows from the wave and retry
                    # the (shrunken) stack build; the healthy rows serve
                    hit = [r for r in wave if r.expert == e.name]
                    if not hit:
                        raise    # not from this wave: don't loop forever
                    self._fail(hit, e)
                    wave = [r for r in wave if r.expert != e.name]
                    experts = [x for x in experts if x != e.name]
            if not wave:
                continue
            if overlay is None:
                # family/leaf not coverable -> merge-on-swap fallback
                self._run_grouped(wave)
                continue
            self._serve_wave(wave, experts, overlay, sched)

    def _pad_prompts(self, reqs: list[Request]) -> tuple:
        """Left-pad prompts to one width.  Returns (tokens [B, T],
        start [B] — each row's first real position, for the pad mask)."""
        T = max(int(r.prompt.shape[0]) for r in reqs)
        toks = jnp.stack([jnp.pad(r.prompt, (T - r.prompt.shape[0], 0),
                                  constant_values=1) for r in reqs]
                         ).astype(jnp.int32)
        start = jnp.asarray([T - int(r.prompt.shape[0]) for r in reqs],
                            jnp.int32)
        return toks, start

    def _kv_sharding_for(self, batch: int):
        """Static ``kv_sharding`` for a wave prefill: batch rows sharded
        along the mesh's ``model`` axis when they divide evenly (rows are
        independent end to end, so placement never changes a value).
        None on the single-device path and for single-row admission
        prefills — their KV is spliced/scattered into the wave cache,
        which keeps its own placement."""
        if self.mesh is None:
            return None
        n = dict(self.mesh.shape).get("model", 1)
        if n <= 1 or batch % n != 0:
            return None
        return self._shard_rules.serve_kv_sharding(
            self.mesh, (0, batch, 0, 0, 0))

    def _row_mask_ok(self) -> bool:
        # per-row left-pad masking needs every position to live in
        # attention KV state (recurrent blocks consume pads through their
        # state; frontends prepend non-text positions)
        c = self.api.cfg
        return (all(b.kind == "attn" for b in c.pattern)
                and c.frontend is None and not c.cross_attn
                and not c.enc_n_units)

    def _can_admit(self) -> bool:
        # slot refill splices per-row KV state; only the pure-attention
        # families keep all decode state per-row
        return (self.cfg.continuous
                and all(b.kind == "attn" for b in self.api.cfg.pattern))

    def _serve_wave(self, wave: list[Request], experts: list[str],
                    overlay: dict, sched) -> None:
        if self.cfg.kv_layout == "paged":
            return self._serve_wave_paged(wave, experts, overlay, sched)
        if self.cfg.decode_chunk:
            return self._serve_wave_chunked(wave, experts, overlay, sched)
        return self._serve_wave_eager(wave, experts, overlay, sched)

    def _admission_block_reason(self, nxt: Request, cur: int, slot: dict,
                                alloc) -> Optional[str]:
        """Why ``nxt`` cannot be placed into a finished slot right now
        (None = placeable).  Dense slots are hostage to the wave position
        (no left-pad down, no ring wrap); paged slots only need free
        blocks."""
        if (nxt.expert not in slot
                and len(slot) >= self.cfg.max_stack):
            return "stack"
        if alloc is None:
            if int(nxt.prompt.shape[0]) > cur:
                return "position"     # cannot left-pad down
            if cur + nxt.max_new_tokens > self.cfg.cache_len:
                return "wrap"         # would wrap the KV ring
        else:
            _, need = paged_kv.blocks_for(int(nxt.prompt.shape[0]),
                                          nxt.max_new_tokens, self._bs)
            if need > alloc.available:
                return "kv_blocks"
        return None

    def _try_admissions(self, rows, done, cur, experts, slot, overlay,
                        eid, tok, keys, cache, sched,
                        alloc=None, row_blocks=None):
        """Refill finished slots in place from the scheduler (host-side
        continuous-admission logic, shared by the eager, chunked and
        paged drivers).  ``cur`` is the host-mirrored wave position on the
        dense path (unused when ``alloc`` is given — paged rows carry
        their own positions).  Returns the updated device state plus the
        list of slots refilled this round.

        Blocked-head semantics are scheduler-defined: ``strict_fifo``
        preserves the historical head-of-line block (an unplaceable head
        stops ALL refills — the bit-identical baseline), while the
        priority/affinity schedulers scan past a blocked candidate, so a
        head whose KV blocks are exhausted defers only itself instead of
        starving placeable requests behind it."""
        sched.release(self._now())
        refilled = []
        if alloc is not None:
            # reclaim every finished row's blocks up front so this round's
            # candidates see the whole reclaimable pool
            for j in done:
                if j in row_blocks:
                    alloc.free(row_blocks.pop(j))
            self._kv_in_use = alloc.in_use
        blocked = False               # strict-FIFO head-of-line block
        for j in done:
            if blocked:
                break
            admitted = rescan = True
            while rescan and not blocked:
                admitted = False
                rescan = False
                for nxt in sched.candidates(slot):
                    reason = self._admission_block_reason(nxt, cur, slot,
                                                          alloc)
                    if reason is not None:
                        if sched.strict_fifo:
                            blocked = True
                            break
                        sched.note_deferred(reason)
                        continue      # try the next placeable candidate
                    if nxt.expert not in slot:
                        try:
                            grown = self._overlay_for(
                                tuple(experts + [nxt.expert]))
                        except ExpertUnavailable as e:
                            # fail ONLY this request and rescan — a dead
                            # expert must not block the admission queue
                            sched.remove(nxt)
                            self._fail([nxt], e)
                            rescan = True
                            break
                        if grown is None:
                            if sched.strict_fifo:
                                blocked = True    # newcomer not coverable
                                break
                            sched.note_deferred("overlay")
                            continue
                        experts.append(nxt.expert)
                        slot[nxt.expert] = len(experts) - 1
                        overlay = grown
                    else:
                        # the row is served entirely from the wave's
                        # resident stacked planes — the affinity lever
                        self.cache.stats.stack_hits += 1
                    sched.remove(nxt)
                    rows[j] = nxt
                    eid = eid.at[j].set(slot[nxt.expert])
                    key_j = decode_loop.row_keys(self.cfg.sampling.seed,
                                                 [nxt.uid])
                    keys = keys.at[j].set(key_j[0])
                    if alloc is not None:
                        tok, cache = self._admit_row_paged(
                            nxt, j, cache, tok, overlay, eid, key_j,
                            alloc, row_blocks)
                    else:
                        tok, cache = self._admit_row(nxt, j, cur, cache,
                                                     tok, overlay, eid,
                                                     key_j)
                    self._mark_admitted([nxt])
                    self._mark_first([nxt])
                    self._journal_admit(nxt, j)
                    refilled.append(j)
                    admitted = True
                    break             # slot j filled; move to the next
                if admitted:
                    break
        return rows, experts, overlay, eid, tok, keys, cache, refilled

    def _serve_wave_eager(self, wave: list[Request], experts: list[str],
                          overlay: dict, sched) -> None:
        """PR-2 baseline: one jitted decode dispatch + one host sync per
        generated token.  Kept (``decode_chunk=0``) as the measured
        baseline of ``perf_lab --exp decode_loop``.  Token selection goes
        through the same on-device selector as the compiled loop, so
        temperature/top-k sampling is eager-vs-chunked reproducible: row
        streams depend only on (seed, uid, draw index)."""
        t0 = time.monotonic()
        self._mark_admitted(wave)
        slot = {e: i for i, e in enumerate(experts)}
        eid = jnp.asarray([slot[r.expert] for r in wave], jnp.int32)
        toks, start = self._pad_prompts(wave)
        cur = int(toks.shape[1])           # host mirror of cache["cur"]
        logits, cache = self._prefill(self.base, {"tokens": toks}, self.rt,
                                      self.cfg.cache_len, delta=overlay,
                                      eid=eid, start=start,
                                      kv_sharding=self._kv_sharding_for(
                                          len(wave)))
        keys = decode_loop.row_keys(self.cfg.sampling.seed,
                                    [r.uid for r in wave])
        tok = self._select(logits, keys, jnp.zeros((len(wave),), jnp.int32))
        self._mark_first(wave)
        rows: list[Optional[Request]] = list(wave)
        admitted = 0
        while True:
            tok_np = np.asarray(tok).ravel()   # one host sync per step
            for j, r in enumerate(rows):
                if r is not None and len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(tok_np[j]))
                    self._mark_done(r)
            done = [j for j, r in enumerate(rows) if r is None
                    or r.status == FAILED
                    or len(r.out_tokens) >= r.max_new_tokens]
            # continuous admission: refill finished slots in place
            if sched is not None and sched.pending() and self._can_admit():
                (rows, experts, overlay, eid, tok, keys, cache,
                 refilled) = self._try_admissions(
                     rows, done, cur, experts, slot, overlay, eid, tok,
                     keys, cache, sched)
                for j in refilled:
                    # the newcomer's prefill selection IS its first
                    # generated token; record it now — the next loop-top
                    # append only sees the decode output that consumes it
                    if rows[j].max_new_tokens > 0:
                        rows[j].out_tokens.append(int(tok[j, 0]))
                        self._mark_done(rows[j])
                admitted += len(refilled)
                done = [j for j, r in enumerate(rows) if r is None
                        or r.status == FAILED
                        or len(r.out_tokens) >= r.max_new_tokens]
            if len(done) == len(rows):
                break
            logits, cache = self._decode(self.base, tok, cache, self.rt,
                                         delta=overlay, eid=eid)
            # draw index = tokens already emitted (pending tok was just
            # appended above) — matches the compiled loop's gen stream
            gen = jnp.asarray([len(r.out_tokens) if r is not None else 0
                               for r in rows], jnp.int32)
            tok = self._select(logits, keys, gen)
            cur += 1
        self._ring_append("wave", {"rows": len(wave),
                                   "experts": len(experts),
                                   "admitted": admitted, "chunks": 0,
                                   "seconds": time.monotonic() - t0})

    def _drive_chunk(self, params, overlay, eid, tok, cache, rows, keys):
        """Launch ONE compiled K-step chunk and flush its ``[B, K]`` token
        buffer into the rows (a single host sync).  Shared by the mixed
        wave and the grouped batch drivers — the flush count
        (``min(K, remaining)``) and the ``gen`` stream indices must match
        the scan body's emit semantics exactly, in one place.  Returns
        ``(tok, cache, decode_steps, launched)`` where ``decode_steps``
        advances the host-side position mirror and ``launched`` is False
        when every row was already done (no launch happened)."""
        K = self.cfg.decode_chunk
        # FAILED rows are terminal mid-wave (resume can restore a wave
        # containing them): they emit nothing and free their slot
        rem = [0 if r.status == FAILED
               else max(r.max_new_tokens - len(r.out_tokens), 0)
               for r in rows]
        if max(rem) == 0:
            return tok, cache, 0, False
        # gen = tokens each row has generated so far (the pending ``tok``
        # counts); indexes fold_in for reproducible sampling
        gen = jnp.asarray([len(r.out_tokens) + 1 for r in rows], jnp.int32)
        tok, cache, buf = self._chunk_fn(params, overlay, eid, tok, cache,
                                         jnp.asarray(rem, jnp.int32), gen,
                                         keys)
        buf_np = np.asarray(buf)           # ONE host sync per K steps
        flushed = []
        for j, r in enumerate(rows):
            n = min(K, rem[j])
            if n:
                toks = [int(t) for t in buf_np[j, :n]]
                r.out_tokens.extend(toks)
                self._mark_done(r)
                flushed.append({"uid": r.uid, "n": n, "toks": toks,
                                "total": len(r.out_tokens)})
        self._chunk_idx += 1
        # the chunk boundary IS the WAL sync point: tokens reach the OS
        # before the next launch, so a SIGKILL costs at most one chunk
        self._journal_append("chunk", {"i": self._chunk_idx,
                                       "rows": flushed}, flush=True)
        if (self._recovery_t0 is not None
                and "first_resumed_token_s" not in self.recovery_stats):
            self.recovery_stats["first_resumed_token_s"] = (
                time.monotonic() - self._recovery_t0)
        for hook in list(self.chunk_hooks):
            hook(self._chunk_idx)
        return tok, cache, decode_loop.host_decode_steps(max(rem), K), True

    @staticmethod
    def _done_rows(rows) -> list:
        """Slots eligible for refill: budget exhausted OR terminally
        FAILED (a failed row must never keep decoding — without the
        status check a restored FAILED row would spin the wave loop
        forever at rem=0)."""
        return [j for j, r in enumerate(rows)
                if r.status == FAILED
                or len(r.out_tokens) >= r.max_new_tokens]

    def _maybe_snapshot(self, rows, experts, cache, tok, cur,
                        alloc=None, row_blocks=None) -> None:
        """Commit a crash-consistent snapshot at the configured chunk
        cadence (post-flush device state = the exact restart point)."""
        every = self.cfg.snapshot_every_chunks
        if (self._journal is None or not every
                or self._chunk_idx % every != 0):
            return
        snapshot_mod.write_snapshot(self, rows=rows, experts=experts,
                                    cache=cache, tok=tok, cur=cur,
                                    alloc=alloc, row_blocks=row_blocks)

    def _chunk_loop(self, rows, experts, slot, overlay, eid, tok, keys,
                    cache, sched, cur=0, alloc=None, row_blocks=None):
        """Shared chunked wave driver (dense and paged): launch a chunk,
        flush + journal its tokens, snapshot at the configured cadence,
        then refill finished slots from the scheduler.  The newcomer's
        first token stays ON DEVICE: it is the pending ``tok[j]`` the next
        chunk emits first — no int(tok[j, 0]) read-back per admission.
        Returns ``(admitted, chunks)``."""
        admitted = chunks = 0
        while True:
            tok, cache, steps, launched = self._drive_chunk(
                self.base, overlay, eid, tok, cache, rows, keys)
            cur += steps                   # host mirror (dense path only)
            chunks += int(launched)
            if launched:
                self._maybe_snapshot(rows, experts, cache, tok, cur,
                                     alloc=alloc, row_blocks=row_blocks)
            done = self._done_rows(rows)
            if sched is not None and sched.pending() and self._can_admit():
                (rows, experts, overlay, eid, tok, keys, cache,
                 refilled) = self._try_admissions(
                     rows, done, cur, experts, slot, overlay, eid, tok,
                     keys, cache, sched, alloc=alloc,
                     row_blocks=row_blocks)
                admitted += len(refilled)
                done = self._done_rows(rows)
            if len(done) == len(rows):
                return admitted, chunks

    def _serve_wave_chunked(self, wave: list[Request], experts: list[str],
                            overlay: dict, sched) -> None:
        """Device-resident wave loop: K decode steps (stopping masks,
        token selection, KV writes) per compiled launch, ONE host sync per
        chunk to flush the ``[B, K]`` token buffer, then host-side
        admission via the shared :meth:`_chunk_loop` driver."""
        t0 = time.monotonic()
        self._mark_admitted(wave)
        slot = {e: i for i, e in enumerate(experts)}
        eid = jnp.asarray([slot[r.expert] for r in wave], jnp.int32)
        toks, start = self._pad_prompts(wave)
        cur = int(toks.shape[1])           # host mirror of cache["cur"]
        logits, cache = self._prefill(self.base, {"tokens": toks}, self.rt,
                                      self.cfg.cache_len, delta=overlay,
                                      eid=eid, start=start,
                                      kv_sharding=self._kv_sharding_for(
                                          len(wave)))
        rows: list[Request] = list(wave)
        keys = decode_loop.row_keys(self.cfg.sampling.seed,
                                    [r.uid for r in rows])
        tok = self._select(logits, keys,
                           jnp.zeros((len(rows),), jnp.int32))
        self._mark_first(rows)
        for j, r in enumerate(rows):
            self._journal_admit(r, j)
        admitted, chunks = self._chunk_loop(rows, experts, slot, overlay,
                                            eid, tok, keys, cache, sched,
                                            cur=cur)
        self._ring_append("wave", {"rows": len(wave),
                                   "experts": len(experts),
                                   "admitted": admitted, "chunks": chunks,
                                   "seconds": time.monotonic() - t0})

    def _admit_row(self, r: Request, j: int, cur: int, cache, tok,
                   overlay, eid, key_row):
        """Prefill one newcomer left-padded to the wave position and splice
        its KV state into row j of the running batch.  The row's ``start``
        (= cur - prompt length) rides along, so the spliced row's decode
        attention ignores the left-pad positions — an admitted request
        matches the same prompt served solo."""
        row_start = cur - int(r.prompt.shape[0])
        prompt = jnp.pad(r.prompt, (row_start, 0),
                         constant_values=1)[None].astype(jnp.int32)
        row_eid = eid[j][None]
        row_logits, row_cache = self._prefill(
            self.base, {"tokens": prompt}, self.rt, self.cfg.cache_len,
            delta=overlay, eid=row_eid,
            start=jnp.asarray([row_start], jnp.int32))

        def splice(c, rc):
            if c.ndim >= 2 and rc.ndim == c.ndim and rc.shape[1] == 1:
                return c.at[:, j].set(rc[:, 0])
            return c
        new_cache = dict(cache)
        new_cache["layers"] = jax.tree_util.tree_map(splice, cache["layers"],
                                                     row_cache["layers"])
        new_cache["start"] = cache["start"].at[j].set(row_start)
        first = self._select(row_logits, key_row,
                             jnp.zeros((1,), jnp.int32))   # [1, 1]
        tok = tok.at[j].set(first[0])
        return tok, new_cache

    # ---------------- paged-KV wave driver ----------------

    def _paged_prefill(self, reqs: list[Request], js: list[int], lp: int,
                       cache, tok, overlay, eid, keys_rows, row_blocks):
        """Prefill N rows (all bucketed to prompt width ``lp``) and scatter
        their KV into the block pool.  The rows run a *dense* prefill at
        ``cache_len = lp`` — with T == S the ring fill is the identity, so
        slot order is position order and the per-row caches drop straight
        into ``lp // block_size`` pool blocks.  No batch re-padding, no
        per-row splice into a running cache."""
        jsa = jnp.asarray(js, jnp.int32)
        toks = jnp.stack([jnp.pad(r.prompt, (lp - r.prompt.shape[0], 0),
                                  constant_values=1) for r in reqs]
                         ).astype(jnp.int32)
        start = jnp.asarray([lp - int(r.prompt.shape[0]) for r in reqs],
                            jnp.int32)
        logits, row_cache = self._prefill(self.base, {"tokens": toks},
                                          self.rt, lp, delta=overlay,
                                          eid=eid[jsa], start=start)
        row_layers = {name: {"k": st["k"], "v": st["v"]}
                      for name, st in row_cache["layers"].items()}
        N, nbp = len(js), lp // self._bs
        ptab = np.asarray([row_blocks[j][:nbp] for j in js], np.int32)
        tables = np.full((N, self._max_blocks), -1, np.int32)
        for i, j in enumerate(js):
            tables[i, :len(row_blocks[j])] = row_blocks[j]
        cache = paged_kv.insert_prefill_rows(
            cache, row_layers, jsa, jnp.asarray(ptab), jnp.asarray(tables),
            jnp.full((N,), lp, jnp.int32), start)
        first = self._select(logits, keys_rows, jnp.zeros((N,), jnp.int32))
        tok = tok.at[jsa].set(first)
        return tok, cache

    def _admit_row_paged(self, r: Request, j: int, cache, tok, overlay,
                         eid, key_row, alloc, row_blocks):
        """Paged slot refill: allocate the row's blocks and write its
        prefill KV.  Unlike the dense path there is no wave position to
        left-pad against and no ring to wrap — any prompt length admits
        whenever enough blocks are free (the feasibility check already
        passed in ``_admission_block_reason``)."""
        lp, need = paged_kv.blocks_for(int(r.prompt.shape[0]),
                                       r.max_new_tokens, self._bs)
        row_blocks[j] = alloc.alloc(need)
        self._kv_in_use = alloc.in_use
        self._kv_peak = max(self._kv_peak, alloc.peak_in_use)
        return self._paged_prefill([r], [j], lp, cache, tok, overlay, eid,
                                   key_row, row_blocks)

    def _serve_wave_paged(self, wave: list[Request], experts: list[str],
                          overlay: dict, sched) -> None:
        """Block-table wave loop: per-bucket batched prefill into pool
        blocks, then the same compiled K-step chunk driver as the dense
        path (the paged cache rides through ``decode_step`` via its
        ``tables``/``lens`` fields).  Admission control is the free list:
        a finished row's blocks return to the pool and any queued request
        whose block need fits is placeable — regardless of prompt length
        or how far the wave has decoded."""
        t0 = time.monotonic()
        alloc = paged_kv.BlockAllocator(self._kv_blocks, self._bs)
        row_blocks: dict[int, list] = {}
        kept: list[Request] = []
        buckets: list[int] = []
        for r in wave:
            lp, need = paged_kv.blocks_for(int(r.prompt.shape[0]),
                                           r.max_new_tokens, self._bs)
            blocks = alloc.alloc(need)
            if blocks is None:
                # pool smaller than the wave: the overflow re-queues and
                # re-enters via a later wave or a slot refill
                sched.push(r)
                continue
            row_blocks[len(kept)] = blocks
            kept.append(r)
            buckets.append(lp)
        if not kept:
            return
        wave = kept
        self._mark_admitted(wave)
        slot = {e: i for i, e in enumerate(experts)}
        eid = jnp.asarray([slot[r.expert] for r in wave], jnp.int32)
        keys = decode_loop.row_keys(self.cfg.sampling.seed,
                                    [r.uid for r in wave])
        cache = paged_kv.init_paged_cache(self.api.cfg, len(wave),
                                          self._kv_blocks, self._bs,
                                          self._max_blocks, mesh=self.mesh)
        tok = jnp.zeros((len(wave), 1), jnp.int32)
        rows: list[Request] = list(wave)
        groups: dict[int, list] = defaultdict(list)
        for j, lp in enumerate(buckets):
            groups[lp].append(j)
        for lp in sorted(groups):
            js = groups[lp]
            tok, cache = self._paged_prefill(
                [rows[j] for j in js], js, lp, cache, tok, overlay, eid,
                keys[jnp.asarray(js, jnp.int32)], row_blocks)
        self._mark_first(rows)
        for j, r in enumerate(rows):
            self._journal_admit(r, j)
        self._kv_in_use = alloc.in_use
        self._kv_peak = max(self._kv_peak, alloc.peak_in_use)
        try:
            admitted, chunks = self._chunk_loop(
                rows, experts, slot, overlay, eid, tok, keys, cache,
                sched, alloc=alloc, row_blocks=row_blocks)
        finally:
            # leak-proof teardown: every live row's blocks return to the
            # pool on ANY exit (fault paths included), and the allocator
            # must balance — a leak here would starve every later wave
            for j in list(row_blocks):
                alloc.free(row_blocks.pop(j))
            self._kv_in_use = alloc.in_use
            assert alloc.in_use == 0, (
                f"paged KV leak: {alloc.in_use} blocks still allocated "
                "at wave teardown")
        self._ring_append("wave", {"rows": len(wave),
                                   "experts": len(experts),
                                   "admitted": admitted, "chunks": chunks,
                                   "kv_blocks_peak": alloc.peak_in_use,
                                   "seconds": time.monotonic() - t0})

    def _serve_batch(self, params, reqs: list[Request]) -> None:
        """Merge-path batch (single expert): prefill then decode."""
        self._mark_admitted(reqs)
        toks, start = self._pad_prompts(reqs)
        batch = {"tokens": toks}
        if self.api.cfg.frontend is not None:
            n = self.api.cfg.frontend.n_tokens
            e = self.api.cfg.frontend.embed_dim
            stub = jnp.zeros((len(reqs), n, e), jnp.float32)
            key = ("frames" if self.api.cfg.family == "audio"
                   else "mm_embeds")
            batch[key] = stub
        logits, cache = self._prefill(params, batch, self.rt,
                                      self.cfg.cache_len,
                                      start=(start if self._row_mask_ok()
                                             else None),
                                      kv_sharding=self._kv_sharding_for(
                                          len(reqs)))
        if self.cfg.decode_chunk:
            return self._decode_batch_chunked(params, reqs, logits, cache)
        keys = decode_loop.row_keys(self.cfg.sampling.seed,
                                    [r.uid for r in reqs])
        tok = self._select(logits, keys, jnp.zeros((len(reqs),), jnp.int32))
        self._mark_first(reqs)
        steps = max(r.max_new_tokens for r in reqs)
        for _ in range(steps):
            tok_np = np.asarray(tok).ravel()   # one host sync per step
            for j, r in enumerate(reqs):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(tok_np[j]))
                    self._mark_done(r)
            logits, cache = self._decode(params, tok, cache, self.rt)
            gen = jnp.asarray([len(r.out_tokens) for r in reqs], jnp.int32)
            tok = self._select(logits, keys, gen)

    def _decode_batch_chunked(self, params, reqs: list[Request],
                              logits, cache) -> None:
        """Segmented merge-path decode: the same compiled K-step loop as
        mixed waves, with a zero overlay (``delta=None``) and no
        admission (the grouped scheduler refills between batches)."""
        keys = decode_loop.row_keys(self.cfg.sampling.seed,
                                    [r.uid for r in reqs])
        tok = self._select(logits, keys, jnp.zeros((len(reqs),), jnp.int32))
        self._mark_first(reqs)
        launched = True
        while launched:
            tok, cache, _, launched = self._drive_chunk(
                params, None, None, tok, cache, reqs, keys)

    # ---------------- accounting ----------------

    def _scheduler_stats(self) -> dict:
        s = self._sched.stats() if self._sched is not None else {
            "policy": self.cfg.scheduler, "queue_depth_max": 0,
            "deferred": 0}
        s["admission_wait_s"] = {
            str(p): {"n": len(w), "mean": sum(w) / len(w), "max": max(w)}
            for p, w in sorted(self._adm_wait.items()) if w}
        return s

    def _kv_stats(self) -> dict:
        total = (self._kv_blocks - 1 if self.cfg.kv_layout == "paged"
                 else None)
        return {"layout": self.cfg.kv_layout,
                "block_size": self._bs,
                "blocks_total": total,
                "blocks_in_use": self._kv_in_use,
                "blocks_peak": self._kv_peak}

    def swap_summary(self) -> dict:
        s = self.cache.stats.as_dict()
        s["n_swaps"] = len(self.swap_log)
        s["swap_seconds"] = sum(x["seconds"] for x in self.swap_log)
        s["n_waves"] = len(self.wave_log)
        s["admitted"] = sum(x["admitted"] for x in self.wave_log)
        s["failed"] = self.failed_total
        s["log_dropped"] = dict(self._log_dropped)
        hits = s.get("stack_hits", 0)
        builds = s.get("stack_builds", 0)
        s["stack_hit_rate"] = hits / max(hits + builds, 1)
        s["scheduler"] = self._scheduler_stats()
        s["kv"] = self._kv_stats()
        if self.mesh is not None:
            s["mesh"] = dict(self.mesh.shape)
            s["shards"] = self.cache.shard_summary()
        return s

    def _export_gauges(self) -> None:
        """Publish serving gauges onto the device cache so
        ``registry.health()`` surfaces them next to swap/straggler state."""
        s = self.cache.stats
        hits = getattr(s, "stack_hits", 0)
        builds = getattr(s, "stack_builds", 0)
        self.cache.gauges = {
            "stack_hit_rate": hits / max(hits + builds, 1),
            "scheduler": self._scheduler_stats(),
            "kv": self._kv_stats(),
        }
        if self.mesh is not None:
            self.cache.gauges["shards"] = self.cache.shard_summary()
