"""Deterministic, stateless synthetic data pipeline.

Every batch is a pure function of (task_id, step) via JAX PRNG folding —
no iterator state.  This is the property that makes checkpoint/restart and
elastic re-sharding *exact*: a restarted (or re-sized) job regenerates the
identical token stream from the step counter alone.

The generator is an order-1 latent Markov chain per task: learnable (loss
drops quickly at 100M scale) but non-degenerate, and different ``task_id``s
give genuinely different conditionals — the substrate for training distinct
experts for the merging / LoraHub benchmarks.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    task_id: int = 0
    latent_vocab: int = 64   # chain runs on a small alphabet mapped into vocab
    noise: float = 0.1


def _chain_params(task_id: int, latent: int):
    rng = np.random.default_rng(1234 + task_id)
    a = int(rng.integers(1, latent))
    c = int(rng.integers(0, latent))
    perm = rng.permutation(latent)
    return a | 1, c, jnp.asarray(perm, jnp.int32)  # odd multiplier


def sample_tokens(key: jax.Array, dcfg: DataConfig) -> jax.Array:
    """[B, T+1] tokens of the task's Markov chain (stateless).

    task_id >= 100: "mixture task" — each batch row follows one of the
    base tasks 1..3 (row i -> task 1 + i%3).  These are the unseen tasks
    for the LoraHub compositional-generalization benchmark: solvable by
    composing the base experts, not by any single one.
    """
    if dcfg.task_id >= 100:
        import dataclasses as _dc
        subs = [sample_tokens(jax.random.fold_in(key, t),
                              _dc.replace(dcfg, task_id=t))
                for t in (1, 2, 3)]                     # three base chains
        stack = jnp.stack(subs)                        # [3, B, T+1]
        rows = jnp.arange(dcfg.global_batch)
        return stack[rows % 3, rows]                   # row i -> task 1+i%3
    a, c, perm = _chain_params(dcfg.task_id, dcfg.latent_vocab)
    L = dcfg.latent_vocab
    B, T = dcfg.global_batch, dcfg.seq_len
    k0, k1 = jax.random.split(key)
    x0 = jax.random.randint(k0, (B,), 0, L)
    noise_keys = jax.random.split(k1, T)

    def step(x, nk):
        flip = jax.random.bernoulli(nk, dcfg.noise, (B,))
        rnd = jax.random.randint(jax.random.fold_in(nk, 1), (B,), 0, L)
        nxt = jnp.where(flip, rnd, (a * x + c) % L)
        return nxt, nxt

    _, xs = jax.lax.scan(step, x0, noise_keys)
    seq = jnp.concatenate([x0[None], xs], axis=0).T  # [B, T+1]
    # map latent alphabet into the model vocab (spread tokens out)
    stride = max(1, dcfg.vocab // (2 * L))
    return (perm[seq] * stride + 1) % dcfg.vocab


def make_lm_batch(step: int, dcfg: DataConfig) -> dict:
    key = jax.random.fold_in(jax.random.PRNGKey(9000 + dcfg.task_id), step)
    toks = sample_tokens(key, dcfg)
    return {"tokens": toks[:, :-1].astype(jnp.int32),
            "targets": toks[:, 1:].astype(jnp.int32)}


def make_batch_for(cfg: ModelConfig, step: int, seq_len: int,
                   global_batch: int, task_id: int = 0) -> dict:
    """Family-aware batch builder (adds stub modality inputs)."""
    if cfg.frontend is not None:
        n_mod = cfg.frontend.n_tokens
        text_len = max(seq_len - n_mod, 1)
    else:
        n_mod, text_len = 0, seq_len
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=text_len,
                      global_batch=global_batch, task_id=task_id)
    batch = make_lm_batch(step, dcfg)
    if cfg.frontend is not None:
        key = jax.random.fold_in(jax.random.PRNGKey(77 + task_id), step)
        emb = jax.random.normal(
            key, (global_batch, n_mod, cfg.frontend.embed_dim), jnp.float32)
        if cfg.family == "audio":
            batch["frames"] = emb
        else:
            batch["mm_embeds"] = emb
    return batch


def eval_loss(api, params, rt, cfg: ModelConfig, task_id: int,
              n_batches: int = 2, seq_len: int = 64,
              global_batch: int = 8) -> float:
    """Deterministic held-out loss (steps 10_000+ are never trained on)."""
    tot = 0.0
    for i in range(n_batches):
        b = make_batch_for(cfg, 10_000 + i, seq_len, global_batch, task_id)
        loss, _ = api.loss_and_logits(params, b, rt)
        tot += float(loss)
    return tot / n_batches
