from repro.data.pipeline import (DataConfig, eval_loss, make_batch_for,
                                 make_lm_batch, sample_tokens)

__all__ = ["DataConfig", "eval_loss", "make_batch_for", "make_lm_batch",
           "sample_tokens"]
