"""ComPEFT (Algorithm 1): sparsify + ternary-quantize task vectors.

The paper's core contribution.  Given a task vector ``tau = theta_ft -
theta_init`` (a pytree of arrays), ComPEFT:

  1. decomposes ``tau`` into sign ``gamma = sgn(tau)`` and magnitude
     ``mu = |tau|``;
  2. keeps the signs of the top-``k`` fraction of entries by magnitude and
     zeroes the rest (``density = k``);
  3. replaces all surviving magnitudes with one scalar ``alpha * std(tau)``.

Everything here is pure JAX and jit-friendly.  Compression granularity is
configurable: per-tensor (default, matches the paper's per-module treatment)
or global (one threshold across the whole pytree).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Hyper-parameters of Algorithm 1.

    Attributes:
      density: fraction ``k`` of entries whose sign survives (paper sweeps
        {0.05, 0.1, 0.2, 0.3, 0.5}).
      alpha: scaling multiplier on ``std(tau)`` (paper sweeps
        {0.5, 1, 2, 3, 4, 5, 6, 8, 10}; alpha=1 recommended for >=13B).
      per_tensor: if True, top-k threshold and sigma are computed per leaf;
        if False, once over the concatenated vector (global).
      scale_mode: 'std' (paper), 'mean_abs' (STC-style, used by baselines),
        or 'none'.
    """

    density: float = 0.05
    alpha: float = 1.0
    per_tensor: bool = True
    scale_mode: str = "std"

    def __post_init__(self):
        if not (0.0 < self.density <= 1.0):
            raise ValueError(f"density must be in (0, 1], got {self.density}")
        if self.alpha <= 0.0:
            raise ValueError(f"alpha must be > 0, got {self.alpha}")
        if self.scale_mode not in ("std", "mean_abs", "none"):
            raise ValueError(f"unknown scale_mode {self.scale_mode!r}")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CompressedTensor:
    """One ComPEFT-compressed leaf: a ternary sign tensor and one scalar.

    ``signs`` is stored as int8 in {-1, 0, +1}; ``scale`` is the f32 scalar
    ``alpha * sigma(tau)``.  ``shape``/``dtype`` record the original leaf so
    decompression is exact.  The *packed* (bitplane) representation lives in
    :mod:`repro.core.packing`; this object is the device-compute-friendly
    form.
    """

    signs: jax.Array  # int8, original shape
    scale: jax.Array  # f32 scalar
    orig_dtype: Any = dataclasses.field(default=jnp.bfloat16)

    def tree_flatten(self):
        return (self.signs, self.scale), (self.orig_dtype,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        signs, scale = children
        return cls(signs=signs, scale=scale, orig_dtype=aux[0])

    @property
    def shape(self):
        return self.signs.shape

    @property
    def density(self):
        return jnp.mean(jnp.abs(self.signs).astype(jnp.float32))

    def decompress(self) -> jax.Array:
        return (self.signs.astype(jnp.float32) * self.scale).astype(self.orig_dtype)


def _topk_threshold(mag: jax.Array, density: float) -> jax.Array:
    """Magnitude cut-off such that ~density fraction of entries survive.

    Uses a quantile over the flattened magnitudes.  ``jnp.quantile`` is a
    sort-based exact implementation — fine for compression which runs once
    per expert, not per step.
    """
    q = jnp.clip(1.0 - density, 0.0, 1.0)
    return jnp.quantile(mag.reshape(-1).astype(jnp.float32), q)


def _scale_of(tau: jax.Array, mode: str) -> jax.Array:
    t = tau.astype(jnp.float32)
    if mode == "std":
        return jnp.std(t)
    if mode == "mean_abs":
        return jnp.mean(jnp.abs(t))
    return jnp.asarray(1.0, jnp.float32)


def compress_leaf(tau: jax.Array, cfg: CompressionConfig,
                  threshold: jax.Array | None = None,
                  scale: jax.Array | None = None) -> CompressedTensor:
    """Algorithm 1 on a single array."""
    mag = jnp.abs(tau.astype(jnp.float32))
    thr = _topk_threshold(mag, cfg.density) if threshold is None else threshold
    keep = mag >= thr
    signs = jnp.where(keep, jnp.sign(tau.astype(jnp.float32)), 0.0).astype(jnp.int8)
    sigma = _scale_of(tau, cfg.scale_mode) if scale is None else scale
    return CompressedTensor(
        signs=signs,
        scale=jnp.asarray(cfg.alpha, jnp.float32) * sigma,
        orig_dtype=tau.dtype,
    )


def compress(tau: PyTree, cfg: CompressionConfig | None = None) -> PyTree:
    """Apply Algorithm 1 over a pytree of task-vector leaves.

    Returns a pytree with the same structure whose leaves are
    :class:`CompressedTensor`.
    """
    cfg = cfg or CompressionConfig()
    leaves, treedef = jax.tree_util.tree_flatten(tau)
    if cfg.per_tensor:
        out = [compress_leaf(l, cfg) for l in leaves]
    else:
        flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
        thr = _topk_threshold(jnp.abs(flat), cfg.density)
        sigma = _scale_of(flat, cfg.scale_mode)
        out = [compress_leaf(l, cfg, threshold=thr, scale=sigma) for l in leaves]
    return jax.tree_util.tree_unflatten(treedef, out)


def decompress(compressed: PyTree) -> PyTree:
    """Inverse map back to dense task-vector leaves."""
    return jax.tree_util.tree_map(
        lambda c: c.decompress(),
        compressed,
        is_leaf=lambda x: isinstance(x, CompressedTensor),
    )


def apply_compressed(theta_init: PyTree, compressed: PyTree) -> PyTree:
    """Reconstruct expert parameters: ``theta = theta_init + tau_tilde``."""
    return jax.tree_util.tree_map(
        lambda w, c: (w.astype(jnp.float32)
                      + c.signs.astype(jnp.float32) * c.scale).astype(w.dtype),
        theta_init,
        compressed,
        is_leaf=lambda x: isinstance(x, CompressedTensor),
    )


# ---------------------------------------------------------------------------
# Streaming compression: one batched pass over all leaves (perf fast path)
# ---------------------------------------------------------------------------

STREAM_COLS = 8192  # segment-buffer row width; multiple of the pack kernel's
                    # 32-bit lane and of its default 512-column block


def _build_segment_buffer(leaves, cols: int):
    """Concatenate flattened leaves into a padded [R, cols] buffer.

    Each leaf is padded to a whole number of rows so every row belongs to
    exactly one leaf (segment); that is what lets one kernel launch carry
    per-leaf thresholds as a per-row vector.  Returns the buffer plus the
    row->segment map, per-row valid counts, per-segment element counts and
    each leaf's (row_start, row_end).
    """
    chunks, row_seg, row_valid, spans = [], [], [], []
    r = 0
    for i, leaf in enumerate(leaves):
        n = int(np.prod(leaf.shape))
        rows = -(-n // cols)
        flat = leaf.reshape(-1).astype(jnp.float32)
        pad = rows * cols - n
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
        chunks.append(flat.reshape(rows, cols))
        row_seg.append(np.full(rows, i, np.int32))
        valid = np.full(rows, cols, np.int32)
        valid[-1] = n - (rows - 1) * cols
        row_valid.append(valid)
        spans.append((r, r + rows))
        r += rows
    buf = jnp.concatenate(chunks) if len(chunks) > 1 else chunks[0]
    return (buf, jnp.asarray(np.concatenate(row_seg)),
            jnp.asarray(np.concatenate(row_valid)),
            jnp.asarray([int(np.prod(l.shape)) for l in leaves], jnp.int32),
            spans)


def compress_packed(tau: PyTree, cfg: CompressionConfig | None = None, *,
                    cols: int = STREAM_COLS,
                    return_stats: bool = False) -> PyTree:
    """Algorithm 1 straight to packed bitplanes, in one streaming pipeline.

    Replaces the per-leaf ``jnp.quantile`` + sign + pack loop (one sort and
    ~5 dispatches per leaf) with: (1) a two-pass O(n) histogram quantile
    over a single segment buffer holding every leaf, which also yields the
    std/mean_abs scale for free, and (2) one batched threshold+sign+pack
    launch with per-row thresholds.  Returns a pytree of
    :class:`~repro.core.packing.PackedTernary` (2 bits/param), the format
    the serving cache keeps resident and the merge kernels consume.
    """
    from repro.core.packing import LANE, PackedTernary
    from repro.kernels.histogram_quantile import segmented_quantile_moments
    from repro.kernels.ops import INTERPRET
    from repro.kernels.pack import (pack_ternary_planes_segmented,
                                    pack_ternary_planes_segmented_ref)

    cfg = cfg or CompressionConfig()
    leaves, treedef = jax.tree_util.tree_flatten(tau)
    if not leaves:
        return jax.tree_util.tree_unflatten(treedef, [])
    buf, row_seg, row_valid, seg_count, spans = _build_segment_buffer(
        leaves, cols)

    if cfg.per_tensor:
        n_seg, seg_ids = len(leaves), row_seg
    else:       # one global threshold/scale over the concatenated vector
        n_seg, seg_ids = 1, jnp.zeros_like(row_seg)
        seg_count = jnp.sum(seg_count, keepdims=True)
    stats = segmented_quantile_moments(
        buf, seg_ids, row_valid, seg_count, cfg.density, n_seg=n_seg,
        interpret=INTERPRET)

    if cfg.scale_mode == "std":
        sigma = stats["std"]
    elif cfg.scale_mode == "mean_abs":
        sigma = stats["mean_abs"]
    else:
        sigma = jnp.ones((n_seg,), jnp.float32)
    scales = jnp.asarray(cfg.alpha, jnp.float32) * sigma

    thr_rows = stats["threshold"][seg_ids]
    if INTERPRET:   # vectorised jnp mirror: same math, no interpreter tax
        pos, neg = pack_ternary_planes_segmented_ref(buf, thr_rows)
    else:
        pos, neg = pack_ternary_planes_segmented(buf, thr_rows,
                                                 interpret=False)

    out = []
    for i, leaf in enumerate(leaves):
        n = int(np.prod(leaf.shape))
        nw = -(-n // LANE)
        r0, r1 = spans[i]
        s = 0 if not cfg.per_tensor else i
        out.append(PackedTernary(
            pos=pos[r0:r1].reshape(-1)[:nw],
            neg=neg[r0:r1].reshape(-1)[:nw],
            scale=scales[s],
            shape=tuple(leaf.shape),
            orig_dtype=leaf.dtype,
        ))
    packed = jax.tree_util.tree_unflatten(treedef, out)
    if return_stats:
        return packed, stats
    return packed


# ---------------------------------------------------------------------------
# Alpha calibration (§2.1: "alpha is the only parameter tuned")
# ---------------------------------------------------------------------------

ALPHA_GRID = (0.5, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 10.0)
DENSITY_GRID = (0.05, 0.1, 0.2, 0.3, 0.5)


def rescale(compressed: PyTree, old_alpha: float, new_alpha: float) -> PyTree:
    """Cheaply retarget a compressed tree to a different alpha (scales only)."""
    r = new_alpha / old_alpha

    def f(c: CompressedTensor) -> CompressedTensor:
        return CompressedTensor(signs=c.signs, scale=c.scale * r,
                                orig_dtype=c.orig_dtype)

    return jax.tree_util.tree_map(
        f, compressed, is_leaf=lambda x: isinstance(x, CompressedTensor))


def calibrate_alpha(
    tau: PyTree,
    eval_fn: Callable[[PyTree], float],
    density: float,
    alpha_grid: tuple[float, ...] = ALPHA_GRID,
    per_tensor: bool = True,
) -> tuple[float, float, PyTree]:
    """Grid-search alpha on a validation metric (higher is better).

    ``eval_fn`` maps a *reconstructed task vector* (dense pytree) to a score.
    Signs/threshold are computed once; only the scalar is swept — this is
    exactly the cheap knob the paper exploits.

    Returns (best_alpha, best_score, best_compressed_tree).
    """
    base = compress(tau, CompressionConfig(density=density, alpha=1.0,
                                           per_tensor=per_tensor))
    best = (None, -np.inf, None)
    for a in alpha_grid:
        cand = rescale(base, 1.0, a)
        score = float(eval_fn(decompress(cand)))
        if score > best[1]:
            best = (a, score, cand)
    return best


def compression_summary(tau: PyTree, compressed: PyTree) -> dict:
    """Diagnostics: density achieved, reconstruction stats, bit accounting."""
    from repro.core import packing  # local import to avoid cycle

    taus = jax.tree_util.tree_leaves(tau)
    comps = jax.tree_util.tree_leaves(
        compressed, is_leaf=lambda x: isinstance(x, CompressedTensor))
    n = sum(int(np.prod(t.shape)) for t in taus)
    nnz = sum(int(jnp.sum(jnp.abs(c.signs).astype(jnp.int32))) for c in comps)
    dense_bits = 16 * n
    ent_bits = sum(
        packing.entropy_bits(int(np.prod(c.shape)),
                             float(jnp.mean(jnp.abs(c.signs).astype(jnp.float32))))
        for c in comps)
    bitplane_bits = sum(2 * int(np.prod(c.shape)) + 16 for c in comps)
    err = 0.0
    for t, c in zip(taus, comps):
        d = c.decompress().astype(jnp.float32) - t.astype(jnp.float32)
        err += float(jnp.sum(d * d))
    norm = sum(float(jnp.sum(t.astype(jnp.float32) ** 2)) for t in taus)
    return {
        "n_params": n,
        "nnz": nnz,
        "density": nnz / max(n, 1),
        "dense_bits": dense_bits,
        "entropy_bits": ent_bits,
        "bitplane_bits": bitplane_bits,
        "compression_x_entropy": dense_bits / max(ent_bits, 1e-9),
        "compression_x_bitplane": dense_bits / max(bitplane_bits, 1),
        "rel_recon_err": float(np.sqrt(err / max(norm, 1e-30))),
    }
