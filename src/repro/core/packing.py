"""Bit-level representations of ternary task vectors (§2.2 of the paper).

Two on-the-wire formats:

* **Bitplane pair** (compute-friendly): two packed ``uint32`` planes, one for
  +1 positions, one for -1 positions, plus the f32 scale.  2 bits/param; this
  is the format the Pallas kernels consume directly.
* **Golomb** (storage-optimal): see :mod:`repro.core.golomb` — host-side codec
  over the run lengths between non-zeros.

Also: entropy accounting used for every storage number we report, matching
the paper's formula ``H = -((1-k)log2(1-k) + k log2(k/2)) * d + 16`` bits.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compeft import CompressedTensor

LANE = 32  # uint32 bit lanes (TPU VPU native word)


def entropy_bits(d: int, k: float) -> float:
    """Paper §2.2: entropy of a d-dim ternary vector with density k, +16 for
    the scalar."""
    if k <= 0.0:
        return 16.0
    if k >= 1.0:
        return float(d) + 16.0  # signs only: 1 bit each
    h = -((1.0 - k) * math.log2(1.0 - k) + k * math.log2(k / 2.0))
    return h * d + 16.0


def golomb_bits_per_position(k: float) -> float:
    """Paper footnote 2: average Golomb bits per *non-zero* position.

    b* = 1 + floor(log2(log(phi - 1)/log(1 - p)));  phi = golden ratio.
    bbar = b* + 1 / (1 - (1-p)^(2^b*)).
    """
    p = min(max(k, 1e-12), 1 - 1e-12)
    phi = (math.sqrt(5.0) + 1.0) / 2.0
    b_star = 1 + int(math.floor(math.log2(math.log(phi - 1.0) / math.log(1.0 - p))))
    b_star = max(b_star, 1)
    bbar = b_star + 1.0 / (1.0 - (1.0 - p) ** (2 ** b_star))
    return bbar


def golomb_total_bits(d: int, k: float) -> float:
    """Total Golomb-coded size: positions + 1 sign bit per nnz + 16-bit scale."""
    nnz = k * d
    return nnz * (golomb_bits_per_position(k) + 1.0) + 16.0


# ---------------------------------------------------------------------------
# Bitplane pack / unpack (pure jnp reference; Pallas kernel mirrors this)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedTernary:
    """Packed bitplane form of one compressed leaf.

    ``pos``/``neg`` are uint32 arrays of shape ``(ceil(n/32),)`` over the
    flattened original tensor (C order).  Bit ``i % 32`` of word ``i // 32``
    is set iff element ``i`` is +1 (resp. -1).
    """

    pos: jax.Array
    neg: jax.Array
    scale: jax.Array
    shape: tuple[int, ...] = ()
    orig_dtype: Any = jnp.bfloat16

    def tree_flatten(self):
        return (self.pos, self.neg, self.scale), (self.shape, self.orig_dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        pos, neg, scale = children
        return cls(pos=pos, neg=neg, scale=scale, shape=aux[0], orig_dtype=aux[1])

    @property
    def n_elements(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 0

    @property
    def packed_bytes(self) -> int:
        return int(self.pos.size + self.neg.size) * 4 + 4


def _pad_to_lanes(flat: jax.Array) -> jax.Array:
    n = flat.shape[0]
    pad = (-n) % LANE
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat


def pack_bits(mask: jax.Array) -> jax.Array:
    """Pack a flat boolean/0-1 int array into uint32 words (little-endian bits)."""
    flat = _pad_to_lanes(mask.reshape(-1).astype(jnp.uint32))
    lanes = flat.reshape(-1, LANE)
    weights = (jnp.uint32(1) << jnp.arange(LANE, dtype=jnp.uint32))
    return jnp.sum(lanes * weights[None, :], axis=1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`pack_bits` -> int32 0/1 array of length n."""
    bits = (words[:, None] >> jnp.arange(LANE, dtype=jnp.uint32)[None, :]) & jnp.uint32(1)
    return bits.reshape(-1)[:n].astype(jnp.int32)


def pack_ternary(ct: CompressedTensor) -> PackedTernary:
    flat = ct.signs.reshape(-1)
    return PackedTernary(
        pos=pack_bits(flat == 1),
        neg=pack_bits(flat == -1),
        scale=ct.scale,
        shape=tuple(ct.signs.shape),
        orig_dtype=ct.orig_dtype,
    )


def unpack_ternary(pt: PackedTernary) -> CompressedTensor:
    n = pt.n_elements
    signs = (unpack_bits(pt.pos, n) - unpack_bits(pt.neg, n)).astype(jnp.int8)
    return CompressedTensor(signs=signs.reshape(pt.shape), scale=pt.scale,
                            orig_dtype=pt.orig_dtype)


def pack_tree(compressed: Any) -> Any:
    return jax.tree_util.tree_map(
        pack_ternary, compressed,
        is_leaf=lambda x: isinstance(x, CompressedTensor))


def unpack_tree(packed: Any) -> Any:
    return jax.tree_util.tree_map(
        unpack_ternary, packed,
        is_leaf=lambda x: isinstance(x, PackedTernary))


def tree_packed_bytes(packed: Any) -> int:
    leaves = jax.tree_util.tree_leaves(
        packed, is_leaf=lambda x: isinstance(x, PackedTernary))
    return sum(l.packed_bytes for l in leaves)


def signs_np(pt: PackedTernary) -> np.ndarray:
    """Host int8 {-1,0,1} signs of a PackedTernary, flat C-order.

    Pure numpy bit unpack (no jax dispatch) — the bridge from the packed
    device format to host-side codecs (Golomb export) and inspection.
    """
    n = pt.n_elements
    pos = np.asarray(jax.device_get(pt.pos)).view(np.uint8)
    neg = np.asarray(jax.device_get(pt.neg)).view(np.uint8)
    pb = np.unpackbits(pos, bitorder="little")[:n]
    nb = np.unpackbits(neg, bitorder="little")[:n]
    return pb.astype(np.int8) - nb.astype(np.int8)


def stack_packed(experts: list[dict]) -> dict:
    """Stack E experts' {path: PackedTernary} dicts into per-path buffers.

    Returns {path: (pos [E, W], neg [E, W], scales [E], shape)} — the
    device-resident form the batched serving kernels consume (one stacked
    buffer per leaf instead of E scattered plane pairs).  Experts missing a
    path contribute an all-zero plane pair with scale 0, so ragged expert
    leaf-sets stack fine.
    """
    paths: dict[str, tuple] = {}
    for ex in experts:
        for path, pt in ex.items():
            paths.setdefault(path, (pt.pos.size, tuple(pt.shape)))
    stacks = {}
    for path, (n_words, shape) in paths.items():
        pos, neg, scales = [], [], []
        for ex in experts:
            pt = ex.get(path)
            if pt is None:
                z = jnp.zeros((n_words,), jnp.uint32)
                pos.append(z)
                neg.append(z)
                scales.append(jnp.zeros((), jnp.float32))
            else:
                assert tuple(pt.shape) == shape, (path, pt.shape, shape)
                pos.append(pt.pos.reshape(-1))
                neg.append(pt.neg.reshape(-1))
                scales.append(pt.scale.astype(jnp.float32))
        stacks[path] = (jnp.stack(pos), jnp.stack(neg), jnp.stack(scales),
                        shape)
    return stacks


def stacked_bytes(stacks: dict) -> int:
    return sum(int(p.size + n.size) * 4 + 4 * int(s.size)
               for p, n, s, _ in stacks.values())
