"""ComPEFT-style ternary gradient compression for cross-pod data parallelism.

The paper's method descends from federated-learning compressors (STC,
TernGrad — §5).  We close the loop: the same sparsify+ternarize+scale
transform compresses the *cross-pod* gradient exchange during training,
with error feedback so the compression bias does not accumulate.

Topology: within a pod, gradients are reduced dense over the ``data`` axis
(fast ICI).  Across pods (slow DCI links), each pod ternarizes its
pod-local mean gradient, packs it into two uint32 bitplanes (2 bits/param
vs 32) + one f32 scale, all-gathers the *packed* planes over the ``pod``
axis, and decompresses+averages locally.  Error feedback keeps the residual
``e_t = g_t - decompress(compress(g_t))`` and adds it to the next step's
gradient (EF-SGD; Karimireddy et al. 2019).

Everything is jit-compatible and runs inside ``shard_map`` in the train
step.  Thresholding uses a Gaussian-quantile approximation (cheap,
O(n)) rather than an exact sort — gradients are near-Gaussian (paper
App. B.4/B.5), and EF absorbs the approximation error.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.scipy.special import erfinv

PyTree = Any
LANE = 32


@dataclasses.dataclass(frozen=True)
class GradCompressionConfig:
    density: float = 0.05          # fraction of entries kept per tensor
    enabled: bool = True
    exact_threshold: bool = False  # True: jnp.quantile (sort); False: Gaussian approx


def gaussian_topk_threshold(x: jax.Array, density: float) -> jax.Array:
    """|x| cut-off keeping ~density of entries assuming x ~ N(mu, sigma).

    For centred Gaussians P(|x| > t) = k  =>  t = sigma * sqrt(2) * erfinv(1-k).
    """
    sigma = jnp.std(x) + 1e-12
    t = jnp.sqrt(2.0) * erfinv(jnp.asarray(1.0 - density, x.dtype))
    return sigma * t


def _threshold(x: jax.Array, cfg: GradCompressionConfig) -> jax.Array:
    if cfg.exact_threshold:
        return jnp.quantile(jnp.abs(x).reshape(-1), 1.0 - cfg.density)
    return gaussian_topk_threshold(x, cfg.density)


def _pack_planes(signs: jax.Array) -> tuple[jax.Array, jax.Array]:
    """{-1,0,1} values -> two uint32 planes packed along the LAST axis only.

    Shape [..., L] -> [..., ceil(L/32)].  Leading dims are untouched so a
    GSPMD-sharded gradient leaf keeps its sharding through pack/exchange/
    unpack — flattening the whole leaf would force XLA to replicate
    multi-GiB gradients on every device."""
    L = signs.shape[-1]
    pad = (-L) % LANE
    s = signs
    if pad:
        s = jnp.pad(s, [(0, 0)] * (s.ndim - 1) + [(0, pad)])
    lanes = s.reshape(s.shape[:-1] + (-1, LANE))
    w = (jnp.uint32(1) << jnp.arange(LANE, dtype=jnp.uint32))
    pos = jnp.sum(jnp.where(lanes > 0, w, jnp.uint32(0)), axis=-1,
                  dtype=jnp.uint32)
    neg = jnp.sum(jnp.where(lanes < 0, w, jnp.uint32(0)), axis=-1,
                  dtype=jnp.uint32)
    return pos, neg


def _unpack_planes(pos: jax.Array, neg: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`_pack_planes` -> f32 {-1,0,1} with last dim n."""
    shifts = jnp.arange(LANE, dtype=jnp.uint32)
    pb = ((pos[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)
    nb = ((neg[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)
    out = (pb - nb).reshape(pos.shape[:-1] + (-1,))
    return out[..., :n]


def compress_leaf_for_allgather(g: jax.Array, err: jax.Array,
                                cfg: GradCompressionConfig):
    """-> (pos_planes, neg_planes, scale, new_err). Shapes static under jit."""
    g32 = g.astype(jnp.float32) + err
    thr = _threshold(g32, cfg)
    mask = jnp.abs(g32) >= thr
    nnz = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    # STC scale: mean magnitude of survivors => unbiased-ish magnitude transport
    scale = jnp.sum(jnp.where(mask, jnp.abs(g32), 0.0)) / nnz
    signs = jnp.where(mask, jnp.sign(g32), 0.0).astype(jnp.int8)
    recon = signs.astype(jnp.float32) * scale
    new_err = g32 - recon
    pos, neg = _pack_planes(signs)
    return pos, neg, scale, new_err


def compressed_cross_pod_mean(grads: PyTree, errors: PyTree,
                              cfg: GradCompressionConfig,
                              axis_name: str = "pod") -> tuple[PyTree, PyTree]:
    """EF-ternary all-reduce(mean) over ``axis_name``; call inside shard_map.

    Returns (mean_grads, new_errors).  Collective payload per leaf:
    2 * ceil(n/32) uint32 words + 1 f32 — a 16x reduction vs f32 ring
    all-reduce, visible in the dry-run HLO as small all-gathers.
    """
    n_pods = lax.psum(1, axis_name)

    def leaf(g, e):
        n_last = g.shape[-1] if g.ndim else 1
        g2 = g if g.ndim else g.reshape(1)
        e2 = e if e.ndim else e.reshape(1)
        pos, neg, scale, new_err = compress_leaf_for_allgather(g2, e2, cfg)
        new_err = new_err.astype(e.dtype).reshape(e.shape)
        pos_all = lax.all_gather(pos, axis_name)      # [pods, ..., words]
        neg_all = lax.all_gather(neg, axis_name)
        scale_all = lax.all_gather(scale, axis_name)  # [pods]

        def body(p, acc):
            return acc + _unpack_planes(pos_all[p], neg_all[p],
                                        n_last) * scale_all[p]

        init = lax.pvary(jnp.zeros(g2.shape, jnp.float32), (axis_name,))
        acc = lax.fori_loop(0, n_pods, body, init)
        mean = (acc / n_pods).reshape(g.shape).astype(g.dtype)
        return mean, new_err

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    outs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return mean, new_err


def init_error_state(params: PyTree) -> PyTree:
    """Zero error-feedback accumulators (f32, same shapes as params)."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_ratio(cfg: GradCompressionConfig) -> float:
    """Wire bytes dense-f32 / compressed (ignoring the scalar)."""
    return 32.0 / 2.0 if cfg.enabled else 1.0
