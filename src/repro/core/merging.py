"""Model merging and composition over (compressed) task vectors.

Implements the paper's §3.6/§3.7 consumers of ComPEFT artifacts:

* **Task Arithmetic** (Ilharco et al. 2023): theta = theta_init + lam * sum(tau_i).
* **TIES-Merging** (Yadav et al. 2023): trim -> elect sign -> disjoint mean.
* **LoraHub composition** (Huang et al. 2023): element-wise weighted sum of
  LoRA A/B factors with weights learned by a gradient-free optimizer on
  few-shot data (we implement the (1+1)-ES / random-search hybrid standing in
  for Shiwa, which is a Nevergrad ensemble).

All functions accept dense pytrees; ``merge_packed`` is the fast path that
runs Task Arithmetic directly on packed ternary bitplanes using the bitwise
algebra from ternary_ops (the paper's "faster merging" claim).
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compeft import CompressedTensor
from repro.core.packing import PackedTernary, unpack_ternary

PyTree = Any


def task_arithmetic(taus: Sequence[PyTree], lam: float = 1.0) -> PyTree:
    """theta_delta = lam * sum_i tau_i."""
    def add(*ls):
        acc = ls[0].astype(jnp.float32)
        for l in ls[1:]:
            acc = acc + l.astype(jnp.float32)
        return (lam * acc).astype(ls[0].dtype)
    return jax.tree_util.tree_map(add, *taus)


def ties_merge(taus: Sequence[PyTree], density: float = 0.2,
               lam: float = 1.0) -> PyTree:
    """TIES: (1) trim to top-k magnitude per task, (2) elect majority sign by
    summed magnitude, (3) mean over entries agreeing with the elected sign."""
    from repro.core.compeft import _topk_threshold

    def merge_leaf(*ls):
        trimmed = []
        for t in ls:
            t32 = t.astype(jnp.float32)
            thr = _topk_threshold(jnp.abs(t32), density)
            trimmed.append(jnp.where(jnp.abs(t32) >= thr, t32, 0.0))
        stack = jnp.stack(trimmed)                      # [T, ...]
        elected = jnp.sign(jnp.sum(stack, axis=0))      # majority by mass
        agree = (jnp.sign(stack) == elected[None]) & (stack != 0.0)
        num = jnp.sum(jnp.where(agree, stack, 0.0), axis=0)
        den = jnp.maximum(jnp.sum(agree.astype(jnp.float32), axis=0), 1.0)
        return (lam * num / den).astype(ls[0].dtype)

    return jax.tree_util.tree_map(merge_leaf, *taus)


def merge_experts(experts: Sequence[Any], method: str = "auto",
                  lam: float = 1.0, density: float = 0.2) -> PyTree:
    """Representation-aware merging over :class:`repro.expert.Expert`
    artifacts (or raw task-vector / packed trees).

    Dispatch:

    * ``"task_arithmetic"`` — dense Task Arithmetic (Ilharco et al. 2023);
      Experts contribute their ternary reconstruction ``tau_tilde`` (the
      artifact is what merges — paper §3.6).
    * ``"ties"`` — TIES-Merging (trim -> elect sign -> disjoint mean) on
      dense trees; ``density`` is the TIES trim fraction.
    * ``"packed"`` — Task Arithmetic straight on the ternary bitplanes
      (:func:`merge_packed`, the paper's "faster merging" claim), no full
      decompression.
    * ``"auto"`` — ``"packed"`` when every input is already packed-resident
      (an Expert holding only compressed forms, or a PackedTernary tree),
      else dense ``"task_arithmetic"``.

    Returns a dense task-vector pytree (what every consumer — apply /
    re-compress / eval — takes).
    """
    from repro.expert import DENSE, PACKED, Expert, as_expert

    # normalize legacy ExpertArtifact inputs (anything carrying .packed)
    experts = [as_expert(e) if (not isinstance(e, Expert)
                                and hasattr(e, "packed")) else e
               for e in experts]

    def is_packed_resident(e):
        if isinstance(e, Expert):
            return PACKED in e.available() and DENSE not in e.available()
        leaves = jax.tree_util.tree_leaves(
            e, is_leaf=lambda x: isinstance(x, PackedTernary))
        return bool(leaves) and all(isinstance(l, PackedTernary)
                                    for l in leaves)

    if method == "auto":
        method = ("packed" if all(is_packed_resident(e) for e in experts)
                  else "task_arithmetic")
    if method == "packed":
        packed = [e.as_(PACKED) if isinstance(e, Expert) else e
                  for e in experts]
        return merge_packed(packed, lam=lam)
    dense = [e.to_dense_tau() if isinstance(e, Expert) else e
             for e in experts]
    if method in ("task_arithmetic", "ta"):
        return task_arithmetic(dense, lam=lam)
    if method == "ties":
        return ties_merge(dense, density=density, lam=lam)
    raise ValueError(f"unknown merge method {method!r}; choose "
                     "task_arithmetic | ties | packed | auto")


def merge_packed(packed_taus: Sequence[PyTree], lam: float = 1.0) -> PyTree:
    """Task Arithmetic over *packed* ternary trees without full decompression.

    Each leaf result: lam * sum_i scale_i * (pos_i - neg_i), accumulated in
    int16 sign-sums per distinct scale then combined — integer adds on
    unpacked planes, no float matrix materialisation until the end.
    """
    def merge_leaf(*pts: PackedTernary):
        acc = None
        for p in pts:
            s = unpack_ternary(p)
            contrib = s.signs.astype(jnp.float32) * p.scale
            acc = contrib if acc is None else acc + contrib
        return (lam * acc).astype(pts[0].orig_dtype).reshape(pts[0].shape)

    return jax.tree_util.tree_map(
        merge_leaf, *packed_taus,
        is_leaf=lambda x: isinstance(x, PackedTernary))


# ---------------------------------------------------------------------------
# LoraHub-style gradient-free composition
# ---------------------------------------------------------------------------


def compose_lora(modules: Sequence[PyTree], weights: jax.Array) -> PyTree:
    """L_m = (sum w_i A_i, sum w_i B_i) — eq. (1) of the paper."""
    def f(*ls):
        stack = jnp.stack([l.astype(jnp.float32) for l in ls])
        w = weights.reshape((-1,) + (1,) * (stack.ndim - 1))
        return jnp.sum(w * stack, axis=0).astype(ls[0].dtype)
    return jax.tree_util.tree_map(f, *modules)


def lorahub_search(
    modules: Sequence[PyTree],
    loss_fn: Callable[[PyTree], float],
    n_iters: int = 40,
    seed: int = 0,
    init_sigma: float = 0.35,
    l1_reg: float = 0.05,
) -> tuple[np.ndarray, float]:
    """Gradient-free weight search (stand-in for Nevergrad's Shiwa).

    (1+1)-ES with 1/5th-rule step adaptation + random restarts; minimises
    ``loss_fn(compose_lora(modules, w)) + l1_reg * |w|_1`` like LoraHub.
    Returns (best_weights, best_loss).
    """
    rng = np.random.default_rng(seed)
    n = len(modules)

    def total(w: np.ndarray) -> float:
        l = float(loss_fn(compose_lora(modules, jnp.asarray(w, jnp.float32))))
        return l + l1_reg * float(np.abs(w).sum())

    best_w = np.zeros((n,), np.float64)
    best_l = total(best_w)
    w, lcur, sigma = best_w.copy(), best_l, init_sigma
    for it in range(n_iters):
        cand = w + rng.normal(0.0, sigma, size=n)
        cand = np.clip(cand, -1.5, 1.5)
        lc = total(cand)
        if lc < lcur:
            w, lcur = cand, lc
            sigma *= 1.3
            if lc < best_l:
                best_w, best_l = cand.copy(), lc
        else:
            sigma *= 0.82
        if sigma < 1e-3:  # restart
            w = rng.normal(0.0, init_sigma, size=n)
            lcur = total(w)
            sigma = init_sigma
    return best_w, best_l


def pairwise_similarity_matrix(packed: Sequence[PyTree]) -> np.ndarray:
    """Expert-expert cosine similarity via popcount algebra (fast routing /
    dedup of an expert library)."""
    from repro.core.ternary_ops import cosine_similarity

    def tree_cos(a, b):
        la = jax.tree_util.tree_leaves(a, is_leaf=lambda x: isinstance(x, PackedTernary))
        lb = jax.tree_util.tree_leaves(b, is_leaf=lambda x: isinstance(x, PackedTernary))
        sims = [float(cosine_similarity(x, y)) for x, y in zip(la, lb)]
        return float(np.mean(sims))

    n = len(packed)
    m = np.eye(n)
    for i in range(n):
        for j in range(i + 1, n):
            m[i, j] = m[j, i] = tree_cos(packed[i], packed[j])
    return m
