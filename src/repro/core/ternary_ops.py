"""Bitwise algebra on packed ternary vectors (§2.2 "Efficient Computation").

The paper: with two binary masks per vector, dot products and distances
reduce to AND/XOR + POPCNT.  On TPU, ``lax.population_count`` runs on the
VPU over uint32 lanes (32 params/lane).  These are the pure-jnp versions;
:mod:`repro.kernels.popcount_dot` is the tiled Pallas variant.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compeft import CompressedTensor
from repro.core.packing import PackedTernary, pack_ternary, unpack_ternary


def _popcount_sum(words: jax.Array) -> jax.Array:
    return jnp.sum(lax.population_count(words).astype(jnp.int32))


def ternary_dot(a: PackedTernary, b: PackedTernary) -> jax.Array:
    """<a, b> for ternary a,b (excluding scales).

    positive contributions: (a+ & b+) | (a- & b-)
    negative contributions: (a+ & b-) | (a- & b+)
    dot = popcount(pos) - popcount(neg), then * scale_a * scale_b outside.
    """
    pp = _popcount_sum(a.pos & b.pos) + _popcount_sum(a.neg & b.neg)
    pn = _popcount_sum(a.pos & b.neg) + _popcount_sum(a.neg & b.pos)
    return (pp - pn).astype(jnp.float32)


def scaled_dot(a: PackedTernary, b: PackedTernary) -> jax.Array:
    return ternary_dot(a, b) * a.scale * b.scale


def hamming_distance(a: PackedTernary, b: PackedTernary) -> jax.Array:
    """# positions where the ternary values differ (paper: XOR + POPCNT).

    sign mismatch at a position iff (a+ xor b+) or (a- xor b-) is set there.
    """
    diff = (a.pos ^ b.pos) | (a.neg ^ b.neg)
    return _popcount_sum(diff).astype(jnp.int32)


def nnz(a: PackedTernary) -> jax.Array:
    return _popcount_sum(a.pos) + _popcount_sum(a.neg)


def cosine_similarity(a: PackedTernary, b: PackedTernary) -> jax.Array:
    num = ternary_dot(a, b)
    den = jnp.sqrt(nnz(a).astype(jnp.float32)) * jnp.sqrt(nnz(b).astype(jnp.float32))
    return num / jnp.maximum(den, 1e-9)


def ternary_add(a: PackedTernary, b: PackedTernary) -> CompressedTensor:
    """a + b in the *decompressed* ternary domain (values in scale units).

    Addition leaves the ternary lattice, so the result is a dense-but-cheap
    int16 sum times a common scale; used as the merge fast path
    (Task Arithmetic adds task vectors).  Scales must be combined by the
    caller (see merging.merge_packed).
    """
    sa = unpack_ternary(a).signs.astype(jnp.int16)
    sb = unpack_ternary(b).signs.astype(jnp.int16)
    return CompressedTensor(signs=(sa + sb).astype(jnp.int8), scale=a.scale,
                            orig_dtype=a.orig_dtype)


def sign_agreement(a: PackedTernary, b: PackedTernary) -> jax.Array:
    """Fraction of mutually-nonzero positions whose signs agree (TIES stat)."""
    both = (a.pos | a.neg) & (b.pos | b.neg)
    agree = (a.pos & b.pos) | (a.neg & b.neg)
    n_both = _popcount_sum(both).astype(jnp.float32)
    return _popcount_sum(agree).astype(jnp.float32) / jnp.maximum(n_both, 1.0)


def packed_matvec(p: PackedTernary, x: jax.Array) -> jax.Array:
    """y = scale * (signs.reshape(shape) @ x) computed from packed planes.

    Reference implementation (unpack then MXU matmul) — mirrors what the
    Pallas kernel does tile-by-tile without materialising the full matrix
    in HBM.
    """
    ct = unpack_ternary(p)
    w = ct.signs.astype(x.dtype).reshape(p.shape)
    return (w @ x) * p.scale.astype(x.dtype)
