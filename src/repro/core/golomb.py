"""Host-side Golomb-Rice codec for sparse ternary vectors (§2.2).

Encodes the *gaps* between consecutive non-zero positions with Golomb-Rice
coding (parameter ``b`` chosen per the paper's footnote-2 rule) plus one sign
bit per non-zero.  This is the storage/network format; the on-device format
is the bitplane pair in :mod:`repro.core.packing`.

Deliberately numpy-only: variable-length bitstreams are a host job (see
DESIGN.md §3 — porting branchy VLC decode to the TPU VPU would be a
degenerate port of a CPU algorithm).
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.packing import golomb_bits_per_position


class BitWriter:
    def __init__(self):
        self._bits: list[int] = []

    def write(self, bit: int) -> None:
        self._bits.append(bit & 1)

    def write_unary(self, q: int) -> None:
        self._bits.extend([1] * q)
        self._bits.append(0)

    def write_uint(self, v: int, nbits: int) -> None:
        for i in range(nbits):
            self._bits.append((v >> i) & 1)

    def getvalue(self) -> bytes:
        bits = np.array(self._bits, dtype=np.uint8)
        return np.packbits(bits, bitorder="little").tobytes()

    def __len__(self) -> int:  # number of bits
        return len(self._bits)


class BitReader:
    def __init__(self, data: bytes, nbits: int):
        arr = np.frombuffer(data, dtype=np.uint8)
        self._bits = np.unpackbits(arr, bitorder="little")[:nbits]
        self._pos = 0

    def read(self) -> int:
        b = int(self._bits[self._pos])
        self._pos += 1
        return b

    def read_unary(self) -> int:
        q = 0
        while self.read() == 1:
            q += 1
        return q

    def read_uint(self, nbits: int) -> int:
        v = 0
        for i in range(nbits):
            v |= self.read() << i
        return v


def rice_parameter(density: float) -> int:
    """Paper footnote 2: b* = 1 + floor(log2(log(phi-1)/log(1-p)))."""
    p = min(max(density, 1e-12), 1.0 - 1e-12)
    phi = (math.sqrt(5.0) + 1.0) / 2.0
    return max(1, 1 + int(math.floor(math.log2(math.log(phi - 1.0) / math.log(1.0 - p)))))


def encode(signs: np.ndarray, scale: float) -> bytes:
    """Encode an int8 {-1,0,1} array + f32 scale into a Golomb-Rice stream.

    Layout: [u64 n][u32 nnz][u8 b][f32 scale][payload bits...].
    """
    flat = np.asarray(signs, dtype=np.int8).reshape(-1)
    n = flat.size
    idx = np.nonzero(flat)[0]
    nnz = idx.size
    density = nnz / max(n, 1)
    b = rice_parameter(density if nnz else 0.5)
    m = 1 << b

    w = BitWriter()
    prev = -1
    for i in idx:
        gap = int(i - prev - 1)  # zeros skipped since last nnz
        q, r = divmod(gap, m)
        w.write_unary(q)
        w.write_uint(r, b)
        w.write(1 if flat[i] > 0 else 0)
        prev = int(i)

    header = (
        np.uint64(n).tobytes()
        + np.uint32(nnz).tobytes()
        + np.uint8(b).tobytes()
        + np.uint64(len(w)).tobytes()
        + np.float32(scale).tobytes()
    )
    return header + w.getvalue()


def decode(data: bytes) -> tuple[np.ndarray, float]:
    """Inverse of :func:`encode` -> (int8 signs, scale)."""
    n = int(np.frombuffer(data[0:8], np.uint64)[0])
    nnz = int(np.frombuffer(data[8:12], np.uint32)[0])
    b = int(np.frombuffer(data[12:13], np.uint8)[0])
    nbits = int(np.frombuffer(data[13:21], np.uint64)[0])
    scale = float(np.frombuffer(data[21:25], np.float32)[0])
    r = BitReader(data[25:], nbits)

    out = np.zeros((n,), dtype=np.int8)
    pos = -1
    m = 1 << b
    for _ in range(nnz):
        q = r.read_unary()
        rem = r.read_uint(b)
        gap = q * m + rem
        pos = pos + gap + 1
        out[pos] = 1 if r.read() == 1 else -1
    return out, scale


def encoded_bits(signs: np.ndarray) -> int:
    """Exact bit count of the payload (excl. fixed 25-byte header)."""
    flat = np.asarray(signs).reshape(-1)
    n = flat.size
    idx = np.nonzero(flat)[0]
    if idx.size == 0:
        return 0
    b = rice_parameter(idx.size / n)
    m = 1 << b
    gaps = np.diff(np.concatenate([[-1], idx])) - 1
    qs = gaps // m
    return int(np.sum(qs + 1 + b + 1))


def theoretical_bits_check(n: int, density: float) -> float:
    """Average-case payload bits predicted by the paper's formula."""
    return density * n * (golomb_bits_per_position(density) + 1.0)
