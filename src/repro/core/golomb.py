"""Host-side Golomb-Rice codec for sparse ternary vectors (§2.2).

Encodes the *gaps* between consecutive non-zero positions with Golomb-Rice
coding (parameter ``b`` chosen per the paper's footnote-2 rule) plus one sign
bit per non-zero.  This is the storage/network format; the on-device format
is the bitplane pair in :mod:`repro.core.packing`.

Deliberately numpy-only: variable-length bitstreams are a host job (see
DESIGN.md §3 — porting branchy VLC decode to the TPU VPU would be a
degenerate port of a CPU algorithm).  But "host job" does not mean
"per-bit Python loop": :func:`encode` and :func:`decode` are fully
vectorized.  Encode scatters the unary/remainder/sign bits of every
codeword at once from the cumulative codeword offsets; decode finds every
codeword's unary *terminator* zero-bit by pointer-doubling the "next zero
at least b+2 bits later" map (O(nnz log nnz) numpy gathers, no sequential
scan), then gathers remainders and signs in one shot.  The store→host
promotion path decodes all leaves of an expert this way
(:func:`decode_tree`).  ``encode_ref``/``decode_ref`` keep the bit-at-a-
time reference implementations as the format oracle.
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.packing import golomb_bits_per_position


class BitWriter:
    def __init__(self):
        self._bits: list[int] = []

    def write(self, bit: int) -> None:
        self._bits.append(bit & 1)

    def write_unary(self, q: int) -> None:
        self._bits.extend([1] * q)
        self._bits.append(0)

    def write_uint(self, v: int, nbits: int) -> None:
        for i in range(nbits):
            self._bits.append((v >> i) & 1)

    def getvalue(self) -> bytes:
        bits = np.array(self._bits, dtype=np.uint8)
        return np.packbits(bits, bitorder="little").tobytes()

    def __len__(self) -> int:  # number of bits
        return len(self._bits)


class BitReader:
    def __init__(self, data: bytes, nbits: int):
        arr = np.frombuffer(data, dtype=np.uint8)
        self._bits = np.unpackbits(arr, bitorder="little")[:nbits]
        self._pos = 0

    def read(self) -> int:
        b = int(self._bits[self._pos])
        self._pos += 1
        return b

    def read_unary(self) -> int:
        q = 0
        while self.read() == 1:
            q += 1
        return q

    def read_uint(self, nbits: int) -> int:
        v = 0
        for i in range(nbits):
            v |= self.read() << i
        return v


def rice_parameter(density: float) -> int:
    """Paper footnote 2: b* = 1 + floor(log2(log(phi-1)/log(1-p)))."""
    p = min(max(density, 1e-12), 1.0 - 1e-12)
    phi = (math.sqrt(5.0) + 1.0) / 2.0
    return max(1, 1 + int(math.floor(math.log2(math.log(phi - 1.0) / math.log(1.0 - p)))))


def _header(n: int, nnz: int, b: int, nbits: int, scale: float) -> bytes:
    return (np.uint64(n).tobytes() + np.uint32(nnz).tobytes()
            + np.uint8(b).tobytes() + np.uint64(nbits).tobytes()
            + np.float32(scale).tobytes())


def _parse_header(data: bytes):
    n = int(np.frombuffer(data[0:8], np.uint64)[0])
    nnz = int(np.frombuffer(data[8:12], np.uint32)[0])
    b = int(np.frombuffer(data[12:13], np.uint8)[0])
    nbits = int(np.frombuffer(data[13:21], np.uint64)[0])
    scale = float(np.frombuffer(data[21:25], np.float32)[0])
    return n, nnz, b, nbits, scale


def encode_ref(signs: np.ndarray, scale: float) -> bytes:
    """Bit-at-a-time reference encoder (format oracle for :func:`encode`)."""
    flat = np.asarray(signs, dtype=np.int8).reshape(-1)
    n = flat.size
    idx = np.nonzero(flat)[0]
    nnz = idx.size
    density = nnz / max(n, 1)
    b = rice_parameter(density if nnz else 0.5)
    m = 1 << b

    w = BitWriter()
    prev = -1
    for i in idx:
        gap = int(i - prev - 1)  # zeros skipped since last nnz
        q, r = divmod(gap, m)
        w.write_unary(q)
        w.write_uint(r, b)
        w.write(1 if flat[i] > 0 else 0)
        prev = int(i)

    return _header(n, nnz, b, len(w), scale) + w.getvalue()


def encode(signs: np.ndarray, scale: float) -> bytes:
    """Encode an int8 {-1,0,1} array + f32 scale into a Golomb-Rice stream.

    Layout: [u64 n][u32 nnz][u8 b][u64 nbits][f32 scale][payload bits...].
    Vectorized: all codewords' unary/remainder/sign bits are scattered in
    one numpy pass (byte-identical to :func:`encode_ref`).
    """
    flat = np.asarray(signs, dtype=np.int8).reshape(-1)
    n = flat.size
    idx = np.nonzero(flat)[0].astype(np.int64)
    nnz = idx.size
    density = nnz / max(n, 1)
    b = rice_parameter(density if nnz else 0.5)
    m = 1 << b
    if nnz == 0:
        return _header(n, 0, b, 0, scale)

    gaps = np.diff(np.concatenate([[-1], idx])) - 1
    q, r = np.divmod(gaps, m)
    lens = q + 1 + b + 1                       # unary + stop + fixed + sign
    starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
    total = int(lens.sum())
    bits = np.zeros(total, np.uint8)
    # unary ones: for codeword k, bits [starts_k, starts_k + q_k)
    run_of = np.repeat(np.arange(nnz), q)
    within = np.arange(int(q.sum())) - np.repeat(
        np.concatenate([[0], np.cumsum(q)[:-1]]), q)
    bits[starts[run_of] + within] = 1
    if b:
        rem_pos = (starts + q + 1)[:, None] + np.arange(b)[None, :]
        rem_bits = ((r[:, None] >> np.arange(b)[None, :]) & 1)
        bits[rem_pos.reshape(-1)] = rem_bits.reshape(-1).astype(np.uint8)
    bits[starts + q + 1 + b] = (flat[idx] > 0).astype(np.uint8)
    payload = np.packbits(bits, bitorder="little").tobytes()
    return _header(n, nnz, b, total, scale) + payload


def decode_ref(data: bytes) -> tuple[np.ndarray, float]:
    """Bit-at-a-time reference decoder (oracle for :func:`decode`)."""
    n, nnz, b, nbits, scale = _parse_header(data)
    r = BitReader(data[25:], nbits)

    out = np.zeros((n,), dtype=np.int8)
    pos = -1
    m = 1 << b
    for _ in range(nnz):
        q = r.read_unary()
        rem = r.read_uint(b)
        gap = q * m + rem
        pos = pos + gap + 1
        out[pos] = 1 if r.read() == 1 else -1
    return out, scale


def _iterates(g: np.ndarray, start: int, count: int) -> np.ndarray:
    """[start, g(start), g²(start), ...] via pointer doubling.

    O(count log count) gathers instead of a length-``count`` Python loop:
    with A = the first L iterates and J = g^L, the next L iterates are
    J[A] and J squares to g^(2L).
    """
    out = np.empty(count, np.int64)
    out[0] = start
    filled, jump = 1, g.astype(np.int64)
    while filled < count:
        take = min(filled, count - filled)
        out[filled:filled + take] = jump[out[:take]]
        filled += take
        if filled < count:
            jump = jump[jump]
    return out


def decode(data: bytes) -> tuple[np.ndarray, float]:
    """Inverse of :func:`encode` -> (int8 signs, scale).  Vectorized.

    Every Rice codeword is ``1^q 0 | r (b bits) | sign (1 bit)``, so each
    consumes exactly one *terminator* zero followed by b+1 payload bits.
    The map "z_i -> first zero >= z_i + b + 2" is static, so all nnz
    terminators fall out of pointer doubling; remainders and signs are then
    plain gathers, and positions a cumsum over the decoded gaps.
    """
    n, nnz, b, nbits, scale = _parse_header(data)
    out = np.zeros((n,), dtype=np.int8)
    if nnz == 0:
        return out, scale
    arr = np.frombuffer(data[25:], dtype=np.uint8)
    bits = np.unpackbits(arr, bitorder="little")[:nbits]   # stay uint8:
    m = 1 << b                                  # 1 byte/bit transient, not 8

    z = np.flatnonzero(bits == 0)
    g = np.minimum(np.searchsorted(z, z + b + 2), z.size - 1)
    term = z[_iterates(g, 0, nnz)]             # terminator bit positions
    starts = np.concatenate([[0], term[:-1] + b + 2])
    q = term - starts
    if b:
        rem_bits = bits[term[:, None] + 1 + np.arange(b)[None, :]]
        r = rem_bits.astype(np.int64) @ (1 << np.arange(b, dtype=np.int64))
    else:
        r = np.zeros(nnz, np.int64)
    sign_bits = bits[term + 1 + b]
    pos = np.cumsum(q * m + r + 1) - 1
    out[pos] = np.where(sign_bits == 1, 1, -1).astype(np.int8)
    return out, scale


def decode_tree(blobs: dict) -> dict:
    """Batched store→host decode: all leaves of an expert in one pass.

    blobs: {path: golomb bytes} -> {path: (int8 signs, scale)}.  Each leaf
    decodes through the vectorized :func:`decode`; the per-leaf Python work
    is O(1), not O(bits).
    """
    return {path: decode(blob) for path, blob in blobs.items()}


def encoded_bits(signs: np.ndarray) -> int:
    """Exact bit count of the payload (excl. fixed 25-byte header)."""
    flat = np.asarray(signs).reshape(-1)
    n = flat.size
    idx = np.nonzero(flat)[0]
    if idx.size == 0:
        return 0
    b = rice_parameter(idx.size / n)
    m = 1 << b
    gaps = np.diff(np.concatenate([[-1], idx])) - 1
    qs = gaps // m
    return int(np.sum(qs + 1 + b + 1))


def theoretical_bits_check(n: int, density: float) -> float:
    """Average-case payload bits predicted by the paper's formula."""
    return density * n * (golomb_bits_per_position(density) + 1.0)
