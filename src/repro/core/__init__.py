"""ComPEFT core: Algorithm 1, packed encodings, ternary algebra, merging,
baselines and the cross-pod gradient compressor."""

from repro.core.compeft import (ALPHA_GRID, DENSITY_GRID, CompressedTensor,
                                CompressionConfig, apply_compressed,
                                calibrate_alpha, compress, compress_leaf,
                                compress_packed, compression_summary,
                                decompress, rescale)
from repro.core.packing import (PackedTernary, entropy_bits,
                                golomb_bits_per_position, golomb_total_bits,
                                pack_bits, pack_ternary, pack_tree,
                                tree_packed_bytes, unpack_bits, unpack_ternary,
                                unpack_tree)

__all__ = [
    "ALPHA_GRID", "DENSITY_GRID", "CompressedTensor", "CompressionConfig",
    "apply_compressed", "calibrate_alpha", "compress", "compress_leaf",
    "compress_packed", "compression_summary", "decompress", "rescale",
    "PackedTernary",
    "entropy_bits", "golomb_bits_per_position", "golomb_total_bits",
    "pack_bits", "pack_ternary", "pack_tree", "tree_packed_bytes",
    "unpack_bits", "unpack_ternary", "unpack_tree",
]
