"""Compression baselines the paper compares against (§4.1, App. C.1).

* ``pruned``   — sparsify only; surviving entries keep their magnitudes.
* ``stc``      — Sparse Ternary Compression (Sattler et al. 2019): top-k +
                 ternary with the *mean magnitude of survivors* as scale
                 (no tuned alpha).
* ``bitdelta`` — sign of every entry (density 1.0), scale = mean |tau|
                 ("No Training" variant of Liu et al. 2024).
* ``dare``     — DARE(-x) random dropping with 1/(1-p) rescale of survivors
                 (Yu et al. 2023 / Deng et al. 2024).

All return dense task-vector pytrees of the original dtype so they can be
evaluated through the identical pipeline as ComPEFT.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.compeft import (CompressedTensor, CompressionConfig,
                                _topk_threshold, compress)

PyTree = Any


def pruned(tau: PyTree, density: float) -> PyTree:
    """Top-k magnitude pruning, magnitudes kept (paper's 'Pruned' ablation)."""

    def f(t):
        mag = jnp.abs(t.astype(jnp.float32))
        thr = _topk_threshold(mag, density)
        return jnp.where(mag >= thr, t.astype(jnp.float32), 0.0).astype(t.dtype)

    return jax.tree_util.tree_map(f, tau)


def stc(tau: PyTree, density: float) -> PyTree:
    """Sparse Ternary Compression: scale = mean |survivors| (no alpha tune)."""

    def f(t):
        t32 = t.astype(jnp.float32)
        mag = jnp.abs(t32)
        thr = _topk_threshold(mag, density)
        keep = mag >= thr
        n_keep = jnp.maximum(jnp.sum(keep.astype(jnp.float32)), 1.0)
        scale = jnp.sum(jnp.where(keep, mag, 0.0)) / n_keep
        return (jnp.where(keep, jnp.sign(t32), 0.0) * scale).astype(t.dtype)

    return jax.tree_util.tree_map(f, tau)


def bitdelta(tau: PyTree) -> PyTree:
    """Sign of every entry, scale = mean |tau| per tensor (density 1)."""

    def f(t):
        t32 = t.astype(jnp.float32)
        scale = jnp.mean(jnp.abs(t32))
        return (jnp.sign(t32) * scale).astype(t.dtype)

    return jax.tree_util.tree_map(f, tau)


def dare(tau: PyTree, density: float, key: jax.Array) -> PyTree:
    """DARE: drop entries i.i.d. with prob (1-density), rescale by 1/density."""
    leaves, treedef = jax.tree_util.tree_flatten(tau)
    keys = jax.random.split(key, len(leaves))
    out = []
    for t, k in zip(leaves, keys):
        keep = jax.random.bernoulli(k, p=density, shape=t.shape)
        out.append(jnp.where(keep, t.astype(jnp.float32) / density, 0.0
                             ).astype(t.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def compeft_dense(tau: PyTree, density: float, alpha: float) -> PyTree:
    """ComPEFT returned as a dense pytree (for like-for-like eval)."""
    from repro.core.compeft import decompress
    return decompress(compress(tau, CompressionConfig(density=density,
                                                      alpha=alpha)))


METHODS = ("compeft", "stc", "pruned", "bitdelta", "dare")


def run_method(name: str, tau: PyTree, density: float, alpha: float = 1.0,
               key: jax.Array | None = None) -> PyTree:
    if name == "compeft":
        return compeft_dense(tau, density, alpha)
    if name == "stc":
        return stc(tau, density)
    if name == "pruned":
        return pruned(tau, density)
    if name == "bitdelta":
        return bitdelta(tau)
    if name == "dare":
        return dare(tau, density, key if key is not None else jax.random.PRNGKey(0))
    raise ValueError(f"unknown method {name!r}")


def method_bits(name: str, n: int, density: float) -> float:
    """Storage cost model per method (bits), matching the paper's accounting:
    Golomb for ternary codes, bitmask for BitDelta, COO for DARE/Pruned."""
    from repro.core import packing
    if name in ("compeft", "stc"):
        return packing.golomb_total_bits(n, density)
    if name == "bitdelta":
        return float(n) + 16.0  # one sign bit per param + scale
    if name == "pruned":
        # positions via Golomb + 16-bit magnitude per survivor
        return density * n * (packing.golomb_bits_per_position(density) + 16.0) + 16.0
    if name == "dare":
        # COO: 32-bit index + 16-bit value per survivor
        return density * n * 48.0
    raise ValueError(name)
