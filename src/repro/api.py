"""repro.api — the front door for the ComPEFT expert lifecycle.

One import gives the whole paper workflow (compress → store → merge →
serve) over the first-class :class:`repro.expert.Expert` artifact:

    from repro import api
    from repro.expert import DENSE, TERNARY, PACKED, GOLOMB

    ex = api.compress(tau, name="math", density=0.05, alpha=1.0)
    ex.nbytes(PACKED)              # 2 bits/param bitplanes
    ex.save("math.npz")            # Golomb wire format

    reg = api.registry()           # ExpertStore + DeviceCache tiers
    reg.add(ex)

    # multi-host: publish over a transport, fetch from another registry
    from repro.transport import LocalTransport
    tr = LocalTransport("/srv/experts")
    api.publish(ex, tr)                       # wire-format blob + checksum
    remote = api.registry(transport=tr)       # REMOTE -> cold -> HBM tiers
    remote.prefetch(["math"])                 # overlap fetch with serving

    merged_tau = api.merge([ex_a, ex_b], method="ties", lam=0.7)

    engine = api.serve(model, rt, base_params, reg,
                       max_batch=8, cache_len=128)
    engine.run(requests)

Everything here is a thin dispatch layer: compression is Algorithm 1
(``repro.core``), merging is §3.6/3.7 (``repro.core.merging``), serving is
the zero-merge mixed-expert engine (``repro.serve``), and cross-host
movement is the checksummed wire format + backends in
``repro.transport``.  The legacy entry
points (``compress_expert``, ``checkpoint.export_expert`` /
``import_expert``, ``ServeEngine(…, ExpertStore, …)``) keep working for
one release with deprecation warnings.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence

from repro.expert import (DENSE, GOLOMB, PACKED, REPRESENTATIONS, TERNARY,
                          Expert)

PyTree = Any

__all__ = ["Expert", "DENSE", "TERNARY", "PACKED", "GOLOMB",
           "REPRESENTATIONS", "compress", "merge", "registry", "serve",
           "load", "save", "publish", "fetch"]


def compress(tau_or_init: PyTree, theta_ft: Optional[PyTree] = None, *,
             name: str = "expert", kind: str = "full", density: float = 0.05,
             alpha: float = 1.0, per_tensor: bool = True,
             method: str = "streaming", meta: Optional[dict] = None
             ) -> Expert:
    """Algorithm 1 as an artifact: compress a task vector into an Expert.

    Call with a task vector (``compress(tau)``) or a fine-tune pair
    (``compress(theta_init, theta_ft)``); the latter forms ``tau =
    theta_ft - theta_init`` first.  ``method='streaming'`` (default) is the
    single-pass histogram-quantile + batched-pack pipeline;
    ``method='exact'`` the sort-based per-leaf numerics oracle.
    Compression itself is lazy — it runs on the first ``as_`` /
    ``.packed`` / ``save`` access.
    """
    kw = dict(name=name, kind=kind, density=density, alpha=alpha,
              per_tensor=per_tensor, method=method, meta=meta)
    if theta_ft is not None:
        return Expert.from_finetune(tau_or_init, theta_ft, **kw)
    return Expert.from_task_vector(tau_or_init, **kw)


def merge(experts: Sequence[Any], method: str = "auto", lam: float = 1.0,
          density: float = 0.2, *, name: Optional[str] = None,
          as_expert: bool = False, **compress_kw) -> PyTree:
    """Merge experts (Task Arithmetic / TIES / packed-bitplane TA).

    Dispatches by representation — see
    :func:`repro.core.merging.merge_experts`.  Returns the merged dense
    task-vector tree, or (``as_expert=True``) a freshly-compressed
    :class:`Expert` named ``name``.
    """
    from repro.core.merging import merge_experts
    tau = merge_experts(experts, method=method, lam=lam, density=density)
    if not as_expert:
        return tau
    compress_kw.setdefault("density", density)
    return compress(tau, name=name or "merged", **compress_kw)


def registry(store=None, *, cold_golomb: bool = False,
             device_cache_bytes: Optional[int] = None,
             transport=None, cold_budget_bytes: Optional[int] = None,
             retry=None, quarantine_after: Optional[int] = None,
             quarantine_probe_s: Optional[float] = None,
             replicas=None, replication_factor: Optional[int] = None,
             hedge_ms: Optional[float] = None, mesh=None,
             experts: Sequence[Any] = ()) -> "ExpertRegistry":
    """A fresh :class:`~repro.serve.expert_cache.ExpertRegistry` (cold
    store + lazy HBM tier), optionally pre-populated with ``experts``.

    ``transport=`` (an :class:`~repro.transport.ExpertTransport`) builds
    the registry over a **remote** store instead: experts publish and
    fetch as checksummed wire-format blobs, and ``reg.prefetch(names)``
    overlaps transfers with serving.  ``store`` and ``transport`` are
    mutually exclusive.  ``cold_budget_bytes`` bounds the cold-local cache
    of fetched wire blobs with an LRU (dropped blobs re-fetch
    transparently; ``SwapStats.cold_evictions`` counts them).

    Fault tolerance (remote registries only): ``retry=`` (a
    :class:`~repro.transport.RetryPolicy`) replaces the transport's
    retry/backoff policy; ``quarantine_after`` puts an expert in timed
    quarantine after that many *consecutive* retry-exhausted fetch
    failures, and ``quarantine_probe_s`` is how long before one probe
    fetch is let through again.  A fetch that still fails after all of
    this surfaces as :class:`~repro.serve.ExpertUnavailable`, which the
    engine degrades to a per-request ``FAILED`` status.

    Replication: ``replicas=[t0, t1, ...]`` (a fleet of transports)
    builds the registry over a
    :class:`~repro.transport.ReplicatedTransport` — consistent-hash
    placement of published blobs onto ``replication_factor`` owners
    (default 2), fastest-healthy-first selection, leaf-resumable
    mid-stream failover, and optional hedged reads after ``hedge_ms``
    (``None`` disables hedging).  A single-replica blackout then costs
    latency, not availability.

    ``mesh=`` (a serving mesh from :func:`repro.launch.mesh.
    make_serve_mesh`) makes the HBM tier expert-parallel: stacked
    ``[E, ...]`` bitplane buffers are partitioned along the mesh's
    ``expert`` axis and ``device_cache_bytes`` becomes a per-shard
    budget.  ``mesh=None`` keeps the single-device tier byte-for-byte.
    """
    from repro.serve.expert_cache import (DEFAULT_DEVICE_BYTES,
                                          DEFAULT_QUARANTINE_AFTER,
                                          DEFAULT_QUARANTINE_PROBE_S,
                                          ExpertRegistry)
    reg = ExpertRegistry(
        store, cold_golomb=cold_golomb, transport=transport,
        cold_budget_bytes=cold_budget_bytes,
        device_cache_bytes=device_cache_bytes or DEFAULT_DEVICE_BYTES,
        retry=retry, replicas=replicas,
        replication_factor=replication_factor, hedge_ms=hedge_ms, mesh=mesh,
        quarantine_after=(DEFAULT_QUARANTINE_AFTER if quarantine_after is None
                          else quarantine_after),
        quarantine_probe_s=(DEFAULT_QUARANTINE_PROBE_S
                            if quarantine_probe_s is None
                            else quarantine_probe_s))
    for e in experts:
        reg.add(e)
    return reg


def serve(model, rt, base_params: PyTree, reg, cfg=None,
          **engine_kw) -> "ServeEngine":
    """A :class:`~repro.serve.engine.ServeEngine` over a registry.

    ``model`` is the :class:`~repro.models.model.ModelApi` from
    ``repro.models.build``; ``cfg`` an
    :class:`~repro.serve.engine.EngineConfig` (or pass its fields as
    keyword arguments, e.g. ``max_batch=8, cache_len=128``).

    Decode is device-resident by default: ``decode_chunk=K`` (16) compiles
    K decode steps per launch with on-device stopping and token selection;
    ``decode_chunk=0`` is the eager per-token baseline.  Sampling knobs
    can be passed flat — ``temperature`` (0 = greedy), ``top_k`` (0 = full
    vocabulary) and ``seed`` build the engine's
    :class:`~repro.serve.decode_loop.SamplingConfig`; seeded sampling is
    reproducible across chunk sizes, eager vs compiled loops, and mid-wave
    admissions.

    ``scheduler=`` picks the admission policy (``"fifo"`` — bit-identical
    to the historical queue, ``"priority"`` — priority classes +
    deadline EDF, ``"affinity"`` — priority + expert-affinity wave
    packing for stacked-plane hits); requests carry ``priority``,
    ``deadline_s`` and ``arrival_s`` (open-loop replay) fields.
    ``kv_layout="paged"`` swaps the dense left-padded KV slots for
    block-table pools (``kv_block_size=`` positions per block,
    ``kv_blocks=`` pool size) with free-list admission control —
    see :mod:`repro.serve.paged_kv` and :mod:`repro.serve.scheduler`.

    ``degrade="request"`` (default) turns an unavailable expert
    (:class:`~repro.serve.ExpertUnavailable` at admission — dead replica,
    quarantined name, corrupted blob past all retries) into a terminal
    per-request ``FAILED`` status (``Request.status``/``Request.error``)
    while the rest of the wave serves normally; ``degrade="raise"``
    propagates the error instead.

    ``mesh=`` (from :func:`repro.launch.mesh.make_serve_mesh`, axes
    ``("expert", "model")``) puts the decode hot path on a device mesh:
    base params go vocab-parallel and KV pools batch/block-sharded along
    ``model``, the stacked bitplane buffers expert-parallel along
    ``expert`` with ``device_cache_bytes`` reinterpreted as a per-shard
    HBM budget (per-shard gauges land in ``swap_summary()["shards"]``).
    Only dims where each output element is computed by exactly one device
    are sharded, so greedy *and* seeded-sampled token streams are
    bit-identical to ``mesh=None`` — which keeps today's single-device
    path byte-for-byte.

    ``snapshot_dir=`` arms crash consistency: every ``run()`` writes an
    append-only CRC-framed journal there (admissions, scheduler
    decisions, per-chunk tokens — flushed at every chunk boundary), and
    ``snapshot_every_chunks=N`` additionally commits an atomic engine
    snapshot (KV cache + pending tokens + allocator state) every N
    compiled chunks.  ``resume=True`` rebuilds a killed run instead of
    returning an idle engine: the engine replays the journal, restores
    the latest snapshot, refetches evicted experts through the normal
    registry tiers, re-runs prefill for rows whose KV postdates the
    snapshot, and continues every in-flight request **bit-identically**
    (greedy and seeded-sampled, dense and paged, on any mesh shape) —
    results land in ``engine.resumed_requests`` and timing in
    ``engine.recovery_stats``.  Engine latency accounting is
    ``time.monotonic()``-based (NTP-immune); each request carries one
    epoch stamp, ``Request.t_wall``, for external correlation.
    """
    import dataclasses
    from repro.serve.decode_loop import SamplingConfig
    from repro.serve.engine import EngineConfig, ServeEngine
    do_resume = engine_kw.pop("resume", False)
    samp_kw = {k: engine_kw.pop(k)
               for k in ("temperature", "top_k", "seed") if k in engine_kw}
    if samp_kw:
        if "sampling" in engine_kw:
            raise ValueError("pass either sampling= or flat "
                             "temperature/top_k/seed, not both")
        base_samp = cfg.sampling if cfg is not None else SamplingConfig()
        engine_kw["sampling"] = dataclasses.replace(base_samp, **samp_kw)
    if cfg is None:
        cfg = EngineConfig(**engine_kw)
    elif engine_kw:
        cfg = dataclasses.replace(cfg, **engine_kw)
    eng = ServeEngine(model, rt, base_params, reg, cfg)
    if do_resume:
        eng.resume()
    return eng


def load(path: str, name: Optional[str] = None) -> Expert:
    """Read an expert artifact npz (new format or legacy
    ``checkpoint.export_expert`` files)."""
    return Expert.load(path, name=name)


def save(expert: Expert, path: str) -> dict:
    """Write ``expert`` as the Golomb wire artifact; returns size stats."""
    return expert.save(path)


def publish(expert: Expert, transport, rep: str = GOLOMB,
            replication_factor: Optional[int] = None) -> dict:
    """Upload ``expert`` through a transport backend as one wire-format
    blob (manifest + per-leaf checksums; see :mod:`repro.transport.wire`).

    ``rep`` picks the payload encoding: :data:`GOLOMB` (default,
    storage-optimal), :data:`PACKED` (2 bits/param, zero decode cost on
    arrival) or :data:`DENSE` (bf16 baseline — what shipping the
    uncompressed delta would cost).  Returns ``{name, rep, nbytes}``.

    ``transport`` may also be a **list** of transports: the blob then
    fans out to the ``replication_factor`` (default 2) consistent-hash
    ring owners of the name, and the result gains a ``replicas`` key
    naming them.  The ring is deterministic in the fleet, so a consumer
    building a :class:`~repro.transport.ReplicatedTransport` over the
    same replica list computes the same owners.
    """
    if isinstance(transport, (list, tuple)):
        from repro.transport.replication import ReplicatedTransport
        transport = ReplicatedTransport(
            list(transport),
            replication_factor=(replication_factor
                                if replication_factor is not None else 2))
    elif replication_factor is not None:
        if not hasattr(transport, "replication_factor"):
            raise ValueError("replication_factor= needs a replica list or "
                             "a ReplicatedTransport")
        transport.replication_factor = min(replication_factor,
                                           len(transport.replicas))
    return transport.publish(expert, rep=rep)


def fetch(transport, name: str, retry=None) -> Expert:
    """Fetch + decode one published expert from a transport backend.

    The blob's CRC and format version are verified before any plane is
    built; the result is bit-identical to the Expert that was published.
    Transient failures (5xx, timeouts, checksum mismatches) are retried
    under the transport's :class:`~repro.transport.RetryPolicy` — pass
    ``retry=`` to override it for this call.
    """
    return transport.fetch_expert(name, retry=retry)[0]
