"""Distributed train step: microbatch gradient accumulation, remat'd model,
optional ComPEFT-compressed cross-pod gradient exchange (EF-ternary), and
pluggable optimizer (AdamW / Adafactor).

Structure (multi-pod):

  shard_map over 'pod' (manual)                 <- compressed boundary
    └── lax.scan over microbatches
          └── jax.grad( model forward )         <- GSPMD over data/model
    └── EF-ternary all-gather over 'pod' (2 bits/param on the wire)
  optimizer update (GSPMD, FSDP-sharded states)

Single-pod: same minus the shard_map (GSPMD's dense all-reduce over 'data'
is the within-pod ICI traffic, which stays dense by design — compression is
for the slow cross-pod links).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.gradient_compression import (GradCompressionConfig,
                                             compressed_cross_pod_mean,
                                             init_error_state)
from repro.models.model import ModelApi
from repro.models.transformer import Runtime
from repro.optim import adafactor, adamw, schedules

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    optimizer: str = "adamw"            # adamw | adafactor
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "warmup_cosine"
    adamw: adamw.AdamWConfig = adamw.AdamWConfig()
    adafactor: adafactor.AdafactorConfig = adafactor.AdafactorConfig()
    grad_compression: GradCompressionConfig = GradCompressionConfig(
        enabled=True, density=0.05)
    ef_dtype: str = "bfloat16"


def init_train_state(params: PyTree, tcfg: TrainConfig,
                     multi_pod: bool) -> dict:
    if tcfg.optimizer == "adamw":
        opt = adamw.init(params, tcfg.adamw)
    else:
        opt = adafactor.init(params, tcfg.adafactor)
    state = {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}
    if multi_pod and tcfg.grad_compression.enabled:
        state["ef"] = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.dtype(tcfg.ef_dtype)), params)
    return state


def _lr(step, tcfg: TrainConfig):
    fn = getattr(schedules, tcfg.schedule)
    return fn(step, peak_lr=tcfg.peak_lr, warmup_steps=tcfg.warmup_steps,
              total_steps=tcfg.total_steps)


def _microbatch_grads(api: ModelApi, params, batch, rt: Runtime,
                      n_micro: int):
    """Accumulated (mean) grads + loss over n_micro sequential microbatches."""

    def loss_fn(p, mb):
        loss, _ = api.loss_and_logits(p, mb, rt)
        return loss

    if n_micro == 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads

    micro = jax.tree_util.tree_map(
        lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
        batch)

    def body(carry, mb):
        acc, lsum = carry
        l, g = jax.value_and_grad(loss_fn)(params, mb)
        acc = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(jnp.float32), acc, g)
        return (acc, lsum + l), None

    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, lsum), _ = lax.scan(body, (zeros, jnp.zeros(())), micro)
    inv = 1.0 / n_micro
    grads = jax.tree_util.tree_map(lambda g: g * inv, gsum)
    return lsum * inv, grads


def _apply_optimizer(state, grads, tcfg: TrainConfig):
    lr = _lr(state["step"], tcfg)
    if tcfg.optimizer == "adamw":
        new_params, new_opt, metrics = adamw.update(
            grads, state["opt"], state["params"], lr, tcfg.adamw)
    else:
        new_params, new_opt, metrics = adafactor.update(
            grads, state["opt"], state["params"], lr, tcfg.adafactor)
    out = dict(state)
    out["params"] = new_params
    out["opt"] = new_opt
    out["step"] = state["step"] + 1
    metrics["lr"] = lr
    return out, metrics


def make_train_step(api: ModelApi, rt: Runtime, tcfg: TrainConfig,
                    mesh: Optional[Mesh] = None) -> Callable:
    """-> step_fn(state, batch) -> (new_state, metrics).

    ``batch`` leaves have global batch at dim 0.  When the mesh has a 'pod'
    axis and compression is enabled, gradients cross pods as packed ternary
    bitplanes with error feedback.
    """
    multi_pod = mesh is not None and "pod" in mesh.axis_names
    use_comp = multi_pod and tcfg.grad_compression.enabled

    def plain_step(state, batch):
        loss, grads = _microbatch_grads(api, state["params"], batch, rt,
                                        tcfg.microbatches)
        new_state, metrics = _apply_optimizer(state, grads, tcfg)
        metrics["loss"] = loss
        return new_state, metrics

    if not use_comp:
        return plain_step

    # inside the pod-manual region, activation constraints must not name
    # the (now Manual) 'pod' axis — rebuild the shard callback without it
    from repro.distributed.sharding import make_shard_fn
    rt_pod = dataclasses.replace(
        rt, shard=make_shard_fn(mesh, api.cfg, drop_axes=("pod",)))

    def step(state, batch):
        def per_pod(params, ef, pod_batch):
            loss, grads = _microbatch_grads(api, params, pod_batch, rt_pod,
                                            tcfg.microbatches)
            mean_grads, new_ef = compressed_cross_pod_mean(
                grads, ef, tcfg.grad_compression, axis_name="pod")
            loss = lax.pmean(loss, "pod")
            return loss, mean_grads, new_ef

        batch_specs = jax.tree_util.tree_map(
            lambda x: P("pod"), batch)
        f = jax.shard_map(
            per_pod, mesh=mesh, axis_names={"pod"},
            in_specs=(P(), P(), batch_specs),
            out_specs=(P(), P(), P()),
            check_vma=False)
        loss, grads, new_ef = f(state["params"], state["ef"], batch)
        new_state, metrics = _apply_optimizer(state, grads, tcfg)
        new_state["ef"] = new_ef
        metrics["loss"] = loss
        return new_state, metrics

    return step
