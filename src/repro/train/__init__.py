from repro.train.train_step import (TrainConfig, init_train_state,
                                    make_train_step)
from repro.train.trainer import LoopConfig, train_loop

__all__ = ["TrainConfig", "init_train_state", "make_train_step",
           "LoopConfig", "train_loop"]
