"""Training loop with checkpoint/restart fault tolerance and straggler
monitoring.  The loop is deliberately restart-idempotent: state lives in
(checkpoint, step) only."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.configs.base import ModelConfig
from repro.data.pipeline import make_batch_for
from repro.distributed.fault import (FailureInjector, SimulatedFailure,
                                     StragglerMonitor)
from repro.models.model import ModelApi
from repro.models.transformer import Runtime
from repro.train.train_step import TrainConfig, init_train_state


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    seq_len: int = 64
    global_batch: int = 8
    task_id: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 20
    log_every: int = 10
    max_restarts: int = 5


def train_loop(api: ModelApi, rt: Runtime, tcfg: TrainConfig,
               lcfg: LoopConfig, step_fn: Callable,
               injector: Optional[FailureInjector] = None,
               state=None, log: Callable = print) -> tuple[dict, list]:
    """Runs (or resumes) training.  Returns (final_state, history).

    Restart semantics: on SimulatedFailure the loop restores the latest
    checkpoint and replays from its step — exactly what a relaunched job
    would do.  The stateless data pipeline guarantees the replayed stream
    is identical.
    """
    cfg = api.cfg
    if state is None:
        params = api.init(jax.random.PRNGKey(0))
        state = init_train_state(params, tcfg, multi_pod=False)

    start = 0
    if lcfg.ckpt_dir:
        last = ckpt.latest_step(lcfg.ckpt_dir)
        if last is not None:
            state = ckpt.restore(state, lcfg.ckpt_dir, last)
            start = int(last)
            log(f"[trainer] resumed from step {start}")

    history: list = []
    monitor = StragglerMonitor()
    restarts = 0
    step = start
    while step < lcfg.total_steps:
        try:
            batch = make_batch_for(cfg, step, lcfg.seq_len,
                                   lcfg.global_batch, lcfg.task_id)
            if injector is not None:
                injector.check(step)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            dt = time.perf_counter() - t0
            monitor.observe(step, dt)
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                raise RuntimeError(f"non-finite loss at step {step}")
            history.append({"step": step, "loss": loss, "sec": dt})
            if step % lcfg.log_every == 0:
                log(f"[trainer] step {step:5d} loss {loss:.4f} "
                    f"({dt*1e3:.0f} ms) straggler={monitor.recommendation()}")
            step += 1
            if lcfg.ckpt_dir and step % lcfg.ckpt_every == 0:
                ckpt.save(state, lcfg.ckpt_dir, step)
        except SimulatedFailure as e:
            restarts += 1
            if restarts > lcfg.max_restarts or not lcfg.ckpt_dir:
                raise
            last = ckpt.latest_step(lcfg.ckpt_dir)
            if last is None:  # no checkpoint yet -> cold restart
                params = api.init(jax.random.PRNGKey(0))
                state = init_train_state(params, tcfg, multi_pod=False)
                step = 0
            else:
                state = ckpt.restore(state, lcfg.ckpt_dir, last)
                step = int(last)
            log(f"[trainer] {e}; restored to step {step} "
                f"(restart {restarts}/{lcfg.max_restarts})")
    if lcfg.ckpt_dir:
        ckpt.save(state, lcfg.ckpt_dir, step)
    return state, history
