"""LoRA: low-rank adapters over arbitrary weight trees.

Adapters attach by *path pattern* to any ≥2-D float weight in the model's
param tree (stacked unit dims are handled transparently: a weight
[U, d_in, d_out] gets A [U, d_in, r], B [U, r, d_out]).  Application is a
functional merge ``W_eff = W + (alpha/r) * A @ B`` so the model code never
changes — the same merge path later consumes ComPEFT-decompressed deltas.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

DEFAULT_TARGETS = r"(wq|wk|wv|wo|wg|wu|Wr|Wk|Wv|Wo|in_proj|out_proj)$"


@dataclasses.dataclass(frozen=True)
class LoraConfig:
    rank: int = 8
    alpha: float = 16.0
    targets: str = DEFAULT_TARGETS  # regex on the last path component

    @property
    def scaling(self) -> float:
        return self.alpha / self.rank


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _is_target(path, leaf, cfg: LoraConfig) -> bool:
    if not isinstance(leaf, jax.Array) and not hasattr(leaf, "shape"):
        return False
    if leaf.ndim < 2 or not jnp.issubdtype(leaf.dtype, jnp.floating):
        return False
    name = _path_str(path).split("/")[-1]
    return re.search(cfg.targets, name) is not None


def _factor_shapes(shape: tuple[int, ...], rank: int, stacked: bool):
    """Factor [(U,) d_in, *out] as A [(U,) d_in, r], B [(U,) r, prod(out)]."""
    lead = shape[:1] if stacked else ()
    core = shape[1:] if stacked else shape
    d_in = core[0]
    d_out = int(np.prod(core[1:]))
    return lead + (d_in, rank), lead + (rank, d_out), core


def init_lora(key: jax.Array, params: PyTree, cfg: LoraConfig,
              stacked_prefixes: tuple[str, ...] = ("blocks", "enc_blocks")
              ) -> PyTree:
    """Create the LoRA tree mirroring targeted weights.  A ~ N(0, 1/r); B = 0
    (so the initial delta is exactly zero, as in the paper's setting)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    out: dict[str, dict] = {}
    keys = jax.random.split(key, len(flat))
    for (path, leaf), k in zip(flat, keys):
        if not _is_target(path, leaf, cfg):
            continue
        ps = _path_str(path)
        stacked = any(ps.startswith(pref) for pref in stacked_prefixes)
        a_shape, b_shape, _ = _factor_shapes(leaf.shape, cfg.rank, stacked)
        out[ps] = {
            "a": (jax.random.normal(k, a_shape, jnp.float32)
                  / np.sqrt(cfg.rank)).astype(leaf.dtype),
            "b": jnp.zeros(b_shape, leaf.dtype),
        }
    return out


def lora_delta(lora_params: PyTree, base_shapes: dict[str, tuple[int, ...]],
               cfg: LoraConfig) -> dict[str, jax.Array]:
    """Materialise dense deltas per targeted path."""
    out = {}
    for ps, ab in lora_params.items():
        a, b = ab["a"], ab["b"]
        if a.ndim == 3:  # stacked units
            d = jnp.einsum("uir,uro->uio", a, b)
        else:
            d = a @ b
        out[ps] = (d * cfg.scaling).reshape(base_shapes[ps])
    return out


def apply_lora(params: PyTree, lora_params: PyTree, cfg: LoraConfig) -> PyTree:
    """W_eff = W + scaling * A@B, matched by path."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        if ps in lora_params:
            ab = lora_params[ps]
            a, b = ab["a"], ab["b"]
            if a.ndim == 3:
                d = jnp.einsum("uir,uro->uio", a.astype(jnp.float32),
                               b.astype(jnp.float32))
            else:
                d = a.astype(jnp.float32) @ b.astype(jnp.float32)
            d = (d * cfg.scaling).reshape(leaf.shape)
            out.append((leaf.astype(jnp.float32) + d).astype(leaf.dtype))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def base_shapes_of(params: PyTree) -> dict[str, tuple[int, ...]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    return {_path_str(p): tuple(l.shape) for p, l in flat}
