"""Task vectors over PEFT or full parameter trees: tau = theta_ft - theta_init
(§2 of the paper), plus the expert-artifact container the serving stack and
checkpoint manager exchange."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import (CompressionConfig, compress, compress_packed,
                        decompress, pack_tree, tree_packed_bytes, unpack_tree)

PyTree = Any


def task_vector(theta_init: PyTree, theta_ft: PyTree) -> PyTree:
    """tau = theta_ft - theta_init, f32 leaves."""
    return jax.tree_util.tree_map(
        lambda a, b: b.astype(jnp.float32) - a.astype(jnp.float32),
        theta_init, theta_ft)


def apply_task_vector(theta_init: PyTree, tau: PyTree,
                      scale: float = 1.0) -> PyTree:
    return jax.tree_util.tree_map(
        lambda w, t: (w.astype(jnp.float32)
                      + scale * t.astype(jnp.float32)).astype(w.dtype),
        theta_init, tau)


@dataclasses.dataclass
class ExpertArtifact:
    """A ComPEFT-compressed expert: what gets stored / transmitted / cached.

    ``packed`` is the bitplane tree (device/compute format).  Golomb bytes
    are produced lazily by the checkpoint manager for cold storage.
    """

    name: str
    kind: str                 # "lora" | "ia3" | "full"
    packed: PyTree            # tree of PackedTernary
    density: float
    alpha: float
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        return tree_packed_bytes(self.packed)

    def to_dense_tau(self) -> PyTree:
        return decompress(unpack_tree(self.packed))


def compress_expert(name: str, kind: str, tau: PyTree, density: float,
                    alpha: float, per_tensor: bool = True,
                    method: str = "streaming") -> ExpertArtifact:
    """Compress a task vector into the packed serving artifact.

    ``method='streaming'`` (default) runs the single-pass histogram-quantile
    + batched-pack pipeline and never materialises dense int8 signs;
    ``method='exact'`` is the seed sort-based per-leaf path, kept as the
    numerics oracle.
    """
    cfg = CompressionConfig(density=density, alpha=alpha,
                            per_tensor=per_tensor)
    if method == "streaming":
        packed = compress_packed(tau, cfg)
    elif method == "exact":
        packed = pack_tree(compress(tau, cfg))
    else:
        raise ValueError(f"unknown compression method {method!r}")
    return ExpertArtifact(name=name, kind=kind, packed=packed,
                          density=density, alpha=alpha,
                          meta={"method": method})


def reconstruct_expert(theta_init: PyTree, artifact: ExpertArtifact,
                       treedef_like: Optional[PyTree] = None) -> PyTree:
    """theta_init + decompressed tau (tree structures must match)."""
    tau = artifact.to_dense_tau()
    return apply_task_vector(theta_init, tau)
