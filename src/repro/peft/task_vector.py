"""Task vectors over PEFT or full parameter trees: tau = theta_ft - theta_init
(§2 of the paper), plus the legacy expert-artifact container.

The expert container role has moved to :class:`repro.expert.Expert` (one
artifact, explicit DENSE/TERNARY/PACKED/GOLOMB representations) behind the
:mod:`repro.api` facade.  ``ExpertArtifact`` / ``compress_expert`` /
``reconstruct_expert`` remain as thin deprecated shims for one release.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import (CompressionConfig, compress, compress_packed,
                        decompress, pack_tree, tree_packed_bytes, unpack_tree)
from repro.expert import Expert

PyTree = Any


def task_vector(theta_init: PyTree, theta_ft: PyTree) -> PyTree:
    """tau = theta_ft - theta_init, f32 leaves."""
    return jax.tree_util.tree_map(
        lambda a, b: b.astype(jnp.float32) - a.astype(jnp.float32),
        theta_init, theta_ft)


def apply_task_vector(theta_init: PyTree, tau: PyTree,
                      scale: float = 1.0) -> PyTree:
    return jax.tree_util.tree_map(
        lambda w, t: (w.astype(jnp.float32)
                      + scale * t.astype(jnp.float32)).astype(w.dtype),
        theta_init, tau)


@dataclasses.dataclass
class ExpertArtifact:
    """DEPRECATED packed-expert container (use :class:`repro.expert.Expert`).

    ``packed`` is the bitplane tree (device/compute format).  Still accepted
    by the serving tiers (normalized to an Expert on the way in); will be
    removed after one release.
    """

    name: str
    kind: str                 # "lora" | "ia3" | "full"
    packed: PyTree            # tree of PackedTernary
    density: float
    alpha: float
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        return tree_packed_bytes(self.packed)

    def to_dense_tau(self) -> PyTree:
        return decompress(unpack_tree(self.packed))


def compress_expert(name: str, kind: str, tau: PyTree, density: float,
                    alpha: float, per_tensor: bool = True,
                    method: str = "streaming") -> ExpertArtifact:
    """DEPRECATED: use ``repro.api.compress`` (returns an Expert).

    Compress a task vector into the packed serving artifact.
    ``method='streaming'`` (default) runs the single-pass histogram-quantile
    + batched-pack pipeline and never materialises dense int8 signs;
    ``method='exact'`` is the seed sort-based per-leaf path, kept as the
    numerics oracle.
    """
    warnings.warn("compress_expert is deprecated; use repro.api.compress "
                  "(returns repro.expert.Expert)", DeprecationWarning,
                  stacklevel=2)
    ex = Expert.from_task_vector(tau, name=name, kind=kind, density=density,
                                 alpha=alpha, per_tensor=per_tensor,
                                 method=method, meta={"method": method})
    return ExpertArtifact(name=name, kind=kind, packed=ex.as_("packed"),
                          density=density, alpha=alpha,
                          meta={"method": method})


def reconstruct_expert(theta_init: PyTree, artifact,
                       treedef_like: Optional[PyTree] = None) -> PyTree:
    """theta_init + decompressed tau (tree structures must match).

    Accepts both the legacy :class:`ExpertArtifact` and
    :class:`repro.expert.Expert`.
    """
    tau = artifact.to_dense_tau()
    return apply_task_vector(theta_init, tau)
