from repro.peft.ia3 import IA3Config, apply_ia3, init_ia3
from repro.peft.lora import LoraConfig, apply_lora, base_shapes_of, init_lora
from repro.peft.task_vector import (ExpertArtifact, apply_task_vector,
                                    compress_expert, reconstruct_expert,
                                    task_vector)

__all__ = ["IA3Config", "apply_ia3", "init_ia3", "LoraConfig", "apply_lora",
           "base_shapes_of", "init_lora", "ExpertArtifact",
           "apply_task_vector", "compress_expert", "reconstruct_expert",
           "task_vector"]
