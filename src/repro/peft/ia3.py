"""(IA)³: learned rescaling vectors on K, V and FFN-hidden activations.

Because the rescaled ops are linear (or the scale commutes with the gate
product — see DESIGN.md), (IA)³ is applied as a multiplicative transform on
the *output dims* of wk / wv / wu, which keeps the model code untouched and
lets (IA)³ share the merge path with LoRA and ComPEFT deltas."""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import jax.numpy as jnp

from repro.peft.lora import _is_target, _path_str

PyTree = Any

IA3_TARGETS = r"(wk|wv|wu|Wk|Wv)$"


@dataclasses.dataclass(frozen=True)
class IA3Config:
    targets: str = IA3_TARGETS


def init_ia3(params: PyTree, cfg: IA3Config | None = None) -> PyTree:
    """One vector per targeted weight over its output dims, initialised to 0
    (scale = 1 + ell, so init is identity)."""
    cfg = cfg or IA3Config()
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    out = {}
    for path, leaf in flat:
        name = _path_str(path).split("/")[-1]
        if leaf.ndim < 2 or re.search(cfg.targets, name) is None:
            continue
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        ps = _path_str(path)
        # stacked unit weights keep their leading U; scale covers out dims
        if ps.startswith(("blocks", "enc_blocks")):
            shape = (leaf.shape[0],) + leaf.shape[2:]
        else:
            shape = leaf.shape[1:]
        out[ps] = {"ell": jnp.zeros(shape, jnp.float32)}
    return out


def apply_ia3(params: PyTree, ia3_params: PyTree,
              cfg: IA3Config | None = None) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        if ps in ia3_params:
            ell = ia3_params[ps]["ell"]
            if ell.ndim == leaf.ndim - 1 and ps.startswith(("blocks",
                                                            "enc_blocks")):
                scale = (1.0 + ell)[:, None]  # broadcast over d_in
            else:
                scale = (1.0 + ell)[None]
            out.append((leaf.astype(jnp.float32) * scale).astype(leaf.dtype))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)
