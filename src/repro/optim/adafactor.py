"""Adafactor (factored second moment): optimizer state ~ O(n/d) instead of
O(2n) — the fit-enabler for the 400B-class archs (llama4, jamba, qwen-110b)
under 16 GB/chip HBM (DESIGN.md §4)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    decay: float = 0.8          # beta2_t = 1 - step^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    min_dim_factored: int = 128


def _factored(shape) -> bool:
    return len(shape) >= 2 and min(shape[-2:]) >= 2


def init(params: PyTree, cfg: AdafactorConfig) -> PyTree:
    def leaf(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"slots": jax.tree_util.tree_map(leaf, params),
            "count": jnp.zeros((), jnp.int32)}


def update(grads: PyTree, state: PyTree, params: PyTree, lr: jax.Array,
           cfg: AdafactorConfig):
    count = state["count"] + 1
    beta2 = 1.0 - count.astype(jnp.float32) ** (-cfg.decay)

    def upd(g, slot, p):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + cfg.eps
        if "vr" in slot:
            vr = beta2 * slot["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * slot["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), cfg.eps)
            v_hat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
            new_slot = {"vr": vr, "vc": vc}
        else:
            v_hat = beta2 * slot["v"] + (1 - beta2) * g2
            new_slot = {"v": v_hat}
        u = g32 / jnp.sqrt(v_hat + cfg.eps)
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u / cfg.clip_threshold)
        newp = p.astype(jnp.float32) - lr * u
        if cfg.weight_decay and p.ndim >= 2:
            newp = newp - lr * cfg.weight_decay * p.astype(jnp.float32)
        return newp.astype(p.dtype), new_slot

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_s = tdef.flatten_up_to(state["slots"])
    flat_p = tdef.flatten_up_to(params)
    outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_state = {"slots": jax.tree_util.tree_unflatten(
        tdef, [o[1] for o in outs]), "count": count}
    return new_params, new_state, {}
