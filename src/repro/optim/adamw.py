"""AdamW with configurable moment dtype (bf16 moments cut optimizer HBM by
2x at <0.1% quality cost at LM scale) and decoupled weight decay."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"   # "bfloat16" halves optimizer memory
    grad_clip_norm: float = 1.0


def init(params: PyTree, cfg: AdamWConfig) -> PyTree:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def update(grads: PyTree, state: PyTree, params: PyTree, lr: jax.Array,
           cfg: AdamWConfig):
    """-> (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (step + decay)
        return (newp.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_m = tdef.flatten_up_to(state["mu"])
    flat_v = tdef.flatten_up_to(state["nu"])
    flat_p = tdef.flatten_up_to(params)
    outs = [upd(g, m, v, p) for g, m, v, p in
            zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_state = {
        "mu": jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs]),
        "nu": jax.tree_util.tree_unflatten(tdef, [o[2] for o in outs]),
        "count": count,
    }
    return new_params, new_state, {"grad_norm": gnorm}
