from repro.optim import adafactor, adamw, schedules
from repro.optim.adafactor import AdafactorConfig
from repro.optim.adamw import AdamWConfig

__all__ = ["adafactor", "adamw", "schedules", "AdafactorConfig",
           "AdamWConfig"]
