"""While-loop-aware HLO cost analyzer.

``compiled.cost_analysis()`` counts while (scan) bodies ONCE and reports
per-device numbers — useless for scanned-layer models (an 80-layer stack
reports 1/80th of its FLOPs).  This module parses the post-SPMD HLO text,
builds the computation call graph (while bodies x trip counts, fusions,
calls), and accumulates:

  * dot FLOPs            (2 x prod(result dims) x prod(contracting dims))
  * HBM traffic proxy    (dot/fusion-boundary/collective/cache-update/gather
                          bytes; standalone elementwise ops are treated as
                          fused away, emulating the TPU backend's fusion)
  * collective wire bytes per kind (ring model, group-size aware)

All numbers are PER DEVICE (shapes in post-partitioning HLO are local).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1,
                "u8": 1, "pred": 1, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
                "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _parse_op_line(line: str):
    """Parse '%name = TYPE opcode(rest' with balanced-paren TYPE (nested
    tuple types appear for scan carries).  Returns (name, type, opcode,
    rest) or None."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    n = len(line)
    if i < n and line[i] == "(":       # tuple type: scan to balance
        depth = 0
        j = i
        while j < n:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        type_str = line[i:j + 1]
        k = j + 1
    else:                               # simple type: up to next space
        k = line.find(" ", i)
        if k < 0:
            return None
        type_str = line[i:k]
    mm = re.match(r"\s+([\w\-]+)\(", line[k:])
    if not mm:
        return None
    opcode = mm.group(1).lower()
    rest = line[k + mm.end():]
    return name, type_str, opcode, rest
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(.*\))?\s*->.*\{")
_WHILE_ATTR = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALLS_ATTR = re.compile(r"calls=%?([\w\.\-]+)")
_TO_ATTR = re.compile(r"to_apply=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS = re.compile(r"%([\w\.\-]+)")

SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "iota", "broadcast", "reshape", "transpose",
    "custom-call", "get-dimension-size", "while", "conditional", "call",
    "opt-barrier", "rng-bit-generator",
}
COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute"}


def _shape_elems_bytes(type_str: str):
    total_b = 0
    total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_b += n * _DTYPE_BYTES[dt]
        total_e += n
    return total_e, total_b


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    rest: str  # operands + attrs raw


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    symtab: dict  # value name -> result type string


def parse_computations(hlo: str) -> dict:
    comps: dict[str, Computation] = {}
    cur = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_START.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1), [], {})
            continue
        s = line.strip()
        if s == "}" or s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _parse_op_line(line)
        if parsed:
            op = Op(*parsed)
            cur.ops.append(op)
            cur.symtab[op.name] = op.result_type
    return comps


def _base_opcode(opcode: str) -> str:
    for suffix in ("-start", "-done"):
        if opcode.endswith(suffix):
            return opcode[: -len(suffix)]
    return opcode


def _trip_count(cond: Computation) -> int:
    """Scan-lowered whiles compare the induction var against a constant;
    take the largest integer constant in the condition computation."""
    best = 1
    for op in cond.ops:
        # constants print as: %c = s32[] constant(80)
        if op.opcode == "constant":
            mm = re.match(r"^(\d+)\)", op.rest)
            if mm:
                best = max(best, int(mm.group(1)))
    return best


def _group_size(rest: str, n_devices: int) -> int:
    m = _GROUPS_IOTA.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(rest)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return n_devices


def _dot_flops(op: Op, symtab: dict) -> float:
    res_elems, _ = _shape_elems_bytes(op.result_type)
    names = _OPERANDS.findall(op.rest.split(")", 1)[0])
    lhs_type = symtab.get(names[0]) if names else None
    contract = 1
    m = _CONTRACT.search(op.rest)
    if lhs_type and m:
        dims = [int(x) for x in m.group(1).split(",") if x]
        sm = _SHAPE_RE.search(lhs_type)
        if sm:
            lhs_dims = [int(x) for x in sm.group(2).split(",") if x]
            for d in dims:
                if d < len(lhs_dims):
                    contract *= lhs_dims[d]
    return 2.0 * res_elems * contract


def _op_bytes(op: Op, symtab: dict) -> float:
    """Traffic proxy: result + resolvable operand bytes."""
    _, b = _shape_elems_bytes(op.result_type)
    names = _OPERANDS.findall(op.rest.split(")", 1)[0])
    for n in names:
        t = symtab.get(n)
        if t:
            _, ob = _shape_elems_bytes(t)
            b += ob
    return float(b)


def analyze(hlo: str, n_devices: int, entry: str | None = None) -> dict:
    comps = parse_computations(hlo)
    if entry is None:
        # ENTRY computation: the one containing 'main' or the last one
        entry = next((n for n in comps if ".main" in n or n.startswith("main")),
                     None) or list(comps)[-1]

    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # BFS through the call graph accumulating multipliers
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for op in comp.ops:
            if op.opcode == "while":
                wm = _WHILE_ATTR.search(op.rest)
                if not wm:
                    continue
                cond_name, body_name = wm.group(1), wm.group(2)
                tm = _TRIP_RE.search(op.rest)
                if tm:
                    trips = int(tm.group(1))
                else:
                    trips = _trip_count(comps[cond_name]) \
                        if cond_name in comps else 1
                for child in (body_name, cond_name):
                    mult[child] += m * trips
                    if child not in seen:
                        seen.add(child)
                        order.append(child)
            else:
                for attr in (_CALLS_ATTR, _TO_ATTR):
                    am = attr.search(op.rest)
                    if am:
                        child = am.group(1)
                        mult[child] += m
                        if child not in seen:
                            seen.add(child)
                            order.append(child)

    flops = 0.0
    bytes_traffic = 0.0
    coll = defaultdict(float)
    coll_ops = defaultdict(int)
    fused = {n for n in comps if "fused" in n}

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        in_fusion = cname in fused
        for op in comp.ops:
            oc = _base_opcode(op.opcode)
            if oc in ("dot", "convolution"):
                flops += m * _dot_flops(op, comp.symtab)
                if in_fusion:
                    continue
                bytes_traffic += m * _op_bytes(op, comp.symtab)
            elif oc in COLLECTIVES:
                if op.opcode.endswith("-done"):
                    continue
                _, nbytes = _shape_elems_bytes(op.result_type)
                g = _group_size(op.rest, n_devices)
                if g <= 1:
                    continue
                if oc == "all-reduce":
                    wire = 2 * nbytes * (g - 1) / g
                elif oc == "collective-permute":
                    wire = nbytes
                else:
                    wire = nbytes * (g - 1) / g
                coll[oc] += m * wire
                coll[f"{oc}@g{g}"] += m * wire   # per-group-size breakdown
                coll_ops[oc] += 1
                bytes_traffic += m * _op_bytes(op, comp.symtab)
            elif oc == "fusion":
                bytes_traffic += m * _op_bytes(op, comp.symtab)
            elif oc in ("dynamic-update-slice", "scatter"):
                # XLA aliases DUS in place: traffic = the update operand
                # (second arg), not the whole buffer
                names = _OPERANDS.findall(op.rest.split(")", 1)[0])
                upd = comp.symtab.get(names[1]) if len(names) > 1 else None
                _, ub = _shape_elems_bytes(upd) if upd else (0, 0)
                bytes_traffic += m * ub
            elif oc in ("gather", "dynamic-slice"):
                # reads only the gathered rows ~= result size (+write)
                _, rb = _shape_elems_bytes(op.result_type)
                bytes_traffic += m * 2 * rb
            # standalone elementwise/reduce ops are skipped: the TPU
            # backend fuses them into neighbours, so counting them (as the
            # CPU backend's sparser fusion would suggest) would overstate
            # HBM traffic several-fold.

    kinds_total = sum(v for k, v in coll.items() if "@" not in k)
    out = {
        "flops_per_device": flops,
        "bytes_per_device": bytes_traffic,
        "collective_bytes_per_device": dict(coll),
        "collective_total": float(kinds_total),
        "collective_op_counts": dict(coll_ops),
        "n_computations": len(comps),
    }
    return out
