"""Production meshes.  Defined as functions so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax use)."""

from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.  Multi-pod adds a 'pod'
    axis: (pod=2, data=16, model=16) = 512 chips."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


SERVE_AXES = ("expert", "model")


def make_serve_mesh(shape=(1, 1)):
    """Serving mesh: (expert, model).

    The ``expert`` axis shards the stacked ``[E, ...]`` bitplane buffers
    (each device group holds a contiguous block of the resident expert
    set); the ``model`` axis shards the base model tensor-parallel along
    dims where every output element is still computed by exactly one
    device (vocab-parallel embed/lm_head, batch-sharded KV) so that token
    streams stay bit-identical to the single-device engine.

    ``shape=(1, 1)`` is a degenerate single-device mesh — useful for
    exercising the mesh code path without multiple devices.
    """
    import jax

    if len(shape) != 2:
        raise ValueError(f"serve mesh shape must be (expert, model), got {shape!r}")
    n = shape[0] * shape[1]
    avail = len(jax.devices())
    if n > avail:
        raise ValueError(
            f"serve mesh {shape} needs {n} devices but only {avail} are "
            "visible (set --xla_force_host_platform_device_count for CPU)")
    return jax.make_mesh(tuple(shape), SERVE_AXES)


# TPU v5e hardware constants used by the roofline (benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
CHIPS_PER_POD = 256
