"""Production meshes.  Defined as functions so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax use)."""

from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.  Multi-pod adds a 'pod'
    axis: (pod=2, data=16, model=16) = 512 chips."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants used by the roofline (benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
CHIPS_PER_POD = 256
