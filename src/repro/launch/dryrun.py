import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and extract the roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]

Per cell this prints ``compiled.memory_analysis()`` (fits-in-HBM proof) and
``compiled.cost_analysis()`` (FLOPs / bytes for the roofline), parses the
post-SPMD HLO for per-device collective wire bytes, and writes JSON under
``benchmarks/results/dryrun/<mesh>/``.

NOTE: the XLA_FLAGS line above must run before ANY other import (jax locks
the device count on first init) — hence the unusual module layout.
"""

import argparse
import dataclasses
import gzip
import json
import re
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.configs.registry import normalize
from repro.distributed.collectives import make_sp_decode_attn
from repro.distributed.sharding import (batch_axes, batch_shardings,
                                        cache_shardings, make_shard_fn,
                                        param_shardings, replicated)
from repro.launch.mesh import make_production_mesh
from repro.models import Runtime, build
from repro.train import TrainConfig, init_train_state, make_train_step
from repro.core.gradient_compression import GradCompressionConfig

# ---------------------------------------------------------------------------
# Cell table
# ---------------------------------------------------------------------------

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

# long_500k runs only for sub-quadratic-memory archs (DESIGN.md §5):
LONG_OK = {"rwkv6_3b", "jamba_1_5_large_398b", "mixtral_8x7b", "gemma2_9b"}

BIG_PARAM_THRESHOLD = 50e9   # adafactor + bf16 EF above this


def cell_list(include_paper_arch: bool = False):
    archs = [a for a in ARCHS if include_paper_arch or a != "llama_7b"]
    cells = []
    for a in archs:
        for s in SHAPES:
            if s == "long_500k" and a not in LONG_OK:
                continue
            cells.append((a, s))
    return cells


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------


def input_specs(arch: str, shape: str) -> dict:
    """Weak-type-correct, shardable, zero-allocation input descriptions."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    T, B = sh["seq_len"], sh["global_batch"]
    sds = jax.ShapeDtypeStruct

    if sh["kind"] in ("train", "prefill"):
        if cfg.frontend is not None:
            n_mod = min(cfg.frontend.n_tokens, T // 2)
            text = T - n_mod
            batch = {"tokens": sds((B, text), jnp.int32),
                     "targets": sds((B, text), jnp.int32)}
            key = "frames" if cfg.family == "audio" else "mm_embeds"
            batch[key] = sds((B, n_mod, cfg.frontend.embed_dim), jnp.float32)
        else:
            batch = {"tokens": sds((B, T), jnp.int32),
                     "targets": sds((B, T), jnp.int32)}
        if sh["kind"] == "prefill":
            batch.pop("targets")
        return batch

    # decode: one new token against a seq_len cache
    api = build(cfg)
    cache = jax.eval_shape(lambda: api.init_decode_cache(B, T))
    return {"token": sds((B, 1), jnp.int32), "cache": cache}


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u64|u32|s16|u16|s8|u8|pred)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
          "pred": 1}
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9\[\],{}_]+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _BYTES[dt]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return n_devices


def collective_bytes(hlo_text: str, n_devices: int) -> dict:
    """Per-device wire bytes per collective kind (ring cost model):
      all-reduce: 2B(g-1)/g, all-gather/reduce-scatter/all-to-all: B(g-1)/g,
      collective-permute: B.  B = result-shape bytes of the op."""
    out: dict = {}
    per_op: dict = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None or "-done" in line:
            continue
        ty, kind = m.group(1), m.group(2).lower()
        nbytes = _shape_bytes(ty)
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        if kind == "all-reduce":
            wire = 2 * nbytes * (g - 1) / g
        elif kind == "collective-permute":
            wire = nbytes
        else:
            wire = nbytes * (g - 1) / g
        out[kind] = out.get(kind, 0.0) + wire
        per_op[kind] = per_op.get(kind, 0) + 1
    out["ops"] = per_op
    out["total"] = sum(v for k, v in out.items()
                       if k not in ("ops", "total"))
    return out


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------


def _train_cfg_for(cfg, shape, multi_pod: bool = False) -> TrainConfig:
    big = cfg.param_count() > BIG_PARAM_THRESHOLD
    gb = SHAPES[shape]["global_batch"]
    # microbatch size chosen per POD so batch shards stay even over `data`
    per_pod = gb // (2 if multi_pod else 1)
    micro = max(1, per_pod // (16 if big else 32))
    return TrainConfig(
        microbatches=micro,
        optimizer="adafactor" if big else "adamw",
        grad_compression=GradCompressionConfig(enabled=True, density=0.05),
    )


def make_runtime(mesh, cfg, global_batch: Optional[int] = None) -> Runtime:
    from repro.distributed.collectives import make_vp_embed_lookup
    from repro.distributed.collectives import make_vp_embed_lookup
    return Runtime(shard=make_shard_fn(mesh, cfg),
                   decode_attn=make_sp_decode_attn(mesh, global_batch),
                   embed_lookup=make_vp_embed_lookup(mesh),
                   remat_policy="unit")


def lower_cell(arch: str, shape: str, multi_pod: bool = False,
               extra_tags: str = "", save_hlo_to: str | None = None) -> dict:
    arch = normalize(arch)
    cfg = get_config(arch)
    api = build(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    sh = SHAPES[shape]
    kind = sh["kind"]
    specs = input_specs(arch, shape)
    t0 = time.time()

    with jax.set_mesh(mesh):
        params_sds = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
        pshard = param_shardings(params_sds, cfg, mesh)

        if kind == "train":
            tcfg = _train_cfg_for(cfg, shape, multi_pod)
            rt = make_runtime(mesh, cfg)
            state_sds = jax.eval_shape(
                lambda: init_train_state(params_sds, tcfg, multi_pod))
            from repro.distributed.sharding import train_state_shardings
            st_shard = train_state_shardings(state_sds, cfg, mesh)
            bshard = batch_shardings(specs, mesh)
            step = make_train_step(api, rt, tcfg, mesh=mesh)
            lowered = jax.jit(step, in_shardings=(st_shard, bshard)).lower(
                state_sds, specs)
        elif kind == "prefill":
            rt = make_runtime(mesh, cfg, sh["global_batch"])
            bshard = batch_shardings(specs, mesh)
            cache_len = sh["seq_len"]

            def prefill_fn(params, batch):
                return api.prefill(params, batch, rt, cache_len)

            cache_sds = jax.eval_shape(
                lambda: api.init_decode_cache(sh["global_batch"], cache_len))
            cshard = cache_shardings(cache_sds, mesh, sh["global_batch"])
            lowered = jax.jit(
                prefill_fn, in_shardings=(pshard, bshard),
                out_shardings=(None, _cache_out_shardings(cshard)),
            ).lower(params_sds, specs)
        else:  # decode
            rt = make_runtime(mesh, cfg, sh["global_batch"])
            cshard = cache_shardings(specs["cache"], mesh,
                                     sh["global_batch"])
            from repro.distributed.sharding import decode_layout
            from jax.sharding import NamedSharding, PartitionSpec as P
            baxes, _ = decode_layout(mesh, sh["global_batch"])
            tshard = NamedSharding(mesh, P(baxes, None))

            def serve_step(params, token, cache):
                return api.decode_step(params, token, cache, rt)

            lowered = jax.jit(
                serve_step, in_shardings=(pshard, tshard, cshard),
                out_shardings=(None, _cache_out_shardings(cshard)),
            ).lower(params_sds, specs["token"], specs["cache"])

        lower_s = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t1

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    from repro.launch.hlo_analysis import analyze
    hstats = analyze(hlo, n_dev)
    colls = collective_bytes(hlo, n_dev)   # naive (body-once) cross-check
    if save_hlo_to:
        with gzip.open(save_hlo_to, "wt") as f:
            f.write(hlo)

    result = {
        "arch": arch,
        "shape": shape,
        "kind": kind,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "n_devices": n_dev,
        "seq_len": sh["seq_len"],
        "global_batch": sh["global_batch"],
        # while-aware per-device accounting (repro.launch.hlo_analysis)
        "flops": hstats["flops_per_device"],
        "bytes_accessed": hstats["bytes_per_device"],
        "collectives": {**hstats["collective_bytes_per_device"],
                        "ops": hstats["collective_op_counts"],
                        "total": hstats["collective_total"]},
        # raw XLA numbers (count scan bodies once; kept for cross-checks)
        "xla_cost_flops": float(cost.get("flops", 0.0)),
        "xla_bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "naive_collectives": colls,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "lower_s": round(lower_s, 2),
        "compile_s": round(compile_s, 2),
        "tags": extra_tags,
    }
    return result


def _cache_out_shardings(cshard):
    return cshard


def result_path(arch: str, shape: str, multi_pod: bool, out_dir: str) -> str:
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    d = os.path.join(out_dir, mesh)
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{normalize(arch)}__{shape}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", type=str,
                    default="benchmarks/results/dryrun")
    args = ap.parse_args()

    if args.all:
        cells = cell_list()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(normalize(args.arch), args.shape)]

    failures = []
    for arch, shape in cells:
        path = result_path(arch, shape, args.multi_pod, args.out)
        if args.skip_existing and os.path.exists(path):
            print(f"[dryrun] skip {arch} {shape} (exists)")
            continue
        print(f"[dryrun] {arch} {shape} multi_pod={args.multi_pod} ...",
              flush=True)
        try:
            res = lower_cell(arch, shape, args.multi_pod,
                             save_hlo_to=path.replace(".json", ".hlo.gz"))
        except Exception as e:  # noqa
            import traceback
            traceback.print_exc()
            failures.append((arch, shape, repr(e)[:200]))
            continue
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        print(f"[dryrun]   flops={res['flops']:.3e} "
              f"coll={res['collectives']['total']:.3e}B "
              f"temp={res['memory']['temp_bytes']/2**30:.2f}GiB "
              f"compile={res['compile_s']}s", flush=True)
    if failures:
        print("[dryrun] FAILURES:")
        for f_ in failures:
            print("   ", f_)
        raise SystemExit(1)
    print("[dryrun] all cells OK")


if __name__ == "__main__":
    main()
