"""Checkpointing: exact, mesh-agnostic full checkpoints (fault tolerance /
elastic re-sharding) + ComPEFT-compressed expert-delta export (the paper's
communication artifact).

Full checkpoints store logical (unsharded) arrays, so a job restarted on a
*different* mesh or pod count restores bit-exactly: restore() device_puts
onto whatever shardings the new topology prescribes.  bf16 leaves are
stored as uint16 views (npz has no bfloat16).

Expert deltas are Golomb-coded ComPEFT artifacts: base + delta round-trips
through the same reconstruct path the serving tier uses.  Since the
transport subsystem landed, both shims speak both containers: an
``out_path`` ending in ``.cpft`` writes the checksummed wire blob
(:mod:`repro.transport.wire`) instead of the npz, and ``import_expert``
sniffs the container — so a checkpointing job can export straight into a
transport root (e.g. a :class:`~repro.transport.LocalTransport`
directory) for other hosts to fetch.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import golomb
from repro.core.compeft import CompressionConfig, compress_packed
from repro.peft.lora import _path_str

PyTree = Any

_SAN = re.compile(r"[^A-Za-z0-9_]")


def _san(path: str) -> str:
    return _SAN.sub("__", path)


def _to_numpy(x) -> tuple[np.ndarray, str]:
    arr = np.asarray(jax.device_get(x))
    if arr.dtype == jnp.bfloat16:
        return arr.view(np.uint16), "bfloat16"
    return arr, str(arr.dtype)


def save(state: PyTree, ckpt_dir: str, step: int,
         extra_meta: Optional[dict] = None) -> str:
    """Write an exact checkpoint; atomic via tmp+rename.  Returns path.

    ``extra_meta`` (JSON-serializable) rides inside ``manifest.json`` —
    under the same atomic rename as the arrays, so consumers that need
    host-side metadata committed *with* the arrays (serve snapshots:
    row/slot composition, allocator free lists) never observe one
    without the other.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(state)
    manifest = {"step": step, "leaves": []}
    if extra_meta is not None:
        manifest["extra"] = extra_meta
    arrays = {}
    for i, (p, leaf) in enumerate(flat):
        ps = _path_str(p)
        arr, dt = _to_numpy(leaf)
        key = f"a{i}_{_san(ps)[:80]}"
        arrays[key] = arr
        manifest["leaves"].append({"path": ps, "key": key, "dtype": dt,
                                   "shape": list(arr.shape)})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    _gc(ckpt_dir, keep=3)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(like: PyTree, ckpt_dir: str, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of ``like``; optionally device_put onto
    ``shardings`` (elastic restore onto a new mesh)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    by_path = {l["path"]: l for l in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (treedef.flatten_up_to(shardings)
                  if shardings is not None else [None] * len(flat))
    out = []
    for (p, leaf), sh in zip(flat, shard_flat):
        ps = _path_str(p)
        meta = by_path[ps]
        arr = data[meta["key"]]
        if meta["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def load_raw(ckpt_dir: str, step: Optional[int] = None
             ) -> tuple[dict, dict]:
    """-> (manifest, {leaf path: np.ndarray}) without a ``like`` tree.

    For consumers that reconstruct structure from the manifest itself
    (serve snapshots restore into an engine that was never prefilled, so
    there is no live pytree to mirror).  bf16 leaves come back as
    bfloat16 ndarrays, exactly as :func:`restore` would produce them.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    out = {}
    for leaf in manifest["leaves"]:
        arr = data[leaf["key"]]
        if leaf["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        out[leaf["path"]] = arr
    return manifest, out


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted([d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                    and not d.endswith(".tmp")])
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


# ---------------------------------------------------------------------------
# ComPEFT expert-delta export (Golomb cold-storage format)
# ---------------------------------------------------------------------------


def export_expert(theta_init: PyTree, theta_ft: PyTree, out_path: str,
                  density: float = 0.05, alpha: float = 1.0) -> dict:
    """DEPRECATED: use ``repro.api.compress(init, ft).save(path)``.

    Thin shim over :meth:`repro.expert.Expert.save`: same Golomb npz
    artifact (the streaming ``compress_packed`` pipeline feeding the
    vectorized encoder), same size-accounting return value.  A ``.cpft``
    ``out_path`` writes the transport wire blob instead.
    """
    import warnings

    from repro.expert import Expert
    warnings.warn("checkpoint.export_expert is deprecated; use "
                  "repro.api.compress(theta_init, theta_ft).save(path)",
                  DeprecationWarning, stacklevel=2)
    ex = Expert.from_finetune(theta_init, theta_ft,
                              name=os.path.splitext(
                                  os.path.basename(out_path))[0],
                              density=density, alpha=alpha)
    return ex.save(out_path)


def import_expert(path: str) -> tuple[dict, dict]:
    """DEPRECATED: use ``repro.api.load(path)`` (an Expert).

    -> ({param_path: dense tau leaf}, manifest) — the legacy contract,
    served through :meth:`repro.expert.Expert.load`.
    """
    import warnings

    from repro.expert import DENSE, Expert
    warnings.warn("checkpoint.import_expert is deprecated; use "
                  "repro.api.load(path)", DeprecationWarning, stacklevel=2)
    ex = Expert.load(path)
    out = {p: np.asarray(l, np.float32).reshape(
               ex._leaf_meta[p]["shape"])
           for p, l in ex.as_(DENSE).items()}
    return out, ex._manifest
