"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, attn:mamba 1:7 interleave, MoE 16 experts top-2 every other
layer.  [arXiv:2403.19887]

Pattern unit = 8 blocks (1 attn + 7 mamba), FFNs alternate dense/MoE within
the unit; 72 layers = 9 units.
"""

from repro.configs.base import (AttnCfg, BlockCfg, FFNCfg, MambaCfg,
                                ModelConfig, MoECfg)


def config() -> ModelConfig:
    attn = AttnCfg(n_q=64, n_kv=8, head_dim=128)
    mamba = MambaCfg(d_state=16, d_conv=4, expand=2)
    dense_ffn = FFNCfg(d_ff=24576, activation="swiglu")
    moe_ffn = FFNCfg(d_ff=24576, activation="swiglu",
                     moe=MoECfg(n_experts=16, top_k=2, d_ff_expert=24576))

    pattern = []
    for i in range(8):
        ffn = moe_ffn if i % 2 == 1 else dense_ffn
        if i == 0:
            pattern.append(BlockCfg(kind="attn", attn=attn, ffn=ffn))
        else:
            pattern.append(BlockCfg(kind="mamba", mamba=mamba, ffn=ffn))
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        d_model=8192,
        vocab=65_536,
        pattern=tuple(pattern),
        n_units=9,  # 72 layers
    )
