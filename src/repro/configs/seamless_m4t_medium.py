"""seamless-m4t-medium [audio]: enc-dec, 12L encoder + 12L decoder,
d_model=1024 16H (kv=16, i.e. MHA) d_ff=4096 vocab=256206.  The audio
frontend is a STUB: input_specs() supplies precomputed frame embeddings.
[arXiv:2308.11596]"""

from repro.configs.base import (AttnCfg, BlockCfg, FFNCfg, FrontendCfg,
                                ModelConfig)


def config() -> ModelConfig:
    dec = BlockCfg(
        kind="attn",
        attn=AttnCfg(n_q=16, n_kv=16, head_dim=64, causal=True),
        ffn=FFNCfg(d_ff=4096, activation="swiglu"),
    )
    enc = BlockCfg(
        kind="attn",
        attn=AttnCfg(n_q=16, n_kv=16, head_dim=64, causal=False),
        ffn=FFNCfg(d_ff=4096, activation="swiglu"),
    )
    return ModelConfig(
        name="seamless-m4t-medium",
        family="audio",
        d_model=1024,
        vocab=256_206,
        pattern=(dec,),
        n_units=12,
        enc_pattern=(enc,),
        enc_n_units=12,
        cross_attn=True,
        frontend=FrontendCfg(kind="audio", n_tokens=1024, embed_dim=1024),
    )
