"""qwen2.5-3b [dense]: 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936, QKV bias, tied embeddings.  [hf:Qwen/Qwen2.5; hf]"""

from repro.configs.base import AttnCfg, BlockCfg, FFNCfg, ModelConfig


def config() -> ModelConfig:
    block = BlockCfg(
        kind="attn",
        attn=AttnCfg(n_q=16, n_kv=2, head_dim=128, qkv_bias=True,
                     rope_theta=1_000_000.0),
        ffn=FFNCfg(d_ff=11008, activation="swiglu"),
    )
    return ModelConfig(
        name="qwen2.5-3b",
        family="dense",
        d_model=2048,
        vocab=151_936,
        pattern=(block,),
        n_units=36,
        tie_embeddings=True,
    )
