"""llama-7b — the paper's own base-model family (QLoRA experiments, §3.1):
32L d_model=4096 32H MHA d_ff=11008 vocab=32000.  [arXiv:2302.13971]"""

from repro.configs.base import AttnCfg, BlockCfg, FFNCfg, ModelConfig


def config() -> ModelConfig:
    block = BlockCfg(
        kind="attn",
        attn=AttnCfg(n_q=32, n_kv=32, head_dim=128),
        ffn=FFNCfg(d_ff=11008, activation="swiglu"),
    )
    return ModelConfig(
        name="llama-7b",
        family="dense",
        d_model=4096,
        vocab=32_000,
        pattern=(block,),
        n_units=32,
    )
