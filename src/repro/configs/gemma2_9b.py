"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000, alternating local(SWA 4096)/global attention, attn softcap 50,
final-logit softcap 30, GeGLU, sandwich norms, head_dim=256, sqrt(d) embed
scaling.  [arXiv:2408.00118]"""

from repro.configs.base import AttnCfg, BlockCfg, FFNCfg, ModelConfig


def config() -> ModelConfig:
    local = BlockCfg(
        kind="attn",
        attn=AttnCfg(n_q=16, n_kv=8, head_dim=256, window=4096,
                     attn_softcap=50.0),
        ffn=FFNCfg(d_ff=14336, activation="geglu"),
        sandwich_norm=True,
    )
    glob = BlockCfg(
        kind="attn",
        attn=AttnCfg(n_q=16, n_kv=8, head_dim=256, attn_softcap=50.0),
        ffn=FFNCfg(d_ff=14336, activation="geglu"),
        sandwich_norm=True,
    )
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        d_model=3584,
        vocab=256_000,
        pattern=(local, glob),  # alternating SWA / global
        n_units=21,             # 42 layers
        tie_embeddings=True,
        logit_softcap=30.0,
        embed_scale=True,
    )
