"""Architecture registry: maps --arch ids to ModelConfig constructors."""

from __future__ import annotations

import importlib

ARCHS = (
    "llama4_maverick_400b",
    "mixtral_8x7b",
    "qwen2_5_3b",
    "qwen3_32b",
    "qwen1_5_110b",
    "gemma2_9b",
    "internvl2_1b",
    "jamba_1_5_large_398b",
    "rwkv6_3b",
    "seamless_m4t_medium",
    # the paper's own base model family
    "llama_7b",
)

_ALIASES = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen2.5-3b": "qwen2_5_3b",
    "qwen3-32b": "qwen3_32b",
    "qwen1.5-110b": "qwen1_5_110b",
    "gemma2-9b": "gemma2_9b",
    "internvl2-1b": "internvl2_1b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "rwkv6-3b": "rwkv6_3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llama-7b": "llama_7b",
}


def normalize(arch: str) -> str:
    a = arch.replace("-", "_").replace(".", "_")
    return _ALIASES.get(arch, a if a in ARCHS else _ALIASES.get(a, a))


def get_config(arch: str):
    name = normalize(arch)
    if name not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.config()


def get_smoke_config(arch: str, **kw):
    from repro.configs.base import reduce_for_smoke
    return reduce_for_smoke(get_config(arch), **kw)
