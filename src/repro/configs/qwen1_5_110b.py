"""qwen1.5-110b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5; hf]"""

from repro.configs.base import AttnCfg, BlockCfg, FFNCfg, ModelConfig


def config() -> ModelConfig:
    block = BlockCfg(
        kind="attn",
        attn=AttnCfg(n_q=64, n_kv=8, head_dim=128, qkv_bias=True,
                     rope_theta=1_000_000.0),
        ffn=FFNCfg(d_ff=49152, activation="swiglu"),
    )
    return ModelConfig(
        name="qwen1.5-110b",
        family="dense",
        d_model=8192,
        vocab=152_064,
        pattern=(block,),
        n_units=80,
    )
