"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, per-head q/k RMSNorm, head_dim=128 (q-proj 8192 != d_model).
[hf:Qwen/Qwen3; hf]"""

from repro.configs.base import AttnCfg, BlockCfg, FFNCfg, ModelConfig


def config() -> ModelConfig:
    block = BlockCfg(
        kind="attn",
        attn=AttnCfg(n_q=64, n_kv=8, head_dim=128, qk_norm=True,
                     rope_theta=1_000_000.0),
        ffn=FFNCfg(d_ff=25600, activation="swiglu"),
    )
    return ModelConfig(
        name="qwen3-32b",
        family="dense",
        d_model=5120,
        vocab=151_936,
        pattern=(block,),
        n_units=64,
    )
