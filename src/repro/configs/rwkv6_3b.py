"""rwkv6-3b "Finch" [ssm]: 32L d_model=2560 (attention-free) d_ff=8960
vocab=65536, data-dependent decay, matrix-valued per-head state.
[arXiv:2404.05892]

Time-mix state is per-64-dim head (40 heads; not TP-divisible) ->
head_tp=False: time-mix replicated over `model`, channel-mix TP.
"""

from repro.configs.base import (BlockCfg, FFNCfg, ModelConfig, RWKVCfg,
                                ShardingOverrides)


def config() -> ModelConfig:
    block = BlockCfg(
        kind="rwkv",
        rwkv=RWKVCfg(head_dim=64, decay_lora=64, mix_lora=32),
        ffn=FFNCfg(d_ff=8960, activation="relu2"),
    )
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        d_model=2560,
        vocab=65_536,
        pattern=(block,),
        n_units=32,
        sharding=ShardingOverrides(head_tp=False),
    )
