"""Config dataclasses for the architecture zoo.

A model is a stack of ``n_units`` repetitions of a *pattern* — a short list of
heterogeneous blocks (attention / mamba / rwkv, each with a dense-or-MoE FFN).
``lax.scan`` runs over the unit axis, so HLO size is O(len(pattern)), not
O(n_layers) — essential for compiling 80-layer/400B configs in the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    shared_expert_dff: Optional[int] = None  # llama4 always-on shared expert


@dataclasses.dataclass(frozen=True)
class FFNCfg:
    d_ff: int
    activation: str = "swiglu"  # swiglu | geglu | relu2
    moe: Optional[MoECfg] = None


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    n_q: int
    n_kv: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: Optional[float] = None   # gemma2: 50.0
    window: Optional[int] = None           # sliding-window size; None = global
    rope_theta: float = 10_000.0
    causal: bool = True                    # False for encoder blocks


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class RWKVCfg:
    head_dim: int = 64
    decay_lora: int = 64   # rank of the data-dependent decay adapter
    mix_lora: int = 32     # rank of the ddlerp token-shift adapters


@dataclasses.dataclass(frozen=True)
class BlockCfg:
    kind: str                       # attn | mamba | rwkv
    ffn: Optional[FFNCfg] = None    # None => block has no FFN (rwkv has its own)
    attn: Optional[AttnCfg] = None
    mamba: Optional[MambaCfg] = None
    rwkv: Optional[RWKVCfg] = None
    sandwich_norm: bool = False     # gemma2 post-norms


@dataclasses.dataclass(frozen=True)
class FrontendCfg:
    """Modality frontend STUB: precomputed embeddings supplied by input_specs."""
    kind: str            # "vision" | "audio"
    n_tokens: int        # patches / frames per example
    embed_dim: int       # dimension of the precomputed embeddings


@dataclasses.dataclass(frozen=True)
class ShardingOverrides:
    """Per-arch deviations from the default logical->mesh rules."""
    head_tp: bool = True        # False: replicate attention over 'model' (llama4, internvl2)
    expert_parallel: bool = True  # False: TP inside experts instead (mixtral)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    d_model: int
    vocab: int
    pattern: Sequence[BlockCfg]
    n_units: int
    # encoder (enc-dec archs only)
    enc_pattern: Sequence[BlockCfg] = ()
    enc_n_units: int = 0
    cross_attn: bool = False
    # embeddings / head
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None   # gemma2: 30.0
    embed_scale: bool = False               # gemma-style sqrt(d) embed scaling
    # modality stub
    frontend: Optional[FrontendCfg] = None
    # norms
    rms_eps: float = 1e-6
    # sharding
    sharding: ShardingOverrides = ShardingOverrides()
    # dtype
    dtype: str = "bfloat16"

    @property
    def n_layers(self) -> int:
        return self.n_units * len(self.pattern) + self.enc_n_units * len(self.enc_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS=6ND)."""
        d = self.d_model
        total = self.vocab * d * (1 if self.tie_embeddings else 2)

        def block_params(b: BlockCfg) -> int:
            p = 2 * d  # pre-norms (attn/ffn)
            if b.sandwich_norm:
                p += 2 * d
            if b.kind == "attn":
                a = b.attn
                p += d * a.n_q * a.head_dim * 2          # wq, wo
                p += d * a.n_kv * a.head_dim * 2          # wk, wv
                if a.qkv_bias:
                    p += (a.n_q + 2 * a.n_kv) * a.head_dim
                if a.qk_norm:
                    p += 2 * a.head_dim
            elif b.kind == "mamba":
                m = b.mamba
                d_in = m.expand * d
                dt_rank = m.dt_rank or -(-d // 16)
                p += d * 2 * d_in                         # in_proj
                p += m.d_conv * d_in + d_in               # conv + bias
                p += d_in * (dt_rank + 2 * m.d_state)     # x_proj
                p += dt_rank * d_in + d_in                # dt_proj
                p += d_in * m.d_state + d_in              # A_log, D
                p += d_in * d                             # out_proj
            elif b.kind == "rwkv":
                r = b.rwkv
                p += 5 * d * d                            # r,k,v,g,o  (time mix)
                p += 2 * d * r.decay_lora                 # decay adapter
                p += 6 * (d * r.mix_lora * 2 + d)         # ddlerp adapters + mus
                p += d                                    # u bonus
                p += 2 * d                                # ln_x
            if b.ffn is not None:
                f = b.ffn
                if f.moe is not None:
                    mo = f.moe
                    p += d * mo.n_experts                     # router
                    p += mo.n_experts * 3 * d * mo.d_ff_expert
                    if mo.shared_expert_dff:
                        p += 3 * d * mo.shared_expert_dff
                else:
                    n_mats = 3 if f.activation in ("swiglu", "geglu") else 2
                    p += n_mats * d * f.d_ff
            if self.cross_attn and b.kind == "attn" and b.attn.causal:
                a = b.attn
                p += d  # cross pre-norm
                p += d * a.n_q * a.head_dim * 2 + d * a.n_kv * a.head_dim * 2
            return p

        for b in self.pattern:
            total += self.n_units * block_params(b)
        for b in self.enc_pattern:
            total += self.enc_n_units * block_params(b)
        total += d  # final norm
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        d = self.d_model
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        full = self.param_count()
        # subtract inactive expert mass
        inactive = 0
        for b in self.pattern:
            if b.ffn is not None and b.ffn.moe is not None:
                mo = b.ffn.moe
                per_expert = 3 * d * mo.d_ff_expert
                inactive += self.n_units * (mo.n_experts - mo.top_k) * per_expert
        return int(full - inactive)


def reduce_for_smoke(cfg: ModelConfig, d_model: int = 64, n_units: int = 2,
                     vocab: int = 512) -> ModelConfig:
    """Shrink any config to CPU-smoke-test size, preserving its *family
    structure* (same pattern kinds, MoE top-k, qk_norm, softcaps...)."""
    scale = d_model / cfg.d_model

    def shrink_block(b: BlockCfg) -> BlockCfg:
        attn = None
        if b.attn is not None:
            attn = dataclasses.replace(
                b.attn,
                n_q=max(2, min(4, b.attn.n_q)),
                n_kv=max(1, min(2, b.attn.n_kv)),
                head_dim=16,
                window=min(b.attn.window, 32) if b.attn.window else None,
            )
        ffn = None
        if b.ffn is not None:
            moe = None
            if b.ffn.moe is not None:
                moe = dataclasses.replace(
                    b.ffn.moe,
                    n_experts=min(4, b.ffn.moe.n_experts),
                    d_ff_expert=128,
                    shared_expert_dff=(128 if b.ffn.moe.shared_expert_dff else None),
                )
            ffn = dataclasses.replace(b.ffn, d_ff=128, moe=moe)
        mamba = dataclasses.replace(b.mamba, d_state=8, dt_rank=8) if b.mamba else None
        rwkv = dataclasses.replace(b.rwkv, head_dim=16, decay_lora=8,
                                   mix_lora=8) if b.rwkv else None
        return dataclasses.replace(b, attn=attn, ffn=ffn, mamba=mamba, rwkv=rwkv)

    frontend = None
    if cfg.frontend is not None:
        frontend = dataclasses.replace(cfg.frontend, n_tokens=8,
                                       embed_dim=d_model)
    return dataclasses.replace(
        cfg,
        d_model=d_model,
        vocab=vocab,
        pattern=tuple(shrink_block(b) for b in cfg.pattern),
        n_units=n_units,
        enc_pattern=tuple(shrink_block(b) for b in cfg.enc_pattern),
        enc_n_units=min(cfg.enc_n_units, n_units),
        frontend=frontend,
        dtype="float32",
    )
