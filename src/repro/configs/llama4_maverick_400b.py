"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1 + shared expert, alternating
dense/MoE layers (Maverick interleave).  [hf:meta-llama/Llama-4; unverified]

40 q-heads do not divide the 16-way model axis -> head_tp=False (attention
replicated over `model`, weights FSDP over `data`; see DESIGN.md §4).
"""

from repro.configs.base import (AttnCfg, BlockCfg, FFNCfg, ModelConfig,
                                MoECfg, ShardingOverrides)

D = 5120


def config() -> ModelConfig:
    attn = AttnCfg(n_q=40, n_kv=8, head_dim=128, rope_theta=500_000.0)
    dense = BlockCfg(kind="attn", attn=attn,
                     ffn=FFNCfg(d_ff=8192, activation="swiglu"))
    moe = BlockCfg(kind="attn", attn=attn,
                   ffn=FFNCfg(d_ff=8192, activation="swiglu",
                              moe=MoECfg(n_experts=128, top_k=1,
                                         d_ff_expert=8192,
                                         shared_expert_dff=8192)))
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        d_model=D,
        vocab=202_048,
        pattern=(dense, moe),   # alternating dense / MoE
        n_units=24,             # 48 layers
        sharding=ShardingOverrides(head_tp=False, expert_parallel=True),
    )
