"""internvl2-1b [vlm]: InternViT frontend (STUB: precomputed patch
embeddings) + Qwen2-0.5B-like backbone: 24L d_model=896 14H (GQA kv=2)
d_ff=4864 vocab=151655.  [arXiv:2404.16821]

14 heads do not divide the 16-way model axis -> head_tp=False.
"""

from repro.configs.base import (AttnCfg, BlockCfg, FFNCfg, FrontendCfg,
                                ModelConfig, ShardingOverrides)


def config() -> ModelConfig:
    block = BlockCfg(
        kind="attn",
        attn=AttnCfg(n_q=14, n_kv=2, head_dim=64, qkv_bias=True,
                     rope_theta=1_000_000.0),
        ffn=FFNCfg(d_ff=4864, activation="swiglu"),
    )
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        d_model=896,
        vocab=151_655,
        pattern=(block,),
        n_units=24,
        tie_embeddings=True,
        frontend=FrontendCfg(kind="vision", n_tokens=256, embed_dim=1024),
        sharding=ShardingOverrides(head_tp=False),
    )
