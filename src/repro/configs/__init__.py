from repro.configs.base import (AttnCfg, BlockCfg, FFNCfg, FrontendCfg,
                                MambaCfg, ModelConfig, MoECfg, RWKVCfg,
                                ShardingOverrides, reduce_for_smoke)
from repro.configs.registry import ARCHS, get_config, get_smoke_config

__all__ = ["AttnCfg", "BlockCfg", "FFNCfg", "FrontendCfg", "MambaCfg",
           "ModelConfig", "MoECfg", "RWKVCfg", "ShardingOverrides",
           "reduce_for_smoke", "ARCHS", "get_config", "get_smoke_config"]
