"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, 8 experts top-2, sliding-window attention.  [arXiv:2401.04088]

8 experts < 16 model shards -> expert-internal TP (shard each expert's
d_ff 16-way) instead of expert parallelism.
"""

from repro.configs.base import (AttnCfg, BlockCfg, FFNCfg, ModelConfig,
                                MoECfg, ShardingOverrides)


def config() -> ModelConfig:
    block = BlockCfg(
        kind="attn",
        attn=AttnCfg(n_q=32, n_kv=8, head_dim=128, window=4096,
                     rope_theta=1_000_000.0),
        ffn=FFNCfg(d_ff=14336, activation="swiglu",
                   moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=14336)),
    )
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        d_model=4096,
        vocab=32_000,
        pattern=(block,),
        n_units=32,
        sharding=ShardingOverrides(head_tp=True, expert_parallel=False),
    )
