#!/usr/bin/env python3
"""Fail on broken intra-repo links in README.md and docs/*.md.

Checks every inline markdown link ``[text](target)`` whose target is a
relative path (external URLs and mailto: are skipped; ``#fragment``
suffixes are stripped; pure-fragment links are ignored).  A target must
exist as a file or directory relative to the markdown file that names
it.  Exits non-zero listing every broken link.

    python tools/check_links.py [files...]     # default: README.md docs/*.md
"""

from __future__ import annotations

import glob
import os
import re
import sys

# inline links, excluding images' leading "!" is fine to include — a
# broken image path is just as broken as a broken link
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP = re.compile(r"^(https?:|mailto:|ftp:|#)")


def check_file(md_path: str) -> list[str]:
    base = os.path.dirname(os.path.abspath(md_path))
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    broken = []
    for m in _LINK.finditer(text):
        target = m.group(1)
        if _SKIP.match(target):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        line = text.count("\n", 0, m.start()) + 1
        if not os.path.exists(os.path.join(base, path)):
            broken.append(f"{md_path}:{line}: broken link -> {target}")
    return broken


def main(argv: list[str]) -> int:
    files = argv or (["README.md"] + sorted(glob.glob("docs/*.md")))
    missing = [f for f in files if not os.path.exists(f)]
    if missing:
        print(f"no such markdown file(s): {missing}", file=sys.stderr)
        return 2
    broken = [b for f in files for b in check_file(f)]
    for b in broken:
        print(b, file=sys.stderr)
    n_files = len(files)
    if broken:
        print(f"{len(broken)} broken link(s) across {n_files} file(s)",
              file=sys.stderr)
        return 1
    print(f"link check OK ({n_files} file(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
