"""End-to-end multi-expert serving driver — the paper's headline scenario,
through the ``repro.api`` facade.

Builds a base model + several ComPEFT-compressed experts in an
``ExpertRegistry``, then serves a mixed batch of requests through the
zero-merge engine, reporting swap bytes vs the uncompressed baseline
(paper Table 5 quantities).

    PYTHONPATH=src python examples/serve_experts.py [--experts 4] [--requests 12]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api as capi
from repro.configs import get_smoke_config
from repro.expert import GOLOMB, PACKED
from repro.models import Runtime, build
from repro.serve import Request, uncompressed_baseline_bytes

RT = Runtime(attn_chunk_q=16, attn_chunk_k=16, remat_policy="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--experts", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--density", type=float, default=0.1)
    args = ap.parse_args()

    cfg = get_smoke_config("qwen2_5_3b", d_model=96, n_units=2)
    api = build(cfg)
    base = api.init(jax.random.PRNGKey(0))

    # expert library: base + per-task deltas, ComPEFT-compressed
    registry = capi.registry()
    for i in range(args.experts):
        leaves, tdef = jax.tree_util.tree_flatten(base)
        keys = jax.random.split(jax.random.PRNGKey(100 + i), len(leaves))
        ft = jax.tree_util.tree_unflatten(tdef, [
            (l.astype(jnp.float32)
             + 0.01 * jax.random.normal(k, l.shape)).astype(l.dtype)
            for l, k in zip(leaves, keys)])
        ex = registry.add(capi.compress(base, ft, name=f"expert{i}",
                                        density=args.density, alpha=1.0))
        if i == 0:
            dense = uncompressed_baseline_bytes(ex)
            print(f"expert artifact: {ex.nbytes(PACKED):,} B packed "
                  f"({ex.nbytes(GOLOMB):,} B on the wire) vs "
                  f"{dense:,} B dense bf16 ({dense/ex.nbytes(PACKED):.1f}x)")

    engine = capi.serve(api, RT, base, registry, max_batch=4, cache_len=64,
                        device_cache_bytes=1 << 26)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, expert=f"expert{i % args.experts}",
                    prompt=jnp.asarray(rng.integers(1, cfg.vocab, 16),
                                       jnp.int32),
                    max_new_tokens=6)
            for i in range(args.requests)]

    t0 = time.perf_counter()
    engine.run(reqs)
    dt = time.perf_counter() - t0
    print(f"served {len(reqs)} requests across {args.experts} experts "
          f"in {dt:.1f}s")
    for r in reqs[:3]:
        print(f"  req{r.uid} [{r.expert}]: {r.out_tokens}")
    s = engine.swap_summary()
    print("swap stats:", {k: v for k, v in s.items()
                          if k in ('hits', 'misses', 'promotions',
                                   'store_to_host_bytes',
                                   'host_to_device_bytes', 'n_swaps',
                                   'n_waves', 'admitted', 'stack_builds')})
    dense_equiv = uncompressed_baseline_bytes(registry.get("expert0")) * 2
    print(f"wire bytes per miss: {dense_equiv:,} dense f32 baseline vs "
          f"{s['store_to_host_bytes'] // max(s['misses'],1):,} compressed "
          f"(experts stay packed on device: "
          f"{s['host_to_device_bytes'] // max(s['misses'],1):,} B resident)")
    print("OK")


if __name__ == "__main__":
    main()
